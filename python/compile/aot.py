"""AOT compiler: lower every model variant to HLO text + manifest.

This is the only place Python touches the pipeline: ``make artifacts``
runs it once, after which the rust coordinator is self-contained.

Interchange format is **HLO text**, never ``.serialize()``: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Outputs under ``--out-dir`` (default ``../artifacts``):
  <entry>_<variant>.<impl>.hlo.txt   e.g. train_gcn_mlp.pallas.hlo.txt
  manifest.json                      shapes / dtypes / param layout /
                                     arg order — the cross-language
                                     contract consumed by rust `runtime`.

Every artifact is emitted in two kernel flavours:
  pallas — L1 Pallas kernels (interpret=True) on the hot ops
  jnp    — plain XLA dots (the ref.py oracle), used by the rust
           integration tests to cross-check the pallas artifacts
           numerically and by the perf benches as the baseline.
"""

import argparse
import hashlib
import json
import os
import time

import jax

from . import kernels as K
from .model import ADAM, ModelConfig, make_entry_points

# The variant list covers every (encoder, decoder) cell the paper's
# tables need: Table 2/7 use {gcn,sage,mlp}+mlp; Table 8 adds the
# heterogeneous cells {gcn,rgcn} x {mlp,distmult}.
VARIANTS = [
    ("gcn", "mlp"),
    ("sage", "mlp"),
    ("mlp", "mlp"),
    ("gcn", "distmult"),
    ("rgcn", "mlp"),
    ("rgcn", "distmult"),
]

IMPLS = ("pallas", "jnp")


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    from jax._src.lib import xla_client as xc

    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _dtype_name(dt) -> str:
    return {"float32": "f32", "int32": "i32"}[str(dt)]


def _spec_json(name, sds):
    return {
        "name": name,
        "dtype": _dtype_name(sds.dtype),
        "shape": list(sds.shape),
    }


def lower_variant(cfg: ModelConfig, out_dir: str, impls) -> dict:
    """Lower all entry points of one variant in all kernel flavours."""
    layout, entries = make_entry_points(cfg)
    vjson = {
        "encoder": cfg.encoder,
        "decoder": cfg.decoder,
        "hetero": cfg.hetero,
        "params": layout.to_json(),
        "entries": {},
    }
    for entry_name, (fn, arg_spec) in entries.items():
        args = [s for (_, s) in arg_spec]
        outs = jax.eval_shape(fn, *args)
        if not isinstance(outs, (tuple, list)):
            outs = (outs,)
        ejson = {
            "args": [_spec_json(n, s) for (n, s) in arg_spec],
            "outputs": [_spec_json(f"out{i}", s) for i, s in enumerate(outs)],
            "artifacts": {},
        }
        for impl in impls:
            # A fresh wrapper per impl: jax's trace cache keys on function
            # identity and would otherwise serve the first impl's trace
            # for both flavours (the kernel dispatch is a global flag read
            # at trace time).
            def fn_impl(*a, _fn=fn, _impl=impl):
                K.use_impl(_impl)
                return _fn(*a)

            t0 = time.time()
            # keep_unused: the MLP encoder ignores `adj` (and the rust
            # packer supplies every manifest arg) — without this XLA
            # prunes the parameter and the call arity drifts.
            lowered = jax.jit(fn_impl, keep_unused=True).lower(*args)
            text = to_hlo_text(lowered)
            fname = f"{entry_name}_{cfg.variant}.{impl}.hlo.txt"
            with open(os.path.join(out_dir, fname), "w") as f:
                f.write(text)
            ejson["artifacts"][impl] = fname
            print(
                f"  {fname:44s} {len(text) // 1024:6d} KiB "
                f"({time.time() - t0:.1f}s)"
            )
        vjson["entries"][entry_name] = ejson
    return vjson


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--feat-dim", type=int, default=64)
    ap.add_argument("--hidden", type=int, default=64)
    ap.add_argument("--block-nodes", type=int, default=256)
    ap.add_argument("--block-edges", type=int, default=128)
    ap.add_argument("--score-batch", type=int, default=2048)
    ap.add_argument("--relations", type=int, default=4)
    ap.add_argument(
        "--variants",
        default="all",
        help="comma list of enc_dec variants, or 'all'",
    )
    ap.add_argument("--impls", default="pallas,jnp")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    impls = tuple(args.impls.split(","))
    for i in impls:
        assert i in IMPLS, i

    want = None if args.variants == "all" else set(args.variants.split(","))

    manifest = {
        "version": 1,
        "adam": ADAM,
        "config": {
            "feat_dim": args.feat_dim,
            "hidden": args.hidden,
            "block_nodes": args.block_nodes,
            "block_edges": args.block_edges,
            "score_batch": args.score_batch,
            "relations": args.relations,
        },
        "variants": {},
    }

    t_start = time.time()
    for enc, dec in VARIANTS:
        variant = f"{enc}_{dec}"
        if want is not None and variant not in want:
            continue
        cfg = ModelConfig(
            encoder=enc,
            decoder=dec,
            feat_dim=args.feat_dim,
            hidden=args.hidden,
            block_nodes=args.block_nodes,
            block_edges=args.block_edges,
            score_batch=args.score_batch,
            relations=args.relations,
        )
        print(f"[aot] variant {variant}")
        manifest["variants"][variant] = lower_variant(cfg, args.out_dir, impls)

    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    digest = hashlib.sha256(
        json.dumps(manifest, sort_keys=True).encode()
    ).hexdigest()[:12]
    print(
        f"[aot] wrote {mpath} (sha {digest}) in {time.time() - t_start:.1f}s"
    )


if __name__ == "__main__":
    main()
