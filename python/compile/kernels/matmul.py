"""L1: tiled Pallas matmul kernels — the MXU-shaped compute hot-spot.

The GCN/SAGE/MLP link-prediction models (L2, ``model.py``) spend their
FLOPs in dense matmuls over fixed-shape training blocks: ``X @ W``
(feature transform), ``A_hat @ XW`` (neighbour aggregation) and the
decoder scoring products. This module provides the three matmul layouts
those need (NN, NT, TN) as Pallas kernels plus a ``custom_vjp`` wrapper
so the *backward* pass also runs through the same kernels.

TPU adaptation (see DESIGN.md §Hardware-Adaptation): the CUDA story of
the original setting (threadblock tiling + shared-memory staging on
V100) maps to ``BlockSpec`` tiling for VMEM with the K grid axis
innermost and sequential, accumulating into the revisited output block.
Block sizes default to 128 (the MXU systolic edge) clamped to the
operand dims; ``f32`` accumulation via ``preferred_element_type``.

Kernels are lowered with ``interpret=True`` — mandatory for CPU-PJRT
execution (real TPU lowering emits Mosaic custom-calls the CPU plugin
cannot run). Correctness is pinned against ``ref.py`` by
``python/tests/test_kernels.py`` (hypothesis shape/dtype sweeps).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile edge: the MXU is a 128x128 systolic array; (8, 128) is the
# f32 VPU lane layout. Tiles are clamped to operand dims for small shapes.
DEFAULT_BLOCK = 128


def _pick_block(dim: int, preferred: int) -> int:
    """Largest tile <= preferred that keeps the grid small for tiny dims."""
    return min(dim, preferred)


def _mm_kernel(
    a_ref,
    b_ref,
    o_ref,
    *,
    nk: int,
    bk: int,
    k_total: int,
    trans_a: bool,
    trans_b: bool,
):
    """Grid point (i, j, k): accumulate one (bm, bk) x (bk, bn) product.

    The output BlockSpec maps every k to the same (i, j) block, and k is
    the innermost (sequential) grid axis, so ``o_ref`` acts as the VMEM
    accumulator that a scratch buffer would be on real hardware.

    When ``bk`` does not divide ``k_total`` the final K tile reads padded
    (undefined — NaN in interpret mode) lanes; they are masked to zero on
    both operands before feeding the MXU, the same predication a real
    Mosaic lowering applies at the tile edge.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...]
    b = b_ref[...]
    if trans_a:
        a = a.T
    if trans_b:
        b = b.T
    if k_total % bk != 0:
        valid = (k * bk + jax.lax.iota(jnp.int32, bk)) < k_total
        a = jnp.where(valid[None, :], a, 0.0)
        b = jnp.where(valid[:, None], b, 0.0)
    o_ref[...] += jnp.dot(a, b, preferred_element_type=jnp.float32)


def _mm_call(a, b, *, trans_a: bool, trans_b: bool, block: int):
    """Shared pallas_call builder for the NN / NT / TN layouts."""
    if trans_a:
        k_dim, m = a.shape
    else:
        m, k_dim = a.shape
    if trans_b:
        n, kb = b.shape
    else:
        kb, n = b.shape
    assert k_dim == kb, f"contraction mismatch: {a.shape} x {b.shape}"

    bm = _pick_block(m, block)
    bn = _pick_block(n, block)
    bk = _pick_block(k_dim, block)
    nm, nn, nk = pl.cdiv(m, bm), pl.cdiv(n, bn), pl.cdiv(k_dim, bk)

    if trans_a:
        a_spec = pl.BlockSpec((bk, bm), lambda i, j, k: (k, i))
    else:
        a_spec = pl.BlockSpec((bm, bk), lambda i, j, k: (i, k))
    if trans_b:
        b_spec = pl.BlockSpec((bn, bk), lambda i, j, k: (j, k))
    else:
        b_spec = pl.BlockSpec((bk, bn), lambda i, j, k: (k, j))
    o_spec = pl.BlockSpec((bm, bn), lambda i, j, k: (i, j))

    kernel = functools.partial(
        _mm_kernel,
        nk=nk,
        bk=bk,
        k_total=k_dim,
        trans_a=trans_a,
        trans_b=trans_b,
    )
    return pl.pallas_call(
        kernel,
        grid=(nm, nn, nk),
        in_specs=[a_spec, b_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,  # CPU-PJRT target; see module docstring
    )(a, b)


def mm(a, b, *, block: int = DEFAULT_BLOCK):
    """``a @ b`` with a [M, K], b [K, N] -> [M, N] (no custom_vjp)."""
    return _mm_call(a, b, trans_a=False, trans_b=False, block=block)


def mm_nt(a, b, *, block: int = DEFAULT_BLOCK):
    """``a @ b.T`` with a [M, K], b [N, K] -> [M, N]."""
    return _mm_call(a, b, trans_a=False, trans_b=True, block=block)


def mm_tn(a, b, *, block: int = DEFAULT_BLOCK):
    """``a.T @ b`` with a [K, M], b [K, N] -> [M, N]."""
    return _mm_call(a, b, trans_a=True, trans_b=False, block=block)


@jax.custom_vjp
def matmul(a, b):
    """Differentiable ``a @ b`` whose forward AND backward run the tiled
    Pallas kernels (da = g @ b.T via NT, db = a.T @ g via TN)."""
    return mm(a, b)


def _matmul_fwd(a, b):
    return mm(a, b), (a, b)


def _matmul_bwd(res, g):
    a, b = res
    return mm_nt(g, b), mm_tn(a, g)


matmul.defvjp(_matmul_fwd, _matmul_bwd)
