"""L1: fused GCN aggregation kernel ``A_hat @ (X @ W)``.

The GCN layer's hot loop is the two-stage product of the row-normalized
block adjacency with the transformed features. On TPU the win of fusing
is keeping the intermediate ``XW`` resident in VMEM instead of a round
trip through HBM between two kernel launches — the analogue of what the
CUDA formulation does with shared-memory staging across the two GEMMs.

At the training block sizes used here (Bn <= 256, F, H <= 256) the full
``X``, ``W`` and an ``XW`` tile all fit in VMEM at once (see DESIGN.md
§Perf for the footprint budget), so the kernel streams row-blocks of
``A_hat`` over a VMEM-resident ``XW``:

    grid = (Bn / bm,)       one program per adjacency row-block
    x, w  : full-array BlockSpecs (VMEM resident)
    adj   : (bm, Bn) row block
    out   : (bm, H)

``XW`` is recomputed per row-block; with Bn/bm = 2..4 row blocks and
the transform being O(Bn·F·H) vs aggregation O(Bn²·H), the recompute
cost is small at these shapes and vanishes as Bn grows (documented in
EXPERIMENTS.md §Perf).

A ``custom_vjp`` routes the backward pass through the tiled matmul
kernels: with ``P = A_hat @ X W``,  ``dXW = A_hat.T @ g``, then
``dX = dXW @ W.T`` and ``dW = X.T @ dXW``.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import matmul as mmk


def _gcn_agg_kernel(adj_ref, x_ref, w_ref, o_ref):
    # x, w are VMEM-resident full arrays; adj is one row-block.
    xw = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = jnp.dot(
        adj_ref[...], xw, preferred_element_type=jnp.float32
    )


def gcn_agg_fwd_kernel(adj, x, w, *, block_rows: int = 128):
    """Forward-only fused ``adj @ (x @ w)`` pallas kernel."""
    bn_nodes, f = x.shape
    f2, h = w.shape
    assert f == f2 and adj.shape == (bn_nodes, bn_nodes)
    bm = min(bn_nodes, block_rows)
    grid = (pl.cdiv(bn_nodes, bm),)
    return pl.pallas_call(
        _gcn_agg_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn_nodes), lambda i: (i, 0)),
            pl.BlockSpec((bn_nodes, f), lambda i: (0, 0)),
            pl.BlockSpec((f, h), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, h), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bn_nodes, h), jnp.float32),
        interpret=True,
    )(adj, x, w)


@jax.custom_vjp
def gcn_agg(adj, x, w):
    """Differentiable fused GCN aggregation: ``adj @ (x @ w)``.

    ``adj`` is treated as data (the sampled block adjacency): its
    cotangent is returned as zeros and DCE'd by XLA since training only
    differentiates with respect to the flat parameter vector.
    """
    return gcn_agg_fwd_kernel(adj, x, w)


def _gcn_agg_vjp_fwd(adj, x, w):
    return gcn_agg_fwd_kernel(adj, x, w), (adj, x, w)


def _gcn_agg_vjp_bwd(res, g):
    adj, x, w = res
    dxw = mmk.mm_tn(adj, g)  # adj.T @ g          [Bn, H]
    dx = mmk.mm_nt(dxw, w)  # dxw @ w.T           [Bn, F]
    dw = mmk.mm_tn(x, dxw)  # x.T @ dxw           [F, H]
    return jnp.zeros_like(adj), dx, dw


gcn_agg.defvjp(_gcn_agg_vjp_fwd, _gcn_agg_vjp_bwd)
