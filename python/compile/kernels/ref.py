"""Pure-jnp correctness oracles for every Pallas kernel (L1).

These are the ground truth the kernel tests (``python/tests/``) pin
against, and double as the ``impl='jnp'`` dispatch target so that every
AOT artifact can be emitted in both a Pallas-kernel flavour and a plain
XLA-dot flavour (the rust integration tests cross-check the two at the
artifact level, and the perf benches compare them).
"""

import jax.numpy as jnp


def mm(a, b):
    """a @ b."""
    return jnp.dot(a, b, preferred_element_type=jnp.float32)


def mm_nt(a, b):
    """a @ b.T with b stored [N, K]."""
    return jnp.dot(a, b.T, preferred_element_type=jnp.float32)


def mm_tn(a, b):
    """a.T @ b with a stored [K, M]."""
    return jnp.dot(a.T, b, preferred_element_type=jnp.float32)


def gcn_agg(adj, x, w):
    """adj @ (x @ w)."""
    return jnp.dot(adj, jnp.dot(x, w, preferred_element_type=jnp.float32),
                   preferred_element_type=jnp.float32)


def had_mm(u, v, w):
    """(u * v) @ w."""
    return jnp.dot(u * v, w, preferred_element_type=jnp.float32)
