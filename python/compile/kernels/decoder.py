"""L1: fused link-decoder scoring kernel ``(u * v) @ W``.

The MLP link decoder's first layer consumes the Hadamard product of the
two endpoint embeddings (paper App. A: e0 = r_u ⊙ r_v). Fusing the
elementwise product into the matmul prologue saves one HBM round-trip
for the [S, H] intermediate — on TPU the product is a VPU pass over the
VMEM-resident tile immediately before it is fed to the MXU.

Backward (custom_vjp), with  P = (u ⊙ v) W :
    dW = (u ⊙ v).T @ g        (TN matmul kernel)
    dU = (g @ W.T) ⊙ v        (NT matmul kernel + VPU elementwise)
    dV = (g @ W.T) ⊙ u
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import matmul as mmk


def _had_mm_kernel(u_ref, v_ref, w_ref, o_ref, *, nk: int, bk: int,
                   k_total: int):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    prod = u_ref[...] * v_ref[...]  # fused Hadamard prologue (VPU)
    w = w_ref[...]
    if k_total % bk != 0:
        # Mask padded K lanes (undefined in interpret mode) on both sides.
        valid = (k * bk + jax.lax.iota(jnp.int32, bk)) < k_total
        prod = jnp.where(valid[None, :], prod, 0.0)
        w = jnp.where(valid[:, None], w, 0.0)
    o_ref[...] += jnp.dot(prod, w, preferred_element_type=jnp.float32)


def had_mm_fwd_kernel(u, v, w, *, block: int = 128):
    """Forward fused ``(u * v) @ w``: u, v [S, H], w [H, N] -> [S, N]."""
    s, h = u.shape
    h2, n = w.shape
    assert v.shape == (s, h) and h2 == h
    bs = min(s, block)
    bk = min(h, block)
    grid = (pl.cdiv(s, bs), pl.cdiv(h, bk))
    row_spec = pl.BlockSpec((bs, bk), lambda i, k: (i, k))
    return pl.pallas_call(
        functools.partial(_had_mm_kernel, nk=grid[1], bk=bk, k_total=h),
        grid=grid,
        in_specs=[
            row_spec,
            row_spec,
            pl.BlockSpec((bk, n), lambda i, k: (k, 0)),
        ],
        out_specs=pl.BlockSpec((bs, n), lambda i, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((s, n), jnp.float32),
        interpret=True,
    )(u, v, w)


@jax.custom_vjp
def had_mm(u, v, w):
    """Differentiable fused ``(u * v) @ w`` decoder product."""
    return had_mm_fwd_kernel(u, v, w)


def _had_mm_vjp_fwd(u, v, w):
    return had_mm_fwd_kernel(u, v, w), (u, v, w)


def _had_mm_vjp_bwd(res, g):
    u, v, w = res
    gw = mmk.mm_nt(g, w)  # g @ w.T   [S, H]
    du = gw * v
    dv = gw * u
    dw = mmk.mm_tn(u * v, g)  # (u ⊙ v).T @ g   [H, N]
    return du, dv, dw


had_mm.defvjp(_had_mm_vjp_fwd, _had_mm_vjp_bwd)
