"""L1 kernel package: Pallas kernels + jnp reference, behind a dispatch.

``use_impl('pallas' | 'jnp')`` selects which implementation the L2 model
traces against; ``aot.py`` emits every artifact in both flavours so the
rust layer can cross-check them numerically and the perf benches can
compare them.
"""

from . import matmul as _pallas_mm
from . import gcn_agg as _pallas_gcn
from . import decoder as _pallas_dec
from . import ref as _ref

_IMPL = "pallas"


def use_impl(name: str) -> None:
    """Select the kernel implementation for subsequent traces."""
    global _IMPL
    if name not in ("pallas", "jnp"):
        raise ValueError(f"unknown kernel impl {name!r}")
    _IMPL = name


def current_impl() -> str:
    return _IMPL


def matmul(a, b):
    """Differentiable a @ b via the selected implementation."""
    if _IMPL == "pallas":
        return _pallas_mm.matmul(a, b)
    return _ref.mm(a, b)


def gcn_agg(adj, x, w):
    """Differentiable fused adj @ (x @ w)."""
    if _IMPL == "pallas":
        return _pallas_gcn.gcn_agg(adj, x, w)
    return _ref.gcn_agg(adj, x, w)


def had_mm(u, v, w):
    """Differentiable fused (u ⊙ v) @ w."""
    if _IMPL == "pallas":
        return _pallas_dec.had_mm(u, v, w)
    return _ref.had_mm(u, v, w)
