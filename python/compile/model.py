"""L2: JAX link-prediction models (build-time only, never on the hot path).

Implements the paper's model zoo over fixed-shape sampled blocks:

* encoders — GCN [18], GraphSAGE [12], MLP (graph-agnostic baseline) for
  homogeneous graphs; RGCN [28] with basis decomposition for the
  heterogeneous E-comm-like graphs. All use LayerNorm before a PReLU
  activation (paper §4.1, following Chen et al. / You et al.).
* decoders — 2-layer MLP over the Hadamard product of endpoint
  embeddings (paper App. A) and DistMult [35] for heterogeneous graphs.
* entry points — ``train_step`` (one fused Adam step), ``grad_step``
  (gradients only; used by GGS sync-SGD and the LLCG server
  correction), ``encode`` (block embeddings for evaluation) and
  ``score`` (decoder-only candidate scoring for MRR evaluation).

Parameters live in a single flat f32 vector. The slice layout is
recorded in the AOT manifest so the rust coordinator (L3) can
initialize, average (model aggregation φ) and ship weights as one
buffer; inside the model the vector is unflattened with static slices.

All dense compute routes through ``kernels.*`` (Pallas tiled matmul /
fused aggregation / fused decoder product — see ``kernels/``), so both
the forward and the backward pass execute the L1 kernels.
"""

import math
from dataclasses import dataclass, field
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from . import kernels as K

# --------------------------------------------------------------------------
# Configuration
# --------------------------------------------------------------------------

ENCODERS = ("gcn", "sage", "mlp", "rgcn")
DECODERS = ("mlp", "distmult")

# Adam exactly as in the paper's setup (lr = 0.001, App. A).
ADAM = dict(lr=1e-3, beta1=0.9, beta2=0.999, eps=1e-8)


@dataclass
class ModelConfig:
    """Static shape/arch config baked into each AOT artifact."""

    encoder: str = "gcn"
    decoder: str = "mlp"
    feat_dim: int = 64          # F  — input feature width
    hidden: int = 64            # H  — embedding width
    layers: int = 2             # encoder depth (paper: 2 everywhere)
    dec_layers: int = 2         # decoder MLP depth (paper App. A)
    block_nodes: int = 256      # Bn — padded nodes per sampled block
    block_edges: int = 128      # Be — pos/neg edge pairs per batch
    score_batch: int = 2048     # S  — pairs per eval scoring call
    relations: int = 4          # R  — edge types (hetero only)
    rgcn_bases: int = 4         # basis decomposition rank (paper App. A)

    def __post_init__(self):
        assert self.encoder in ENCODERS, self.encoder
        assert self.decoder in DECODERS, self.decoder

    @property
    def variant(self) -> str:
        return f"{self.encoder}_{self.decoder}"

    @property
    def hetero(self) -> bool:
        """Whether batches carry per-relation adjacency / edge types."""
        return self.encoder == "rgcn" or self.decoder == "distmult"


# --------------------------------------------------------------------------
# Flat-parameter layout
# --------------------------------------------------------------------------


@dataclass
class TensorSpec:
    name: str
    shape: Tuple[int, ...]
    init: str  # "glorot" | "zeros" | "ones" | "prelu" | "normal"
    offset: int

    @property
    def size(self) -> int:
        return int(math.prod(self.shape))


@dataclass
class Layout:
    """Named-tensor views over one flat f32 parameter vector."""

    tensors: List[TensorSpec] = field(default_factory=list)
    total: int = 0

    def add(self, name: str, shape: Tuple[int, ...], init: str) -> None:
        self.tensors.append(TensorSpec(name, tuple(shape), init, self.total))
        self.total += int(math.prod(shape))

    def unflatten(self, flat: jnp.ndarray) -> Dict[str, jnp.ndarray]:
        out = {}
        for t in self.tensors:
            out[t.name] = jax.lax.dynamic_slice(
                flat, (t.offset,), (t.size,)
            ).reshape(t.shape)
        return out

    def to_json(self) -> dict:
        return {
            "total": self.total,
            "tensors": [
                {
                    "name": t.name,
                    "shape": list(t.shape),
                    "init": t.init,
                    "offset": t.offset,
                }
                for t in self.tensors
            ],
        }


def build_layout(cfg: ModelConfig) -> Layout:
    """Parameter layout for an (encoder, decoder) variant.

    Kept deliberately deterministic and explicit: the rust side
    re-implements glorot/zeros/ones/prelu init from this table, so
    ordering and naming are a cross-language contract (tested on both
    sides).
    """
    lo = Layout()
    h, f = cfg.hidden, cfg.feat_dim

    for layer in range(cfg.layers):
        d_in = f if layer == 0 else h
        p = f"enc{layer}"
        if cfg.encoder == "gcn":
            lo.add(f"{p}.w", (d_in, h), "glorot")
        elif cfg.encoder == "sage":
            lo.add(f"{p}.w_self", (d_in, h), "glorot")
            lo.add(f"{p}.w_nbr", (d_in, h), "glorot")
        elif cfg.encoder == "mlp":
            lo.add(f"{p}.w", (d_in, h), "glorot")
        elif cfg.encoder == "rgcn":
            lo.add(f"{p}.w_self", (d_in, h), "glorot")
            lo.add(f"{p}.basis", (cfg.rgcn_bases, d_in, h), "glorot")
            lo.add(f"{p}.coeff", (cfg.relations, cfg.rgcn_bases), "glorot")
        lo.add(f"{p}.b", (h,), "zeros")
        lo.add(f"{p}.ln_scale", (h,), "ones")
        lo.add(f"{p}.ln_bias", (h,), "zeros")
        lo.add(f"{p}.prelu", (1,), "prelu")

    if cfg.decoder == "mlp":
        for layer in range(cfg.dec_layers):
            d_out = 1 if layer == cfg.dec_layers - 1 else h
            p = f"dec{layer}"
            lo.add(f"{p}.w", (h, d_out), "glorot")
            lo.add(f"{p}.b", (d_out,), "zeros")
            if layer != cfg.dec_layers - 1:
                lo.add(f"{p}.prelu", (1,), "prelu")
    else:  # distmult: one embedding per relation
        lo.add("dec.rel", (cfg.relations, h), "normal")

    return lo


# --------------------------------------------------------------------------
# Building blocks
# --------------------------------------------------------------------------


def prelu(a: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """PReLU with a scalar learned slope (paper §4.1)."""
    return jnp.where(x >= 0.0, x, a[0] * x)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    """LayerNorm over the feature axis, applied before activation."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * scale + bias


def _enc_layer_post(p, pre, prefix):
    return prelu(p[f"{prefix}.prelu"],
                 layer_norm(pre, p[f"{prefix}.ln_scale"],
                            p[f"{prefix}.ln_bias"]))


# --------------------------------------------------------------------------
# Encoders: block features (+ adjacency) -> node embeddings [Bn, H]
# --------------------------------------------------------------------------


def encode_homogeneous(cfg: ModelConfig, p, feats, adj):
    """GCN / SAGE / MLP over one padded dense block.

    ``adj`` is the row-normalized block adjacency prepared by the rust
    sampler: for GCN it includes self-loops (A_hat = D^-1 (A + I)); for
    SAGE it is neighbours-only (the self path is the separate W_self
    term); the MLP encoder ignores it (graph-agnostic baseline).
    """
    x = feats
    for layer in range(cfg.layers):
        pr = f"enc{layer}"
        if cfg.encoder == "gcn":
            pre = K.gcn_agg(adj, x, p[f"{pr}.w"]) + p[f"{pr}.b"]
        elif cfg.encoder == "sage":
            pre = (
                K.matmul(x, p[f"{pr}.w_self"])
                + K.gcn_agg(adj, x, p[f"{pr}.w_nbr"])
                + p[f"{pr}.b"]
            )
        else:  # mlp
            pre = K.matmul(x, p[f"{pr}.w"]) + p[f"{pr}.b"]
        x = _enc_layer_post(p, pre, pr)
    return x


def encode_rgcn(cfg: ModelConfig, p, feats, adjr):
    """RGCN with basis decomposition over per-relation block adjacency.

    ``adjr`` is [R, Bn, Bn], each relation row-normalized. Relation
    weights W_r = Σ_b coeff[r, b] · basis[b] (paper App. A: 4 bases).
    The relation loop is unrolled (R is a small static constant).
    """
    x = feats
    for layer in range(cfg.layers):
        pr = f"enc{layer}"
        pre = K.matmul(x, p[f"{pr}.w_self"]) + p[f"{pr}.b"]
        basis = p[f"{pr}.basis"]  # [B, d_in, H]
        coeff = p[f"{pr}.coeff"]  # [R, B]
        for r in range(cfg.relations):
            w_r = jnp.einsum("b,bdh->dh", coeff[r], basis)
            pre = pre + K.gcn_agg(adjr[r], x, w_r)
        x = _enc_layer_post(p, pre, pr)
    return x


def encode(cfg: ModelConfig, p, feats, adj):
    if cfg.encoder == "rgcn":
        return encode_rgcn(cfg, p, feats, adj)
    return encode_homogeneous(cfg, p, feats, adj)


# --------------------------------------------------------------------------
# Decoders: endpoint embeddings -> link logits
# --------------------------------------------------------------------------


def decode_mlp(cfg: ModelConfig, p, r_u, r_v):
    """2-layer MLP over r_u ⊙ r_v (paper App. A), fused first layer."""
    e = K.had_mm(r_u, r_v, p["dec0.w"]) + p["dec0.b"]
    e = prelu(p["dec0.prelu"], e)
    for layer in range(1, cfg.dec_layers):
        pr = f"dec{layer}"
        e = K.matmul(e, p[f"{pr}.w"]) + p[f"{pr}.b"]
        if layer != cfg.dec_layers - 1:
            e = prelu(p[f"{pr}.prelu"], e)
    return e[:, 0]


def decode_distmult(cfg: ModelConfig, p, r_u, r_v, rel):
    """DistMult: sum(r_u ⊙ rel_emb[rel] ⊙ r_v)."""
    rel_emb = jnp.take(p["dec.rel"], rel, axis=0)  # [S, H]
    return jnp.sum(r_u * rel_emb * r_v, axis=-1)


def decode(cfg: ModelConfig, p, r_u, r_v, rel=None):
    if cfg.decoder == "mlp":
        return decode_mlp(cfg, p, r_u, r_v)
    return decode_distmult(cfg, p, r_u, r_v, rel)


# --------------------------------------------------------------------------
# Loss + entry points
# --------------------------------------------------------------------------


def link_loss(cfg: ModelConfig, layout: Layout, flat, batch):
    """Masked BCE-with-logits over (pos, neg) edge pairs in one block.

    ``batch`` is the tuple produced by the rust sampler:
      homogeneous: (feats, adj, pos_u, pos_v, neg_v, mask)
      hetero:      (feats, adj_or_adjr, pos_u, pos_v, rel, neg_v, mask)
    One negative per positive, sharing the head u (paper §4.1).
    """
    p = layout.unflatten(flat)
    if cfg.hetero:
        feats, adj, pos_u, pos_v, rel, neg_v, mask = batch
    else:
        feats, adj, pos_u, pos_v, neg_v, mask = batch
        rel = None
    emb = encode(cfg, p, feats, adj)
    r_u = jnp.take(emb, pos_u, axis=0)
    r_v = jnp.take(emb, pos_v, axis=0)
    r_n = jnp.take(emb, neg_v, axis=0)
    pos_logit = decode(cfg, p, r_u, r_v, rel)
    neg_logit = decode(cfg, p, r_u, r_n, rel)
    # BCE with logits: -log σ(pos) - log(1 - σ(neg))
    per_edge = jax.nn.softplus(-pos_logit) + jax.nn.softplus(neg_logit)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.sum(per_edge * mask) / denom


def make_entry_points(cfg: ModelConfig):
    """Build the four jit-able entry points for one model variant.

    Returns (layout, {name: (fn, example_args)}) where example_args are
    ``jax.ShapeDtypeStruct``s — exactly what ``aot.py`` lowers with and
    what the manifest records for the rust literal packer.
    """
    layout = build_layout(cfg)
    f32, i32 = jnp.float32, jnp.int32
    P = layout.total
    Bn, Be, S = cfg.block_nodes, cfg.block_edges, cfg.score_batch
    F, H, R = cfg.feat_dim, cfg.hidden, cfg.relations

    sd = jax.ShapeDtypeStruct
    if cfg.encoder == "rgcn":
        adj_spec = sd((R, Bn, Bn), f32)
    else:
        adj_spec = sd((Bn, Bn), f32)

    if cfg.hetero:
        batch_spec = [
            ("feats", sd((Bn, F), f32)),
            ("adj", adj_spec),
            ("pos_u", sd((Be,), i32)),
            ("pos_v", sd((Be,), i32)),
            ("rel", sd((Be,), i32)),
            ("neg_v", sd((Be,), i32)),
            ("mask", sd((Be,), f32)),
        ]
    else:
        batch_spec = [
            ("feats", sd((Bn, F), f32)),
            ("adj", adj_spec),
            ("pos_u", sd((Be,), i32)),
            ("pos_v", sd((Be,), i32)),
            ("neg_v", sd((Be,), i32)),
            ("mask", sd((Be,), f32)),
        ]

    loss_fn = lambda flat, *batch: link_loss(cfg, layout, flat, batch)

    def train_step(flat, m, v, t, *batch):
        """One SGD step with fused Adam (lr/betas from the paper)."""
        loss, g = jax.value_and_grad(loss_fn)(flat, *batch)
        t1 = t + 1.0
        m1 = ADAM["beta1"] * m + (1.0 - ADAM["beta1"]) * g
        v1 = ADAM["beta2"] * v + (1.0 - ADAM["beta2"]) * g * g
        m_hat = m1 / (1.0 - ADAM["beta1"] ** t1[0])
        v_hat = v1 / (1.0 - ADAM["beta2"] ** t1[0])
        flat1 = flat - ADAM["lr"] * m_hat / (jnp.sqrt(v_hat) + ADAM["eps"])
        return flat1, m1, v1, t1, loss

    def grad_step(flat, *batch):
        """Loss + raw gradient (GGS allreduce / LLCG server correction)."""
        loss, g = jax.value_and_grad(loss_fn)(flat, *batch)
        return g, loss

    def encode_block(flat, feats, adj):
        """Embeddings for one evaluation block."""
        p = layout.unflatten(flat)
        return (encode(cfg, p, feats, adj),)

    if cfg.decoder == "distmult":

        def score(flat, emb_u, emb_v, rel):
            p = layout.unflatten(flat)
            return (decode(cfg, p, emb_u, emb_v, rel),)

        score_spec = [
            ("params", sd((P,), f32)),
            ("emb_u", sd((S, H), f32)),
            ("emb_v", sd((S, H), f32)),
            ("rel", sd((S,), i32)),
        ]
    else:

        def score(flat, emb_u, emb_v):
            p = layout.unflatten(flat)
            return (decode(cfg, p, emb_u, emb_v),)

        score_spec = [
            ("params", sd((P,), f32)),
            ("emb_u", sd((S, H), f32)),
            ("emb_v", sd((S, H), f32)),
        ]

    params_spec = ("params", sd((P,), f32))
    opt_spec = [
        params_spec,
        ("adam_m", sd((P,), f32)),
        ("adam_v", sd((P,), f32)),
        ("adam_t", sd((1,), f32)),
    ]

    entries = {
        "train": (train_step, opt_spec + batch_spec),
        "grad": (grad_step, [params_spec] + batch_spec),
        "encode": (
            encode_block,
            [params_spec, ("feats", sd((Bn, F), f32)), ("adj", adj_spec)],
        ),
        "score": (score, score_spec),
    }
    return layout, entries
