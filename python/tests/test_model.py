"""L2 model tests: layouts, shapes, loss semantics, optimizer step.

These pin the *semantic* contract the rust coordinator depends on:
parameter layout determinism, entry-point signatures, masked loss,
Adam update behaviour, and pallas-vs-jnp agreement at the model level.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

import compile.kernels as K
from compile.model import (
    ADAM,
    Layout,
    ModelConfig,
    build_layout,
    link_loss,
    make_entry_points,
)

SMALL = dict(feat_dim=8, hidden=8, block_nodes=24, block_edges=12,
             score_batch=16)


def small_cfg(encoder="gcn", decoder="mlp"):
    return ModelConfig(encoder=encoder, decoder=decoder, **SMALL)


def _sparse_row_norm_adj(rng, n, deg=4):
    """Random sparse row-stochastic adjacency with self-loops.

    A uniform dense adjacency would collapse GCN embeddings to the global
    mean (every row identical), which is unrepresentative of the sampled
    blocks the rust sampler actually produces.
    """
    adj = np.zeros((n, n), np.float32)
    for i in range(n):
        nbrs = rng.choice(n, size=min(deg, n), replace=False)
        adj[i, nbrs] = 1.0
        adj[i, i] = 1.0
    adj /= adj.sum(-1, keepdims=True)
    return adj


def make_batch(cfg, rng, seed_mask_ones=True):
    Bn, Be, F, R = (cfg.block_nodes, cfg.block_edges, cfg.feat_dim,
                    cfg.relations)
    feats = rng.normal(size=(Bn, F)).astype(np.float32)
    if cfg.encoder == "rgcn":
        adj = np.stack(
            [_sparse_row_norm_adj(rng, Bn) for _ in range(R)]
        )
    else:
        adj = _sparse_row_norm_adj(rng, Bn)
    ints = lambda: rng.integers(0, Bn, size=(Be,)).astype(np.int32)
    mask = np.ones(Be, np.float32) if seed_mask_ones else None
    if cfg.hetero:
        rel = rng.integers(0, R, size=(Be,)).astype(np.int32)
        return (feats, adj, ints(), ints(), rel, ints(), mask)
    return (feats, adj, ints(), ints(), ints(), mask)


def init_flat(layout, rng, scale=0.1):
    return (rng.normal(size=(layout.total,)) * scale).astype(np.float32)


# ------------------------------------------------------------ layouts


@pytest.mark.parametrize("enc", ["gcn", "sage", "mlp", "rgcn"])
@pytest.mark.parametrize("dec", ["mlp", "distmult"])
def test_layout_deterministic_and_packed(enc, dec):
    cfg = small_cfg(enc, dec)
    a, b = build_layout(cfg), build_layout(cfg)
    assert [t.name for t in a.tensors] == [t.name for t in b.tensors]
    # offsets are contiguous and non-overlapping
    off = 0
    for t in a.tensors:
        assert t.offset == off
        off += t.size
    assert off == a.total


def test_layout_unflatten_roundtrip():
    cfg = small_cfg()
    lo = build_layout(cfg)
    flat = jnp.arange(lo.total, dtype=jnp.float32)
    parts = lo.unflatten(flat)
    # every flat element appears exactly once across tensors
    got = jnp.concatenate([parts[t.name].reshape(-1) for t in lo.tensors])
    np.testing.assert_array_equal(np.asarray(got), np.asarray(flat))


def test_layout_names_unique():
    lo = build_layout(small_cfg("rgcn", "distmult"))
    names = [t.name for t in lo.tensors]
    assert len(names) == len(set(names))


# ----------------------------------------------------------- entries


@pytest.mark.parametrize(
    "enc,dec",
    [("gcn", "mlp"), ("sage", "mlp"), ("mlp", "mlp"),
     ("gcn", "distmult"), ("rgcn", "mlp"), ("rgcn", "distmult")],
)
def test_entry_shapes(enc, dec):
    cfg = small_cfg(enc, dec)
    layout, entries = make_entry_points(cfg)
    rng = np.random.default_rng(0)
    batch = make_batch(cfg, rng)
    flat = init_flat(layout, rng)
    m = np.zeros_like(flat)
    t = np.zeros(1, np.float32)

    fn, _ = entries["train"]
    out = jax.jit(fn)(flat, m, m, t, *batch)
    assert out[0].shape == (layout.total,)
    assert out[3].shape == (1,) and float(out[3][0]) == 1.0
    assert out[4].shape == ()

    fn, _ = entries["grad"]
    g, loss = jax.jit(fn)(flat, *batch)
    assert g.shape == (layout.total,) and loss.shape == ()

    fn, _ = entries["encode"]
    (emb,) = jax.jit(fn)(flat, batch[0], batch[1])
    assert emb.shape == (cfg.block_nodes, cfg.hidden)

    fn, spec = entries["score"]
    S = cfg.score_batch
    eu = rng.normal(size=(S, cfg.hidden)).astype(np.float32)
    ev = rng.normal(size=(S, cfg.hidden)).astype(np.float32)
    if dec == "distmult":
        rel = rng.integers(0, cfg.relations, size=(S,)).astype(np.int32)
        (s,) = jax.jit(fn)(flat, eu, ev, rel)
    else:
        (s,) = jax.jit(fn)(flat, eu, ev)
    assert s.shape == (S,)


def test_entry_arg_specs_match_callables():
    """The manifest arg specs must exactly describe what the fn accepts —
    this is the cross-language packing contract."""
    cfg = small_cfg("gcn", "mlp")
    _, entries = make_entry_points(cfg)
    for name, (fn, spec) in entries.items():
        args = [
            jnp.zeros(s.shape, s.dtype) if str(s.dtype) == "float32"
            else jnp.zeros(s.shape, jnp.int32)
            for (_, s) in spec
        ]
        jax.eval_shape(fn, *args)  # raises on mismatch


# ------------------------------------------------------------- loss


def test_loss_mask_excludes_padding():
    cfg = small_cfg()
    layout, _ = make_entry_points(cfg)
    rng = np.random.default_rng(1)
    feats, adj, pu, pv, nv, _ = make_batch(cfg, rng)
    flat = jnp.asarray(init_flat(layout, rng))

    full = np.ones(cfg.block_edges, np.float32)
    half = full.copy()
    half[cfg.block_edges // 2:] = 0.0
    # Perturb the masked-out tail: loss must not change.
    pu2, pv2, nv2 = pu.copy(), pv.copy(), nv.copy()
    pu2[cfg.block_edges // 2:] = 0
    pv2[cfg.block_edges // 2:] = 1
    nv2[cfg.block_edges // 2:] = 2
    l1 = link_loss(cfg, layout, flat, (feats, adj, pu, pv, nv, half))
    l2 = link_loss(cfg, layout, flat, (feats, adj, pu2, pv2, nv2, half))
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)


def test_loss_at_zero_params_is_2ln2():
    """With all-zero weights every logit is 0 → BCE = 2·ln2 exactly."""
    cfg = small_cfg()
    layout, _ = make_entry_points(cfg)
    rng = np.random.default_rng(2)
    batch = make_batch(cfg, rng)
    flat = jnp.zeros(layout.total, jnp.float32)
    loss = link_loss(cfg, layout, flat, batch)
    np.testing.assert_allclose(float(loss), 2 * np.log(2), rtol=1e-5)


def test_mlp_encoder_ignores_graph():
    cfg = small_cfg("mlp", "mlp")
    layout, entries = make_entry_points(cfg)
    rng = np.random.default_rng(3)
    feats, adj, pu, pv, nv, mask = make_batch(cfg, rng)
    flat = jnp.asarray(init_flat(layout, rng))
    fn, _ = entries["grad"]
    _, l1 = jax.jit(fn)(flat, feats, adj, pu, pv, nv, mask)
    adj2 = np.zeros_like(adj)
    _, l2 = jax.jit(fn)(flat, feats, adj2, pu, pv, nv, mask)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)


def test_gcn_encoder_uses_graph():
    cfg = small_cfg("gcn", "mlp")
    layout, entries = make_entry_points(cfg)
    rng = np.random.default_rng(4)
    feats, adj, pu, pv, nv, mask = make_batch(cfg, rng)
    flat = jnp.asarray(init_flat(layout, rng, scale=0.5))
    fn, _ = entries["encode"]
    (e1,) = jax.jit(fn)(flat, feats, adj)
    (e2,) = jax.jit(fn)(flat, feats, np.eye(cfg.block_nodes, dtype=np.float32))
    assert not np.allclose(np.asarray(e1), np.asarray(e2), atol=1e-4)


# ---------------------------------------------------------- training


def test_train_step_is_adam():
    """One train_step must equal grad_step + a hand-rolled Adam update."""
    cfg = small_cfg()
    layout, entries = make_entry_points(cfg)
    rng = np.random.default_rng(5)
    batch = make_batch(cfg, rng)
    flat = init_flat(layout, rng)
    m = np.zeros_like(flat)
    v = np.zeros_like(flat)
    t = np.zeros(1, np.float32)

    train, _ = entries["train"]
    grad, _ = entries["grad"]
    f1, m1, v1, t1, loss1 = jax.jit(train)(flat, m, v, t, *batch)
    g, loss2 = jax.jit(grad)(flat, *batch)
    np.testing.assert_allclose(float(loss1), float(loss2), rtol=1e-6)

    g = np.asarray(g)
    em = ADAM["beta1"] * m + (1 - ADAM["beta1"]) * g
    ev = ADAM["beta2"] * v + (1 - ADAM["beta2"]) * g * g
    mh = em / (1 - ADAM["beta1"] ** 1)
    vh = ev / (1 - ADAM["beta2"] ** 1)
    ef = flat - ADAM["lr"] * mh / (np.sqrt(vh) + ADAM["eps"])
    np.testing.assert_allclose(np.asarray(f1), ef, rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(np.asarray(m1), em, rtol=1e-4, atol=1e-7)
    np.testing.assert_allclose(np.asarray(v1), ev, rtol=1e-4, atol=1e-9)


@pytest.mark.parametrize("enc", ["gcn", "sage"])
def test_loss_decreases_under_training(enc):
    """A few hundred steps on a fixed learnable batch must cut the loss —
    end-to-end sanity of encoder + decoder + Adam."""
    cfg = small_cfg(enc)
    layout, entries = make_entry_points(cfg)
    rng = np.random.default_rng(6)
    batch = make_batch(cfg, rng)
    flat = init_flat(layout, rng)
    m = np.zeros_like(flat)
    v = np.zeros_like(flat)
    t = np.zeros(1, np.float32)
    step = jax.jit(entries["train"][0])
    first = None
    for i in range(200):
        flat, m, v, t, loss = step(flat, m, v, t, *batch)
        if first is None:
            first = float(loss)
    assert float(loss) < first * 0.7, (first, float(loss))


def test_model_level_pallas_vs_jnp():
    """Whole-model agreement between kernel flavours (value and grad)."""
    cfg = small_cfg()
    layout, _ = make_entry_points(cfg)
    rng = np.random.default_rng(7)
    batch = make_batch(cfg, rng)
    flat = jnp.asarray(init_flat(layout, rng))

    def run(impl):
        def f(fl):
            K.use_impl(impl)
            return link_loss(cfg, layout, fl, batch)

        return jax.value_and_grad(f)(flat)

    try:
        lp, gp = run("pallas")
        lj, gj = run("jnp")
    finally:
        K.use_impl("pallas")
    np.testing.assert_allclose(float(lp), float(lj), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(gp), np.asarray(gj),
                               rtol=1e-3, atol=1e-5)
