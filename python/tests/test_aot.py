"""AOT contract tests: the manifest + HLO text artifacts rust consumes.

Beyond structural checks, the key test executes an emitted HLO module
through xla_client's CPU backend and compares against direct jax
execution — validating the full interchange path (stablehlo → HLO text
→ parse → compile → run) without needing the rust binary.
"""

import json
import os
import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile.aot import VARIANTS, to_hlo_text, _spec_json
from compile.model import ModelConfig, make_entry_points
import compile.kernels as K

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def manifest():
    path = os.path.join(ART, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built (run `make artifacts`)")
    with open(path) as f:
        return json.load(f)


# ------------------------------------------------------------ manifest


def test_manifest_covers_all_variants():
    m = manifest()
    for enc, dec in VARIANTS:
        assert f"{enc}_{dec}" in m["variants"]


def test_manifest_files_exist_and_parse():
    m = manifest()
    for vname, v in m["variants"].items():
        total = v["params"]["total"]
        assert total > 0
        # layout is packed
        off = 0
        for t in v["params"]["tensors"]:
            assert t["offset"] == off, (vname, t["name"])
            off += int(np.prod(t["shape"])) if t["shape"] else 1
        assert off == total
        for ename, e in v["entries"].items():
            assert e["args"][0]["name"] == "params"
            assert e["args"][0]["shape"] == [total]
            for impl, fname in e["artifacts"].items():
                path = os.path.join(ART, fname)
                assert os.path.exists(path), fname
                with open(path) as f:
                    head = f.read(200)
                assert "HloModule" in head, fname


def test_manifest_entry_set_complete():
    m = manifest()
    for v in m["variants"].values():
        assert set(v["entries"]) == {"train", "grad", "encode", "score"}


def test_manifest_init_kinds_known():
    m = manifest()
    kinds = {"glorot", "zeros", "ones", "prelu", "normal"}
    for v in m["variants"].values():
        for t in v["params"]["tensors"]:
            assert t["init"] in kinds, t


def test_adam_hyperparams_recorded():
    m = manifest()
    assert m["adam"]["lr"] == pytest.approx(1e-3)
    assert m["adam"]["beta1"] == pytest.approx(0.9)


# ------------------------------------------- HLO round-trip execution


def _exec_hlo_text(text, args):
    """Compile HLO text with xla_client's CPU backend and run it."""
    from jax._src.lib import xla_client as xc

    backend = jax.devices("cpu")[0].client
    comp = xc.XlaComputation(
        xc._xla.hlo_module_proto_from_text(text).as_serialized_hlo_module_proto()
    )
    exe = backend.compile(comp.as_serialized_hlo_module_proto())
    bufs = [backend.buffer_from_pyval(a) for a in args]
    out = exe.execute(bufs)
    return [np.asarray(b) for b in out]


def test_hlo_text_roundtrip_matches_jax():
    """encode artifact: HLO-text → compile → run == direct jax call."""
    cfg = ModelConfig(feat_dim=8, hidden=8, block_nodes=16, block_edges=8,
                      score_batch=16)
    layout, entries = make_entry_points(cfg)
    fn, spec = entries["encode"]
    rng = np.random.default_rng(0)
    flat = rng.normal(size=(layout.total,)).astype(np.float32) * 0.1
    feats = rng.normal(size=(16, 8)).astype(np.float32)
    adj = np.eye(16, dtype=np.float32)

    K.use_impl("pallas")
    lowered = jax.jit(fn).lower(
        jax.ShapeDtypeStruct((layout.total,), jnp.float32),
        jax.ShapeDtypeStruct((16, 8), jnp.float32),
        jax.ShapeDtypeStruct((16, 16), jnp.float32),
    )
    text = to_hlo_text(lowered)

    direct = np.asarray(jax.jit(fn)(flat, feats, adj)[0])
    try:
        via_hlo = _exec_hlo_text(text, [flat, feats, adj])
    except Exception as e:  # pragma: no cover - api drift guard
        pytest.skip(f"xla_client text execution unavailable: {e}")
    np.testing.assert_allclose(via_hlo[0], direct, rtol=1e-4, atol=1e-5)


def test_spec_json_dtypes():
    s = jax.ShapeDtypeStruct((2, 3), jnp.float32)
    assert _spec_json("x", s) == {"name": "x", "dtype": "f32",
                                  "shape": [2, 3]}
    s = jax.ShapeDtypeStruct((4,), jnp.int32)
    assert _spec_json("i", s)["dtype"] == "i32"
