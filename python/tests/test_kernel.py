"""L1 kernel correctness: Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes (including non-tile-divisible and degenerate
dims) and checks both forward values and ``custom_vjp`` gradients
against ``ref.py`` / ``jax.grad`` of the reference — the core
correctness signal for everything the AOT artifacts compute.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

import importlib

# The package exports dispatch *functions* named like the submodules
# (kernels.matmul shadows kernels/matmul.py), so fetch the real modules.
mmk = importlib.import_module("compile.kernels.matmul")
gak = importlib.import_module("compile.kernels.gcn_agg")
deck = importlib.import_module("compile.kernels.decoder")
from compile.kernels import ref
import compile.kernels as K

DIM = st.integers(min_value=1, max_value=160)
SETTINGS = dict(max_examples=15, deadline=None)


def _arr(rng, *shape):
    return rng.normal(size=shape).astype(np.float32)


# ---------------------------------------------------------------- matmul


@settings(**SETTINGS)
@given(m=DIM, k=DIM, n=DIM, seed=st.integers(0, 2**31 - 1))
def test_mm_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a, b = _arr(rng, m, k), _arr(rng, k, n)
    np.testing.assert_allclose(mmk.mm(a, b), ref.mm(a, b), rtol=1e-4, atol=1e-4)


@settings(**SETTINGS)
@given(m=DIM, k=DIM, n=DIM, seed=st.integers(0, 2**31 - 1))
def test_mm_nt_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a, b = _arr(rng, m, k), _arr(rng, n, k)
    np.testing.assert_allclose(
        mmk.mm_nt(a, b), ref.mm_nt(a, b), rtol=1e-4, atol=1e-4
    )


@settings(**SETTINGS)
@given(m=DIM, k=DIM, n=DIM, seed=st.integers(0, 2**31 - 1))
def test_mm_tn_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a, b = _arr(rng, k, m), _arr(rng, k, n)
    np.testing.assert_allclose(
        mmk.mm_tn(a, b), ref.mm_tn(a, b), rtol=1e-4, atol=1e-4
    )


@pytest.mark.parametrize("block", [32, 64, 128, 256])
def test_mm_block_size_invariance(block):
    """Result must not depend on the tile decomposition."""
    rng = np.random.default_rng(7)
    a, b = _arr(rng, 96, 80), _arr(rng, 80, 56)
    np.testing.assert_allclose(
        mmk.mm(a, b, block=block), ref.mm(a, b), rtol=1e-4, atol=1e-4
    )


@settings(max_examples=8, deadline=None)
@given(
    m=st.integers(2, 48),
    k=st.integers(2, 48),
    n=st.integers(2, 48),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_vjp_matches_jax_grad(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a, b = _arr(rng, m, k), _arr(rng, k, n)

    def f_pallas(a_, b_):
        return jnp.sum(jnp.sin(mmk.matmul(a_, b_)))

    def f_ref(a_, b_):
        return jnp.sum(jnp.sin(ref.mm(a_, b_)))

    ga_p, gb_p = jax.grad(f_pallas, argnums=(0, 1))(a, b)
    ga_r, gb_r = jax.grad(f_ref, argnums=(0, 1))(a, b)
    np.testing.assert_allclose(ga_p, ga_r, rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(gb_p, gb_r, rtol=1e-3, atol=1e-4)


# --------------------------------------------------------------- gcn_agg


@settings(**SETTINGS)
@given(
    bn=st.integers(1, 128),
    f=st.integers(1, 96),
    h=st.integers(1, 96),
    seed=st.integers(0, 2**31 - 1),
)
def test_gcn_agg_matches_ref(bn, f, h, seed):
    rng = np.random.default_rng(seed)
    adj, x, w = _arr(rng, bn, bn), _arr(rng, bn, f), _arr(rng, f, h)
    np.testing.assert_allclose(
        gak.gcn_agg(adj, x, w), ref.gcn_agg(adj, x, w), rtol=1e-3, atol=1e-3
    )


def test_gcn_agg_grad_matches_ref():
    rng = np.random.default_rng(3)
    adj, x, w = _arr(rng, 40, 40), _arr(rng, 40, 16), _arr(rng, 16, 12)

    def loss(fn):
        return lambda w_: jnp.sum(fn(adj, x, w_) ** 2)

    np.testing.assert_allclose(
        jax.grad(loss(gak.gcn_agg))(w),
        jax.grad(loss(ref.gcn_agg))(w),
        rtol=1e-3,
        atol=1e-3,
    )


def test_gcn_agg_grad_wrt_features():
    """dL/dX must also flow (SAGE self+neighbour paths share x)."""
    rng = np.random.default_rng(4)
    adj, x, w = _arr(rng, 24, 24), _arr(rng, 24, 8), _arr(rng, 8, 8)
    g_p = jax.grad(lambda x_: jnp.sum(gak.gcn_agg(adj, x_, w) ** 2))(x)
    g_r = jax.grad(lambda x_: jnp.sum(ref.gcn_agg(adj, x_, w) ** 2))(x)
    np.testing.assert_allclose(g_p, g_r, rtol=1e-3, atol=1e-3)


def test_gcn_agg_row_normalized_identity():
    """With identity features and a row-stochastic adj, output rows = W
    averaged over neighbours: sanity anchor independent of the oracle."""
    bn = 16
    adj = np.full((bn, bn), 1.0 / bn, dtype=np.float32)
    x = np.eye(bn, dtype=np.float32)
    w = np.random.default_rng(0).normal(size=(bn, 4)).astype(np.float32)
    out = np.asarray(gak.gcn_agg(adj, x, w))
    expect = np.tile(w.mean(axis=0), (bn, 1))
    np.testing.assert_allclose(out, expect, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------- had_mm


@settings(**SETTINGS)
@given(
    s=st.integers(1, 160),
    h=st.integers(1, 96),
    n=st.integers(1, 32),
    seed=st.integers(0, 2**31 - 1),
)
def test_had_mm_matches_ref(s, h, n, seed):
    rng = np.random.default_rng(seed)
    u, v, w = _arr(rng, s, h), _arr(rng, s, h), _arr(rng, h, n)
    np.testing.assert_allclose(
        deck.had_mm(u, v, w), ref.had_mm(u, v, w), rtol=1e-4, atol=1e-4
    )


def test_had_mm_vjp_all_args():
    rng = np.random.default_rng(5)
    u, v, w = _arr(rng, 20, 12), _arr(rng, 20, 12), _arr(rng, 12, 6)

    def f(fn):
        return lambda u_, v_, w_: jnp.sum(jnp.tanh(fn(u_, v_, w_)))

    gp = jax.grad(f(deck.had_mm), argnums=(0, 1, 2))(u, v, w)
    gr = jax.grad(f(ref.had_mm), argnums=(0, 1, 2))(u, v, w)
    for p, r in zip(gp, gr):
        np.testing.assert_allclose(p, r, rtol=1e-3, atol=1e-4)


# ------------------------------------------------------------- dispatch


def test_impl_dispatch_switches():
    assert K.current_impl() == "pallas"
    K.use_impl("jnp")
    assert K.current_impl() == "jnp"
    K.use_impl("pallas")
    with pytest.raises(ValueError):
        K.use_impl("cuda")


def test_dispatch_numerics_agree():
    rng = np.random.default_rng(6)
    a, b = _arr(rng, 33, 17), _arr(rng, 17, 9)
    K.use_impl("pallas")
    out_p = K.matmul(a, b)
    K.use_impl("jnp")
    out_j = K.matmul(a, b)
    K.use_impl("pallas")
    np.testing.assert_allclose(out_p, out_j, rtol=1e-4, atol=1e-4)
