//! Quickstart: the end-to-end validation run (DESIGN.md §7).
//!
//! Generates the citation-sim graph, partitions it with RandomTMA,
//! trains a 2-layer GCN link predictor with M = 3 trainers for a
//! configurable window (a few hundred steps each on this testbed),
//! prints the loss curve + validation MRR trajectory, and reports the
//! final test MRR. Recorded in EXPERIMENTS.md §End-to-end.
//!
//! Run: `cargo run --release --example quickstart [-- --quick]`

use random_tma::config::{Approach, RunConfig};
use random_tma::coordinator::run_experiment;
use random_tma::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(&["quick"]);
    let cfg = RunConfig {
        dataset: args.str_or("dataset", "citation-sim"),
        quick: args.flag("quick"),
        variant: args.str_or("variant", "gcn_mlp"),
        approach: Approach::RandomTma,
        trainers: args.usize_or("m", 3),
        train_secs: args.f64_or("train-secs", 30.0),
        agg_secs: args.f64_or("agg-secs", 2.0),
        seed: args.u64_or("seed", 17),
        ..RunConfig::default()
    };
    println!("== quickstart: {} ==", cfg.label());
    let r = run_experiment(&cfg)?;

    println!("\nvalidation MRR over time:");
    for p in &r.val_curve {
        let bar = "#".repeat((p.val_mrr * 60.0) as usize);
        println!("  t={:6.1}s  mrr={:.4}  {bar}", p.t, p.val_mrr);
    }
    println!("\nper-trainer loss (first -> last):");
    for (i, tl) in r.trainer_losses.iter().enumerate() {
        if let (Some(first), Some(last)) = (tl.first(), tl.last()) {
            println!(
                "  trainer {i}: {:.4} -> {:.4}  ({} steps)",
                first.loss, last.loss, r.steps[i]
            );
        }
    }
    println!(
        "\nbest val MRR {:.4} | TEST MRR {:.4} | convergence {:.1}s | r={:.2}",
        r.best_val_mrr,
        r.test_mrr,
        r.convergence_secs(0.01),
        r.ratio_r
    );
    anyhow::ensure!(
        r.test_mrr > 0.2,
        "quickstart failed to learn (test MRR {:.4})",
        r.test_mrr
    );
    println!("quickstart OK");
    Ok(())
}
