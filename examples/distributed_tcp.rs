//! Distributed mode: TMA over TCP with real worker *processes*.
//!
//! The leader (this example) binds a socket, spawns `M` `rtma worker`
//! subprocesses, broadcasts initial weights, opens time-based
//! aggregation rounds (Collect → Weights → streaming-average →
//! Broadcast) and finally stops the workers — the same Alg 1 protocol
//! as the in-process driver, across process boundaries. The round
//! data plane mirrors the in-process one: incoming weight vectors
//! fold straight into one [`MeanAccum`] (no `Vec<Vec<f32>>` staging),
//! and every broadcast frame is encoded from the shared global slab
//! through one reused scratch buffer (`comm::send_wire`).
//!
//! After the last round the leader scores the aggregated weights on
//! the validation split and asserts the MRR is finite — the
//! `distributed-smoke` CI assertion.
//!
//! Run: `cargo run --release --example distributed_tcp`
//! (defaults: M=3 workers, ~9 s; the CI smoke job passes
//! `--m 2 --train-secs 6`). Requires compiled artifacts; skips
//! gracefully — exit 0 — without them, like the failure drill.

use std::net::TcpListener;
use std::process::{Child, Command};
use std::time::{Duration, Instant};

use random_tma::comm::{recv, send, send_wire, Message, WireMsg};
use random_tma::coordinator::evaluate_mrr;
use random_tma::gen::load_preset;
use random_tma::model::{MeanAccum, ModelState};
use random_tma::runtime::{Engine, Manifest};
use random_tma::sampler::eval::EvalBlockConfig;
use random_tma::sampler::{AdjMode, EvalPlan};
use random_tma::util::cli::Args;
use random_tma::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(&["quick"]);
    let m = args.usize_or("m", 3);
    let seed = args.u64_or("seed", 17);
    let train_secs = args.f64_or("train-secs", 9.0);
    let agg_secs = args.f64_or("agg-secs", 1.5);
    let dataset = args.str_or("dataset", "citation-sim");
    let variant = args.str_or("variant", "gcn_mlp");

    let Ok(manifest) = Manifest::load(&Manifest::default_dir()) else {
        println!(
            "distributed_tcp skipped: artifacts missing (run `make \
             artifacts` for the full TCP smoke)"
        );
        return Ok(());
    };

    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    println!("[leader] listening on {addr}, M={m}");

    // Spawn workers as real OS processes running `rtma worker`.
    let exe = rtma_binary()?;
    let mut children: Vec<Child> = Vec::new();
    for id in 0..m {
        children.push(
            Command::new(&exe)
                .args([
                    "worker",
                    "--leader",
                    &addr.to_string(),
                    "--id",
                    &id.to_string(),
                    "--m",
                    &m.to_string(),
                    "--dataset",
                    &dataset,
                    "--seed",
                    &seed.to_string(),
                    "--variant",
                    &variant,
                ])
                .spawn()?,
        );
    }

    // Accept M workers (Hello + Ready).
    let mut streams = Vec::new();
    for _ in 0..m {
        let (mut s, peer) = listener.accept()?;
        let hello = recv(&mut s)?;
        let ready = recv(&mut s)?;
        println!("[leader] {peer} -> {hello:?} {ready:?}");
        streams.push(s);
    }

    // Initial broadcast: one shared slab, frames encoded through one
    // reused scratch buffer.
    let spec = manifest.variant(&variant)?;
    let mut w_global =
        ModelState::init(spec, &mut Rng::new(seed ^ 0x1417)).params;
    let mut scratch = Vec::new();
    for s in &mut streams {
        send_wire(
            s,
            &WireMsg::Broadcast { round: 0, data: &w_global },
            &mut scratch,
        )?;
    }

    // Time-based aggregation rounds with a streaming allreduce.
    let mut acc = MeanAccum::new(w_global.len());
    let start = Instant::now();
    let mut round = 0u64;
    while start.elapsed().as_secs_f64() < train_secs {
        std::thread::sleep(Duration::from_secs_f64(agg_secs));
        round += 1;
        for s in &mut streams {
            send(s, &Message::Collect { round })?;
        }
        acc.reset();
        let mut total_steps = 0u64;
        for s in &mut streams {
            match recv(s)? {
                Message::Weights { data, steps, .. } => {
                    total_steps += steps;
                    acc.add(&data);
                }
                other => anyhow::bail!("unexpected {other:?}"),
            }
        }
        w_global = acc.mean();
        for s in &mut streams {
            send_wire(
                s,
                &WireMsg::Broadcast { round, data: &w_global },
                &mut scratch,
            )?;
        }
        println!(
            "[leader] round {round}: aggregated {} workers, {} total steps",
            acc.count(),
            total_steps
        );
    }
    for s in &mut streams {
        send(s, &Message::Stop)?;
    }
    for mut c in children {
        c.wait()?;
    }
    let norm: f32 = w_global.iter().map(|x| x * x).sum::<f32>().sqrt();
    println!(
        "[leader] done: {round} rounds, final ||W|| = {norm:.3} \
         (weights moved from init — training happened across processes)"
    );
    anyhow::ensure!(round >= 2, "too few rounds completed");

    // Score the aggregated weights on the validation split — the
    // distributed run must produce a usable (finite-MRR) model.
    let preset = load_preset(&dataset, true, 16, 8, seed)?;
    let engine = Engine::load(&manifest, &variant, "pallas")?;
    engine.prepare(&["encode", "score"])?;
    let adj_mode = AdjMode::for_encoder(&engine.variant.encoder);
    let relations = if adj_mode == AdjMode::Relational {
        manifest.dims.relations
    } else {
        1
    };
    let eval_cfg = EvalBlockConfig::new(
        manifest.dims.block_nodes,
        manifest.dims.feat_dim,
        adj_mode,
        relations,
        preset.boundary,
    );
    let plan = EvalPlan::build(
        &preset.split.train,
        &preset.split.val,
        &preset.split.val_negatives,
        &eval_cfg,
    );
    let mrr = evaluate_mrr(&engine, &plan, &w_global)?;
    println!("[leader] final val MRR {mrr:.4}");
    anyhow::ensure!(
        mrr.is_finite() && mrr > 0.0,
        "distributed run produced unusable weights (MRR {mrr})"
    );
    println!("distributed_tcp OK");
    Ok(())
}

/// Locate the `rtma` binary next to this example's executable.
fn rtma_binary() -> anyhow::Result<std::path::PathBuf> {
    let me = std::env::current_exe()?;
    // target/release/examples/distributed_tcp -> target/release/rtma
    let dir = me
        .parent()
        .and_then(|p| p.parent())
        .ok_or_else(|| anyhow::anyhow!("no target dir"))?;
    let cand = dir.join("rtma");
    anyhow::ensure!(
        cand.exists(),
        "{} missing — run `cargo build --release` first",
        cand.display()
    );
    Ok(cand)
}
