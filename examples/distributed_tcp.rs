//! Distributed mode: TMA over TCP with real worker *processes*.
//!
//! The leader (this example) binds a socket, spawns `M` `rtma worker`
//! subprocesses, broadcasts initial weights, opens time-based
//! aggregation rounds (Collect → Weights → average → Broadcast) and
//! finally stops the workers — the same Alg 1 protocol as the
//! in-process driver, across process boundaries.
//!
//! Run: `cargo run --release --example distributed_tcp`
//! (builds on the quick citation dataset; ~20 s wall clock)

use std::net::TcpListener;
use std::process::{Child, Command};
use std::time::{Duration, Instant};

use random_tma::comm::{recv, send, Message};
use random_tma::model::{aggregate, AggregateOp, ModelState};
use random_tma::runtime::Manifest;
use random_tma::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let m = 3usize;
    let seed = 17u64;
    let train_secs = 9.0;
    let agg_secs = 1.5;
    let dataset = "citation-sim";
    let variant = "gcn_mlp";

    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    println!("[leader] listening on {addr}");

    // Spawn workers as real OS processes running `rtma worker`.
    let exe = rtma_binary()?;
    let mut children: Vec<Child> = Vec::new();
    for id in 0..m {
        children.push(
            Command::new(&exe)
                .args([
                    "worker",
                    "--leader",
                    &addr.to_string(),
                    "--id",
                    &id.to_string(),
                    "--m",
                    &m.to_string(),
                    "--dataset",
                    dataset,
                    "--seed",
                    &seed.to_string(),
                    "--variant",
                    variant,
                ])
                .spawn()?,
        );
    }

    // Accept M workers (Hello + Ready).
    let mut streams = Vec::new();
    for _ in 0..m {
        let (mut s, peer) = listener.accept()?;
        let hello = recv(&mut s)?;
        let ready = recv(&mut s)?;
        println!("[leader] {peer} -> {hello:?} {ready:?}");
        streams.push(s);
    }

    // Initial broadcast.
    let manifest = Manifest::load(&Manifest::default_dir())?;
    let spec = manifest.variant(variant)?;
    let init = ModelState::init(spec, &mut Rng::new(seed ^ 0x1417)).params;
    let mut w_global = init;
    for s in &mut streams {
        send(s, &Message::Broadcast { round: 0, data: w_global.clone() })?;
    }

    // Time-based aggregation rounds.
    let start = Instant::now();
    let mut round = 0u64;
    while start.elapsed().as_secs_f64() < train_secs {
        std::thread::sleep(Duration::from_secs_f64(agg_secs));
        round += 1;
        for s in &mut streams {
            send(s, &Message::Collect { round })?;
        }
        let mut weights = Vec::new();
        let mut total_steps = 0u64;
        for s in &mut streams {
            match recv(s)? {
                Message::Weights { data, steps, .. } => {
                    total_steps += steps;
                    weights.push(data);
                }
                other => anyhow::bail!("unexpected {other:?}"),
            }
        }
        w_global = aggregate(AggregateOp::Mean, &weights, &[]);
        for s in &mut streams {
            send(
                s,
                &Message::Broadcast { round, data: w_global.clone() },
            )?;
        }
        println!(
            "[leader] round {round}: aggregated {} workers, {} total steps",
            weights.len(),
            total_steps
        );
    }
    for s in &mut streams {
        send(s, &Message::Stop)?;
    }
    for mut c in children {
        c.wait()?;
    }
    let norm: f32 = w_global.iter().map(|x| x * x).sum::<f32>().sqrt();
    println!(
        "[leader] done: {round} rounds, final ||W|| = {norm:.3} \
         (weights moved from init — training happened across processes)"
    );
    anyhow::ensure!(round >= 2, "too few rounds completed");
    println!("distributed_tcp OK");
    Ok(())
}

/// Locate the `rtma` binary next to this example's executable.
fn rtma_binary() -> anyhow::Result<std::path::PathBuf> {
    let me = std::env::current_exe()?;
    // target/release/examples/distributed_tcp -> target/release/rtma
    let dir = me
        .parent()
        .and_then(|p| p.parent())
        .ok_or_else(|| anyhow::anyhow!("no target dir"))?;
    let cand = dir.join("rtma");
    anyhow::ensure!(
        cand.exists(),
        "{} missing — run `cargo build --release` first",
        cand.display()
    );
    Ok(cand)
}
