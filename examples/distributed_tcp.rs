//! Distributed mode: TMA over TCP with real worker *processes*.
//!
//! The leader (this example) binds a socket, spawns `M` `rtma worker`
//! subprocesses, broadcasts initial weights, opens time-based
//! aggregation rounds (Collect → Weights → streaming-average →
//! Broadcast) and finally stops the workers — the same Alg 1 protocol
//! as the in-process driver, across process boundaries. The round
//! data plane mirrors the in-process one: incoming weight vectors
//! fold straight into one [`MeanAccum`] (no `Vec<Vec<f32>>` staging),
//! and every broadcast frame is encoded from the shared global slab
//! through one reused scratch buffer (`comm::send_wire`).
//!
//! By default the workers *really train* on the native backend (no
//! artifacts needed — the builtin manifest covers a bare checkout)
//! and the leader scores the aggregated weights on the validation
//! split, asserting a finite positive MRR. With `--no-train` the run
//! degrades to *protocol-only* mode: workers echo weights back with a
//! NaN-loss sentinel (steps=0) and the leader verifies the echo mean
//! instead — the CI `distributed-smoke-protocol` job uses this to
//! isolate the wire protocol from the compute plane. In trained mode
//! the leader asserts the sentinel never leaks: any worker reporting
//! steps > 0 with a non-finite loss fails the run.
//!
//! Observability: leader round phases are traced as `leader` spans
//! (collect/aggregate/broadcast — `rtma trace-report` folds them with
//! the in-process server phases), each worker writes its own JSONL
//! sink at `$RTMA_TRACE.worker<id>` when tracing is on, and the run
//! persists a `BENCH_distributed_smoke.json` baseline with round
//! timings plus comm byte/frame counters.
//!
//! Run: `cargo run --release --example distributed_tcp`
//! (defaults: M=3 workers, ~9 s; the CI smoke job passes
//! `--m 2 --train-secs 6 --agg-secs 1`).

use std::net::TcpListener;
use std::process::{Child, Command};
use std::time::{Duration, Instant};

use random_tma::benchkit::BenchBaseline;
use random_tma::comm::codec;
use random_tma::comm::{
    recv_from, send, send_wire, server_handshake, Message, Peer, WireMsg,
};
use random_tma::coordinator::evaluate_mrr;
use random_tma::gen::load_preset;
use random_tma::model::{MeanAccum, ModelState};
use random_tma::runtime::{load_backend, ComputeBackend, Manifest};
use random_tma::sampler::eval::EvalBlockConfig;
use random_tma::sampler::{AdjMode, EvalPlan};
use random_tma::telemetry::{self, metrics, Span};
use random_tma::util::bench::Timing;
use random_tma::util::cli::Args;
use random_tma::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(&["quick", "no-train"]);
    let m = args.usize_or("m", 3);
    let seed = args.u64_or("seed", 17);
    let train_secs = args.f64_or("train-secs", 9.0);
    let agg_secs = args.f64_or("agg-secs", 1.5);
    let dataset = args.str_or("dataset", "citation-sim");
    let variant = args.str_or("variant", "gcn_mlp");
    let backend_flag = args.str_or("backend", "");
    // identity < --codec < RTMA_CODEC. The resolved choice is passed
    // to every worker on its command line AND re-verified by the
    // Hello/Ready codec negotiation, so a mismatched peer fails loudly
    // instead of mis-decoding frames.
    let codec_kind = codec::resolve(&args.str_or("codec", ""))?;
    if !codec_kind.is_identity() {
        println!("[leader] round codec: {}", codec_kind.name());
    }

    // `--no-train` isolates the wire protocol: workers echo weights
    // instead of training. The default is a real training run — the
    // native backend needs no artifacts.
    let manifest = if args.flag("no-train") {
        println!(
            "[leader] --no-train: protocol-only mode (workers echo \
             weights)"
        );
        None
    } else {
        let mut man = Manifest::load_or_builtin();
        if !backend_flag.is_empty() {
            man.backend = backend_flag.clone();
        }
        Some(man)
    };

    let tel_base = telemetry::snapshot();
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    println!("[leader] listening on {addr}, M={m}");

    // Spawn workers as real OS processes running `rtma worker`. When
    // the leader is traced, give each worker its own JSONL sink so
    // the per-process buffers never interleave in one file.
    let exe = rtma_binary()?;
    let trace_base = std::env::var("RTMA_TRACE").ok();
    let mut children: Vec<Child> = Vec::new();
    for id in 0..m {
        let mut cmd = Command::new(&exe);
        cmd.args([
            "worker",
            "--leader",
            &addr.to_string(),
            "--id",
            &id.to_string(),
            "--m",
            &m.to_string(),
            "--dataset",
            &dataset,
            "--seed",
            &seed.to_string(),
            "--variant",
            &variant,
            "--codec",
            codec_kind.name().as_str(),
        ]);
        if manifest.is_none() {
            cmd.arg("--no-train");
        } else if !backend_flag.is_empty() {
            cmd.args(["--backend", &backend_flag]);
        }
        if let Some(base) = &trace_base {
            cmd.env("RTMA_TRACE", format!("{base}.worker{id}"));
        }
        children.push(cmd.spawn()?);
    }

    // Accept M workers (Hello + Codec + Ready): the handshake bails
    // on any worker negotiating a different codec family.
    let mut streams = Vec::new();
    for _ in 0..m {
        let (mut s, peer) = listener.accept()?;
        let id = server_handshake(&mut s, codec_kind)?;
        telemetry::info(
            "leader",
            "worker_joined",
            &[("worker", id as f64)],
            format_args!("{peer} -> worker {id} ({})", codec_kind.name()),
        );
        streams.push(s);
    }

    // Initial broadcast: one shared slab, frames encoded through one
    // reused scratch buffer. Protocol-only mode uses a fixed dummy
    // slab in place of the manifest-shaped init.
    let mut w_global = match &manifest {
        Some(man) => {
            let spec = man.variant(&variant)?;
            ModelState::init(spec, &mut Rng::new(seed ^ 0x1417)).params
        }
        None => vec![0.1f32; 4096],
    };
    let mut scratch = Vec::new();
    let mut rbuf = Vec::new();
    let mut body: Vec<u8> = Vec::new();
    let mut down_enc = (!codec_kind.is_identity())
        .then(|| codec::RoundEncoder::new(codec_kind, seed ^ 0xb07a_dc0d));
    // Non-identity: the initial broadcast encodes against the empty
    // (= zero) base the workers start with, then w_global becomes the
    // decode so both ends hold bit-identical bases from round 0 on.
    if let Some(enc) = down_enc.as_mut() {
        let cid = enc.encode_down(&w_global, &[], &mut body);
        w_global = codec::decode_dense(cid, w_global.len(), &body, &[])?;
        for s in &mut streams {
            send_wire(
                s,
                &WireMsg::BroadcastEnc {
                    round: 0,
                    codec: cid,
                    n: w_global.len() as u64,
                    body: &body,
                },
                &mut scratch,
            )?;
        }
    } else {
        for s in &mut streams {
            send_wire(
                s,
                &WireMsg::Broadcast { round: 0, data: &w_global },
                &mut scratch,
            )?;
        }
    }

    // Time-based aggregation rounds with a streaming allreduce. Each
    // phase is traced as a `leader` span so `trace-report` folds it
    // alongside the in-process server phases.
    let mut acc = MeanAccum::new(w_global.len());
    let mut round_samples: Vec<f64> = Vec::new();
    let start = Instant::now();
    let mut round = 0u64;
    let mut grand_steps = 0u64;
    while start.elapsed().as_secs_f64() < train_secs {
        std::thread::sleep(Duration::from_secs_f64(agg_secs));
        round += 1;
        let t_round = Instant::now();
        let mut total_steps = 0u64;
        {
            let _sp = Span::start("leader", "collect")
                .round(round)
                .hist(&metrics().phase_collect);
            for s in &mut streams {
                send(s, &Message::Collect { round })?;
            }
            acc.reset();
            for s in &mut streams {
                match recv_from(s, &mut rbuf, Peer::Trainer)? {
                    Message::Weights { data, steps, loss, .. } => {
                        // A NaN loss is the protocol-only "no batch
                        // yet" sentinel (steps = 0). A worker that DID
                        // step must report a finite loss — otherwise
                        // the sentinel (or a diverged model) would
                        // silently leak into the run's metrics.
                        anyhow::ensure!(
                            steps == 0 || loss.is_finite(),
                            "worker reported {steps} steps with \
                             non-finite loss {loss}"
                        );
                        total_steps += steps;
                        acc.add(&data);
                    }
                    Message::WeightsEnc {
                        loss,
                        steps,
                        codec: cid,
                        n,
                        body: eb,
                        ..
                    } => {
                        anyhow::ensure!(
                            steps == 0 || loss.is_finite(),
                            "worker reported {steps} steps with \
                             non-finite loss {loss}"
                        );
                        total_steps += steps;
                        // Fold base-relative against the last
                        // broadcast (the base every worker encoded
                        // against), no dense materialisation.
                        codec::decode_fold(
                            cid,
                            n as usize,
                            &eb,
                            &w_global,
                            &mut acc,
                        )?;
                    }
                    other => anyhow::bail!("unexpected {other:?}"),
                }
            }
        }
        grand_steps = grand_steps.max(total_steps);
        let bcast = {
            let _sp = Span::start("leader", "aggregate")
                .round(round)
                .hist(&metrics().phase_aggregate);
            let mut next = acc.mean_with(Some(&w_global));
            let mut cid_opt = None;
            if let Some(enc) = down_enc.as_mut() {
                let cid = enc.encode_down(&next, &w_global, &mut body);
                next =
                    codec::decode_dense(cid, next.len(), &body, &w_global)?;
                cid_opt = Some(cid);
            }
            w_global = next;
            cid_opt
        };
        {
            let _sp = Span::start("leader", "broadcast")
                .round(round)
                .hist(&metrics().phase_broadcast);
            for s in &mut streams {
                match bcast {
                    Some(cid) => send_wire(
                        s,
                        &WireMsg::BroadcastEnc {
                            round,
                            codec: cid,
                            n: w_global.len() as u64,
                            body: &body,
                        },
                        &mut scratch,
                    )?,
                    None => send_wire(
                        s,
                        &WireMsg::Broadcast { round, data: &w_global },
                        &mut scratch,
                    )?,
                }
            }
        }
        round_samples.push(t_round.elapsed().as_secs_f64());
        println!(
            "[leader] round {round}: aggregated {} workers, {} total steps",
            acc.count(),
            total_steps
        );
    }
    for s in &mut streams {
        send(s, &Message::Stop)?;
    }
    for mut c in children {
        c.wait()?;
    }
    let norm: f32 = w_global.iter().map(|x| x * x).sum::<f32>().sqrt();
    println!(
        "[leader] done: {round} rounds, final ||W|| = {norm:.3}"
    );
    anyhow::ensure!(round >= 2, "too few rounds completed");
    anyhow::ensure!(norm.is_finite(), "aggregated weights diverged");

    // Persist the smoke baseline: per-round wall time plus the comm
    // counters this run added on top of the process baseline.
    let delta = telemetry::snapshot().delta_since(&tel_base);
    let mut bench = BenchBaseline::new("distributed_smoke");
    bench.push_timing(&Timing {
        label: "round".into(),
        samples: round_samples,
    });
    for key in [
        "comm_bytes_out",
        "comm_bytes_in",
        "comm_frames_out",
        "comm_frames_in",
        "comm_scratch_reuse",
        "comm_frames_rejected",
        "codec_frames",
        "codec_bytes_raw",
        "codec_bytes_encoded",
    ] {
        bench.push_counter(key, delta.counter(key) as f64);
    }
    let path = bench.write()?;
    let back = BenchBaseline::read("distributed_smoke")?;
    anyhow::ensure!(
        back == bench,
        "bench baseline failed schema round-trip"
    );
    println!("[leader] bench baseline -> {}", path.display());

    match &manifest {
        Some(man) => {
            // A trained run that took zero steps is a silent failure
            // even if the protocol round-tripped.
            anyhow::ensure!(
                grand_steps > 0,
                "trained mode but no worker took a single step"
            );
            // Score the aggregated weights on the validation split —
            // the distributed run must produce a usable model.
            let preset = load_preset(&dataset, true, 16, 8, seed)?;
            let engine = load_backend(man, &variant, "pallas", "leader")?;
            engine.prepare(&["encode", "score"])?;
            let adj_mode =
                AdjMode::for_encoder(&engine.variant().encoder);
            let relations = if adj_mode == AdjMode::Relational {
                man.dims.relations
            } else {
                1
            };
            let eval_cfg = EvalBlockConfig::new(
                man.dims.block_nodes,
                man.dims.feat_dim,
                adj_mode,
                relations,
                preset.boundary,
            );
            let plan = EvalPlan::build(
                &preset.split.train,
                &preset.split.val,
                &preset.split.val_negatives,
                &eval_cfg,
            );
            let mrr = evaluate_mrr(&*engine, &plan, &w_global)?;
            // Validate BEFORE printing: CI greps the line below as its
            // success signal, so a NaN/zero MRR must never emit it.
            anyhow::ensure!(
                mrr.is_finite() && mrr > 0.0,
                "distributed run produced unusable weights (MRR {mrr})"
            );
            println!("[leader] final val MRR {mrr:.4}");
        }
        None => {
            // Protocol-only: the workers echoed the broadcast slab, so
            // the mean must reproduce it exactly.
            anyhow::ensure!(
                (norm - 0.1 * (w_global.len() as f32).sqrt()).abs() < 1e-2,
                "echoed weights drifted (||W|| {norm})"
            );
            println!("[leader] protocol-only run verified (echo mean)");
        }
    }
    telemetry::trace_counters("leader");
    telemetry::flush();
    println!("distributed_tcp OK");
    Ok(())
}

/// Locate the `rtma` binary next to this example's executable.
fn rtma_binary() -> anyhow::Result<std::path::PathBuf> {
    let me = std::env::current_exe()?;
    // target/release/examples/distributed_tcp -> target/release/rtma
    let dir = me
        .parent()
        .and_then(|p| p.parent())
        .ok_or_else(|| anyhow::anyhow!("no target dir"))?;
    let cand = dir.join("rtma");
    anyhow::ensure!(
        cand.exists(),
        "{} missing — run `cargo build --release` first",
        cand.display()
    );
    Ok(cand)
}
