//! Load generator for the online inference server (docs/SERVING.md):
//! the "query" leg of the train → deploy → query smoke.
//!
//! Spawns `rtma serve` as a real OS process on an ephemeral port,
//! parses the bound address off its stdout, then drives it from
//! concurrent client threads issuing link-score batches (val-split
//! edges plus random in-graph pairs — every score must come back
//! finite) and a few top-k-neighbour queries (must come back sorted).
//! Reports throughput and latency and persists them as the
//! `BENCH_serving.json` baseline for the CI regression gate
//! (`rtma bench-compare`).
//!
//! ```text
//! cargo build --release
//! target/release/rtma train --quick --train-secs 4 --agg-secs 1 \
//!     --save-model results/model.bin
//! cargo run --release --example serve_loadgen -- \
//!     --model results/model.bin --quick
//! ```

use std::io::BufRead;
use std::process::{Child, Command, Stdio};
use std::time::Instant;

use anyhow::{ensure, Context};
use random_tma::benchkit::BenchBaseline;
use random_tma::gen::load_preset;
use random_tma::serve::ServeClient;
use random_tma::util::bench::Timing;
use random_tma::util::cli::Args;
use random_tma::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(&["quick"]);
    let model = args.str_or("model", "results/model.bin");
    let dataset = args.str_or("dataset", "citation-sim");
    let quick = args.flag("quick");
    let variant = args.str_or("variant", "gcn_mlp");
    let seed = args.u64_or("seed", 17);
    let clients = args.usize_or("clients", 4);
    let requests = args.usize_or("requests", 100);
    let pairs_per_req = args.usize_or("pairs", 8);
    ensure!(
        std::path::Path::new(&model).exists(),
        "{model} missing — train one first: rtma train --save-model {model}"
    );

    // The query workload: the preset's held-out val edges (realistic
    // link queries the model was validated on) plus random in-graph
    // pairs. Same preset args as the server, so ids always resolve.
    let preset = load_preset(&dataset, quick, 16, 8, seed)?;
    let num_nodes = preset.split.train.num_nodes() as u32;
    let val_edges: Vec<(u32, u32, i32)> = preset
        .split
        .val
        .iter()
        .map(|&(u, v)| (u, v, -1))
        .collect();
    ensure!(!val_edges.is_empty(), "preset has no val edges");

    // ---- deploy: rtma serve as a child process ------------------------------
    let exe = rtma_binary()?;
    let mut cmd = Command::new(&exe);
    cmd.args(["serve", "--model", &model, "--dataset", &dataset]);
    cmd.args(["--variant", &variant, "--seed", &seed.to_string()]);
    cmd.args(["--addr", "127.0.0.1:0"]);
    if quick {
        cmd.arg("--quick");
    }
    cmd.stdout(Stdio::piped());
    let mut child = cmd.spawn().context("spawning rtma serve")?;
    let addr = wait_for_listening(&mut child)?;
    println!("[loadgen] server up on {addr}");

    // ---- query: concurrent clients, every request timed ---------------------
    let t_start = Instant::now();
    let mut handles = Vec::new();
    for c in 0..clients {
        let addr = addr.clone();
        let edges = val_edges.clone();
        handles.push(std::thread::spawn(move || -> anyhow::Result<Vec<u64>> {
            let mut client = ServeClient::connect(&addr, c as u32)?;
            let mut rng = Rng::new(0x10AD ^ c as u64);
            let mut lat_us = Vec::with_capacity(requests);
            let mut batch = Vec::with_capacity(pairs_per_req);
            for r in 0..requests {
                batch.clear();
                for p in 0..pairs_per_req {
                    // Alternate val edges with random pairs.
                    if (r + p) % 2 == 0 {
                        let e = edges
                            [rng.next_u64() as usize % edges.len()];
                        batch.push(e);
                    } else {
                        batch.push((
                            rng.next_u64() as u32 % num_nodes,
                            rng.next_u64() as u32 % num_nodes,
                            -1,
                        ));
                    }
                }
                let t0 = Instant::now();
                let scores = client.score(&batch)?;
                lat_us.push(t0.elapsed().as_micros() as u64);
                for (i, s) in scores.iter().enumerate() {
                    ensure!(
                        s.is_finite(),
                        "client {c} request {r}: non-finite score {s} \
                         for pair {:?}",
                        batch[i]
                    );
                }
            }
            // A couple of top-k queries: sorted, finite, k-bounded.
            for _ in 0..2 {
                let node = rng.next_u64() as u32 % num_nodes;
                let items = client.topk(node, 5)?;
                ensure!(items.len() <= 5, "topk returned {}", items.len());
                for w in items.windows(2) {
                    ensure!(
                        w[0].1 >= w[1].1,
                        "topk not sorted: {:?}",
                        items
                    );
                }
                for &(_, s) in &items {
                    ensure!(s.is_finite(), "topk score {s} for {node}");
                }
            }
            Ok(lat_us)
        }));
    }
    let mut lat_us: Vec<u64> = Vec::new();
    for h in handles {
        lat_us.extend(h.join().expect("client thread panicked")?);
    }
    let wall = t_start.elapsed().as_secs_f64();

    // ---- report + baseline --------------------------------------------------
    lat_us.sort_unstable();
    let n = lat_us.len();
    ensure!(n == clients * requests, "lost requests: {n}");
    let pick = |p: f64| lat_us[((n as f64 * p) as usize).min(n - 1)];
    let (p50, p99) = (pick(0.50), pick(0.99));
    let qps = n as f64 / wall;
    // CI greps this exact line — keep the format stable.
    println!(
        "[loadgen] qps {qps:.0} p50 {p50}us p99 {p99}us \
         ({n} requests x {pairs_per_req} pairs, {clients} clients)"
    );

    let mut bench = BenchBaseline::new("serving");
    bench.push_timing(&Timing {
        label: "request".into(),
        samples: lat_us.iter().map(|&u| u as f64 / 1e6).collect(),
    });
    bench.push_counter("loadgen_qps", qps);
    bench.push_counter("loadgen_p50_us", p50 as f64);
    bench.push_counter("loadgen_p99_us", p99 as f64);
    let path = bench.write()?;
    let back = BenchBaseline::read("serving")?;
    ensure!(back == bench, "bench baseline failed schema round-trip");
    println!("[loadgen] bench baseline -> {}", path.display());

    // ---- teardown: ask the server to stop, reap the child -------------------
    ServeClient::connect(&addr, 999)?.stop()?;
    let status = child.wait()?;
    ensure!(status.success(), "rtma serve exited with {status}");
    println!("serve_loadgen OK");
    Ok(())
}

/// Read the child's stdout until the `[serve] listening on <addr>`
/// line, then keep draining it on a background thread (so the server
/// never blocks on a full pipe).
fn wait_for_listening(child: &mut Child) -> anyhow::Result<String> {
    let stdout = child.stdout.take().context("no child stdout")?;
    let mut reader = std::io::BufReader::new(stdout);
    let mut line = String::new();
    loop {
        line.clear();
        let read = reader.read_line(&mut line)?;
        ensure!(read > 0, "rtma serve exited before listening");
        print!("[serve-child] {line}");
        if let Some(addr) = line.trim().strip_prefix("[serve] listening on ")
        {
            let addr = addr.to_string();
            std::thread::spawn(move || {
                let mut sink = String::new();
                loop {
                    sink.clear();
                    match reader.read_line(&mut sink) {
                        Ok(0) | Err(_) => break,
                        Ok(_) => print!("[serve-child] {sink}"),
                    }
                }
            });
            return Ok(addr);
        }
    }
}

/// Locate the `rtma` binary next to this example's executable.
fn rtma_binary() -> anyhow::Result<std::path::PathBuf> {
    let me = std::env::current_exe()?;
    // target/release/examples/serve_loadgen -> target/release/rtma
    let dir = me
        .parent()
        .and_then(|p| p.parent())
        .ok_or_else(|| anyhow::anyhow!("no target dir"))?;
    let cand = dir.join("rtma");
    ensure!(
        cand.exists(),
        "{} missing — run `cargo build --release` first",
        cand.display()
    );
    Ok(cand)
}
