//! Partition study: the paper's §3.2 mechanism, measured directly.
//!
//! For each dataset and each partition scheme, reports the
//! retained-edge ratio r, edge-cut, balance, preprocessing time, and —
//! the quantity the theory says matters — the cross-partition class /
//! feature disparity ‖C_i − C_j‖. Shows the trade-off axis N (number
//! of super-nodes) interpolating PSGD-PA (N = M) → SuperTMA → RandomTMA
//! (N = |V|).

use random_tma::gen::load_preset;
use random_tma::partition::{partition_stats, Scheme};
use random_tma::util::bench::Table;
use random_tma::util::cli::Args;
use random_tma::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(&["quick"]);
    let dataset = args.str_or("dataset", "citation-sim");
    let m = args.usize_or("m", 3);
    let preset = load_preset(
        &dataset,
        args.flag("quick"),
        16,
        8,
        args.u64_or("seed", 17),
    )?;
    let g = &preset.split.train;
    let nv = g.num_nodes();

    let mut t = Table::new(
        &format!("Partition trade-off on {dataset} (M={m}, |V|={nv})"),
        &["Scheme (N)", "r", "balance", "class disp", "feat disp",
          "prep(s)"],
    );
    let mut schemes: Vec<(String, Scheme)> = vec![
        (format!("min-cut (N={m})"), Scheme::MinCut),
    ];
    for n in [m * 8, nv / 200, nv / 40, nv / 8] {
        if n > m {
            schemes.push((
                format!("super (N={n})"),
                Scheme::Super { num_clusters: n },
            ));
        }
    }
    schemes.push((format!("random (N={nv})"), Scheme::Random));

    for (label, scheme) in schemes {
        let mut rng = Rng::new(args.u64_or("seed", 17));
        let t0 = std::time::Instant::now();
        let assign = scheme.assign(g, m, &mut rng);
        let prep = t0.elapsed().as_secs_f64();
        let s = partition_stats(g, &assign, m);
        t.row(vec![
            label,
            format!("{:.3}", s.ratio_r),
            format!("{:.2}", s.balance),
            format!("{:.3}", s.class_disparity),
            format!("{:.3}", s.feature_disparity),
            format!("{prep:.2}"),
        ]);
    }
    t.emit("partition_study");
    println!(
        "expected shape: disparity falls monotonically with N while r \
         falls toward 1/M — the paper trades r for uniformity."
    );
    Ok(())
}
