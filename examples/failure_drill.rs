//! Failure drill: Q4 robustness, interactively.
//!
//! Two stages:
//!
//! 1. **Prep drill** (always runs, no artifacts needed — this is what
//!    the CI smoke job exercises): partition the dataset, extract
//!    survivor subgraphs with trainer 0's partition dropped via
//!    `induce_all_except`, and verify the drill invariants — exact cut
//!    accounting, nothing materialised for the lost partition, and all
//!    survivors borrowing one shared feature slab (zero copies).
//! 2. **Training drill** (needs compiled artifacts; skipped with a
//!    note otherwise): the same training twice — once healthy, once
//!    with trainer 0 failed at start — for both RandomTMA and PSGD-PA,
//!    printing the MRR deltas side by side. A compressed Table 6 for
//!    eyeballing the robustness gap.

use random_tma::config::{Approach, RunConfig};
use random_tma::coordinator::run_experiment;
use random_tma::gen::load_preset;
use random_tma::graph::{induce_all, induce_all_except};
use random_tma::partition::{partition_stats_with_cuts, random_partition};
use random_tma::runtime::Manifest;
use random_tma::util::bench::Table;
use random_tma::util::cli::Args;
use random_tma::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(&["quick"]);
    let base = RunConfig {
        dataset: args.str_or("dataset", "citation-sim"),
        quick: args.flag("quick"),
        train_secs: args.f64_or("train-secs", 15.0),
        agg_secs: args.f64_or("agg-secs", 1.5),
        trainers: args.usize_or("m", 3),
        seed: args.u64_or("seed", 17),
        ..RunConfig::default()
    };

    prep_drill(&base)?;

    if Manifest::load(&Manifest::default_dir()).is_err() {
        println!(
            "training drill skipped: artifacts missing (run `make \
             artifacts` for the MRR comparison)"
        );
        return Ok(());
    }
    training_drill(&base)
}

/// Stage 1: partition + drill extraction invariants, artifact-free.
fn prep_drill(base: &RunConfig) -> anyhow::Result<()> {
    let preset = load_preset(&base.dataset, base.quick, 20, 8, base.seed)?;
    let g = &preset.split.train;
    let m = base.trainers;
    let mut rng = Rng::new(base.seed);
    let assign = random_partition(g.num_nodes(), m, &mut rng);

    let healthy = induce_all(g, &assign, m);
    let drilled = induce_all_except(g, &assign, m, &[0]);
    let cuts: Vec<usize> = drilled.iter().map(|s| s.cut_edges).collect();
    let stats = partition_stats_with_cuts(g, &assign, m, &cuts);

    // Drill invariants — fail loudly in CI if any regresses.
    let parent_slab = g.features.slab_ptr();
    anyhow::ensure!(
        parent_slab.is_some(),
        "train graph is not slab-backed ({}) — the zero-copy prep \
         contract is broken at the source",
        g.features.backend()
    );
    for (p, (h, d)) in healthy.iter().zip(&drilled).enumerate() {
        anyhow::ensure!(
            h.cut_edges == d.cut_edges,
            "part {p}: drill changed the cut count"
        );
        if p == 0 {
            anyhow::ensure!(
                d.graph.num_nodes() == 0 && d.graph.features.is_empty(),
                "lost partition 0 was materialised"
            );
        } else {
            anyhow::ensure!(
                d.graph.features.slab_ptr() == parent_slab,
                "part {p}: survivor does not share the parent feature slab"
            );
        }
    }
    println!(
        "prep drill ok: |V|={} M={m} F=1, r={:.3}, survivors share one \
         {}-f32 slab ({} private feature bytes across survivors)",
        g.num_nodes(),
        stats.ratio_r,
        g.num_nodes() * g.feat_dim,
        drilled
            .iter()
            .map(|s| s.graph.features.heap_bytes())
            .sum::<usize>(),
    );
    Ok(())
}

/// Stage 2: the full Table-6-style MRR comparison.
fn training_drill(base: &RunConfig) -> anyhow::Result<()> {
    let mut t = Table::new(
        "Failure drill: F=1 of M=3 (trainer 0 never starts)",
        &["Approach", "MRR healthy", "MRR F=1", "Δ", "Survivors"],
    );
    for approach in [Approach::RandomTma, Approach::PsgdPa] {
        let healthy = run_experiment(&RunConfig {
            approach,
            ..base.clone()
        })?;
        let failed = run_experiment(&RunConfig {
            approach,
            failures: 1,
            failed_ids: vec![0],
            ..base.clone()
        })?;
        // Survivor count comes from the run's authoritative
        // `Control::live_count` (via RunResult), not drill bookkeeping.
        t.row(vec![
            approach.name().to_string(),
            format!("{:.4}", healthy.test_mrr),
            format!("{:.4}", failed.test_mrr),
            format!("{:+.4}", failed.test_mrr - healthy.test_mrr),
            format!(
                "{}/{}",
                failed.trainers_live, failed.trainers_spawned
            ),
        ]);
    }
    t.emit("failure_drill");
    println!(
        "expected shape: RandomTMA's Δ is small (a random third of the \
         data resembles the rest); PSGD-PA loses whole communities."
    );
    Ok(())
}
