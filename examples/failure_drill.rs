//! Failure drill: Q4 robustness, interactively.
//!
//! Runs the same training twice — once healthy, once with trainer 0
//! failed at start (its partition lost) — for both RandomTMA and
//! PSGD-PA, and prints the MRR deltas side by side. A compressed
//! version of Table 6 meant for eyeballing the robustness gap.

use random_tma::config::{Approach, RunConfig};
use random_tma::coordinator::run_experiment;
use random_tma::util::bench::Table;
use random_tma::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(&["quick"]);
    let base = RunConfig {
        dataset: args.str_or("dataset", "citation-sim"),
        quick: args.flag("quick"),
        train_secs: args.f64_or("train-secs", 15.0),
        agg_secs: args.f64_or("agg-secs", 1.5),
        trainers: args.usize_or("m", 3),
        seed: args.u64_or("seed", 17),
        ..RunConfig::default()
    };

    let mut t = Table::new(
        "Failure drill: F=1 of M=3 (trainer 0 never starts)",
        &["Approach", "MRR healthy", "MRR F=1", "Δ"],
    );
    for approach in [Approach::RandomTma, Approach::PsgdPa] {
        let healthy = run_experiment(&RunConfig {
            approach,
            ..base.clone()
        })?;
        let failed = run_experiment(&RunConfig {
            approach,
            failures: 1,
            failed_ids: vec![0],
            ..base.clone()
        })?;
        t.row(vec![
            approach.name().to_string(),
            format!("{:.4}", healthy.test_mrr),
            format!("{:.4}", failed.test_mrr),
            format!("{:+.4}", failed.test_mrr - healthy.test_mrr),
        ]);
    }
    t.emit("failure_drill");
    println!(
        "expected shape: RandomTMA's Δ is small (a random third of the \
         data resembles the rest); PSGD-PA loses whole communities."
    );
    Ok(())
}
