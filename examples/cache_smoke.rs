//! Cache round-trip smoke: generate a `--quick` preset, save it, then
//! reopen the cache both ways — the heap loader (`io::load`) and the
//! fully-mapped loader (`io::load_mapped`) — and assert that graph
//! statistics, partition cut counts and feature rows are identical.
//! This is the CI gate for the RTMAGRF2 cache path: a layout
//! regression (writer/reader disagreement, a section served from the
//! wrong offsets) fails this binary, not a training run three steps
//! later.
//!
//! Run under `RTMA_MMAP=1` the preset itself arrives mapped, so the
//! smoke also exercises the preset-level opt-in end to end. Without
//! `--quick` the full-size preset is generated and checked.
//!
//! ```text
//! cargo run --release --example cache_smoke -- --quick \
//!     [--preset mag-sim] [--seed 97]
//! ```

use random_tma::gen::{cache_path, load_preset, preset_names};
use random_tma::graph::stats::graph_stats;
use random_tma::graph::{induce_all, io};
use random_tma::partition::random_partition;
use random_tma::util::cli::Args;
use random_tma::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(&["quick"]);
    let quick = args.flag("quick");
    let preset = args.str_or("preset", "mag-sim");
    let seed = args.u64_or("seed", 97);
    anyhow::ensure!(
        preset_names().contains(&preset.as_str()),
        "unknown preset {preset:?}"
    );

    // Fresh generation (drop any stale cache), which also writes the
    // cache file this smoke is about.
    let path = cache_path(&preset, quick, seed);
    let _ = std::fs::remove_file(&path);
    let p = load_preset(&preset, quick, 16, 8, seed)?;
    anyhow::ensure!(path.exists(), "preset did not write {}", path.display());
    println!(
        "generated {preset}{}: |V|={} |E|={} [{} features]",
        if quick { " (quick)" } else { "" },
        p.graph.num_nodes(),
        p.graph.num_edges(),
        p.graph.features.backend(),
    );

    let heap = io::load(&path)?;
    let mapped = io::load_mapped(&path)?;
    anyhow::ensure!(
        mapped.offsets.backend() == "mapped"
            && mapped.neighbors.backend() == "mapped"
            && mapped.labels.backend() == "mapped"
            && mapped.features.backend() == "mapped",
        "load_mapped did not serve every section from the map"
    );

    // Graph statistics must agree exactly: both loaders read the same
    // bytes, so even the float-valued stats are bit-equal.
    let a = graph_stats(&heap);
    let b = graph_stats(&mapped);
    let same = a.num_nodes == b.num_nodes
        && a.num_edges == b.num_edges
        && a.feat_dim == b.feat_dim
        && a.num_classes == b.num_classes
        && a.num_relations == b.num_relations
        && a.avg_degree == b.avg_degree
        && a.max_degree == b.max_degree
        && a.homophily == b.homophily
        && a.isolated == b.isolated;
    anyhow::ensure!(same, "graph stats diverge:\n  heap {a:?}\n  map  {b:?}");
    println!(
        "stats ok: |V|={} |E|={} h={:.4} (heap == mapped)",
        a.num_nodes, a.num_edges, a.homophily
    );

    // Partition cut accounting must agree across loaders too (this is
    // what the coordinator's prep step consumes).
    let k = 4;
    let mut rng = Rng::new(seed ^ 0xC0DE);
    let assign = random_partition(heap.num_nodes(), k, &mut rng);
    let cuts_heap: Vec<usize> = induce_all(&heap, &assign, k)
        .iter()
        .map(|s| s.cut_edges)
        .collect();
    let cuts_mapped: Vec<usize> = induce_all(&mapped, &assign, k)
        .iter()
        .map(|s| s.cut_edges)
        .collect();
    anyhow::ensure!(
        cuts_heap == cuts_mapped,
        "cut counts diverge: heap {cuts_heap:?} vs mapped {cuts_mapped:?}"
    );
    println!("cuts ok: M={k} {cuts_heap:?} (heap == mapped)");

    // And the preset the coordinator actually received must match the
    // cache on a sample of feature rows, bit for bit.
    let n = heap.num_nodes();
    for v in [0, n / 3, n / 2, n - 1] {
        let rows = [p.graph.feature(v), heap.feature(v), mapped.feature(v)];
        anyhow::ensure!(
            rows[0].len() == rows[1].len() && rows[1].len() == rows[2].len(),
            "feature width diverges at node {v}"
        );
        for d in 0..rows[0].len() {
            anyhow::ensure!(
                rows[0][d].to_bits() == rows[1][d].to_bits()
                    && rows[1][d].to_bits() == rows[2][d].to_bits(),
                "feature bits diverge at node {v} dim {d}"
            );
        }
    }
    println!("features ok: sampled rows bit-identical across loaders");
    println!("cache round trip OK: {}", path.display());
    Ok(())
}
