//! Gradient-correctness suite for the native backend.
//!
//! Always-on and artifact-free: every test runs on a tiny
//! `Manifest::builtin_sized` layout (F=3, H=4, Bn=6, Be=5) with a
//! hand-built block, so a central-difference sweep over **all six**
//! variants stays fast. The analytic gradients from `grad_step` are
//! the ground truth the distributed modes (GGS allreduce, LLCG
//! correction) train on — a silently wrong backward term would still
//! "learn", just worse, which is exactly the failure mode plain
//! loss-goes-down tests cannot catch.

use random_tma::model::{Adam, ModelState};
use random_tma::runtime::{Manifest, ModelDims, NativeEngine};
use random_tma::sampler::Block;
use random_tma::util::rng::Rng;

const VARIANTS: [&str; 6] = [
    "gcn_mlp",
    "sage_mlp",
    "mlp_mlp",
    "gcn_distmult",
    "rgcn_mlp",
    "rgcn_distmult",
];

/// Small enough that a full finite-difference probe per variant is
/// cheap, large enough that every tensor kind (weights, biases,
/// LayerNorm, PReLU, relation bases) is exercised.
fn tiny() -> Manifest {
    Manifest::builtin_sized(
        ModelDims {
            feat_dim: 3,
            hidden: 4,
            block_nodes: 6,
            block_edges: 5,
            score_batch: 8,
            relations: 2,
        },
        2,
        2,
        2,
    )
}

/// Hand-built block: 5 used nodes, one padding row, one masked edge
/// slot. `relational` switches the adjacency to `R x Bn x Bn` planes
/// (rgcn encoders); `rel` ids are always valid so the same block
/// drives every decoder.
fn tiny_block(m: &Manifest, relational: bool, seed: u64) -> Block {
    let d = m.dims;
    let (bn, be, f) = (d.block_nodes, d.block_edges, d.feat_dim);
    let n_used = bn - 1;
    let mut rng = Rng::new(seed);
    let mut feats = vec![0f32; bn * f];
    for x in feats.iter_mut().take(n_used * f) {
        *x = 0.5 * rng.gaussian() as f32;
    }
    let planes = if relational { d.relations } else { 1 };
    let mut adj = vec![0f32; planes * bn * bn];
    for r in 0..planes {
        for i in 0..n_used {
            adj[r * bn * bn + i * bn + i] = 0.5;
            adj[r * bn * bn + i * bn + (i + 1 + r) % n_used] = 0.5;
        }
    }
    let mut mask = vec![1.0f32; be];
    mask[be - 1] = 0.0;
    Block {
        feats,
        adj,
        pos_u: (0..be).map(|e| (e % n_used) as i32).collect(),
        pos_v: (0..be).map(|e| ((e + 1) % n_used) as i32).collect(),
        rel: (0..be).map(|e| (e % d.relations) as i32).collect(),
        neg_v: (0..be).map(|e| ((e + 2) % n_used) as i32).collect(),
        mask,
        n_used,
        globals: (0..n_used as u32).collect(),
    }
}

fn engine_and_block(m: &Manifest, variant: &str, seed: u64) -> (NativeEngine, Block) {
    let engine = NativeEngine::new(m, variant).expect(variant);
    let block = tiny_block(m, engine.variant.encoder == "rgcn", seed);
    (engine, block)
}

/// Central differences vs `grad_step` for every variant. Per-probe
/// tolerance absorbs f32 forward noise and the PReLU kink; the
/// aggregate relative-L2 bound catches a systematically wrong term
/// even if each probe squeaks under the pointwise bound.
#[test]
fn grad_matches_central_difference_on_all_variants() {
    let m = tiny();
    for variant in VARIANTS {
        let (engine, block) = engine_and_block(&m, variant, 0xC0FFEE);
        let mut rng = Rng::new(21);
        let state = ModelState::init(&engine.variant, &mut rng);
        let p0 = state.params.clone();
        let (grad, loss) = engine.grad_step(&p0, &block).unwrap();
        assert!(
            loss.is_finite() && loss > 0.0,
            "{variant}: loss {loss}"
        );
        assert_eq!(grad.len(), p0.len(), "{variant}: grad length");
        assert!(
            grad.iter().any(|&g| g != 0.0),
            "{variant}: all-zero gradient"
        );

        let n = p0.len();
        let h = 1e-3f32;
        let stride = n.div_ceil(48).max(1);
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        let mut i = 0;
        while i < n {
            let mut p = p0.clone();
            p[i] = p0[i] + h;
            let (_, lp) = engine.grad_step(&p, &block).unwrap();
            p[i] = p0[i] - h;
            let (_, lm) = engine.grad_step(&p, &block).unwrap();
            let fd = (lp - lm) / (2.0 * h);
            let g = grad[i];
            let diff = (fd - g).abs();
            assert!(
                diff < 1e-2 + 0.05 * fd.abs().max(g.abs()),
                "{variant}: param {i} analytic {g} vs central-diff {fd}"
            );
            num += (diff * diff) as f64;
            den += (fd * fd + g * g) as f64;
            i += stride;
        }
        assert!(
            num <= 1e-3 * den.max(1e-6),
            "{variant}: relative grad error {} over probed set",
            (num / den.max(1e-6)).sqrt()
        );
    }
}

/// `train_step`'s fused Adam must reproduce `grad_step` followed by
/// the rust-side `model::Adam` — the GGS baseline and the TMA trainers
/// are the same update rule, only the aggregation schedule differs.
#[test]
fn train_step_matches_grad_step_plus_rust_adam() {
    let m = tiny();
    for variant in VARIANTS {
        let (engine, block) = engine_and_block(&m, variant, 7);
        let mut rng = Rng::new(33);
        let mut state = ModelState::init(&engine.variant, &mut rng);
        let mut reference = state.params.clone();
        let mut adam = Adam::new(m.adam, reference.len());
        for step in 0..3 {
            let (grad, loss_g) =
                engine.grad_step(&reference, &block).unwrap();
            adam.step(&mut reference, &grad);
            let loss_t = engine.train_step(&mut state, &block).unwrap();
            assert!(
                (loss_g - loss_t).abs() < 1e-6,
                "{variant} step {step}: losses {loss_g} vs {loss_t}"
            );
            for (i, (a, b)) in
                state.params.iter().zip(&reference).enumerate()
            {
                assert!(
                    (a - b).abs() < 1e-5,
                    "{variant} step {step} param {i}: {a} vs {b}"
                );
            }
        }
        assert_eq!(state.step_count(), 3, "{variant}");
    }
}

/// Padded edge slots (mask 0) must be inert: scrambling their indices
/// changes neither the loss nor a single gradient element.
#[test]
fn masked_edge_slots_do_not_affect_loss_or_grad() {
    let m = tiny();
    for variant in ["gcn_mlp", "rgcn_distmult"] {
        let (engine, block) = engine_and_block(&m, variant, 11);
        let mut scrambled = block.clone();
        let last = scrambled.mask.len() - 1;
        assert_eq!(scrambled.mask[last], 0.0);
        scrambled.pos_u[last] = 0;
        scrambled.pos_v[last] = 0;
        scrambled.neg_v[last] = 0;
        scrambled.rel[last] = 0;

        let mut rng = Rng::new(5);
        let state = ModelState::init(&engine.variant, &mut rng);
        let (ga, la) = engine.grad_step(&state.params, &block).unwrap();
        let (gb, lb) =
            engine.grad_step(&state.params, &scrambled).unwrap();
        assert_eq!(la, lb, "{variant}: masked slot leaked into loss");
        assert_eq!(ga, gb, "{variant}: masked slot leaked into grad");
    }
}

/// Every variant optimises its own tiny problem: repeated steps on a
/// fixed block lower the loss and keep it finite.
#[test]
fn all_variants_learn_on_fixed_block() {
    let m = tiny();
    for variant in VARIANTS {
        let (engine, block) = engine_and_block(&m, variant, 19);
        let mut rng = Rng::new(23);
        let mut state = ModelState::init(&engine.variant, &mut rng);
        let first = engine.train_step(&mut state, &block).unwrap();
        let mut last = first;
        for _ in 0..40 {
            last = engine.train_step(&mut state, &block).unwrap();
        }
        assert!(last.is_finite(), "{variant}: diverged to {last}");
        assert!(
            last < first,
            "{variant}: no progress ({first} -> {last})"
        );
    }
}
