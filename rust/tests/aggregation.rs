//! Differential suite for the zero-clone aggregation data plane.
//!
//! The server's round collection is a streaming fold: each arriving
//! `TrainerMsg` is accumulated in place into one pre-sized buffer
//! (`model::MeanAccum`), so a round holds O(P) bytes however many
//! trainers report — where the old path staged all `M` vectors
//! (O(M·P)) before reducing. These tests lock the streamed aggregate
//! to the staged reference (`collect_round_staged` + `aggregate`)
//! **bit-for-bit** over random M/P grids, for both operators, at
//! several fold worker counts, plus the degenerate InverseLoss cases.

use std::sync::mpsc;
use std::time::Duration;

use random_tma::coordinator::kv::{RoundPayload, TrainerMsg};
use random_tma::coordinator::server::{collect_round, collect_round_staged};
use random_tma::model::{aggregate, AggregateOp, MeanAccum};
use random_tma::util::rng::Rng;

fn random_round(
    rng: &mut Rng,
    m: usize,
    p: usize,
    round: u64,
) -> Vec<TrainerMsg> {
    (0..m)
        .map(|id| TrainerMsg {
            id,
            round,
            payload: RoundPayload::Dense(
                (0..p).map(|_| (rng.gaussian() * 2.0) as f32).collect(),
            ),
            loss: if rng.chance(0.15) {
                f32::NAN // trainer with no batch yet
            } else {
                (rng.f64() * 3.0) as f32
            },
            steps: id as u64,
        })
        .collect()
}

fn send_all(tx: &mpsc::Sender<TrainerMsg>, msgs: &[TrainerMsg]) {
    for m in msgs {
        tx.send(m.clone()).unwrap();
    }
}

/// Run both collection paths over the same message sequence (same
/// arrival order — mpsc is FIFO) and return (reference, streamed).
fn both_paths(
    msgs: &[TrainerMsg],
    m: usize,
    round: u64,
    op: AggregateOp,
) -> (Vec<f32>, Vec<f32>) {
    let (tx, rx) = mpsc::channel();
    send_all(&tx, msgs);
    let (weights, losses) =
        collect_round_staged(&rx, m, round, Duration::from_secs(5), None);
    assert_eq!(weights.len(), m, "staged reference lost messages");
    let reference = aggregate(op, &weights, &losses);

    let (tx, rx) = mpsc::channel();
    send_all(&tx, msgs);
    let out = collect_round(&rx, m, round, Duration::from_secs(5), op);
    assert_eq!(out.reporters, m, "streaming path lost messages");
    (reference, out.global.expect("non-empty round"))
}

fn assert_bitwise(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{what}: element {i} diverged ({x} vs {y})"
        );
    }
}

#[test]
fn streaming_mean_bit_identical_to_staged_reference() {
    let mut rng = Rng::new(0x5EED);
    for m in [1usize, 2, 3, 4, 8, 16, 33] {
        for p in [1usize, 7, 129, 1024] {
            let msgs = random_round(&mut rng, m, p, 3);
            let (reference, streamed) =
                both_paths(&msgs, m, 3, AggregateOp::Mean);
            assert_bitwise(
                &reference,
                &streamed,
                &format!("mean m={m} p={p}"),
            );
        }
    }
}

#[test]
fn streaming_inverse_loss_bit_identical_to_staged_reference() {
    // InverseLoss rides the staging path inside collect_round (it
    // cannot scale any vector before every loss is known); the
    // differential still locks the whole collection protocol.
    let mut rng = Rng::new(0xAB1E);
    for m in [1usize, 2, 5, 16] {
        for p in [1usize, 33, 500] {
            let msgs = random_round(&mut rng, m, p, 9);
            let (reference, streamed) =
                both_paths(&msgs, m, 9, AggregateOp::InverseLoss);
            assert_bitwise(
                &reference,
                &streamed,
                &format!("inverse-loss m={m} p={p}"),
            );
        }
    }
}

#[test]
fn parallel_fold_workers_do_not_change_the_bits() {
    // The streaming fold chunks big vectors across worker threads;
    // disjoint windows never reorder per-element arithmetic, so the
    // aggregate is worker-count-invariant. (collect_round itself uses
    // the default worker count — this pins the invariant it relies
    // on, above the accumulator's serial-fold threshold.)
    // Above MeanAccum's serial-fold threshold (1 << 18), so the
    // chunked multi-worker path actually engages.
    let p = (1 << 18) + 777;
    let mut rng = Rng::new(42);
    let vectors: Vec<Vec<f32>> = (0..5)
        .map(|_| (0..p).map(|_| rng.gaussian() as f32).collect())
        .collect();
    let fold = |workers: usize| {
        let mut acc = MeanAccum::with_workers(p, workers);
        for v in &vectors {
            acc.add(v);
        }
        acc.mean()
    };
    let serial = fold(1);
    for workers in [2, 3, 8] {
        assert_bitwise(&serial, &fold(workers), &format!("w={workers}"));
    }
    // And the staged reference agrees with the serial fold.
    let reference =
        aggregate(AggregateOp::Mean, &vectors, &[0.0; 5]);
    assert_bitwise(&reference, &serial, "staged vs fold");
}

#[test]
fn inverse_loss_all_inf_losses_stay_finite_end_to_end() {
    // Regression: all-inf losses (every trainer diverged) used to
    // drive `total == 0` and NaN global weights through the whole
    // collection path. The operator now falls back to the plain mean.
    let (tx, rx) = mpsc::channel();
    for id in 0..2usize {
        tx.send(TrainerMsg {
            id,
            round: 1,
            payload: RoundPayload::Dense(vec![1.0 + id as f32; 3]),
            loss: f32::INFINITY,
            steps: 1,
        })
        .unwrap();
    }
    let out = collect_round(
        &rx,
        2,
        1,
        Duration::from_secs(5),
        AggregateOp::InverseLoss,
    );
    let agg = out.global.unwrap();
    assert!(
        agg.iter().all(|x| x.is_finite()),
        "NaN global weights: {agg:?}"
    );
    assert_eq!(agg, vec![1.5f32; 3], "falls back to the plain mean");
}
