//! Determinism suite for the parallel count-then-fill generators.
//!
//! Locks in the two properties the parallel rewrite promised:
//!
//! 1. for a fixed seed, every generator's output is **byte-identical**
//!    at any worker count (1, 2, and whatever this machine has) —
//!    thread scheduling never leaks into the sampled graph;
//! 2. the per-chunk RNG streams (`Rng::stream(seed, domain, chunk)`)
//!    don't collide across seeds, domains or chunk ids, so close-by
//!    seeds still produce independent graphs.

use random_tma::gen::{
    bipartite_with_workers, dcsbm_with_workers, sbm2_with_workers,
    BipartiteConfig, DcsbmConfig, Sbm2Config,
};
use random_tma::graph::Graph;
use random_tma::util::rng::Rng;

fn num_cpus() -> usize {
    std::thread::available_parallelism().map_or(1, |c| c.get())
}

/// Field-by-field byte equality, features compared bit-for-bit.
fn assert_identical(a: &Graph, b: &Graph, what: &str) {
    assert_eq!(a.offsets, b.offsets, "{what}: offsets");
    assert_eq!(a.neighbors, b.neighbors, "{what}: neighbors");
    assert_eq!(a.rel, b.rel, "{what}: rel");
    assert_eq!(a.labels, b.labels, "{what}: labels");
    assert_eq!(a.feat_dim, b.feat_dim, "{what}: feat_dim");
    assert!(
        a.features.rows_equal(&b.features, a.feat_dim),
        "{what}: features differ bitwise"
    );
    assert_eq!(a.num_classes, b.num_classes, "{what}: num_classes");
    assert_eq!(a.num_relations, b.num_relations, "{what}: num_relations");
}

#[test]
fn prop_dcsbm_identical_across_worker_counts() {
    random_tma::util::prop::check(6, 101, |rng: &mut Rng| {
        let cfg = DcsbmConfig {
            nodes: rng.range(50, 2000),
            communities: rng.range(1, 12),
            avg_degree: 4.0 + rng.f64() * 12.0,
            homophily: 0.5 + rng.f64() * 0.45,
            feat_dim: rng.range(0, 9),
            feature_noise: rng.f64(),
            degree_exponent: rng.f64(),
            seed: rng.next_u64(),
        };
        let cfg = DcsbmConfig {
            nodes: cfg.nodes.max(cfg.communities),
            ..cfg
        };
        let one = dcsbm_with_workers(&cfg, 1);
        for workers in [2, num_cpus()] {
            let w = dcsbm_with_workers(&cfg, workers);
            assert_identical(&one, &w, &format!("dcsbm workers={workers}"));
        }
        Ok(())
    });
}

#[test]
fn prop_sbm2_identical_across_worker_counts() {
    random_tma::util::prop::check(6, 103, |rng: &mut Rng| {
        let cfg = Sbm2Config {
            class_size: rng.range(20, 1500),
            avg_degree: 4.0 + rng.f64() * 12.0,
            homophily: rng.f64(),
            seed: rng.next_u64(),
        };
        let one = sbm2_with_workers(&cfg, 1);
        for workers in [2, num_cpus()] {
            let w = sbm2_with_workers(&cfg, workers);
            assert_identical(&one, &w, &format!("sbm2 workers={workers}"));
        }
        Ok(())
    });
}

#[test]
fn prop_bipartite_identical_across_worker_counts() {
    random_tma::util::prop::check(6, 107, |rng: &mut Rng| {
        let communities = rng.range(1, 8);
        let cfg = BipartiteConfig {
            num_queries: rng.range(10, 600),
            num_items: rng.range(communities.max(10), 900),
            communities,
            qi_degree: 1.0 + rng.f64() * 6.0,
            ii_degree: rng.f64() * 5.0,
            homophily: 0.5 + rng.f64() * 0.45,
            feat_dim: rng.range(1, 9),
            feature_noise: rng.f64(),
            seed: rng.next_u64(),
        };
        let one = bipartite_with_workers(&cfg, 1);
        for workers in [2, num_cpus()] {
            let w = bipartite_with_workers(&cfg, workers);
            assert_identical(
                &one.graph,
                &w.graph,
                &format!("bipartite workers={workers}"),
            );
            assert_eq!(one.boundary, w.boundary);
        }
        Ok(())
    });
}

/// Same config, different seeds: the graphs must differ (chunk streams
/// are seed-dependent, not chunk-id-only).
#[test]
fn different_seeds_produce_different_graphs() {
    let base = DcsbmConfig {
        nodes: 1000,
        communities: 8,
        avg_degree: 10.0,
        homophily: 0.8,
        feat_dim: 4,
        feature_noise: 0.3,
        degree_exponent: 0.7,
        seed: 500,
    };
    let a = dcsbm_with_workers(&base, 2);
    let b = dcsbm_with_workers(&DcsbmConfig { seed: 501, ..base }, 2);
    assert_ne!(a.neighbors, b.neighbors);
    assert!(!a.features.rows_equal(&b.features, a.feat_dim));
}

/// Chunk streams must not collide: over a grid of (seed, domain,
/// chunk) triples — including adjacent seeds, the classic collision
/// hazard for naive `seed + chunk` schemes — the first few outputs of
/// every stream are pairwise distinct.
#[test]
fn prop_chunk_streams_do_not_collide_across_seeds() {
    let mut seen = std::collections::HashMap::new();
    let mut rng = Rng::new(77);
    let mut seeds: Vec<u64> = (0..8).map(|s| 1000 + s).collect();
    seeds.extend((0..8).map(|_| rng.next_u64()));
    for &seed in &seeds {
        for domain in [0xDC02u64, 0x5B20, 0xB1A0] {
            for chunk in 0..32u64 {
                let mut s = Rng::stream(seed, domain, chunk);
                let sig = (s.next_u64(), s.next_u64());
                if let Some(prev) =
                    seen.insert(sig, (seed, domain, chunk))
                {
                    panic!(
                        "stream collision: {prev:?} and \
                         {:?} share {sig:?}",
                        (seed, domain, chunk)
                    );
                }
            }
        }
    }
    assert_eq!(seen.len(), seeds.len() * 3 * 32);
}
