//! Round-codec integration tests (PR: pluggable round codecs).
//!
//! 1. The identity session's data-plane frames are pinned bit-for-bit
//!    against hand-written golden bytes of the *pre-codec* wire
//!    layout: adding the codec layer must not move a single byte for
//!    anyone who never opts in.
//! 2. Top-k error feedback drains: every unsent coordinate is
//!    eventually shipped, the cumulative decoded stream equals the
//!    cumulative input exactly, and the residual reaches exactly zero.
//! 3. End-to-end: `collect_round_with` folding `Encoded` payloads
//!    agrees with the dense (pre-codec) collection path.
//! 4. Quantization round-trip error bounds through the public
//!    encoder/decoder API.

use std::sync::mpsc;
use std::time::Duration;

use random_tma::comm::codec::{self, CodecKind, RoundEncoder};
use random_tma::comm::{tags, Message, WireMsg};
use random_tma::coordinator::kv::{RoundPayload, TrainerMsg};
use random_tma::coordinator::server::{collect_round, collect_round_with};
use random_tma::model::AggregateOp;
use random_tma::util::rng::Rng;

// ---------------------------------------------------------------------------
// 1. identity == pre-codec wire, bit for bit

/// The pre-codec `Weights` frame, written out by hand from the frozen
/// wire spec (docs/COMM.md): tag 3, round u64, loss f32, steps u64,
/// count u64, count × f32 — all little-endian.
fn golden_weights(round: u64, loss: f32, steps: u64, data: &[f32]) -> Vec<u8> {
    let mut b = vec![3u8];
    b.extend_from_slice(&round.to_le_bytes());
    b.extend_from_slice(&loss.to_le_bytes());
    b.extend_from_slice(&steps.to_le_bytes());
    b.extend_from_slice(&(data.len() as u64).to_le_bytes());
    for x in data {
        b.extend_from_slice(&x.to_le_bytes());
    }
    b
}

/// The pre-codec `Broadcast` frame: tag 4, round u64, count u64,
/// count × f32.
fn golden_broadcast(round: u64, data: &[f32]) -> Vec<u8> {
    let mut b = vec![4u8];
    b.extend_from_slice(&round.to_le_bytes());
    b.extend_from_slice(&(data.len() as u64).to_le_bytes());
    for x in data {
        b.extend_from_slice(&x.to_le_bytes());
    }
    b
}

#[test]
fn identity_wire_is_bit_identical_to_pre_codec_protocol() {
    // An identity session never wraps payloads in WeightsEnc /
    // BroadcastEnc — it ships the same Weights/Broadcast frames as the
    // pre-codec build. Pin their encodings to golden bytes so a codec
    // refactor cannot silently shift the default wire.
    let mut rng = Rng::new(42);
    let data: Vec<f32> = (0..257).map(|_| rng.gaussian() as f32).collect();

    let w = Message::Weights {
        round: 9,
        loss: 0.625,
        steps: 1234,
        data: data.clone(),
    };
    assert_eq!(w.encode(), golden_weights(9, 0.625, 1234, &data));

    let b = Message::Broadcast { round: 3, data: data.clone() };
    assert_eq!(b.encode(), golden_broadcast(3, &data));

    // The borrowed (zero-clone) encode path produces the same bytes.
    let mut scratch = Vec::new();
    WireMsg::Weights { round: 9, loss: 0.625, steps: 1234, data: &data }
        .encode_into(&mut scratch);
    assert_eq!(scratch, golden_weights(9, 0.625, 1234, &data));
    WireMsg::Broadcast { round: 3, data: &data }.encode_into(&mut scratch);
    assert_eq!(scratch, golden_broadcast(3, &data));

    // Control frames are frozen too: Hello=1/Ready=2/Stop=5/Collect=6
    // with their pre-codec field layout.
    assert_eq!(
        Message::Hello { id: 7 }.encode(),
        [&[1u8][..], &7u32.to_le_bytes()[..]].concat()
    );
    assert_eq!(
        Message::Ready { id: 7 }.encode(),
        [&[2u8][..], &7u32.to_le_bytes()[..]].concat()
    );
    assert_eq!(Message::Stop.encode(), vec![5u8]);
    assert_eq!(
        Message::Collect { round: 11 }.encode(),
        [&[6u8][..], &11u64.to_le_bytes()[..]].concat()
    );
}

/// The tag registry (`comm::tags::all()`) is the machine-readable
/// source of wire tags: unique, contiguous from 1, and bit-identical
/// to the leading byte of every encoded frame. A new tag that
/// collides or skips a slot fails here before it reaches the wire.
#[test]
fn tag_registry_matches_encoded_frames() {
    let reg = tags::all();
    for (i, (tag, name)) in reg.iter().enumerate() {
        assert_eq!(*tag as usize, i + 1, "{name} breaks contiguity");
    }

    let by_name = |n: &str| -> u8 {
        reg.iter().find(|(_, name)| *name == n).expect(n).0
    };
    let cases: Vec<(&str, Message)> = vec![
        ("Hello", Message::Hello { id: 1 }),
        ("Ready", Message::Ready { id: 1 }),
        (
            "Weights",
            Message::Weights {
                round: 1,
                loss: 0.5,
                steps: 2,
                data: vec![1.0],
            },
        ),
        ("Broadcast", Message::Broadcast { round: 1, data: vec![1.0] }),
        ("Stop", Message::Stop),
        ("Collect", Message::Collect { round: 1 }),
        ("Codec", Message::Codec { codec: 0 }),
        (
            "WeightsEnc",
            Message::WeightsEnc {
                round: 1,
                loss: 0.5,
                steps: 2,
                codec: 1,
                n: 0,
                body: vec![],
            },
        ),
        (
            "BroadcastEnc",
            Message::BroadcastEnc { round: 1, codec: 1, n: 0, body: vec![] },
        ),
        (
            "QueryScore",
            Message::QueryScore { id: 1, pairs: vec![(0, 1, -1)] },
        ),
        ("QueryTopK", Message::QueryTopK { id: 1, node: 0, k: 1 }),
        ("ReplyScore", Message::ReplyScore { id: 1, scores: vec![0.5] }),
        (
            "ReplyTopK",
            Message::ReplyTopK { id: 1, items: vec![(0, 0.5)] },
        ),
    ];
    assert_eq!(cases.len(), reg.len(), "registry entry without a frame");
    for (name, msg) in cases {
        let frame = msg.encode();
        assert_eq!(frame[0], by_name(name), "{name} leads with its tag");
        // And the frame round-trips under the tag it declares.
        assert_eq!(Message::decode(&frame).unwrap(), msg);
    }
}

#[test]
fn identity_codec_body_is_raw_le_f32() {
    // Even when an identity body does go through the codec API (the
    // bench harness does this for ratio accounting), the body is the
    // raw LE f32 payload — the same bytes a Weights frame carries.
    let data: Vec<f32> = (0..100).map(|i| i as f32 * 0.5 - 3.0).collect();
    let mut enc = RoundEncoder::new(CodecKind::Identity, 1);
    let mut body = Vec::new();
    let id = enc.encode_up(&data, &[], &mut body);
    assert_eq!(id, codec::CODEC_IDENTITY);
    let raw: Vec<u8> =
        data.iter().flat_map(|x| x.to_le_bytes()).collect();
    assert_eq!(body, raw);
    let back = codec::decode_dense(id, data.len(), &body, &[]).unwrap();
    assert_eq!(
        back.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
        data.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
    );
}

// ---------------------------------------------------------------------------
// 2. top-k error feedback drains the residual to exactly zero

#[test]
fn topk_error_feedback_residual_drains_to_zero() {
    // Integer-valued gradients: every add below is exact in f32, so
    // the error-feedback invariant (cumulative shipped + residual =
    // cumulative input) holds bit-exactly, not just approximately.
    let n = 256usize;
    let denom = 32u32; // k = n/denom = 8 coordinates per round
    let k = n / denom as usize;
    let g: Vec<f32> = (0..n).map(|i| ((i % 5) as f32) - 2.0).collect();

    let mut enc = RoundEncoder::new(CodecKind::TopK { denom }, 77);
    let mut body = Vec::new();
    let mut cum = vec![0.0f32; n];

    // Ship the same gradient for a few rounds; k ≪ n, so most
    // coordinates land in the residual instead of on the wire.
    let rounds = 3;
    for _ in 0..rounds {
        let id = enc.encode_up(&g, &[], &mut body);
        assert_eq!(id, codec::CODEC_TOPK);
        let dec = codec::decode_dense(id, n, &body, &[]).unwrap();
        for (c, d) in cum.iter_mut().zip(&dec) {
            *c += d;
        }
    }
    assert!(
        enc.residual_norm() > 0.0,
        "with k={k} of n={n} shipped per round the residual must hold \
         unsent mass"
    );

    // Now feed zero input: each round ships the k largest leftover
    // residual coordinates exactly. Every coordinate is shipped at
    // least once within ceil(n/k) rounds, so the residual hits
    // *exactly* zero — error feedback loses nothing.
    let zeros = vec![0.0f32; n];
    let mut drained = None;
    for r in 0..n.div_ceil(k) {
        if enc.residual_norm() == 0.0 {
            drained = Some(r);
            break;
        }
        let id = enc.encode_up(&zeros, &[], &mut body);
        let dec = codec::decode_dense(id, n, &body, &[]).unwrap();
        for (c, d) in cum.iter_mut().zip(&dec) {
            *c += d;
        }
    }
    if enc.residual_norm() == 0.0 && drained.is_none() {
        drained = Some(n.div_ceil(k));
    }
    assert!(
        drained.is_some(),
        "residual norm {} after {} drain rounds — error feedback leaks",
        enc.residual_norm(),
        n.div_ceil(k)
    );

    // Cumulative decoded == cumulative input, exactly.
    for (i, (c, gi)) in cum.iter().zip(&g).enumerate() {
        assert!(
            *c == rounds as f32 * gi,
            "coordinate {i}: cumulative decode {c} != {}",
            rounds as f32 * gi
        );
    }
}

// ---------------------------------------------------------------------------
// 3. encoded collection agrees with the dense path end to end

fn mk_weights(m: usize, base: &[f32]) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(99);
    (0..m)
        .map(|_| {
            base.iter()
                .map(|b| b + 0.05 * rng.gaussian() as f32)
                .collect()
        })
        .collect()
}

#[test]
fn encoded_collect_round_matches_dense_collect_round() {
    let (m, p) = (3usize, 400usize);
    let mut rng = Rng::new(7);
    let base: Vec<f32> = (0..p).map(|_| rng.gaussian() as f32).collect();
    let weights = mk_weights(m, &base);

    // Reference: the dense (pre-codec) collection path.
    let (tx, rx) = mpsc::channel::<TrainerMsg>();
    for (id, w) in weights.iter().enumerate() {
        tx.send(TrainerMsg {
            id,
            round: 1,
            payload: RoundPayload::Dense(w.clone()),
            loss: 0.5,
            steps: 10,
        })
        .unwrap();
    }
    let dense = collect_round(
        &rx,
        m,
        1,
        Duration::from_secs(5),
        AggregateOp::Mean,
    );
    let dense_mean = dense.global.expect("dense round produced no mean");
    assert_eq!(dense.reporters, m);

    // delta decodes bit-exactly; topk:1 ships every coordinate (k=n)
    // so its first round is exact too. Both must land on the dense
    // mean up to fold-order rounding.
    for kind in [CodecKind::Delta, CodecKind::TopK { denom: 1 }] {
        let (tx, rx) = mpsc::channel::<TrainerMsg>();
        for (id, w) in weights.iter().enumerate() {
            let mut enc = RoundEncoder::new(kind, id as u64);
            let mut body = Vec::new();
            let cid = enc.encode_up(w, &base, &mut body);
            tx.send(TrainerMsg {
                id,
                round: 1,
                payload: RoundPayload::Encoded { codec: cid, n: p, body },
                loss: 0.5,
                steps: 10,
            })
            .unwrap();
        }
        let out = collect_round_with(
            &rx,
            &|| m,
            1,
            Duration::from_secs(5),
            AggregateOp::Mean,
            Some(&base),
        );
        assert_eq!(out.reporters, m, "{kind:?}");
        let mean = out.global.expect("encoded round produced no mean");
        for (i, (a, b)) in dense_mean.iter().zip(&mean).enumerate() {
            assert!(
                (a - b).abs() <= 1e-5 * a.abs().max(1.0),
                "{kind:?} coordinate {i}: encoded mean {b} != dense {a}"
            );
        }
    }
}

#[test]
fn encoded_collect_drops_undecodable_body_but_keeps_round_alive() {
    // A corrupt body must not kill the round: the reporter is dropped
    // from the count/loss bookkeeping, `comm_frames_rejected` bumps,
    // and the survivors still produce a finite aggregate. (The exact
    // mean is deliberately not pinned: a partially-applied fold can
    // leak into the sum on this can't-happen path — see the comment
    // in `collect_round_with`.)
    let (m, p) = (2usize, 50usize);
    let base = vec![0.0f32; p];
    let good = vec![1.0f32; p];
    let rejected_before = random_tma::telemetry::snapshot()
        .counter("comm_frames_rejected");
    let (tx, rx) = mpsc::channel::<TrainerMsg>();
    let mut enc = RoundEncoder::new(CodecKind::Delta, 5);
    let mut body = Vec::new();
    let cid = enc.encode_up(&good, &base, &mut body);
    tx.send(TrainerMsg {
        id: 0,
        round: 1,
        payload: RoundPayload::Encoded { codec: cid, n: p, body },
        loss: 0.5,
        steps: 1,
    })
    .unwrap();
    tx.send(TrainerMsg {
        id: 1,
        round: 1,
        // Garbage topk body: k far beyond n.
        payload: RoundPayload::Encoded {
            codec: codec::CODEC_TOPK,
            n: p,
            body: 999u32.to_le_bytes().to_vec(),
        },
        loss: 0.5,
        steps: 1,
    })
    .unwrap();
    drop(tx);
    let out = collect_round_with(
        &rx,
        &|| m,
        1,
        Duration::from_millis(300),
        AggregateOp::Mean,
        Some(&base),
    );
    assert_eq!(out.reporters, 1, "corrupt reporter must be dropped");
    let mean = out.global.expect("surviving reporter still aggregates");
    assert!(mean.iter().all(|x| x.is_finite()));
    let rejected_after = random_tma::telemetry::snapshot()
        .counter("comm_frames_rejected");
    assert!(
        rejected_after > rejected_before,
        "undecodable round body must bump comm_frames_rejected"
    );
}

// ---------------------------------------------------------------------------
// 4. quantization bounds through the public API

#[test]
fn quantization_roundtrip_error_is_bounded() {
    let n = 4096usize;
    let mut rng = Rng::new(13);
    let w: Vec<f32> = (0..n).map(|_| rng.gaussian() as f32).collect();

    // f16: relative error ≤ 2^-9 of |x| plus the subnormal flush.
    let mut enc = RoundEncoder::new(CodecKind::F16, 3);
    let mut body = Vec::new();
    let id = enc.encode_up(&w, &[], &mut body);
    assert_eq!(body.len(), n * 2, "f16 body is 2 bytes per element");
    let back = codec::decode_dense(id, n, &body, &[]).unwrap();
    for (x, y) in w.iter().zip(&back) {
        let bound = x.abs() as f64 / 512.0 + 6.2e-5;
        assert!(
            ((x - y).abs() as f64) <= bound,
            "f16 {x} -> {y} exceeds {bound}"
        );
    }

    // i8: absolute error ≤ one quantization step (chunk maxabs / 127).
    let mut enc = RoundEncoder::new(CodecKind::I8, 3);
    let id = enc.encode_up(&w, &[], &mut body);
    assert!(
        body.len() < n + 8,
        "i8 body {} should be ~1 byte per element",
        body.len()
    );
    let back = codec::decode_dense(id, n, &body, &[]).unwrap();
    let step = w.iter().fold(0f32, |a, x| a.max(x.abs())) / 127.0;
    for (x, y) in w.iter().zip(&back) {
        assert!(
            (x - y).abs() <= step * 1.0001 + 1e-12,
            "i8 {x} -> {y} exceeds step {step}"
        );
    }
}
