//! Seeded byte-level fuzz of the wire decode surface
//! (docs/ANALYSIS.md): thousands of mutated frames through
//! `recv_into`, and mutated codec bodies through `decode_fold`.
//!
//! Properties under fuzz:
//! - no panic, ever — corrupt input is an `Err`, not a crash;
//! - no over-cap allocation: a hostile length prefix grows the
//!   receive scratch only as far as bytes actually delivered
//!   (chunked reads), never the announced length;
//! - a corrupt codec body at worst drops that reporter — for the
//!   length-prefix-validated codecs (`identity`, `f16`) the
//!   accumulator is untouched, and for all codecs a subsequent good
//!   report still folds and the round still produces a mean.
//!
//! Deterministic on purpose: every mutation comes from
//! `Rng::stream`, so a failure replays from the iteration index.

use random_tma::comm::codec::{
    decode_fold, CodecKind, RoundEncoder, CODEC_F16, CODEC_IDENTITY,
};
use random_tma::comm::{recv_into, Message};
use random_tma::model::MeanAccum;
use random_tma::util::rng::Rng;

/// One valid body per wire-message shape (no length prefix).
fn corpus() -> Vec<Vec<u8>> {
    let data = vec![0.5f32, -1.25, 3.0, 0.0];
    let msgs = vec![
        Message::Hello { id: 7 },
        Message::Ready { id: 7 },
        Message::Weights {
            round: 3,
            loss: 0.25,
            steps: 40,
            data: data.clone(),
        },
        Message::Broadcast { round: 3, data },
        Message::Stop,
        Message::Collect { round: 9 },
        Message::Codec { codec: 1 },
        Message::WeightsEnc {
            round: 3,
            loss: 0.25,
            steps: 40,
            codec: 1,
            n: 4,
            body: vec![1, 2, 3, 4],
        },
        Message::BroadcastEnc {
            round: 3,
            codec: 1,
            n: 4,
            body: vec![1, 2, 3, 4],
        },
        Message::QueryScore {
            id: 11,
            pairs: vec![(1, 2, 0), (3, 4, 1)],
        },
        Message::QueryTopK { id: 12, node: 5, k: 3 },
        Message::ReplyScore { id: 11, scores: vec![0.5, -0.5] },
        Message::ReplyTopK {
            id: 12,
            items: vec![(1, 0.9), (2, 0.1)],
        },
    ];
    msgs.iter().map(Message::encode).collect()
}

/// Mutate `frame` (4-byte LE prefix + body) in place.
fn mutate(rng: &mut Rng, frame: &mut Vec<u8>) {
    match rng.below(4) {
        // flip a handful of bytes anywhere (prefix included)
        0 => {
            for _ in 0..rng.range(1, 8) {
                let i = rng.below(frame.len());
                frame[i] ^= rng.next_u64() as u8;
            }
        }
        // truncate
        1 => {
            let keep = rng.below(frame.len());
            frame.truncate(keep);
        }
        // extend with garbage
        2 => {
            for _ in 0..rng.range(1, 64) {
                frame.push(rng.next_u64() as u8);
            }
        }
        // hostile prefix: announce an arbitrary (possibly huge)
        // length over the same small body
        _ => {
            let lie = rng.next_u64() as u32;
            frame[..4].copy_from_slice(&lie.to_le_bytes());
        }
    }
}

#[test]
fn fuzzed_frames_never_panic_or_overallocate() {
    let corpus = corpus();
    let max_body = corpus.iter().map(Vec::len).max().unwrap();
    let mut scratch = Vec::new();
    let mut ok = 0u64;
    let mut err = 0u64;
    for i in 0..10_000u64 {
        let mut rng = Rng::stream(0xFEED_FACE, 17, i);
        let body = &corpus[rng.below(corpus.len())];
        let mut frame =
            Vec::with_capacity(4 + body.len() + 64);
        frame.extend_from_slice(
            &(body.len() as u32).to_le_bytes(),
        );
        frame.extend_from_slice(body);
        mutate(&mut rng, &mut frame);
        match recv_into(&mut &frame[..], &mut scratch) {
            Ok(_) => ok += 1,
            Err(_) => err += 1,
        }
        // The scratch buffer tracks delivered bytes (one 64 KiB
        // read chunk of slack), never a hostile announced length.
        assert!(
            scratch.capacity() <= max_body + 64 + 2 * 64 * 1024,
            "scratch over-allocated to {} at iteration {i}",
            scratch.capacity()
        );
    }
    // The mutator must exercise both sides to mean anything.
    assert!(ok > 0, "no mutated frame survived decode");
    assert!(err > 0, "no mutated frame was rejected");
}

#[test]
fn unmutated_corpus_roundtrips() {
    // Anchor for the fuzz loop: every corpus frame is valid as-is,
    // so each Err above is the mutation's doing.
    let mut scratch = Vec::new();
    for body in corpus() {
        let mut frame = (body.len() as u32).to_le_bytes().to_vec();
        frame.extend_from_slice(&body);
        let msg = recv_into(&mut &frame[..], &mut scratch)
            .expect("corpus frame must decode");
        assert_eq!(msg.encode(), body);
    }
}

#[test]
fn fuzzed_codec_bodies_never_panic_and_reports_still_land() {
    const N: usize = 64;
    let base: Vec<f32> = (0..N).map(|i| i as f32 * 0.5).collect();
    let w: Vec<f32> = (0..N).map(|i| 1.0 + i as f32 * 0.25).collect();
    let kinds = [
        CodecKind::Identity,
        CodecKind::Delta,
        CodecKind::F16,
        CodecKind::I8,
        CodecKind::TopK { denom: 4 },
    ];
    // Valid encoded bodies, one per codec family.
    let mut bodies: Vec<(u8, Vec<u8>)> = Vec::new();
    for kind in kinds {
        let mut enc = RoundEncoder::new(kind, 0xC0DEC);
        let mut out = Vec::new();
        let id = enc.encode_up(&w, &base, &mut out);
        bodies.push((id, out));
    }
    let mut good = Vec::new();
    for x in &w {
        good.extend_from_slice(&x.to_le_bytes());
    }

    let mut dropped_clean = 0u64;
    let mut errs = 0u64;
    for i in 0..10_000u64 {
        let mut rng = Rng::stream(0xDEAD_BEA7, 23, i);
        let (id, valid) = &bodies[rng.below(bodies.len())];
        let mut body = valid.clone();
        // Reuse the frame mutator minus the prefix arm: flip,
        // truncate or extend the raw body.
        match rng.below(3) {
            0 => {
                for _ in 0..rng.range(1, 8) {
                    if body.is_empty() {
                        break;
                    }
                    let j = rng.below(body.len());
                    body[j] ^= rng.next_u64() as u8;
                }
            }
            1 => {
                let keep = rng.below(body.len().max(1));
                body.truncate(keep);
            }
            _ => {
                for _ in 0..rng.range(1, 64) {
                    body.push(rng.next_u64() as u8);
                }
            }
        }
        // Occasionally fuzz the codec id too (unknown ids must be
        // a clean error).
        let id = if rng.chance(0.05) {
            rng.next_u64() as u8
        } else {
            *id
        };

        let mut acc = MeanAccum::with_workers(N, 1);
        let before = acc.count();
        let r = decode_fold(id, N, &body, &base, &mut acc);
        if r.is_err() {
            errs += 1;
            // identity/f16 validate the body length before touching
            // the accumulator: the corrupt reporter vanishes.
            if (id == CODEC_IDENTITY || id == CODEC_F16)
                && acc.count() == before
            {
                dropped_clean += 1;
            }
        }
        // Whatever the fuzz did, a good report still lands and the
        // round still closes with a full-length mean.
        decode_fold(CODEC_IDENTITY, N, &good, &base, &mut acc)
            .expect("good identity body must fold");
        let mean = acc.mean_with(Some(&base));
        assert_eq!(mean.len(), N, "iteration {i}");
    }
    assert!(errs > 0, "no mutated body was rejected");
    assert!(
        dropped_clean > 0,
        "no clean reporter drop was observed"
    );
}
