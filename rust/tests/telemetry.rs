//! Telemetry integration tests (no artifacts required).
//!
//! 1. **Traced server run** — mock trainers + a mock evaluator drive
//!    the real `tma_server` loop with a JSONL sink armed; the trace
//!    must fold into per-round rows carrying all four server phases,
//!    the final counters record must show the run's rounds, and the
//!    val curve timestamps (stamped off the shared run epoch) must be
//!    monotone.
//! 2. **Comm loopback** — one framed send/recv over a loopback socket
//!    bumps the wire byte/frame counters by at least the frame size.
//! 3. **Schema pin** — every line kind (event/span/counters) carries
//!    the required keys and its kind-specific fields; this is the
//!    JSONL schema contract `rtma trace-report` validates in CI.
//!
//! The trace sink is process-global, so the tests that arm it
//! serialize on one mutex and use distinct sink files.

use std::net::{TcpListener, TcpStream};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;
use std::time::Duration;

use random_tma::comm::{recv, send_wire, Message, WireMsg};
use random_tma::config::RunConfig;
use random_tma::coordinator::evaluator::{EvalDone, EvalReq};
use random_tma::comm::codec::CodecKind;
use random_tma::coordinator::kv::{
    Control, GlobalWeights, RoundPayload, TrainerAction, TrainerMsg,
};
use random_tma::coordinator::server::tma_server;
use random_tma::telemetry::{self, report, Level};
use random_tma::util::json::Json;

/// Serializes the tests that arm the process-global trace sink.
static TRACE_LOCK: Mutex<()> = Mutex::new(());

fn lock_trace() -> std::sync::MutexGuard<'static, ()> {
    TRACE_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Mock trainer: the exact control-flow skeleton of `tma_trainer`
/// (ready mark → initial broadcast → next_action loop), with a cheap
/// arithmetic body standing in for the engine step.
fn mock_trainer(
    id: usize,
    control: Arc<Control>,
    rx: mpsc::Receiver<GlobalWeights>,
    tx: mpsc::Sender<TrainerMsg>,
) -> u64 {
    control.mark_ready();
    let mut w = rx.recv().expect("initial broadcast").to_vec();
    let mut last_round = 0u64;
    let mut steps = 0u64;
    loop {
        match control.next_action(last_round) {
            TrainerAction::Train => {
                steps += 1;
                for x in w.iter_mut() {
                    *x += 1e-3;
                }
                thread::sleep(Duration::from_millis(2));
            }
            TrainerAction::Ship { round } => {
                tx.send(TrainerMsg {
                    id,
                    round,
                    payload: RoundPayload::Dense(w.clone()),
                    loss: 0.5,
                    steps,
                })
                .ok();
                match rx.recv() {
                    Ok(g) => w = g.to_vec(),
                    Err(_) => break,
                }
                last_round = round;
            }
            TrainerAction::Stop => break,
        }
    }
    steps
}

#[test]
fn traced_server_run_produces_foldable_jsonl() {
    let _guard = lock_trace();
    telemetry::set_level(Level::Off);
    let path = std::env::temp_dir().join("rtma_trace_server_test.jsonl");
    std::fs::remove_file(&path).ok(); // sink appends
    telemetry::set_trace_path(Some(&path)).unwrap();

    let m = 2usize;
    let cfg = RunConfig {
        trainers: m,
        train_secs: 1.2,
        agg_secs: 0.25,
        ..RunConfig::default()
    };
    let control = Arc::new(Control::new());
    let (msg_tx, msg_rx) = mpsc::channel::<TrainerMsg>();
    let (eval_tx, eval_req_rx) = mpsc::channel::<EvalReq>();
    let (eval_done_tx, eval_done_rx) = mpsc::channel::<EvalDone>();

    // Mock evaluator: echo every periodic request as MRR 0.5.
    let evaluator = thread::spawn(move || {
        while let Ok(req) = eval_req_rx.recv() {
            if let EvalReq::Periodic { round, t, .. } = req {
                eval_done_tx
                    .send(EvalDone { round, t, mrr: 0.5, is_final: false })
                    .ok();
            }
        }
    });

    let mut txs = Vec::new();
    let mut trainers = Vec::new();
    for id in 0..m {
        let (tx, rx) = mpsc::channel::<GlobalWeights>();
        txs.push(tx);
        let control = control.clone();
        let msg_tx = msg_tx.clone();
        trainers
            .push(thread::spawn(move || mock_trainer(id, control, rx, msg_tx)));
    }

    let outcome = tma_server(
        &cfg,
        &control,
        vec![0.0f32; 64],
        &txs,
        &msg_rx,
        &eval_tx,
        &eval_done_rx,
        None,
        CodecKind::Identity,
    )
    .expect("server run");

    drop(txs);
    drop(eval_tx);
    for t in trainers {
        assert!(t.join().unwrap() > 0, "mock trainer took no steps");
    }
    evaluator.join().unwrap();
    telemetry::flush();
    telemetry::set_trace_path(None).unwrap();

    assert!(outcome.rounds >= 2, "only {} rounds", outcome.rounds);
    // Epoch satellite: every eval timestamp measures from the shared
    // run epoch, so the curve is monotone in t.
    assert!(!outcome.val_curve.is_empty(), "no eval points landed");
    for w in outcome.val_curve.windows(2) {
        assert!(
            w[1].t >= w[0].t,
            "val curve went backwards: {} -> {}",
            w[0].t,
            w[1].t
        );
    }

    let text = std::fs::read_to_string(&path).unwrap();
    let rep = report::parse_trace(&text).expect("trace must validate");
    std::fs::remove_file(&path).ok();
    assert!(rep.spans > 0 && rep.lines > 0);
    assert!(!rep.rounds.is_empty(), "no per-round span rows folded");
    assert!(
        rep.rounds
            .iter()
            .any(|r| r.phase_n.iter().all(|&n| n > 0)),
        "no round carries all four server phases: {:?}",
        rep.rounds
    );
    // The server's end-of-run counters record must be present and
    // show the rounds this run opened.
    assert!(rep.counter_records >= 1);
    assert!(
        rep.counters.get("rounds_opened").copied().unwrap_or(0.0) >= 1.0,
        "counters record missing rounds_opened: {:?}",
        rep.counters
    );
}

#[test]
fn comm_loopback_bumps_wire_counters() {
    let base = telemetry::snapshot();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let n = 256usize;
    let sender = thread::spawn(move || {
        let mut s = TcpStream::connect(addr).unwrap();
        let mut scratch = Vec::new();
        let data = vec![1.0f32; n];
        send_wire(
            &mut s,
            &WireMsg::Broadcast { round: 1, data: &data },
            &mut scratch,
        )
        .unwrap();
        // Second send through the same scratch: steady-state reuse.
        send_wire(
            &mut s,
            &WireMsg::Broadcast { round: 2, data: &data },
            &mut scratch,
        )
        .unwrap();
    });
    let (mut s, _) = listener.accept().unwrap();
    for want in 1..=2u64 {
        match recv(&mut s).unwrap() {
            Message::Broadcast { round, data } => {
                assert_eq!(round, want);
                assert_eq!(data.len(), n);
            }
            other => panic!("unexpected {other:?}"),
        }
    }
    sender.join().unwrap();

    // Parallel tests may bump these too, so all assertions are >=.
    let frame = (4 + 1 + 8 + 8 + n * 4) as u64; // len + tag + round + count + payload
    let d = telemetry::snapshot().delta_since(&base);
    assert!(d.counter("comm_frames_out") >= 2);
    assert!(d.counter("comm_frames_in") >= 2);
    assert!(d.counter("comm_bytes_out") >= frame, "{d:?}");
    assert!(d.counter("comm_bytes_in") >= frame, "{d:?}");
    assert!(
        d.counter("comm_scratch_reuse") >= 1,
        "second send must reuse scratch capacity: {d:?}"
    );
}

#[test]
fn jsonl_schema_carries_required_and_kind_fields() {
    let _guard = lock_trace();
    telemetry::set_level(Level::Off);
    let path = std::env::temp_dir().join("rtma_trace_schema_test.jsonl");
    std::fs::remove_file(&path).ok();
    telemetry::set_trace_path(Some(&path)).unwrap();

    telemetry::info(
        "test",
        "pinned_event",
        &[("answer", 42.0)],
        format_args!("hello"),
    );
    {
        let _sp = telemetry::Span::start("test", "pinned_span")
            .round(7)
            .trainer(3);
    }
    telemetry::trace_counters("test");
    telemetry::flush();
    telemetry::set_trace_path(None).unwrap();

    let text = std::fs::read_to_string(&path).unwrap();
    std::fs::remove_file(&path).ok();
    // Every line validates through the report parser...
    report::parse_trace(&text).expect("schema-valid trace");
    // ...and the pinned lines carry their kind-specific fields.
    let lines: Vec<Json> = text
        .lines()
        .filter(|l| !l.trim().is_empty())
        .map(|l| Json::parse(l).expect("line parses"))
        .collect();
    for j in &lines {
        for k in report::REQUIRED_KEYS {
            assert!(j.get(k) != &Json::Null, "missing {k} in {j}");
        }
    }
    let event = lines
        .iter()
        .find(|j| j.get("name").as_str() == Some("pinned_event"))
        .expect("event line");
    assert_eq!(event.get("lvl").as_str(), Some("info"));
    assert_eq!(event.get("msg").as_str(), Some("hello"));
    assert_eq!(event.get("answer").as_f64(), Some(42.0));
    let span = lines
        .iter()
        .find(|j| j.get("name").as_str() == Some("pinned_span"))
        .expect("span line");
    assert!(span.get("dur_us").as_f64().is_some());
    assert_eq!(span.get("round").as_f64(), Some(7.0));
    assert_eq!(span.get("trainer").as_f64(), Some(3.0));
    let counters = lines
        .iter()
        .find(|j| j.get("kind").as_str() == Some("counters"))
        .expect("counters line");
    assert!(
        counters
            .get("counters")
            .get("rounds_opened")
            .as_f64()
            .is_some(),
        "counters record must nest the registry"
    );
}
