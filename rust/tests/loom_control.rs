//! Exhaustive interleaving models of `coordinator::kv::Control` —
//! the crate's loom-style correctness suite (docs/ANALYSIS.md).
//!
//! `Control` is all `SeqCst` atomics, so every real execution is
//! equivalent to some total order of its atomic operations. These
//! tests transcribe the production decision logic at atomic-op
//! granularity (one explorer step = one load or store) and run
//! `util::interleave::explore` over *every* schedule, asserting the
//! properties the driver relies on:
//!
//! - round-before-stop: a trainer can never observe `Stop` while the
//!   server's final collection round is still unanswered
//!   (`Control::next_action`'s re-read, mirroring
//!   `next_action_orders_round_before_stop` in kv.rs — but here over
//!   the full schedule space, not one lucky ordering);
//! - no double ship: a trainer never ships the same round twice;
//! - ready barrier: `wait_ready`'s release condition is eventually
//!   true in every schedule once each trainer has marked ready or
//!   dead — `mark_dead` really does release a stuck barrier.
//!
//! Each test also asserts the explored-schedule count equals the
//! multinomial of the step counts: proof the walk was exhaustive.

use random_tma::util::interleave::{explore, interleavings, Step};

/// What one poll of `next_action` decided.
#[derive(Clone, Copy, PartialEq, Debug)]
enum Action {
    Train,
    Ship(u64),
    Stop,
}

/// Shared state for the stop-handshake model: the server's two
/// atomics plus one trainer's registers and outcome log.
#[derive(Clone)]
struct StopModel {
    // server side (the atomics)
    round: u64,
    stop: bool,
    // trainer side (registers of the current poll)
    r1: u64,
    st: bool,
    r2: u64,
    // trainer loop state
    last: u64,
    done: bool,
    shipped: Vec<u64>,
}

impl StopModel {
    fn new() -> StopModel {
        StopModel {
            round: 0,
            stop: false,
            r1: 0,
            st: false,
            r2: 0,
            last: 0,
            done: false,
            shipped: Vec::new(),
        }
    }
}

// Server program: open the final round, THEN raise stop — the
// ordering `tma_server` promises (kv.rs `next_action` doc).
fn srv_open(s: &mut StopModel, _t: usize) {
    s.round += 1; // open_round: agg_round.fetch_add
}

fn srv_stop(s: &mut StopModel, _t: usize) {
    s.stop = true; // request_stop: stop.store(true)
}

// Trainer poll, transcribed from `Control::next_action` with one
// explorer step per atomic load. The decision applies in the last
// step; a finished trainer no-ops.
fn tr_load_round(s: &mut StopModel, _t: usize) {
    s.r1 = s.round; // current_round()
}

fn tr_load_stop(s: &mut StopModel, _t: usize) {
    s.st = s.stop; // stopped()
}

fn tr_decide(s: &mut StopModel, _t: usize) {
    s.r2 = s.round; // the final-round re-read
    if s.done {
        return;
    }
    let action = if s.r1 > s.last {
        Action::Ship(s.r1)
    } else if s.st {
        if s.r2 > s.last {
            Action::Ship(s.r2)
        } else {
            Action::Stop
        }
    } else {
        Action::Train
    };
    match action {
        Action::Ship(r) => {
            s.shipped.push(r);
            s.last = r;
        }
        Action::Stop => s.done = true,
        Action::Train => {}
    }
}

#[test]
fn stop_never_races_past_the_final_round() {
    let server: Vec<Step<StopModel>> = vec![srv_open, srv_stop];
    let trainer: Vec<Step<StopModel>> =
        vec![tr_load_round, tr_load_stop, tr_decide];
    // Two consecutive polls: enough for every phase combination of
    // (train / ship final / observe stop) around the server's two
    // stores.
    let mut prog = trainer.clone();
    prog.extend(trainer.iter().copied());
    let threads = vec![server, prog];

    let mut exited = 0u64;
    let mut still_running = 0u64;
    let n = explore(&StopModel::new(), &threads, &mut |s| {
        // No double ship, ever.
        let mut seen = s.shipped.clone();
        seen.dedup();
        assert_eq!(seen, s.shipped, "round shipped twice: {:?}", s.shipped);
        // The load-bearing property: an exited trainer has always
        // shipped the final round first. A schedule where `done`
        // holds with `shipped` empty is exactly the historical
        // silent-exit bug.
        if s.done {
            assert_eq!(
                s.shipped,
                vec![1],
                "trainer exited with the final round unanswered"
            );
            exited += 1;
        } else {
            still_running += 1;
        }
    });
    assert_eq!(n, interleavings(&[2, 6]), "walk was not exhaustive");
    assert_eq!(n, 28);
    // Both terminal phases must actually occur across schedules —
    // otherwise the assertions above were vacuous.
    assert!(exited > 0, "no schedule reached a clean exit");
    assert!(still_running > 0, "no schedule left the trainer mid-loop");
}

// The pre-fix decision order (stop checked first, no re-read): the
// explorer must surface the silent exit in at least one schedule.
// This is the suite's own canary — if the model or explorer ever
// weakens, this test fails first.
fn buggy_decide(s: &mut StopModel, _t: usize) {
    if s.done {
        return;
    }
    if s.st {
        s.done = true;
    } else if s.r1 > s.last {
        s.shipped.push(s.r1);
        s.last = s.r1;
    }
}

#[test]
fn explorer_catches_the_historical_stop_first_bug() {
    let server: Vec<Step<StopModel>> = vec![srv_open, srv_stop];
    // Buggy poll order: load stop, then round, decide without
    // re-reading.
    let trainer: Vec<Step<StopModel>> =
        vec![tr_load_stop, tr_load_round, buggy_decide];
    let threads = vec![server, trainer];

    let mut silent_exits = 0u64;
    let n = explore(&StopModel::new(), &threads, &mut |s| {
        if s.done && s.shipped.is_empty() {
            silent_exits += 1;
        }
    });
    assert_eq!(n, interleavings(&[2, 3]));
    assert!(
        silent_exits > 0,
        "the explorer failed to find the known bug — model broken"
    );
}

/// Ready-barrier model: one trainer marks ready, one dies, the
/// server polls `wait_ready`'s condition once (dead load, then ready
/// load, then the comparison — the exact order in kv.rs).
#[derive(Clone)]
struct BarrierModel {
    ready: usize,
    dead: usize,
    obs_dead: usize,
    obs_ready: usize,
    released: Option<usize>,
}

const TOTAL: usize = 2;

fn tr_mark_ready(s: &mut BarrierModel, _t: usize) {
    s.ready += 1; // mark_ready: ready.fetch_add
}

fn tr_mark_dead(s: &mut BarrierModel, _t: usize) {
    s.dead += 1; // mark_dead: dead.fetch_add
}

fn srv_load_dead(s: &mut BarrierModel, _t: usize) {
    s.obs_dead = s.dead; // dead_count()
}

fn srv_load_ready(s: &mut BarrierModel, _t: usize) {
    s.obs_ready = s.ready; // ready_count()
}

fn srv_release(s: &mut BarrierModel, _t: usize) {
    if s.obs_ready + s.obs_dead >= TOTAL {
        s.released = Some(TOTAL - s.obs_dead.min(TOTAL));
    }
}

#[test]
fn mark_dead_releases_the_ready_barrier() {
    let init = BarrierModel {
        ready: 0,
        dead: 0,
        obs_dead: 0,
        obs_ready: 0,
        released: None,
    };
    let threads: Vec<Vec<Step<BarrierModel>>> = vec![
        vec![tr_mark_ready],
        vec![tr_mark_dead],
        vec![srv_load_dead, srv_load_ready, srv_release],
    ];
    let mut released = 0u64;
    let mut blocked = 0u64;
    let n = explore(&init, &threads, &mut |s| {
        match s.released {
            // A release never overcounts survivors, and never
            // reports the dead trainer live.
            Some(live) => {
                assert_eq!(live, 1, "released with wrong live count");
                released += 1;
            }
            // A blocked poll is fine — but the condition must hold
            // on the terminal state, so the *next* poll releases:
            // a stuck barrier is impossible once every trainer has
            // marked ready or dead.
            None => {
                assert!(
                    s.ready + s.dead >= TOTAL,
                    "barrier can hang: terminal condition false"
                );
                blocked += 1;
            }
        }
    });
    assert_eq!(n, interleavings(&[1, 1, 3]));
    assert_eq!(n, 20);
    assert!(released > 0, "no schedule released inside the poll");
    assert!(blocked > 0, "no schedule exercised the re-poll path");
}
