//! Integration tests across the runtime boundary: manifest → compute
//! backend → samplers → training/eval numerics.
//!
//! Always-on: every test here runs the **native** backend against the
//! builtin manifest, so `cargo test` exercises the full numeric path
//! on a bare checkout — no AOT artifacts. The PJRT differential half
//! (pallas-vs-jnp, native-vs-PJRT) only compiles with
//! `--features pjrt` and still skips gracefully without `artifacts/`.

use random_tma::gen::{dcsbm, DcsbmConfig};
use random_tma::model::ModelState;
use random_tma::runtime::{Manifest, NativeEngine};
use random_tma::sampler::{AdjMode, TrainSampler, TrainSamplerConfig};
use random_tma::util::rng::Rng;

fn graph(seed: u64) -> random_tma::graph::Graph {
    dcsbm(&DcsbmConfig {
        nodes: 800,
        communities: 8,
        avg_degree: 12.0,
        homophily: 0.85,
        feat_dim: 64,
        feature_noise: 0.5,
        degree_exponent: 0.5,
        seed,
    })
}

fn sampler(m: &Manifest, encoder: &str, seed: u64) -> TrainSampler {
    let g = graph(seed);
    let globals: Vec<u32> = (0..g.num_nodes() as u32).collect();
    let cfg = TrainSamplerConfig {
        block_nodes: m.dims.block_nodes,
        block_edges: m.dims.block_edges,
        feat_dim: m.dims.feat_dim,
        fanouts: vec![10, 5],
        adj_mode: AdjMode::for_encoder(encoder),
        relations: 1,
        boundary: 0,
    };
    TrainSampler::new(g, globals, cfg)
}

fn native(m: &Manifest, variant: &str) -> NativeEngine {
    NativeEngine::new(m, variant).expect("native engine")
}

#[test]
fn train_step_runs_and_loss_is_sane() {
    let m = Manifest::builtin();
    let engine = native(&m, "gcn_mlp");
    let mut s = sampler(&m, "gcn", 1);
    let mut rng = Rng::new(2);
    let mut state = ModelState::init(&engine.variant, &mut rng);

    let block = s.next_block(&mut rng).unwrap().clone();
    let loss = engine.train_step(&mut state, &block).expect("train");
    // BCE at init should be near 2 ln 2 ~= 1.386
    assert!(loss > 0.3 && loss < 4.0, "loss={loss}");
    assert_eq!(state.step_count(), 1);
}

#[test]
fn training_reduces_loss_on_fixed_block() {
    let m = Manifest::builtin();
    let engine = native(&m, "gcn_mlp");
    let mut s = sampler(&m, "gcn", 3);
    let mut rng = Rng::new(4);
    let mut state = ModelState::init(&engine.variant, &mut rng);
    let block = s.next_block(&mut rng).unwrap().clone();

    let first = engine.train_step(&mut state, &block).unwrap();
    let mut last = first;
    for _ in 0..60 {
        last = engine.train_step(&mut state, &block).unwrap();
    }
    assert!(
        last < first * 0.8,
        "no learning: first={first} last={last}"
    );
}

#[test]
fn grad_step_matches_train_step_loss() {
    let m = Manifest::builtin();
    let engine = native(&m, "sage_mlp");
    let mut s = sampler(&m, "sage", 7);
    let mut rng = Rng::new(8);
    let mut state = ModelState::init(&engine.variant, &mut rng);
    let block = s.next_block(&mut rng).unwrap().clone();

    let (_, loss_g) = engine.grad_step(&state.params, &block).unwrap();
    let loss_t = engine.train_step(&mut state, &block).unwrap();
    assert!((loss_g - loss_t).abs() < 1e-5, "{loss_g} vs {loss_t}");
}

#[test]
fn train_step_is_deterministic() {
    // Bit-identical replay: same init, same block, same parameters
    // after each step — the native kernels' fixed accumulation order
    // (zero-skip included) is part of the round-metrics contract.
    let m = Manifest::builtin();
    let engine = native(&m, "gcn_mlp");
    let mut s = sampler(&m, "gcn", 13);
    let mut rng = Rng::new(14);
    let init = ModelState::init(&engine.variant, &mut rng);
    let block = s.next_block(&mut rng).unwrap().clone();

    let mut a = init.clone();
    let mut b = init;
    for _ in 0..3 {
        let la = engine.train_step(&mut a, &block).unwrap();
        let lb = engine.train_step(&mut b, &block).unwrap();
        assert_eq!(la.to_bits(), lb.to_bits());
    }
    assert!(a
        .params
        .iter()
        .zip(&b.params)
        .all(|(x, y)| x.to_bits() == y.to_bits()));
}

#[test]
fn encode_and_score_shapes() {
    let m = Manifest::builtin();
    let engine = native(&m, "gcn_mlp");
    let mut rng = Rng::new(9);
    let state = ModelState::init(&engine.variant, &mut rng);

    let g = graph(10);
    let edges: Vec<(u32, u32)> = (0..8)
        .map(|i| {
            let u = (i * 37) % g.num_nodes();
            (u as u32, g.neighbors_of(u)[0])
        })
        .collect();
    let negs: Vec<Vec<u32>> = edges
        .iter()
        .map(|_| (0..4).map(|_| rng.below(g.num_nodes()) as u32).collect())
        .collect();
    let cfg = random_tma::sampler::eval::EvalBlockConfig::new(
        m.dims.block_nodes,
        m.dims.feat_dim,
        AdjMode::SelfLoop,
        1,
        0,
    );
    let plan = random_tma::sampler::EvalPlan::build(&g, &edges, &negs, &cfg);

    let emb = engine.encode(&state.params, &plan.blocks[0]).unwrap();
    assert_eq!(emb.len(), m.dims.block_nodes * m.dims.hidden);
    assert!(emb.iter().any(|&x| x != 0.0));

    let s_len = m.dims.score_batch;
    let eu = vec![0.1f32; s_len * m.dims.hidden];
    let ev = vec![0.2f32; s_len * m.dims.hidden];
    let rel = vec![0i32; s_len];
    let scores = engine.score(&state.params, &eu, &ev, &rel).unwrap();
    assert_eq!(scores.len(), s_len);
    // identical pairs -> identical scores
    assert!(scores.windows(2).all(|w| (w[0] - w[1]).abs() < 1e-6));
}

#[test]
fn hetero_engine_runs() {
    let m = Manifest::builtin();
    let engine = native(&m, "rgcn_distmult");
    let bg = random_tma::gen::bipartite(&random_tma::gen::BipartiteConfig {
        num_queries: 300,
        num_items: 500,
        communities: 5,
        qi_degree: 6.0,
        ii_degree: 4.0,
        homophily: 0.8,
        feat_dim: 64,
        feature_noise: 0.4,
        seed: 11,
    });
    let globals: Vec<u32> = (0..bg.graph.num_nodes() as u32).collect();
    let cfg = TrainSamplerConfig {
        block_nodes: m.dims.block_nodes,
        block_edges: m.dims.block_edges,
        feat_dim: m.dims.feat_dim,
        fanouts: vec![8, 4],
        adj_mode: AdjMode::Relational,
        relations: m.dims.relations,
        boundary: bg.boundary,
    };
    let mut s = TrainSampler::new(bg.graph, globals, cfg);
    let mut rng = Rng::new(12);
    let mut state = ModelState::init(&engine.variant, &mut rng);
    let block = s.next_block(&mut rng).unwrap().clone();
    let l1 = engine.train_step(&mut state, &block).unwrap();
    let l2 = engine.train_step(&mut state, &block).unwrap();
    assert!(l1.is_finite() && l2.is_finite());
    assert!(l2 <= l1 * 1.2, "diverging: {l1} -> {l2}");
}

/// The artifact-gated differential half: compiled only with
/// `--features pjrt`, and each test still skips without `artifacts/`.
/// Tolerance policy (docs/ENGINE.md): loss within 1e-4, per-element
/// gradient within 1e-3 — f32 accumulation-order noise, not model
/// drift.
#[cfg(feature = "pjrt")]
mod pjrt_differential {
    use super::*;
    use random_tma::runtime::Engine;

    fn manifest() -> Option<Manifest> {
        let dir = std::path::PathBuf::from("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping pjrt differential: run `make artifacts`");
            return None;
        }
        Some(Manifest::load(&dir).expect("manifest"))
    }

    fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
        a.iter()
            .zip(b)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max)
    }

    #[test]
    fn pallas_and_jnp_artifacts_agree() {
        // Same inputs, same numerics through the Pallas kernels and
        // the XLA-dot reference.
        let Some(m) = manifest() else { return };
        let pallas = Engine::load(&m, "gcn_mlp", "pallas").unwrap();
        let jnp = Engine::load(&m, "gcn_mlp", "jnp").unwrap();
        let mut s = sampler(&m, "gcn", 5);
        let mut rng = Rng::new(6);
        let state = ModelState::init(&pallas.variant, &mut rng);
        let block = s.next_block(&mut rng).unwrap().clone();

        let (gp, lp) = pallas.grad_step(&state.params, &block).unwrap();
        let (gj, lj) = jnp.grad_step(&state.params, &block).unwrap();
        assert!((lp - lj).abs() < 1e-4, "loss mismatch {lp} vs {lj}");
        let max_diff = max_abs_diff(&gp, &gj);
        assert!(max_diff < 1e-3, "grad mismatch {max_diff}");
    }

    #[test]
    fn native_and_pjrt_agree() {
        // The backend refactor's contract: the pure-Rust kernels and
        // the compiled artifacts are the same model.
        let Some(m) = manifest() else { return };
        let pjrt = Engine::load(&m, "gcn_mlp", "pallas").unwrap();
        let nat = native(&m, "gcn_mlp");
        let mut s = sampler(&m, "gcn", 15);
        let mut rng = Rng::new(16);
        let state = ModelState::init(&pjrt.variant, &mut rng);
        let block = s.next_block(&mut rng).unwrap().clone();

        let (gp, lp) = pjrt.grad_step(&state.params, &block).unwrap();
        let (gn, ln) = nat.grad_step(&state.params, &block).unwrap();
        assert!((lp - ln).abs() < 1e-4, "loss mismatch {lp} vs {ln}");
        let max_diff = max_abs_diff(&gp, &gn);
        assert!(max_diff < 1e-3, "grad mismatch {max_diff}");
    }
}
