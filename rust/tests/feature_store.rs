//! Differential suite for the three `FeatureStore` backends.
//!
//! Locks in the zero-copy FeatureStore contract end to end: every
//! backend (Owned baseline, Shared slab, Mapped file) must read
//! bit-identically through `feature(v)`, produce bit-identical sampler
//! blocks, and — with artifacts present — bit-identical per-round
//! training metrics; and `induce_all` must share one slab across all
//! `k` trainer subgraphs instead of allocating per-trainer copies.

use random_tma::gen::{dcsbm, DcsbmConfig};
use random_tma::graph::{induce_all, induce_all_except, io, Graph};
use random_tma::partition::random_partition;
use random_tma::sampler::eval::EvalBlockConfig;
use random_tma::sampler::{AdjMode, EvalPlan, TrainSampler, TrainSamplerConfig};
use random_tma::util::rng::Rng;

fn seeded_graph(feat_dim: usize) -> Graph {
    dcsbm(&DcsbmConfig {
        nodes: 2_000,
        communities: 8,
        avg_degree: 10.0,
        homophily: 0.8,
        feat_dim,
        feature_noise: 0.5,
        degree_exponent: 0.7,
        seed: 77,
    })
}

/// The same graph rehosted on each backend (`owned` reference first,
/// then `shared` and — unix only — `mapped`): the one shared recipe
/// from `graph::features`, also used by the in-crate induce suite.
use random_tma::graph::features::rehost_backends as backends;

fn assert_feats_bitwise(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{what}: f32 {i} differs ({x} vs {y})"
        );
    }
}

/// The acceptance regression: prep allocates no per-trainer feature
/// slab. Every subgraph of `induce_all` over a Shared parent is a
/// `Shared` view whose slab pointer equals the parent's — one
/// allocation for all `k` trainers — and its private heap is just the
/// u32 row index.
#[test]
fn induce_all_shares_one_slab_zero_copy() {
    let g = seeded_graph(16);
    assert_eq!(g.features.backend(), "shared", "generators emit Shared");
    let parent_ptr = g.features.slab_ptr().expect("parent has a slab");

    let k = 6;
    let mut rng = Rng::new(5);
    let assign = random_partition(g.num_nodes(), k, &mut rng);
    let subs = induce_all(&g, &assign, k);
    assert_eq!(subs.len(), k);
    for (p, sub) in subs.iter().enumerate() {
        assert!(
            sub.graph.features.is_shared(),
            "part {p}: expected Shared, got {}",
            sub.graph.features.backend()
        );
        assert_eq!(
            sub.graph.features.slab_ptr(),
            Some(parent_ptr),
            "part {p}: view must point at the parent slab"
        );
        // Private feature bytes = 4 per node (the index), not 4*d.
        assert_eq!(
            sub.graph.features.heap_bytes(),
            sub.num_nodes() * 4,
            "part {p}: per-trainer slab was allocated"
        );
        // And the view reads exactly the parent's rows.
        for (l, &gid) in sub.global_ids.iter().enumerate() {
            assert_feats_bitwise(
                sub.graph.feature(l),
                g.feature(gid as usize),
                &format!("part {p} node {l}"),
            );
        }
    }
    // Same contract on the drill path for survivors; lost partitions
    // are never materialised.
    let drilled = induce_all_except(&g, &assign, k, &[2]);
    for (p, sub) in drilled.iter().enumerate() {
        if p == 2 {
            assert!(sub.graph.features.is_empty());
            assert_eq!(sub.graph.features.heap_bytes(), 0);
        } else {
            assert_eq!(sub.graph.features.slab_ptr(), Some(parent_ptr));
        }
    }
}

#[cfg(unix)]
#[test]
fn mapped_parent_yields_mapped_views_over_one_map() {
    let g = seeded_graph(16);
    let path = std::env::temp_dir().join(format!(
        "rtma_fstore_mapviews_{}.bin",
        std::process::id()
    ));
    io::save(&g, &path).unwrap();
    let m = io::load_mapped(&path).unwrap();
    std::fs::remove_file(&path).ok();
    let map_ptr = m.features.slab_ptr().expect("mapped slab");

    let k = 4;
    let mut rng = Rng::new(9);
    let assign = random_partition(m.num_nodes(), k, &mut rng);
    let subs = induce_all(&m, &assign, k);
    for (p, sub) in subs.iter().enumerate() {
        assert_eq!(sub.graph.features.backend(), "mapped", "part {p}");
        assert_eq!(sub.graph.features.slab_ptr(), Some(map_ptr));
        for (l, &gid) in sub.global_ids.iter().enumerate() {
            assert_feats_bitwise(
                sub.graph.feature(l),
                g.feature(gid as usize),
                &format!("mapped part {p} node {l}"),
            );
        }
    }
}

/// Training blocks sampled from each backend's subgraphs must be
/// bit-identical to the Owned baseline: same features, adjacency,
/// edge indices and masks for the same RNG stream.
#[test]
fn train_blocks_bit_identical_across_backends() {
    let g = seeded_graph(8);
    let k = 3;
    let mut rng = Rng::new(13);
    let assign = random_partition(g.num_nodes(), k, &mut rng);
    let cfg = TrainSamplerConfig {
        block_nodes: 64,
        block_edges: 16,
        feat_dim: 8,
        fanouts: vec![4, 3],
        adj_mode: AdjMode::SelfLoop,
        relations: 1,
        boundary: 0,
    };

    // Baseline blocks from the Owned backend.
    let hosts = backends(&g, "train_blocks");
    let baseline: Vec<Vec<random_tma::sampler::Block>> = {
        let (_, owned) = &hosts[0];
        sample_blocks(owned, &assign, k, &cfg)
    };
    for (backend, host) in &hosts[1..] {
        let blocks = sample_blocks(host, &assign, k, &cfg);
        for (p, (base_p, got_p)) in
            baseline.iter().zip(&blocks).enumerate()
        {
            for (i, (base, got)) in base_p.iter().zip(got_p).enumerate() {
                let what = format!("{backend} part {p} block {i}");
                assert_eq!(base.n_used, got.n_used, "{what}: n_used");
                assert_eq!(base.globals, got.globals, "{what}: globals");
                assert_feats_bitwise(
                    &base.feats,
                    &got.feats,
                    &format!("{what} feats"),
                );
                assert_feats_bitwise(
                    &base.adj,
                    &got.adj,
                    &format!("{what} adj"),
                );
                assert_eq!(base.pos_u, got.pos_u, "{what}: pos_u");
                assert_eq!(base.pos_v, got.pos_v, "{what}: pos_v");
                assert_eq!(base.neg_v, got.neg_v, "{what}: neg_v");
                assert_eq!(base.mask, got.mask, "{what}: mask");
            }
        }
    }
}

fn sample_blocks(
    host: &Graph,
    assign: &[u32],
    k: usize,
    cfg: &TrainSamplerConfig,
) -> Vec<Vec<random_tma::sampler::Block>> {
    induce_all(host, assign, k)
        .into_iter()
        .enumerate()
        .map(|(p, sub)| {
            let mut sampler = TrainSampler::new(
                sub.graph,
                sub.global_ids,
                cfg.clone(),
            );
            let mut rng = Rng::new(100 + p as u64);
            (0..8)
                .filter_map(|_| sampler.next_block(&mut rng).cloned())
                .collect()
        })
        .collect()
}

/// Deterministic eval plans gather identical block features from every
/// backend.
#[test]
fn eval_blocks_bit_identical_across_backends() {
    let g = seeded_graph(8);
    let mut rng = Rng::new(31);
    let edges: Vec<(u32, u32)> = (0..24)
        .map(|_| {
            let u = rng.below(g.num_nodes()) as u32;
            let nbrs = g.neighbors_of(u as usize);
            if nbrs.is_empty() {
                (u, (u + 1) % g.num_nodes() as u32)
            } else {
                (u, nbrs[0])
            }
        })
        .collect();
    let negs: Vec<Vec<u32>> = edges
        .iter()
        .map(|_| (0..6).map(|_| rng.below(g.num_nodes()) as u32).collect())
        .collect();
    let cfg = EvalBlockConfig::new(64, 8, AdjMode::SelfLoop, 1, 0);

    let hosts = backends(&g, "eval_blocks");
    let base = EvalPlan::build(&hosts[0].1, &edges, &negs, &cfg);
    for (backend, host) in &hosts[1..] {
        let plan = EvalPlan::build(host, &edges, &negs, &cfg);
        assert_eq!(base.blocks.len(), plan.blocks.len(), "{backend}");
        for (i, (a, b)) in base.blocks.iter().zip(&plan.blocks).enumerate()
        {
            assert_eq!(a.globals, b.globals, "{backend} block {i}");
            assert_feats_bitwise(
                &a.feats,
                &b.feats,
                &format!("{backend} eval block {i} feats"),
            );
            assert_feats_bitwise(
                &a.adj,
                &b.adj,
                &format!("{backend} eval block {i} adj"),
            );
        }
    }
}

/// End-to-end round metrics: a deterministic miniature of the TMA loop
/// (fixed steps per round, mean aggregation — no wall clocks) must
/// produce bit-identical losses and aggregated parameters on every
/// FeatureStore backend. Always-on: the native engine runs on the
/// builtin manifest, and its kernels' fixed accumulation order makes
/// the bitwise comparison exact on any machine.
#[test]
fn round_metrics_bit_identical_across_backends() {
    use random_tma::model::ModelState;
    use random_tma::runtime::{Manifest, NativeEngine};

    let manifest = Manifest::builtin();
    let engine =
        NativeEngine::new(&manifest, "gcn_mlp").expect("native engine");
    let dims = manifest.dims;
    let g = seeded_graph(dims.feat_dim);
    let k = 2;
    let mut rng = Rng::new(41);
    let assign = random_partition(g.num_nodes(), k, &mut rng);
    let cfg = TrainSamplerConfig {
        block_nodes: dims.block_nodes,
        block_edges: dims.block_edges,
        feat_dim: dims.feat_dim,
        fanouts: vec![4, 3],
        adj_mode: AdjMode::SelfLoop,
        relations: 1,
        boundary: 0,
    };

    let run = |host: &Graph| -> (Vec<f32>, Vec<f32>) {
        let subs = induce_all(host, &assign, k);
        let variant = engine.variant.clone();
        let mut states: Vec<ModelState> = (0..k)
            .map(|_| ModelState::init(&variant, &mut Rng::new(4242)))
            .collect();
        let mut samplers: Vec<TrainSampler> = subs
            .into_iter()
            .map(|s| TrainSampler::new(s.graph, s.global_ids, cfg.clone()))
            .collect();
        let mut rngs: Vec<Rng> =
            (0..k).map(|p| Rng::new(900 + p as u64)).collect();
        let mut losses = Vec::new();
        for _round in 0..2 {
            for (p, sampler) in samplers.iter_mut().enumerate() {
                for _ in 0..3 {
                    let block =
                        sampler.next_block(&mut rngs[p]).expect("block");
                    let loss = engine
                        .train_step(&mut states[p], block)
                        .expect("train step");
                    losses.push(loss);
                }
            }
            // Mean aggregation (the TMA server's reduce).
            let dim = states[0].params.len();
            let mut mean = vec![0f32; dim];
            for s in &states {
                for (m, &x) in mean.iter_mut().zip(&s.params) {
                    *m += x / k as f32;
                }
            }
            for s in &mut states {
                s.set_params(&mean);
            }
        }
        (losses, states[0].params.clone())
    };

    let hosts = backends(&g, "rounds");
    let (base_losses, base_params) = run(&hosts[0].1);
    assert!(!base_losses.is_empty());
    for (backend, host) in &hosts[1..] {
        let (losses, params) = run(host);
        assert_feats_bitwise(
            &base_losses,
            &losses,
            &format!("{backend} round losses"),
        );
        assert_feats_bitwise(
            &base_params,
            &params,
            &format!("{backend} aggregated params"),
        );
    }
}
