//! End-to-end coordinator tests: full distributed runs on quick data.
//! Always-on: the native backend needs no artifacts, so these run on
//! a bare checkout (forced via `backend: "native"` so an
//! `RTMA_BACKEND=pjrt` environment can't break `cargo test`).
//! Time-boxed short.

use random_tma::config::{Approach, RunConfig};
use random_tma::coordinator::run_experiment;

fn quick_cfg(approach: Approach) -> RunConfig {
    RunConfig {
        dataset: "citation-sim".into(),
        quick: true,
        approach,
        trainers: 2,
        train_secs: 5.0,
        agg_secs: 1.0,
        eval_edges: 32,
        negatives: 16,
        eval_sample: 16,
        seed: 23,
        backend: "native".into(),
        ..RunConfig::default()
    }
}

#[test]
fn tma_run_produces_learning_and_metrics() {
    let r = run_experiment(&quick_cfg(Approach::RandomTma)).expect("run");
    assert_eq!(r.steps.len(), 2);
    assert!(r.steps.iter().all(|&s| s > 10), "steps {:?}", r.steps);
    assert!(r.best_val_mrr > 0.1, "no learning: {}", r.best_val_mrr);
    assert!(r.test_mrr > 0.1, "test mrr {}", r.test_mrr);
    assert!(!r.val_curve.is_empty());
    assert!((r.ratio_r - 0.5).abs() < 0.1, "r={}", r.ratio_r); // M=2
    assert!(r.convergence_secs(0.01).is_finite());
    // timelines are time-ordered
    for tl in &r.trainer_losses {
        assert!(tl.windows(2).all(|w| w[0].t <= w[1].t));
    }
}

#[test]
fn ggs_run_is_synchronous() {
    let r = run_experiment(&quick_cfg(Approach::Ggs)).expect("run");
    // lock-step: all trainers do the same number of steps (±1 on stop)
    let (min, max, _) = r.step_spread();
    assert!(max - min <= 1, "ggs not synchronous: {:?}", r.steps);
    assert!((r.ratio_r - 1.0).abs() < 1e-9);
}

#[test]
fn failure_run_drops_partition_but_completes() {
    let mut cfg = quick_cfg(Approach::RandomTma);
    cfg.trainers = 3;
    cfg.failures = 1;
    cfg.failed_ids = vec![1];
    let r = run_experiment(&cfg).expect("run");
    assert_eq!(r.steps.len(), 2, "one trainer should be gone");
    assert!(r.test_mrr > 0.05);
}

#[test]
fn supertma_and_psgd_have_higher_r_than_random() {
    let rnd = run_experiment(&quick_cfg(Approach::RandomTma)).unwrap();
    let sup = run_experiment(&quick_cfg(Approach::SuperTma {
        num_clusters: 256,
    }))
    .unwrap();
    let cut = run_experiment(&quick_cfg(Approach::PsgdPa)).unwrap();
    assert!(sup.ratio_r > rnd.ratio_r, "{} vs {}", sup.ratio_r, rnd.ratio_r);
    assert!(cut.ratio_r > sup.ratio_r, "{} vs {}", cut.ratio_r, sup.ratio_r);
}
