//! Integration suite for the online inference server (`serve`,
//! docs/SERVING.md): wire-format goldens over a real socket, the
//! batch-vs-single bit-identity guarantee, live weight swaps, frame-cap
//! hostility and the LRU embedding cache. Everything runs on the
//! native backend over a tiny `Manifest::builtin_sized` layout — no
//! artifacts, no network beyond loopback.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use random_tma::comm::{self, tags};
use random_tma::coordinator::kv::GlobalWeights;
use random_tma::graph::{Graph, GraphBuilder};
use random_tma::model::ModelState;
use random_tma::runtime::{Manifest, ModelDims, NativeEngine};
use random_tma::serve::{
    load_weights, save_weights, serve, EmbCache, ServeClient, ServeConfig,
    ServeHandle,
};
use random_tma::util::rng::Rng;

fn tiny_manifest() -> Manifest {
    Manifest::builtin_sized(
        ModelDims {
            feat_dim: 3,
            hidden: 4,
            block_nodes: 6,
            block_edges: 5,
            score_batch: 8,
            relations: 2,
        },
        2,
        2,
        2,
    )
}

/// Ring graph with deterministic features — enough structure that
/// different nodes get different embeddings.
fn tiny_graph(n: usize, f: usize) -> Graph {
    let mut b = GraphBuilder::new(n);
    for i in 0..n as u32 {
        b.add_edge(i, (i + 1) % n as u32);
    }
    let mut g = b.build();
    g.feat_dim = f;
    g.features = (0..n * f)
        .map(|i| ((i as f32) * 0.37).sin())
        .collect::<Vec<f32>>()
        .into();
    g
}

/// Deterministic parameter vector for the tiny gcn_mlp variant.
fn params_for(manifest: &Manifest, seed: u64) -> GlobalWeights {
    let engine = NativeEngine::new(manifest, "gcn_mlp").unwrap();
    let mut rng = Rng::new(seed);
    let state = ModelState::init(&engine.variant, &mut rng);
    Arc::from(state.params)
}

fn start_server(weights: GlobalWeights) -> ServeHandle {
    let cfg = ServeConfig {
        addr: "127.0.0.1:0".into(),
        window: Duration::from_micros(500),
        max_batch: 64,
        cache_cap: 64,
        topk_scan: 16,
    };
    serve(
        &cfg,
        Arc::new(tiny_graph(12, 3)),
        0,
        tiny_manifest(),
        "gcn_mlp".into(),
        "pallas".into(),
        weights,
    )
    .expect("server failed to start")
}

/// Batched scoring must be *bit-identical* to single-request scoring:
/// the batcher amortises the matmul, not the math. One 5-pair request
/// vs five 1-pair requests (which also crosses the warm-cache path —
/// canonical per-node embeddings make that a no-op by construction).
#[test]
fn batched_scores_bit_identical_to_single() {
    let m = tiny_manifest();
    let handle = start_server(params_for(&m, 7));
    let addr = handle.addr().to_string();
    let mut c = ServeClient::connect(&addr, 1).unwrap();

    let pairs: Vec<(u32, u32, i32)> =
        vec![(0, 1, -1), (1, 2, 0), (3, 7, 1), (5, 5, -1), (11, 0, 0)];
    let batched = c.score(&pairs).unwrap();
    assert_eq!(batched.len(), pairs.len());
    for (i, s) in batched.iter().enumerate() {
        assert!(s.is_finite(), "pair {i} scored {s}");
    }
    for (i, &p) in pairs.iter().enumerate() {
        let single = c.score(&[p]).unwrap();
        assert_eq!(
            single[0].to_bits(),
            batched[i].to_bits(),
            "pair {i}: single {} != batched {}",
            single[0],
            batched[i]
        );
    }
    // Concurrent clients folded into shared batches agree too.
    let mut c2 = ServeClient::connect(&addr, 2).unwrap();
    let again = c2.score(&pairs).unwrap();
    for (a, b) in again.iter().zip(&batched) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
    c.stop().unwrap();
    handle.join();
}

/// Degraded inputs must degrade per-row, not poison the batch: an
/// out-of-graph node or an out-of-range relation scores NaN while
/// every valid row in the same request keeps its exact value.
#[test]
fn invalid_rows_nan_without_poisoning_the_batch() {
    let m = tiny_manifest();
    let handle = start_server(params_for(&m, 7));
    let addr = handle.addr().to_string();
    let mut c = ServeClient::connect(&addr, 1).unwrap();

    let clean = c.score(&[(0, 1, 0)]).unwrap()[0];
    let mixed = c
        .score(&[(0, 1, 0), (0, 999_999, 0), (2, 3, 57), (0, 1, 0)])
        .unwrap();
    assert_eq!(mixed[0].to_bits(), clean.to_bits());
    assert!(mixed[1].is_nan(), "unknown node must score NaN");
    assert!(mixed[2].is_nan(), "relation 57 of 2 must score NaN");
    assert_eq!(mixed[3].to_bits(), clean.to_bits());

    // Top-k: bounded by both k and the node's true degree (ring: 2),
    // sorted descending, all finite.
    let items = c.topk(4, 10).unwrap();
    assert!(!items.is_empty() && items.len() <= 2, "{items:?}");
    for w in items.windows(2) {
        assert!(w[0].1 >= w[1].1, "unsorted: {items:?}");
    }
    for &(nb, s) in &items {
        assert!(s.is_finite(), "neighbour {nb} scored {s}");
        assert!(nb == 3 || nb == 5, "{nb} is not a ring neighbour of 4");
    }
    c.stop().unwrap();
    handle.join();
}

/// Live weight swap: replies before the push use the old weights,
/// replies after use the new — and the post-swap scores are
/// bit-identical to a server *started* with the new weights (the swap
/// also invalidated the embedding cache; stale embeddings would break
/// this equality). No request is dropped across the boundary.
#[test]
fn weight_swap_is_atomic_per_batch_and_flushes_cache() {
    let m = tiny_manifest();
    let w_old = params_for(&m, 7);
    let w_new = params_for(&m, 8);
    let handle = start_server(w_old);
    let addr = handle.addr().to_string();
    let mut c = ServeClient::connect(&addr, 1).unwrap();

    let pairs: Vec<(u32, u32, i32)> = vec![(0, 1, -1), (2, 9, 0), (4, 4, 1)];
    let before = c.score(&pairs).unwrap();

    handle.push_weights(w_new.clone());
    let after = c.score(&pairs).unwrap();
    assert!(
        before
            .iter()
            .zip(&after)
            .any(|(a, b)| a.to_bits() != b.to_bits()),
        "swap had no effect: {before:?}"
    );

    let fresh_handle = start_server(w_new);
    let fresh_addr = fresh_handle.addr().to_string();
    let mut fc = ServeClient::connect(&fresh_addr, 9).unwrap();
    let fresh = fc.score(&pairs).unwrap();
    for (i, (a, b)) in after.iter().zip(&fresh).enumerate() {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "pair {i}: swapped server {a} != fresh server {b}"
        );
    }
    fc.stop().unwrap();
    fresh_handle.join();
    c.stop().unwrap();
    handle.join();
}

/// Wire-format golden, independent of `WireMsg`: hand-assembled
/// QueryScore bytes in, hand-parsed ReplyScore bytes out. Locks the
/// layout clients in other languages would implement against
/// (docs/SERVING.md): LE length prefix, tag 10/12, u64 id, u64 count,
/// 12-byte (u32,u32,i32) pairs / 4-byte f32 scores.
#[test]
fn raw_wire_golden_roundtrip() {
    let m = tiny_manifest();
    let handle = start_server(params_for(&m, 7));
    let mut s = TcpStream::connect(handle.addr()).unwrap();
    comm::serve_client_handshake(&mut s, 77).unwrap();

    // QueryScore { id: 0xABCD, pairs: [(0,1,0), (2,3,1)] }. The
    // registry pins tag 10 — hand-roll the frame from it so this
    // golden and `comm::tags::all()` cannot drift apart.
    assert_eq!(tags::TAG_QUERY_SCORE, 10);
    assert!(tags::all().contains(&(10, "QueryScore")));
    let mut frame = vec![tags::TAG_QUERY_SCORE];
    frame.extend_from_slice(&0xABCDu64.to_le_bytes());
    frame.extend_from_slice(&2u64.to_le_bytes());
    for (u, v, r) in [(0u32, 1u32, 0u32), (2, 3, 1)] {
        frame.extend_from_slice(&u.to_le_bytes());
        frame.extend_from_slice(&v.to_le_bytes());
        frame.extend_from_slice(&r.to_le_bytes());
    }
    assert_eq!(frame.len(), 1 + 8 + 8 + 2 * 12); // golden query length
    s.write_all(&(frame.len() as u32).to_le_bytes()).unwrap();
    s.write_all(&frame).unwrap();

    // ReplyScore: 4-byte prefix, then tag 12 + id + count + 2 f32.
    let mut prefix = [0u8; 4];
    s.read_exact(&mut prefix).unwrap();
    let len = u32::from_le_bytes(prefix) as usize;
    assert_eq!(len, 1 + 8 + 8 + 2 * 4); // golden reply length
    let mut body = vec![0u8; len];
    s.read_exact(&mut body).unwrap();
    assert_eq!(tags::TAG_REPLY_SCORE, 12);
    assert!(tags::all().contains(&(12, "ReplyScore")));
    assert_eq!(body[0], tags::TAG_REPLY_SCORE);
    assert_eq!(u64::from_le_bytes(body[1..9].try_into().unwrap()), 0xABCD);
    assert_eq!(u64::from_le_bytes(body[9..17].try_into().unwrap()), 2);
    for i in 0..2 {
        let off = 17 + 4 * i;
        let score = f32::from_le_bytes(
            body[off..off + 4].try_into().unwrap(),
        );
        assert!(score.is_finite(), "score {i} = {score}");
    }

    // Stop via the raw socket too: tag 5 (TAG_STOP), empty payload.
    s.write_all(&1u32.to_le_bytes()).unwrap();
    s.write_all(&[tags::TAG_STOP]).unwrap();
    handle.join();
}

/// Frame-cap hostility (the PR-8 cap idiom, now on the serving plane):
/// a length prefix beyond MAX_FRAME drops that connection before any
/// body byte is read — and the server keeps serving everyone else.
#[test]
fn oversized_frame_drops_connection_not_server() {
    let m = tiny_manifest();
    let handle = start_server(params_for(&m, 7));
    let addr = handle.addr().to_string();

    let mut evil = TcpStream::connect(&addr).unwrap();
    comm::serve_client_handshake(&mut evil, 66).unwrap();
    let huge = (comm::MAX_FRAME as u32) + 1;
    evil.write_all(&huge.to_le_bytes()).unwrap();
    evil.write_all(&[10u8; 64]).unwrap(); // a little "body" that must never be read as a frame
    // The reader bails on the cap check and closes; we observe EOF.
    evil.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut scratch = [0u8; 16];
    match evil.read(&mut scratch) {
        Ok(0) => {}                   // clean close
        Ok(n) => panic!("server answered an oversized frame with {n} bytes"),
        Err(_) => {}                  // reset — also fine
    }

    // A well-behaved client connected after the attack still works.
    let mut c = ServeClient::connect(&addr, 1).unwrap();
    let scores = c.score(&[(0, 1, 0)]).unwrap();
    assert!(scores[0].is_finite());
    c.stop().unwrap();
    handle.join();
}

/// The LRU embedding cache, hammered through its public API: fill,
/// hit-bump, evict, refresh, generation invalidation. (The in-module
/// unit tests cover the basics; this is the churn test.)
#[test]
fn emb_cache_churn_keeps_lru_invariants() {
    let h = 4;
    let cap = 8;
    let mut cache = EmbCache::new(cap, h);
    let row = |node: u32| vec![node as f32; 4];
    // Two full passes over 3*cap nodes: size never exceeds cap and
    // the survivors are exactly the cap most-recently-inserted keys.
    for pass in 0..2u32 {
        for node in 0..(3 * cap as u32) {
            cache.insert(node.wrapping_add(pass), &row(node));
            assert!(cache.len() <= cap);
        }
    }
    // Keep node A hot while inserting cap-1 fresh nodes: A survives.
    let a = 1000u32;
    cache.insert(a, &row(a));
    for i in 0..(cap as u32 - 1) {
        cache.insert(2000 + i, &row(i));
        assert!(cache.get(a).is_some(), "hot entry evicted at {i}");
    }
    // One more insert without touching A first evicts the oldest
    // *cold* entry, not A (A was bumped by the last get).
    cache.insert(3000, &row(3));
    assert!(cache.contains(a));
    // Generation swap wipes everything.
    cache.invalidate(42);
    assert_eq!(cache.len(), 0);
    assert_eq!(cache.generation(), 42);
    assert!(!cache.contains(a));
}

/// Weights persistence round-trip through a real file plus the
/// `rtma train --save-model` → `rtma serve --model` contract.
#[test]
fn weights_file_roundtrip_exact() {
    let dir = std::env::temp_dir().join(format!(
        "rtma-serve-test-{}",
        std::process::id()
    ));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("model.bin");
    let m = tiny_manifest();
    let w = params_for(&m, 3);
    save_weights(&path, &w).unwrap();
    let back = load_weights(&path).unwrap();
    assert_eq!(back.len(), w.len());
    assert!(back
        .iter()
        .zip(w.iter())
        .all(|(a, b)| a.to_bits() == b.to_bits()));
    std::fs::remove_dir_all(&dir).ok();
}
