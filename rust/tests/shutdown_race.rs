//! Shutdown-protocol regression tests (no artifacts required).
//!
//! The bug: `tma_trainer` used to check `Control::stopped()` *before*
//! checking for an open aggregation round, while `tma_server` raised
//! stop *before* opening its final collection round. A trainer that
//! observed the stop flag first exited without shipping its
//! last-interval weights, so the final collection blocked for its full
//! 60 s timeout per lost trainer and then silently aggregated a
//! subset. The fix is a protocol pair: the server opens the final
//! round before raising stop, and trainers decide their next move via
//! [`Control::next_action`] (round-check before stop-check, with a
//! round re-read after observing stop). These tests drive exactly
//! those primitives — plus the server's round-validated
//! [`collect_round`] — with mock trainer threads standing in for the
//! engine-bound loop.

use std::sync::mpsc;
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use random_tma::coordinator::kv::{Control, TrainerAction, TrainerMsg};
use random_tma::coordinator::server::collect_round;

/// A mock trainer running the exact control-flow skeleton of
/// `tma_trainer`: next_action → ship + await broadcast | stop | one
/// "local step". Returns the rounds it shipped.
fn mock_trainer(
    id: usize,
    control: Arc<Control>,
    tx: mpsc::Sender<TrainerMsg>,
    rx_global: mpsc::Receiver<Vec<f32>>,
) -> thread::JoinHandle<Vec<u64>> {
    thread::spawn(move || {
        let mut last_round = 0u64;
        let mut shipped = Vec::new();
        loop {
            match control.next_action(last_round) {
                TrainerAction::Ship { round } => {
                    let msg = TrainerMsg {
                        id,
                        round,
                        weights: vec![id as f32],
                        loss: 0.5,
                        steps: shipped.len() as u64,
                    };
                    if tx.send(msg).is_err() {
                        break;
                    }
                    match rx_global.recv() {
                        Ok(_) => {}
                        Err(_) => break,
                    }
                    shipped.push(round);
                    last_round = round;
                }
                TrainerAction::Stop => break,
                TrainerAction::Train => {
                    // One "local step": long enough that trainers are
                    // usually mid-step when rounds open, as real ones
                    // are.
                    thread::sleep(Duration::from_millis(1));
                }
            }
        }
        shipped
    })
}

#[test]
fn budget_expiry_mid_round_collects_all_live_trainers_fast() {
    let m = 4usize;
    let control = Arc::new(Control::new());
    let (msg_tx, msg_rx) = mpsc::channel::<TrainerMsg>();
    let mut global_txs = Vec::new();
    let mut handles = Vec::new();
    for id in 0..m {
        let (gtx, grx) = mpsc::channel::<Vec<f32>>();
        global_txs.push(gtx);
        handles.push(mock_trainer(id, control.clone(), msg_tx.clone(), grx));
    }
    drop(msg_tx);

    // Two regular rounds, fully collected and broadcast.
    for expect in 1..=2u64 {
        let round = control.open_round();
        assert_eq!(round, expect);
        let (weights, losses) =
            collect_round(&msg_rx, m, round, Duration::from_secs(10));
        assert_eq!(weights.len(), m, "round {round} incomplete");
        assert_eq!(losses.len(), m);
        for tx in &global_txs {
            tx.send(vec![0.0]).ok();
        }
    }

    // Budget expires "mid-round": final round opens, then stop — the
    // server-side ordering of tma_server. All live trainers must ship
    // within one local step; well under a second, not 60 s.
    let t0 = Instant::now();
    let final_round = control.open_round();
    control.request_stop();
    let (weights, _) =
        collect_round(&msg_rx, m, final_round, Duration::from_secs(30));
    let elapsed = t0.elapsed();
    assert_eq!(
        weights.len(),
        m,
        "final aggregation lost trainers: got {} of {m}",
        weights.len()
    );
    assert!(
        elapsed < Duration::from_secs(1),
        "final collection took {elapsed:?} — the 60 s timeout path"
    );

    // Unblock the final-round broadcast waiters and join.
    for tx in &global_txs {
        tx.send(vec![0.0]).ok();
    }
    for h in handles {
        let shipped = h.join().expect("mock trainer panicked");
        assert_eq!(
            shipped,
            vec![1, 2, final_round],
            "every trainer serves every round, the final one included"
        );
    }
}

#[test]
fn stop_without_open_round_exits_promptly() {
    // When no round is pending at stop time there is nothing to flush:
    // trainers must exit without shipping anything extra.
    let control = Arc::new(Control::new());
    let (msg_tx, msg_rx) = mpsc::channel::<TrainerMsg>();
    let (_gtx, grx) = mpsc::channel::<Vec<f32>>();
    let h = mock_trainer(0, control.clone(), msg_tx, grx);
    thread::sleep(Duration::from_millis(10));
    control.request_stop();
    let shipped = h.join().expect("trainer panicked");
    assert!(shipped.is_empty());
    assert!(msg_rx.try_recv().is_err(), "spurious message after stop");
}

#[test]
fn collection_drops_stale_round_messages() {
    // A message stamped with an old round (a dying trainer's last
    // gasp) must not be counted into the current round's aggregate.
    let (tx, rx) = mpsc::channel::<TrainerMsg>();
    let stale = TrainerMsg {
        id: 7,
        round: 1,
        weights: vec![7.0],
        loss: 9.9,
        steps: 0,
    };
    let fresh = TrainerMsg {
        id: 1,
        round: 2,
        weights: vec![1.0],
        loss: 0.1,
        steps: 3,
    };
    tx.send(stale).unwrap();
    tx.send(fresh).unwrap();
    let (weights, losses) =
        collect_round(&rx, 1, 2, Duration::from_secs(5));
    assert_eq!(weights, vec![vec![1.0]]);
    assert_eq!(losses, vec![0.1f32]);
}

#[test]
fn collection_times_out_on_truly_dead_trainer() {
    // The deadline is a safety net, not the normal path: with one
    // registered trainer that never reports, collection returns the
    // survivors (none) after the deadline instead of hanging forever.
    let (tx, rx) = mpsc::channel::<TrainerMsg>();
    let t0 = Instant::now();
    let (weights, _) = collect_round(&rx, 1, 1, Duration::from_millis(50));
    assert!(weights.is_empty());
    assert!(t0.elapsed() >= Duration::from_millis(50));
    drop(tx);
}

#[test]
fn nan_losses_are_sanitised_during_collection() {
    // A trainer that never produced a batch reports loss = NaN; the
    // aggregation operators expect a large-but-finite sentinel.
    let (tx, rx) = mpsc::channel::<TrainerMsg>();
    tx.send(TrainerMsg {
        id: 0,
        round: 1,
        weights: vec![0.0],
        loss: f32::NAN,
        steps: 0,
    })
    .unwrap();
    let (_, losses) = collect_round(&rx, 1, 1, Duration::from_secs(5));
    assert_eq!(losses, vec![f32::MAX]);
}
