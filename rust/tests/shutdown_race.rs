//! Round-protocol regression tests (no artifacts required).
//!
//! Three protocol bugs live here, each with a failing-before test:
//!
//! 1. **Shutdown race** — `tma_trainer` used to check
//!    `Control::stopped()` *before* checking for an open aggregation
//!    round, while `tma_server` raised stop *before* opening its final
//!    collection round. A trainer that observed the stop flag first
//!    exited without shipping its last-interval weights, so the final
//!    collection blocked for its full 60 s timeout per lost trainer
//!    and then silently aggregated a subset. Fix: the server opens the
//!    final round before raising stop, and trainers decide their next
//!    move via [`Control::next_action`].
//! 2. **Ready-barrier hang** — a trainer whose engine load/compile
//!    failed returned without `mark_ready()`, so the server spun
//!    forever in `while ready_count() < active`. Fix:
//!    [`Control::mark_dead`] + [`Control::wait_ready`] counting the
//!    dead, releasing the barrier with the survivors.
//! 3. **Duplicate double-count** — collection did not dedup by trainer
//!    id, so a duplicated round-r message filled a slot, skewing the
//!    aggregate toward the duplicated trainer and silently dropping
//!    another trainer's weights. Fix: id-dedup in [`collect_round`].
//!
//! The mock trainer threads below drive exactly the primitives the
//! real loops use, standing in for the engine-bound bodies.

use std::sync::mpsc;
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use random_tma::coordinator::kv::{
    Control, GlobalWeights, RoundPayload, TrainerAction, TrainerMsg,
};
use random_tma::coordinator::server::{
    collect_round, collect_round_staged, collect_round_with,
};
use random_tma::model::AggregateOp;

/// A mock trainer running the exact control-flow skeleton of
/// `tma_trainer`: next_action → ship + await broadcast | stop | one
/// "local step". Returns the rounds it shipped.
fn mock_trainer(
    id: usize,
    control: Arc<Control>,
    tx: mpsc::Sender<TrainerMsg>,
    rx_global: mpsc::Receiver<GlobalWeights>,
) -> thread::JoinHandle<Vec<u64>> {
    thread::spawn(move || {
        let mut last_round = 0u64;
        let mut shipped = Vec::new();
        loop {
            match control.next_action(last_round) {
                TrainerAction::Ship { round } => {
                    let msg = TrainerMsg {
                        id,
                        round,
                        payload: RoundPayload::Dense(vec![id as f32]),
                        loss: 0.5,
                        steps: shipped.len() as u64,
                    };
                    if tx.send(msg).is_err() {
                        break;
                    }
                    match rx_global.recv() {
                        Ok(_) => {}
                        Err(_) => break,
                    }
                    shipped.push(round);
                    last_round = round;
                }
                TrainerAction::Stop => break,
                TrainerAction::Train => {
                    // One "local step": long enough that trainers are
                    // usually mid-step when rounds open, as real ones
                    // are.
                    thread::sleep(Duration::from_millis(1));
                }
            }
        }
        shipped
    })
}

fn broadcast(txs: &[mpsc::Sender<GlobalWeights>]) {
    let w: GlobalWeights = vec![0.0f32].into();
    for tx in txs {
        tx.send(w.clone()).ok();
    }
}

#[test]
fn budget_expiry_mid_round_collects_all_live_trainers_fast() {
    let m = 4usize;
    let control = Arc::new(Control::new());
    let (msg_tx, msg_rx) = mpsc::channel::<TrainerMsg>();
    let mut global_txs = Vec::new();
    let mut handles = Vec::new();
    for id in 0..m {
        let (gtx, grx) = mpsc::channel::<GlobalWeights>();
        global_txs.push(gtx);
        handles.push(mock_trainer(id, control.clone(), msg_tx.clone(), grx));
    }
    drop(msg_tx);

    // Two regular rounds, fully collected and broadcast.
    for expect in 1..=2u64 {
        let round = control.open_round();
        assert_eq!(round, expect);
        let out = collect_round(
            &msg_rx,
            m,
            round,
            Duration::from_secs(10),
            AggregateOp::Mean,
        );
        assert_eq!(out.reporters, m, "round {round} incomplete");
        assert!(out.global.is_some());
        broadcast(&global_txs);
    }

    // Budget expires "mid-round": final round opens, then stop — the
    // server-side ordering of tma_server. All live trainers must ship
    // within one local step; well under a second, not 60 s.
    let t0 = Instant::now();
    let final_round = control.open_round();
    control.request_stop();
    let out = collect_round(
        &msg_rx,
        m,
        final_round,
        Duration::from_secs(30),
        AggregateOp::Mean,
    );
    let elapsed = t0.elapsed();
    assert_eq!(
        out.reporters, m,
        "final aggregation lost trainers: got {} of {m}",
        out.reporters
    );
    // Mean of trainer ids 0..4 shipping [id]: (0+1+2+3)/4 = 1.5.
    assert_eq!(out.global.unwrap(), vec![1.5f32]);
    assert!(
        elapsed < Duration::from_secs(1),
        "final collection took {elapsed:?} — the 60 s timeout path"
    );

    // Unblock the final-round broadcast waiters and join.
    broadcast(&global_txs);
    for h in handles {
        let shipped = h.join().expect("mock trainer panicked");
        assert_eq!(
            shipped,
            vec![1, 2, final_round],
            "every trainer serves every round, the final one included"
        );
    }
}

#[test]
fn stop_without_open_round_exits_promptly() {
    // When no round is pending at stop time there is nothing to flush:
    // trainers must exit without shipping anything extra.
    let control = Arc::new(Control::new());
    let (msg_tx, msg_rx) = mpsc::channel::<TrainerMsg>();
    let (_gtx, grx) = mpsc::channel::<GlobalWeights>();
    let h = mock_trainer(0, control.clone(), msg_tx, grx);
    thread::sleep(Duration::from_millis(10));
    control.request_stop();
    let shipped = h.join().expect("trainer panicked");
    assert!(shipped.is_empty());
    assert!(msg_rx.try_recv().is_err(), "spurious message after stop");
}

#[test]
fn ready_barrier_releases_when_a_trainer_dies_at_startup() {
    // Regression: a trainer whose Engine::load/prepare failed returned
    // without mark_ready(), and the server's `while ready_count() <
    // active` barrier spun forever. wait_ready counts the dead and
    // releases with the survivors.
    let m = 3usize;
    let control = Arc::new(Control::new());
    for id in 0..m {
        let control = control.clone();
        thread::spawn(move || {
            // Trainer 1 "fails its engine load" after a delay; the
            // others compile and mark ready.
            thread::sleep(Duration::from_millis(5 * (id as u64 + 1)));
            if id == 1 {
                control.mark_dead();
            } else {
                control.mark_ready();
            }
        });
    }
    let (tx, rx) = mpsc::channel();
    let c2 = control.clone();
    thread::spawn(move || {
        tx.send(c2.wait_ready(m)).unwrap();
    });
    // Before the fix this would hang forever; recv_timeout turns the
    // hang into a clean failure.
    let live = rx
        .recv_timeout(Duration::from_secs(10))
        .expect("ready barrier hung on the dead trainer");
    assert_eq!(live, m - 1, "barrier must report the survivors");
}

#[test]
fn duplicate_trainer_message_does_not_displace_another() {
    // Regression: before id-dedup, a duplicate round-1 message from
    // trainer 0 filled the second collection slot — aggregate became
    // (10+10)/2 = 10 and trainer 1's weights were silently dropped.
    let (tx, rx) = mpsc::channel::<TrainerMsg>();
    let dup = TrainerMsg {
        id: 0,
        round: 1,
        payload: RoundPayload::Dense(vec![10.0]),
        loss: 1.0,
        steps: 4,
    };
    tx.send(dup.clone()).unwrap();
    tx.send(dup).unwrap(); // duplicate (e.g. a retry after a hiccup)
    tx.send(TrainerMsg {
        id: 1,
        round: 1,
        payload: RoundPayload::Dense(vec![2.0]),
        loss: 1.0,
        steps: 4,
    })
    .unwrap();
    let out = collect_round(
        &rx,
        2,
        1,
        Duration::from_secs(5),
        AggregateOp::Mean,
    );
    assert_eq!(out.reporters, 2, "dedup must keep collecting");
    assert_eq!(out.global.unwrap(), vec![6.0f32], "(10+2)/2, not (10+10)/2");

    // The staged reference dedups identically.
    let (tx, rx) = mpsc::channel::<TrainerMsg>();
    for (id, w) in [(0usize, 10.0f32), (0, 10.0), (1, 2.0)] {
        tx.send(TrainerMsg {
            id,
            round: 1,
            payload: RoundPayload::Dense(vec![w]),
            loss: 1.0,
            steps: 0,
        })
        .unwrap();
    }
    let (weights, _) =
        collect_round_staged(&rx, 2, 1, Duration::from_secs(5), None);
    assert_eq!(weights, vec![vec![10.0], vec![2.0]]);
}

#[test]
fn collection_shrinks_to_survivors_when_target_drops_mid_round() {
    use std::sync::atomic::{AtomicUsize, Ordering};

    // Regression: a trainer dying *during* a collection used to stall
    // the server for the full deadline (its message never comes) and
    // then fail the run. collect_round_with re-polls the live target
    // between ≤200 ms waits, so the recorded death shrinks the round
    // to the survivors within a slice.
    let (tx, rx) = mpsc::channel::<TrainerMsg>();
    for id in 0..2usize {
        tx.send(TrainerMsg {
            id,
            round: 1,
            payload: RoundPayload::Dense(vec![id as f32]),
            loss: 0.1,
            steps: 1,
        })
        .unwrap();
    }
    // Trainer 2 never ships; ~300 ms in, its death is recorded.
    let live = Arc::new(AtomicUsize::new(3));
    let live2 = live.clone();
    let h = thread::spawn(move || {
        thread::sleep(Duration::from_millis(300));
        live2.store(2, Ordering::SeqCst);
    });
    let t0 = Instant::now();
    let out = collect_round_with(
        &rx,
        &|| live.load(Ordering::SeqCst),
        1,
        Duration::from_secs(30),
        AggregateOp::Mean,
        None,
    );
    h.join().unwrap();
    assert_eq!(out.reporters, 2);
    assert_eq!(out.global.unwrap(), vec![0.5f32]); // (0 + 1) / 2
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "collection rode the deadline instead of shrinking: {:?}",
        t0.elapsed()
    );
}

#[test]
fn collection_drops_stale_round_messages() {
    // A message stamped with an old round (a dying trainer's last
    // gasp) must not be counted into the current round's aggregate.
    let (tx, rx) = mpsc::channel::<TrainerMsg>();
    let stale = TrainerMsg {
        id: 7,
        round: 1,
        payload: RoundPayload::Dense(vec![7.0]),
        loss: 9.9,
        steps: 0,
    };
    let fresh = TrainerMsg {
        id: 1,
        round: 2,
        payload: RoundPayload::Dense(vec![1.0]),
        loss: 0.1,
        steps: 3,
    };
    tx.send(stale).unwrap();
    tx.send(fresh).unwrap();
    let out = collect_round(
        &rx,
        1,
        2,
        Duration::from_secs(5),
        AggregateOp::Mean,
    );
    assert_eq!(out.reporters, 1);
    assert_eq!(out.global.unwrap(), vec![1.0f32]);
}

#[test]
fn collection_times_out_on_truly_dead_trainer() {
    // The deadline is a safety net, not the normal path: with one
    // registered trainer that never reports, collection returns the
    // survivors (none) after the deadline instead of hanging forever.
    let (tx, rx) = mpsc::channel::<TrainerMsg>();
    let t0 = Instant::now();
    let out = collect_round(
        &rx,
        1,
        1,
        Duration::from_millis(50),
        AggregateOp::Mean,
    );
    assert_eq!(out.reporters, 0);
    assert!(out.global.is_none());
    assert!(t0.elapsed() >= Duration::from_millis(50));
    drop(tx);
}

#[test]
fn nan_losses_are_sanitised_during_collection() {
    // A trainer that never produced a batch reports loss = NaN; the
    // aggregation operators expect a large-but-finite sentinel. Both
    // collection paths sanitise identically.
    let (tx, rx) = mpsc::channel::<TrainerMsg>();
    tx.send(TrainerMsg {
        id: 0,
        round: 1,
        payload: RoundPayload::Dense(vec![0.0]),
        loss: f32::NAN,
        steps: 0,
    })
    .unwrap();
    let (_, losses) =
        collect_round_staged(&rx, 1, 1, Duration::from_secs(5), None);
    assert_eq!(losses, vec![f32::MAX]);

    // Streaming InverseLoss on a NaN-loss trainer: the sanitised
    // sentinel keeps the aggregate finite.
    let (tx, rx) = mpsc::channel::<TrainerMsg>();
    tx.send(TrainerMsg {
        id: 0,
        round: 1,
        payload: RoundPayload::Dense(vec![4.0]),
        loss: f32::NAN,
        steps: 0,
    })
    .unwrap();
    let out = collect_round(
        &rx,
        1,
        1,
        Duration::from_secs(5),
        AggregateOp::InverseLoss,
    );
    let agg = out.global.unwrap();
    assert!(agg[0].is_finite(), "NaN leaked into the aggregate: {agg:?}");
}
