//! Source model for the rules: a lexical pass that separates code
//! from comments and string literals, and marks `#[cfg(test)]`
//! regions, so rules match against what the compiler sees instead of
//! tripping on prose. No syn, no regex — a hand-rolled scanner is
//! enough for project-invariant linting and keeps the tool
//! dependency-free (the repo builds offline).

use std::fmt;

/// One violation, `file:line`-anchored for editor jumps.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Diag {
    pub rule: &'static str,
    pub file: String,
    pub line: usize,
    pub msg: String,
}

impl Diag {
    pub fn new(
        rule: &'static str,
        file: &str,
        line: usize,
        msg: String,
    ) -> Diag {
        Diag { rule, file: file.to_string(), line, msg }
    }
}

impl fmt::Display for Diag {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.msg)
    }
}

/// One line of a scanned source file.
#[derive(Debug, Clone)]
pub struct Line {
    /// The line as written.
    pub raw: String,
    /// The line with comments and string/char literal *contents*
    /// blanked to spaces — what code-token rules match against.
    pub code: String,
    /// String literals that *start* on this line, in order.
    pub strings: Vec<String>,
    /// Inside a `#[cfg(test)]`-gated brace block.
    pub in_test: bool,
}

/// A scanned `.rs` file.
#[derive(Debug, Clone)]
pub struct SourceFile {
    /// Path relative to the repo root, forward slashes.
    pub rel: String,
    pub lines: Vec<Line>,
}

impl SourceFile {
    /// 1-indexed iteration over lines.
    pub fn numbered(&self) -> impl Iterator<Item = (usize, &Line)> {
        self.lines.iter().enumerate().map(|(i, l)| (i + 1, l))
    }
}

/// A documentation file (markdown): raw lines only.
#[derive(Debug, Clone)]
pub struct DocFile {
    pub rel: String,
    pub lines: Vec<String>,
}

impl DocFile {
    pub fn new(rel: &str, text: &str) -> DocFile {
        DocFile {
            rel: rel.to_string(),
            lines: text.lines().map(|l| l.to_string()).collect(),
        }
    }

    pub fn numbered(&self) -> impl Iterator<Item = (usize, &String)> {
        self.lines.iter().enumerate().map(|(i, l)| (i + 1, l))
    }
}

/// Everything the rules see: scanned sources plus raw docs.
pub struct Tree {
    pub sources: Vec<SourceFile>,
    pub docs: Vec<DocFile>,
}

impl Tree {
    pub fn source(&self, rel: &str) -> Option<&SourceFile> {
        self.sources.iter().find(|s| s.rel == rel)
    }

    pub fn doc(&self, rel: &str) -> Option<&DocFile> {
        self.docs.iter().find(|d| d.rel == rel)
    }
}

/// Build a tree from inline fixtures — the rule tests' entry point.
#[cfg(test)]
pub fn tree_of(sources: &[(&str, &str)], docs: &[(&str, &str)]) -> Tree {
    Tree {
        sources: sources
            .iter()
            .map(|(rel, text)| parse_source(rel, text))
            .collect(),
        docs: docs
            .iter()
            .map(|(rel, text)| DocFile::new(rel, text))
            .collect(),
    }
}

#[derive(Clone, Copy, PartialEq)]
enum St {
    Code,
    LineComment,
    BlockComment(u32),
    Str,
    RawStr(u32),
}

/// Scan one source file: strip comments and literal contents from the
/// code view, collect string literals, and mark `#[cfg(test)]` brace
/// regions (tests are allowed clocks, prints and unwraps — the
/// determinism rules skip them).
pub fn parse_source(rel: &str, text: &str) -> SourceFile {
    let mut lines: Vec<Line> = Vec::new();
    let bytes: Vec<char> = text.chars().collect();
    let mut code = String::new();
    let mut raw_line = String::new();
    let mut cur_strings: Vec<String> = Vec::new();
    let mut cur_string = String::new();
    let mut st = St::Code;
    let mut i = 0usize;

    let mut flush =
        |code: &mut String, raw: &mut String, strs: &mut Vec<String>| {
            lines.push(Line {
                raw: std::mem::take(raw),
                code: std::mem::take(code),
                strings: std::mem::take(strs),
                in_test: false,
            });
        };

    while i < bytes.len() {
        let c = bytes[i];
        let next = bytes.get(i + 1).copied();
        if c == '\n' {
            if st == St::LineComment {
                st = St::Code;
            }
            flush(&mut code, &mut raw_line, &mut cur_strings);
            i += 1;
            continue;
        }
        raw_line.push(c);
        match st {
            St::Code => match c {
                '/' if next == Some('/') => {
                    st = St::LineComment;
                    code.push(' ');
                }
                '/' if next == Some('*') => {
                    st = St::BlockComment(1);
                    code.push(' ');
                }
                '"' => {
                    st = St::Str;
                    cur_string.clear();
                    code.push('"');
                }
                'r' | 'b' if is_raw_string_start(&bytes, i) => {
                    let (hashes, skip) = raw_string_open(&bytes, i);
                    for k in 0..skip {
                        if k > 0 {
                            raw_line.push(bytes[i + k]);
                        }
                        code.push(bytes[i + k]);
                    }
                    cur_string.clear();
                    st = St::RawStr(hashes);
                    i += skip;
                    continue;
                }
                '\'' => {
                    // Char/byte literal vs lifetime. A literal closes
                    // with ' within a few chars; a lifetime never
                    // does.
                    let lit_len = char_literal_len(&bytes, i);
                    if let Some(n) = lit_len {
                        for k in 0..n {
                            if k > 0 {
                                raw_line.push(bytes[i + k]);
                            }
                            code.push(if k == 0 || k == n - 1 {
                                '\''
                            } else {
                                ' '
                            });
                        }
                        i += n;
                        continue;
                    }
                    code.push('\'');
                }
                _ => code.push(c),
            },
            St::LineComment => code.push(' '),
            St::BlockComment(depth) => {
                code.push(' ');
                if c == '/' && next == Some('*') {
                    st = St::BlockComment(depth + 1);
                    raw_line.push('*');
                    code.push(' ');
                    i += 2;
                    continue;
                }
                if c == '*' && next == Some('/') {
                    raw_line.push('/');
                    code.push(' ');
                    st = if depth == 1 {
                        St::Code
                    } else {
                        St::BlockComment(depth - 1)
                    };
                    i += 2;
                    continue;
                }
            }
            St::Str => match c {
                '\\' => {
                    cur_string.push(c);
                    if let Some(n) = next {
                        if n != '\n' {
                            raw_line.push(n);
                            cur_string.push(n);
                            code.push(' ');
                            code.push(' ');
                            i += 2;
                            continue;
                        }
                    }
                    code.push(' ');
                }
                '"' => {
                    st = St::Code;
                    code.push('"');
                    cur_strings.push(std::mem::take(&mut cur_string));
                }
                _ => {
                    cur_string.push(c);
                    code.push(' ');
                }
            },
            St::RawStr(hashes) => {
                if c == '"' && raw_close(&bytes, i, hashes) {
                    for k in 0..(hashes as usize + 1) {
                        if k > 0 {
                            raw_line.push(bytes[i + k]);
                        }
                        code.push(bytes[i + k]);
                    }
                    cur_strings.push(std::mem::take(&mut cur_string));
                    st = St::Code;
                    i += hashes as usize + 1;
                    continue;
                }
                cur_string.push(c);
                code.push(' ');
            }
        }
        i += 1;
    }
    if !raw_line.is_empty() || !code.is_empty() {
        flush(&mut code, &mut raw_line, &mut cur_strings);
    }
    drop(flush);

    mark_test_regions(&mut lines);
    SourceFile { rel: rel.to_string(), lines }
}

fn is_raw_string_start(b: &[char], i: usize) -> bool {
    // r"..."  r#"..."#  br"..."  b"..." is NOT raw (plain byte
    // string — handled by the '"' arm via the preceding 'b' being
    // ordinary code). Only treat r/br with a quote or hashes as raw.
    let mut j = i;
    if b[j] == 'b' {
        if b.get(j + 1) != Some(&'r') {
            return false;
        }
        j += 1;
    }
    if b[j] != 'r' {
        return false;
    }
    // An identifier character before 'r' means this is just an ident
    // ending in r (e.g. `var"..."` cannot happen, but `r` inside
    // `for` can).
    if i > 0 && (b[i - 1].is_alphanumeric() || b[i - 1] == '_') {
        return false;
    }
    let mut k = j + 1;
    while b.get(k) == Some(&'#') {
        k += 1;
    }
    b.get(k) == Some(&'"')
}

fn raw_string_open(b: &[char], i: usize) -> (u32, usize) {
    let mut j = i;
    if b[j] == 'b' {
        j += 1;
    }
    j += 1; // the 'r'
    let mut hashes = 0u32;
    while b.get(j) == Some(&'#') {
        hashes += 1;
        j += 1;
    }
    j += 1; // the opening quote
    (hashes, j - i)
}

fn raw_close(b: &[char], i: usize, hashes: u32) -> bool {
    for k in 0..hashes as usize {
        if b.get(i + 1 + k) != Some(&'#') {
            return false;
        }
    }
    true
}

/// `'x'`, `'\n'`, `'\u{1F600}'`, `b'x'` (the b was consumed as code).
/// Returns the literal's total length, or None for a lifetime.
fn char_literal_len(b: &[char], i: usize) -> Option<usize> {
    match b.get(i + 1)? {
        '\\' => {
            let mut j = i + 2;
            while j < b.len() && b[j] != '\'' && b[j] != '\n' {
                j += 1;
            }
            (b.get(j) == Some(&'\'')).then_some(j - i + 1)
        }
        _ => (b.get(i + 2) == Some(&'\'')).then_some(3),
    }
}

/// Mark every line inside a brace block introduced after a
/// `#[cfg(test)]` attribute. Good enough for this tree's idiom
/// (`#[cfg(test)] mod tests { ... }`), which is all the determinism
/// rules need to skip.
fn mark_test_regions(lines: &mut [Line]) {
    let mut depth: i64 = 0;
    let mut pending = false;
    let mut test_stack: Vec<i64> = Vec::new();
    for line in lines.iter_mut() {
        line.in_test = !test_stack.is_empty();
        if line.code.contains("#[cfg(test)]") {
            pending = true;
        }
        for c in line.code.chars() {
            match c {
                '{' => {
                    depth += 1;
                    if pending {
                        test_stack.push(depth);
                        pending = false;
                        line.in_test = true;
                    }
                }
                '}' => {
                    if test_stack.last() == Some(&depth) {
                        test_stack.pop();
                    }
                    depth -= 1;
                }
                _ => {}
            }
        }
    }
}

/// Find `word` in `code` at identifier boundaries; returns true if
/// present as a standalone token.
pub fn has_word(code: &str, word: &str) -> bool {
    find_word(code, word).is_some()
}

/// First identifier-boundary occurrence of `word` in `code`.
pub fn find_word(code: &str, word: &str) -> Option<usize> {
    let b = code.as_bytes();
    let mut from = 0;
    while let Some(pos) = code[from..].find(word) {
        let at = from + pos;
        let before_ok = at == 0 || !is_ident(b[at - 1]);
        let end = at + word.len();
        let after_ok = end >= b.len() || !is_ident(b[end]);
        if before_ok && after_ok {
            return Some(at);
        }
        from = at + 1;
    }
    None
}

fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strips_comments_and_strings() {
        let src = "let a = \"Instant::now\"; // Instant::now\n\
                   let b = 1; /* Instant::now */ let c = 2;\n";
        let f = parse_source("x.rs", src);
        assert!(!has_word(&f.lines[0].code, "Instant"));
        assert!(!has_word(&f.lines[1].code, "Instant"));
        assert_eq!(f.lines[0].strings, vec!["Instant::now".to_string()]);
        assert!(has_word(&f.lines[1].code, "let"));
    }

    #[test]
    fn char_literals_and_lifetimes() {
        let src = "fn f<'a>(x: &'a str) -> char { '\\'' }\n\
                   let q = 'z'; let s = \"RTMA_LOG\";\n";
        let f = parse_source("x.rs", src);
        assert!(has_word(&f.lines[0].code, "str"));
        assert_eq!(f.lines[1].strings, vec!["RTMA_LOG".to_string()]);
        assert!(!f.lines[1].code.contains('z'));
    }

    #[test]
    fn raw_strings_blank_their_contents() {
        let src = "let a = r#\"unsafe { } \"quoted\" \"#; let b = 1;\n";
        let f = parse_source("x.rs", src);
        assert!(!has_word(&f.lines[0].code, "unsafe"));
        assert!(has_word(&f.lines[0].code, "let"));
        assert_eq!(f.lines[0].strings.len(), 1);
        assert!(f.lines[0].strings[0].contains("quoted"));
    }

    #[test]
    fn cfg_test_regions_are_marked() {
        let src = "fn live() { now(); }\n\
                   #[cfg(test)]\n\
                   mod tests {\n\
                       fn t() { now(); }\n\
                   }\n\
                   fn live2() {}\n";
        let f = parse_source("x.rs", src);
        assert!(!f.lines[0].in_test);
        assert!(f.lines[3].in_test);
        assert!(!f.lines[5].in_test);
    }

    #[test]
    fn word_boundaries_respected() {
        assert!(has_word("use std::collections::HashMap;", "HashMap"));
        assert!(!has_word("let my_hashmap_like = 1;", "HashMap"));
        assert!(!has_word("NotAHashMapType", "HashMap"));
    }
}
