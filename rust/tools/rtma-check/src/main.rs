//! rtma-check — project-invariant static analysis for the
//! random_tma tree (docs/ANALYSIS.md).
//!
//! Scans `rust/src`, `rust/tests`, `rust/benches`, `examples`,
//! `docs/*.md` and `README.md`, then runs five rules: wire-tags,
//! telemetry-schema, env-knobs, the determinism lints and the
//! unsafe audit. Violations print as `file:line: [rule] message`
//! and the process exits nonzero — CI's `analysis` job runs
//! `cargo run -p rtma-check` and fails the build on any hit.
//!
//! No dependencies on purpose: the scanner in `scan.rs` is a small
//! lexical pass (comment/string stripping + `#[cfg(test)]`
//! tracking), which is all these whole-project invariants need and
//! keeps the tool building in the same offline environment as the
//! crate it checks.

mod rules;
mod scan;

use std::fs;
use std::io;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

use scan::{parse_source, DocFile, Tree};

fn main() -> ExitCode {
    let root = match repo_root() {
        Ok(r) => r,
        Err(e) => {
            eprintln!("rtma-check: {e}");
            return ExitCode::from(2);
        }
    };
    let tree = match load_tree(&root) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("rtma-check: {e}");
            return ExitCode::from(2);
        }
    };
    let diags = rules::run_all(&tree);
    for d in &diags {
        println!("{d}");
    }
    if diags.is_empty() {
        println!(
            "rtma-check: clean ({} source files, {} docs)",
            tree.sources.len(),
            tree.docs.len()
        );
        ExitCode::SUCCESS
    } else {
        println!("rtma-check: {} violation(s)", diags.len());
        ExitCode::FAILURE
    }
}

/// The repo root: three levels above this crate's manifest
/// (`rust/tools/rtma-check` -> `/`), sanity-checked by a landmark.
fn repo_root() -> Result<PathBuf, String> {
    let manifest = Path::new(env!("CARGO_MANIFEST_DIR"));
    let root = manifest
        .ancestors()
        .nth(3)
        .ok_or("cannot locate the repo root")?;
    if !root.join("docs/COMM.md").is_file() {
        return Err(format!(
            "{} does not look like the repo root (docs/COMM.md missing)",
            root.display()
        ));
    }
    Ok(root.to_path_buf())
}

fn load_tree(root: &Path) -> Result<Tree, String> {
    let mut paths = Vec::new();
    for dir in ["rust/src", "rust/tests", "rust/benches", "examples"] {
        walk_rs(&root.join(dir), &mut paths)
            .map_err(|e| format!("walking {dir}: {e}"))?;
    }
    paths.sort();
    let mut sources = Vec::new();
    for p in &paths {
        let text = fs::read_to_string(p)
            .map_err(|e| format!("reading {}: {e}", p.display()))?;
        sources.push(parse_source(&rel_of(root, p), &text));
    }

    let mut docs = Vec::new();
    let mut doc_paths: Vec<PathBuf> = fs::read_dir(root.join("docs"))
        .map_err(|e| format!("reading docs/: {e}"))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.extension().is_some_and(|x| x == "md"))
        .collect();
    doc_paths.sort();
    doc_paths.push(root.join("README.md"));
    for p in &doc_paths {
        let text = fs::read_to_string(p)
            .map_err(|e| format!("reading {}: {e}", p.display()))?;
        docs.push(DocFile::new(&rel_of(root, p), &text));
    }
    Ok(Tree { sources, docs })
}

/// Recursively collect `.rs` files (sorted later for stable output).
fn walk_rs(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    for entry in fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            walk_rs(&path, out)?;
        } else if path.extension().is_some_and(|x| x == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Repo-relative path with forward slashes (diagnostic keys).
fn rel_of(root: &Path, p: &Path) -> String {
    p.strip_prefix(root)
        .unwrap_or(p)
        .to_string_lossy()
        .replace('\\', "/")
}
