//! `unsafe-audit`: every `unsafe` in the files that hold the tree's
//! unsafe surface must carry a `// SAFETY:` justification — on the
//! same line or in the contiguous comment/attribute block right
//! above it. The audit list is explicit so a new file growing an
//! `unsafe` block shows up as a review decision (add it here) rather
//! than sliding in silently; `clippy::undocumented_unsafe_blocks`
//! covers the rest of the tree but never sees `pjrt.rs` (feature
//! gated off in default builds), which this rule always scans.

use crate::scan::{has_word, Diag, SourceFile, Tree};

const RULE: &str = "unsafe-audit";

/// The audited unsafe surface (mmap windows, byte-view casts, wire
/// scratch, PJRT buffer views).
const FILES: [&str; 4] = [
    "rust/src/graph/slab.rs",
    "rust/src/graph/io.rs",
    "rust/src/comm/mod.rs",
    "rust/src/runtime/pjrt.rs",
];

pub fn check(tree: &Tree) -> Vec<Diag> {
    let mut out = Vec::new();
    for rel in FILES {
        let Some(f) = tree.source(rel) else {
            let msg =
                "audited file missing — update the unsafe-audit list"
                    .to_string();
            out.push(Diag::new(RULE, rel, 1, msg));
            continue;
        };
        for (ln, line) in f.numbered() {
            if !has_word(&line.code, "unsafe") {
                continue;
            }
            if !safety_documented(f, ln) {
                out.push(Diag::new(
                    RULE,
                    rel,
                    ln,
                    "`unsafe` without a `// SAFETY:` comment on or \
                     above it"
                        .to_string(),
                ));
            }
        }
    }
    out
}

/// The line itself, or the contiguous run of comment / attribute /
/// blank lines directly above it, mentions SAFETY.
fn safety_documented(f: &SourceFile, ln: usize) -> bool {
    if f.lines[ln - 1].raw.contains("SAFETY") {
        return true;
    }
    let mut j = ln - 1;
    while j > 0 {
        j -= 1;
        let t = f.lines[j].raw.trim();
        if t.contains("SAFETY") {
            return true;
        }
        let skippable = t.is_empty()
            || t.starts_with("//")
            || t.starts_with("/*")
            || t.starts_with('*')
            || t.starts_with("#[");
        if !skippable {
            return false;
        }
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scan::tree_of;

    #[test]
    fn documented_unsafe_passes() {
        let src = "// SAFETY: the region outlives the view and the\n\
                   // cast target is plain-old-data.\n\
                   let b = unsafe { view(ptr) };\n\
                   \n\
                   // SAFETY: same argument, shared this time.\n\
                   unsafe impl Sync for M {}\n";
        let t = tree_of(&[("rust/src/graph/io.rs", src)], &[]);
        let d: Vec<_> = check(&t)
            .into_iter()
            .filter(|d| d.file == "rust/src/graph/io.rs")
            .collect();
        assert!(d.is_empty(), "{d:?}");
    }

    #[test]
    fn undocumented_unsafe_is_flagged_with_its_line() {
        let src = "fn f(ptr: *const u8) {\n\
                   let b = unsafe { view(ptr) };\n\
                   }\n";
        let t = tree_of(&[("rust/src/comm/mod.rs", src)], &[]);
        let d: Vec<_> = check(&t)
            .into_iter()
            .filter(|d| d.file == "rust/src/comm/mod.rs")
            .collect();
        assert_eq!(d.len(), 1, "{d:?}");
        assert_eq!(d[0].rule, "unsafe-audit");
        assert_eq!(d[0].line, 2);
    }

    #[test]
    fn a_code_line_breaks_the_comment_walk() {
        // The SAFETY comment belongs to the first block only; the
        // second unsafe cannot borrow it across the code line.
        let src = "// SAFETY: argument for the first block.\n\
                   let a = unsafe { one() };\n\
                   let b = unsafe { two() };\n";
        let t = tree_of(&[("rust/src/graph/slab.rs", src)], &[]);
        let d: Vec<_> = check(&t)
            .into_iter()
            .filter(|d| d.file == "rust/src/graph/slab.rs")
            .collect();
        assert_eq!(d.len(), 1, "{d:?}");
        assert_eq!(d[0].line, 3);
    }

    #[test]
    fn unsafe_in_comments_strings_and_missing_files_behave() {
        let src = "// unsafe is discussed here only\n\
                   let s = \"unsafe\";\n";
        let t = tree_of(&[("rust/src/graph/io.rs", src)], &[]);
        let d = check(&t);
        // io.rs is clean; the other three audit files are absent
        // from the fixture tree and each reports exactly once.
        assert_eq!(d.len(), 3, "{d:?}");
        assert!(d.iter().all(|d| d.msg.contains("audited file")));
    }
}
