//! `wire-tags`: the frame-tag registry must be the single source of
//! truth for wire bytes.
//!
//! - every `TAG_*` constant in `comm/tags.rs` is listed in `all()`
//!   exactly once, and the registry is unique and contiguous from 1;
//! - the tag table in docs/COMM.md is bit-identical to `all()` in
//!   both directions (same tag values, same message names);
//! - no `const TAG_*: u8` is declared anywhere else in the tree.

use crate::scan::{Diag, DocFile, SourceFile, Tree};

const RULE: &str = "wire-tags";
const REGISTRY: &str = "rust/src/comm/tags.rs";
const DOC: &str = "docs/COMM.md";

struct TagConst {
    name: String,
    value: u8,
    line: usize,
}

struct Entry {
    name: String,
    const_name: String,
    line: usize,
}

pub fn check(tree: &Tree) -> Vec<Diag> {
    let mut out = Vec::new();
    let Some(reg) = tree.source(REGISTRY) else {
        let msg = "tag registry file missing".to_string();
        out.push(Diag::new(RULE, REGISTRY, 1, msg));
        return out;
    };
    let consts = parse_consts(reg, &mut out);
    let entries = parse_all(reg);

    // Constant values must be unique.
    for (i, a) in consts.iter().enumerate() {
        if let Some(b) = consts[..i].iter().find(|b| b.value == a.value) {
            out.push(Diag::new(
                RULE,
                REGISTRY,
                a.line,
                format!(
                    "tag value {} of {} already taken by {}",
                    a.value, a.name, b.name
                ),
            ));
        }
    }

    // Every constant is listed in all() exactly once.
    for c in &consts {
        let n = entries
            .iter()
            .filter(|e| e.const_name == c.name)
            .count();
        if n != 1 {
            out.push(Diag::new(
                RULE,
                REGISTRY,
                c.line,
                format!("{} listed {n} times in all() (want 1)", c.name),
            ));
        }
    }
    for e in &entries {
        if !consts.iter().any(|c| c.name == e.const_name) {
            out.push(Diag::new(
                RULE,
                REGISTRY,
                e.line,
                format!("all() lists unknown constant {}", e.const_name),
            ));
        }
    }

    // Contiguous from 1 in declaration order, unique names.
    for (i, e) in entries.iter().enumerate() {
        let want = i as u8 + 1;
        if let Some(c) = consts.iter().find(|c| c.name == e.const_name) {
            if c.value != want {
                out.push(Diag::new(
                    RULE,
                    REGISTRY,
                    e.line,
                    format!(
                        "registry not contiguous: {} is {} at position \
                         {} (want {want})",
                        c.name,
                        c.value,
                        i + 1
                    ),
                ));
            }
        }
        if entries[..i].iter().any(|p| p.name == e.name) {
            out.push(Diag::new(
                RULE,
                REGISTRY,
                e.line,
                format!("duplicate message name {:?} in all()", e.name),
            ));
        }
    }

    // docs/COMM.md tag table <-> all(), both directions.
    match tree.doc(DOC) {
        None => {
            let msg = "tag-table doc missing".to_string();
            out.push(Diag::new(RULE, DOC, 1, msg));
        }
        Some(doc) => {
            let rows = doc_rows(doc);
            for (i, e) in entries.iter().enumerate() {
                let value = i as u8 + 1;
                let hit = rows
                    .iter()
                    .any(|(v, n, _)| *v == value && *n == e.name);
                if !hit {
                    out.push(Diag::new(
                        RULE,
                        REGISTRY,
                        e.line,
                        format!(
                            "tag {value} ({}) missing from the {DOC} \
                             tag table",
                            e.name
                        ),
                    ));
                }
            }
            for (v, n, ln) in &rows {
                let i = *v as usize;
                let hit = i >= 1
                    && i <= entries.len()
                    && entries[i - 1].name == *n;
                if !hit {
                    out.push(Diag::new(
                        RULE,
                        DOC,
                        *ln,
                        format!(
                            "documented tag {v} ({n}) does not match \
                             comm::tags::all()"
                        ),
                    ));
                }
            }
        }
    }

    // No tag constants outside the registry.
    for f in &tree.sources {
        if f.rel == REGISTRY {
            continue;
        }
        for (ln, line) in f.numbered() {
            if stray_tag_const(&line.code) {
                out.push(Diag::new(
                    RULE,
                    &f.rel,
                    ln,
                    "wire tag declared outside comm::tags — add it \
                     to the registry instead"
                        .to_string(),
                ));
            }
        }
    }
    out
}

/// `pub const TAG_FOO: u8 = 3;` lines in the registry.
fn parse_consts(reg: &SourceFile, out: &mut Vec<Diag>) -> Vec<TagConst> {
    let mut v = Vec::new();
    for (ln, line) in reg.numbered() {
        let Some(pos) = line.code.find("const TAG_") else {
            continue;
        };
        let rest = &line.code[pos + "const ".len()..];
        let name: String = rest
            .chars()
            .take_while(|c| c.is_ascii_alphanumeric() || *c == '_')
            .collect();
        let after = rest[name.len()..].trim_start();
        let parsed = after.strip_prefix(": u8").and_then(|a| {
            let digits: String = a
                .trim_start_matches([' ', '='])
                .chars()
                .take_while(char::is_ascii_digit)
                .collect();
            digits.parse::<u8>().ok()
        });
        match parsed {
            Some(value) => v.push(TagConst { name, value, line: ln }),
            None => out.push(Diag::new(
                RULE,
                &reg.rel,
                ln,
                format!("unparseable tag constant {name} (want \
                         `pub const {name}: u8 = <n>;`)"),
            )),
        }
    }
    v
}

/// `(TAG_FOO, "Foo"),` entries inside `all()`.
fn parse_all(reg: &SourceFile) -> Vec<Entry> {
    let mut v = Vec::new();
    for (ln, line) in reg.numbered() {
        let Some(pos) = line.code.find("(TAG_") else {
            continue;
        };
        let Some(name) = line.strings.first() else {
            continue;
        };
        let const_name: String = line.code[pos + 1..]
            .chars()
            .take_while(|c| c.is_ascii_alphanumeric() || *c == '_')
            .collect();
        v.push(Entry { name: name.clone(), const_name, line: ln });
    }
    v
}

/// Markdown table rows whose first cell is a number and second a
/// backticked name: `| 3 | \`Weights\` | ... |`.
fn doc_rows(doc: &DocFile) -> Vec<(u8, String, usize)> {
    let mut v = Vec::new();
    for (ln, raw) in doc.numbered() {
        let t = raw.trim();
        if !t.starts_with('|') {
            continue;
        }
        let cells: Vec<&str> = t
            .trim_matches('|')
            .split('|')
            .map(str::trim)
            .collect();
        if cells.len() < 2 {
            continue;
        }
        let Ok(num) = cells[0].parse::<u8>() else {
            continue;
        };
        let name = cells[1].trim_matches('`');
        v.push((num, name.to_string(), ln));
    }
    v
}

/// A `const TAG_X: u8` declaration (stray registry entry).
fn stray_tag_const(code: &str) -> bool {
    let Some(pos) = code.find("const TAG_") else {
        return false;
    };
    let rest = &code[pos + "const ".len()..];
    let name_len = rest
        .chars()
        .take_while(|c| c.is_ascii_alphanumeric() || *c == '_')
        .count();
    rest[name_len..].trim_start().starts_with(": u8")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scan::tree_of;

    const GOOD_REG: &str = "pub const TAG_HELLO: u8 = 1;\n\
                            pub const TAG_READY: u8 = 2;\n\
                            pub const fn all() {\n\
                            (TAG_HELLO, \"Hello\"),\n\
                            (TAG_READY, \"Ready\"),\n\
                            }\n";
    const GOOD_DOC: &str = "| Tag | Message |\n\
                            | 1 | `Hello` |\n\
                            | 2 | `Ready` |\n";

    #[test]
    fn clean_registry_passes() {
        let t = tree_of(
            &[("rust/src/comm/tags.rs", GOOD_REG)],
            &[("docs/COMM.md", GOOD_DOC)],
        );
        assert!(check(&t).is_empty(), "{:?}", check(&t));
    }

    #[test]
    fn missing_doc_row_is_flagged_at_the_registry_line() {
        let doc = "| 1 | `Hello` |\n";
        let t = tree_of(
            &[("rust/src/comm/tags.rs", GOOD_REG)],
            &[("docs/COMM.md", doc)],
        );
        let d = check(&t);
        assert_eq!(d.len(), 1, "{d:?}");
        assert_eq!(d[0].rule, "wire-tags");
        assert_eq!(d[0].line, 5); // the (TAG_READY, "Ready") entry
    }

    #[test]
    fn doc_row_not_in_registry_is_flagged_at_the_doc_line() {
        let doc = "| 1 | `Hello` |\n\
                   | 2 | `Ready` |\n\
                   | 3 | `Ghost` |\n";
        let t = tree_of(
            &[("rust/src/comm/tags.rs", GOOD_REG)],
            &[("docs/COMM.md", doc)],
        );
        let d = check(&t);
        assert_eq!(d.len(), 1, "{d:?}");
        assert_eq!(d[0].file, "docs/COMM.md");
        assert_eq!(d[0].line, 3);
    }

    #[test]
    fn non_contiguous_values_are_flagged() {
        let reg = "pub const TAG_HELLO: u8 = 1;\n\
                   pub const TAG_READY: u8 = 3;\n\
                   pub const fn all() {\n\
                   (TAG_HELLO, \"Hello\"),\n\
                   (TAG_READY, \"Ready\"),\n\
                   }\n";
        let doc = "| 1 | `Hello` |\n| 2 | `Ready` |\n";
        let t = tree_of(
            &[("rust/src/comm/tags.rs", reg)],
            &[("docs/COMM.md", doc)],
        );
        let d = check(&t);
        assert!(
            d.iter().any(|d| d.line == 5
                && d.msg.contains("not contiguous")),
            "{d:?}"
        );
    }

    #[test]
    fn duplicate_value_and_unlisted_const_are_flagged() {
        let reg = "pub const TAG_HELLO: u8 = 1;\n\
                   pub const TAG_READY: u8 = 1;\n\
                   pub const fn all() {\n\
                   (TAG_HELLO, \"Hello\"),\n\
                   }\n";
        let doc = "| 1 | `Hello` |\n";
        let t = tree_of(
            &[("rust/src/comm/tags.rs", reg)],
            &[("docs/COMM.md", doc)],
        );
        let d = check(&t);
        assert!(d.iter().any(|d| d.msg.contains("already taken")));
        assert!(d.iter().any(|d| d.msg.contains("listed 0 times")));
    }

    #[test]
    fn stray_tag_const_outside_registry_is_flagged() {
        let t = tree_of(
            &[
                ("rust/src/comm/tags.rs", GOOD_REG),
                (
                    "rust/src/serve.rs",
                    "const TAG_EXTRA: u8 = 99;\n",
                ),
            ],
            &[("docs/COMM.md", GOOD_DOC)],
        );
        let d = check(&t);
        assert_eq!(d.len(), 1, "{d:?}");
        assert_eq!(d[0].file, "rust/src/serve.rs");
        assert_eq!(d[0].line, 1);
    }
}
