//! The project-invariant rules. Each rule is a pure function from
//! the scanned [`Tree`] to a list of `file:line`-anchored [`Diag`]s,
//! so every rule carries inline bad-fixture tests that feed it a
//! hand-built tree and assert the exact violation (rule + line)
//! comes back.

pub mod determinism;
pub mod knobs;
pub mod metrics;
pub mod tags;
pub mod unsafety;

use crate::scan::{Diag, Tree};

/// Run every rule and return the violations sorted by location.
pub fn run_all(tree: &Tree) -> Vec<Diag> {
    let mut out = Vec::new();
    out.extend(tags::check(tree));
    out.extend(metrics::check(tree));
    out.extend(knobs::check(tree));
    out.extend(determinism::check(tree));
    out.extend(unsafety::check(tree));
    out.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule)
            .cmp(&(b.file.as_str(), b.line, b.rule))
    });
    out
}
