//! Determinism lints. Three sub-rules, all scoped to `rust/src` and
//! all skipping `#[cfg(test)]` regions:
//!
//! - `det-clock`: no raw `Instant::now()` / `SystemTime::now()`
//!   outside the telemetry plane and the bench harness — wall time
//!   goes through `telemetry::now()` so replays and tests can reason
//!   about one clock.
//! - `det-collections`: no `HashMap`/`HashSet` in the deterministic
//!   modules (`gen`, `model`, `runtime::native`, `comm::codec`) —
//!   iteration order there must not depend on hasher seeds.
//! - `det-print`: no stray `println!`/`eprintln!` outside `main.rs`
//!   and the telemetry/bench planes — diagnostics go through
//!   telemetry events so `RTMA_LOG=off` actually silences the tree.

use crate::scan::{find_word, Diag, SourceFile, Tree};

pub fn check(tree: &Tree) -> Vec<Diag> {
    let mut out = Vec::new();
    for f in &tree.sources {
        clock(f, &mut out);
        collections(f, &mut out);
        prints(f, &mut out);
    }
    out
}

const CLOCK_ALLOWED: [&str; 2] =
    ["rust/src/benchkit.rs", "rust/src/util/bench.rs"];

fn clock(f: &SourceFile, out: &mut Vec<Diag>) {
    if !f.rel.starts_with("rust/src/")
        || f.rel.starts_with("rust/src/telemetry/")
        || CLOCK_ALLOWED.contains(&f.rel.as_str())
    {
        return;
    }
    for (ln, line) in f.numbered() {
        if line.in_test {
            continue;
        }
        for pat in ["Instant::now", "SystemTime::now"] {
            if line.code.contains(pat) {
                out.push(Diag::new(
                    "det-clock",
                    &f.rel,
                    ln,
                    format!(
                        "raw `{pat}()` — route wall time through \
                         telemetry::now()"
                    ),
                ));
            }
        }
    }
}

const DET_DIRS: [&str; 2] = ["rust/src/gen/", "rust/src/model/"];
const DET_FILES: [&str; 2] =
    ["rust/src/runtime/native.rs", "rust/src/comm/codec.rs"];

fn collections(f: &SourceFile, out: &mut Vec<Diag>) {
    let scoped = DET_DIRS.iter().any(|d| f.rel.starts_with(d))
        || DET_FILES.contains(&f.rel.as_str());
    if !scoped {
        return;
    }
    for (ln, line) in f.numbered() {
        if line.in_test {
            continue;
        }
        for ty in ["HashMap", "HashSet"] {
            if find_word(&line.code, ty).is_some() {
                out.push(Diag::new(
                    "det-collections",
                    &f.rel,
                    ln,
                    format!(
                        "`{ty}` in a deterministic module — use \
                         BTreeMap/BTreeSet or a sorted Vec"
                    ),
                ));
            }
        }
    }
}

const PRINT_ALLOWED: [&str; 3] = [
    "rust/src/main.rs",
    "rust/src/benchkit.rs",
    "rust/src/util/bench.rs",
];

fn prints(f: &SourceFile, out: &mut Vec<Diag>) {
    if !f.rel.starts_with("rust/src/")
        || f.rel.starts_with("rust/src/telemetry/")
        || PRINT_ALLOWED.contains(&f.rel.as_str())
    {
        return;
    }
    for (ln, line) in f.numbered() {
        if line.in_test {
            continue;
        }
        for mac in ["println", "eprintln", "print", "eprint"] {
            if has_macro(&line.code, mac) {
                out.push(Diag::new(
                    "det-print",
                    &f.rel,
                    ln,
                    format!(
                        "stray `{mac}!` — emit a telemetry event \
                         (telemetry::info/debug) instead"
                    ),
                ));
                break;
            }
        }
    }
}

/// `name!` at an identifier boundary (so `print` does not match
/// inside `println` or `eprint`).
fn has_macro(code: &str, name: &str) -> bool {
    let b = code.as_bytes();
    let mut from = 0;
    while let Some(pos) = code[from..].find(name) {
        let at = from + pos;
        let pre = at == 0
            || !(b[at - 1].is_ascii_alphanumeric() || b[at - 1] == b'_');
        let post = b.get(at + name.len()) == Some(&b'!');
        if pre && post {
            return true;
        }
        from = at + 1;
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scan::tree_of;

    #[test]
    fn raw_clock_read_is_flagged() {
        let t = tree_of(
            &[(
                "rust/src/coordinator/server.rs",
                "fn f() {\nlet t = std::time::Instant::now();\n}\n",
            )],
            &[],
        );
        let d = check(&t);
        assert_eq!(d.len(), 1, "{d:?}");
        assert_eq!(d[0].rule, "det-clock");
        assert_eq!(d[0].line, 2);
    }

    #[test]
    fn telemetry_bench_and_tests_may_read_the_clock() {
        let t = tree_of(
            &[
                (
                    "rust/src/telemetry/mod.rs",
                    "pub fn now() { Instant::now() }\n",
                ),
                (
                    "rust/src/util/bench.rs",
                    "fn t() { Instant::now(); }\n",
                ),
                (
                    "rust/src/coordinator/server.rs",
                    "#[cfg(test)]\nmod tests {\nfn t() { \
                     Instant::now(); }\n}\n",
                ),
            ],
            &[],
        );
        assert!(check(&t).is_empty(), "{:?}", check(&t));
    }

    #[test]
    fn hash_collections_in_deterministic_modules_are_flagged() {
        let t = tree_of(
            &[
                (
                    "rust/src/gen/dcsbm.rs",
                    "use std::collections::HashMap;\n",
                ),
                (
                    "rust/src/serve.rs",
                    "use std::collections::HashMap;\n",
                ),
            ],
            &[],
        );
        let d = check(&t);
        assert_eq!(d.len(), 1, "{d:?}");
        assert_eq!(d[0].rule, "det-collections");
        assert_eq!(d[0].file, "rust/src/gen/dcsbm.rs");
    }

    #[test]
    fn stray_prints_are_flagged_but_main_and_comments_pass() {
        let t = tree_of(
            &[
                (
                    "rust/src/coordinator/ggs.rs",
                    "fn f() {\neprintln!(\"x\");\n}\n",
                ),
                ("rust/src/main.rs", "fn f() { println!(\"ok\"); }\n"),
                (
                    "rust/src/serve.rs",
                    "// println! would be wrong here\nfn f() {}\n",
                ),
            ],
            &[],
        );
        let d = check(&t);
        assert_eq!(d.len(), 1, "{d:?}");
        assert_eq!(d[0].rule, "det-print");
        assert_eq!(d[0].file, "rust/src/coordinator/ggs.rs");
        assert_eq!(d[0].line, 2);
    }
}
