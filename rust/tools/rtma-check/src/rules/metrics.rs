//! `telemetry-schema`: the metric registry, its published names and
//! docs/TELEMETRY.md must agree.
//!
//! - every `Metrics` field is published exactly once via
//!   `counters_list` / `gauges_list` / `hists_list`, under the list
//!   matching its kind;
//! - the "Metric registry" table in docs/TELEMETRY.md names exactly
//!   the published set, with matching kinds;
//! - no dead metrics: every field has a call site outside the
//!   registry file;
//! - every `metrics().<ident>` call site resolves to a real field or
//!   method of `Metrics`.

use crate::scan::{find_word, Diag, SourceFile, Tree};

const RULE: &str = "telemetry-schema";
const REGISTRY: &str = "rust/src/telemetry/registry.rs";
const DOC: &str = "docs/TELEMETRY.md";

#[derive(Clone, Copy, PartialEq, Debug)]
enum Kind {
    Counter,
    Gauge,
    Hist,
}

impl Kind {
    fn as_str(self) -> &'static str {
        match self {
            Kind::Counter => "counter",
            Kind::Gauge => "gauge",
            Kind::Hist => "histogram",
        }
    }
}

struct Field {
    name: String,
    kind: Kind,
    line: usize,
}

struct Published {
    name: String,
    field: String,
    kind: Kind,
    line: usize,
}

pub fn check(tree: &Tree) -> Vec<Diag> {
    let mut out = Vec::new();
    let Some(reg) = tree.source(REGISTRY) else {
        let msg = "metric registry file missing".to_string();
        out.push(Diag::new(RULE, REGISTRY, 1, msg));
        return out;
    };
    let fields = parse_fields(reg);
    let published = parse_published(reg);
    let methods = parse_methods(reg);

    // Each field published exactly once, under its own kind.
    for f in &fields {
        let hits: Vec<&Published> = published
            .iter()
            .filter(|p| p.field == f.name)
            .collect();
        match hits.as_slice() {
            [] => out.push(Diag::new(
                RULE,
                REGISTRY,
                f.line,
                format!(
                    "metric field `{}` is never published — add it \
                     to {}s_list()",
                    f.name,
                    f.kind.as_str()
                ),
            )),
            [one] => {
                if one.kind != f.kind {
                    out.push(Diag::new(
                        RULE,
                        REGISTRY,
                        one.line,
                        format!(
                            "`{}` is a {} but is published from the \
                             {} list",
                            f.name,
                            f.kind.as_str(),
                            one.kind.as_str()
                        ),
                    ));
                }
            }
            many => out.push(Diag::new(
                RULE,
                REGISTRY,
                many[1].line,
                format!("metric field `{}` published twice", f.name),
            )),
        }
    }
    for p in &published {
        if !fields.iter().any(|f| f.name == p.field) {
            out.push(Diag::new(
                RULE,
                REGISTRY,
                p.line,
                format!("published entry reads unknown field `{}`", p.field),
            ));
        }
        if published
            .iter()
            .filter(|q| q.name == p.name)
            .count()
            > 1
        {
            out.push(Diag::new(
                RULE,
                REGISTRY,
                p.line,
                format!("published metric name {:?} is not unique", p.name),
            ));
        }
    }

    // The doc table <-> the published set, both directions.
    match tree.doc(DOC) {
        None => {
            let msg = "telemetry doc missing".to_string();
            out.push(Diag::new(RULE, DOC, 1, msg));
        }
        Some(doc) => {
            let rows = doc_rows(doc);
            for p in &published {
                let hit = rows
                    .iter()
                    .any(|(n, k, _)| *n == p.name && *k == p.kind);
                if !hit {
                    out.push(Diag::new(
                        RULE,
                        REGISTRY,
                        p.line,
                        format!(
                            "published {} `{}` missing from the {DOC} \
                             metric-registry table",
                            p.kind.as_str(),
                            p.name
                        ),
                    ));
                }
            }
            for (n, k, ln) in &rows {
                let hit = published
                    .iter()
                    .any(|p| p.name == *n && p.kind == *k);
                if !hit {
                    out.push(Diag::new(
                        RULE,
                        DOC,
                        *ln,
                        format!(
                            "documented {} `{n}` is not published by \
                             the registry",
                            k.as_str()
                        ),
                    ));
                }
            }
        }
    }

    // Dead metrics: a field nobody touches outside the registry.
    for f in &fields {
        let used = tree.sources.iter().any(|s| {
            s.rel != REGISTRY
                && s.lines
                    .iter()
                    .any(|l| field_read(&l.code, &f.name))
        });
        if !used {
            out.push(Diag::new(
                RULE,
                REGISTRY,
                f.line,
                format!(
                    "dead metric: `{}` has no call site outside the \
                     registry",
                    f.name
                ),
            ));
        }
    }

    // metrics().<ident> call sites resolve.
    for s in &tree.sources {
        if s.rel == REGISTRY {
            continue;
        }
        for (ln, line) in s.numbered() {
            for ident in metrics_idents(&line.code) {
                let known = fields.iter().any(|f| f.name == ident)
                    || methods.iter().any(|m| *m == ident);
                if !known {
                    out.push(Diag::new(
                        RULE,
                        &s.rel,
                        ln,
                        format!(
                            "metrics().{ident} does not resolve to a \
                             registry field or method"
                        ),
                    ));
                }
            }
        }
    }
    out
}

/// `pub foo: Counter,` lines inside `pub struct Metrics { .. }`.
fn parse_fields(reg: &SourceFile) -> Vec<Field> {
    let mut v = Vec::new();
    let mut in_struct = false;
    for (ln, line) in reg.numbered() {
        let t = line.code.trim();
        if t.starts_with("pub struct Metrics") {
            in_struct = true;
            continue;
        }
        if !in_struct {
            continue;
        }
        if t == "}" {
            break;
        }
        let Some(rest) = t.strip_prefix("pub ") else {
            continue;
        };
        let Some((name, ty)) = rest.split_once(':') else {
            continue;
        };
        let kind = match ty.trim().trim_end_matches(',') {
            "Counter" => Kind::Counter,
            "Gauge" => Kind::Gauge,
            "Histogram" => Kind::Hist,
            _ => continue,
        };
        v.push(Field { name: name.trim().to_string(), kind, line: ln });
    }
    v
}

/// `("name", self.field.get()),` entries inside the three `*_list`
/// publishers.
fn parse_published(reg: &SourceFile) -> Vec<Published> {
    let mut v = Vec::new();
    let mut cur: Option<Kind> = None;
    for (ln, line) in reg.numbered() {
        let code = &line.code;
        if code.contains("fn counters_list") {
            cur = Some(Kind::Counter);
            continue;
        }
        if code.contains("fn gauges_list") {
            cur = Some(Kind::Gauge);
            continue;
        }
        if code.contains("fn hists_list") {
            cur = Some(Kind::Hist);
            continue;
        }
        if code.contains("fn ") {
            cur = None;
            continue;
        }
        let Some(kind) = cur else { continue };
        let Some(pos) = code.find("self.") else { continue };
        let Some(name) = line.strings.first() else { continue };
        if !code.contains("(\"") {
            continue;
        }
        let field: String = code[pos + "self.".len()..]
            .chars()
            .take_while(|c| c.is_ascii_alphanumeric() || *c == '_')
            .collect();
        v.push(Published {
            name: name.clone(),
            field,
            kind,
            line: ln,
        });
    }
    v
}

/// Every `fn <ident>` in the registry file (resolution targets for
/// `metrics().<ident>()` call sites).
fn parse_methods(reg: &SourceFile) -> Vec<String> {
    let mut v = Vec::new();
    for line in &reg.lines {
        let Some(pos) = line.code.find("fn ") else { continue };
        let name: String = line.code[pos + 3..]
            .chars()
            .take_while(|c| c.is_ascii_alphanumeric() || *c == '_')
            .collect();
        if !name.is_empty() {
            v.push(name);
        }
    }
    v
}

/// Rows of the docs/TELEMETRY.md metric table:
/// `| \`name\` | counter \| gauge \| histogram | ... |`.
fn doc_rows(doc: &crate::scan::DocFile) -> Vec<(String, Kind, usize)> {
    let mut v = Vec::new();
    for (ln, raw) in doc.numbered() {
        let t = raw.trim();
        if !t.starts_with("| `") {
            continue;
        }
        let cells: Vec<&str> = t
            .trim_matches('|')
            .split('|')
            .map(str::trim)
            .collect();
        if cells.len() < 2 {
            continue;
        }
        let kind = match cells[1] {
            "counter" => Kind::Counter,
            "gauge" => Kind::Gauge,
            "histogram" => Kind::Hist,
            _ => continue,
        };
        let name = cells[0].trim_matches('`').to_string();
        v.push((name, kind, ln));
    }
    v
}

/// `.field` with an identifier boundary on the right and a literal
/// dot on the left — a field read like `metrics().step_us.observe()`.
fn field_read(code: &str, field: &str) -> bool {
    let mut from = 0;
    while let Some(pos) = find_word(&code[from..], field) {
        let at = from + pos;
        if at > 0 && code.as_bytes()[at - 1] == b'.' {
            return true;
        }
        from = at + 1;
        if from >= code.len() {
            break;
        }
    }
    false
}

/// Idents read directly off `metrics().` on this line.
fn metrics_idents(code: &str) -> Vec<String> {
    let mut v = Vec::new();
    let mut from = 0;
    let pat = "metrics().";
    while let Some(pos) = code[from..].find(pat) {
        let at = from + pos + pat.len();
        let ident: String = code[at..]
            .chars()
            .take_while(|c| c.is_ascii_alphanumeric() || *c == '_')
            .collect();
        if !ident.is_empty() {
            v.push(ident);
        }
        from = at;
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scan::tree_of;

    const GOOD_REG: &str = "pub struct Metrics {\n\
                            pub rounds: Counter,\n\
                            pub depth: Gauge,\n\
                            }\n\
                            fn counters_list() {\n\
                            (\"rounds\", self.rounds.get()),\n\
                            }\n\
                            fn gauges_list() {\n\
                            (\"depth\", self.depth.get()),\n\
                            }\n";
    const GOOD_DOC: &str = "| `rounds` | counter | round count |\n\
                            | `depth` | gauge | queue depth |\n";
    const GOOD_USE: &str = "fn f() { metrics().rounds.inc(); }\n\
                            fn g() { metrics().depth.set(1); }\n";

    fn reg_path() -> &'static str {
        "rust/src/telemetry/registry.rs"
    }

    #[test]
    fn clean_registry_passes() {
        let t = tree_of(
            &[(reg_path(), GOOD_REG), ("rust/src/server.rs", GOOD_USE)],
            &[("docs/TELEMETRY.md", GOOD_DOC)],
        );
        assert!(check(&t).is_empty(), "{:?}", check(&t));
    }

    #[test]
    fn unpublished_field_is_flagged() {
        let reg = "pub struct Metrics {\n\
                   pub rounds: Counter,\n\
                   pub lost: Counter,\n\
                   }\n\
                   fn counters_list() {\n\
                   (\"rounds\", self.rounds.get()),\n\
                   }\n";
        let use_both = "fn f() { metrics().rounds.inc(); \
                        metrics().lost.inc(); }\n";
        let t = tree_of(
            &[(reg_path(), reg), ("rust/src/server.rs", use_both)],
            &[("docs/TELEMETRY.md", "| `rounds` | counter | n |\n")],
        );
        let d = check(&t);
        assert_eq!(d.len(), 1, "{d:?}");
        assert_eq!(d[0].line, 3);
        assert!(d[0].msg.contains("never published"));
    }

    #[test]
    fn undocumented_published_metric_is_flagged() {
        let t = tree_of(
            &[(reg_path(), GOOD_REG), ("rust/src/server.rs", GOOD_USE)],
            &[("docs/TELEMETRY.md", "| `rounds` | counter | n |\n")],
        );
        let d = check(&t);
        assert_eq!(d.len(), 1, "{d:?}");
        assert!(d[0].msg.contains("missing from the docs/TELEMETRY.md"));
    }

    #[test]
    fn doc_row_for_unknown_metric_is_flagged_at_doc_line() {
        let doc = "| `rounds` | counter | n |\n\
                   | `depth` | gauge | d |\n\
                   | `ghost` | counter | boo |\n";
        let t = tree_of(
            &[(reg_path(), GOOD_REG), ("rust/src/server.rs", GOOD_USE)],
            &[("docs/TELEMETRY.md", doc)],
        );
        let d = check(&t);
        assert_eq!(d.len(), 1, "{d:?}");
        assert_eq!(d[0].file, "docs/TELEMETRY.md");
        assert_eq!(d[0].line, 3);
    }

    #[test]
    fn dead_metric_is_flagged() {
        let only_rounds = "fn f() { metrics().rounds.inc(); }\n";
        let t = tree_of(
            &[(reg_path(), GOOD_REG), ("rust/src/server.rs", only_rounds)],
            &[("docs/TELEMETRY.md", GOOD_DOC)],
        );
        let d = check(&t);
        assert_eq!(d.len(), 1, "{d:?}");
        assert_eq!(d[0].line, 3); // pub depth: Gauge,
        assert!(d[0].msg.contains("dead metric"));
    }

    #[test]
    fn unresolvable_metrics_ident_is_flagged() {
        let bad = "fn f() { metrics().rounds.inc(); \
                   metrics().bogus.inc(); metrics().depth.set(2); }\n";
        let t = tree_of(
            &[(reg_path(), GOOD_REG), ("rust/src/server.rs", bad)],
            &[("docs/TELEMETRY.md", GOOD_DOC)],
        );
        let d = check(&t);
        assert_eq!(d.len(), 1, "{d:?}");
        assert!(d[0].msg.contains("metrics().bogus"));
        assert_eq!(d[0].file, "rust/src/server.rs");
    }

    #[test]
    fn kind_mismatch_between_list_and_field_is_flagged() {
        let reg = "pub struct Metrics {\n\
                   pub depth: Gauge,\n\
                   }\n\
                   fn counters_list() {\n\
                   (\"depth\", self.depth.get()),\n\
                   }\n";
        let t = tree_of(
            &[
                (reg_path(), reg),
                (
                    "rust/src/server.rs",
                    "fn f() { metrics().depth.set(1); }\n",
                ),
            ],
            &[("docs/TELEMETRY.md", "| `depth` | gauge | d |\n")],
        );
        let d = check(&t);
        assert!(
            d.iter().any(|d| d.msg.contains("published from the")),
            "{d:?}"
        );
    }
}
