//! `env-knobs`: every `RTMA_*` environment variable the code reads
//! is documented, and every documented knob is live.
//!
//! Source side: any `RTMA_<NAME>` token inside a string literal in
//! `rust/src`, `rust/tests`, `rust/benches` or `examples`. Doc side:
//! any `RTMA_<NAME>` token in `docs/*.md` or `README.md`. Tokens
//! ending in `_` are prefix fragments (`RTMA_SERVE_*` family
//! references) and are skipped on both sides.

use crate::scan::{Diag, Tree};

const RULE: &str = "env-knobs";

pub fn check(tree: &Tree) -> Vec<Diag> {
    let mut out = Vec::new();

    // knob -> first site that mentions it
    let mut live: Vec<(String, String, usize)> = Vec::new();
    for s in &tree.sources {
        for (ln, line) in s.numbered() {
            for lit in &line.strings {
                for tok in tokens_in(lit) {
                    if !live.iter().any(|(t, _, _)| *t == tok) {
                        live.push((tok, s.rel.clone(), ln));
                    }
                }
            }
        }
    }
    let mut documented: Vec<(String, String, usize)> = Vec::new();
    for d in &tree.docs {
        for (ln, raw) in d.numbered() {
            for tok in tokens_in(raw) {
                if !documented.iter().any(|(t, _, _)| *t == tok) {
                    documented.push((tok, d.rel.clone(), ln));
                }
            }
        }
    }

    for (tok, file, ln) in &live {
        if !documented.iter().any(|(t, _, _)| t == tok) {
            out.push(Diag::new(
                RULE,
                file,
                *ln,
                format!(
                    "env knob `{tok}` is read here but documented \
                     nowhere (docs/*.md, README.md)"
                ),
            ));
        }
    }
    for (tok, file, ln) in &documented {
        if !live.iter().any(|(t, _, _)| t == tok) {
            out.push(Diag::new(
                RULE,
                file,
                *ln,
                format!(
                    "documented env knob `{tok}` has no live read in \
                     the source tree"
                ),
            ));
        }
    }
    out
}

/// Maximal `RTMA_[A-Z0-9_]+` tokens in `s`, skipping prefix
/// fragments that end in `_`.
fn tokens_in(s: &str) -> Vec<String> {
    let b = s.as_bytes();
    let mut v = Vec::new();
    let mut from = 0;
    while let Some(pos) = s[from..].find("RTMA_") {
        let at = from + pos;
        if at > 0 && is_tok(b[at - 1]) {
            from = at + 1;
            continue;
        }
        let mut end = at;
        while end < b.len() && is_tok(b[end]) {
            end += 1;
        }
        let tok = &s[at..end];
        if !tok.ends_with('_') {
            v.push(tok.to_string());
        }
        from = end;
    }
    v
}

fn is_tok(b: u8) -> bool {
    b.is_ascii_uppercase() || b.is_ascii_digit() || b == b'_'
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scan::tree_of;

    #[test]
    fn matched_knobs_pass() {
        let t = tree_of(
            &[(
                "rust/src/serve.rs",
                "let a = std::env::var(\"RTMA_SERVE_ADDR\");\n",
            )],
            &[("docs/SERVING.md", "Set `RTMA_SERVE_ADDR` to bind.\n")],
        );
        assert!(check(&t).is_empty(), "{:?}", check(&t));
    }

    #[test]
    fn undocumented_live_knob_is_flagged_at_the_read_site() {
        let t = tree_of(
            &[(
                "rust/src/serve.rs",
                "fn f() {}\nlet a = std::env::var(\"RTMA_SECRET\");\n",
            )],
            &[("docs/SERVING.md", "No knobs here.\n")],
        );
        let d = check(&t);
        assert_eq!(d.len(), 1, "{d:?}");
        assert_eq!(d[0].file, "rust/src/serve.rs");
        assert_eq!(d[0].line, 2);
        assert!(d[0].msg.contains("RTMA_SECRET"));
    }

    #[test]
    fn documented_dead_knob_is_flagged_at_the_doc_line() {
        let t = tree_of(
            &[("rust/src/serve.rs", "fn f() {}\n")],
            &[("docs/SERVING.md", "intro\nUse `RTMA_GHOST=1`.\n")],
        );
        let d = check(&t);
        assert_eq!(d.len(), 1, "{d:?}");
        assert_eq!(d[0].file, "docs/SERVING.md");
        assert_eq!(d[0].line, 2);
        assert!(d[0].msg.contains("RTMA_GHOST"));
    }

    #[test]
    fn prefix_fragments_and_comments_are_ignored() {
        // `RTMA_SERVE_*` in docs and a knob named only in a source
        // comment must not count on either side.
        let t = tree_of(
            &[(
                "rust/src/serve.rs",
                "// RTMA_IMAGINARY is described in a comment only\n\
                 let a = std::env::var(\"RTMA_SERVE_ADDR\");\n",
            )],
            &[(
                "docs/SERVING.md",
                "The `RTMA_SERVE_*` family: `RTMA_SERVE_ADDR`.\n",
            )],
        );
        assert!(check(&t).is_empty(), "{:?}", check(&t));
    }
}
