//! Fig 3 + §4.3.1: per-trainer training-loss curves for PSGD-PA vs
//! SuperTMA vs RandomTMA, and the cross-trainer loss discrepancy at
//! convergence. The paper's claim: the N = M min-cut scheme leaves
//! trainers converging to visibly different losses; randomized
//! (super-)node schemes make the curves coincide.
//!
//! Emits `results/fig3_<approach>_trainer<i>.csv` (EMA alpha = 0.1,
//! as the paper plots) and a discrepancy summary table.

use random_tma::benchkit::{best_variant, run_cell, BenchOpts};
use random_tma::config::Approach;
use random_tma::metrics::write_series_csv;
use random_tma::util::bench::Table;
use random_tma::util::stats::ema;

fn main() {
    let (opts, args) = BenchOpts::parse();
    let ds = args.str_or("dataset", "mag-sim");
    let preset = opts.preset(&ds, opts.base_seed).expect("preset");
    let variant = best_variant(&ds);

    let mut t = Table::new(
        &format!("Fig 3: per-trainer loss on {ds} ({variant})"),
        &["Approach", "loss discrepancy (std)", "final losses"],
    );
    for a in [
        Approach::PsgdPa,
        Approach::SuperTma { num_clusters: 0 },
        Approach::RandomTma,
    ] {
        let cell = run_cell(&opts, &preset, variant, a, |_| {}).expect("run");
        let r = &cell.results[0];
        let mut finals = Vec::new();
        for (i, tl) in r.trainer_losses.iter().enumerate() {
            let raw: Vec<f64> = tl.iter().map(|p| p.loss as f64).collect();
            let smooth = ema(&raw, 0.1);
            let series: Vec<(f64, f64)> = tl
                .iter()
                .zip(&smooth)
                .map(|(p, &s)| (p.t, s))
                .collect();
            let path = std::path::PathBuf::from(format!(
                "results/fig3_{}_trainer{}.csv",
                a.name().to_ascii_lowercase().replace('-', "_"),
                i
            ));
            write_series_csv(&path, "t_secs,loss_ema", &series).expect("csv");
            finals.push(*smooth.last().unwrap_or(&f64::NAN));
        }
        t.row(vec![
            a.name().to_string(),
            format!("{:.4}", r.loss_discrepancy()),
            finals
                .iter()
                .map(|l| format!("{l:.3}"))
                .collect::<Vec<_>>()
                .join(" / "),
        ]);
    }
    t.emit("fig3_loss_discrepancy");
}
