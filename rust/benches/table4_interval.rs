//! Table 4: ablation on the aggregation interval ρ.
//!
//! Paper: ρ ∈ {2, 8, 30} min of a 4 h budget; here {1/15, 1/30, 1/8}
//! of ΔT_train preserve the ratios. Expected shape: RandomTMA and
//! SuperTMA are insensitive to ρ; PSGD-PA/LLCG degrade markedly as ρ
//! grows (their min-cut partitions drift apart between syncs).

use random_tma::benchkit::{best_variant, run_cell, BenchOpts};
use random_tma::config::Approach;
use random_tma::util::bench::Table;

fn main() {
    let (opts, args) = BenchOpts::parse();
    let datasets: Vec<String> = args
        .str_or("datasets", "reddit-sim")
        .split(',')
        .map(String::from)
        .collect();
    // Paper ratio rho/T_train: 2/240, 8/240, 30/240.
    let rhos: Vec<f64> = [2.0, 8.0, 30.0]
        .iter()
        .map(|m| (m / 240.0) * opts.train_secs)
        .collect();

    let mut t = Table::new(
        "Table 4: varying aggregation interval ρ (test MRR % / conv s)",
        &["Dataset", "Approach", "ρ=2' eq", "ρ=8' eq", "ρ=30' eq"],
    );
    for ds in &datasets {
        let preset = opts.preset(ds, opts.base_seed).expect("preset");
        let variant = best_variant(ds);
        for a in [
            Approach::RandomTma,
            Approach::SuperTma { num_clusters: 0 },
            Approach::PsgdPa,
            Approach::Llcg { correction_steps: 4 },
        ] {
            let mut cells = Vec::new();
            for &rho in &rhos {
                let cell = run_cell(&opts, &preset, variant, a, |cfg| {
                    cfg.agg_secs = rho;
                })
                .expect("run");
                cells.push(format!(
                    "{} / {}",
                    cell.mrr_str(),
                    cell.conv_str()
                ));
            }
            t.row(vec![
                ds.clone(),
                a.name().to_string(),
                cells[0].clone(),
                cells[1].clone(),
                cells[2].clone(),
            ]);
        }
    }
    t.emit("table4_interval");
}
