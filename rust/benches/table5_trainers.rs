//! Table 5: ablation on the number of trainers M.
//!
//! Paper: M ∈ {3, 5, 23} (23 = all GPUs minus the evaluator). On one
//! time-shared core we use {3, 5, 8} — threads beyond the core count
//! only shrink each trainer's share, which is exactly the effect under
//! study (less data per trainer). Expected shape: RandomTMA peaks at
//! moderate M then drops (r = 1/M data loss); SuperTMA keeps improving
//! or holds (clusters preserve local edges); PSGD-PA/LLCG stay behind.

use random_tma::benchkit::{best_variant, run_cell, BenchOpts};
use random_tma::config::Approach;
use random_tma::util::bench::Table;

fn main() {
    let (opts, args) = BenchOpts::parse();
    let datasets: Vec<String> = args
        .str_or("datasets", "ecomm-sim")
        .split(',')
        .map(String::from)
        .collect();
    let ms: Vec<usize> = args
        .str_or("ms", "3,5,8")
        .split(',')
        .map(|x| x.parse().unwrap())
        .collect();

    let mut header = vec!["Dataset".to_string(), "Approach".to_string()];
    for m in &ms {
        header.push(format!("r M={m}"));
        header.push(format!("MRR M={m}"));
    }
    let href: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new("Table 5: varying number of trainers M", &href);

    for ds in &datasets {
        let preset = opts.preset(ds, opts.base_seed).expect("preset");
        let variant = best_variant(ds);
        for a in [
            Approach::RandomTma,
            Approach::SuperTma { num_clusters: 0 },
            Approach::PsgdPa,
            Approach::Llcg { correction_steps: 4 },
        ] {
            let mut row = vec![ds.clone(), a.name().to_string()];
            for &m in &ms {
                let cell = run_cell(&opts, &preset, variant, a, |cfg| {
                    cfg.trainers = m;
                })
                .expect("run");
                row.push(format!("{:.2}", cell.ratio_r));
                row.push(cell.mrr_str());
            }
            t.row(row);
        }
    }
    t.emit("table5_trainers");
}
