//! Table 6: robustness to trainer failures — F=1 of M=3 trainers
//! never starts and its subgraph is lost; training proceeds on the
//! remaining two. As in the paper, we run M sub-runs per seed dropping
//! a different partition each time and average.
//!
//! Expected shape: RandomTMA/SuperTMA lose <~0.5 MRR points (any
//! random third of the data looks like the rest); PSGD-PA/LLCG lose
//! much more with higher variance (an entire min-cut community
//! disappears).

use random_tma::benchkit::{best_variant, run_cell, BenchOpts};
use random_tma::config::Approach;
use random_tma::util::bench::Table;
use random_tma::util::stats;

fn main() {
    let (opts, args) = BenchOpts::parse();
    let ds = args.str_or("dataset", "mag-sim");
    let m = args.usize_or("m", 3);
    let preset = opts.preset(&ds, opts.base_seed).expect("preset");
    let variant = best_variant(&ds);

    let mut t = Table::new(
        &format!("Table 6: failure robustness on {ds} (F=1 of M={m})"),
        &[
            "Approach",
            "MRR F=1",
            "MRR F=0",
            "ΔMRR",
            "Conv F=1",
            "Conv F=0",
            "Live F=1",
        ],
    );
    for a in [
        Approach::RandomTma,
        Approach::SuperTma { num_clusters: 0 },
        Approach::PsgdPa,
        Approach::Llcg { correction_steps: 4 },
    ] {
        // Baseline F=0.
        let base = run_cell(&opts, &preset, variant, a, |cfg| {
            cfg.trainers = m;
        })
        .expect("run");
        // F=1: drop each partition in turn under the same assignment.
        let mut mrr_f1 = Vec::new();
        let mut conv_f1 = Vec::new();
        let mut live_f1 = Vec::new();
        for dropped in 0..m {
            let cell = run_cell(&opts, &preset, variant, a, |cfg| {
                cfg.trainers = m;
                cfg.failures = 1;
                cfg.failed_ids = vec![dropped];
            })
            .expect("run");
            mrr_f1.push(cell.mean_mrr());
            conv_f1.push(cell.mean_conv());
            // Authoritative survivor count (Control::live_count via
            // RunResult), not this bench's own bookkeeping.
            live_f1.push(cell.mean_live());
        }
        t.row(vec![
            a.name().to_string(),
            stats::fmt_mean_std(&mrr_f1, 2),
            base.mrr_str(),
            format!("{:+.2}", stats::mean(&mrr_f1) - base.mean_mrr()),
            stats::fmt_mean_std(&conv_f1, 1),
            base.conv_str(),
            format!("{:.1}/{m}", stats::mean(&live_f1)),
        ]);
    }
    t.emit("table6_failure");
}
