//! Fig 2: validation MRR vs training time per approach on the
//! citation benchmark (best encoder). Emits one CSV series per
//! approach under `results/fig2_<approach>.csv` and prints the
//! convergence summary.

use random_tma::benchkit::{best_variant, run_cell, BenchOpts};
use random_tma::config::Approach;
use random_tma::metrics::write_series_csv;
use random_tma::util::bench::Table;

fn main() {
    let (opts, args) = BenchOpts::parse();
    let ds = args.str_or("dataset", "citation-sim");
    let preset = opts.preset(&ds, opts.base_seed).expect("preset");
    let variant = best_variant(&ds);

    let mut t = Table::new(
        &format!("Fig 2: val-MRR-vs-time on {ds} ({variant})"),
        &["Approach", "best val MRR", "Conv(s)", "points"],
    );
    for a in Approach::all(0) {
        let cell = run_cell(&opts, &preset, variant, a, |_| {}).expect("run");
        let r = &cell.results[0];
        let series: Vec<(f64, f64)> =
            r.val_curve.iter().map(|p| (p.t, p.val_mrr)).collect();
        let path = std::path::PathBuf::from(format!(
            "results/fig2_{}.csv",
            a.name().to_ascii_lowercase().replace('-', "_")
        ));
        write_series_csv(&path, "t_secs,val_mrr", &series).expect("csv");
        t.row(vec![
            a.name().to_string(),
            format!("{:.4}", r.best_val_mrr),
            format!("{:.1}", r.convergence_secs(0.01)),
            series.len().to_string(),
        ]);
    }
    t.emit("fig2_convergence");
}
