//! Table 8: heterogeneous (E-comm) ablation — GCN vs RGCN encoders ×
//! MLP vs DistMult decoders across the training approaches.
//!
//! Expected shape (the paper's "surprising" finding): plain GCN with
//! the MLP decoder, which ignores edge types entirely, beats the
//! relation-aware RGCN variants; DistMult trails the MLP decoder.

use random_tma::benchkit::{run_cell, BenchOpts};
use random_tma::config::Approach;
use random_tma::util::bench::Table;

fn main() {
    let (opts, args) = BenchOpts::parse();
    let ds = args.str_or("dataset", "ecomm-sim");
    let preset = opts.preset(&ds, opts.base_seed).expect("preset");
    let variants = [
        ("gcn_mlp", "GCN-M"),
        ("gcn_distmult", "GCN-D"),
        ("rgcn_mlp", "RGCN-M"),
        ("rgcn_distmult", "RGCN-D"),
    ];

    let mut t = Table::new(
        &format!("Table 8: heterogeneous ablation on {ds} (test MRR %)"),
        &["Approach", "r", "GCN-M", "GCN-D", "RGCN-M", "RGCN-D"],
    );
    for a in Approach::all(0) {
        let mut cells = Vec::new();
        let mut ratio = 0.0;
        for (variant, _) in variants {
            let cell =
                run_cell(&opts, &preset, variant, a, |_| {}).expect("run");
            ratio = cell.ratio_r;
            cells.push(cell.mrr_str());
        }
        t.row(vec![
            a.name().to_string(),
            format!("{ratio:.2}"),
            cells[0].clone(),
            cells[1].clone(),
            cells[2].clone(),
            cells[3].clone(),
        ]);
    }
    t.emit("table8_hetero");
}
