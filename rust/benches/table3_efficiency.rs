//! Table 3: efficiency — local-data memory, convergence time, and the
//! min/max/diff of training steps finished per trainer.
//!
//! Expected shape (paper): TMA approaches finish several times more
//! steps on the slowest trainer than GGS (whose every step is gated by
//! the slowest trainer), and the per-trainer step spread under TMA
//! reflects the injected heterogeneity (~up to 28.8% in the paper)
//! while GGS's spread is 0 by construction. RandomTMA holds the least
//! local data.

use random_tma::benchkit::{best_variant, run_cell, BenchOpts};
use random_tma::config::Approach;
use random_tma::util::bench::Table;

fn main() {
    let (opts, args) = BenchOpts::parse();
    let ds = args.str_or("dataset", "mag-sim");
    let preset = opts.preset(&ds, opts.base_seed).expect("preset");
    let variant = best_variant(&ds);
    let slowdown = vec![1.0, 1.15, 1.3];

    let mut t = Table::new(
        &format!("Table 3: efficiency on {ds} ({variant})"),
        &["Approach", "r", "LocalMB", "Conv(s)", "StepsMin", "StepsMax",
          "Diff%"],
    );
    for a in Approach::all(0) {
        let cell = run_cell(&opts, &preset, variant, a, |cfg| {
            cfg.slowdown = slowdown.clone();
        })
        .expect("run");
        let r = &cell.results[0];
        let (min, max, diff) = r.step_spread();
        t.row(vec![
            a.name().to_string(),
            format!("{:.2}", cell.ratio_r),
            format!("{:.1}", r.local_bytes as f64 / 1e6),
            cell.conv_str(),
            min.to_string(),
            max.to_string(),
            format!("{:.1}", diff * 100.0),
        ]);
    }
    t.emit("table3_efficiency");
}
