//! Perf probe: per-component latency of the training hot path.
use random_tma::gen::{dcsbm, DcsbmConfig};
use random_tma::model::ModelState;
use random_tma::runtime::{Engine, Manifest};
use random_tma::sampler::{AdjMode, TrainSampler, TrainSamplerConfig};
use random_tma::util::bench::{fmt_secs, time};
use random_tma::util::rng::Rng;

fn main() {
    let manifest = Manifest::load(&Manifest::default_dir()).expect("artifacts");
    let g = dcsbm(&DcsbmConfig {
        nodes: 5000, communities: 10, avg_degree: 12.0, homophily: 0.8,
        feat_dim: 64, feature_noise: 0.5, degree_exponent: 0.8, seed: 1,
    });
    let globals: Vec<u32> = (0..g.num_nodes() as u32).collect();
    for (variant, encoder, impl_name) in [
        ("gcn_mlp", "gcn", "pallas"), ("gcn_mlp", "gcn", "jnp"),
        ("sage_mlp", "sage", "pallas"), ("sage_mlp", "sage", "jnp"),
        ("mlp_mlp", "mlp", "jnp"),
    ] {
        let t0 = std::time::Instant::now();
        let engine = Engine::load(&manifest, variant, impl_name).unwrap();
        let compile_s = t0.elapsed().as_secs_f64();
        let cfg = TrainSamplerConfig {
            block_nodes: manifest.dims.block_nodes,
            block_edges: manifest.dims.block_edges,
            feat_dim: manifest.dims.feat_dim,
            fanouts: vec![10, 5],
            adj_mode: AdjMode::for_encoder(encoder),
            relations: 1, boundary: 0,
        };
        let mut sampler = TrainSampler::new(g.clone(), globals.clone(), cfg);
        let mut rng = Rng::new(2);
        let mut state = ModelState::init(&engine.variant, &mut rng);
        let t_sample = time("sample", 2, 10, || {
            sampler.next_block(&mut rng);
        });
        let block = sampler.next_block(&mut rng).unwrap().clone();
        let t_step = time("train_step", 1, 5, || {
            engine.train_step(&mut state, &block).unwrap();
        });
        let t_enc = time("encode", 1, 5, || {
            engine.encode(&state.params, &block).unwrap();
        });
        println!(
            "{variant:10} {impl_name:6} compile {:6.1}s  sample {}  step {}  encode {}",
            compile_s,
            fmt_secs(t_sample.median_s()),
            fmt_secs(t_step.median_s()),
            fmt_secs(t_enc.median_s()),
        );
    }
}
