//! Perf probe: dataset generation throughput, prep-path (partition →
//! subgraph) throughput, aggregation round data plane, comm encode
//! throughput, per-entry latency of the native compute engine, and
//! the round-codec ablation (MRR vs bytes-per-round).
//! No section needs AOT artifacts — the engine section times the
//! native backend on the builtin manifest. Sections persist their
//! numbers as `results/BENCH_<section>.json` baselines (generation,
//! prep, aggregation, perf_hotpath, engine, codec) which CI uploads
//! as artifacts.
//!
//! Positional args filter sections by substring, e.g.
//! `cargo bench --bench perf_hotpath -- engine` runs only
//! `engine_path`.

use std::hint::black_box;
use std::sync::Arc;

use random_tma::benchkit::BenchBaseline;
use random_tma::comm::Message;
use random_tma::config::{Approach, RunConfig};
use random_tma::coordinator::driver::run_on_preset;
use random_tma::gen::{
    dcsbm, dcsbm_with_workers, load_preset, reference, DcsbmConfig,
};
use random_tma::graph::{induce_all, Subgraph};
use random_tma::model::{aggregate, AggregateOp, MeanAccum, ModelState};
use random_tma::partition::{
    partition_stats, partition_stats_with_cuts, parts_of, random_partition,
};
use random_tma::runtime::{Manifest, NativeEngine};
use random_tma::sampler::{AdjMode, TrainSampler, TrainSamplerConfig};
use random_tma::telemetry::{self, metrics, Level, Span};
use random_tma::util::bench::{fmt_secs, time, Timing};
use random_tma::util::rng::Rng;

fn main() {
    let filters: Vec<String> = std::env::args()
        .skip(1)
        .filter(|a| !a.starts_with('-'))
        .collect();
    let want = |section: &str| {
        filters.is_empty()
            || filters.iter().any(|f| section.contains(f.as_str()))
    };
    if want("generation") {
        generation_path();
    }
    if want("prep") {
        prep_path();
        prep_feature_store();
    }
    if want("aggregation") {
        aggregation_path();
    }
    if want("comm") {
        comm_encode();
    }
    if want("telemetry") {
        telemetry_overhead();
    }
    if want("engine") {
        engine_path();
    }
    if want("codec") {
        codec_ablation();
    }
}

/// Dataset generation at mag-sim scale (120k nodes, avg degree 12):
/// the serial `GraphBuilder` reference (one global RNG stream plus an
/// O(E log E) build-time re-sort) vs the parallel count-then-fill
/// generator at 1/2/8 workers. Target: >= 4x at 8 workers on real
/// hardware (this is the cost of regenerating a cached preset, and
/// the scaling knob for billion-edge datasets).
fn generation_path() {
    let cfg = DcsbmConfig {
        nodes: 120_000,
        communities: 150,
        avg_degree: 12.0,
        homophily: 0.8,
        feat_dim: 64,
        feature_noise: 0.7,
        degree_exponent: 1.1,
        seed: 1,
    };
    let mut bench = BenchBaseline::new("generation");
    let t_ref = time("dcsbm serial (GraphBuilder reference)", 1, 3, || {
        black_box(reference::dcsbm_serial(&cfg));
    });
    bench.push_timing(&t_ref);
    let mut at_8 = f64::INFINITY;
    for workers in [1usize, 2, 8] {
        let t = time(
            &format!("dcsbm parallel count-then-fill w={workers}"),
            1,
            3,
            || {
                black_box(dcsbm_with_workers(&cfg, workers));
            },
        );
        bench.push_timing(&t);
        if workers == 8 {
            at_8 = t.median_s();
        }
        println!(
            "gen |V|=120k d=64: serial {}  parallel(w={workers}) {}  \
             ({:.1}x)",
            fmt_secs(t_ref.median_s()),
            fmt_secs(t.median_s()),
            t_ref.median_s() / t.median_s().max(1e-12),
        );
    }
    let speedup_at_8 = t_ref.median_s() / at_8.max(1e-12);
    println!("gen speedup at 8 workers: {speedup_at_8:.1}x (target >= 4x)");
    // Record-only baseline (no assert: CI runners have few cores);
    // the speedup lands next to the timings in BENCH_generation.json.
    bench.push_counter("speedup_at_8", speedup_at_8);
    let path = bench.write().expect("write generation bench baseline");
    println!("generation bench baseline -> {}", path.display());
}

/// Partition→subgraph extraction at mag-sim scale (120k nodes, M=8):
/// the serial per-part HashMap path vs the fused parallel
/// `induce_all`, and `partition_stats` with vs without its own edge
/// scan. This is the Table 3 / Table 7 prep column.
fn prep_path() {
    let g = dcsbm(&DcsbmConfig {
        nodes: 120_000,
        communities: 150,
        avg_degree: 12.0,
        homophily: 0.8,
        feat_dim: 64,
        feature_noise: 0.7,
        degree_exponent: 1.1,
        seed: 1,
    });
    let m = 8;
    let mut rng = Rng::new(2);
    let assign = random_partition(g.num_nodes(), m, &mut rng);
    let parts = parts_of(&assign, m);

    let t_serial = time("induce serial (HashMap reference)", 1, 3, || {
        for p in &parts {
            black_box(Subgraph::induce(&g, p));
        }
    });
    let t_fused = time("induce_all (fused parallel)", 1, 3, || {
        black_box(induce_all(&g, &assign, m));
    });
    let cuts: Vec<usize> = induce_all(&g, &assign, m)
        .iter()
        .map(|s| s.cut_edges)
        .collect();
    let t_scan = time("partition_stats (edge scan)", 1, 3, || {
        black_box(partition_stats(&g, &assign, m));
    });
    let t_reuse = time("partition_stats_with_cuts", 1, 3, || {
        black_box(partition_stats_with_cuts(&g, &assign, m, &cuts));
    });
    println!(
        "prep |V|={} |E|={} M={m}: serial {}  fused {}  ({:.1}x)",
        g.num_nodes(),
        g.num_edges(),
        fmt_secs(t_serial.median_s()),
        fmt_secs(t_fused.median_s()),
        t_serial.median_s() / t_fused.median_s().max(1e-12),
    );
    println!(
        "stats: edge scan {}  cut reuse {}  ({:.1}x)",
        fmt_secs(t_scan.median_s()),
        fmt_secs(t_reuse.median_s()),
        t_scan.median_s() / t_reuse.median_s().max(1e-12),
    );
    // Record-only baseline: prep timings + speedups, BENCH_prep.json.
    let mut bench = BenchBaseline::new("prep");
    bench.push_timing(&t_serial);
    bench.push_timing(&t_fused);
    bench.push_timing(&t_scan);
    bench.push_timing(&t_reuse);
    bench.push_counter(
        "induce_speedup",
        t_serial.median_s() / t_fused.median_s().max(1e-12),
    );
    bench.push_counter(
        "stats_cut_reuse_speedup",
        t_scan.median_s() / t_reuse.median_s().max(1e-12),
    );
    let path = bench.write().expect("write prep bench baseline");
    println!("prep bench baseline -> {}", path.display());
}

/// Feature-store prep at high feature width: `induce_all` over an
/// Owned parent (per-trainer slab copies — the pre-FeatureStore
/// behaviour) vs a Shared parent (index-only views over one Arc'd
/// slab). At d=256 the copy is the dominant prep cost the refactor
/// removes; the byte counters show what each trainer privately holds.
fn prep_feature_store() {
    let g = dcsbm(&DcsbmConfig {
        nodes: 60_000,
        communities: 100,
        avg_degree: 10.0,
        homophily: 0.8,
        feat_dim: 256,
        feature_noise: 0.6,
        degree_exponent: 0.9,
        seed: 3,
    });
    let m = 8;
    let mut rng = Rng::new(4);
    let assign = random_partition(g.num_nodes(), m, &mut rng);
    let owned = {
        let mut h = g.clone();
        h.features = h.features.to_vec(h.feat_dim).into();
        h
    };

    let t_copied = time("induce_all d=256 (Owned: copy slabs)", 1, 3, || {
        black_box(induce_all(&owned, &assign, m));
    });
    let t_shared = time("induce_all d=256 (Shared: zero-copy)", 1, 3, || {
        black_box(induce_all(&g, &assign, m));
    });
    let feat_bytes = |subs: &[random_tma::graph::Subgraph]| -> usize {
        subs.iter().map(|s| s.graph.features.heap_bytes()).sum()
    };
    let copied_bytes = feat_bytes(&induce_all(&owned, &assign, m));
    let shared_bytes = feat_bytes(&induce_all(&g, &assign, m));
    println!(
        "feature store |V|={} d=256 M={m}: copied {}  shared {}  ({:.1}x); \
         private feature bytes {:.1} MB -> {:.1} MB",
        g.num_nodes(),
        fmt_secs(t_copied.median_s()),
        fmt_secs(t_shared.median_s()),
        t_copied.median_s() / t_shared.median_s().max(1e-12),
        copied_bytes as f64 / 1e6,
        shared_bytes as f64 / 1e6,
    );
}

/// The aggregation round data plane at ~1M parameters, M ∈ {4,16,64}:
/// the staged reference (hold all M weight vectors until the round
/// completes, reduce, then clone the result once per trainer for
/// broadcast) vs the streaming fold (each vector folded into one
/// pre-sized [`MeanAccum`] as it arrives, one shared `Arc` broadcast).
///
/// Bytes per round on the server: staged holds M staged vectors + the
/// reduce output + M broadcast clones = (2M+1)·P·4; streaming holds
/// the accumulator + the one in-flight message + the output
/// = 3·P·4 — O(P), independent of M (target ≥ 3x fewer bytes at
/// M=4, growing linearly with M). The wall-clock win at M=64 is
/// dominated by the M elided broadcast memcpys.
fn aggregation_path() {
    let p = 1 << 20;
    let mut rng = Rng::new(9);
    let base: Vec<f32> = (0..p).map(|_| rng.gaussian() as f32).collect();
    let mut bench = BenchBaseline::new("aggregation");
    for m in [4usize, 16, 64] {
        // The per-trainer round snapshots (trainer-side allocations —
        // identical for both paths; the server-side handling differs).
        let msgs: Vec<Vec<f32>> = (0..m)
            .map(|i| base.iter().map(|x| x + i as f32).collect())
            .collect();
        let losses = vec![0.0f32; m];
        let t_staged =
            time(&format!("agg staged M={m}"), 1, 3, || {
                let out = aggregate(AggregateOp::Mean, &msgs, &losses);
                for _ in 0..m {
                    black_box(out.clone()); // per-trainer broadcast clone
                }
                black_box(out);
            });
        let t_stream =
            time(&format!("agg streaming M={m}"), 1, 3, || {
                let mut acc = MeanAccum::new(p);
                for w in &msgs {
                    acc.add(w);
                }
                let out: Arc<[f32]> = acc.mean().into();
                for _ in 0..m {
                    black_box(out.clone()); // Arc bump per trainer
                }
                black_box(out);
            });
        let staged_bytes = (2 * m + 1) * p * 4;
        let stream_bytes = 3 * p * 4;
        println!(
            "agg P=1M M={m}: staged {}  streaming {}  ({:.1}x); \
             round bytes {:.1} MB -> {:.1} MB ({:.1}x, target >= 3x)",
            fmt_secs(t_staged.median_s()),
            fmt_secs(t_stream.median_s()),
            t_staged.median_s() / t_stream.median_s().max(1e-12),
            staged_bytes as f64 / 1e6,
            stream_bytes as f64 / 1e6,
            staged_bytes as f64 / stream_bytes as f64,
        );
        bench.push_timing(&t_staged);
        bench.push_timing(&t_stream);
        bench.push_counter(
            &format!("speedup_m{m}"),
            t_staged.median_s() / t_stream.median_s().max(1e-12),
        );
        bench.push_counter(
            &format!("bytes_ratio_m{m}"),
            staged_bytes as f64 / stream_bytes as f64,
        );
    }
    // Record-only baseline, BENCH_aggregation.json.
    let path = bench.write().expect("write aggregation bench baseline");
    println!("aggregation bench baseline -> {}", path.display());
}

/// Wire-protocol encode of a realistic (1M-parameter) weight vector.
fn comm_encode() {
    let msg = Message::Weights {
        round: 1,
        loss: 0.5,
        steps: 1,
        data: (0..1 << 20).map(|i| i as f32).collect(),
    };
    let t = time("comm encode 1M f32", 1, 5, || {
        black_box(msg.encode());
    });
    println!("comm: encode 1M-f32 Weights {}", fmt_secs(t.median_s()));
}

/// Telemetry overhead on the round data plane: the streaming fold
/// with exactly the per-message instrumentation the server performs
/// (counter bumps + one phase span) vs the bare fold. Contract
/// (ISSUE 6): with logging off and no trace sink, telemetry is
/// relaxed atomic bumps only — no allocation, no formatting — so the
/// instrumented path must stay within 3% of the bare one. Compared
/// on best-of-N to shed scheduler noise; persisted as the
/// `BENCH_perf_hotpath.json` baseline.
fn telemetry_overhead() {
    telemetry::set_level(Level::Off);
    let p = 1 << 20;
    let m = 8usize;
    let mut rng = Rng::new(11);
    let base: Vec<f32> = (0..p).map(|_| rng.gaussian() as f32).collect();
    let msgs: Vec<Vec<f32>> = (0..m)
        .map(|i| base.iter().map(|x| x + i as f32).collect())
        .collect();

    let t_plain = time("fold plain M=8 P=1M", 1, 7, || {
        let mut acc = MeanAccum::new(p);
        for w in &msgs {
            acc.add(w);
        }
        black_box(acc.mean());
    });
    let t_instr = time("fold instrumented M=8 P=1M", 1, 7, || {
        let mm = metrics();
        let _sp =
            Span::start("bench", "collect").hist(&mm.phase_collect);
        let mut acc = MeanAccum::new(p);
        for w in &msgs {
            mm.round_msgs.inc();
            mm.comm_frames_in.inc();
            mm.comm_bytes_in.add((4 + w.len() * 4) as u64);
            acc.add(w);
        }
        black_box(acc.mean());
    });
    let min_s = |t: &Timing| {
        t.samples.iter().copied().fold(f64::INFINITY, f64::min)
    };
    let ratio = min_s(&t_instr) / min_s(&t_plain).max(1e-12);
    println!(
        "telemetry off: plain {}  instrumented {}  overhead {:.2}% \
         (budget 3%)",
        fmt_secs(t_plain.median_s()),
        fmt_secs(t_instr.median_s()),
        (ratio - 1.0) * 100.0,
    );
    assert!(
        ratio <= 1.03,
        "telemetry-off overhead {:.2}% exceeds the 3% budget",
        (ratio - 1.0) * 100.0
    );

    let mut bench = BenchBaseline::new("perf_hotpath");
    bench.push_timing(&t_plain);
    bench.push_timing(&t_instr);
    bench.push_counter("telemetry_overhead_ratio", ratio);
    let path = bench.write().expect("write bench baseline");
    println!("bench baseline -> {}", path.display());
}

/// Per-entry latency of the native engine on the builtin manifest —
/// runs on a bare checkout (no artifacts, no PJRT). Persists the
/// per-variant sample/step/encode timings as the `engine` bench
/// baseline (`results/BENCH_engine.json`).
fn engine_path() {
    let manifest = Manifest::builtin();
    let g = dcsbm(&DcsbmConfig {
        nodes: 5000, communities: 10, avg_degree: 12.0, homophily: 0.8,
        feat_dim: 64, feature_noise: 0.5, degree_exponent: 0.8, seed: 1,
    });
    let globals: Vec<u32> = (0..g.num_nodes() as u32).collect();
    let mut bench = BenchBaseline::new("engine");
    for (variant, encoder) in
        [("gcn_mlp", "gcn"), ("sage_mlp", "sage"), ("mlp_mlp", "mlp")]
    {
        let t0 = std::time::Instant::now();
        let engine = NativeEngine::new(&manifest, variant).unwrap();
        let load_s = t0.elapsed().as_secs_f64();
        let cfg = TrainSamplerConfig {
            block_nodes: manifest.dims.block_nodes,
            block_edges: manifest.dims.block_edges,
            feat_dim: manifest.dims.feat_dim,
            fanouts: vec![10, 5],
            adj_mode: AdjMode::for_encoder(encoder),
            relations: 1, boundary: 0,
        };
        let mut sampler = TrainSampler::new(g.clone(), globals.clone(), cfg);
        let mut rng = Rng::new(2);
        let mut state = ModelState::init(&engine.variant, &mut rng);
        let t_sample = time(&format!("{variant}_sample"), 2, 10, || {
            sampler.next_block(&mut rng);
        });
        let block = sampler.next_block(&mut rng).unwrap().clone();
        let t_step = time(&format!("{variant}_train_step"), 1, 5, || {
            engine.train_step(&mut state, &block).unwrap();
        });
        let t_enc = time(&format!("{variant}_encode"), 1, 5, || {
            engine.encode(&state.params, &block).unwrap();
        });
        println!(
            "{variant:10} native load {:6.3}s  sample {}  step {}  encode {}",
            load_s,
            fmt_secs(t_sample.median_s()),
            fmt_secs(t_step.median_s()),
            fmt_secs(t_enc.median_s()),
        );
        bench.push_timing(&t_sample);
        bench.push_timing(&t_step);
        bench.push_timing(&t_enc);
    }
    let path = bench.write().expect("write engine bench baseline");
    let back = BenchBaseline::read("engine").expect("read engine baseline");
    assert!(back == bench, "engine baseline failed schema round-trip");
    println!("engine bench baseline -> {}", path.display());
}

/// Round-codec ablation on the mag-sim quick preset: validation MRR
/// and round bytes at M ∈ {4,16,64} for identity vs topk vs i8.
///
/// The compression ratio is `codec_bytes_raw / codec_bytes_encoded`
/// over the whole run — every encode op adds the 4·P dense bytes it
/// *would* have shipped to `raw` and the body it *did* ship to
/// `encoded`, across both the M upstream legs and the downstream
/// broadcast — so the ratio is exactly the round-traffic reduction
/// vs the identity wire. Acceptance (pinned here, persisted as
/// `BENCH_codec.json`): at least one non-identity codec reaches a
/// ≥ 4x byte reduction at equal (± 0.01) validation MRR.
fn codec_ablation() {
    // The env override would silently retarget every cell.
    std::env::remove_var("RTMA_CODEC");
    let preset =
        load_preset("mag-sim", true, 64, 32, 5).expect("mag-sim preset");
    let manifest = Manifest::builtin();
    let variant = manifest.variant("gcn_mlp").expect("builtin variant");
    let p = ModelState::init(variant, &mut Rng::new(1)).params.len();

    let mut bench = BenchBaseline::new("codec");
    // (m, codec, mrr, ratio)
    let mut cells: Vec<(usize, &str, f64, f64)> = Vec::new();
    for m in [4usize, 16, 64] {
        for codec in ["identity", "topk", "i8"] {
            let cfg = RunConfig {
                dataset: "mag-sim".into(),
                quick: true,
                approach: Approach::RandomTma,
                trainers: m,
                train_secs: 4.0,
                agg_secs: 1.0,
                codec: codec.into(),
                seed: 5,
                ..RunConfig::default()
            };
            let res =
                run_on_preset(&cfg, &preset).expect("codec ablation run");
            let rounds =
                res.telemetry.counter("rounds_opened").max(1) as f64;
            let raw = res.telemetry.counter("codec_bytes_raw") as f64;
            let enc = res.telemetry.counter("codec_bytes_encoded") as f64;
            // identity skips the codec layer entirely: its round bytes
            // are the dense frames, ratio 1 by definition.
            let (ratio, bytes_per_round) = if enc > 0.0 {
                (raw / enc, enc / rounds)
            } else {
                (1.0, ((m + 1) * p * 4) as f64)
            };
            let mrr = res.best_val_mrr;
            println!(
                "codec M={m:2} {codec:8}: val MRR {mrr:.4}  \
                 {bytes_per_round:>12.0} B/round  ({ratio:.1}x vs dense)",
            );
            bench.push_counter(&format!("mrr_m{m}_{codec}"), mrr);
            bench.push_counter(&format!("ratio_m{m}_{codec}"), ratio);
            bench.push_counter(
                &format!("bytes_per_round_m{m}_{codec}"),
                bytes_per_round,
            );
            cells.push((m, codec, mrr, ratio));
        }
    }

    // Acceptance: ≥ 1 non-identity codec with ≥ 4x fewer round bytes
    // at equal (± 0.01) MRR against identity at the same M.
    let ok = cells.iter().any(|&(m, codec, mrr, ratio)| {
        if codec == "identity" || ratio < 4.0 {
            return false;
        }
        cells
            .iter()
            .find(|&&(m2, c2, _, _)| m2 == m && c2 == "identity")
            .is_some_and(|&(_, _, id_mrr, _)| (mrr - id_mrr).abs() <= 0.01)
    });
    assert!(
        ok,
        "no non-identity codec reached a >=4x byte reduction at equal \
         (+-0.01) MRR: {cells:?}"
    );

    let path = bench.write().expect("write codec bench baseline");
    let back = BenchBaseline::read("codec").expect("read codec baseline");
    assert!(back == bench, "codec baseline failed schema round-trip");
    println!("codec bench baseline -> {}", path.display());
}
