//! Theory validation: Lemma 1, Theorem 2 and Corollary 3 measured on
//! the exact 2-class compatibility model they are stated for.
//!
//! 1. **Lemma 1 / Eq. (2)** — expected edge-cut λ(β) of a balanced
//!    2-partition whose class-purity is β:
//!        λ(β) = (1 − 2(1−β)β − (2β−1)² h) · η²/C
//!    normalised here to the cut *fraction* λ(β)/λ(0.5) =
//!    1 − (2β−1)²(2h−1). Monte-Carlo cut fractions on sampled SBM
//!    graphs must match, with the minimum at β = 1 (class-pure parts).
//! 2. **Thm 2 (1)** — closed-form initial-gradient discrepancies
//!    ‖E∇L_i^local − E∇L^global‖ must grow with ‖C₂−C₁‖ = √2|1−2β|,
//!    and vanish at β = 0.5.
//! 3. **Cor 3** — under random partition, measured ‖C₂−C₁‖ ≈ 0 and the
//!    min-cut partitioner instead drives it toward √2.

use random_tma::gen::{sbm2, Sbm2Config};
use random_tma::graph::stats::{class_distribution, l2_distance};
use random_tma::partition::{
    metis_like, partition_stats, random_partition, MetisConfig,
};
use random_tma::util::bench::Table;
use random_tma::util::cli::Args;
use random_tma::util::rng::Rng;

/// Closed-form expected cut *fraction* for purity β (Eq. 2 without the
/// η²/C scale — the bracket is already the per-edge crossing
/// probability: h·2β(1−β) + (1−h)(1−2β(1−β))).
fn cut_fraction(beta: f64, h: f64) -> f64 {
    let q = 2.0 * beta - 1.0;
    1.0 - 2.0 * (1.0 - beta) * beta - q * q * h
}

/// Thm 2 closed forms for the initial-gradient discrepancies.
fn grad_discrepancies(beta: f64, h: f64) -> (f64, f64, f64) {
    let s2 = 2f64.sqrt();
    let g_l1 = s2 / 8.0
        * ((1.0 - 2.0 * beta) * (h - 1.0) * h
            / (beta - 2.0 * beta * h + h))
            .abs();
    let g_l2 = s2 / 8.0
        * ((2.0 * beta - 1.0) * (h - 1.0) * h
            / (1.0 - beta + (2.0 * beta - 1.0) * h))
            .abs();
    let l1_l2 = ((1.0 / (4.0 * s2)) * (2.0 * beta - 1.0) * (h - 1.0) * h
        / ((beta - 2.0 * beta * h + h - 1.0)
            * (beta - 2.0 * beta * h + h)))
        .abs();
    (g_l1, g_l2, l1_l2)
}

fn main() {
    let args = Args::parse(&["quick"]);
    let h = args.f64_or("h", 0.8);
    let class_size = args.usize_or("class-size", 2000);
    let seed = args.u64_or("seed", 17);

    let g = sbm2(&Sbm2Config {
        class_size,
        avg_degree: 16.0,
        homophily: h,
        seed,
    });
    let n = g.num_nodes();

    // ---- Lemma 1: cut fraction vs beta -----------------------------------
    let mut t1 = Table::new(
        &format!("Lemma 1: edge-cut fraction vs partition purity β (h={h})"),
        &["β", "closed form", "measured", "abs err"],
    );
    let mut rng = Rng::new(seed ^ 1);
    for beta in [0.5, 0.6, 0.7, 0.8, 0.9, 1.0] {
        // Build a balanced partition with class purity beta.
        let per_class = class_size;
        let take0 = (beta * per_class as f64) as usize;
        let mut assign = vec![1u32; n];
        // class 0 occupies [0, per_class); class 1 the rest.
        let mut c0: Vec<usize> = (0..per_class).collect();
        let mut c1: Vec<usize> = (per_class..n).collect();
        rng.shuffle(&mut c0);
        rng.shuffle(&mut c1);
        for &v in c0.iter().take(take0) {
            assign[v] = 0;
        }
        for &v in c1.iter().take(per_class - take0) {
            assign[v] = 0;
        }
        let stats = partition_stats(&g, &assign, 2);
        let measured = 1.0 - stats.ratio_r;
        let expect = cut_fraction(beta, h);
        t1.row(vec![
            format!("{beta:.1}"),
            format!("{expect:.4}"),
            format!("{measured:.4}"),
            format!("{:.4}", (measured - expect).abs()),
        ]);
    }
    t1.emit("theory_lemma1");

    // ---- Thm 2: gradient discrepancies vs ||C2 - C1|| --------------------
    let mut t2 = Table::new(
        &format!("Thm 2: initial-gradient discrepancies vs β (h={h})"),
        &["β", "‖C2−C1‖", "‖∇g−∇l1‖", "‖∇g−∇l2‖", "‖∇l1−∇l2‖"],
    );
    for beta in [0.5, 0.6, 0.7, 0.8, 0.9, 1.0] {
        let (a, b, c) = grad_discrepancies(beta, h);
        t2.row(vec![
            format!("{beta:.1}"),
            format!("{:.4}", 2f64.sqrt() * (1.0 - 2.0 * beta).abs()),
            format!("{a:.4}"),
            format!("{b:.4}"),
            format!("{c:.4}"),
        ]);
    }
    t2.emit("theory_thm2");

    // ---- Cor 3 vs Lemma 1 partitioners on the same graph ------------------
    let mut t3 = Table::new(
        "Cor 3: measured class disparity ‖C2−C1‖ by partitioner",
        &["Partitioner", "‖C2−C1‖", "cut fraction"],
    );
    let mut rng = Rng::new(seed ^ 2);
    let rand_assign = random_partition(n, 2, &mut rng);
    let s_rand = partition_stats(&g, &rand_assign, 2);
    let metis_assign = metis_like(&g, 2, &MetisConfig::default(), &mut rng);
    let s_metis = partition_stats(&g, &metis_assign, 2);
    for (name, s) in [("random (Cor 3)", &s_rand), ("min-cut (Lem 1)", &s_metis)]
    {
        t3.row(vec![
            name.to_string(),
            format!("{:.4}", s.class_disparity),
            format!("{:.4}", 1.0 - s.ratio_r),
        ]);
    }
    t3.emit("theory_cor3");

    // ---- Lemma 1 mechanism on a community graph ---------------------------
    // On the structureless 2-class SBM a *heuristic* min-cut can find
    // balanced local optima that mix classes (Lemma 1 speaks about the
    // optimal cut, verified by the λ(β) curve above: minimum at β = 1).
    // The disparity mechanism the paper exploits appears on graphs with
    // community structure, where min-cut aligns parts with communities:
    let gc = random_tma::gen::dcsbm(&random_tma::gen::DcsbmConfig {
        nodes: 3000,
        communities: 12,
        avg_degree: 14.0,
        homophily: 0.9,
        feat_dim: 4,
        feature_noise: 0.3,
        degree_exponent: 0.5,
        seed: seed ^ 3,
    });
    let mut rng = Rng::new(seed ^ 4);
    let rc = random_partition(gc.num_nodes(), 3, &mut rng);
    let mc = metis_like(&gc, 3, &MetisConfig::default(), &mut rng);
    let s_rc = partition_stats(&gc, &rc, 3);
    let s_mc = partition_stats(&gc, &mc, 3);
    let mut t4 = Table::new(
        "Lemma 1 mechanism on a 12-community DC-SBM (M=3)",
        &["Partitioner", "class disparity", "cut fraction"],
    );
    for (name, s) in [("random", &s_rc), ("min-cut", &s_mc)] {
        t4.row(vec![
            name.to_string(),
            format!("{:.4}", s.class_disparity),
            format!("{:.4}", 1.0 - s.ratio_r),
        ]);
    }
    t4.emit("theory_mechanism");

    // Assertions: this bench doubles as a checked experiment.
    let parts = random_tma::partition::parts_of(&rand_assign, 2);
    let d_rand = l2_distance(
        &class_distribution(&g, &parts[0]),
        &class_distribution(&g, &parts[1]),
    );
    assert!(d_rand < 0.1, "Cor 3 violated: random disparity {d_rand}");
    assert!(
        (1.0 - s_metis.ratio_r) < 0.45,
        "min-cut worse than random: cut {}",
        1.0 - s_metis.ratio_r
    );
    assert!(
        s_mc.class_disparity > 3.0 * s_rc.class_disparity,
        "Lemma 1 mechanism absent on community graph: {} vs {}",
        s_mc.class_disparity,
        s_rc.class_disparity
    );
    println!("theory checks passed ✓");
}
