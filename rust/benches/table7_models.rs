//! Table 7: base-model ablation on the homogeneous datasets —
//! GCN / GraphSAGE / MLP encoders × 5 approaches, plus the partition
//! preprocessing time and retained-edge ratio r.
//!
//! Expected shape: the GNN encoders beat the graph-agnostic MLP by a
//! wide margin everywhere; RandomTMA's prep time is ~0 while the
//! min-cut schemes pay a clustering cost; MLP is skipped for LLCG (its
//! global correction exists to recover graph structure the MLP never
//! uses — paper App. A).

use random_tma::benchkit::{run_cell, BenchOpts};
use random_tma::config::Approach;
use random_tma::util::bench::Table;
use random_tma::util::stats;

fn main() {
    let (opts, args) = BenchOpts::parse();
    let datasets: Vec<String> = args
        .str_or("datasets", "reddit-sim,citation-sim")
        .split(',')
        .map(String::from)
        .collect();
    let encoders = ["gcn_mlp", "sage_mlp", "mlp_mlp"];

    let mut t = Table::new(
        "Table 7: base-model ablation (test MRR %)",
        &["Dataset", "Approach", "r", "Prep(s)", "GCN", "SAGE", "MLP"],
    );
    for ds in &datasets {
        let preset = opts.preset(ds, opts.base_seed).expect("preset");
        for a in Approach::all(0) {
            let mut cells = Vec::new();
            let mut ratio = 0.0;
            let mut prep = Vec::new();
            for variant in encoders {
                if variant == "mlp_mlp"
                    && matches!(a, Approach::Llcg { .. })
                {
                    cells.push("-".to_string());
                    continue;
                }
                let cell =
                    run_cell(&opts, &preset, variant, a, |_| {}).expect("run");
                ratio = cell.ratio_r;
                prep.extend_from_slice(&cell.prep);
                cells.push(cell.mrr_str());
            }
            t.row(vec![
                ds.clone(),
                a.name().to_string(),
                format!("{ratio:.2}"),
                format!("{:.2}", stats::mean(&prep)),
                cells[0].clone(),
                cells[1].clone(),
                cells[2].clone(),
            ]);
        }
    }
    t.emit("table7_models");
}
