//! Table 2 — the headline result: ratio r, test MRR and convergence
//! time for all 5 approaches × 4 datasets, plus the Average Rank
//! columns. Also emits the per-run curves consumed by EXPERIMENTS.md.
//!
//! Expected shape (paper): RandomTMA/SuperTMA lead MRR despite the
//! smallest r; RandomTMA has the best convergence-time rank; GGS
//! trails despite r = 1.0.

use random_tma::benchkit::{average_ranks, best_variant, run_cell, BenchOpts};
use random_tma::config::Approach;
use random_tma::util::bench::Table;
use random_tma::util::json::Json;

fn main() {
    let (opts, args) = BenchOpts::parse();
    let datasets: Vec<String> = match args.get("datasets") {
        Some(list) => list.split(',').map(String::from).collect(),
        None => random_tma::gen::preset_names()
            .iter()
            .map(|s| s.to_string())
            .collect(),
    };
    let approaches = Approach::all(0); // SuperTMA N resolved per dataset

    let mut t = Table::new(
        "Table 2: main comparison (test MRR %, convergence time s)",
        &["Dataset", "Approach", "r", "MRR(%)", "Conv(s)"],
    );
    let mut mrr_by_ds = Vec::new();
    let mut conv_by_ds = Vec::new();
    let mut raw = Vec::new();
    // Heterogeneous trainer speeds (the paper's instances show up to
    // 28.8% step spread; on a time-shared core we inject it).
    let slowdown = vec![1.0, 1.15, 1.3];

    for ds in &datasets {
        let preset = opts.preset(ds, opts.base_seed).expect("preset");
        let variant = best_variant(ds);
        let mut mrrs = Vec::new();
        let mut convs = Vec::new();
        for &a in &approaches {
            let cell = run_cell(&opts, &preset, variant, a, |cfg| {
                cfg.slowdown = slowdown.clone();
            })
            .expect("run");
            t.row(vec![
                ds.clone(),
                a.name().to_string(),
                format!("{:.2}", cell.ratio_r),
                cell.mrr_str(),
                cell.conv_str(),
            ]);
            mrrs.push(cell.mean_mrr());
            convs.push(cell.mean_conv());
            for r in &cell.results {
                raw.push(r.to_json());
            }
        }
        mrr_by_ds.push(mrrs);
        conv_by_ds.push(convs);
    }

    let (mrr_rank, conv_rank) = average_ranks(&mrr_by_ds, &conv_by_ds);
    let mut rank_t = Table::new(
        "Table 2 (cont.): average ranks across datasets",
        &["Approach", "MRR rank", "Conv rank"],
    );
    for (i, a) in approaches.iter().enumerate() {
        rank_t.row(vec![
            a.name().to_string(),
            format!("{:.1}", mrr_rank[i]),
            format!("{:.1}", conv_rank[i]),
        ]);
    }
    t.emit("table2_main");
    rank_t.emit("table2_ranks");
    Json::arr(raw)
        .write_file(std::path::Path::new("results/table2_runs.json"))
        .ok();
}
