//! Table 1: dataset statistics (synthetic substitutes — DESIGN.md §2).
//!
//! Regenerates the paper's dataset table for the four generated
//! benchmarks, plus the homophily column the theory depends on.

use random_tma::benchkit::BenchOpts;
use random_tma::graph::stats::graph_stats;
use random_tma::util::bench::{fmt_secs, time, Table};

fn main() {
    let (opts, _) = BenchOpts::parse();
    let mut t = Table::new(
        "Table 1: dataset statistics",
        &["Dataset", "#Nodes |V|", "#Edges |E|", "#Feat F", "AvgDeg",
          "homophily h", "#Val/Test", "GenTime"],
    );
    for name in random_tma::gen::preset_names() {
        let mut preset = None;
        let gen_t = time(name, 0, 1, || {
            preset = Some(opts.preset(name, opts.base_seed).expect("preset"));
        });
        let p = preset.unwrap();
        let s = graph_stats(&p.graph);
        t.row(vec![
            name.to_string(),
            s.num_nodes.to_string(),
            s.num_edges.to_string(),
            s.feat_dim.to_string(),
            format!("{:.1}", s.avg_degree),
            format!("{:.2}", s.homophily),
            format!("{}/{}", p.split.val.len(), p.split.test.len()),
            fmt_secs(gen_t.median_s()),
        ]);
    }
    t.emit("table1_datasets");
}
