//! Trace folding: JSONL trace file → per-round server phase
//! breakdown + final counter totals (`rtma trace-report`).
//!
//! Doubles as the schema validator: every line must parse as JSON and
//! carry the required keys, with line-numbered errors otherwise — the
//! distributed-smoke CI job runs it over the trace it just recorded,
//! so a malformed line fails the build.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::util::bench::{fmt_secs, Table};
use crate::util::json::Json;

/// Keys every trace line must carry, whatever its kind.
pub const REQUIRED_KEYS: [&str; 4] = ["ts", "kind", "comp", "name"];

/// The four server phases `trace-report` folds per round, in emission
/// order.
pub const SERVER_PHASES: [&str; 4] =
    ["collect", "aggregate", "broadcast", "eval_dispatch"];

/// One aggregation round's phase totals (µs) and span counts.
#[derive(Clone, Debug, Default)]
pub struct RoundRow {
    pub round: u64,
    pub phase_us: [u64; 4],
    pub phase_n: [u64; 4],
}

/// A folded trace.
#[derive(Clone, Debug, Default)]
pub struct TraceReport {
    pub lines: usize,
    pub events: usize,
    pub spans: usize,
    pub counter_records: usize,
    /// Per-round server phase rows, ordered by round.
    pub rounds: Vec<RoundRow>,
    pub phase_total_us: [u64; 4],
    /// Final counter totals (last `counters` record wins per key,
    /// merged across components).
    pub counters: BTreeMap<String, f64>,
    /// Lines per component.
    pub comps: BTreeMap<String, usize>,
}

/// Parse + validate a JSONL trace and fold it. Errors carry the
/// 1-based line number of the first offending line.
pub fn parse_trace(text: &str) -> Result<TraceReport> {
    let mut rep = TraceReport::default();
    let mut by_round: BTreeMap<u64, RoundRow> = BTreeMap::new();
    for (i, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let j = Json::parse(line)
            .map_err(|e| anyhow::anyhow!("trace line {}: {e}", i + 1))?;
        for k in REQUIRED_KEYS {
            if j.get(k) == &Json::Null {
                bail!("trace line {}: missing required key {k:?}", i + 1);
            }
        }
        rep.lines += 1;
        let comp = j.get("comp").as_str().unwrap_or("?").to_string();
        *rep.comps.entry(comp).or_insert(0) += 1;
        match j.get("kind").as_str() {
            Some("event") => rep.events += 1,
            Some("span") => {
                rep.spans += 1;
                let name = j.get("name").as_str();
                if let Some(p) =
                    SERVER_PHASES.iter().position(|n| Some(*n) == name)
                {
                    let dur =
                        j.get("dur_us").as_f64().unwrap_or(0.0) as u64;
                    let round =
                        j.get("round").as_f64().unwrap_or(0.0) as u64;
                    let row = by_round
                        .entry(round)
                        .or_insert_with(|| RoundRow {
                            round,
                            ..RoundRow::default()
                        });
                    row.phase_us[p] += dur;
                    row.phase_n[p] += 1;
                    rep.phase_total_us[p] += dur;
                }
            }
            Some("counters") => {
                rep.counter_records += 1;
                if let Some(m) = j.get("counters").as_obj() {
                    for (k, v) in m {
                        if let Some(x) = v.as_f64() {
                            rep.counters.insert(k.clone(), x);
                        }
                    }
                }
            }
            other => {
                bail!("trace line {}: unknown kind {other:?}", i + 1)
            }
        }
    }
    rep.rounds = by_round.into_values().collect();
    Ok(rep)
}

fn fmt_us(us: u64) -> String {
    fmt_secs(us as f64 / 1e6)
}

impl TraceReport {
    /// The per-round phase-breakdown table (+ a totals row).
    pub fn phase_table(&self) -> Table {
        let mut t = Table::new(
            "Per-round server phase breakdown",
            &[
                "Round",
                "Collect",
                "Aggregate",
                "Broadcast",
                "EvalDispatch",
                "Total",
            ],
        );
        for row in &self.rounds {
            let total: u64 = row.phase_us.iter().sum();
            t.row(vec![
                row.round.to_string(),
                fmt_us(row.phase_us[0]),
                fmt_us(row.phase_us[1]),
                fmt_us(row.phase_us[2]),
                fmt_us(row.phase_us[3]),
                fmt_us(total),
            ]);
        }
        let total: u64 = self.phase_total_us.iter().sum();
        t.row(vec![
            "all".to_string(),
            fmt_us(self.phase_total_us[0]),
            fmt_us(self.phase_total_us[1]),
            fmt_us(self.phase_total_us[2]),
            fmt_us(self.phase_total_us[3]),
            fmt_us(total),
        ]);
        t
    }

    /// Final counter totals as a table (empty when the trace carried
    /// no counters record).
    pub fn counter_table(&self) -> Table {
        let mut t = Table::new("Final counters", &["Counter", "Value"]);
        for (k, v) in &self.counters {
            t.row(vec![k.clone(), format!("{v}")]);
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(round: u64, name: &str, dur: u64) -> String {
        format!(
            "{{\"ts\":0.1,\"kind\":\"span\",\"comp\":\"server\",\
             \"name\":\"{name}\",\"dur_us\":{dur},\"round\":{round}}}"
        )
    }

    #[test]
    fn folds_phases_per_round() {
        let mut text = String::new();
        for r in 1..=2u64 {
            for (i, p) in SERVER_PHASES.iter().enumerate() {
                text.push_str(&span(r, p, 100 * (i as u64 + 1)));
                text.push('\n');
            }
        }
        text.push_str(
            "{\"ts\":1.0,\"kind\":\"event\",\"lvl\":\"info\",\
             \"comp\":\"server\",\"name\":\"x\",\"msg\":\"m\"}\n",
        );
        text.push_str(
            "{\"ts\":2.0,\"kind\":\"counters\",\"comp\":\"server\",\
             \"name\":\"counters\",\"counters\":{\"rounds_opened\":2}}\n",
        );
        let rep = parse_trace(&text).unwrap();
        assert_eq!(rep.lines, 10);
        assert_eq!(rep.spans, 8);
        assert_eq!(rep.events, 1);
        assert_eq!(rep.counter_records, 1);
        assert_eq!(rep.rounds.len(), 2);
        assert_eq!(rep.rounds[0].phase_us, [100, 200, 300, 400]);
        assert_eq!(rep.phase_total_us, [200, 400, 600, 800]);
        assert_eq!(rep.counters["rounds_opened"], 2.0);
        let rendered = rep.phase_table().render();
        assert!(rendered.contains("Round"));
        assert!(rendered.contains("all"));
    }

    #[test]
    fn rejects_unparseable_line_with_number() {
        let text = format!("{}\nnot json\n", span(1, "collect", 5));
        let err = parse_trace(&text).unwrap_err().to_string();
        assert!(err.contains("line 2"), "{err}");
    }

    #[test]
    fn rejects_missing_required_key() {
        let text = "{\"ts\":0.1,\"kind\":\"span\",\"comp\":\"x\"}\n";
        let err = parse_trace(text).unwrap_err().to_string();
        assert!(err.contains("name"), "{err}");
    }

    #[test]
    fn rejects_unknown_kind() {
        let text = "{\"ts\":0.1,\"kind\":\"blob\",\"comp\":\"x\",\
                    \"name\":\"y\"}\n";
        assert!(parse_trace(text).is_err());
    }

    #[test]
    fn empty_trace_is_valid_and_empty() {
        let rep = parse_trace("\n\n").unwrap();
        assert_eq!(rep.lines, 0);
        assert!(rep.rounds.is_empty());
    }
}
