//! Structured telemetry: leveled events, RAII span timers, a JSONL
//! trace sink, and the counter registry (ISSUE 6's measurement plane).
//!
//! Three layers, all dependency-free:
//!
//! 1. **Events + spans** — [`info`]/[`debug`] replace the scattered
//!    `eprintln!`s behind an `RTMA_LOG=off|info|debug` filter; a
//!    [`Span`] times a scope and records the duration into a registry
//!    histogram on drop. When a trace sink is armed (`RTMA_TRACE=path`
//!    or [`set_trace_path`]) both also append one JSON object per line
//!    (JSONL) built with [`crate::util::json::Json`], so every line is
//!    parseable by construction.
//! 2. **Registry** — [`registry`]: plain relaxed atomics, no
//!    allocation on the hot path whether or not logging is on.
//! 3. **Report** — [`report`]: folds a JSONL trace into per-round
//!    server phase breakdowns (`rtma trace-report`).
//!
//! Trace lines buffer in a per-thread `String` (lock-free append) and
//! flush to the shared sink file when the buffer passes 8 KiB, on
//! [`flush`], and from the thread-local's destructor at thread exit —
//! so trainer threads never contend on the sink lock mid-round.
//!
//! JSONL schema (pinned by `tests/telemetry.rs`): every line carries
//! `ts` (seconds since process start), `kind`
//! (`event|span|counters`), `comp` and `name`. Events add `lvl` +
//! `msg` (+ flattened numeric kv pairs); spans add `dur_us` and
//! optionally `round`/`trainer`; counters records nest the full
//! registry under `counters`.

pub mod registry;
pub mod report;

pub use registry::{
    metrics, snapshot, Counter, Gauge, HistSnap, Histogram, Metrics,
    Snapshot, METRICS,
};

use std::cell::RefCell;
use std::fmt;
use std::fs::{File, OpenOptions};
use std::io::Write;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU8, Ordering};
use std::sync::{Mutex, Once, OnceLock};
use std::time::Instant;

use crate::util::json::Json;

/// Stderr log level, from `RTMA_LOG` (default `info`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Off = 0,
    Info = 1,
    Debug = 2,
}

impl Level {
    pub fn parse(s: &str) -> Level {
        match s.trim().to_ascii_lowercase().as_str() {
            "off" | "0" | "none" | "silent" => Level::Off,
            "debug" | "2" => Level::Debug,
            _ => Level::Info,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Level::Off => "off",
            Level::Info => "info",
            Level::Debug => "debug",
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);
static TRACE_ON: AtomicBool = AtomicBool::new(false);
static SINK: Mutex<Option<File>> = Mutex::new(None);
static ENV_INIT: Once = Once::new();
static PROC_EPOCH: OnceLock<Instant> = OnceLock::new();

/// One-time env configuration: `RTMA_LOG` sets the stderr level,
/// `RTMA_TRACE` arms the JSONL sink. Called lazily from every public
/// entry point; [`set_level`]/[`set_trace_path`] override it later.
fn ensure_env() {
    ENV_INIT.call_once(|| {
        PROC_EPOCH.get_or_init(Instant::now);
        if let Ok(v) = std::env::var("RTMA_LOG") {
            LEVEL.store(Level::parse(&v) as u8, Ordering::Relaxed);
        }
        if let Ok(p) = std::env::var("RTMA_TRACE") {
            if !p.is_empty() {
                if let Err(e) = install_sink(Some(Path::new(&p))) {
                    eprintln!("[telemetry] RTMA_TRACE={p}: {e}");
                }
            }
        }
    });
}

fn install_sink(path: Option<&Path>) -> std::io::Result<()> {
    let file = match path {
        Some(p) => {
            if let Some(dir) = p.parent() {
                if !dir.as_os_str().is_empty() {
                    std::fs::create_dir_all(dir)?;
                }
            }
            Some(OpenOptions::new().create(true).append(true).open(p)?)
        }
        None => None,
    };
    let armed = file.is_some();
    *SINK.lock().unwrap() = file;
    TRACE_ON.store(armed, Ordering::Relaxed);
    Ok(())
}

/// Current stderr level.
pub fn level() -> Level {
    ensure_env();
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Off,
        2 => Level::Debug,
        _ => Level::Info,
    }
}

/// Override the stderr level (tests; wins over `RTMA_LOG`).
pub fn set_level(l: Level) {
    ensure_env();
    LEVEL.store(l as u8, Ordering::Relaxed);
}

/// Would an event at `l` print to stderr right now?
pub fn on(l: Level) -> bool {
    l != Level::Off && l <= level()
}

/// Is the JSONL trace sink armed?
pub fn trace_on() -> bool {
    ensure_env();
    TRACE_ON.load(Ordering::Relaxed)
}

/// Arm (`Some(path)`, append/create) or disarm (`None`) the trace
/// sink programmatically — wins over `RTMA_TRACE`, which tests can't
/// set race-free in-process.
pub fn set_trace_path(path: Option<&Path>) -> std::io::Result<()> {
    ensure_env();
    install_sink(path)
}

/// Seconds since process start (the `ts` field of every trace line).
pub fn ts() -> f64 {
    ensure_env();
    PROC_EPOCH.get_or_init(Instant::now).elapsed().as_secs_f64()
}

/// The project clock. Every wall-time read outside the telemetry
/// plane and the bench harness goes through here — `rtma-check`'s
/// determinism lint denies raw `Instant::now()`/`SystemTime::now()`
/// elsewhere — so timing stays greppable and a future
/// deterministic-replay harness can interpose one function instead
/// of chasing call sites.
#[inline]
pub fn now() -> Instant {
    Instant::now()
}

// ---- per-thread line buffer ------------------------------------------------

const FLUSH_BYTES: usize = 8 * 1024;

struct LineBuf {
    s: String,
}

impl Drop for LineBuf {
    fn drop(&mut self) {
        // Thread exit: hand any buffered lines to the sink.
        flush_buf(&mut self.s);
    }
}

thread_local! {
    static BUF: RefCell<LineBuf> =
        RefCell::new(LineBuf { s: String::new() });
}

fn flush_buf(s: &mut String) {
    if s.is_empty() {
        return;
    }
    if let Ok(mut sink) = SINK.lock() {
        if let Some(f) = sink.as_mut() {
            let _ = f.write_all(s.as_bytes());
            let _ = f.flush();
        }
    }
    s.clear();
}

fn push_line(line: &str) {
    let pushed = BUF.try_with(|b| {
        let mut b = b.borrow_mut();
        b.s.push_str(line);
        b.s.push('\n');
        if b.s.len() >= FLUSH_BYTES {
            flush_buf(&mut b.s);
        }
    });
    if pushed.is_err() {
        // TLS already destroyed (thread teardown): write through.
        let mut one = String::with_capacity(line.len() + 1);
        one.push_str(line);
        one.push('\n');
        flush_buf(&mut one);
    }
}

/// Flush the calling thread's buffered trace lines to the sink. Call
/// before process exit on threads that outlive their TLS destructors
/// (main).
pub fn flush() {
    let _ = BUF.try_with(|b| flush_buf(&mut b.borrow_mut().s));
}

// ---- events ----------------------------------------------------------------

/// Emit a leveled event: `[comp] msg` on stderr when `RTMA_LOG`
/// allows, plus a JSONL record (with the numeric `kv` pairs flattened
/// in) when the trace sink is armed. Fully disabled: no formatting,
/// no allocation.
pub fn event(
    lvl: Level,
    comp: &'static str,
    name: &'static str,
    kv: &[(&'static str, f64)],
    args: fmt::Arguments<'_>,
) {
    let log = on(lvl);
    let trace = trace_on();
    if !log && !trace {
        return;
    }
    let msg = fmt::format(args);
    if log {
        eprintln!("[{comp}] {msg}");
    }
    if trace {
        let mut obj = Json::obj(vec![
            ("ts", Json::num(ts())),
            ("kind", Json::str("event")),
            ("lvl", Json::str(lvl.name())),
            ("comp", Json::str(comp)),
            ("name", Json::str(name)),
            ("msg", Json::str(msg)),
        ]);
        for (k, v) in kv {
            obj.set(k, Json::num(*v));
        }
        push_line(&format!("{obj}"));
    }
}

/// Info-level event (the old `eprintln!` sites).
pub fn info(
    comp: &'static str,
    name: &'static str,
    kv: &[(&'static str, f64)],
    args: fmt::Arguments<'_>,
) {
    event(Level::Info, comp, name, kv, args);
}

/// Debug-level event (per-round chatter, off by default).
pub fn debug(
    comp: &'static str,
    name: &'static str,
    kv: &[(&'static str, f64)],
    args: fmt::Arguments<'_>,
) {
    event(Level::Debug, comp, name, kv, args);
}

// ---- spans -----------------------------------------------------------------

/// RAII scope timer. On drop it observes the elapsed µs into the
/// attached registry histogram (always — counters are never gated)
/// and appends a `kind:"span"` trace line when the sink is armed.
///
/// ```ignore
/// let _s = Span::start("server", "collect")
///     .round(r)
///     .hist(&metrics().phase_collect);
/// ```
pub struct Span {
    comp: &'static str,
    name: &'static str,
    round: Option<u64>,
    trainer: Option<u64>,
    hist: Option<&'static Histogram>,
    t0: Instant,
    traced: bool,
}

impl Span {
    pub fn start(comp: &'static str, name: &'static str) -> Span {
        Span {
            comp,
            name,
            round: None,
            trainer: None,
            hist: None,
            t0: Instant::now(),
            traced: trace_on(),
        }
    }

    pub fn round(mut self, r: u64) -> Span {
        self.round = Some(r);
        self
    }

    pub fn trainer(mut self, id: u64) -> Span {
        self.trainer = Some(id);
        self
    }

    pub fn hist(mut self, h: &'static Histogram) -> Span {
        self.hist = Some(h);
        self
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        let dur_us = self.t0.elapsed().as_micros() as u64;
        if let Some(h) = self.hist {
            h.observe(dur_us);
        }
        if self.traced {
            let mut obj = Json::obj(vec![
                ("ts", Json::num(ts())),
                ("kind", Json::str("span")),
                ("comp", Json::str(self.comp)),
                ("name", Json::str(self.name)),
                ("dur_us", Json::num(dur_us as f64)),
            ]);
            if let Some(r) = self.round {
                obj.set("round", Json::num(r as f64));
            }
            if let Some(t) = self.trainer {
                obj.set("trainer", Json::num(t as f64));
            }
            push_line(&format!("{obj}"));
        }
    }
}

/// Append a `kind:"counters"` trace record — the full registry
/// (counters + gauges) at this instant. Emitted by the server, the
/// driver and the workers at run end so a trace carries its final
/// byte/step/round totals. No-op when the sink is disarmed.
pub fn trace_counters(comp: &'static str) {
    if !trace_on() {
        return;
    }
    let snap = snapshot();
    let mut counters = Json::obj(vec![]);
    for (n, v) in &snap.counters {
        counters.set(n, Json::num(*v as f64));
    }
    for (n, v) in &snap.gauges {
        counters.set(n, Json::num(*v as f64));
    }
    let obj = Json::obj(vec![
        ("ts", Json::num(ts())),
        ("kind", Json::str("counters")),
        ("comp", Json::str(comp)),
        ("name", Json::str("counters")),
        ("counters", counters),
    ]);
    push_line(&format!("{obj}"));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parsing() {
        assert_eq!(Level::parse("off"), Level::Off);
        assert_eq!(Level::parse("0"), Level::Off);
        assert_eq!(Level::parse("debug"), Level::Debug);
        assert_eq!(Level::parse("info"), Level::Info);
        assert_eq!(Level::parse("garbage"), Level::Info);
        assert!(Level::Off < Level::Info && Level::Info < Level::Debug);
    }

    #[test]
    fn ts_is_monotone() {
        let a = ts();
        let b = ts();
        assert!(b >= a && a >= 0.0);
    }

    #[test]
    fn disabled_event_is_inert() {
        // No sink, level off: must neither panic nor print.
        let prev = level();
        set_level(Level::Off);
        info("test", "noop", &[("k", 1.0)], format_args!("dropped"));
        let _s = Span::start("test", "noop");
        drop(_s);
        set_level(prev);
    }
}
