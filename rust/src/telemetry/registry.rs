//! Counter/gauge/histogram registry — the always-on half of the
//! telemetry plane.
//!
//! Every metric is a process-wide static of plain relaxed atomics, so
//! the round hot path pays one `fetch_add` per increment and zero
//! allocations whether or not a trace sink or stderr logging is
//! enabled (the ISSUE-6 "telemetry off adds no allocation" contract;
//! `perf_hotpath`'s telemetry-overhead section pins it within 3%).
//!
//! Histograms are fixed-bucket log2: bucket `i` counts observations
//! whose bit length is `i` (bucket 0 = exactly zero), so a duration
//! histogram in µs spans ns-to-hours in 64 buckets with no locks and
//! no dynamic memory. [`Snapshot`] freezes the registry into plain
//! vectors; [`Snapshot::delta_since`] subtracts a baseline so a driver
//! run reports only its own activity even though the statics are
//! shared process-wide.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::util::json::Json;

/// Monotone counter (relaxed `AtomicU64`).
pub struct Counter(AtomicU64);

impl Counter {
    pub const fn new() -> Counter {
        Counter(AtomicU64::new(0))
    }
    pub fn add(&self, v: u64) {
        self.0.fetch_add(v, Ordering::Relaxed);
    }
    pub fn inc(&self) {
        self.add(1);
    }
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-write-wins gauge (absolute level, not a rate).
pub struct Gauge(AtomicU64);

impl Gauge {
    pub const fn new() -> Gauge {
        Gauge(AtomicU64::new(0))
    }
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

pub const HIST_BUCKETS: usize = 64;

/// Lock-free log2 histogram: bucket `i` holds observations with bit
/// length `i` (bucket 0 = zero), i.e. values in `[2^(i-1), 2^i)`.
pub struct Histogram {
    buckets: [AtomicU64; HIST_BUCKETS],
    sum: AtomicU64,
}

/// Index of the log2 bucket holding `v`.
pub fn bucket_of(v: u64) -> usize {
    (64 - v.leading_zeros() as usize).min(HIST_BUCKETS - 1)
}

/// Lower bound of bucket `i` (the conservative representative value
/// percentile queries report).
pub fn bucket_floor(i: usize) -> u64 {
    if i == 0 {
        0
    } else {
        1u64 << (i - 1)
    }
}

impl Histogram {
    pub const fn new() -> Histogram {
        const ZERO: AtomicU64 = AtomicU64::new(0);
        Histogram {
            buckets: [ZERO; HIST_BUCKETS],
            sum: AtomicU64::new(0),
        }
    }

    pub fn observe(&self, v: u64) {
        self.buckets[bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    pub fn snap(&self) -> HistSnap {
        HistSnap {
            buckets: self
                .buckets
                .iter()
                .map(|b| b.load(Ordering::Relaxed))
                .collect(),
            sum: self.sum.load(Ordering::Relaxed),
        }
    }
}

/// Frozen histogram state.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct HistSnap {
    pub buckets: Vec<u64>,
    pub sum: u64,
}

impl HistSnap {
    pub fn count(&self) -> u64 {
        self.buckets.iter().sum()
    }

    /// Mean observed value (0 when empty).
    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum as f64 / n as f64
        }
    }

    /// Approximate percentile: the floor of the bucket holding the
    /// k-th ordered observation (log2 resolution; 0 when empty).
    pub fn percentile(&self, p: f64) -> u64 {
        let n = self.count();
        if n == 0 {
            return 0;
        }
        let k = ((p / 100.0) * n as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= k {
                return bucket_floor(i);
            }
        }
        bucket_floor(HIST_BUCKETS - 1)
    }

    /// Bucket-wise difference vs an earlier snapshot of the same
    /// histogram.
    pub fn delta_since(&self, base: &HistSnap) -> HistSnap {
        HistSnap {
            buckets: self
                .buckets
                .iter()
                .zip(base.buckets.iter().chain(std::iter::repeat(&0)))
                .map(|(a, b)| a.saturating_sub(*b))
                .collect(),
            sum: self.sum.saturating_sub(base.sum),
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::num(self.count() as f64)),
            ("sum_us", Json::num(self.sum as f64)),
            ("mean_us", Json::num(self.mean())),
            ("p50_us", Json::num(self.percentile(50.0) as f64)),
            ("p95_us", Json::num(self.percentile(95.0) as f64)),
        ])
    }
}

/// The process-wide metric registry. Names here are the public
/// contract: `docs/TELEMETRY.md` documents each, `trace-report` and
/// the BENCH baselines key off them.
pub struct Metrics {
    // ---- coordinator round phases (durations in µs) ----
    pub phase_collect: Histogram,
    pub phase_aggregate: Histogram,
    pub phase_broadcast: Histogram,
    pub phase_eval_dispatch: Histogram,
    // ---- round/control plane ----
    pub rounds_opened: Counter,
    pub round_msgs: Counter,
    pub round_stale_dropped: Counter,
    pub round_dup_dropped: Counter,
    pub trainer_ready_marks: Counter,
    pub trainer_dead_marks: Counter,
    // ---- trainers ----
    pub train_steps: Counter,
    pub step_us: Histogram,
    pub last_loss_bits: Gauge,
    // ---- compute engine (backend loads + per-entry durations) ----
    pub engine_load_fail: Counter,
    pub engine_native_loads: Counter,
    pub engine_pjrt_loads: Counter,
    pub engine_train_us: Histogram,
    pub engine_grad_us: Histogram,
    pub engine_encode_us: Histogram,
    pub engine_score_us: Histogram,
    // ---- evaluator ----
    pub evals_dispatched: Counter,
    pub evals_done: Counter,
    pub eval_inflight: Gauge,
    // ---- wire protocol ----
    pub comm_bytes_out: Counter,
    pub comm_bytes_in: Counter,
    pub comm_frames_out: Counter,
    pub comm_frames_in: Counter,
    pub comm_scratch_reuse: Counter,
    pub comm_scratch_grow: Counter,
    /// Frames refused at either end of the wire: oversized sends
    /// (> [`crate::comm::MAX_FRAME`]), oversized announced lengths on
    /// receive, and undecodable frame/codec bodies.
    pub comm_frames_rejected: Counter,
    // ---- round codecs ----
    pub codec_frames: Counter,
    /// Raw (pre-codec, 4·P) vs encoded body bytes across every encode:
    /// `codec_bytes_raw / codec_bytes_encoded` is the compression
    /// ratio `BENCH_codec.json` persists.
    pub codec_bytes_raw: Counter,
    pub codec_bytes_encoded: Counter,
    pub codec_encode_us: Histogram,
    pub codec_decode_us: Histogram,
    // ---- threadpool ----
    pub pool_sections: Counter,
    pub pool_tasks: Counter,
    pub pool_workers: Counter,
    // ---- serving plane (docs/SERVING.md) ----
    pub serve_requests: Counter,
    pub serve_pairs: Counter,
    pub serve_batches: Counter,
    pub serve_cache_hits: Counter,
    pub serve_cache_misses: Counter,
    /// Weight generations swapped into the running server (one per
    /// aggregation-round push in train-and-serve mode).
    pub serve_weight_swaps: Counter,
    pub serve_connections: Gauge,
    /// Whole-batch latency (gather + score + reply writes), µs.
    pub serve_batch_us: Histogram,
    /// Per-request latency from reader decode to reply write, µs.
    pub serve_request_us: Histogram,
}

impl Metrics {
    pub const fn new() -> Metrics {
        Metrics {
            phase_collect: Histogram::new(),
            phase_aggregate: Histogram::new(),
            phase_broadcast: Histogram::new(),
            phase_eval_dispatch: Histogram::new(),
            rounds_opened: Counter::new(),
            round_msgs: Counter::new(),
            round_stale_dropped: Counter::new(),
            round_dup_dropped: Counter::new(),
            trainer_ready_marks: Counter::new(),
            trainer_dead_marks: Counter::new(),
            train_steps: Counter::new(),
            step_us: Histogram::new(),
            last_loss_bits: Gauge::new(),
            engine_load_fail: Counter::new(),
            engine_native_loads: Counter::new(),
            engine_pjrt_loads: Counter::new(),
            engine_train_us: Histogram::new(),
            engine_grad_us: Histogram::new(),
            engine_encode_us: Histogram::new(),
            engine_score_us: Histogram::new(),
            evals_dispatched: Counter::new(),
            evals_done: Counter::new(),
            eval_inflight: Gauge::new(),
            comm_bytes_out: Counter::new(),
            comm_bytes_in: Counter::new(),
            comm_frames_out: Counter::new(),
            comm_frames_in: Counter::new(),
            comm_scratch_reuse: Counter::new(),
            comm_scratch_grow: Counter::new(),
            comm_frames_rejected: Counter::new(),
            codec_frames: Counter::new(),
            codec_bytes_raw: Counter::new(),
            codec_bytes_encoded: Counter::new(),
            codec_encode_us: Histogram::new(),
            codec_decode_us: Histogram::new(),
            pool_sections: Counter::new(),
            pool_tasks: Counter::new(),
            pool_workers: Counter::new(),
            serve_requests: Counter::new(),
            serve_pairs: Counter::new(),
            serve_batches: Counter::new(),
            serve_cache_hits: Counter::new(),
            serve_cache_misses: Counter::new(),
            serve_weight_swaps: Counter::new(),
            serve_connections: Gauge::new(),
            serve_batch_us: Histogram::new(),
            serve_request_us: Histogram::new(),
        }
    }

    /// Every counter as `(name, value)` in a fixed order.
    pub fn counters_list(&self) -> Vec<(&'static str, u64)> {
        vec![
            ("rounds_opened", self.rounds_opened.get()),
            ("round_msgs", self.round_msgs.get()),
            ("round_stale_dropped", self.round_stale_dropped.get()),
            ("round_dup_dropped", self.round_dup_dropped.get()),
            ("trainer_ready_marks", self.trainer_ready_marks.get()),
            ("trainer_dead_marks", self.trainer_dead_marks.get()),
            ("train_steps", self.train_steps.get()),
            ("engine_load_fail", self.engine_load_fail.get()),
            ("engine_native_loads", self.engine_native_loads.get()),
            ("engine_pjrt_loads", self.engine_pjrt_loads.get()),
            ("evals_dispatched", self.evals_dispatched.get()),
            ("evals_done", self.evals_done.get()),
            ("comm_bytes_out", self.comm_bytes_out.get()),
            ("comm_bytes_in", self.comm_bytes_in.get()),
            ("comm_frames_out", self.comm_frames_out.get()),
            ("comm_frames_in", self.comm_frames_in.get()),
            ("comm_scratch_reuse", self.comm_scratch_reuse.get()),
            ("comm_scratch_grow", self.comm_scratch_grow.get()),
            ("comm_frames_rejected", self.comm_frames_rejected.get()),
            ("codec_frames", self.codec_frames.get()),
            ("codec_bytes_raw", self.codec_bytes_raw.get()),
            ("codec_bytes_encoded", self.codec_bytes_encoded.get()),
            ("pool_sections", self.pool_sections.get()),
            ("pool_tasks", self.pool_tasks.get()),
            ("pool_workers", self.pool_workers.get()),
            ("serve_requests", self.serve_requests.get()),
            ("serve_pairs", self.serve_pairs.get()),
            ("serve_batches", self.serve_batches.get()),
            ("serve_cache_hits", self.serve_cache_hits.get()),
            ("serve_cache_misses", self.serve_cache_misses.get()),
            ("serve_weight_swaps", self.serve_weight_swaps.get()),
        ]
    }

    /// Every gauge as `(name, value)` in a fixed order.
    pub fn gauges_list(&self) -> Vec<(&'static str, u64)> {
        vec![
            ("eval_inflight", self.eval_inflight.get()),
            ("last_loss_bits", self.last_loss_bits.get()),
            ("serve_connections", self.serve_connections.get()),
        ]
    }

    /// Every histogram as `(name, snapshot)` in a fixed order. The
    /// four `phase_*` entries use the bare phase names `trace-report`
    /// folds on.
    pub fn hists_list(&self) -> Vec<(&'static str, HistSnap)> {
        vec![
            ("collect", self.phase_collect.snap()),
            ("aggregate", self.phase_aggregate.snap()),
            ("broadcast", self.phase_broadcast.snap()),
            ("eval_dispatch", self.phase_eval_dispatch.snap()),
            ("train_step", self.step_us.snap()),
            ("engine_train", self.engine_train_us.snap()),
            ("engine_grad", self.engine_grad_us.snap()),
            ("engine_encode", self.engine_encode_us.snap()),
            ("engine_score", self.engine_score_us.snap()),
            ("codec_encode", self.codec_encode_us.snap()),
            ("codec_decode", self.codec_decode_us.snap()),
            ("serve_batch", self.serve_batch_us.snap()),
            ("serve_request", self.serve_request_us.snap()),
        ]
    }
}

/// The one process-wide registry.
pub static METRICS: Metrics = Metrics::new();

pub fn metrics() -> &'static Metrics {
    &METRICS
}

/// Frozen registry state: counters, gauges and histogram snapshots.
/// `Default` is the all-zero snapshot (used by hand-built
/// [`crate::metrics::RunResult`]s in tests).
#[derive(Clone, Debug, Default)]
pub struct Snapshot {
    pub counters: Vec<(&'static str, u64)>,
    pub gauges: Vec<(&'static str, u64)>,
    pub hists: Vec<(&'static str, HistSnap)>,
}

/// Freeze the registry now.
pub fn snapshot() -> Snapshot {
    Snapshot {
        counters: METRICS.counters_list(),
        gauges: METRICS.gauges_list(),
        hists: METRICS.hists_list(),
    }
}

impl Snapshot {
    /// Counter value by name (0 when absent).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters
            .iter()
            .find(|(n, _)| *n == name)
            .map(|(_, v)| *v)
            .unwrap_or(0)
    }

    /// Gauge value by name (0 when absent).
    pub fn gauge(&self, name: &str) -> u64 {
        self.gauges
            .iter()
            .find(|(n, _)| *n == name)
            .map(|(_, v)| *v)
            .unwrap_or(0)
    }

    /// Histogram snapshot by name.
    pub fn hist(&self, name: &str) -> Option<&HistSnap> {
        self.hists.iter().find(|(n, _)| *n == name).map(|(_, h)| h)
    }

    /// Activity since `base` (an earlier snapshot of the same
    /// process): counters and histograms subtract, gauges keep their
    /// current (absolute) level. This is how a driver run reports only
    /// its own work off the shared statics.
    pub fn delta_since(&self, base: &Snapshot) -> Snapshot {
        Snapshot {
            counters: self
                .counters
                .iter()
                .map(|(n, v)| (*n, v.saturating_sub(base.counter(n))))
                .collect(),
            gauges: self.gauges.clone(),
            hists: self
                .hists
                .iter()
                .map(|(n, h)| {
                    let d = match base.hist(n) {
                        Some(b) => h.delta_since(b),
                        None => h.clone(),
                    };
                    (*n, d)
                })
                .collect(),
        }
    }

    pub fn to_json(&self) -> Json {
        let mut counters = Json::obj(vec![]);
        for (n, v) in &self.counters {
            counters.set(n, Json::num(*v as f64));
        }
        let mut gauges = Json::obj(vec![]);
        for (n, v) in &self.gauges {
            gauges.set(n, Json::num(*v as f64));
        }
        let mut hists = Json::obj(vec![]);
        for (n, h) in &self.hists {
            hists.set(n, h.to_json());
        }
        Json::obj(vec![
            ("counters", counters),
            ("gauges", gauges),
            ("hists", hists),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_index_is_bit_length() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 1);
        assert_eq!(bucket_of(2), 2);
        assert_eq!(bucket_of(3), 2);
        assert_eq!(bucket_of(4), 3);
        assert_eq!(bucket_of(1023), 10);
        assert_eq!(bucket_of(1024), 11);
        assert_eq!(bucket_of(u64::MAX), 63);
        // floors invert the index (lower bound of each bucket)
        assert_eq!(bucket_floor(0), 0);
        assert_eq!(bucket_floor(1), 1);
        assert_eq!(bucket_floor(11), 1024);
    }

    #[test]
    fn histogram_counts_and_percentiles() {
        let h = Histogram::new();
        for v in [0u64, 1, 1, 3, 100, 1000] {
            h.observe(v);
        }
        let s = h.snap();
        assert_eq!(s.count(), 6);
        assert_eq!(s.sum, 1105);
        assert!((s.mean() - 1105.0 / 6.0).abs() < 1e-9);
        // ordered buckets: 0, 1, 1, 3, 100, 1000 → p50 lands in the
        // bit-length-1 bucket (floor 1), p95 in 1000's bucket.
        assert_eq!(s.percentile(50.0), 1);
        assert_eq!(s.percentile(95.0), bucket_floor(bucket_of(1000)));
        assert_eq!(HistSnap::default().percentile(95.0), 0);
    }

    #[test]
    fn snapshot_delta_subtracts_counters_and_hists() {
        // Use registry statics additively: parallel tests may also
        // bump them, so assert on deltas of a private baseline.
        let base = snapshot();
        METRICS.rounds_opened.add(3);
        METRICS.phase_collect.observe(7);
        let d = snapshot().delta_since(&base);
        assert!(d.counter("rounds_opened") >= 3);
        assert!(d.hist("collect").unwrap().count() >= 1);
        assert_eq!(d.counter("no_such_counter"), 0);
    }

    #[test]
    fn snapshot_json_has_all_sections() {
        let j = snapshot().to_json();
        assert!(j.get("counters").get("rounds_opened").as_f64().is_some());
        assert!(j.get("gauges").get("eval_inflight").as_f64().is_some());
        assert!(j.get("hists").get("collect").get("count").as_f64().is_some());
    }
}
