//! `rtma` — the RandomTMA/SuperTMA distributed GNN training CLI.
//!
//! Subcommands:
//!   doctor                 verify manifest + backend + one smoke step
//!   datasets               generate/print dataset statistics (Table 1)
//!   partition              compare partition schemes on one dataset
//!   train                  run one full experiment (any approach)
//!   worker                 TCP worker process for distributed mode
//!   serve                  online inference server (docs/SERVING.md)
//!   bench-compare          regression-gate two bench baseline sets
//!   trace-report           fold an RTMA_TRACE JSONL file into tables
//!
//! Examples:
//!   rtma train --dataset citation-sim --approach RandomTMA --m 3 \
//!       --train-secs 30 --agg-secs 2 --save-model results/model.bin
//!   rtma serve --model results/model.bin --dataset citation-sim --quick
//!   rtma bench-compare baselines/prev baselines/current
//!   rtma partition --dataset reddit-sim --m 3
//!
//! Everything the paper's tables need beyond single runs lives in the
//! benches (`cargo bench`) — see DESIGN.md §6.

use anyhow::Result;
use random_tma::config::{Approach, RunConfig};
use random_tma::coordinator::driver::default_clusters;
use random_tma::coordinator::run_experiment;
use random_tma::gen::{load_preset, preset_names};
use random_tma::graph::stats::graph_stats;
use random_tma::model::AggregateOp;
use random_tma::partition::{partition_stats, Scheme};
use random_tma::telemetry;
use random_tma::util::bench::Table;
use random_tma::util::cli::Args;
use random_tma::util::rng::Rng;

fn main() {
    let args = Args::parse(&["quick", "jnp", "help", "no-train"]);
    let (cmd, rest) = args.subcommand();
    let result = match cmd {
        Some("doctor") => doctor(&rest),
        Some("datasets") => datasets(&rest),
        Some("partition") => partition(&rest),
        Some("train") => train(&rest),
        Some("worker") => worker(&rest),
        Some("serve") => serve(&rest),
        Some("bench-compare") => bench_compare(&rest),
        Some("trace-report") => trace_report(&rest),
        _ => {
            print_usage();
            Ok(())
        }
    };
    // Hand any buffered trace lines to the sink before exiting —
    // main's thread-local destructor is not guaranteed to run.
    telemetry::flush();
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_usage() {
    println!(
        "rtma — RandomTMA/SuperTMA distributed GNN training\n\
         \n\
         usage: rtma <doctor|datasets|partition|train|worker|serve|\
         bench-compare|trace-report> [flags]\n\
         \n\
         common flags:\n\
         \x20 --dataset <reddit-sim|citation-sim|mag-sim|ecomm-sim>\n\
         \x20 --variant <gcn_mlp|sage_mlp|mlp_mlp|gcn_distmult|rgcn_mlp|rgcn_distmult>\n\
         \x20 --approach <RandomTMA|SuperTMA|PSGD-PA|LLCG|GGS>\n\
         \x20 --m <trainers>  --train-secs <s>  --agg-secs <ρ>\n\
         \x20 --seed <u64>  --quick  --jnp (use XLA-dot artifacts)\n\
         \n\
         backend selection (precedence low to high):\n\
         \x20 manifest `backend` field (default \"native\")\n\
         \x20 RTMA_BACKEND=native|pjrt  env override\n\
         \x20 --backend native|pjrt     CLI override (see docs/ENGINE.md)\n\
         \n\
         round codec (precedence low to high; see docs/COMM.md):\n\
         \x20 --codec identity|delta|f16|i8|topk[:denom]\n\
         \x20 RTMA_CODEC=...            env override (wins)\n\
         \n\
         serving (see docs/SERVING.md):\n\
         \x20 rtma train ... --save-model <path>   persist best params\n\
         \x20 rtma serve --model <path> [--addr host:port]\n\
         \x20 RTMA_SERVE_WINDOW_US / _MAX_BATCH / _CACHE / _TOPK_SCAN\n\
         \x20 rtma bench-compare <old> <new> [--tolerance 0.2]\n\
         \n\
         telemetry (all subcommands):\n\
         \x20 RTMA_LOG=off|info|debug   stderr event level\n\
         \x20 RTMA_TRACE=<path>         append a JSONL trace\n\
         \x20 rtma trace-report --trace <path>   fold it into tables\n\
         \x20 rtma worker --no-train    protocol-only worker (no \
         engine)"
    );
}

fn run_config(args: &Args) -> RunConfig {
    let mut cfg = RunConfig {
        dataset: args.str_or("dataset", "citation-sim"),
        quick: args.flag("quick"),
        variant: args.str_or("variant", "gcn_mlp"),
        impl_name: if args.flag("jnp") {
            "jnp".into()
        } else {
            args.str_or("impl", "pallas")
        },
        backend: args.str_or("backend", ""),
        trainers: args.usize_or("m", 3),
        train_secs: args.f64_or("train-secs", 30.0),
        agg_secs: args.f64_or("agg-secs", 2.0),
        eval_edges: args.usize_or("eval-edges", 128),
        negatives: args.usize_or("negatives", 64),
        eval_sample: args.usize_or("eval-sample", 64),
        failures: args.usize_or("failures", 0),
        codec: args.str_or("codec", ""),
        save_model: args.str_or("save-model", ""),
        seed: args.u64_or("seed", 17),
        aggregate_op: if args.str_or("agg-op", "mean") == "inverse-loss" {
            AggregateOp::InverseLoss
        } else {
            AggregateOp::Mean
        },
        ..RunConfig::default()
    };
    let clusters = args.usize_or("clusters", 0);
    cfg.approach = Approach::parse(
        &args.str_or("approach", "RandomTMA"),
        clusters, // 0 = resolved against the dataset in train()
    )
    .unwrap_or(Approach::RandomTma);
    cfg
}

fn doctor(args: &Args) -> Result<()> {
    use random_tma::model::ModelState;
    use random_tma::runtime::{load_backend, ComputeBackend, Manifest};
    println!("rtma doctor");
    let mut manifest = Manifest::load_or_builtin();
    let backend_flag = args.str_or("backend", "");
    if !backend_flag.is_empty() {
        manifest.backend = backend_flag;
    }
    println!(
        "  manifest: {} variants, Bn={}, Be={}, H={} ({})",
        manifest.variants.len(),
        manifest.dims.block_nodes,
        manifest.dims.block_edges,
        manifest.dims.hidden,
        manifest.dir.display(),
    );
    let variant = args.str_or("variant", "gcn_mlp");
    let engine = load_backend(&manifest, &variant, "pallas", "doctor")?;
    engine.prepare(&["train"])?;
    println!("  engine:   {} ready", engine.describe());
    let preset = load_preset("citation-sim", true, 16, 8, 1)?;
    let s = graph_stats(&preset.graph);
    println!(
        "  dataset:  citation-sim(quick) |V|={} |E|={} h={:.2}",
        s.num_nodes, s.num_edges, s.homophily
    );
    let mut rng = Rng::new(1);
    let globals: Vec<u32> =
        (0..preset.split.train.num_nodes() as u32).collect();
    let mut sampler = random_tma::sampler::TrainSampler::new(
        preset.split.train.clone(),
        globals,
        random_tma::sampler::TrainSamplerConfig::homogeneous(
            manifest.dims.block_nodes,
            manifest.dims.block_edges,
            manifest.dims.feat_dim,
            random_tma::sampler::AdjMode::SelfLoop,
        ),
    );
    let mut state = ModelState::init(engine.variant(), &mut rng);
    let block = sampler.next_block(&mut rng).unwrap();
    let loss = engine.train_step(&mut state, block)?;
    println!("  smoke:    one train step OK, loss={loss:.4}");
    println!("doctor OK");
    Ok(())
}

fn datasets(args: &Args) -> Result<()> {
    let quick = args.flag("quick");
    let seed = args.u64_or("seed", 17);
    let mut t = Table::new(
        "Table 1: dataset statistics (synthetic substitutes)",
        &["Dataset", "#Nodes", "#Edges", "#Feat", "AvgDeg", "MaxDeg", "h",
          "#Val/Test"],
    );
    for name in preset_names() {
        let p = load_preset(name, quick, args.usize_or("eval-edges", 128),
                            8, seed)?;
        let s = graph_stats(&p.graph);
        t.row(vec![
            name.to_string(),
            s.num_nodes.to_string(),
            s.num_edges.to_string(),
            s.feat_dim.to_string(),
            format!("{:.1}", s.avg_degree),
            s.max_degree.to_string(),
            format!("{:.2}", s.homophily),
            format!("{}/{}", p.split.val.len(), p.split.test.len()),
        ]);
    }
    t.emit("table1_datasets");
    Ok(())
}

fn partition(args: &Args) -> Result<()> {
    let dataset = args.str_or("dataset", "citation-sim");
    let m = args.usize_or("m", 3);
    let quick = args.flag("quick");
    let preset = load_preset(&dataset, quick, 16, 8, args.u64_or("seed", 17))?;
    let g = &preset.split.train;
    let clusters = default_clusters(g.num_nodes());
    let mut t = Table::new(
        &format!("Partition schemes on {dataset} (M={m})"),
        &["Scheme", "r", "EdgeCut", "Balance", "ClassDisp", "FeatDisp",
          "PrepSecs"],
    );
    for scheme in [
        Scheme::Random,
        Scheme::Super { num_clusters: clusters },
        Scheme::MinCut,
    ] {
        let mut rng = Rng::new(args.u64_or("seed", 17));
        let t0 = telemetry::now();
        let assign = scheme.assign(g, m, &mut rng);
        let secs = t0.elapsed().as_secs_f64();
        let s = partition_stats(g, &assign, m);
        t.row(vec![
            scheme.name(),
            format!("{:.3}", s.ratio_r),
            s.edge_cut.to_string(),
            format!("{:.2}", s.balance),
            format!("{:.3}", s.class_disparity),
            format!("{:.3}", s.feature_disparity),
            format!("{secs:.2}"),
        ]);
    }
    t.emit("partition_study");
    Ok(())
}

fn train(args: &Args) -> Result<()> {
    let mut cfg = run_config(args);
    // Resolve SuperTMA cluster count against the actual graph size.
    if let Approach::SuperTma { num_clusters } = cfg.approach {
        if num_clusters == 0 {
            let preset = load_preset(
                &cfg.dataset,
                cfg.quick,
                cfg.eval_edges,
                cfg.negatives,
                cfg.seed,
            )?;
            cfg.approach = Approach::SuperTma {
                num_clusters: default_clusters(
                    preset.split.train.num_nodes(),
                ),
            };
        }
    }
    println!("[rtma] {}", cfg.label());
    let result = run_experiment(&cfg)?;
    println!(
        "[rtma] best val MRR {:.4} | test MRR {:.4} | conv {:.1}s | \
         steps {:?} | r={:.2} | prep {:.2}s",
        result.best_val_mrr,
        result.test_mrr,
        result.convergence_secs(0.01),
        result.steps,
        result.ratio_r,
        result.prep_secs,
    );
    let out = std::path::Path::new("results").join("last_train.json");
    result.to_json().write_file(&out)?;
    println!("[rtma] wrote {}", out.display());
    Ok(())
}

/// Online inference server (docs/SERVING.md): load the persisted best
/// parameters (`rtma train --save-model`), rebuild the preset's train
/// graph (honours `RTMA_MMAP=1` exactly like training does) and answer
/// `QueryScore`/`QueryTopK` frames until a client sends `Stop`.
///
/// Serves over the *train* split — the graph the deployed model was
/// trained and validated on — so served scores line up with the
/// evaluator's (the held-out val/test edges are what clients query).
fn serve(args: &Args) -> Result<()> {
    use anyhow::Context;
    use random_tma::coordinator::kv::GlobalWeights;
    use random_tma::runtime::Manifest;
    use random_tma::serve::{load_weights, serve as start_server, ServeConfig};
    use std::sync::Arc;

    let model = args.get("model").context(
        "--model <path> required (write one with rtma train --save-model)",
    )?;
    let params = load_weights(std::path::Path::new(model))?;
    let mut manifest = Manifest::load_or_builtin();
    let backend_flag = args.str_or("backend", "");
    if !backend_flag.is_empty() {
        manifest.backend = backend_flag;
    }
    let variant = args.str_or("variant", "gcn_mlp");
    let impl_name = if args.flag("jnp") {
        "jnp".to_string()
    } else {
        args.str_or("impl", "pallas")
    };
    let preset = load_preset(
        &args.str_or("dataset", "citation-sim"),
        args.flag("quick"),
        args.usize_or("eval-edges", 16),
        args.usize_or("negatives", 8),
        args.u64_or("seed", 17),
    )?;
    let boundary = preset.boundary;
    let graph = Arc::new(preset.split.train);
    let mut cfg = ServeConfig::from_env();
    if let Some(addr) = args.get("addr") {
        cfg.addr = addr.to_string();
    }
    let init: GlobalWeights = Arc::from(params);
    let handle = start_server(
        &cfg, graph, boundary, manifest, variant, impl_name, init,
    )?;
    // The load generator and the CI smoke parse this exact line to
    // discover the bound port — keep the format stable.
    println!("[serve] listening on {}", handle.addr());
    handle.join();
    println!("[serve] stopped");
    Ok(())
}

/// Regression gate over persisted bench baselines: compare every
/// `BENCH_*.json` section present in both trees and fail on any
/// timing/latency counter that got slower — or throughput counter
/// that got smaller — by more than `--tolerance` (default 20%). An
/// empty/missing *old* side soft-passes with a notice: the first run
/// on a branch has no prior artifact to gate against.
fn bench_compare(args: &Args) -> Result<()> {
    use random_tma::benchkit::{compare, BenchBaseline};

    let pos = args.positional();
    anyhow::ensure!(
        pos.len() == 2,
        "usage: rtma bench-compare <old dir|file> <new dir|file> \
         [--tolerance 0.2]"
    );
    let tolerance = args.f64_or("tolerance", 0.2);
    let old = collect_baselines(std::path::Path::new(&pos[0]))?;
    let new = collect_baselines(std::path::Path::new(&pos[1]))?;
    if old.is_empty() {
        println!(
            "[bench-compare] no prior baselines under {} — nothing to \
             gate against (soft pass)",
            pos[0]
        );
        return Ok(());
    }
    anyhow::ensure!(!new.is_empty(), "no BENCH_*.json under {}", pos[1]);
    let mut regressions: Vec<String> = Vec::new();
    let mut compared = 0usize;
    for (section, ob) in &old {
        match new.get(section) {
            Some(nb) => {
                compared += 1;
                let regs = compare(ob, nb, tolerance);
                println!(
                    "[bench-compare] {section}: {} timing(s), {} \
                     counter(s), {} regression(s)",
                    nb.timings.len(),
                    nb.counters.len(),
                    regs.len()
                );
                regressions.extend(regs);
            }
            None => println!(
                "[bench-compare] {section}: only in old side — skipped"
            ),
        }
    }
    for section in new.keys().filter(|s| !old.contains_key(*s)) {
        println!("[bench-compare] {section}: new section — no baseline");
    }
    if regressions.is_empty() {
        println!(
            "[bench-compare] OK: {compared} section(s) within \
             {:.0}% tolerance",
            tolerance * 100.0
        );
        return Ok(());
    }
    for r in &regressions {
        println!("[bench-compare] REGRESSION {r}");
    }
    anyhow::bail!(
        "{} bench regression(s) beyond the {:.0}% tolerance",
        regressions.len(),
        tolerance * 100.0
    )
}

/// Gather `BENCH_*.json` baselines under a file or directory, keyed
/// by section. Recurses a few levels because `gh run download` nests
/// one directory per artifact. A missing root is an empty set (the
/// soft-pass path), but a file that *is* there must parse.
fn collect_baselines(
    root: &std::path::Path,
) -> Result<std::collections::BTreeMap<
    String,
    random_tma::benchkit::BenchBaseline,
>> {
    use anyhow::Context;
    use random_tma::benchkit::BenchBaseline;
    use random_tma::util::json::Json;

    let mut out = std::collections::BTreeMap::new();
    if !root.exists() {
        return Ok(out);
    }
    let mut stack = vec![(root.to_path_buf(), 0usize)];
    while let Some((p, depth)) = stack.pop() {
        if p.is_dir() {
            if depth > 3 {
                continue;
            }
            for entry in std::fs::read_dir(&p)
                .with_context(|| format!("reading {}", p.display()))?
            {
                stack.push((entry?.path(), depth + 1));
            }
            continue;
        }
        let name = p.file_name().and_then(|n| n.to_str()).unwrap_or("");
        if !(name.starts_with("BENCH_") && name.ends_with(".json")) {
            continue;
        }
        let j = Json::read_file(&p)
            .with_context(|| format!("parsing {}", p.display()))?;
        let b = BenchBaseline::from_json(&j)
            .with_context(|| format!("validating {}", p.display()))?;
        out.insert(b.section.clone(), b);
    }
    Ok(out)
}

/// Fold a JSONL trace (`RTMA_TRACE`) into the per-round server phase
/// table + final counter totals. Doubles as the trace schema check:
/// any malformed line fails with its line number (the
/// distributed-smoke CI job runs this over the trace it recorded).
fn trace_report(args: &Args) -> Result<()> {
    use random_tma::telemetry::report::parse_trace;
    let path = match args.get("trace") {
        Some(p) => p.to_string(),
        None => std::env::var("RTMA_TRACE").map_err(|_| {
            anyhow::anyhow!("pass --trace <file> or set RTMA_TRACE")
        })?,
    };
    let text = std::fs::read_to_string(&path)
        .map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
    let rep = parse_trace(&text)?;
    println!(
        "[trace-report] {path}: {} lines ({} events, {} spans, {} \
         counter records) from {} component(s)",
        rep.lines,
        rep.events,
        rep.spans,
        rep.counter_records,
        rep.comps.len(),
    );
    println!("{}", rep.phase_table().render());
    if !rep.counters.is_empty() {
        println!("{}", rep.counter_table().render());
    }
    Ok(())
}

/// TCP worker process (distributed mode): connects to the leader,
/// trains on its partition between broadcasts, ships weights back.
/// Driven by examples/distributed_tcp.rs.
///
/// With `--no-train` it degrades to a *protocol-only* worker: it
/// holds the last broadcast weights and answers every collection with
/// them (NaN loss, 0 steps), exercising the full wire protocol with
/// no engine. Real training needs no artifacts either — the native
/// backend runs on the builtin manifest.
fn worker(args: &Args) -> Result<()> {
    use random_tma::comm::codec;
    use random_tma::comm::{
        client_handshake, recv_from, send_wire, train_until_pending, Peer,
        Message, WireMsg,
    };
    use random_tma::model::ModelState;
    use random_tma::runtime::{load_backend, ComputeBackend, Manifest};
    use random_tma::sampler::{AdjMode, TrainSampler, TrainSamplerConfig};
    use std::net::TcpStream;

    let addr = args.str_or("leader", "127.0.0.1:7117");
    let id = args.usize_or("id", 0);
    let m = args.usize_or("m", 3);
    let dataset = args.str_or("dataset", "citation-sim");
    let seed = args.u64_or("seed", 17);
    let variant = args.str_or("variant", "gcn_mlp");
    // Same precedence as the leader (identity < --codec < RTMA_CODEC);
    // the Hello/Ready handshake verifies both ends actually agree.
    let codec_kind = codec::resolve(&args.str_or("codec", ""))?;

    if args.flag("no-train") {
        telemetry::info(
            "worker",
            "protocol_only",
            &[("worker", id as f64)],
            format_args!(
                "worker {id}: protocol-only mode (no engine)"
            ),
        );
        let r = worker_protocol_only(&addr, id, codec_kind);
        telemetry::trace_counters("worker");
        telemetry::flush();
        return r;
    }

    // Load local data exactly as the in-process driver would: same
    // seed -> same partition -> this worker takes part `id`.
    let mut manifest = Manifest::load_or_builtin();
    let backend_flag = args.str_or("backend", "");
    if !backend_flag.is_empty() {
        manifest.backend = backend_flag;
    }
    let preset = load_preset(&dataset, true, 16, 8, seed)?;
    let g = &preset.split.train;
    let mut rng = Rng::new(seed ^ 0xC0FFEE);
    let assign = Scheme::Random.assign(g, m, &mut rng);
    let part: Vec<u32> = (0..g.num_nodes())
        .filter(|&v| assign[v] as usize == id)
        .map(|v| v as u32)
        .collect();
    let sub = random_tma::graph::Subgraph::induce(g, &part);
    let mut sampler = TrainSampler::new(
        sub.graph,
        sub.global_ids,
        TrainSamplerConfig::homogeneous(
            manifest.dims.block_nodes,
            manifest.dims.block_edges,
            manifest.dims.feat_dim,
            AdjMode::SelfLoop,
        ),
    );
    let engine = load_backend(&manifest, &variant, "pallas", "worker")?;
    engine.prepare(&["train"])?;
    let mut state = ModelState::init(engine.variant(), &mut rng);

    let mut stream = TcpStream::connect(&addr)?;
    client_handshake(&mut stream, id as u32, codec_kind)?;

    let mut steps = 0u64;
    let mut last_loss = f32::NAN;
    let mut trng = Rng::new(seed).fork(id as u64 + 1);
    // One reused frame buffer: round shipping encodes straight from
    // the live parameter slab into this scratch, no per-round clones.
    let mut scratch = Vec::new();
    // Reused receive buffer (frames are read into it in bounded
    // chunks — comm::recv_into) and the codec state: the last decoded
    // broadcast is the base the next upstream encode is relative to.
    let mut rbuf = Vec::new();
    let mut up_enc = (!codec_kind.is_identity()).then(|| {
        codec::RoundEncoder::new(
            codec_kind,
            seed ^ (id as u64).wrapping_mul(0x9e37_79b9),
        )
    });
    let mut base: Vec<f32> = Vec::new();
    let mut body: Vec<u8> = Vec::new();
    loop {
        match recv_from(&mut stream, &mut rbuf, Peer::Server)? {
            Message::Broadcast { round: _, data } => {
                state.set_params(&data);
                base = data;
                // Train until the leader opens the next round
                // (non-blocking peek between steps). An empty
                // partition sleeps 5 ms per poll instead of
                // busy-spinning — comm::train_until_pending.
                train_until_pending(&mut stream, || {
                    match sampler.next_block(&mut trng) {
                        Some(block) => {
                            last_loss =
                                engine.train_step(&mut state, block)?;
                            steps += 1;
                            Ok(true)
                        }
                        None => Ok(false),
                    }
                })?;
            }
            Message::BroadcastEnc { round: _, codec: cid, n, body: eb } => {
                // First broadcast decodes against the empty (= zero)
                // base, later ones against the previous broadcast —
                // mirroring the leader's encode.
                let w =
                    codec::decode_dense(cid, n as usize, &eb, &base)?;
                state.set_params(&w);
                base = w;
                train_until_pending(&mut stream, || {
                    match sampler.next_block(&mut trng) {
                        Some(block) => {
                            last_loss =
                                engine.train_step(&mut state, block)?;
                            steps += 1;
                            Ok(true)
                        }
                        None => Ok(false),
                    }
                })?;
            }
            Message::Collect { round } => match up_enc.as_mut() {
                None => send_wire(
                    &mut stream,
                    &WireMsg::Weights {
                        round,
                        loss: last_loss,
                        steps,
                        data: &state.params,
                    },
                    &mut scratch,
                )?,
                Some(enc) => {
                    let cid =
                        enc.encode_up(&state.params, &base, &mut body);
                    send_wire(
                        &mut stream,
                        &WireMsg::WeightsEnc {
                            round,
                            loss: last_loss,
                            steps,
                            codec: cid,
                            n: state.params.len() as u64,
                            body: &body,
                        },
                        &mut scratch,
                    )?;
                }
            },
            Message::Stop => {
                telemetry::info(
                    "worker",
                    "stop",
                    &[("worker", id as f64), ("steps", steps as f64)],
                    format_args!(
                        "worker {id}: stopping after {steps} steps"
                    ),
                );
                telemetry::trace_counters("worker");
                telemetry::flush();
                return Ok(());
            }
            other => {
                telemetry::info(
                    "worker",
                    "unexpected_message",
                    &[("worker", id as f64)],
                    format_args!(
                        "worker {id}: unexpected message {other:?}"
                    ),
                );
            }
        }
    }
}

/// The engine-less worker loop: same handshake, same framing, no
/// training. The weights it ships are whatever the leader last
/// broadcast, so a leader averaging them gets its own weights back —
/// a pure round-protocol + wire-counter exercise that runs on any
/// machine (the distributed-smoke CI job has no AOT artifacts).
fn worker_protocol_only(
    addr: &str,
    id: usize,
    codec_kind: random_tma::comm::codec::CodecKind,
) -> Result<()> {
    use random_tma::comm::codec;
    use random_tma::comm::{
        client_handshake, recv_from, send_wire, Message, Peer, WireMsg,
    };
    use std::net::TcpStream;

    let mut stream = TcpStream::connect(addr)?;
    client_handshake(&mut stream, id as u32, codec_kind)?;
    let mut params: Vec<f32> = Vec::new();
    let mut scratch = Vec::new();
    let mut rbuf = Vec::new();
    let mut body: Vec<u8> = Vec::new();
    let mut up_enc = (!codec_kind.is_identity()).then(|| {
        codec::RoundEncoder::new(
            codec_kind,
            0x1d1e ^ (id as u64).wrapping_mul(0x9e37_79b9),
        )
    });
    loop {
        match recv_from(&mut stream, &mut rbuf, Peer::Server)? {
            Message::Broadcast { round: _, data } => params = data,
            Message::BroadcastEnc { round: _, codec: cid, n, body: eb } => {
                params = codec::decode_dense(cid, n as usize, &eb, &params)?;
            }
            Message::Collect { round } => match up_enc.as_mut() {
                None => send_wire(
                    &mut stream,
                    &WireMsg::Weights {
                        round,
                        loss: f32::NAN, // "no batch yet" sentinel
                        steps: 0,
                        data: &params,
                    },
                    &mut scratch,
                )?,
                Some(enc) => {
                    // An idle worker's weights ARE its base (the last
                    // broadcast): sparse codecs ship near-empty bodies.
                    let cid = enc.encode_up(&params, &params, &mut body);
                    send_wire(
                        &mut stream,
                        &WireMsg::WeightsEnc {
                            round,
                            loss: f32::NAN,
                            steps: 0,
                            codec: cid,
                            n: params.len() as u64,
                            body: &body,
                        },
                        &mut scratch,
                    )?;
                }
            },
            Message::Stop => {
                telemetry::info(
                    "worker",
                    "stop",
                    &[("worker", id as f64), ("steps", 0.0)],
                    format_args!("worker {id}: stopping (protocol-only)"),
                );
                return Ok(());
            }
            other => telemetry::info(
                "worker",
                "unexpected_message",
                &[("worker", id as f64)],
                format_args!("worker {id}: unexpected message {other:?}"),
            ),
        }
    }
}
