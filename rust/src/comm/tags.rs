//! The wire-tag registry — the single place a frame tag may be born.
//!
//! Every message on the wire (training plane tags 1–9, serving plane
//! tags 10–13, see docs/COMM.md) starts with one of these bytes.
//! Declaring a tag anywhere else is a `rtma-check` violation: the
//! `wire-tags` rule parses this file, cross-checks the constants and
//! [`all`] against the tag table in docs/COMM.md, and denies stray
//! `TAG_*` constants elsewhere in the tree — so a new tag cannot
//! silently collide with an existing one or drift from the docs.
//!
//! The golden-byte tests (`tests/codec.rs`, `tests/serve.rs`) consume
//! [`all`] too: they assert uniqueness/contiguity and that encoded
//! frames lead with exactly these bytes, pinning the registry to the
//! bytes real peers see.

/// Training handshake: worker announces itself (`id: u32`).
pub const TAG_HELLO: u8 = 1;
/// Training handshake: worker is ready to take rounds (`id: u32`).
pub const TAG_READY: u8 = 2;
/// Dense upstream weights for a round (pre-codec path).
pub const TAG_WEIGHTS: u8 = 3;
/// Dense downstream broadcast of aggregated weights.
pub const TAG_BROADCAST: u8 = 4;
/// Stop: end of run (training) or end of connection (serving).
pub const TAG_STOP: u8 = 5;
/// Server opens collection round `round: u64`.
pub const TAG_COLLECT: u8 = 6;
/// Codec negotiation during the handshake (`codec: u8`).
pub const TAG_CODEC: u8 = 7;
/// Encoded upstream weights (codec id + opaque body).
pub const TAG_WEIGHTS_ENC: u8 = 8;
/// Encoded downstream broadcast (codec id + opaque body).
pub const TAG_BROADCAST_ENC: u8 = 9;
/// Serving plane: batch of `(u, v, rel)` link-score queries.
pub const TAG_QUERY_SCORE: u8 = 10;
/// Serving plane: top-k neighbours of one node.
pub const TAG_QUERY_TOPK: u8 = 11;
/// Serving plane: scores for a [`TAG_QUERY_SCORE`] batch.
pub const TAG_REPLY_SCORE: u8 = 12;
/// Serving plane: `(node, score)` items for a [`TAG_QUERY_TOPK`].
pub const TAG_REPLY_TOPK: u8 = 13;

/// Every wire tag with its canonical message name, in tag order —
/// the machine-readable registry `rtma-check` and the golden-byte
/// tests consume. The names match the `Message`/`WireMsg` variant
/// names and the docs/COMM.md tag table verbatim.
pub const fn all() -> &'static [(u8, &'static str)] {
    &[
        (TAG_HELLO, "Hello"),
        (TAG_READY, "Ready"),
        (TAG_WEIGHTS, "Weights"),
        (TAG_BROADCAST, "Broadcast"),
        (TAG_STOP, "Stop"),
        (TAG_COLLECT, "Collect"),
        (TAG_CODEC, "Codec"),
        (TAG_WEIGHTS_ENC, "WeightsEnc"),
        (TAG_BROADCAST_ENC, "BroadcastEnc"),
        (TAG_QUERY_SCORE, "QueryScore"),
        (TAG_QUERY_TOPK, "QueryTopK"),
        (TAG_REPLY_SCORE, "ReplyScore"),
        (TAG_REPLY_TOPK, "ReplyTopK"),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_is_unique_and_contiguous() {
        let tags = all();
        for (i, (tag, _)) in tags.iter().enumerate() {
            assert_eq!(
                *tag,
                i as u8 + 1,
                "tags must stay contiguous from 1 in declaration order"
            );
        }
        assert_eq!(tags.len(), 13);
    }
}
