//! Wire protocol + TCP transport for multi-process mode.
//!
//! The default benches run trainers as threads in one process (the
//! paper also co-locates trainers on machines). This module provides
//! the genuinely distributed alternative: a leader (TMA server) and
//! `rtma worker` processes exchanging the same aggregation protocol
//! over TCP. `examples/distributed_tcp.rs` drives it end to end.
//!
//! Framing: 4-byte LE length prefix + 1 tag byte + fixed header +
//! payload (f32 weights as raw LE bytes). No serde dependency. Both
//! sides enforce one shared [`MAX_FRAME`] cap: the sender bails
//! before writing a frame the receiver would refuse (an unguarded
//! `len as u32` used to silently wrap past 4 GiB and desync the
//! stream), and the receiver reads accepted bodies in bounded chunks
//! instead of allocating the announced length up front.
//!
//! Round payloads can travel compressed: `WeightsEnc`/`BroadcastEnc`
//! frames carry a codec id plus an opaque encoded body (see
//! [`codec`]), and the `Codec` message negotiates the session codec
//! during the `Hello`/`Ready` handshake so mismatched peers fail
//! loudly instead of mis-decoding each other's bodies. With the
//! default `identity` codec the data plane uses the plain
//! `Weights`/`Broadcast` frames — bit-for-bit the pre-codec wire
//! (pinned by `tests/codec.rs`).
//!
//! The serving plane (`rtma serve`, docs/SERVING.md) rides the same
//! framing: `QueryScore`/`QueryTopK` requests and their
//! `ReplyScore`/`ReplyTopK` responses (tags 10–13) obey the identical
//! `MAX_FRAME` cap and length-prefix discipline, with a codec-free
//! `Hello`/`Ready` handshake ([`serve_client_handshake`] /
//! [`serve_server_handshake`]) because query bodies are tiny.

#![deny(clippy::unwrap_used)]

pub mod codec;
pub mod tags;

use std::io::{Read, Write};
use std::net::TcpStream;

use anyhow::{bail, Result};

use crate::telemetry::metrics;

/// Infallible `&[u8] -> [u8; N]` for slices whose length the caller
/// just checked or produced (`take(N)`, `chunks_exact(N)`) — the
/// lint-clean spelling of `try_into().unwrap()` on the decode paths
/// (`clippy::unwrap_used` is denied in `comm` and `serve`).
#[inline]
pub(crate) fn le_bytes<const N: usize>(b: &[u8]) -> [u8; N] {
    let mut a = [0u8; N];
    a.copy_from_slice(b);
    a
}

/// Hard cap on one frame's encoded length (bytes, excluding the
/// 4-byte prefix). Shared by [`send_wire`] (bail before writing) and
/// [`recv_into`] (refuse the prefix before reading the body); fits a
/// 256M-parameter dense weight vector.
pub const MAX_FRAME: usize = 1 << 30;

/// Protocol messages between leader and workers.
#[derive(Debug, Clone, PartialEq)]
pub enum Message {
    /// Worker -> leader: join with a trainer id.
    Hello { id: u32 },
    /// Worker -> leader: local data loaded, ready to train.
    Ready { id: u32 },
    /// Worker -> leader: local weights at an aggregation round.
    Weights { round: u64, loss: f32, steps: u64, data: Vec<f32> },
    /// Leader -> worker: global weights (round 0 = initial broadcast).
    Broadcast { round: u64, data: Vec<f32> },
    /// Leader -> worker: aggregation round `round` is open — ship your
    /// local weights now (the `KV[agg]` signal of Alg 1/2).
    Collect { round: u64 },
    /// Leader -> worker: stop training and report.
    Stop,
    /// Both directions during the handshake: the sender's round codec
    /// family id (`codec::CODEC_*`). Workers announce theirs after
    /// `Hello`; the leader acks with its own after `Ready`.
    Codec { codec: u8 },
    /// Worker -> leader: codec-encoded local weights. `codec` is the
    /// *actual* encoding id of `body`; `n` is the decoded element
    /// count.
    WeightsEnc {
        round: u64,
        loss: f32,
        steps: u64,
        codec: u8,
        n: u64,
        body: Vec<u8>,
    },
    /// Leader -> worker: codec-encoded global weights.
    BroadcastEnc { round: u64, codec: u8, n: u64, body: Vec<u8> },
    /// Client -> server: score `(u, v, rel)` link candidates. `rel`
    /// is the decoder relation id, or `-1` to let the server derive
    /// it from the graph's boundary (docs/SERVING.md).
    QueryScore { id: u64, pairs: Vec<(u32, u32, i32)> },
    /// Client -> server: the `k` highest-scoring CSR neighbours of
    /// `node`.
    QueryTopK { id: u64, node: u32, k: u32 },
    /// Server -> client: one score per queried pair, in order.
    ReplyScore { id: u64, scores: Vec<f32> },
    /// Server -> client: `(neighbour, score)` descending by score.
    ReplyTopK { id: u64, items: Vec<(u32, f32)> },
}

/// Borrowed view of a [`Message`] for zero-clone sends: the weight
/// payloads reference the caller's live buffers — a trainer's current
/// parameters, the leader's shared global slab — instead of owning a
/// per-send copy. Encode with [`WireMsg::encode_into`] through a
/// reused scratch buffer ([`send_wire`]); the receive side still
/// decodes into an owned [`Message`].
#[derive(Debug, Clone, Copy)]
pub enum WireMsg<'a> {
    Hello { id: u32 },
    Ready { id: u32 },
    Weights { round: u64, loss: f32, steps: u64, data: &'a [f32] },
    Broadcast { round: u64, data: &'a [f32] },
    Collect { round: u64 },
    Stop,
    Codec { codec: u8 },
    WeightsEnc {
        round: u64,
        loss: f32,
        steps: u64,
        codec: u8,
        n: u64,
        body: &'a [u8],
    },
    BroadcastEnc { round: u64, codec: u8, n: u64, body: &'a [u8] },
    QueryScore { id: u64, pairs: &'a [(u32, u32, i32)] },
    QueryTopK { id: u64, node: u32, k: u32 },
    ReplyScore { id: u64, scores: &'a [f32] },
    ReplyTopK { id: u64, items: &'a [(u32, f32)] },
}

// Wire tags live in one registry module ([`tags`]) so a new tag
// cannot silently collide and docs/COMM.md stays machine-checked
// against the constants (`rtma-check`'s wire-tags rule).
use tags::{
    TAG_BROADCAST, TAG_BROADCAST_ENC, TAG_CODEC, TAG_COLLECT, TAG_HELLO,
    TAG_QUERY_SCORE, TAG_QUERY_TOPK, TAG_READY, TAG_REPLY_SCORE,
    TAG_REPLY_TOPK, TAG_STOP, TAG_WEIGHTS, TAG_WEIGHTS_ENC,
};

impl WireMsg<'_> {
    /// Encode into `out`, clearing it first. Callers keep one scratch
    /// buffer per connection, so steady-state encodes reuse its
    /// capacity and allocate nothing.
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        out.clear();
        match *self {
            WireMsg::Hello { id } => {
                out.push(TAG_HELLO);
                out.extend_from_slice(&id.to_le_bytes());
            }
            WireMsg::Ready { id } => {
                out.push(TAG_READY);
                out.extend_from_slice(&id.to_le_bytes());
            }
            WireMsg::Weights { round, loss, steps, data } => {
                out.push(TAG_WEIGHTS);
                out.extend_from_slice(&round.to_le_bytes());
                out.extend_from_slice(&loss.to_le_bytes());
                out.extend_from_slice(&steps.to_le_bytes());
                out.extend_from_slice(&(data.len() as u64).to_le_bytes());
                put_f32s(out, data);
            }
            WireMsg::Broadcast { round, data } => {
                out.push(TAG_BROADCAST);
                out.extend_from_slice(&round.to_le_bytes());
                out.extend_from_slice(&(data.len() as u64).to_le_bytes());
                put_f32s(out, data);
            }
            WireMsg::Collect { round } => {
                out.push(TAG_COLLECT);
                out.extend_from_slice(&round.to_le_bytes());
            }
            WireMsg::Stop => out.push(TAG_STOP),
            WireMsg::Codec { codec } => {
                out.push(TAG_CODEC);
                out.push(codec);
            }
            WireMsg::WeightsEnc { round, loss, steps, codec, n, body } => {
                out.push(TAG_WEIGHTS_ENC);
                out.extend_from_slice(&round.to_le_bytes());
                out.extend_from_slice(&loss.to_le_bytes());
                out.extend_from_slice(&steps.to_le_bytes());
                out.push(codec);
                out.extend_from_slice(&n.to_le_bytes());
                out.extend_from_slice(body);
            }
            WireMsg::BroadcastEnc { round, codec, n, body } => {
                out.push(TAG_BROADCAST_ENC);
                out.extend_from_slice(&round.to_le_bytes());
                out.push(codec);
                out.extend_from_slice(&n.to_le_bytes());
                out.extend_from_slice(body);
            }
            WireMsg::QueryScore { id, pairs } => {
                out.push(TAG_QUERY_SCORE);
                out.extend_from_slice(&id.to_le_bytes());
                out.extend_from_slice(&(pairs.len() as u64).to_le_bytes());
                for &(u, v, rel) in pairs {
                    out.extend_from_slice(&u.to_le_bytes());
                    out.extend_from_slice(&v.to_le_bytes());
                    out.extend_from_slice(&rel.to_le_bytes());
                }
            }
            WireMsg::QueryTopK { id, node, k } => {
                out.push(TAG_QUERY_TOPK);
                out.extend_from_slice(&id.to_le_bytes());
                out.extend_from_slice(&node.to_le_bytes());
                out.extend_from_slice(&k.to_le_bytes());
            }
            WireMsg::ReplyScore { id, scores } => {
                out.push(TAG_REPLY_SCORE);
                out.extend_from_slice(&id.to_le_bytes());
                out.extend_from_slice(&(scores.len() as u64).to_le_bytes());
                put_f32s(out, scores);
            }
            WireMsg::ReplyTopK { id, items } => {
                out.push(TAG_REPLY_TOPK);
                out.extend_from_slice(&id.to_le_bytes());
                out.extend_from_slice(&(items.len() as u64).to_le_bytes());
                for &(node, score) in items {
                    out.extend_from_slice(&node.to_le_bytes());
                    out.extend_from_slice(&score.to_le_bytes());
                }
            }
        }
    }
}

impl Message {
    /// Borrowed wire view of this message (payloads by reference).
    pub fn wire(&self) -> WireMsg<'_> {
        match self {
            Message::Hello { id } => WireMsg::Hello { id: *id },
            Message::Ready { id } => WireMsg::Ready { id: *id },
            Message::Weights { round, loss, steps, data } => {
                WireMsg::Weights {
                    round: *round,
                    loss: *loss,
                    steps: *steps,
                    data,
                }
            }
            Message::Broadcast { round, data } => {
                WireMsg::Broadcast { round: *round, data }
            }
            Message::Collect { round } => {
                WireMsg::Collect { round: *round }
            }
            Message::Stop => WireMsg::Stop,
            Message::Codec { codec } => WireMsg::Codec { codec: *codec },
            Message::WeightsEnc { round, loss, steps, codec, n, body } => {
                WireMsg::WeightsEnc {
                    round: *round,
                    loss: *loss,
                    steps: *steps,
                    codec: *codec,
                    n: *n,
                    body,
                }
            }
            Message::BroadcastEnc { round, codec, n, body } => {
                WireMsg::BroadcastEnc {
                    round: *round,
                    codec: *codec,
                    n: *n,
                    body,
                }
            }
            Message::QueryScore { id, pairs } => {
                WireMsg::QueryScore { id: *id, pairs }
            }
            Message::QueryTopK { id, node, k } => {
                WireMsg::QueryTopK { id: *id, node: *node, k: *k }
            }
            Message::ReplyScore { id, scores } => {
                WireMsg::ReplyScore { id: *id, scores }
            }
            Message::ReplyTopK { id, items } => {
                WireMsg::ReplyTopK { id: *id, items }
            }
        }
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut b = Vec::new();
        self.wire().encode_into(&mut b);
        b
    }

    pub fn decode(b: &[u8]) -> Result<Message> {
        Message::decode_from(b, Peer::Unknown)
    }

    /// [`Message::decode`] with the sending peer's role threaded in:
    /// a bad tag then reports *who* sent *how much*, so a
    /// mis-negotiated codec or desynced stream is triaged from the
    /// error line instead of a packet capture.
    pub fn decode_from(b: &[u8], peer: Peer) -> Result<Message> {
        let mut cur = Cursor { b, i: 0 };
        let tag = cur.u8()?;
        Ok(match tag {
            TAG_HELLO => Message::Hello { id: cur.u32()? },
            TAG_READY => Message::Ready { id: cur.u32()? },
            TAG_WEIGHTS => {
                let round = cur.u64()?;
                let loss = cur.f32()?;
                let steps = cur.u64()?;
                let n = cur.u64()? as usize;
                Message::Weights { round, loss, steps, data: cur.f32s(n)? }
            }
            TAG_BROADCAST => {
                let round = cur.u64()?;
                let n = cur.u64()? as usize;
                Message::Broadcast { round, data: cur.f32s(n)? }
            }
            TAG_COLLECT => Message::Collect { round: cur.u64()? },
            TAG_STOP => Message::Stop,
            TAG_CODEC => Message::Codec { codec: cur.u8()? },
            TAG_WEIGHTS_ENC => {
                let round = cur.u64()?;
                let loss = cur.f32()?;
                let steps = cur.u64()?;
                let codec = cur.u8()?;
                let n = cur.u64()?;
                Message::WeightsEnc {
                    round,
                    loss,
                    steps,
                    codec,
                    n,
                    body: cur.rest().to_vec(),
                }
            }
            TAG_BROADCAST_ENC => {
                let round = cur.u64()?;
                let codec = cur.u8()?;
                let n = cur.u64()?;
                Message::BroadcastEnc {
                    round,
                    codec,
                    n,
                    body: cur.rest().to_vec(),
                }
            }
            TAG_QUERY_SCORE => {
                let id = cur.u64()?;
                let mut pairs = Vec::new();
                decode_pairs_into(&mut cur, &mut pairs)?;
                Message::QueryScore { id, pairs }
            }
            TAG_QUERY_TOPK => Message::QueryTopK {
                id: cur.u64()?,
                node: cur.u32()?,
                k: cur.u32()?,
            },
            TAG_REPLY_SCORE => {
                let id = cur.u64()?;
                let n = cur.u64()? as usize;
                Message::ReplyScore { id, scores: cur.f32s(n)? }
            }
            TAG_REPLY_TOPK => {
                let id = cur.u64()?;
                let n = cur.u64()? as usize;
                // Bound the reservation by what the frame can actually
                // hold (8 bytes per item) before trusting the count.
                if n > cur.remaining() / 8 {
                    bail!("truncated message");
                }
                let mut items = Vec::with_capacity(n);
                for _ in 0..n {
                    let node = cur.u32()?;
                    let score = cur.f32()?;
                    items.push((node, score));
                }
                Message::ReplyTopK { id, items }
            }
            other => bail!(
                "bad message tag {other} (frame len {} B, peer {})",
                b.len(),
                peer.as_str()
            ),
        })
    }
}

/// Which peer produced the frame being decoded — threaded into
/// [`Message::decode_from`] / [`recv_from`] so wire errors name the
/// sending side of the connection.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Peer {
    /// The training-plane leader (TMA server).
    Server,
    /// A training-plane worker (`rtma worker`).
    Trainer,
    /// An inference server (`rtma serve`).
    ServeServer,
    /// A serving-plane query client.
    ServeClient,
    /// Role not threaded through this call path.
    Unknown,
}

impl Peer {
    pub fn as_str(self) -> &'static str {
        match self {
            Peer::Server => "server",
            Peer::Trainer => "trainer",
            Peer::ServeServer => "serve-server",
            Peer::ServeClient => "serve-client",
            Peer::Unknown => "unknown",
        }
    }
}

/// Decode the `count + count×(u32,u32,i32)` tail of a score query
/// into the caller's reused buffer (cleared first). Shared by the
/// owned [`Message::decode`] path and the serve reader's zero-alloc
/// [`decode_score_query_into`].
fn decode_pairs_into(
    cur: &mut Cursor<'_>,
    pairs: &mut Vec<(u32, u32, i32)>,
) -> Result<()> {
    let n = cur.u64()? as usize;
    // 12 bytes per pair: refuse a hostile count before reserving.
    if n > cur.remaining() / 12 {
        bail!("truncated message");
    }
    pairs.clear();
    pairs.reserve(n);
    for _ in 0..n {
        let u = cur.u32()?;
        let v = cur.u32()?;
        let rel = cur.u32()? as i32;
        pairs.push((u, v, rel));
    }
    Ok(())
}

/// Zero-alloc decode of a `QueryScore` frame into the caller's reused
/// pair buffer: returns `Ok(Some(id))` and fills `pairs` when `b` is
/// a score query, `Ok(None)` for any other tag (fall back to
/// [`Message::decode`]), and an error for a malformed score query.
/// Steady-state serving decodes every hot-path request through a
/// recycled `Vec` with no per-request allocation.
pub fn decode_score_query_into(
    b: &[u8],
    pairs: &mut Vec<(u32, u32, i32)>,
) -> Result<Option<u64>> {
    if b.first() != Some(&TAG_QUERY_SCORE) {
        return Ok(None);
    }
    let mut cur = Cursor { b, i: 1 };
    let id = cur.u64()?;
    decode_pairs_into(&mut cur, pairs)?;
    Ok(Some(id))
}

/// Append `data` as raw little-endian f32 bytes. Weight vectors run to
/// millions of parameters, so this is the encode hot loop: on LE hosts
/// (every deployment target) it is a single bulk copy rather than a
/// per-element `to_le_bytes` round-trip through a 4-byte temporary.
fn put_f32s(out: &mut Vec<u8>, data: &[f32]) {
    if cfg!(target_endian = "little") {
        // SAFETY: f32 and [u8; 4] have the same size with no invalid
        // bit patterns, `data` is a fully initialized slice, and u8
        // has the weakest alignment — reinterpreting the buffer as
        // bytes is sound. On little-endian hosts the in-memory layout
        // already equals the wire format.
        let bytes = unsafe {
            std::slice::from_raw_parts(
                data.as_ptr().cast::<u8>(),
                std::mem::size_of_val(data),
            )
        };
        out.extend_from_slice(bytes);
    } else {
        out.reserve(4 * data.len());
        for x in data {
            out.extend_from_slice(&x.to_le_bytes());
        }
    }
}

struct Cursor<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        // `remaining` form rather than `i + n` so a huge `n` can't
        // overflow the bound check.
        if n > self.b.len() - self.i {
            bail!("truncated message");
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    fn remaining(&self) -> usize {
        self.b.len() - self.i
    }
    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(le_bytes(self.take(4)?)))
    }
    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(le_bytes(self.take(8)?)))
    }
    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(le_bytes(self.take(4)?)))
    }
    /// All remaining bytes (encoded codec bodies run to the end of
    /// the frame — the outer length prefix already bounds them).
    fn rest(&mut self) -> &'a [u8] {
        let s = &self.b[self.i..];
        self.i = self.b.len();
        s
    }
    fn f32s(&mut self, n: usize) -> Result<Vec<f32>> {
        // A hostile element count must not wrap the byte length into a
        // small (and then "successful") read.
        let Some(bytes) = n.checked_mul(4) else {
            bail!("f32 count overflow: {n}");
        };
        let raw = self.take(bytes)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(le_bytes(c)))
            .collect())
    }
}

/// Write one length-prefixed message, encoding through `scratch` —
/// the caller's reused per-connection buffer. `Weights`/`Broadcast`
/// payloads are written straight from the borrowed slab, so the
/// steady-state round path neither clones the weight vector nor
/// allocates the frame. Frames over [`MAX_FRAME`] bail *before any
/// byte is written*: the old code framed with an unguarded
/// `len as u32`, so an oversized payload was only caught by the
/// receiver (and one over 4 GiB wrapped the prefix and desynced the
/// stream).
pub fn send_wire(
    stream: &mut TcpStream,
    msg: &WireMsg<'_>,
    scratch: &mut Vec<u8>,
) -> Result<()> {
    send_wire_capped(stream, msg, scratch, MAX_FRAME)
}

/// [`send_wire`] with an explicit cap — generic over the sink so the
/// sender-side bail is testable without a 1 GiB payload.
fn send_wire_capped<W: Write>(
    stream: &mut W,
    msg: &WireMsg<'_>,
    scratch: &mut Vec<u8>,
    cap: usize,
) -> Result<()> {
    let cap_before = scratch.capacity();
    msg.encode_into(scratch);
    if scratch.len() > cap {
        metrics().comm_frames_rejected.inc();
        bail!(
            "refusing to send {}-byte frame: exceeds the {cap}-byte \
             frame cap (the receiver would reject it)",
            scratch.len()
        );
    }
    // Wire counters: did this encode reuse the scratch allocation
    // (steady state) or grow it (first frame of a new high-water
    // mark)? Plus raw frame/byte totals for `trace-report`.
    let m = metrics();
    if scratch.capacity() > cap_before {
        m.comm_scratch_grow.inc();
    } else {
        m.comm_scratch_reuse.inc();
    }
    m.comm_frames_out.inc();
    m.comm_bytes_out.add(4 + scratch.len() as u64);
    stream.write_all(&(scratch.len() as u32).to_le_bytes())?;
    stream.write_all(scratch)?;
    stream.flush()?;
    Ok(())
}

/// Write one length-prefixed message (allocating convenience wrapper
/// over [`send_wire`] for the infrequent control messages).
pub fn send(stream: &mut TcpStream, msg: &Message) -> Result<()> {
    let mut scratch = Vec::new();
    send_wire(stream, &msg.wire(), &mut scratch)
}

/// Drive a worker's local training until the leader's next message is
/// pending on `stream` (or the peer hung up). `step` returns
/// `Ok(true)` after training one step and `Ok(false)` when it had no
/// work — an empty partition after failures. The no-work path sleeps
/// 5 ms between socket polls, mirroring the in-process trainer's idle
/// sleep: before this, a data-less worker's peek loop spun hot on
/// `WouldBlock` with no sleep and no train step, pinning a core at
/// 100% for the whole run. Blocking mode is restored on every exit
/// path.
pub fn train_until_pending(
    stream: &mut TcpStream,
    mut step: impl FnMut() -> Result<bool>,
) -> Result<()> {
    stream.set_nonblocking(true)?;
    let outcome = loop {
        let mut peek = [0u8; 1];
        match stream.peek(&mut peek) {
            Ok(_) => break Ok(()), // message waiting, or clean EOF
            Err(ref e)
                if e.kind() == std::io::ErrorKind::WouldBlock => {}
            Err(e) => break Err(e.into()),
        }
        match step() {
            Ok(true) => {}
            Ok(false) => {
                std::thread::sleep(std::time::Duration::from_millis(5))
            }
            Err(e) => break Err(e),
        }
    };
    stream.set_nonblocking(false)?;
    outcome
}

/// Body bytes pulled per `read_exact` call in [`recv_into`]: bounds
/// how much memory a garbage length prefix can commit before the
/// stream runs dry.
const RECV_CHUNK: usize = 64 * 1024;

/// Read one length-prefixed message into the caller's reused scratch
/// buffer (blocking) — the receive-side mirror of [`send_wire`]'s
/// scratch discipline. The body is read in [`RECV_CHUNK`]-bounded
/// slices, so an accepted-but-bogus prefix (the old code did
/// `vec![0u8; n]` for anything under the cap before reading a single
/// body byte) grows the buffer only as far as the peer actually
/// delivers. Rejected prefixes and undecodable frames bump the
/// `comm_frames_rejected` counter.
pub fn recv_into<R: Read>(
    stream: &mut R,
    scratch: &mut Vec<u8>,
) -> Result<Message> {
    recv_from(stream, scratch, Peer::Unknown)
}

/// [`recv_into`] with the sending peer's role threaded into decode
/// errors (see [`Message::decode_from`]).
pub fn recv_from<R: Read>(
    stream: &mut R,
    scratch: &mut Vec<u8>,
    peer: Peer,
) -> Result<Message> {
    recv_frame_into(stream, scratch)?;
    match Message::decode_from(scratch, peer) {
        Ok(m) => Ok(m),
        Err(e) => {
            metrics().comm_frames_rejected.inc();
            Err(e)
        }
    }
}

/// The framing half of [`recv_into`]: read one length-prefixed frame
/// body into `scratch` (cap check, chunked reads, wire counters)
/// *without* decoding it. The serve reader uses this to dispatch on
/// the raw tag byte and decode hot-path queries zero-alloc
/// ([`decode_score_query_into`]); callers that take this path must
/// bump `comm_frames_rejected` themselves on a decode failure, as
/// [`recv_into`] does.
pub fn recv_frame_into<R: Read>(
    stream: &mut R,
    scratch: &mut Vec<u8>,
) -> Result<()> {
    let mut len = [0u8; 4];
    stream.read_exact(&mut len)?;
    let n = u32::from_le_bytes(len) as usize;
    if n > MAX_FRAME {
        metrics().comm_frames_rejected.inc();
        bail!("message too large: {n}");
    }
    scratch.clear();
    let mut got = 0usize;
    while got < n {
        let take = (n - got).min(RECV_CHUNK);
        scratch.resize(got + take, 0);
        stream.read_exact(&mut scratch[got..got + take])?;
        got += take;
    }
    metrics().comm_frames_in.inc();
    metrics().comm_bytes_in.add(4 + n as u64);
    Ok(())
}

/// Read one length-prefixed message (allocating convenience wrapper
/// over [`recv_into`] for handshake and control paths).
pub fn recv(stream: &mut TcpStream) -> Result<Message> {
    recv_as(stream, Peer::Unknown)
}

/// [`recv`] with the sending peer's role threaded into decode errors.
pub fn recv_as(stream: &mut TcpStream, peer: Peer) -> Result<Message> {
    let mut scratch = Vec::new();
    recv_from(stream, &mut scratch, peer)
}

/// Worker side of the connection handshake: announce `id` and the
/// configured codec, signal ready, then check the leader's codec ack.
/// A family mismatch fails loudly here — before any round frame could
/// be mis-decoded.
pub fn client_handshake(
    stream: &mut TcpStream,
    id: u32,
    codec: codec::CodecKind,
) -> Result<()> {
    send(stream, &Message::Hello { id })?;
    send(stream, &Message::Codec { codec: codec.id() })?;
    send(stream, &Message::Ready { id })?;
    match recv_as(stream, Peer::Server)? {
        Message::Codec { codec: leader } if leader == codec.id() => Ok(()),
        Message::Codec { codec: leader } => bail!(
            "codec mismatch: leader runs codec id {leader}, this worker \
             is configured for {} (id {})",
            codec.name(),
            codec.id()
        ),
        other => bail!("expected leader codec ack, got {other:?}"),
    }
}

/// Leader side of the connection handshake: expect `Hello`, `Codec`,
/// `Ready` in order, verify the codec family matches, and ack with
/// ours. Returns the worker id. A worker that skips the `Codec`
/// announcement (a pre-codec build) fails loudly too.
pub fn server_handshake(
    stream: &mut TcpStream,
    codec: codec::CodecKind,
) -> Result<u32> {
    let id = match recv_as(stream, Peer::Trainer)? {
        Message::Hello { id } => id,
        other => bail!("expected Hello, got {other:?}"),
    };
    match recv_as(stream, Peer::Trainer)? {
        Message::Codec { codec: worker } if worker == codec.id() => {}
        Message::Codec { codec: worker } => bail!(
            "codec mismatch: worker {id} runs codec id {worker}, leader \
             is configured for {} (id {})",
            codec.name(),
            codec.id()
        ),
        other => bail!(
            "worker {id} did not negotiate a codec (got {other:?}) — \
             peer predates codec negotiation"
        ),
    }
    match recv_as(stream, Peer::Trainer)? {
        Message::Ready { .. } => {}
        other => bail!("expected Ready from worker {id}, got {other:?}"),
    }
    send(stream, &Message::Codec { codec: codec.id() })?;
    Ok(id)
}

/// Client side of the serving handshake: announce an id, expect the
/// server's `Ready` ack. No codec negotiation — query frames are
/// always plain (docs/SERVING.md).
pub fn serve_client_handshake(stream: &mut TcpStream, id: u32) -> Result<()> {
    send(stream, &Message::Hello { id })?;
    match recv_as(stream, Peer::ServeServer)? {
        Message::Ready { .. } => Ok(()),
        other => bail!("expected serve Ready ack, got {other:?}"),
    }
}

/// Server side of the serving handshake: expect `Hello`, ack `Ready`,
/// return the client id. A training worker that opens with a `Codec`
/// frame (or anything else) is refused loudly here.
pub fn serve_server_handshake(stream: &mut TcpStream) -> Result<u32> {
    let id = match recv_as(stream, Peer::ServeClient)? {
        Message::Hello { id } => id,
        other => bail!("expected Hello from serve client, got {other:?}"),
    };
    send(stream, &Message::Ready { id })?;
    Ok(id)
}

#[cfg(test)]
// Tests assert through unwrap by design — a panic is the failure.
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    #[test]
    fn encode_decode_roundtrip() {
        let msgs = vec![
            Message::Hello { id: 7 },
            Message::Ready { id: 3 },
            Message::Weights {
                round: 9,
                loss: 1.25,
                steps: 42,
                data: vec![1.0, -2.5, 3.25],
            },
            Message::Broadcast { round: 2, data: vec![0.0; 100] },
            Message::Collect { round: 5 },
            Message::Stop,
        ];
        for m in msgs {
            assert_eq!(Message::decode(&m.encode()).unwrap(), m);
        }
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(Message::decode(&[]).is_err());
        assert!(Message::decode(&[99]).is_err());
        assert!(Message::decode(&[TAG_WEIGHTS, 1, 2]).is_err());
    }

    /// A bad tag names the tag, the frame length, and the sending
    /// peer's role — triage without a packet capture. The generic
    /// [`Message::decode`] path reports the role as "unknown".
    #[test]
    fn bad_tag_error_reports_frame_len_and_peer() {
        let frame = [200u8, 1, 2, 3, 4];
        let err = Message::decode_from(&frame, Peer::Trainer).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("bad message tag 200"), "{msg}");
        assert!(msg.contains("frame len 5 B"), "{msg}");
        assert!(msg.contains("peer trainer"), "{msg}");

        let err = Message::decode(&frame).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("peer unknown"), "{msg}");
    }

    #[test]
    fn decode_rejects_overflowing_element_count() {
        // Broadcast frame whose u64 element count would wrap n*4.
        let mut b = vec![TAG_BROADCAST];
        b.extend_from_slice(&1u64.to_le_bytes()); // round
        b.extend_from_slice(&u64::MAX.to_le_bytes()); // count
        assert!(Message::decode(&b).is_err());
    }

    #[test]
    fn decode_rejects_truncated_weights_body() {
        let msg = Message::Weights {
            round: 2,
            loss: 1.0,
            steps: 9,
            data: vec![0.5; 100],
        };
        let body = msg.encode();
        // Header is 29 bytes (tag + round + loss + steps + count);
        // every cut below the promised payload length must error, not
        // yield a short vector.
        for cut in [body.len() - 1, body.len() - 50, 30, 29, 10] {
            assert!(Message::decode(&body[..cut]).is_err(), "cut={cut}");
        }
    }

    #[test]
    fn large_weights_roundtrip_bulk_encode() {
        // ≥1M f32 parameters: the bulk LE encode path must round-trip
        // bit-exactly and lay bytes out identically to `to_le_bytes`.
        let n = 1 << 20;
        let data: Vec<f32> =
            (0..n).map(|i| (i as f32) * 0.5 - 3.0).collect();
        let msg = Message::Weights {
            round: 3,
            loss: f32::NAN,
            steps: 7,
            data: data.clone(),
        };
        let b = msg.encode();
        assert_eq!(b.len(), 29 + 4 * n);
        assert_eq!(&b[29..33], &data[0].to_le_bytes());
        assert_eq!(&b[b.len() - 4..], &data[n - 1].to_le_bytes());
        match Message::decode(&b).unwrap() {
            Message::Weights { round, loss, steps, data: d } => {
                assert_eq!(round, 3);
                assert!(loss.is_nan(), "NaN loss must survive the wire");
                assert_eq!(steps, 7);
                assert_eq!(d.len(), n);
                assert!(d
                    .iter()
                    .zip(&data)
                    .all(|(a, b)| a.to_bits() == b.to_bits()));
            }
            other => panic!("decoded wrong message: {other:?}"),
        }
    }

    #[test]
    fn recv_rejects_oversized_length_prefix() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let h = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            // 2 GiB frame announcement: recv must refuse before
            // attempting the allocation.
            s.write_all(&(1u32 << 31).to_le_bytes()).unwrap();
            s.flush().unwrap();
        });
        let mut client = TcpStream::connect(addr).unwrap();
        let err = recv(&mut client).unwrap_err();
        assert!(err.to_string().contains("too large"), "{err}");
        h.join().unwrap();
    }

    #[test]
    fn recv_errors_on_weights_truncated_mid_stream() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let h = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let msg = Message::Weights {
                round: 1,
                loss: 0.0,
                steps: 5,
                data: vec![1.0; 256],
            };
            let body = msg.encode();
            // Promise the full frame, deliver half, drop the socket.
            s.write_all(&(body.len() as u32).to_le_bytes()).unwrap();
            s.write_all(&body[..body.len() / 2]).unwrap();
            s.flush().unwrap();
        });
        let mut client = TcpStream::connect(addr).unwrap();
        assert!(recv(&mut client).is_err(), "half a payload must error");
        h.join().unwrap();
    }

    #[test]
    fn wire_encoding_matches_owned_encoding() {
        let msgs = vec![
            Message::Hello { id: 7 },
            Message::Ready { id: 3 },
            Message::Weights {
                round: 9,
                loss: 1.25,
                steps: 42,
                data: vec![1.0, -2.5, 3.25],
            },
            Message::Broadcast { round: 2, data: vec![0.5; 100] },
            Message::Collect { round: 5 },
            Message::Stop,
        ];
        let mut scratch = Vec::new();
        for m in &msgs {
            m.wire().encode_into(&mut scratch);
            assert_eq!(scratch, m.encode(), "{m:?}");
        }
    }

    #[test]
    fn encode_into_reuses_scratch_capacity() {
        let big = Message::Broadcast {
            round: 1,
            data: (0..10_000).map(|i| i as f32).collect(),
        };
        let mut scratch = Vec::new();
        big.wire().encode_into(&mut scratch);
        let cap = scratch.capacity();
        let ptr = scratch.as_ptr();
        // A smaller frame into the same buffer: no reallocation, and
        // the stale tail must not leak into the shorter encoding.
        let small = Message::Collect { round: 3 };
        small.wire().encode_into(&mut scratch);
        assert_eq!(scratch.capacity(), cap);
        assert_eq!(scratch.as_ptr(), ptr);
        assert_eq!(Message::decode(&scratch).unwrap(), small);
    }

    #[test]
    fn send_wire_writes_borrowed_payload() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let h = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            recv(&mut s).unwrap()
        });
        let mut client = TcpStream::connect(addr).unwrap();
        let slab: Vec<f32> = (0..512).map(|i| i as f32 * 0.25).collect();
        let mut scratch = Vec::new();
        send_wire(
            &mut client,
            &WireMsg::Broadcast { round: 4, data: &slab },
            &mut scratch,
        )
        .unwrap();
        match h.join().unwrap() {
            Message::Broadcast { round, data } => {
                assert_eq!(round, 4);
                assert_eq!(data, slab);
            }
            other => panic!("wrong message {other:?}"),
        }
    }

    #[test]
    fn idle_worker_sleeps_instead_of_busy_spinning() {
        // Regression: a worker with an empty partition (step has no
        // work) used to spin the peek loop hot on WouldBlock — no
        // sleep, no step — pinning a core. With the 5 ms idle sleep,
        // ~100 ms of leader silence yields tens of polls, not
        // hundreds of thousands.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let h = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            std::thread::sleep(std::time::Duration::from_millis(100));
            s.write_all(&[1u8]).unwrap(); // pending byte releases the loop
            s.flush().unwrap();
            s
        });
        let mut client = TcpStream::connect(addr).unwrap();
        let mut polls = 0u64;
        train_until_pending(&mut client, || {
            polls += 1;
            Ok(false) // empty partition: never any work
        })
        .unwrap();
        let _ = h.join().unwrap();
        assert!(polls >= 1, "loop never polled");
        assert!(
            polls < 1000,
            "idle loop busy-spun: {polls} polls in ~100 ms"
        );
    }

    #[test]
    fn train_until_pending_propagates_step_errors() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let _keep = listener; // hold the socket open, send nothing
        let mut client = TcpStream::connect(addr).unwrap();
        let err = train_until_pending(&mut client, || {
            bail!("engine exploded")
        })
        .unwrap_err();
        assert!(err.to_string().contains("engine exploded"));
        // Blocking mode was restored on the error path.
        let mut scratch = Vec::new();
        send_wire(
            &mut client,
            &WireMsg::Hello { id: 1 },
            &mut scratch,
        )
        .unwrap();
    }

    #[test]
    fn send_wire_bails_before_writing_oversized_frame() {
        // Failing-before test for the framing bug: the old send path
        // wrote `len as u32` unguarded, so an oversized frame hit the
        // wire and desynced the receiver. Now the sender errors and
        // the sink stays empty.
        let data: Vec<f32> = vec![1.0; 64];
        let mut sink: Vec<u8> = Vec::new();
        let mut scratch = Vec::new();
        let err = send_wire_capped(
            &mut sink,
            &WireMsg::Broadcast { round: 1, data: &data },
            &mut scratch,
            100, // tiny cap: the 273-byte frame must be refused
        )
        .unwrap_err();
        assert!(err.to_string().contains("frame cap"), "{err}");
        assert!(
            sink.is_empty(),
            "no bytes may reach the wire once the cap check fails"
        );
        // Same frame under the real cap goes through.
        send_wire_capped(
            &mut sink,
            &WireMsg::Broadcast { round: 1, data: &data },
            &mut scratch,
            MAX_FRAME,
        )
        .unwrap();
        assert_eq!(sink.len(), 4 + scratch.len());
    }

    #[test]
    fn recv_into_reads_garbage_prefix_in_bounded_chunks() {
        // A peer that announces a huge (but under-cap) body and then
        // hangs up must not cost the receiver the announced
        // allocation: the chunked read grows the scratch by at most
        // RECV_CHUNK before the dry stream errors out.
        let announced = 512 * 1024 * 1024u32; // 512 MiB, under MAX_FRAME
        let mut wire = Vec::new();
        wire.extend_from_slice(&announced.to_le_bytes());
        wire.extend_from_slice(&[7u8; 100]); // then silence
        let mut stream = std::io::Cursor::new(wire);
        let mut scratch = Vec::new();
        assert!(recv_into(&mut stream, &mut scratch).is_err());
        assert!(
            scratch.capacity() <= 2 * RECV_CHUNK,
            "scratch grew to {} for an undelivered body",
            scratch.capacity()
        );
    }

    #[test]
    fn recv_into_reuses_scratch_and_rejects_bump_counter() {
        let msg = Message::Weights {
            round: 1,
            loss: 0.5,
            steps: 3,
            data: vec![2.0; 300],
        };
        let body = msg.encode();
        let mut wire = Vec::new();
        for _ in 0..2 {
            wire.extend_from_slice(&(body.len() as u32).to_le_bytes());
            wire.extend_from_slice(&body);
        }
        // Third frame: well-formed length, undecodable payload.
        wire.extend_from_slice(&3u32.to_le_bytes());
        wire.extend_from_slice(&[99, 99, 99]);
        let mut stream = std::io::Cursor::new(wire);
        let mut scratch = Vec::new();
        let rejected_before =
            crate::telemetry::snapshot().counter("comm_frames_rejected");
        assert_eq!(recv_into(&mut stream, &mut scratch).unwrap(), msg);
        let cap = scratch.capacity();
        let ptr = scratch.as_ptr();
        assert_eq!(recv_into(&mut stream, &mut scratch).unwrap(), msg);
        assert_eq!(scratch.capacity(), cap, "second frame reallocated");
        assert_eq!(scratch.as_ptr(), ptr);
        assert!(recv_into(&mut stream, &mut scratch).is_err());
        let rejected_after =
            crate::telemetry::snapshot().counter("comm_frames_rejected");
        assert!(
            rejected_after > rejected_before,
            "undecodable frame must bump comm_frames_rejected"
        );
    }

    #[test]
    fn codec_and_encoded_frames_roundtrip() {
        let msgs = vec![
            Message::Codec { codec: 4 },
            Message::WeightsEnc {
                round: 6,
                loss: 0.75,
                steps: 11,
                codec: 1,
                n: 1000,
                body: vec![1, 2, 3, 4, 5],
            },
            Message::BroadcastEnc {
                round: 7,
                codec: 2,
                n: 64,
                body: vec![9; 128],
            },
        ];
        let mut scratch = Vec::new();
        for m in &msgs {
            assert_eq!(&Message::decode(&m.encode()).unwrap(), m);
            m.wire().encode_into(&mut scratch);
            assert_eq!(scratch, m.encode(), "{m:?}");
        }
        // Truncated encoded-frame headers error instead of panicking.
        let b = msgs[1].encode();
        for cut in [1, 8, 20, 29] {
            assert!(Message::decode(&b[..cut]).is_err(), "cut={cut}");
        }
    }

    #[test]
    fn query_reply_frames_roundtrip() {
        let msgs = vec![
            Message::QueryScore {
                id: 17,
                pairs: vec![(1, 2, -1), (3, 4, 0), (5, 6, 3)],
            },
            Message::QueryTopK { id: 18, node: 42, k: 10 },
            Message::ReplyScore {
                id: 17,
                scores: vec![0.5, -1.25, f32::NEG_INFINITY],
            },
            Message::ReplyTopK {
                id: 18,
                items: vec![(7, 0.9), (2, 0.1)],
            },
        ];
        let mut scratch = Vec::new();
        for m in &msgs {
            assert_eq!(&Message::decode(&m.encode()).unwrap(), m);
            m.wire().encode_into(&mut scratch);
            assert_eq!(scratch, m.encode(), "{m:?}");
        }
        // Truncated bodies must error, not yield short vectors — a
        // score query's 17-byte header promises 12 bytes per pair.
        let b = msgs[0].encode();
        assert_eq!(b.len(), 17 + 12 * 3);
        for cut in [1, 8, 16, 17 + 5, b.len() - 1] {
            assert!(Message::decode(&b[..cut]).is_err(), "cut={cut}");
        }
        // A hostile pair count larger than the frame can hold is
        // refused before any reservation.
        let mut hostile = vec![TAG_QUERY_SCORE];
        hostile.extend_from_slice(&1u64.to_le_bytes());
        hostile.extend_from_slice(&u64::MAX.to_le_bytes());
        assert!(Message::decode(&hostile).is_err());
    }

    #[test]
    fn zero_alloc_query_decode_matches_owned_path() {
        let msg = Message::QueryScore {
            id: 99,
            pairs: vec![(10, 20, 1), (30, 40, -1)],
        };
        let frame = msg.encode();
        let mut pairs = Vec::with_capacity(8);
        pairs.push((0, 0, 0)); // stale entry: must be cleared
        let cap = pairs.capacity();
        let id = decode_score_query_into(&frame, &mut pairs).unwrap();
        assert_eq!(id, Some(99));
        assert_eq!(pairs, vec![(10, 20, 1), (30, 40, -1)]);
        assert_eq!(pairs.capacity(), cap, "decode reallocated the pool buf");
        // Non-query tags fall through untouched for Message::decode.
        let other = Message::Stop.encode();
        assert_eq!(
            decode_score_query_into(&other, &mut pairs).unwrap(),
            None
        );
        // Malformed score queries error rather than falling through.
        assert!(decode_score_query_into(&frame[..9], &mut pairs).is_err());
    }

    #[test]
    fn serve_handshake_roundtrip_and_rejects_codec_peer() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let h = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            serve_server_handshake(&mut s)
        });
        let mut client = TcpStream::connect(addr).unwrap();
        serve_client_handshake(&mut client, 12).unwrap();
        assert_eq!(h.join().unwrap().unwrap(), 12);

        // A peer that opens with anything but Hello is refused.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let h = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            serve_server_handshake(&mut s)
        });
        let mut client = TcpStream::connect(addr).unwrap();
        send(&mut client, &Message::Codec { codec: 1 }).unwrap();
        let err = h.join().unwrap().unwrap_err();
        assert!(err.to_string().contains("expected Hello"), "{err}");
    }

    #[test]
    fn handshake_negotiates_and_rejects_mismatch() {
        use super::codec::CodecKind;
        // Matching codecs: handshake completes, id survives.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let h = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            server_handshake(&mut s, CodecKind::TopK { denom: 64 })
        });
        let mut client = TcpStream::connect(addr).unwrap();
        client_handshake(&mut client, 5, CodecKind::TopK { denom: 32 })
            .unwrap(); // same family, different denom: negotiates
        assert_eq!(h.join().unwrap().unwrap(), 5);

        // Mismatched families: both sides fail loudly.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let h = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            server_handshake(&mut s, CodecKind::Identity)
        });
        let mut client = TcpStream::connect(addr).unwrap();
        // The leader drops the connection on mismatch without acking,
        // so the client fails too — either with the explicit mismatch
        // or the dead socket; both are loud.
        assert!(
            client_handshake(&mut client, 2, CodecKind::Delta).is_err()
        );
        let server_err = h.join().unwrap().unwrap_err();
        assert!(
            server_err.to_string().contains("codec mismatch"),
            "{server_err}"
        );

        // A pre-codec peer (Hello then Ready, no Codec frame).
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let h = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            server_handshake(&mut s, CodecKind::Identity)
        });
        let mut client = TcpStream::connect(addr).unwrap();
        send(&mut client, &Message::Hello { id: 1 }).unwrap();
        send(&mut client, &Message::Ready { id: 1 }).unwrap();
        let err = h.join().unwrap().unwrap_err();
        assert!(
            err.to_string().contains("did not negotiate"),
            "{err}"
        );
    }

    #[test]
    fn tcp_roundtrip_localhost() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let h = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let m = recv(&mut s).unwrap();
            send(&mut s, &m).unwrap(); // echo
        });
        let mut client = TcpStream::connect(addr).unwrap();
        let msg = Message::Weights {
            round: 1,
            loss: 0.5,
            steps: 10,
            data: (0..1000).map(|i| i as f32).collect(),
        };
        send(&mut client, &msg).unwrap();
        let echo = recv(&mut client).unwrap();
        assert_eq!(echo, msg);
        h.join().unwrap();
    }
}
