//! Wire protocol + TCP transport for multi-process mode.
//!
//! The default benches run trainers as threads in one process (the
//! paper also co-locates trainers on machines). This module provides
//! the genuinely distributed alternative: a leader (TMA server) and
//! `rtma worker` processes exchanging the same aggregation protocol
//! over TCP. `examples/distributed_tcp.rs` drives it end to end.
//!
//! Framing: 4-byte LE length prefix + 1 tag byte + fixed header +
//! payload (f32 weights as raw LE bytes). No serde dependency.

use std::io::{Read, Write};
use std::net::TcpStream;

use anyhow::{bail, Result};

/// Protocol messages between leader and workers.
#[derive(Debug, Clone, PartialEq)]
pub enum Message {
    /// Worker -> leader: join with a trainer id.
    Hello { id: u32 },
    /// Worker -> leader: local data loaded, ready to train.
    Ready { id: u32 },
    /// Worker -> leader: local weights at an aggregation round.
    Weights { round: u64, loss: f32, steps: u64, data: Vec<f32> },
    /// Leader -> worker: global weights (round 0 = initial broadcast).
    Broadcast { round: u64, data: Vec<f32> },
    /// Leader -> worker: aggregation round `round` is open — ship your
    /// local weights now (the `KV[agg]` signal of Alg 1/2).
    Collect { round: u64 },
    /// Leader -> worker: stop training and report.
    Stop,
}

const TAG_HELLO: u8 = 1;
const TAG_READY: u8 = 2;
const TAG_WEIGHTS: u8 = 3;
const TAG_BROADCAST: u8 = 4;
const TAG_STOP: u8 = 5;
const TAG_COLLECT: u8 = 6;

impl Message {
    pub fn encode(&self) -> Vec<u8> {
        let mut b = Vec::new();
        match self {
            Message::Hello { id } => {
                b.push(TAG_HELLO);
                b.extend_from_slice(&id.to_le_bytes());
            }
            Message::Ready { id } => {
                b.push(TAG_READY);
                b.extend_from_slice(&id.to_le_bytes());
            }
            Message::Weights { round, loss, steps, data } => {
                b.push(TAG_WEIGHTS);
                b.extend_from_slice(&round.to_le_bytes());
                b.extend_from_slice(&loss.to_le_bytes());
                b.extend_from_slice(&steps.to_le_bytes());
                b.extend_from_slice(&(data.len() as u64).to_le_bytes());
                for x in data {
                    b.extend_from_slice(&x.to_le_bytes());
                }
            }
            Message::Broadcast { round, data } => {
                b.push(TAG_BROADCAST);
                b.extend_from_slice(&round.to_le_bytes());
                b.extend_from_slice(&(data.len() as u64).to_le_bytes());
                for x in data {
                    b.extend_from_slice(&x.to_le_bytes());
                }
            }
            Message::Collect { round } => {
                b.push(TAG_COLLECT);
                b.extend_from_slice(&round.to_le_bytes());
            }
            Message::Stop => b.push(TAG_STOP),
        }
        b
    }

    pub fn decode(b: &[u8]) -> Result<Message> {
        let mut cur = Cursor { b, i: 0 };
        let tag = cur.u8()?;
        Ok(match tag {
            TAG_HELLO => Message::Hello { id: cur.u32()? },
            TAG_READY => Message::Ready { id: cur.u32()? },
            TAG_WEIGHTS => {
                let round = cur.u64()?;
                let loss = cur.f32()?;
                let steps = cur.u64()?;
                let n = cur.u64()? as usize;
                Message::Weights { round, loss, steps, data: cur.f32s(n)? }
            }
            TAG_BROADCAST => {
                let round = cur.u64()?;
                let n = cur.u64()? as usize;
                Message::Broadcast { round, data: cur.f32s(n)? }
            }
            TAG_COLLECT => Message::Collect { round: cur.u64()? },
            TAG_STOP => Message::Stop,
            other => bail!("bad message tag {other}"),
        })
    }
}

struct Cursor<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.i + n > self.b.len() {
            bail!("truncated message");
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn f32s(&mut self, n: usize) -> Result<Vec<f32>> {
        let raw = self.take(n * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }
}

/// Write one length-prefixed message.
pub fn send(stream: &mut TcpStream, msg: &Message) -> Result<()> {
    let body = msg.encode();
    stream.write_all(&(body.len() as u32).to_le_bytes())?;
    stream.write_all(&body)?;
    stream.flush()?;
    Ok(())
}

/// Read one length-prefixed message (blocking).
pub fn recv(stream: &mut TcpStream) -> Result<Message> {
    let mut len = [0u8; 4];
    stream.read_exact(&mut len)?;
    let n = u32::from_le_bytes(len) as usize;
    if n > 1 << 30 {
        bail!("message too large: {n}");
    }
    let mut body = vec![0u8; n];
    stream.read_exact(&mut body)?;
    Message::decode(&body)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    #[test]
    fn encode_decode_roundtrip() {
        let msgs = vec![
            Message::Hello { id: 7 },
            Message::Ready { id: 3 },
            Message::Weights {
                round: 9,
                loss: 1.25,
                steps: 42,
                data: vec![1.0, -2.5, 3.25],
            },
            Message::Broadcast { round: 2, data: vec![0.0; 100] },
            Message::Collect { round: 5 },
            Message::Stop,
        ];
        for m in msgs {
            assert_eq!(Message::decode(&m.encode()).unwrap(), m);
        }
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(Message::decode(&[]).is_err());
        assert!(Message::decode(&[99]).is_err());
        assert!(Message::decode(&[TAG_WEIGHTS, 1, 2]).is_err());
    }

    #[test]
    fn tcp_roundtrip_localhost() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let h = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let m = recv(&mut s).unwrap();
            send(&mut s, &m).unwrap(); // echo
        });
        let mut client = TcpStream::connect(addr).unwrap();
        let msg = Message::Weights {
            round: 1,
            loss: 0.5,
            steps: 10,
            data: (0..1000).map(|i| i as f32).collect(),
        };
        send(&mut client, &msg).unwrap();
        let echo = recv(&mut client).unwrap();
        assert_eq!(echo, msg);
        h.join().unwrap();
    }
}
