//! Round codecs — the compression layer between the aggregation data
//! plane and the TCP wire (ROADMAP item 3: Grappa ships gradients
//! only, ABC reduces before communicating; both attack the P·4-bytes
//! per trainer per round traffic that dominates at scale).
//!
//! Four encodings behind one [`CodecKind`]:
//!
//! - `identity` — the reference. Callers skip the codec entirely and
//!   ship today's raw `Weights`/`Broadcast` frames, so the wire stays
//!   bit-for-bit identical to the pre-codec protocol (pinned by
//!   `tests/codec.rs`).
//! - `delta` — XOR of the f32 bit patterns against the last broadcast
//!   base, run-length encoded over zero words. XOR (not f32
//!   subtraction) because it is *exactly* invertible: decode
//!   reproduces the input bit-for-bit, so server and trainers keep
//!   bit-synced bases for free.
//! - `f16` / `i8` — stochastic-rounding quantization (unbiased: the
//!   expected decode equals the input), 2x / ~4x smaller bodies.
//! - `topk` — top-k-by-magnitude sparsification of the base-relative
//!   change with per-sender error feedback: unsent coordinates
//!   accumulate in a residual and are shipped once they grow, so the
//!   cumulative decoded stream converges to the cumulative input
//!   (`tests/codec.rs` drains the residual to exactly zero).
//!
//! Encoded bodies travel in `WeightsEnc`/`BroadcastEnc` frames that
//! carry the *actual* encoding id byte — a `topk` session broadcasts
//! downstream as `delta` (sparsifying the one authoritative global
//! model would desync the fleet; sparsification is for the many
//! upstream trainer→server legs).
//!
//! Decode offers two shapes: [`decode_dense`] materialises the vector
//! (workers applying a broadcast), while [`decode_fold`] streams
//! straight into the server's [`MeanAccum`] without ever building the
//! dense vector for sparse codecs (`fold_sparse` + a base-count so
//! `mean_with` can add the shared base back once).

use std::time::Instant;

use anyhow::{bail, ensure, Result};

use super::le_bytes;
use crate::model::MeanAccum;
use crate::telemetry::metrics;
use crate::util::rng::Rng;

/// Wire encoding ids (the byte carried in `WeightsEnc`/`BroadcastEnc`
/// frames and in the `Codec` negotiation message).
pub const CODEC_IDENTITY: u8 = 0;
pub const CODEC_DELTA: u8 = 1;
pub const CODEC_F16: u8 = 2;
pub const CODEC_I8: u8 = 3;
pub const CODEC_TOPK: u8 = 4;

/// Elements per i8 quantization chunk (one f32 scale per chunk).
const I8_CHUNK: usize = 4096;

/// A configured round codec. `TopK` carries its sparsity denominator
/// (k = max(1, n/denom)); the wire/negotiation id is the family only.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CodecKind {
    Identity,
    Delta,
    F16,
    I8,
    TopK { denom: u32 },
}

impl CodecKind {
    /// Parse a codec spec: `identity` (or empty), `delta`, `f16`,
    /// `i8`, `topk`, `topk:<denom>`.
    pub fn parse(s: &str) -> Result<CodecKind> {
        let s = s.trim();
        Ok(match s {
            "" | "identity" => CodecKind::Identity,
            "delta" => CodecKind::Delta,
            "f16" => CodecKind::F16,
            "i8" | "int8" => CodecKind::I8,
            "topk" => CodecKind::TopK { denom: 64 },
            _ => {
                if let Some(d) = s.strip_prefix("topk:") {
                    let denom: u32 = d.parse().map_err(|_| {
                        anyhow::anyhow!("bad topk denominator: {d:?}")
                    })?;
                    ensure!(denom >= 1, "topk denominator must be >= 1");
                    CodecKind::TopK { denom }
                } else {
                    bail!(
                        "unknown codec {s:?} (expected identity | delta | \
                         f16 | i8 | topk | topk:<denom>)"
                    );
                }
            }
        })
    }

    /// Canonical spec string (round-trips through [`CodecKind::parse`]).
    pub fn name(&self) -> String {
        match self {
            CodecKind::Identity => "identity".into(),
            CodecKind::Delta => "delta".into(),
            CodecKind::F16 => "f16".into(),
            CodecKind::I8 => "i8".into(),
            CodecKind::TopK { denom } => format!("topk:{denom}"),
        }
    }

    /// Wire family id (what the `Codec` handshake compares).
    pub fn id(&self) -> u8 {
        match self {
            CodecKind::Identity => CODEC_IDENTITY,
            CodecKind::Delta => CODEC_DELTA,
            CodecKind::F16 => CODEC_F16,
            CodecKind::I8 => CODEC_I8,
            CodecKind::TopK { .. } => CODEC_TOPK,
        }
    }

    pub fn is_identity(&self) -> bool {
        matches!(self, CodecKind::Identity)
    }
}

/// Resolve the effective codec: a non-empty `RTMA_CODEC` env var wins
/// over the config field, which wins over the `identity` default
/// (mirroring the PR 7 backend chain; see docs/COMM.md).
pub fn resolve(field: &str) -> Result<CodecKind> {
    let env = std::env::var("RTMA_CODEC").unwrap_or_default();
    let pick = if env.trim().is_empty() { field } else { env.as_str() };
    CodecKind::parse(pick)
}

// ---------------------------------------------------------------------------
// Encoder

/// Per-sender encoder state: the top-k error-feedback residual and
/// the stochastic-rounding RNG stream live here, one per trainer (or
/// one on the server for the downstream leg).
pub struct RoundEncoder {
    kind: CodecKind,
    residual: Vec<f32>,
    rng: Rng,
}

impl RoundEncoder {
    pub fn new(kind: CodecKind, seed: u64) -> RoundEncoder {
        RoundEncoder { kind, residual: Vec::new(), rng: Rng::new(seed) }
    }

    pub fn kind(&self) -> CodecKind {
        self.kind
    }

    /// L2 norm of the error-feedback residual (0 for non-topk kinds);
    /// the drain test in `tests/codec.rs` watches this reach zero.
    pub fn residual_norm(&self) -> f64 {
        self.residual.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>().sqrt()
    }

    /// Encode the trainer→server leg of `w` against `base` (the last
    /// broadcast; empty slice = all zeros, e.g. GGS gradients).
    /// Returns the wire encoding id actually used.
    pub fn encode_up(
        &mut self,
        w: &[f32],
        base: &[f32],
        out: &mut Vec<u8>,
    ) -> u8 {
        debug_assert!(base.is_empty() || base.len() == w.len());
        let t0 = crate::telemetry::now();
        out.clear();
        let id = match self.kind {
            CodecKind::Identity => {
                raw_encode(w, out);
                CODEC_IDENTITY
            }
            CodecKind::Delta => {
                xor_rle_encode(w, base, out);
                CODEC_DELTA
            }
            CodecKind::F16 => {
                f16_encode_all(w, &mut self.rng, out);
                CODEC_F16
            }
            CodecKind::I8 => {
                i8_encode_all(w, &mut self.rng, out);
                CODEC_I8
            }
            CodecKind::TopK { denom } => {
                self.topk_encode(w, base, denom, out);
                CODEC_TOPK
            }
        };
        bump_encode(w.len(), out.len(), t0);
        id
    }

    /// Encode the server→trainers leg (the broadcast). Top-k sessions
    /// use exact XOR-RLE here — the one global model is never
    /// sparsified — so the returned id can differ from the session
    /// codec's family id.
    pub fn encode_down(
        &mut self,
        w: &[f32],
        base: &[f32],
        out: &mut Vec<u8>,
    ) -> u8 {
        debug_assert!(base.is_empty() || base.len() == w.len());
        let t0 = crate::telemetry::now();
        out.clear();
        let id = match self.kind {
            CodecKind::Identity => {
                raw_encode(w, out);
                CODEC_IDENTITY
            }
            CodecKind::Delta | CodecKind::TopK { .. } => {
                xor_rle_encode(w, base, out);
                CODEC_DELTA
            }
            CodecKind::F16 => {
                f16_encode_all(w, &mut self.rng, out);
                CODEC_F16
            }
            CodecKind::I8 => {
                i8_encode_all(w, &mut self.rng, out);
                CODEC_I8
            }
        };
        bump_encode(w.len(), out.len(), t0);
        id
    }

    /// Top-k with error feedback: rank `c = w - base + residual` by
    /// magnitude, ship the k largest coordinates of `c` exactly, keep
    /// the rest in the residual for later rounds.
    fn topk_encode(
        &mut self,
        w: &[f32],
        base: &[f32],
        denom: u32,
        out: &mut Vec<u8>,
    ) {
        let n = w.len();
        if self.residual.len() != n {
            self.residual = vec![0.0; n];
        }
        let k = ((n as u64 / denom.max(1) as u64).max(1) as usize).min(n);
        let bv = |i: usize| if base.is_empty() { 0.0 } else { base[i] };
        let c: Vec<f32> = (0..n)
            .map(|i| w[i] - bv(i) + self.residual[i])
            .collect();
        let mut order: Vec<u32> = (0..n as u32).collect();
        if k < n {
            order.select_nth_unstable_by(k - 1, |&a, &b| {
                c[b as usize].abs().total_cmp(&c[a as usize].abs())
            });
        }
        let mut sel = order[..k].to_vec();
        sel.sort_unstable();
        out.extend_from_slice(&(k as u32).to_le_bytes());
        for &i in &sel {
            out.extend_from_slice(&i.to_le_bytes());
        }
        for &i in &sel {
            out.extend_from_slice(&c[i as usize].to_le_bytes());
        }
        self.residual.copy_from_slice(&c);
        for &i in &sel {
            self.residual[i as usize] = 0.0;
        }
    }
}

fn bump_encode(n: usize, encoded: usize, t0: Instant) {
    let m = metrics();
    m.codec_frames.inc();
    m.codec_bytes_raw.add((n * 4) as u64);
    m.codec_bytes_encoded.add(encoded as u64);
    m.codec_encode_us.observe(t0.elapsed().as_micros() as u64);
}

// ---------------------------------------------------------------------------
// Decode

/// Decode an encoded body into a dense vector (workers applying a
/// broadcast, the staged InverseLoss path, tests). `base` is the
/// receiver's copy of the sender's base; empty = all zeros.
pub fn decode_dense(
    codec: u8,
    n: usize,
    body: &[u8],
    base: &[f32],
) -> Result<Vec<f32>> {
    ensure!(
        base.is_empty() || base.len() == n,
        "codec base length {} != element count {n}",
        base.len()
    );
    let t0 = crate::telemetry::now();
    let mut out = Vec::with_capacity(n);
    match codec {
        CODEC_IDENTITY => raw_decode(n, body, &mut out)?,
        CODEC_DELTA => xor_rle_decode(n, body, base, &mut out)?,
        CODEC_F16 => f16_decode_all(n, body, &mut out)?,
        CODEC_I8 => i8_decode_all(n, body, &mut out)?,
        CODEC_TOPK => {
            if base.is_empty() {
                out.resize(n, 0.0);
            } else {
                out.extend_from_slice(base);
            }
            topk_walk(n, body, |i, v| out[i as usize] += v)?;
        }
        other => bail!("unknown codec id {other}"),
    }
    metrics().codec_decode_us.observe(t0.elapsed().as_micros() as u64);
    Ok(out)
}

/// Decode an encoded body straight into the streaming mean fold.
/// Sparse codecs (`delta`, `topk`) fold only the base-relative
/// changes plus one `mark_base` tick — the dense vector is never
/// materialised; [`MeanAccum::mean_with`] adds the shared base back.
pub fn decode_fold(
    codec: u8,
    n: usize,
    body: &[u8],
    base: &[f32],
    acc: &mut MeanAccum,
) -> Result<()> {
    ensure!(
        acc.len() == n,
        "codec element count {n} != accumulator length {}",
        acc.len()
    );
    ensure!(
        base.is_empty() || base.len() == n,
        "codec base length {} != element count {n}",
        base.len()
    );
    let t0 = crate::telemetry::now();
    match codec {
        CODEC_IDENTITY => {
            ensure_body_len(body, n * 4, "identity")?;
            acc.begin();
            let mut scratch = [0f32; 1024];
            let mut off = 0usize;
            while off < n {
                let take = (n - off).min(scratch.len());
                for (j, s) in scratch[..take].iter_mut().enumerate() {
                    let p = (off + j) * 4;
                    *s = f32::from_le_bytes(le_bytes(&body[p..p + 4]));
                }
                acc.fold_at(off, &scratch[..take]);
                off += take;
            }
        }
        CODEC_F16 => {
            ensure_body_len(body, n * 2, "f16")?;
            acc.begin();
            let mut scratch = [0f32; 1024];
            let mut off = 0usize;
            while off < n {
                let take = (n - off).min(scratch.len());
                for (j, s) in scratch[..take].iter_mut().enumerate() {
                    let p = (off + j) * 2;
                    *s = f16_decode(u16::from_le_bytes(le_bytes(
                        &body[p..p + 2],
                    )));
                }
                acc.fold_at(off, &scratch[..take]);
                off += take;
            }
        }
        CODEC_I8 => {
            acc.begin();
            i8_walk(n, body, &mut |off, chunk: &[f32]| {
                acc.fold_at(off, chunk);
            })?;
        }
        CODEC_DELTA => {
            acc.begin();
            acc.mark_base();
            xor_rle_walk(n, body, &mut |pos, xor| {
                let b = if base.is_empty() { 0.0 } else { base[pos] };
                let w = f32::from_bits(b.to_bits() ^ xor);
                acc.fold_sparse(&[pos as u32], &[w - b]);
            })?;
        }
        CODEC_TOPK => {
            acc.begin();
            acc.mark_base();
            topk_walk(n, body, |i, v| {
                acc.fold_sparse(&[i], &[v]);
            })?;
        }
        other => bail!("unknown codec id {other}"),
    }
    metrics().codec_decode_us.observe(t0.elapsed().as_micros() as u64);
    Ok(())
}

fn ensure_body_len(body: &[u8], want: usize, what: &str) -> Result<()> {
    ensure!(
        body.len() == want,
        "{what} body length {} != expected {want}",
        body.len()
    );
    Ok(())
}

// ---------------------------------------------------------------------------
// Raw (identity) body

fn raw_encode(w: &[f32], out: &mut Vec<u8>) {
    out.reserve(w.len() * 4);
    for x in w {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

fn raw_decode(n: usize, body: &[u8], out: &mut Vec<f32>) -> Result<()> {
    ensure_body_len(body, n * 4, "identity")?;
    for i in 0..n {
        out.push(f32::from_le_bytes(le_bytes(&body[i * 4..i * 4 + 4])));
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// XOR-RLE (delta) body: records of (u32 skip, u32 run, run × u32 xor
// words). `skip` counts words whose xor against the base is zero;
// short (< 3-word) zero gaps are absorbed into the surrounding run
// because two extra xor words are cheaper than an 8-byte header.

fn xor_word(w: &[f32], base: &[f32], i: usize) -> u32 {
    let b = if base.is_empty() { 0 } else { base[i].to_bits() };
    w[i].to_bits() ^ b
}

fn xor_rle_encode(w: &[f32], base: &[f32], out: &mut Vec<u8>) {
    let n = w.len();
    let mut i = 0usize;
    while i < n {
        let mut skip = 0usize;
        while i < n && xor_word(w, base, i) == 0 {
            skip += 1;
            i += 1;
        }
        if i == n {
            break;
        }
        let start = i;
        let mut end = i; // one past the last nonzero xor in this run
        let mut gap = 0usize;
        let mut j = i;
        while j < n {
            if xor_word(w, base, j) != 0 {
                end = j + 1;
                gap = 0;
            } else {
                gap += 1;
                if gap >= 3 {
                    break;
                }
            }
            j += 1;
        }
        out.extend_from_slice(&(skip as u32).to_le_bytes());
        out.extend_from_slice(&((end - start) as u32).to_le_bytes());
        for k in start..end {
            out.extend_from_slice(&xor_word(w, base, k).to_le_bytes());
        }
        i = end;
    }
}

/// Validated walk over an XOR-RLE body: calls `f(pos, xor)` for every
/// *nonzero* xor word (zero words inside a run change nothing).
fn xor_rle_walk(
    n: usize,
    body: &[u8],
    f: &mut dyn FnMut(usize, u32),
) -> Result<()> {
    let mut c = Bc::new(body);
    let mut pos = 0usize;
    while !c.done() {
        let skip = c.u32()? as usize;
        let run = c.u32()? as usize;
        pos = pos
            .checked_add(skip)
            .ok_or_else(|| anyhow::anyhow!("delta skip overflow"))?;
        ensure!(
            pos.checked_add(run).is_some_and(|e| e <= n),
            "delta run [{pos}, {pos}+{run}) exceeds element count {n}"
        );
        for _ in 0..run {
            let x = c.u32()?;
            if x != 0 {
                f(pos, x);
            }
            pos += 1;
        }
    }
    Ok(())
}

fn xor_rle_decode(
    n: usize,
    body: &[u8],
    base: &[f32],
    out: &mut Vec<f32>,
) -> Result<()> {
    if base.is_empty() {
        out.resize(n, 0.0);
    } else {
        out.extend_from_slice(base);
    }
    let o: &mut Vec<f32> = out;
    xor_rle_walk(n, body, &mut |pos, xor| {
        o[pos] = f32::from_bits(o[pos].to_bits() ^ xor);
    })
}

// ---------------------------------------------------------------------------
// f16 body: n × 2 bytes, stochastic rounding. Overflow clamps to the
// max finite half (0x7bff); |x| below the normal-half threshold
// (2^-14) flushes to zero; inf/nan pass through.

fn f16_encode_one(x: f32, rand13: u32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let man = bits & 0x007f_ffff;
    if exp == 0xff {
        return sign | 0x7c00 | if man != 0 { 0x200 } else { 0 };
    }
    let he = exp - 127 + 15;
    if he >= 0x1f {
        return sign | 0x7bff;
    }
    if he <= 0 {
        return sign;
    }
    let mut h = sign | ((he as u16) << 10) | (man >> 13) as u16;
    let rem = man & 0x1fff;
    if (rand13 & 0x1fff) < rem {
        h = h.wrapping_add(1);
        if (h & 0x7c00) == 0x7c00 {
            h = sign | 0x7bff; // mantissa carry crossed into inf
        }
    }
    h
}

fn f16_decode(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let man = (h & 0x3ff) as u32;
    let bits = match (exp, man) {
        (0, 0) => sign,
        (0, m) => {
            // Subnormal half: renormalise into an f32 exponent.
            let mut e: i32 = 113;
            let mut mm = m;
            while mm & 0x400 == 0 {
                mm <<= 1;
                e -= 1;
            }
            sign | ((e as u32) << 23) | ((mm & 0x3ff) << 13)
        }
        (0x1f, 0) => sign | 0x7f80_0000,
        (0x1f, m) => sign | 0x7fc0_0000 | (m << 13),
        (e, m) => sign | ((e + 127 - 15) << 23) | (m << 13),
    };
    f32::from_bits(bits)
}

fn f16_encode_all(w: &[f32], rng: &mut Rng, out: &mut Vec<u8>) {
    out.reserve(w.len() * 2);
    for x in w {
        let h = f16_encode_one(*x, rng.next_u64() as u32);
        out.extend_from_slice(&h.to_le_bytes());
    }
}

fn f16_decode_all(n: usize, body: &[u8], out: &mut Vec<f32>) -> Result<()> {
    ensure_body_len(body, n * 2, "f16")?;
    for i in 0..n {
        out.push(f16_decode(u16::from_le_bytes(le_bytes(
            &body[i * 2..i * 2 + 2],
        ))));
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// i8 body: chunks of up to I8_CHUNK elements, each [f32 scale][len ×
// i8]. scale = maxabs/127 (an all-zero chunk stores scale 0 and a
// zero payload); values stochastically round to q = x/scale.

fn i8_encode_all(w: &[f32], rng: &mut Rng, out: &mut Vec<u8>) {
    out.reserve(w.len() + (w.len() / I8_CHUNK + 1) * 4);
    for chunk in w.chunks(I8_CHUNK) {
        let maxabs = chunk.iter().fold(0f32, |a, x| a.max(x.abs()));
        let scale = maxabs / 127.0;
        out.extend_from_slice(&scale.to_le_bytes());
        if scale == 0.0 || !scale.is_finite() {
            // All-zero (or degenerate non-finite) chunk: zero payload.
            out.extend(std::iter::repeat(0u8).take(chunk.len()));
            continue;
        }
        for x in chunk {
            let q = (*x / scale) as f64;
            let lo = q.floor();
            let up = rng.f64() < (q - lo);
            let v = (lo as i64 + i64::from(up)).clamp(-127, 127);
            out.push(v as i8 as u8);
        }
    }
}

/// Validated walk over an i8 body: calls `f(offset, decoded_chunk)`.
fn i8_walk(
    n: usize,
    body: &[u8],
    f: &mut dyn FnMut(usize, &[f32]),
) -> Result<()> {
    let mut c = Bc::new(body);
    let mut off = 0usize;
    let mut scratch = [0f32; I8_CHUNK];
    while off < n {
        let take = (n - off).min(I8_CHUNK);
        let scale = c.f32()?;
        ensure!(scale.is_finite(), "i8 chunk scale is not finite");
        let q = c.bytes(take)?;
        for (s, b) in scratch[..take].iter_mut().zip(q) {
            *s = (*b as i8) as f32 * scale;
        }
        f(off, &scratch[..take]);
        off += take;
    }
    ensure!(c.done(), "i8 body has trailing bytes");
    Ok(())
}

fn i8_decode_all(n: usize, body: &[u8], out: &mut Vec<f32>) -> Result<()> {
    i8_walk(n, body, &mut |_, chunk| out.extend_from_slice(chunk))
}

// ---------------------------------------------------------------------------
// top-k body: u32 k, k × u32 ascending indices, k × f32 values
// (base-relative changes, exact f32).

fn topk_walk(
    n: usize,
    body: &[u8],
    mut f: impl FnMut(u32, f32),
) -> Result<()> {
    let mut c = Bc::new(body);
    let k = c.u32()? as usize;
    ensure!(k <= n, "topk k={k} exceeds element count {n}");
    ensure_body_len(body, 4 + k * 8, "topk")?;
    let mut idx = Vec::with_capacity(k);
    for _ in 0..k {
        let i = c.u32()?;
        ensure!((i as usize) < n, "topk index {i} out of range (n={n})");
        idx.push(i);
    }
    for i in idx {
        f(i, c.f32()?);
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Minimal validated byte cursor for codec bodies (the wire-level
// cursor in `comm` owns the frame headers; bodies are opaque there).

struct Bc<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Bc<'a> {
    fn new(b: &'a [u8]) -> Bc<'a> {
        Bc { b, i: 0 }
    }
    fn done(&self) -> bool {
        self.i >= self.b.len()
    }
    fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(
            self.i + n <= self.b.len(),
            "codec body truncated at byte {} (want {n} more of {})",
            self.i,
            self.b.len()
        );
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }
    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(le_bytes(self.bytes(4)?)))
    }
    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(le_bytes(self.bytes(4)?)))
    }
}

#[cfg(test)]
// Tests assert through unwrap by design — a panic is the failure.
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;

    fn vecs(seed: u64, n: usize) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let base: Vec<f32> =
            (0..n).map(|_| rng.gaussian() as f32).collect();
        let w: Vec<f32> = base
            .iter()
            .map(|x| x + 0.01 * rng.gaussian() as f32)
            .collect();
        (w, base)
    }

    #[test]
    fn parse_roundtrips_and_rejects() {
        for s in ["identity", "delta", "f16", "i8", "topk:64", "topk:8"] {
            let k = CodecKind::parse(s).unwrap();
            assert_eq!(CodecKind::parse(&k.name()).unwrap(), k);
        }
        assert_eq!(
            CodecKind::parse("").unwrap(),
            CodecKind::Identity
        );
        assert_eq!(
            CodecKind::parse("topk").unwrap(),
            CodecKind::TopK { denom: 64 }
        );
        assert!(CodecKind::parse("gzip").is_err());
        assert!(CodecKind::parse("topk:0").is_err());
        assert!(CodecKind::parse("topk:x").is_err());
    }

    #[test]
    fn resolve_env_beats_config_field() {
        // Serialised inside one test: RTMA_CODEC is process-global.
        std::env::remove_var("RTMA_CODEC");
        assert!(resolve("").unwrap().is_identity());
        assert_eq!(resolve("delta").unwrap(), CodecKind::Delta);
        std::env::set_var("RTMA_CODEC", "f16");
        assert_eq!(resolve("delta").unwrap(), CodecKind::F16);
        std::env::set_var("RTMA_CODEC", "nonsense");
        assert!(resolve("delta").is_err());
        std::env::remove_var("RTMA_CODEC");
        assert_eq!(resolve("delta").unwrap(), CodecKind::Delta);
    }

    #[test]
    fn delta_roundtrip_is_bit_exact() {
        for (seed, n) in [(1u64, 1usize), (2, 257), (3, 4096)] {
            let (w, base) = vecs(seed, n);
            let mut enc = RoundEncoder::new(CodecKind::Delta, 7);
            let mut body = Vec::new();
            let id = enc.encode_up(&w, &base, &mut body);
            assert_eq!(id, CODEC_DELTA);
            let back = decode_dense(id, n, &body, &base).unwrap();
            assert_eq!(
                w.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                back.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            );
        }
    }

    #[test]
    fn delta_sparse_change_compresses() {
        let n = 8192;
        let (base, _) = vecs(4, n);
        let mut w = base.clone();
        for i in (0..n).step_by(512) {
            w[i] += 1.0;
        }
        let mut enc = RoundEncoder::new(CodecKind::Delta, 7);
        let mut body = Vec::new();
        enc.encode_up(&w, &base, &mut body);
        assert!(
            body.len() < n, // 16 changed words ≪ 4n raw bytes
            "sparse delta body {} should be far under raw {}",
            body.len(),
            n * 4
        );
        let back = decode_dense(CODEC_DELTA, n, &body, &base).unwrap();
        assert_eq!(
            w.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            back.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
        );
    }

    #[test]
    fn delta_empty_base_means_zeros() {
        let (w, _) = vecs(5, 300);
        let mut enc = RoundEncoder::new(CodecKind::Delta, 7);
        let mut body = Vec::new();
        enc.encode_up(&w, &[], &mut body);
        let back = decode_dense(CODEC_DELTA, w.len(), &body, &[]).unwrap();
        assert_eq!(
            w.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            back.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
        );
    }

    #[test]
    fn f16_error_bounded_and_exact_on_representables() {
        let (w, _) = vecs(6, 4096);
        let mut enc = RoundEncoder::new(CodecKind::F16, 9);
        let mut body = Vec::new();
        enc.encode_up(&w, &[], &mut body);
        let back = decode_dense(CODEC_F16, w.len(), &body, &[]).unwrap();
        for (x, y) in w.iter().zip(&back) {
            let bound = x.abs() as f64 / 512.0 + 6.2e-5;
            assert!(
                ((x - y).abs() as f64) <= bound,
                "f16 error {x} -> {y} exceeds bound {bound}"
            );
        }
        // Exactly representable halves survive any rounding bits.
        let exact = [0.0f32, 1.0, -1.0, 0.5, 2.0, -0.25, 1024.0];
        for bits in [0u32, 0x1fff, 0x1000] {
            for x in exact {
                assert_eq!(f16_decode(f16_encode_one(x, bits)), x);
            }
        }
        // Overflow clamps finite; inf/nan pass through.
        assert!(f16_decode(f16_encode_one(1e30, 0)).is_finite());
        assert!(f16_decode(f16_encode_one(f32::INFINITY, 0)).is_infinite());
        assert!(f16_decode(f16_encode_one(f32::NAN, 0)).is_nan());
    }

    #[test]
    fn i8_error_bounded_by_chunk_scale() {
        let (w, _) = vecs(8, 2 * I8_CHUNK + 100);
        let mut enc = RoundEncoder::new(CodecKind::I8, 9);
        let mut body = Vec::new();
        enc.encode_up(&w, &[], &mut body);
        let back = decode_dense(CODEC_I8, w.len(), &body, &[]).unwrap();
        for (ci, chunk) in w.chunks(I8_CHUNK).enumerate() {
            let scale = chunk.iter().fold(0f32, |a, x| a.max(x.abs())) / 127.0;
            for (j, x) in chunk.iter().enumerate() {
                let y = back[ci * I8_CHUNK + j];
                assert!(
                    (x - y).abs() <= scale * 1.0001 + 1e-12,
                    "i8 error {x} -> {y} exceeds scale {scale}"
                );
            }
        }
        assert!(body.len() * 3 < w.len() * 4 && body.len() > w.len());
    }

    #[test]
    fn i8_all_zero_chunk_roundtrips() {
        let w = vec![0.0f32; I8_CHUNK + 3];
        let mut enc = RoundEncoder::new(CodecKind::I8, 9);
        let mut body = Vec::new();
        enc.encode_up(&w, &[], &mut body);
        let back = decode_dense(CODEC_I8, w.len(), &body, &[]).unwrap();
        assert!(back.iter().all(|x| *x == 0.0));
    }

    #[test]
    fn topk_ships_largest_changes_exactly() {
        let n = 1024;
        let (base, _) = vecs(10, n);
        let mut w = base.clone();
        w[17] += 5.0;
        w[600] -= 4.0;
        let mut enc = RoundEncoder::new(CodecKind::TopK { denom: 512 }, 3);
        let mut body = Vec::new();
        let id = enc.encode_up(&w, &base, &mut body);
        assert_eq!(id, CODEC_TOPK);
        let back = decode_dense(id, n, &body, &base).unwrap();
        // k = 2: exactly the two injected coordinates move.
        assert_eq!(back[17].to_bits(), w[17].to_bits());
        assert_eq!(back[600].to_bits(), w[600].to_bits());
        let moved = (0..n)
            .filter(|&i| back[i].to_bits() != base[i].to_bits())
            .count();
        assert_eq!(moved, 2);
    }

    #[test]
    fn fold_matches_dense_decode() {
        let n = 3000;
        let (w, base) = vecs(11, n);
        for kind in [
            CodecKind::Delta,
            CodecKind::F16,
            CodecKind::I8,
            CodecKind::TopK { denom: 16 },
        ] {
            let mut enc = RoundEncoder::new(kind, 21);
            let mut body = Vec::new();
            let id = enc.encode_up(&w, &base, &mut body);
            let dense = decode_dense(id, n, &body, &base).unwrap();
            let mut acc = MeanAccum::new(n);
            decode_fold(id, n, &body, &base, &mut acc).unwrap();
            let mean = acc.mean_with(Some(&base));
            for (a, b) in dense.iter().zip(&mean) {
                assert!(
                    (a - b).abs() <= 1e-5 * a.abs().max(1.0),
                    "{kind:?}: fold {b} != dense {a}"
                );
            }
        }
    }

    #[test]
    fn decode_rejects_malformed_bodies() {
        // Truncated / oversized structural fields in every codec.
        assert!(decode_dense(CODEC_IDENTITY, 4, &[0u8; 15], &[]).is_err());
        assert!(decode_dense(CODEC_F16, 4, &[0u8; 7], &[]).is_err());
        assert!(decode_dense(CODEC_I8, 4, &[0u8; 2], &[]).is_err());
        // delta run past the end.
        let mut body = Vec::new();
        body.extend_from_slice(&0u32.to_le_bytes());
        body.extend_from_slice(&9u32.to_le_bytes());
        body.extend_from_slice(&[0u8; 36]);
        assert!(decode_dense(CODEC_DELTA, 4, &body, &[]).is_err());
        // topk k > n and index out of range.
        let mut body = Vec::new();
        body.extend_from_slice(&9u32.to_le_bytes());
        assert!(decode_dense(CODEC_TOPK, 4, &body, &[]).is_err());
        let mut body = Vec::new();
        body.extend_from_slice(&1u32.to_le_bytes());
        body.extend_from_slice(&7u32.to_le_bytes());
        body.extend_from_slice(&1.0f32.to_le_bytes());
        assert!(decode_dense(CODEC_TOPK, 4, &body, &[]).is_err());
        // Unknown codec id.
        assert!(decode_dense(99, 4, &[], &[]).is_err());
        let mut acc = MeanAccum::new(4);
        assert!(decode_fold(99, 4, &[], &[], &mut acc).is_err());
    }
}
