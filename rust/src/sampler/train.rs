//! Training mini-batch sampler: random local edges + GraphSAGE fan-out.
//!
//! Per batch (Alg 2 line 8, "Construct mini-batch on local subgraph"):
//! 1. sample `Be` training edges uniformly from the trainer's local
//!    subgraph (directed adjacency entries — uniform over edges);
//! 2. one negative per positive by corrupting the tail with a random
//!    non-neighbour (restricted to items for query-item edges on
//!    bipartite graphs);
//! 3. expand endpoints with fan-out neighbour sampling (default
//!    [10, 5], the usual 2-layer GraphSAGE setting) until the `Bn`
//!    node budget is filled;
//! 4. induce and row-normalise the dense block adjacency.
//!
//! The sampler reuses its block buffers across calls — the hot path
//! allocates nothing after warmup (see EXPERIMENTS.md §Perf).

use std::collections::HashMap;

use crate::graph::Graph;
use crate::util::rng::Rng;

use super::{directional_rel, fill_adj, AdjMode, Block};

#[derive(Clone, Debug)]
pub struct TrainSamplerConfig {
    pub block_nodes: usize,
    pub block_edges: usize,
    pub feat_dim: usize,
    pub fanouts: Vec<usize>,
    pub adj_mode: AdjMode,
    /// Relation planes in the block (1 for homogeneous).
    pub relations: usize,
    /// Bipartite boundary in *global* ids (0 = homogeneous).
    pub boundary: u32,
}

impl TrainSamplerConfig {
    pub fn homogeneous(bn: usize, be: usize, f: usize, mode: AdjMode) -> Self {
        TrainSamplerConfig {
            block_nodes: bn,
            block_edges: be,
            feat_dim: f,
            fanouts: vec![10, 5],
            adj_mode: mode,
            relations: 1,
            boundary: 0,
        }
    }
}

/// Samples blocks from one trainer's local graph.
pub struct TrainSampler {
    cfg: TrainSamplerConfig,
    /// Local graph (a partition's induced subgraph, or the full train
    /// graph for GGS).
    graph: Graph,
    /// Local -> global id map (identity when training on the full graph).
    globals: Vec<u32>,
    block: Block,
    /// Scratch: local node -> block slot.
    slot_of: HashMap<u32, u32>,
}

impl TrainSampler {
    pub fn new(graph: Graph, globals: Vec<u32>, cfg: TrainSamplerConfig) -> Self {
        assert_eq!(graph.num_nodes(), globals.len());
        let bn = cfg.block_nodes;
        let planes = if cfg.adj_mode == AdjMode::Relational {
            cfg.relations
        } else {
            1
        };
        let block = Block {
            feats: vec![0.0; bn * cfg.feat_dim],
            adj: vec![0.0; planes * bn * bn],
            pos_u: vec![0; cfg.block_edges],
            pos_v: vec![0; cfg.block_edges],
            rel: vec![0; cfg.block_edges],
            neg_v: vec![0; cfg.block_edges],
            mask: vec![0.0; cfg.block_edges],
            n_used: 0,
            globals: Vec::with_capacity(bn),
        };
        TrainSampler { cfg, graph, globals, block, slot_of: HashMap::new() }
    }

    pub fn graph(&self) -> &Graph {
        &self.graph
    }

    /// Whether this local graph can produce batches at all.
    pub fn has_edges(&self) -> bool {
        self.graph.num_edges() > 0
    }

    /// Uniform random directed adjacency entry -> undirected edge.
    fn random_edge(&self, rng: &mut Rng) -> (u32, u32, u8) {
        let e = rng.below(self.graph.num_adj());
        // find row via binary search over offsets
        let u = match self.graph.offsets.binary_search(&(e as u64)) {
            Ok(mut i) => {
                // offsets can repeat for degree-0 nodes; take the last
                // row starting at e.
                while i + 1 < self.graph.offsets.len()
                    && self.graph.offsets[i + 1] == e as u64
                {
                    i += 1;
                }
                i
            }
            Err(i) => i - 1,
        };
        let v = self.graph.neighbors[e];
        let r = self.graph.rel.as_ref().map(|rs| rs[e]).unwrap_or(0);
        (u as u32, v, r)
    }

    /// Corrupted tail for `(u, v)`: random local non-neighbour of `u`,
    /// kept within the item population for query-item edges.
    fn negative_tail(&self, u: u32, rng: &mut Rng) -> u32 {
        let n = self.graph.num_nodes();
        for _ in 0..32 {
            let cand = rng.below(n) as u32;
            if cand == u {
                continue;
            }
            if self.cfg.boundary > 0
                && self.globals[cand as usize] < self.cfg.boundary
            {
                continue; // tails must be items on bipartite graphs
            }
            if !self.graph.has_edge(u as usize, cand as usize) {
                return cand;
            }
        }
        // Dense-neighbourhood fallback: accept a random distinct node.
        ((u as usize + 1 + rng.below(n - 1)) % n) as u32
    }

    /// Block slot for local node `v`, inserting if the budget allows.
    fn slot(&mut self, v: u32) -> Option<u32> {
        if let Some(&s) = self.slot_of.get(&v) {
            return Some(s);
        }
        if self.block.n_used >= self.cfg.block_nodes {
            return None;
        }
        let s = self.block.n_used as u32;
        self.block.n_used += 1;
        self.slot_of.insert(v, s);
        self.block.globals.push(self.globals[v as usize]);
        Some(s)
    }

    /// Sample the next training block. Returns None if the local graph
    /// has no edges (a failed/empty partition).
    pub fn next_block(&mut self, rng: &mut Rng) -> Option<&Block> {
        if !self.has_edges() {
            return None;
        }
        let be = self.cfg.block_edges;
        self.block.n_used = 0;
        self.block.globals.clear();
        self.slot_of.clear();

        // 1+2: edges + negatives. Each accepted edge consumes up to 3
        // node slots; stop accepting once the endpoint budget (3/4 of
        // the block — the rest is reserved for fan-out context) is hit
        // and mask the remaining edge slots instead.
        let node_budget = (self.cfg.block_nodes * 3) / 4;
        let mut raw: Vec<(u32, u32, u8, u32)> = Vec::with_capacity(be);
        let mut frontier: Vec<u32> = Vec::new();
        for _ in 0..be {
            if self.block.n_used + 3 > node_budget {
                break;
            }
            let (u, v, r) = self.random_edge(rng);
            let nv = self.negative_tail(u, rng);
            for &x in &[u, v, nv] {
                if !self.slot_of.contains_key(&x) {
                    self.slot(x).expect("within budget");
                    frontier.push(x);
                }
            }
            raw.push((u, v, r, nv));
        }
        let fanouts = self.cfg.fanouts.clone();
        let mut picks: Vec<u32> = Vec::new();
        for fanout in fanouts {
            let mut next_frontier = Vec::new();
            'outer: for &v in &frontier {
                picks.clear();
                {
                    let nbrs = self.graph.neighbors_of(v as usize);
                    let take = fanout.min(nbrs.len());
                    for _ in 0..take {
                        picks.push(nbrs[rng.below(nbrs.len())]);
                    }
                }
                for &u in &picks {
                    let fresh = !self.slot_of.contains_key(&u);
                    match self.slot(u) {
                        Some(_) => {
                            if fresh {
                                next_frontier.push(u);
                            }
                        }
                        None => break 'outer, // block full
                    }
                }
            }
            frontier = next_frontier;
            if frontier.is_empty() {
                break;
            }
        }

        // 4: induced dense adjacency among block nodes.
        let mut edges: Vec<(u32, u32, u8)> = Vec::new();
        let slots: Vec<(u32, u32)> =
            self.slot_of.iter().map(|(&v, &s)| (v, s)).collect();
        for &(v, s) in &slots {
            let rels = self.graph.rels_of(v as usize);
            for (k, &u) in self.graph.neighbors_of(v as usize).iter().enumerate()
            {
                if let Some(&su) = self.slot_of.get(&u) {
                    let r = if self.cfg.adj_mode == AdjMode::Relational {
                        directional_rel(
                            self.globals[v as usize],
                            self.globals[u as usize],
                            rels.map(|rs| rs[k]).unwrap_or(0),
                            self.cfg.boundary,
                        )
                    } else {
                        0
                    };
                    edges.push((s, su, r));
                }
            }
        }
        fill_adj(
            &mut self.block.adj,
            self.cfg.block_nodes,
            self.cfg.relations,
            self.block.n_used,
            &edges,
            self.cfg.adj_mode,
        );

        // Features: the only per-step feature copy in the system — Bn
        // rows gathered from the graph's FeatureStore (a borrowed
        // Shared/Mapped slab row or a private Owned row, bit-identical
        // either way) into the block's reused packing buffer.
        self.block.feats.iter_mut().for_each(|x| *x = 0.0);
        for (&v, &s) in self.slot_of.iter() {
            let dst = s as usize * self.cfg.feat_dim;
            self.block.feats[dst..dst + self.cfg.feat_dim]
                .copy_from_slice(self.graph.feature(v as usize));
        }

        // Edge index arrays; slots beyond `raw.len()` are masked out.
        self.block.pos_u.iter_mut().for_each(|x| *x = 0);
        self.block.pos_v.iter_mut().for_each(|x| *x = 0);
        self.block.neg_v.iter_mut().for_each(|x| *x = 0);
        self.block.rel.iter_mut().for_each(|x| *x = 0);
        self.block.mask.iter_mut().for_each(|x| *x = 0.0);
        for (i, &(u, v, r, nv)) in raw.iter().enumerate() {
            let su = self.slot_of[&u] as i32;
            let sv = self.slot_of[&v] as i32;
            let sn = self.slot_of[&nv] as i32;
            self.block.pos_u[i] = su;
            self.block.pos_v[i] = sv;
            self.block.neg_v[i] = sn;
            self.block.mask[i] = 1.0;
            self.block.rel[i] = if self.cfg.boundary > 0 {
                directional_rel(
                    self.globals[u as usize],
                    self.globals[v as usize],
                    r,
                    self.cfg.boundary,
                ) as i32
            } else {
                0
            };
        }
        Some(&self.block)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::{dcsbm, DcsbmConfig};
    use crate::graph::Subgraph;

    fn graph() -> Graph {
        dcsbm(&DcsbmConfig {
            nodes: 500,
            communities: 5,
            avg_degree: 10.0,
            homophily: 0.8,
            feat_dim: 8,
            feature_noise: 0.3,
            degree_exponent: 0.5,
            seed: 21,
        })
    }

    fn sampler(mode: AdjMode) -> TrainSampler {
        let g = graph();
        let globals: Vec<u32> = (0..g.num_nodes() as u32).collect();
        let cfg = TrainSamplerConfig {
            block_nodes: 64,
            block_edges: 16,
            feat_dim: 8,
            fanouts: vec![4, 3],
            adj_mode: mode,
            relations: 1,
            boundary: 0,
        };
        TrainSampler::new(g, globals, cfg)
    }

    #[test]
    fn block_indices_valid() {
        let mut s = sampler(AdjMode::SelfLoop);
        let mut rng = Rng::new(1);
        for _ in 0..20 {
            let b = s.next_block(&mut rng).unwrap();
            assert!(b.n_used <= 64);
            assert!(b.n_used >= 2);
            let valid = b.mask.iter().filter(|&&m| m == 1.0).count();
            assert!(valid >= 1, "no valid edges");
            // valid slots form a prefix; all indices in range
            for i in 0..16 {
                assert!(b.mask[i] == 0.0 || b.mask[i] == 1.0);
                if i > 0 {
                    assert!(b.mask[i] <= b.mask[i - 1], "mask not a prefix");
                }
                if b.mask[i] == 1.0 {
                    for &x in [&b.pos_u[i], &b.pos_v[i], &b.neg_v[i]] {
                        assert!(
                            (x as usize) < b.n_used,
                            "index {x} >= {}",
                            b.n_used
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn positive_edges_exist_negatives_mostly_dont() {
        let mut s = sampler(AdjMode::SelfLoop);
        let mut rng = Rng::new(2);
        let b = s.next_block(&mut rng).unwrap().clone();
        // recover local ids: block globals == local ids here
        for i in 0..16 {
            if b.mask[i] != 1.0 {
                continue;
            }
            let u = b.globals[b.pos_u[i] as usize] as usize;
            let v = b.globals[b.pos_v[i] as usize] as usize;
            assert!(s.graph().has_edge(u, v), "pos edge missing {u}-{v}");
        }
    }

    #[test]
    fn rows_normalized() {
        let mut s = sampler(AdjMode::SelfLoop);
        let mut rng = Rng::new(3);
        let b = s.next_block(&mut rng).unwrap();
        for i in 0..b.n_used {
            let sum: f32 = b.adj[i * 64..(i + 1) * 64].iter().sum();
            assert!((sum - 1.0).abs() < 1e-5, "row {i}: {sum}");
        }
    }

    #[test]
    fn neighbor_only_mode_excludes_self() {
        let mut s = sampler(AdjMode::NeighborOnly);
        let mut rng = Rng::new(4);
        let b = s.next_block(&mut rng).unwrap();
        for i in 0..b.n_used {
            assert_eq!(b.adj[i * 64 + i], 0.0, "self loop at {i}");
        }
    }

    #[test]
    fn empty_partition_yields_none() {
        let g = graph();
        // single node -> no edges
        let sub = Subgraph::induce(&g, &[0]);
        let cfg = TrainSamplerConfig::homogeneous(64, 16, 8, AdjMode::SelfLoop);
        let mut s = TrainSampler::new(sub.graph, sub.global_ids, cfg);
        assert!(s.next_block(&mut Rng::new(5)).is_none());
    }

    #[test]
    fn deterministic_given_rng() {
        let mut a = sampler(AdjMode::SelfLoop);
        let mut b = sampler(AdjMode::SelfLoop);
        let blk_a = a.next_block(&mut Rng::new(6)).unwrap().clone();
        let blk_b = b.next_block(&mut Rng::new(6)).unwrap().clone();
        assert_eq!(blk_a.pos_u, blk_b.pos_u);
        assert_eq!(blk_a.adj, blk_b.adj);
        assert_eq!(blk_a.feats, blk_b.feats);
    }

    #[test]
    fn prop_block_invariants_across_seeds() {
        crate::util::prop::check(15, 31, |rng: &mut Rng| {
            let mut s = sampler(AdjMode::SelfLoop);
            let b = s.next_block(rng).unwrap();
            crate::prop_assert!(b.globals.len() == b.n_used);
            // all slot features match source graph features
            let set: std::collections::HashSet<_> = b.globals.iter().collect();
            crate::prop_assert!(set.len() == b.n_used, "duplicate slots");
            // padded adjacency region is zero
            for i in b.n_used..64 {
                let row = &b.adj[i * 64..(i + 1) * 64];
                crate::prop_assert!(row.iter().all(|&x| x == 0.0));
            }
            Ok(())
        });
    }
}
