//! Deterministic evaluation blocks + MRR.
//!
//! Evaluation scores each held-out edge (u, v) against its fixed
//! negative candidates (u, v'_1..K) — Mean Reciprocal Rank over the
//! rank of the positive (paper §4.1: fixed negatives, no sampling
//! randomness in evaluation). The plan:
//!
//! 1. collect every node whose embedding is needed (heads, tails,
//!    candidates);
//! 2. pack them as the *target* (first) slots of fixed-shape blocks,
//!    padding the remainder of each block with deterministic 2-hop
//!    neighbourhood context (first-k neighbours by id — no RNG);
//! 3. the evaluator runs the `encode` artifact per block and gathers
//!    target embeddings;
//! 4. score pairs with the `score` artifact in fixed-size chunks and
//!    fold ranks into MRR.

use std::collections::HashMap;

use crate::graph::Graph;

use super::{directional_rel, fill_adj, AdjMode, Block};

#[derive(Clone, Debug)]
pub struct EvalBlockConfig {
    pub block_nodes: usize,
    pub feat_dim: usize,
    pub adj_mode: AdjMode,
    pub relations: usize,
    pub boundary: u32,
    /// Per-hop deterministic neighbour caps for context packing.
    pub context_fanouts: Vec<usize>,
    /// Target slots per block (rest is context).
    pub targets_per_block: usize,
}

impl EvalBlockConfig {
    pub fn new(bn: usize, f: usize, mode: AdjMode, relations: usize,
               boundary: u32) -> Self {
        EvalBlockConfig {
            block_nodes: bn,
            feat_dim: f,
            adj_mode: mode,
            relations,
            boundary,
            context_fanouts: vec![6, 3],
            targets_per_block: bn / 2,
        }
    }
}

/// Prebuilt evaluation schedule over one graph + edge set.
pub struct EvalPlan {
    pub blocks: Vec<Block>,
    /// Targets occupy the first `targets[i]` slots of block i.
    pub targets: Vec<usize>,
    /// global node -> (block index, slot) where its embedding lives.
    pub slot_of: HashMap<u32, (u32, u32)>,
    /// (head, tail, relation) per held-out edge.
    pub edges: Vec<(u32, u32, i32)>,
    /// Fixed candidates per edge.
    pub negatives: Vec<Vec<u32>>,
}

impl EvalPlan {
    /// Build the plan for `edges` + `negatives` over `graph` (the
    /// training graph — held-out edges are absent from it by
    /// construction).
    pub fn build(
        graph: &Graph,
        edges: &[(u32, u32)],
        negatives: &[Vec<u32>],
        cfg: &EvalBlockConfig,
    ) -> EvalPlan {
        assert_eq!(edges.len(), negatives.len());
        // 1: required nodes, deduped, in first-use order (deterministic).
        let mut required: Vec<u32> = Vec::new();
        let mut seen: HashMap<u32, ()> = HashMap::new();
        let need = |v: u32, req: &mut Vec<u32>, seen: &mut HashMap<u32, ()>| {
            if seen.insert(v, ()).is_none() {
                req.push(v);
            }
        };
        for (i, &(u, v)) in edges.iter().enumerate() {
            need(u, &mut required, &mut seen);
            need(v, &mut required, &mut seen);
            for &c in &negatives[i] {
                need(c, &mut required, &mut seen);
            }
        }

        // 2: chunk into blocks.
        let mut blocks = Vec::new();
        let mut targets = Vec::new();
        let mut slot_of = HashMap::new();
        for chunk in required.chunks(cfg.targets_per_block) {
            let bi = blocks.len() as u32;
            let block = build_block(graph, chunk, cfg);
            for (s, &g) in chunk.iter().enumerate() {
                slot_of.insert(g, (bi, s as u32));
            }
            targets.push(chunk.len());
            blocks.push(block);
        }

        // Edge relations (hetero): canonical base rel from the original
        // edge type; 0 for homogeneous graphs.
        let typed_edges = edges
            .iter()
            .map(|&(u, v)| {
                let rel = if cfg.boundary > 0 {
                    let base = graph
                        .neighbors_of(u as usize)
                        .iter()
                        .position(|&x| x == v)
                        .and_then(|k| graph.rels_of(u as usize).map(|rs| rs[k]))
                        // held-out edges are not in the train graph: infer
                        // the type from endpoint populations instead.
                        .unwrap_or(if u < cfg.boundary || v < cfg.boundary {
                            0
                        } else {
                            1
                        });
                    directional_rel(u, v, base, cfg.boundary) as i32
                } else {
                    0
                };
                (u, v, rel)
            })
            .collect();

        EvalPlan {
            blocks,
            targets,
            slot_of,
            edges: typed_edges,
            negatives: negatives.to_vec(),
        }
    }

    /// Scoring pairs in schedule order: for edge i the positive pair
    /// then its K negatives — `(head, candidate, rel)` global ids.
    pub fn pairs(&self) -> impl Iterator<Item = (u32, u32, i32)> + '_ {
        self.edges.iter().enumerate().flat_map(move |(i, &(u, v, r))| {
            std::iter::once((u, v, r))
                .chain(self.negatives[i].iter().map(move |&c| (u, c, r)))
        })
    }

    pub fn num_pairs(&self) -> usize {
        self.edges.len() + self.negatives.iter().map(|n| n.len()).sum::<usize>()
    }
}

/// Build one eval block: `targets` in the leading slots, deterministic
/// neighbour context afterwards.
///
/// Public because the serving batcher (`serve`) reuses it to compute
/// *canonical* per-node embeddings — one single-target block per node,
/// so an embedding is a pure function of `(graph, node, weights)`,
/// independent of which other nodes happen to share a batch. That
/// invariance is what makes the serve LRU cache and the
/// batch-vs-single bit-identity guarantee sound (`tests/serve.rs`).
pub fn build_block(
    graph: &Graph,
    targets: &[u32],
    cfg: &EvalBlockConfig,
) -> Block {
    let bn = cfg.block_nodes;
    let planes = if cfg.adj_mode == AdjMode::Relational {
        cfg.relations
    } else {
        1
    };
    let mut slot_of: HashMap<u32, u32> = HashMap::new();
    let mut globals: Vec<u32> = Vec::with_capacity(bn);
    for &t in targets {
        if !slot_of.contains_key(&t) && globals.len() < bn {
            slot_of.insert(t, globals.len() as u32);
            globals.push(t);
        }
    }
    // deterministic context: first-k neighbours per hop
    let mut frontier: Vec<u32> = globals.clone();
    for &fanout in &cfg.context_fanouts {
        let mut next = Vec::new();
        'outer: for &v in &frontier {
            for &u in graph.neighbors_of(v as usize).iter().take(fanout) {
                if !slot_of.contains_key(&u) {
                    if globals.len() >= bn {
                        break 'outer;
                    }
                    slot_of.insert(u, globals.len() as u32);
                    globals.push(u);
                    next.push(u);
                }
            }
        }
        frontier = next;
        if frontier.is_empty() {
            break;
        }
    }

    let n_used = globals.len();
    // induced adjacency
    let mut edges: Vec<(u32, u32, u8)> = Vec::new();
    for (&v, &s) in slot_of.iter() {
        let rels = graph.rels_of(v as usize);
        for (k, &u) in graph.neighbors_of(v as usize).iter().enumerate() {
            if let Some(&su) = slot_of.get(&u) {
                let r = if cfg.adj_mode == AdjMode::Relational {
                    directional_rel(
                        v,
                        u,
                        rels.map(|rs| rs[k]).unwrap_or(0),
                        cfg.boundary,
                    )
                } else {
                    0
                };
                edges.push((s, su, r));
            }
        }
    }
    let mut adj = vec![0.0f32; planes * bn * bn];
    fill_adj(&mut adj, bn, cfg.relations, n_used, &edges, cfg.adj_mode);

    // Feature gather reads through the graph's FeatureStore — eval
    // plans built over Shared/Mapped-backed train graphs never copy
    // the slab, only the Bn rows each block actually uses.
    let mut feats = vec![0.0f32; bn * cfg.feat_dim];
    for (s, &g) in globals.iter().enumerate() {
        feats[s * cfg.feat_dim..(s + 1) * cfg.feat_dim]
            .copy_from_slice(graph.feature(g as usize));
    }

    Block {
        feats,
        adj,
        pos_u: Vec::new(),
        pos_v: Vec::new(),
        rel: Vec::new(),
        neg_v: Vec::new(),
        mask: Vec::new(),
        n_used,
        globals,
    }
}

/// Mean Reciprocal Rank accumulator.
#[derive(Debug, Default, Clone)]
pub struct Mrr {
    sum: f64,
    count: usize,
}

impl Mrr {
    /// Add one edge's scores: positive first, then the candidates.
    /// Rank = 1 + #candidates with score >= positive (ties pessimistic,
    /// matching OGB's evaluator).
    pub fn add(&mut self, pos_score: f32, neg_scores: &[f32]) {
        let rank =
            1 + neg_scores.iter().filter(|&&s| s >= pos_score).count();
        self.sum += 1.0 / rank as f64;
        self.count += 1;
    }

    pub fn value(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    pub fn count(&self) -> usize {
        self.count
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::{dcsbm, DcsbmConfig};
    use crate::util::rng::Rng;

    fn graph() -> Graph {
        dcsbm(&DcsbmConfig {
            nodes: 400,
            communities: 4,
            avg_degree: 10.0,
            homophily: 0.8,
            feat_dim: 8,
            feature_noise: 0.3,
            degree_exponent: 0.0,
            seed: 8,
        })
    }

    fn plan(k_negs: usize) -> (Graph, EvalPlan) {
        let g = graph();
        let mut rng = Rng::new(1);
        let edges: Vec<(u32, u32)> = (0..10)
            .map(|_| {
                let u = rng.below(400) as u32;
                let v = g.neighbors_of(u as usize)[0];
                (u, v)
            })
            .collect();
        let negs: Vec<Vec<u32>> = edges
            .iter()
            .map(|_| (0..k_negs).map(|_| rng.below(400) as u32).collect())
            .collect();
        let cfg = EvalBlockConfig::new(64, 8, AdjMode::SelfLoop, 1, 0);
        let p = EvalPlan::build(&g, &edges, &negs, &cfg);
        (g, p)
    }

    #[test]
    fn covers_all_required_nodes() {
        let (_, p) = plan(8);
        for &(u, v, _) in &p.edges {
            assert!(p.slot_of.contains_key(&u));
            assert!(p.slot_of.contains_key(&v));
        }
        for negs in &p.negatives {
            for c in negs {
                assert!(p.slot_of.contains_key(c));
            }
        }
    }

    #[test]
    fn targets_lead_each_block() {
        let (_, p) = plan(8);
        for (&g, &(bi, s)) in &p.slot_of {
            let b = &p.blocks[bi as usize];
            assert!((s as usize) < p.targets[bi as usize]);
            assert_eq!(b.globals[s as usize], g);
        }
    }

    #[test]
    fn pair_schedule_interleaves_pos_then_negs() {
        let (_, p) = plan(3);
        let pairs: Vec<_> = p.pairs().collect();
        assert_eq!(pairs.len(), p.num_pairs());
        assert_eq!(pairs.len(), 10 * 4);
        // first group: edge 0 pos then its 3 negatives, same head
        let (u0, v0, _) = p.edges[0];
        assert_eq!(pairs[0].0, u0);
        assert_eq!(pairs[0].1, v0);
        assert!(pairs[1..4].iter().all(|&(u, _, _)| u == u0));
    }

    #[test]
    fn deterministic_plan() {
        let (_, a) = plan(4);
        let (_, b) = plan(4);
        assert_eq!(a.blocks.len(), b.blocks.len());
        for (x, y) in a.blocks.iter().zip(&b.blocks) {
            assert_eq!(x.globals, y.globals);
            assert_eq!(x.adj, y.adj);
        }
    }

    #[test]
    fn mrr_arithmetic() {
        let mut m = Mrr::default();
        m.add(1.0, &[0.5, 0.2]); // rank 1
        m.add(0.1, &[0.5, 0.2]); // rank 3
        assert!((m.value() - (1.0 + 1.0 / 3.0) / 2.0).abs() < 1e-12);
        assert_eq!(m.count(), 2);
        // tie counts against the positive
        let mut t = Mrr::default();
        t.add(0.5, &[0.5]);
        assert!((t.value() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn perfect_model_mrr_is_one() {
        let mut m = Mrr::default();
        for _ in 0..5 {
            m.add(10.0, &[1.0, 2.0, 3.0]);
        }
        assert_eq!(m.value(), 1.0);
    }
}
