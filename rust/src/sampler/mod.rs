//! Mini-batch and evaluation block samplers.
//!
//! The AOT artifacts consume *fixed-shape padded blocks*: `Bn` nodes
//! with a dense row-normalized adjacency, `Be` (positive, negative)
//! edge-index pairs and a validity mask. This module turns CSR
//! (sub)graphs into those blocks:
//!
//! - [`train::TrainSampler`] — GraphSAGE-style fan-out sampling around
//!   a random batch of local training edges, with one corrupted-tail
//!   negative per positive (paper §4.1).
//! - [`eval::EvalPlan`] — deterministic blocks covering the nodes
//!   needed for MRR evaluation (no sampling randomness in eval,
//!   following the paper).
//!
//! Adjacency conventions (must match `python/compile/model.py`):
//! GCN blocks get `D^-1 (A + I)` (self-loops inside the normalisation);
//! SAGE/RGCN blocks get neighbour-only `D^-1 A` (the self path is the
//! model's separate `W_self` term). Heterogeneous blocks carry one
//! row-normalized adjacency per directional relation (R = 4: q→i, i→q,
//! i-i forward, i-i inverse).

pub mod eval;
pub mod train;

pub use eval::{build_block, EvalBlockConfig, EvalPlan, Mrr};
pub use train::{TrainSampler, TrainSamplerConfig};

/// How the dense block adjacency is normalised for the encoder.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdjMode {
    /// Row-normalized with self-loops (GCN; also fine for MLP which
    /// ignores it).
    SelfLoop,
    /// Neighbour-only row normalisation (SAGE's aggregation term).
    NeighborOnly,
    /// Per-relation neighbour-only normalisation (RGCN), R block mats.
    Relational,
}

impl AdjMode {
    /// Mode for an encoder name from the AOT manifest.
    pub fn for_encoder(encoder: &str) -> AdjMode {
        match encoder {
            "sage" => AdjMode::NeighborOnly,
            "rgcn" => AdjMode::Relational,
            _ => AdjMode::SelfLoop,
        }
    }
}

/// One padded training/eval block, laid out exactly as the artifact
/// arguments expect (row-major f32 / i32 buffers).
#[derive(Clone, Debug)]
pub struct Block {
    /// `Bn x F` node features (padding rows zero).
    pub feats: Vec<f32>,
    /// `Bn x Bn` (homogeneous) or `R x Bn x Bn` (relational) dense
    /// row-normalized adjacency.
    pub adj: Vec<f32>,
    /// Local head/tail indices of positive edges, `Be`.
    pub pos_u: Vec<i32>,
    pub pos_v: Vec<i32>,
    /// Relation id per edge (hetero decoders), `Be`.
    pub rel: Vec<i32>,
    /// Corrupted tails, `Be`.
    pub neg_v: Vec<i32>,
    /// 1.0 for valid edge slots, 0.0 for padding, `Be`.
    pub mask: Vec<f32>,
    /// Nodes actually used (<= Bn).
    pub n_used: usize,
    /// Global node id per local slot (len `n_used`).
    pub globals: Vec<u32>,
}

/// Dense row-normalisation helper shared by train/eval block builders.
///
/// `edges` are local (u, v, rel) adjacency entries (directed views).
pub(crate) fn fill_adj(
    adj: &mut [f32],
    bn: usize,
    relations: usize,
    n_used: usize,
    edges: &[(u32, u32, u8)],
    mode: AdjMode,
) {
    adj.iter_mut().for_each(|x| *x = 0.0);
    match mode {
        AdjMode::SelfLoop | AdjMode::NeighborOnly => {
            for &(u, v, _) in edges {
                adj[u as usize * bn + v as usize] = 1.0;
            }
            if mode == AdjMode::SelfLoop {
                for i in 0..n_used {
                    adj[i * bn + i] = 1.0;
                }
            }
            for i in 0..n_used {
                let row = &mut adj[i * bn..i * bn + n_used];
                let deg: f32 = row.iter().sum();
                if deg > 0.0 {
                    row.iter_mut().for_each(|x| *x /= deg);
                }
            }
        }
        AdjMode::Relational => {
            let plane = bn * bn;
            for &(u, v, r) in edges {
                debug_assert!((r as usize) < relations);
                adj[r as usize * plane + u as usize * bn + v as usize] = 1.0;
            }
            for r in 0..relations {
                for i in 0..n_used {
                    let row = &mut adj
                        [r * plane + i * bn..r * plane + i * bn + n_used];
                    let deg: f32 = row.iter().sum();
                    if deg > 0.0 {
                        row.iter_mut().for_each(|x| *x /= deg);
                    }
                }
            }
        }
    }
}

/// Directional relation id for a heterogeneous adjacency entry
/// (paper App. A: 4 = forward + inverse relations).
///
/// `boundary` splits queries (`global < boundary`) from items.
pub(crate) fn directional_rel(
    gu: u32,
    gv: u32,
    base_rel: u8,
    boundary: u32,
) -> u8 {
    match base_rel {
        0 => {
            if gu < boundary {
                0 // query -> item
            } else {
                1 // item -> query
            }
        }
        _ => {
            if gu < gv {
                2 // item-item forward
            } else {
                3 // item-item inverse
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fill_adj_self_loop_rows_stochastic() {
        let bn = 4;
        let mut adj = vec![0.0; bn * bn];
        fill_adj(&mut adj, bn, 1, 3, &[(0, 1, 0), (1, 0, 0)], AdjMode::SelfLoop);
        // rows 0..3 sum to 1; padded row 3 all zero
        for i in 0..3 {
            let s: f32 = adj[i * bn..(i + 1) * bn].iter().sum();
            assert!((s - 1.0).abs() < 1e-6, "row {i} sums {s}");
        }
        assert!(adj[3 * bn..].iter().all(|&x| x == 0.0));
        // node 2 has only its self loop
        assert_eq!(adj[2 * bn + 2], 1.0);
    }

    #[test]
    fn fill_adj_neighbor_only_zero_rows() {
        let bn = 3;
        let mut adj = vec![0.0; bn * bn];
        fill_adj(&mut adj, bn, 1, 3, &[(0, 1, 0)], AdjMode::NeighborOnly);
        assert_eq!(adj[0 * bn + 1], 1.0);
        // isolated node rows stay zero (W_self carries them)
        assert!(adj[1 * bn..2 * bn].iter().all(|&x| x == 0.0));
        assert!(adj[2 * bn..].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn fill_adj_relational_planes() {
        let bn = 2;
        let r = 4;
        let mut adj = vec![0.0; r * bn * bn];
        fill_adj(
            &mut adj,
            bn,
            r,
            2,
            &[(0, 1, 0), (1, 0, 1)],
            AdjMode::Relational,
        );
        assert_eq!(adj[0 * 4 + 0 * bn + 1], 1.0); // rel 0 plane
        assert_eq!(adj[1 * 4 + 1 * bn + 0], 1.0); // rel 1 plane
        assert!(adj[2 * 4..].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn directional_rel_mapping() {
        let b = 10;
        assert_eq!(directional_rel(3, 12, 0, b), 0); // q->i
        assert_eq!(directional_rel(12, 3, 0, b), 1); // i->q
        assert_eq!(directional_rel(11, 14, 1, b), 2); // ii fwd
        assert_eq!(directional_rel(14, 11, 1, b), 3); // ii inv
    }

    #[test]
    fn adj_mode_per_encoder() {
        assert_eq!(AdjMode::for_encoder("gcn"), AdjMode::SelfLoop);
        assert_eq!(AdjMode::for_encoder("mlp"), AdjMode::SelfLoop);
        assert_eq!(AdjMode::for_encoder("sage"), AdjMode::NeighborOnly);
        assert_eq!(AdjMode::for_encoder("rgcn"), AdjMode::Relational);
    }
}
