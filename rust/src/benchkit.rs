//! Shared harness for the paper-table benches (`rust/benches/*.rs`).
//!
//! Each bench binary reproduces one table/figure: it builds the
//! relevant [`RunConfig`]s, runs them through the coordinator, and
//! renders a paper-style table (stdout + `results/*.json`). Common
//! flags:
//!
//! ```text
//! --quick            1/8-size datasets, short training windows
//! --seeds <n>        repeats per cell (mean ± std, like the paper)
//! --train-secs <s>   override ΔT_train
//! --agg-secs <s>     override ρ
//! ```
//!
//! Scale note (DESIGN.md §2): the paper's 4-hour × 8-GPU budget maps
//! to tens of seconds on this single-core testbed; ρ/ΔT_train ratios
//! are preserved.

use crate::config::{Approach, RunConfig};
use crate::coordinator::driver::{default_clusters, run_on_preset};
use crate::gen::{load_preset, Preset};
use crate::metrics::RunResult;
use crate::util::bench::Timing;
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::stats;

/// Common bench parameters parsed from argv.
#[derive(Clone, Debug)]
pub struct BenchOpts {
    pub quick: bool,
    pub seeds: u64,
    pub train_secs: f64,
    pub agg_secs: f64,
    pub negatives: usize,
    pub eval_edges: usize,
    pub eval_sample: usize,
    pub base_seed: u64,
}

impl BenchOpts {
    pub fn parse() -> (BenchOpts, Args) {
        // Budget default: quick mode unless --full is passed (the full
        // datasets + windows need ~10x the wall clock).
        let args = Args::parse(&["quick", "full"]);
        let quick = !args.flag("full");
        let opts = BenchOpts {
            quick,
            seeds: args.u64_or("seeds", 1),
            train_secs: args
                .f64_or("train-secs", if quick { 8.0 } else { 30.0 }),
            agg_secs: args.f64_or("agg-secs", if quick { 1.0 } else { 2.0 }),
            negatives: args.usize_or("negatives", if quick { 32 } else { 64 }),
            eval_edges: args.usize_or("eval-edges", if quick { 64 } else { 128 }),
            eval_sample: args.usize_or("eval-sample", if quick { 32 } else { 64 }),
            base_seed: args.u64_or("seed", 17),
        };
        (opts, args)
    }

    /// Base RunConfig for a dataset/variant/approach cell.
    pub fn config(
        &self,
        dataset: &str,
        variant: &str,
        approach: Approach,
        seed: u64,
    ) -> RunConfig {
        RunConfig {
            dataset: dataset.into(),
            quick: self.quick,
            variant: variant.into(),
            approach,
            train_secs: self.train_secs,
            agg_secs: self.agg_secs,
            eval_edges: self.eval_edges,
            negatives: self.negatives,
            eval_sample: self.eval_sample,
            seed,
            ..RunConfig::default()
        }
    }

    /// Dataset preset shared by all approaches of one table row.
    pub fn preset(&self, dataset: &str, seed: u64) -> anyhow::Result<Preset> {
        load_preset(dataset, self.quick, self.eval_edges, self.negatives, seed)
    }
}

/// The paper's best encoder per dataset (Table 2 selects per-approach
/// bests from Table 7; SAGE wins MAG240M-P, GCN the rest).
pub fn best_variant(dataset: &str) -> &'static str {
    match dataset {
        "mag-sim" => "sage_mlp",
        _ => "gcn_mlp",
    }
}

/// Resolve SuperTMA's N against a dataset (paper: N = 15,000).
pub fn approach_for(preset: &Preset, approach: Approach) -> Approach {
    match approach {
        Approach::SuperTma { num_clusters: 0 } => Approach::SuperTma {
            num_clusters: default_clusters(preset.split.train.num_nodes()),
        },
        other => other,
    }
}

/// One table cell aggregated over seeds.
#[derive(Debug, Clone, Default)]
pub struct Cell {
    pub mrr: Vec<f64>,
    pub conv: Vec<f64>,
    pub ratio_r: f64,
    pub prep: Vec<f64>,
    pub results: Vec<RunResult>,
}

impl Cell {
    pub fn push(&mut self, r: RunResult) {
        self.mrr.push(r.test_mrr * 100.0);
        let c = r.convergence_secs(0.01);
        self.conv.push(if c.is_finite() { c } else { r.wall_secs });
        self.ratio_r = r.ratio_r;
        self.prep.push(r.prep_secs);
        self.results.push(r);
    }

    pub fn mrr_str(&self) -> String {
        stats::fmt_mean_std(&self.mrr, 2)
    }

    pub fn conv_str(&self) -> String {
        stats::fmt_mean_std(&self.conv, 1)
    }

    pub fn mean_mrr(&self) -> f64 {
        stats::mean(&self.mrr)
    }

    pub fn mean_conv(&self) -> f64 {
        stats::mean(&self.conv)
    }

    /// Mean surviving trainers across the cell's runs, off the
    /// authoritative `Control::live_count` carried in each
    /// [`RunResult`] (the failure tables report this instead of their
    /// own bookkeeping).
    pub fn mean_live(&self) -> f64 {
        let live: Vec<f64> = self
            .results
            .iter()
            .map(|r| r.trainers_live as f64)
            .collect();
        stats::mean(&live)
    }
}

/// Run one (dataset, variant, approach) cell over `seeds` repeats.
pub fn run_cell(
    opts: &BenchOpts,
    preset: &Preset,
    variant: &str,
    approach: Approach,
    mutate: impl Fn(&mut RunConfig),
) -> anyhow::Result<Cell> {
    let mut cell = Cell::default();
    for s in 0..opts.seeds {
        let seed = opts.base_seed + s * 1000;
        let mut cfg =
            opts.config(&preset.name, variant, approach_for(preset, approach), seed);
        mutate(&mut cfg);
        eprintln!("[bench] {} seed {}", cfg.label(), seed);
        cell.push(run_on_preset(&cfg, preset)?);
    }
    Ok(cell)
}

/// One timing row of a persisted bench baseline.
#[derive(Clone, Debug, PartialEq)]
pub struct BenchTiming {
    pub label: String,
    pub median_s: f64,
    pub p95_s: f64,
    /// Samples the summary was computed from.
    pub n: usize,
}

impl BenchTiming {
    /// Summarise a finished [`Timing`] series.
    pub fn from_timing(t: &Timing) -> BenchTiming {
        BenchTiming {
            label: t.label.clone(),
            median_s: t.median_s(),
            p95_s: t.p95_s(),
            n: t.samples.len(),
        }
    }
}

/// Schema tag pinned into every baseline file (bump on layout change).
pub const BENCH_SCHEMA: &str = "rtma-bench-v1";

/// A persisted bench baseline: the timing summaries (and optionally
/// counter totals) of one bench section, written to
/// `results/BENCH_<section>.json` so CI uploads them as artifacts and
/// successive runs can be diffed. [`BenchBaseline::from_json`]
/// validates the schema, so a read-back is a round-trip check.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct BenchBaseline {
    pub section: String,
    pub timings: Vec<BenchTiming>,
    pub counters: Vec<(String, f64)>,
}

impl BenchBaseline {
    pub fn new(section: &str) -> BenchBaseline {
        BenchBaseline { section: section.into(), ..Default::default() }
    }

    pub fn push_timing(&mut self, t: &Timing) {
        self.timings.push(BenchTiming::from_timing(t));
    }

    pub fn push_counter(&mut self, name: &str, value: f64) {
        self.counters.push((name.into(), value));
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schema", Json::str(BENCH_SCHEMA)),
            ("section", Json::str(self.section.clone())),
            (
                "timings",
                Json::arr(self.timings.iter().map(|t| {
                    Json::obj(vec![
                        ("label", Json::str(t.label.clone())),
                        ("median_s", Json::num(t.median_s)),
                        ("p95_s", Json::num(t.p95_s)),
                        ("n", Json::num(t.n as f64)),
                    ])
                })),
            ),
            (
                "counters",
                Json::obj(
                    self.counters
                        .iter()
                        .map(|(k, v)| (k.as_str(), Json::num(*v)))
                        .collect(),
                ),
            ),
        ])
    }

    /// Parse + schema-validate a baseline object.
    pub fn from_json(j: &Json) -> anyhow::Result<BenchBaseline> {
        anyhow::ensure!(
            j.get("schema").as_str() == Some(BENCH_SCHEMA),
            "bench baseline: bad or missing schema tag (want {:?})",
            BENCH_SCHEMA
        );
        let section = j
            .get("section")
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("baseline: missing section"))?
            .to_string();
        let mut out = BenchBaseline::new(&section);
        let timings = j
            .get("timings")
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("baseline: missing timings"))?;
        for t in timings {
            let field = |k: &str| -> anyhow::Result<f64> {
                t.get(k).as_f64().ok_or_else(|| {
                    anyhow::anyhow!("baseline timing: missing {k}")
                })
            };
            out.timings.push(BenchTiming {
                label: t
                    .get("label")
                    .as_str()
                    .ok_or_else(|| {
                        anyhow::anyhow!("baseline timing: missing label")
                    })?
                    .to_string(),
                median_s: field("median_s")?,
                p95_s: field("p95_s")?,
                n: field("n")? as usize,
            });
        }
        if let Some(m) = j.get("counters").as_obj() {
            for (k, v) in m {
                if let Some(x) = v.as_f64() {
                    out.counters.push((k.clone(), x));
                }
            }
        }
        Ok(out)
    }

    /// `$RTMA_BENCH_DIR|results/BENCH_<section>.json`.
    pub fn path(section: &str) -> std::path::PathBuf {
        let dir = std::env::var("RTMA_BENCH_DIR")
            .unwrap_or_else(|_| "results".into());
        std::path::Path::new(&dir).join(format!("BENCH_{section}.json"))
    }

    /// Write to [`Self::path`]; returns the path written.
    pub fn write(&self) -> anyhow::Result<std::path::PathBuf> {
        let p = Self::path(&self.section);
        self.to_json().write_file(&p)?;
        Ok(p)
    }

    /// Read + validate the persisted baseline of `section`.
    pub fn read(section: &str) -> anyhow::Result<BenchBaseline> {
        let p = Self::path(section);
        let j = Json::read_file(&p)?;
        Self::from_json(&j)
    }
}

/// Tolerance-gated diff of two persisted baselines — the engine of
/// the `rtma bench-compare` CI regression gate. Returns
/// human-readable regression descriptions; empty means "within
/// tolerance". Rules:
///
/// - timings are matched by label; `median_s`/`p95_s` are
///   lower-better (a new median beyond `old * (1 + tolerance)` gates).
/// - counters are matched by name with the direction inferred from
///   the suffix: `*_qps` / `*_per_sec` are higher-better throughputs,
///   `*_us` / `*_ms` / `*_secs` are lower-better latencies. Anything
///   else (byte totals, round counts, …) is informational and never
///   gates.
/// - entries present on only one side are skipped: new benches appear
///   and old ones retire without tripping the gate.
pub fn compare(
    old: &BenchBaseline,
    new: &BenchBaseline,
    tolerance: f64,
) -> Vec<String> {
    let mut out = Vec::new();
    let worse = 1.0 + tolerance;
    let pct = tolerance * 100.0;
    for nt in &new.timings {
        let Some(ot) = old.timings.iter().find(|t| t.label == nt.label)
        else {
            continue;
        };
        for (what, o, n) in [
            ("median", ot.median_s, nt.median_s),
            ("p95", ot.p95_s, nt.p95_s),
        ] {
            if o > 0.0 && n > o * worse {
                out.push(format!(
                    "{}/{} {what}: {o:.4}s -> {n:.4}s \
                     (+{:.0}% > {pct:.0}% tolerance)",
                    new.section,
                    nt.label,
                    (n / o - 1.0) * 100.0,
                ));
            }
        }
    }
    for (name, nv) in &new.counters {
        let Some((_, ov)) = old.counters.iter().find(|(k, _)| k == name)
        else {
            continue;
        };
        if *ov <= 0.0 {
            continue;
        }
        let higher_better =
            name.ends_with("_qps") || name.ends_with("_per_sec");
        let lower_better = name.ends_with("_us")
            || name.ends_with("_ms")
            || name.ends_with("_secs");
        if higher_better && *nv < ov * (1.0 - tolerance) {
            out.push(format!(
                "{}/{name}: {ov:.1} -> {nv:.1} \
                 (-{:.0}% throughput > {pct:.0}% tolerance)",
                new.section,
                (1.0 - nv / ov) * 100.0,
            ));
        } else if lower_better && *nv > ov * worse {
            out.push(format!(
                "{}/{name}: {ov:.1} -> {nv:.1} \
                 (+{:.0}% latency > {pct:.0}% tolerance)",
                new.section,
                (nv / ov - 1.0) * 100.0,
            ));
        }
    }
    out
}

/// Average ranks across datasets (Table 2's final columns): for each
/// dataset, rank approaches by MRR (higher better) and conv time
/// (lower better), then average each approach's ranks.
pub fn average_ranks(
    mrr_by_dataset: &[Vec<f64>],
    conv_by_dataset: &[Vec<f64>],
) -> (Vec<f64>, Vec<f64>) {
    let n = mrr_by_dataset[0].len();
    let mut mrr_rank_sum = vec![0.0; n];
    let mut conv_rank_sum = vec![0.0; n];
    for (ms, cs) in mrr_by_dataset.iter().zip(conv_by_dataset) {
        for (i, r) in stats::ranks(ms, true).into_iter().enumerate() {
            mrr_rank_sum[i] += r;
        }
        for (i, r) in stats::ranks(cs, false).into_iter().enumerate() {
            conv_rank_sum[i] += r;
        }
    }
    let d = mrr_by_dataset.len() as f64;
    (
        mrr_rank_sum.iter().map(|x| x / d).collect(),
        conv_rank_sum.iter().map(|x| x / d).collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn average_ranks_match_hand_example() {
        // two datasets, three approaches
        let mrr = vec![vec![10.0, 30.0, 20.0], vec![30.0, 20.0, 10.0]];
        let conv = vec![vec![1.0, 2.0, 3.0], vec![3.0, 2.0, 1.0]];
        let (mr, cr) = average_ranks(&mrr, &conv);
        assert_eq!(mr, vec![2.0, 1.5, 2.5]);
        assert_eq!(cr, vec![2.0, 2.0, 2.0]);
    }

    #[test]
    fn best_variant_mapping() {
        assert_eq!(best_variant("mag-sim"), "sage_mlp");
        assert_eq!(best_variant("reddit-sim"), "gcn_mlp");
    }

    #[test]
    fn bench_baseline_roundtrips_through_schema() {
        let mut b = BenchBaseline::new("unit");
        b.push_timing(&Timing {
            label: "fold".into(),
            samples: vec![0.5, 0.3, 0.4],
        });
        b.push_counter("comm_bytes_out", 1234.0);
        let j = b.to_json();
        let back = BenchBaseline::from_json(&j).unwrap();
        assert_eq!(back.section, "unit");
        assert_eq!(back.timings.len(), 1);
        assert_eq!(back.timings[0].label, "fold");
        assert_eq!(back.timings[0].median_s, 0.4);
        assert_eq!(back.timings[0].n, 3);
        assert_eq!(back.counters, vec![("comm_bytes_out".into(), 1234.0)]);
        // The compact text form parses back too (what CI reads).
        let reparsed = crate::util::json::Json::parse(&format!("{j}"))
            .unwrap();
        assert_eq!(BenchBaseline::from_json(&reparsed).unwrap(), back);
    }

    #[test]
    fn bench_baseline_rejects_bad_schema() {
        let j = Json::obj(vec![
            ("schema", Json::str("other-v9")),
            ("section", Json::str("x")),
            ("timings", Json::arr(Vec::new())),
        ]);
        assert!(BenchBaseline::from_json(&j).is_err());
        assert!(BenchBaseline::from_json(&Json::obj(vec![])).is_err());
    }

    #[test]
    fn bench_baseline_path_respects_env_dir() {
        // Default (results/) — don't set the env var here: tests run
        // in parallel and RTMA_BENCH_DIR would race across threads.
        let p = BenchBaseline::path("smoke");
        assert!(p.ends_with("BENCH_smoke.json"), "{p:?}");
    }

    fn baseline_with(
        timings: &[(&str, f64, f64)],
        counters: &[(&str, f64)],
    ) -> BenchBaseline {
        let mut b = BenchBaseline::new("serving");
        for (label, med, p95) in timings {
            b.timings.push(BenchTiming {
                label: label.to_string(),
                median_s: *med,
                p95_s: *p95,
                n: 10,
            });
        }
        for (k, v) in counters {
            b.push_counter(k, *v);
        }
        b
    }

    #[test]
    fn compare_passes_within_tolerance() {
        let old = baseline_with(
            &[("score", 0.010, 0.020)],
            &[("loadgen_qps", 1000.0), ("loadgen_p99_us", 900.0)],
        );
        let new = baseline_with(
            &[("score", 0.011, 0.021)],
            &[("loadgen_qps", 950.0), ("loadgen_p99_us", 1000.0)],
        );
        assert!(compare(&old, &new, 0.2).is_empty());
    }

    #[test]
    fn compare_flags_latency_and_throughput_regressions() {
        let old = baseline_with(
            &[("score", 0.010, 0.020)],
            &[("loadgen_qps", 1000.0), ("loadgen_p99_us", 900.0)],
        );
        // median +50%, qps -40%, p99 +100%: three regressions.
        let new = baseline_with(
            &[("score", 0.015, 0.020)],
            &[("loadgen_qps", 600.0), ("loadgen_p99_us", 1800.0)],
        );
        let regs = compare(&old, &new, 0.2);
        assert_eq!(regs.len(), 3, "{regs:?}");
        assert!(regs.iter().any(|r| r.contains("score median")));
        assert!(regs.iter().any(|r| r.contains("loadgen_qps")));
        assert!(regs.iter().any(|r| r.contains("loadgen_p99_us")));
    }

    #[test]
    fn compare_skips_unmatched_and_directionless_entries() {
        let old = baseline_with(
            &[("gone", 1.0, 1.0)],
            &[("comm_bytes_out", 10.0)],
        );
        // "new" label and a 100x informational counter: no gate. An
        // improvement (faster timing) never gates either.
        let new = baseline_with(
            &[("fresh", 99.0, 99.0)],
            &[("comm_bytes_out", 1000.0)],
        );
        assert!(compare(&old, &new, 0.2).is_empty());
    }

    #[test]
    fn cell_aggregates() {
        let mut c = Cell::default();
        assert_eq!(c.mean_mrr(), 0.0);
        c.mrr = vec![40.0, 50.0];
        c.conv = vec![10.0, 20.0];
        assert_eq!(c.mean_mrr(), 45.0);
        assert_eq!(c.mean_conv(), 15.0);
        assert!(c.mrr_str().starts_with("45.00"));
    }
}
