//! Run metrics: timelines, convergence-time extraction, run results.
//!
//! Mirrors what the paper reports: validation-MRR-vs-time curves
//! (Fig 2), per-trainer loss curves (Fig 3), convergence time ("time to
//! reach within 1% of the maximum validation MRR", Table 2), step
//! counts per trainer (Table 3) and memory proxies.

use crate::util::json::Json;
use crate::util::stats;

/// One point on a trainer's loss timeline.
#[derive(Clone, Copy, Debug)]
pub struct LossPoint {
    /// Seconds since training start.
    pub t: f64,
    pub loss: f32,
    pub step: u64,
}

/// One periodic validation evaluation.
#[derive(Clone, Debug)]
pub struct EvalPoint {
    pub t: f64,
    pub round: u64,
    pub val_mrr: f64,
}

/// Everything one run produces (the unit Tables 2-8 aggregate over).
#[derive(Clone, Debug)]
pub struct RunResult {
    pub label: String,
    /// Validation MRR curve over wall-clock time.
    pub val_curve: Vec<EvalPoint>,
    /// Best validation MRR and the test MRR of those weights.
    pub best_val_mrr: f64,
    pub test_mrr: f64,
    /// Per-trainer loss timelines (Fig 3).
    pub trainer_losses: Vec<Vec<LossPoint>>,
    /// Training steps finished per trainer (Table 3).
    pub steps: Vec<u64>,
    /// Fraction of training edges available across trainers (Table 2 r).
    pub ratio_r: f64,
    /// Partition preprocessing time in seconds (Table 7 "Prep. Time").
    pub prep_secs: f64,
    /// Bytes of local graph data across trainers — the memory proxy
    /// standing in for Table 3's GPU-memory column.
    pub local_bytes: usize,
    /// Wall-clock seconds the run actually trained.
    pub wall_secs: f64,
    /// Trainers the run launched with.
    pub trainers_spawned: usize,
    /// Trainers still live at run end, from the authoritative
    /// `Control::live_count` — the failure drills report survivor
    /// counts off this instead of their own bookkeeping.
    pub trainers_live: usize,
    /// Telemetry registry delta over this run (counters, gauges and
    /// phase histograms) — see [`crate::telemetry::Snapshot`].
    pub telemetry: crate::telemetry::Snapshot,
}

impl RunResult {
    /// Convergence time: first time the validation MRR reaches within
    /// `frac` (paper: 1%) of the run's maximum validation MRR.
    pub fn convergence_secs(&self, frac: f64) -> f64 {
        convergence_secs(&self.val_curve, frac)
    }

    /// [`convergence_secs`] with an explicit no-convergence signal.
    pub fn convergence_secs_opt(&self, frac: f64) -> Option<f64> {
        convergence_secs_opt(&self.val_curve, frac)
    }

    /// Min/max/diff of per-trainer finished steps (Table 3).
    pub fn step_spread(&self) -> (u64, u64, f64) {
        let min = self.steps.iter().copied().min().unwrap_or(0);
        let max = self.steps.iter().copied().max().unwrap_or(0);
        let diff = if max == 0 {
            0.0
        } else {
            (max - min) as f64 / max as f64
        };
        (min, max, diff)
    }

    /// Discrepancy of converged losses across trainers (§4.3.1): std
    /// of each trainer's mean loss over its final quarter.
    pub fn loss_discrepancy(&self) -> f64 {
        let finals: Vec<f64> = self
            .trainer_losses
            .iter()
            .filter(|tl| !tl.is_empty())
            .map(|tl| {
                let tail = &tl[tl.len() - (tl.len() / 4).max(1)..];
                stats::mean(
                    &tail.iter().map(|p| p.loss as f64).collect::<Vec<_>>(),
                )
            })
            .collect();
        stats::std_dev(&finals)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("label", Json::str(self.label.clone())),
            ("best_val_mrr", Json::num(self.best_val_mrr)),
            ("test_mrr", Json::num(self.test_mrr)),
            ("ratio_r", Json::num(self.ratio_r)),
            ("prep_secs", Json::num(self.prep_secs)),
            ("wall_secs", Json::num(self.wall_secs)),
            ("conv_secs", Json::num(self.convergence_secs(0.01))),
            (
                "trainers_spawned",
                Json::num(self.trainers_spawned as f64),
            ),
            ("trainers_live", Json::num(self.trainers_live as f64)),
            ("telemetry", self.telemetry.to_json()),
            (
                "steps",
                Json::arr(self.steps.iter().map(|&s| Json::num(s as f64))),
            ),
            (
                "val_curve",
                Json::arr(self.val_curve.iter().map(|p| {
                    Json::arr([Json::num(p.t), Json::num(p.val_mrr)])
                })),
            ),
            (
                "trainer_losses",
                Json::arr(self.trainer_losses.iter().map(|tl| {
                    Json::arr(tl.iter().map(|p| {
                        Json::arr([Json::num(p.t), Json::num(p.loss as f64)])
                    }))
                })),
            ),
        ])
    }
}

/// Paper rule: time to reach within `frac` of the max validation MRR.
/// `f64::INFINITY` when the run never converged (see
/// [`convergence_secs_opt`] for the explicit form).
pub fn convergence_secs(curve: &[EvalPoint], frac: f64) -> f64 {
    convergence_secs_opt(curve, frac).unwrap_or(f64::INFINITY)
}

/// [`convergence_secs`], but `None` instead of `INFINITY` when there
/// is no convergence time: an empty curve, a curve whose best MRR is
/// non-positive (nothing to be within 1% *of*), or an all-NaN curve
/// (a diverged model scoring NaN everywhere). NaN points are skipped
/// — a single NaN eval must neither panic nor poison the max — and
/// the threshold crossing is searched over finite points only.
pub fn convergence_secs_opt(
    curve: &[EvalPoint],
    frac: f64,
) -> Option<f64> {
    let best = curve
        .iter()
        .map(|p| p.val_mrr)
        .filter(|v| v.is_finite())
        .fold(f64::NEG_INFINITY, f64::max);
    if !best.is_finite() || best <= 0.0 {
        return None;
    }
    let threshold = best * (1.0 - frac);
    curve
        .iter()
        .find(|p| p.val_mrr.is_finite() && p.val_mrr >= threshold)
        .map(|p| p.t)
}

/// Write a (time, value) series as CSV (for Figs 2-3 replotting).
pub fn write_series_csv(
    path: &std::path::Path,
    header: &str,
    rows: &[(f64, f64)],
) -> anyhow::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut out = String::from(header);
    out.push('\n');
    for (t, v) in rows {
        out.push_str(&format!("{t:.3},{v:.6}\n"));
    }
    std::fs::write(path, out)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn curve(points: &[(f64, f64)]) -> Vec<EvalPoint> {
        points
            .iter()
            .enumerate()
            .map(|(i, &(t, v))| EvalPoint { t, round: i as u64, val_mrr: v })
            .collect()
    }

    #[test]
    fn convergence_uses_one_percent_rule() {
        // max = 0.80; threshold = 0.792; first time reaching it = 20s
        let c = curve(&[(10.0, 0.70), (20.0, 0.795), (30.0, 0.80)]);
        assert_eq!(convergence_secs(&c, 0.01), 20.0);
    }

    #[test]
    fn convergence_handles_monotone_and_flat() {
        let c = curve(&[(5.0, 0.5)]);
        assert_eq!(convergence_secs(&c, 0.01), 5.0);
        assert!(convergence_secs(&[], 0.01).is_infinite());
    }

    #[test]
    fn convergence_plateau_at_max_from_t_zero() {
        // Best value from the very first point: convergence is t=0,
        // not the end of the plateau.
        let c = curve(&[(0.0, 0.8), (10.0, 0.8), (20.0, 0.8)]);
        assert_eq!(convergence_secs_opt(&c, 0.01), Some(0.0));
    }

    #[test]
    fn convergence_non_monotone_takes_first_crossing() {
        // Peak in the middle, dip after: the first crossing of the
        // 1%-of-max threshold counts, even though later points fall
        // back below it.
        let c = curve(&[
            (10.0, 0.50),
            (20.0, 0.80),
            (30.0, 0.60),
            (40.0, 0.795),
        ]);
        assert_eq!(convergence_secs_opt(&c, 0.01), Some(20.0));
    }

    #[test]
    fn convergence_single_point_is_its_own_max() {
        let c = curve(&[(7.5, 0.3)]);
        assert_eq!(convergence_secs_opt(&c, 0.01), Some(7.5));
        // ... unless that single point is non-positive.
        let z = curve(&[(7.5, 0.0)]);
        assert_eq!(convergence_secs_opt(&z, 0.01), None);
    }

    #[test]
    fn convergence_all_nan_curve_returns_none() {
        let c = curve(&[(1.0, f64::NAN), (2.0, f64::NAN)]);
        assert_eq!(convergence_secs_opt(&c, 0.01), None);
        assert!(convergence_secs(&c, 0.01).is_infinite());
    }

    #[test]
    fn convergence_skips_nan_points_without_poisoning_max() {
        // One diverged eval (NaN) mid-curve: the max and the crossing
        // search must both skip it.
        let c = curve(&[(1.0, 0.2), (2.0, f64::NAN), (3.0, 0.9)]);
        assert_eq!(convergence_secs_opt(&c, 0.01), Some(3.0));
    }

    fn result_with(steps: Vec<u64>, losses: Vec<Vec<(f64, f32)>>) -> RunResult {
        RunResult {
            label: "t".into(),
            val_curve: vec![],
            best_val_mrr: 0.0,
            test_mrr: 0.0,
            trainer_losses: losses
                .into_iter()
                .map(|tl| {
                    tl.into_iter()
                        .enumerate()
                        .map(|(i, (t, loss))| LossPoint { t, loss, step: i as u64 })
                        .collect()
                })
                .collect(),
            steps,
            ratio_r: 0.0,
            prep_secs: 0.0,
            local_bytes: 0,
            wall_secs: 0.0,
            trainers_spawned: 0,
            trainers_live: 0,
            telemetry: Default::default(),
        }
    }

    #[test]
    fn step_spread_matches_table3_definition() {
        let r = result_with(vec![380, 533, 400], vec![]);
        let (min, max, diff) = r.step_spread();
        assert_eq!((min, max), (380, 533));
        assert!((diff - (533.0 - 380.0) / 533.0).abs() < 1e-12);
    }

    #[test]
    fn loss_discrepancy_zero_for_identical_trainers() {
        let tl = vec![(0.0, 1.0f32), (1.0, 0.5), (2.0, 0.4), (3.0, 0.4)];
        let r = result_with(vec![], vec![tl.clone(), tl.clone(), tl]);
        assert!(r.loss_discrepancy() < 1e-9);
    }

    #[test]
    fn loss_discrepancy_positive_when_trainers_diverge() {
        let a = vec![(0.0, 1.0f32), (1.0, 0.2), (2.0, 0.2), (3.0, 0.2)];
        let b = vec![(0.0, 1.0f32), (1.0, 0.9), (2.0, 0.9), (3.0, 0.9)];
        let r = result_with(vec![], vec![a, b]);
        assert!(r.loss_discrepancy() > 0.3);
    }

    #[test]
    fn csv_writer_emits_rows() {
        let p = std::env::temp_dir().join("rtma_series.csv");
        write_series_csv(&p, "t,v", &[(1.0, 2.0), (3.0, 4.0)]).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert!(text.starts_with("t,v\n1.000,2.000000\n"));
        std::fs::remove_file(p).ok();
    }
}
