//! Fused multi-partition subgraph induction — the prep hot path.
//!
//! [`Subgraph::induce`] is the *reference* implementation: per part it
//! builds a `HashMap` global→local index, feeds every internal edge
//! through a [`GraphBuilder`] and pays an O(E log E) re-sort, so
//! materialising all `k` trainer subgraphs scans the parent CSR `k`
//! times and sorts what was already sorted. [`induce_all`] replaces
//! that with one fused, single-logical-pass extraction:
//!
//! 1. a **dense** `global → (part, local)` index array (two `Vec`
//!    lookups per adjacency entry, no hashing);
//! 2. per-part CSRs built **count-then-fill** directly from the
//!    parent's sorted rows — local ids are assigned in ascending
//!    global order, so the monotone global→local map emits already
//!    sorted local rows and no builder or re-sort is needed;
//! 3. partitions extracted in parallel on [`parallel_map`] workers
//!    (each parent adjacency entry belongs to exactly one part's node
//!    range, so the parts together traverse the edge set once);
//! 4. per-part cut counts returned on each [`Subgraph`], letting
//!    [`partition_stats_with_cuts`] skip its own full edge scan;
//! 5. feature slabs **shared, not copied**: each part's store is a
//!    [`FeatureStore::view`] over the parent — an index-only `Shared`
//!    (or `Mapped`) view when the parent uses a sharable backend, so
//!    extracting `k` trainer subgraphs moves zero feature floats and
//!    all trainers borrow one slab via `Arc`. Only an `Owned` parent
//!    still gathers per-part copies (the reference semantics).
//!
//! The output reads identically to running [`Subgraph::induce`] on
//! each part of the assignment — bit-for-bit on every `feature(v)`
//! slice across all three store backends (see the differential tests
//! at the bottom), which is what the coordinator relied on before this
//! path existed.
//!
//! [`partition_stats_with_cuts`]: crate::partition::partition_stats_with_cuts
//! [`FeatureStore::view`]: super::FeatureStore::view

use crate::util::threadpool::parallel_map;

use super::{Graph, Subgraph};

/// Induce all `k` partition subgraphs of `assignment` at once.
///
/// `assignment[v]` is node `v`'s partition in `0..k` (every node must
/// be assigned — this is the coordinator's R1 contract). Returns one
/// [`Subgraph`] per partition, index-aligned with trainer ids; empty
/// partitions yield empty subgraphs. Each subgraph's `cut_edges` is
/// the number of directed parent adjacency entries leaving the
/// partition, so across a full assignment they sum to twice the
/// undirected edge-cut.
///
/// Relation types are copied per directed entry from the parent, which
/// assumes the parent stores symmetric relations — true of every
/// [`GraphBuilder`]-built graph ([`Subgraph::induce`] makes the same
/// assumption by copying the lower-endpoint row's value).
///
/// [`GraphBuilder`]: crate::graph::GraphBuilder
pub fn induce_all(parent: &Graph, assignment: &[u32], k: usize) -> Vec<Subgraph> {
    induce_all_except(parent, assignment, k, &[])
}

/// [`induce_all`] for the coordinator's failure drills: partitions
/// listed in `skip` (trainers lost at start) still contribute *exact*
/// cut counts — the partition statistics describe the full assignment
/// regardless of who survives — but their CSRs and feature slabs are
/// never materialised, so failure runs pay extraction cost only for
/// surviving trainers, as the serial path always did. Skipped entries
/// come back as placeholders: correct `global_ids` and `cut_edges`,
/// empty graph.
pub fn induce_all_except(
    parent: &Graph,
    assignment: &[u32],
    k: usize,
    skip: &[usize],
) -> Vec<Subgraph> {
    assert_eq!(
        assignment.len(),
        parent.num_nodes(),
        "assignment must cover every parent node"
    );

    // Dense global → (part, local) index. Locals count same-part nodes
    // in ascending global order, so each part's `global_ids` list is
    // born sorted and the global→local map is monotone within a part.
    let n = parent.num_nodes();
    let mut local_of: Vec<u32> = vec![0; n];
    let mut parts: Vec<Vec<u32>> = vec![Vec::new(); k];
    for v in 0..n {
        let p = assignment[v] as usize;
        assert!(p < k, "node {v} assigned to part {p} >= k={k}");
        local_of[v] = parts[p].len() as u32;
        parts[p].push(v as u32);
    }

    let workers = std::thread::available_parallelism()
        .map_or(1, |c| c.get())
        .min(k.max(1));
    parallel_map(k, workers, |p| {
        if skip.contains(&p) {
            cut_only_placeholder(parent, assignment, &parts[p], p as u32)
        } else {
            induce_part(parent, assignment, &local_of, &parts[p], p as u32)
        }
    })
}

/// Count a skipped partition's cut views without building its CSR or
/// copying its feature slab (the data is lost with its trainer).
fn cut_only_placeholder(
    parent: &Graph,
    assignment: &[u32],
    part: &[u32],
    p: u32,
) -> Subgraph {
    let mut cut = 0usize;
    for &g in part {
        for &nb in parent.neighbors_of(g as usize) {
            if assignment[nb as usize] != p {
                cut += 1;
            }
        }
    }
    let graph = Graph {
        offsets: vec![0].into(),
        feat_dim: parent.feat_dim,
        num_classes: parent.num_classes,
        num_relations: parent.num_relations,
        ..Graph::default()
    };
    Subgraph { graph, global_ids: part.to_vec(), cut_edges: cut }
}

/// Build one partition's subgraph by count-then-fill over the parent
/// rows of its nodes. `part` holds the partition's global ids in
/// ascending order.
fn induce_part(
    parent: &Graph,
    assignment: &[u32],
    local_of: &[u32],
    part: &[u32],
    p: u32,
) -> Subgraph {
    let size = part.len();

    // Pass 1: per-node internal degree → CSR offsets, plus cut views.
    let mut offsets = vec![0u64; size + 1];
    let mut cut = 0usize;
    for (l, &g) in part.iter().enumerate() {
        let mut internal = 0u64;
        for &nb in parent.neighbors_of(g as usize) {
            if assignment[nb as usize] == p {
                internal += 1;
            } else {
                cut += 1;
            }
        }
        offsets[l + 1] = internal;
    }
    for l in 0..size {
        offsets[l + 1] += offsets[l];
    }
    let num_adj = offsets[size] as usize;

    // Pass 2: fill. Parent rows are sorted by global id and the
    // global→local map is monotone within the part, so appending in
    // row order yields sorted local rows — no re-sort.
    let mut neighbors: Vec<u32> = Vec::with_capacity(num_adj);
    let mut rel: Vec<u8> = if parent.rel.is_some() {
        Vec::with_capacity(num_adj)
    } else {
        Vec::new()
    };
    let mut any_rel = false;
    for &g in part {
        let row = parent.neighbors_of(g as usize);
        match parent.rels_of(g as usize) {
            Some(rels) => {
                for (i, &nb) in row.iter().enumerate() {
                    if assignment[nb as usize] == p {
                        neighbors.push(local_of[nb as usize]);
                        any_rel |= rels[i] > 0;
                        rel.push(rels[i]);
                    }
                }
            }
            None => {
                for &nb in row {
                    if assignment[nb as usize] == p {
                        neighbors.push(local_of[nb as usize]);
                    }
                }
            }
        }
    }
    debug_assert_eq!(neighbors.len(), num_adj);

    // Features: an index view over the parent's slab — zero floats
    // copied for Shared/Mapped parents (the coordinator's run-time
    // backends), a gathering copy only for Owned ones. Labels are a
    // 2-byte-per-node copy and stay private.
    let feat_dim = parent.feat_dim;
    let features = parent.features.view(part, feat_dim);
    let mut labels: Vec<u16> = Vec::with_capacity(size);
    for &g in part {
        labels.push(parent.labels[g as usize]);
    }

    let graph = Graph {
        offsets: offsets.into(),
        neighbors: neighbors.into(),
        // Match the reference semantics: a subgraph records relation
        // types only when an internal entry is actually typed (>0) —
        // GraphBuilder's `hetero` flag behaves the same way.
        rel: if any_rel { Some(rel.into()) } else { None },
        features,
        feat_dim,
        labels: labels.into(),
        num_classes: parent.num_classes,
        num_relations: parent.num_relations,
    };
    Subgraph { graph, global_ids: part.to_vec(), cut_edges: cut }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::{bipartite, dcsbm, BipartiteConfig, DcsbmConfig};
    use crate::graph::{FeatureStore, GraphBuilder};
    use crate::partition::{parts_of, random_partition};
    use crate::util::rng::Rng;

    /// Field-for-field equality against the reference implementation
    /// (features compared bit-for-bit through the store accessors, so
    /// the same check covers every backend).
    fn diff(a: &Subgraph, b: &Subgraph) -> Result<(), String> {
        crate::prop_assert!(a.global_ids == b.global_ids, "global_ids");
        crate::prop_assert!(a.cut_edges == b.cut_edges, "cut_edges");
        crate::prop_assert!(a.graph.offsets == b.graph.offsets, "offsets");
        crate::prop_assert!(
            a.graph.neighbors == b.graph.neighbors,
            "neighbors"
        );
        crate::prop_assert!(a.graph.rel == b.graph.rel, "rel");
        crate::prop_assert!(a.graph.feat_dim == b.graph.feat_dim, "feat_dim");
        crate::prop_assert!(
            a.graph.features.rows_equal(&b.graph.features, a.graph.feat_dim),
            "features ({} vs {})",
            a.graph.features.backend(),
            b.graph.features.backend()
        );
        crate::prop_assert!(a.graph.labels == b.graph.labels, "labels");
        crate::prop_assert!(
            a.graph.num_classes == b.graph.num_classes,
            "num_classes"
        );
        crate::prop_assert!(
            a.graph.num_relations == b.graph.num_relations,
            "num_relations"
        );
        Ok(())
    }

    use crate::graph::features::rehost_backends as backends;

    fn assert_matches_reference(g: &Graph, assign: &[u32], k: usize) {
        // Reference: the serial copying path over the Owned baseline.
        let parts = parts_of(assign, k);
        let baseline = {
            let mut h = g.clone();
            h.features = h.features.to_vec(h.feat_dim).into();
            h
        };
        let references: Vec<Subgraph> =
            parts.iter().map(|p| Subgraph::induce(&baseline, p)).collect();

        for (backend, host) in backends(g, "ref") {
            let fused = induce_all(&host, assign, k);
            assert_eq!(fused.len(), k);
            for (p, reference) in references.iter().enumerate() {
                diff(&fused[p], reference).unwrap_or_else(|f| {
                    panic!("backend {backend}, part {p}: {f} mismatch")
                });
            }
            // Cut views from inside each part account for every cross
            // edge twice; internal edges partition the remainder.
            let internal: usize =
                fused.iter().map(|s| s.graph.num_edges()).sum();
            let cut_views: usize =
                fused.iter().map(|s| s.cut_edges).sum();
            assert_eq!(cut_views % 2, 0);
            assert_eq!(internal + cut_views / 2, g.num_edges());
        }
    }

    #[test]
    fn matches_reference_on_dcsbm_preset() {
        let g = dcsbm(&DcsbmConfig {
            nodes: 1500,
            communities: 10,
            avg_degree: 12.0,
            homophily: 0.8,
            feat_dim: 8,
            feature_noise: 0.5,
            degree_exponent: 0.8,
            seed: 9,
        });
        let mut rng = Rng::new(11);
        for k in [1, 2, 5, 8] {
            let assign = random_partition(g.num_nodes(), k, &mut rng);
            assert_matches_reference(&g, &assign, k);
        }
    }

    #[test]
    fn matches_reference_on_bipartite_hetero_preset() {
        let bg = bipartite(&BipartiteConfig {
            num_queries: 200,
            num_items: 300,
            communities: 5,
            qi_degree: 6.0,
            ii_degree: 4.0,
            homophily: 0.8,
            feat_dim: 8,
            feature_noise: 0.4,
            seed: 13,
        });
        assert!(bg.graph.rel.is_some(), "bipartite preset must be typed");
        let mut rng = Rng::new(17);
        for k in [2, 4] {
            let assign = random_partition(bg.graph.num_nodes(), k, &mut rng);
            assert_matches_reference(&bg.graph, &assign, k);
        }
    }

    #[test]
    fn empty_parts_yield_empty_subgraphs() {
        let mut b = GraphBuilder::new(4);
        b.add_edge(0, 1);
        b.add_edge(2, 3);
        let mut g = b.build();
        g.feat_dim = 1;
        g.features = (0..4).map(|i| i as f32).collect::<Vec<f32>>().into();
        // part 1 is never assigned
        let assign = vec![0, 0, 2, 2];
        let subs = induce_all(&g, &assign, 3);
        assert_eq!(subs[1].num_nodes(), 0);
        assert_eq!(subs[1].graph.num_adj(), 0);
        assert_eq!(subs[1].graph.offsets, vec![0]);
        assert_eq!(subs[1].cut_edges, 0);
        assert_eq!(subs[0].graph.num_edges(), 1);
        assert_eq!(subs[2].graph.num_edges(), 1);
    }

    #[test]
    fn skipped_parts_keep_exact_cuts_without_materialising() {
        let g = dcsbm(&DcsbmConfig {
            nodes: 800,
            communities: 8,
            avg_degree: 10.0,
            homophily: 0.8,
            feat_dim: 4,
            feature_noise: 0.5,
            degree_exponent: 0.5,
            seed: 31,
        });
        let mut rng = Rng::new(33);
        let k = 4;
        let assign = random_partition(g.num_nodes(), k, &mut rng);
        // The drill path must behave identically on every backend.
        for (backend, host) in backends(&g, "drill") {
            let full = induce_all(&host, &assign, k);
            let drilled = induce_all_except(&host, &assign, k, &[1, 3]);
            for p in 0..k {
                assert_eq!(
                    drilled[p].cut_edges, full[p].cut_edges,
                    "{backend} part {p}: cuts must not depend on skipping"
                );
                assert_eq!(drilled[p].global_ids, full[p].global_ids);
            }
            // Skipped parts carry no graph data — the lost partition is
            // never materialised in any backend.
            for p in [1usize, 3] {
                assert_eq!(drilled[p].graph.num_nodes(), 0, "{backend}");
                assert!(drilled[p].graph.neighbors.is_empty());
                assert!(drilled[p].graph.features.is_empty());
                assert_eq!(drilled[p].graph.features.heap_bytes(), 0);
            }
            for p in [0usize, 2] {
                diff(&drilled[p], &full[p])
                    .unwrap_or_else(|f| panic!("{backend}: {f}"));
            }
        }
    }

    #[test]
    #[should_panic(expected = "assignment must cover")]
    fn rejects_short_assignment() {
        let g = GraphBuilder::new(3).build();
        induce_all(&g, &[0, 0], 1);
    }

    #[test]
    fn prop_matches_reference_on_random_graphs() {
        crate::util::prop::check(25, 29, |rng: &mut Rng| {
            let n = rng.range(1, 80);
            let hetero = rng.chance(0.5);
            let mut b = GraphBuilder::new(n);
            for _ in 0..rng.range(0, 250) {
                let r = if hetero { rng.below(3) as u8 } else { 0 };
                b.add_rel_edge(
                    rng.below(n) as u32,
                    rng.below(n) as u32,
                    r,
                );
            }
            let mut g = b.build();
            g.feat_dim = rng.below(3);
            let feats: Vec<f32> =
                (0..n * g.feat_dim).map(|_| rng.f32()).collect();
            // Half the cases exercise the zero-copy Shared backend
            // (Mapped is covered by the preset-based tests — per-case
            // file IO would dominate the property run).
            g.features = if rng.chance(0.5) {
                FeatureStore::shared_from_vec(feats, g.feat_dim)
            } else {
                feats.into()
            };
            g.labels =
                (0..n).map(|_| rng.below(4) as u16).collect::<Vec<_>>().into();
            g.num_classes = 4;

            let k = rng.range(1, 7);
            let assign: Vec<u32> =
                (0..n).map(|_| rng.below(k) as u32).collect();
            let fused = induce_all(&g, &assign, k);
            let parts = parts_of(&assign, k);
            for (p, part) in parts.iter().enumerate() {
                let reference = Subgraph::induce(&g, part);
                diff(&fused[p], &reference)?;
            }
            let internal: usize =
                fused.iter().map(|s| s.graph.num_edges()).sum();
            let cut_views: usize =
                fused.iter().map(|s| s.cut_edges).sum();
            crate::prop_assert!(
                internal + cut_views / 2 == g.num_edges(),
                "edge accounting: internal={internal} cuts={cut_views} \
                 total={}",
                g.num_edges()
            );
            Ok(())
        });
    }
}
