//! Link-prediction train/val/test splits.
//!
//! Follows the paper's protocol for Reddit / MAG240M-P (§4.1): select
//! a set of probe nodes, remove one incident edge per probe node for
//! validation and one for test, and train on the remaining graph. Also
//! samples the fixed negative-candidate sets used for MRR evaluation
//! (the paper fixes 1000 negatives per positive across runs; the count
//! is configurable here).

use crate::util::rng::Rng;

use super::{Graph, GraphBuilder};

/// A link-prediction split over one graph.
#[derive(Clone, Debug)]
pub struct LinkSplit {
    /// Training graph: the original with val/test edges removed.
    pub train: Graph,
    /// Held-out positive edges.
    pub val: Vec<(u32, u32)>,
    pub test: Vec<(u32, u32)>,
    /// Fixed negative candidates per val/test edge, `[k]` tails each.
    pub val_negatives: Vec<Vec<u32>>,
    pub test_negatives: Vec<Vec<u32>>,
}

/// Remove `per_split` edges each for val and test. Only edges whose
/// endpoints keep degree >= 2 are eligible, so the training graph never
/// gains isolated nodes. Negatives are tails sampled uniformly from
/// non-neighbours, fixed per edge (seeded) across runs.
pub fn split_links(
    g: &Graph,
    per_split: usize,
    negatives: usize,
    seed: u64,
) -> LinkSplit {
    let mut rng = Rng::new(seed);
    let n = g.num_nodes();

    let mut degree: Vec<usize> = (0..n).map(|v| g.degree(v)).collect();
    let mut removed: std::collections::HashSet<(u32, u32)> =
        std::collections::HashSet::new();
    let mut held = Vec::with_capacity(per_split * 2);

    // Sample held-out edges by rejection from the edge set.
    let all_edges: Vec<(u32, u32)> = g.edges().collect();
    let mut order: Vec<usize> = (0..all_edges.len()).collect();
    rng.shuffle(&mut order);
    for &ei in &order {
        if held.len() == per_split * 2 {
            break;
        }
        let (u, v) = all_edges[ei];
        if degree[u as usize] >= 2 && degree[v as usize] >= 2 {
            degree[u as usize] -= 1;
            degree[v as usize] -= 1;
            removed.insert((u, v));
            held.push((u, v));
        }
    }
    let val: Vec<_> = held[..held.len() / 2].to_vec();
    let test: Vec<_> = held[held.len() / 2..].to_vec();

    // Rebuild training CSR without the held-out edges.
    let mut b = GraphBuilder::new(n);
    for u in 0..n {
        let rels = g.rels_of(u);
        for (k, &v) in g.neighbors_of(u).iter().enumerate() {
            if (u as u32) < v {
                let key = (u as u32, v);
                if !removed.contains(&key) {
                    b.add_rel_edge(u as u32, v, rels.map(|r| r[k]).unwrap_or(0));
                }
            }
        }
    }
    let mut train = b.build();
    train.features = g.features.clone();
    train.feat_dim = g.feat_dim;
    train.labels = g.labels.clone();
    train.num_classes = g.num_classes;
    train.num_relations = g.num_relations;

    let negs_for = |edges: &[(u32, u32)], rng: &mut Rng| {
        edges
            .iter()
            .map(|&(u, _)| sample_negatives(g, u, negatives, rng))
            .collect::<Vec<_>>()
    };
    let val_negatives = negs_for(&val, &mut rng);
    let test_negatives = negs_for(&test, &mut rng);

    LinkSplit { train, val, test, val_negatives, test_negatives }
}

/// `count` negative tails for source `u`, uniform over non-neighbours
/// (duplicates are possible, matching the paper's sampled-candidate
/// protocol). Rejection sampling is fast when non-neighbours abound —
/// the common case — but a hub adjacent to almost every node used to
/// spin forever, so the attempts are bounded and the remainder is
/// drawn from an explicitly materialised non-neighbour pool.
///
/// Panics (cleanly, with the offending node) only when `u` is adjacent
/// to *every* other node, i.e. no negative candidate exists at all.
fn sample_negatives(g: &Graph, u: u32, count: usize, rng: &mut Rng) -> Vec<u32> {
    let n = g.num_nodes();
    let mut negs = Vec::with_capacity(count);
    // Acceptance rate is (n - 1 - deg(u)) / n; 32 tries per slot covers
    // everything but near-complete rows without changing the sampled
    // stream on ordinary graphs.
    let mut attempts = 32 * count + 64;
    while negs.len() < count && attempts > 0 {
        attempts -= 1;
        let cand = rng.below(n) as u32;
        if cand != u && !g.has_edge(u as usize, cand as usize) {
            negs.push(cand);
        }
    }
    if negs.len() < count {
        let pool: Vec<u32> = (0..n as u32)
            .filter(|&v| v != u && !g.has_edge(u as usize, v as usize))
            .collect();
        assert!(
            !pool.is_empty(),
            "node {u} is adjacent to every other node — no negative \
             candidates exist"
        );
        while negs.len() < count {
            negs.push(pool[rng.below(pool.len())]);
        }
    }
    negs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen;
    use crate::util::rng::Rng;

    fn toy() -> Graph {
        gen::dcsbm(&gen::DcsbmConfig {
            nodes: 300,
            communities: 4,
            avg_degree: 12.0,
            homophily: 0.8,
            feat_dim: 4,
            feature_noise: 0.5,
            degree_exponent: 0.0,
            seed: 3,
        })
    }

    #[test]
    fn split_sizes_and_disjoint() {
        let g = toy();
        let s = split_links(&g, 40, 16, 7);
        assert_eq!(s.val.len(), 40);
        assert_eq!(s.test.len(), 40);
        assert_eq!(s.train.num_edges(), g.num_edges() - 80);
        // held-out edges absent from train
        for &(u, v) in s.val.iter().chain(&s.test) {
            assert!(!s.train.has_edge(u as usize, v as usize));
            assert!(g.has_edge(u as usize, v as usize));
        }
    }

    #[test]
    fn negatives_are_true_negatives() {
        let g = toy();
        let s = split_links(&g, 20, 8, 7);
        for (i, &(u, _)) in s.val.iter().enumerate() {
            assert_eq!(s.val_negatives[i].len(), 8);
            for &c in &s.val_negatives[i] {
                assert!(!g.has_edge(u as usize, c as usize));
                assert_ne!(c, u);
            }
        }
    }

    #[test]
    fn split_deterministic_per_seed() {
        let g = toy();
        let a = split_links(&g, 10, 4, 9);
        let b = split_links(&g, 10, 4, 9);
        assert_eq!(a.val, b.val);
        assert_eq!(a.test_negatives, b.test_negatives);
        let c = split_links(&g, 10, 4, 10);
        assert_ne!(a.val, c.val);
    }

    #[test]
    fn no_isolated_nodes_created() {
        let g = toy();
        let before: usize = (0..g.num_nodes()).filter(|&v| g.degree(v) == 0).count();
        let s = split_links(&g, 60, 4, 11);
        let after: usize = (0..s.train.num_nodes())
            .filter(|&v| s.train.degree(v) == 0)
            .count();
        assert_eq!(before, after);
    }

    #[test]
    fn hub_node_negative_sampling_terminates() {
        // Node 0 adjacent to all but one node: rejection sampling alone
        // would need ~n tries per accept; the pool fallback must fill
        // the remainder with the single non-neighbour.
        let n = 40;
        let mut b = GraphBuilder::new(n);
        for v in 1..(n as u32 - 1) {
            b.add_edge(0, v);
        }
        // keep the last node connected (elsewhere) so it's not isolated
        b.add_edge(n as u32 - 1, 1);
        let g = b.build();
        let mut rng = Rng::new(5);
        let negs = sample_negatives(&g, 0, 16, &mut rng);
        assert_eq!(negs.len(), 16);
        assert!(negs.iter().all(|&v| v == n as u32 - 1));
    }

    #[test]
    #[should_panic(expected = "no negative candidates exist")]
    fn fully_connected_node_errors_cleanly() {
        let mut b = GraphBuilder::new(4);
        for v in 1..4u32 {
            b.add_edge(0, v);
        }
        let g = b.build();
        let mut rng = Rng::new(6);
        sample_negatives(&g, 0, 2, &mut rng);
    }

    #[test]
    fn prop_train_plus_held_equals_original() {
        crate::util::prop::check(5, 13, |rng: &mut Rng| {
            let g = toy();
            let s = split_links(&g, rng.range(5, 30), 2, rng.next_u64());
            let total = s.train.num_edges() + s.val.len() + s.test.len();
            crate::prop_assert!(total == g.num_edges());
            Ok(())
        });
    }
}
