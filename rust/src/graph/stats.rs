//! Graph statistics: degrees, homophily ratio, feature distributions.
//!
//! The homophily ratio `h` (fraction of same-class edges, paper §3
//! Preliminaries / Zhu et al. [45]) and per-partition feature/class
//! distributions `C_i` are the quantities the paper's theory (Lem 1,
//! Thm 2, Cor 3) speaks about; the partition-stats module builds its
//! disparity measures on top of these.

use super::Graph;

/// Summary statistics printed by Table 1 and used in DESIGN.md checks.
#[derive(Debug, Clone)]
pub struct GraphStats {
    pub num_nodes: usize,
    pub num_edges: usize,
    pub feat_dim: usize,
    pub num_classes: usize,
    pub num_relations: usize,
    pub avg_degree: f64,
    pub max_degree: usize,
    pub homophily: f64,
    pub isolated: usize,
}

pub fn graph_stats(g: &Graph) -> GraphStats {
    let n = g.num_nodes();
    let mut max_degree = 0;
    let mut isolated = 0;
    for v in 0..n {
        let d = g.degree(v);
        max_degree = max_degree.max(d);
        if d == 0 {
            isolated += 1;
        }
    }
    GraphStats {
        num_nodes: n,
        num_edges: g.num_edges(),
        feat_dim: g.feat_dim,
        num_classes: g.num_classes,
        num_relations: g.num_relations,
        avg_degree: if n == 0 { 0.0 } else { g.num_adj() as f64 / n as f64 },
        max_degree,
        homophily: homophily_ratio(g),
        isolated,
    }
}

/// Fraction of edges linking same-class nodes: h = |{(u,v): y_u = y_v}| / |E|.
pub fn homophily_ratio(g: &Graph) -> f64 {
    let mut same = 0usize;
    let mut total = 0usize;
    for (u, v) in g.edges() {
        total += 1;
        if g.labels[u as usize] == g.labels[v as usize] {
            same += 1;
        }
    }
    if total == 0 {
        0.0
    } else {
        same as f64 / total as f64
    }
}

/// Class histogram over an arbitrary node set, normalised to a
/// distribution — the `C_i` of the paper's theory section.
pub fn class_distribution(g: &Graph, nodes: &[u32]) -> Vec<f64> {
    let mut hist = vec![0.0; g.num_classes.max(1)];
    for &v in nodes {
        hist[g.labels[v as usize] as usize] += 1.0;
    }
    let total: f64 = hist.iter().sum();
    if total > 0.0 {
        for h in &mut hist {
            *h /= total;
        }
    }
    hist
}

/// Mean feature vector over a node set (feature-space analogue of C_i).
pub fn mean_feature(g: &Graph, nodes: &[u32]) -> Vec<f64> {
    let mut mu = vec![0.0f64; g.feat_dim];
    if nodes.is_empty() {
        return mu;
    }
    for &v in nodes {
        for (m, &x) in mu.iter_mut().zip(g.feature(v as usize)) {
            *m += x as f64;
        }
    }
    for m in &mut mu {
        *m /= nodes.len() as f64;
    }
    mu
}

/// L2 distance between two distributions / mean vectors: the paper's
/// disparity measure ||C_i - C_j||.
pub fn l2_distance(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y) * (x - y))
        .sum::<f64>()
        .sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    fn labeled_path() -> Graph {
        // 0-1-2-3 with labels [0,0,1,1]: edges (0,1) same, (1,2) diff,
        // (2,3) same -> h = 2/3.
        let mut b = GraphBuilder::new(4);
        b.add_edge(0, 1);
        b.add_edge(1, 2);
        b.add_edge(2, 3);
        let mut g = b.build();
        g.labels = vec![0, 0, 1, 1].into();
        g.num_classes = 2;
        g
    }

    #[test]
    fn homophily_counts_same_class_edges() {
        let g = labeled_path();
        assert!((homophily_ratio(&g) - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn stats_basics() {
        let g = labeled_path();
        let s = graph_stats(&g);
        assert_eq!(s.num_nodes, 4);
        assert_eq!(s.num_edges, 3);
        assert_eq!(s.max_degree, 2);
        assert_eq!(s.isolated, 0);
        assert!((s.avg_degree - 1.5).abs() < 1e-12);
    }

    #[test]
    fn class_distribution_normalises() {
        let g = labeled_path();
        let c = class_distribution(&g, &[0, 1, 2]);
        assert!((c[0] - 2.0 / 3.0).abs() < 1e-12);
        assert!((c[1] - 1.0 / 3.0).abs() < 1e-12);
        assert_eq!(class_distribution(&g, &[]), vec![0.0, 0.0]);
    }

    #[test]
    fn mean_feature_averages() {
        let mut g = labeled_path();
        g.feat_dim = 2;
        g.features = vec![1.0, 0.0, 3.0, 0.0, 0.0, 2.0, 0.0, 0.0].into();
        let mu = mean_feature(&g, &[0, 1]);
        assert_eq!(mu, vec![2.0, 0.0]);
    }

    #[test]
    fn l2_distance_basics() {
        assert_eq!(l2_distance(&[0.0, 0.0], &[3.0, 4.0]), 5.0);
        assert_eq!(l2_distance(&[1.0], &[1.0]), 0.0);
    }
}
