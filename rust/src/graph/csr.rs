//! CSR graph with node features, class labels and optional edge types.

use super::{FeatureStore, Slab};

/// Compact undirected graph in CSR form. Both directions of every
/// undirected edge are stored, so `deg(v)` is the true degree and the
/// undirected edge count is `num_adj() / 2`.
///
/// Every array lives behind a [`Slab`] (heap `Owned` or `Mapped` view
/// of an RTMAGRF2 cache file — see [`super::slab`]); reads deref to
/// plain slices either way, so only `io::load_mapped` ever cares.
#[derive(Clone, Debug, Default)]
pub struct Graph {
    /// CSR row offsets, length `num_nodes + 1`.
    pub offsets: Slab<u64>,
    /// Flattened neighbour lists (sorted within each row).
    pub neighbors: Slab<u32>,
    /// Optional per-adjacency-entry relation type (heterogeneous graphs).
    pub rel: Option<Slab<u8>>,
    /// `num_nodes x feat_dim` node features behind one of the three
    /// [`FeatureStore`] backends (owned / shared slab / mmap).
    pub features: FeatureStore,
    pub feat_dim: usize,
    /// Synthetic community / class label per node (ground truth used by
    /// the theory benches and the feature generator; never by training).
    pub labels: Slab<u16>,
    pub num_classes: usize,
    /// Number of distinct relation types (1 for homogeneous).
    pub num_relations: usize,
}

impl Graph {
    pub fn num_nodes(&self) -> usize {
        self.offsets.len().saturating_sub(1)
    }

    /// Directed adjacency entries (2x undirected edges).
    pub fn num_adj(&self) -> usize {
        self.neighbors.len()
    }

    /// Undirected edge count.
    pub fn num_edges(&self) -> usize {
        self.neighbors.len() / 2
    }

    #[inline]
    pub fn degree(&self, v: usize) -> usize {
        (self.offsets[v + 1] - self.offsets[v]) as usize
    }

    #[inline]
    pub fn neighbors_of(&self, v: usize) -> &[u32] {
        &self.neighbors[self.offsets[v] as usize..self.offsets[v + 1] as usize]
    }

    /// Relation types aligned with [`Self::neighbors_of`].
    pub fn rels_of(&self, v: usize) -> Option<&[u8]> {
        self.rel.as_ref().map(|r| {
            &r[self.offsets[v] as usize..self.offsets[v + 1] as usize]
        })
    }

    #[inline]
    pub fn feature(&self, v: usize) -> &[f32] {
        self.features.row(v, self.feat_dim)
    }

    pub fn has_edge(&self, u: usize, v: usize) -> bool {
        self.neighbors_of(u).binary_search(&(v as u32)).is_ok()
    }

    /// Iterate undirected edges as (u, v) with u <= v.
    pub fn edges(&self) -> impl Iterator<Item = (u32, u32)> + '_ {
        (0..self.num_nodes()).flat_map(move |u| {
            self.neighbors_of(u)
                .iter()
                .filter(move |&&v| u as u32 <= v)
                .map(move |&v| (u as u32, v))
        })
    }
}

/// Edge-list accumulator producing a deduplicated, sorted CSR.
#[derive(Default)]
pub struct GraphBuilder {
    num_nodes: usize,
    edges: Vec<(u32, u32, u8)>,
    hetero: bool,
}

impl GraphBuilder {
    pub fn new(num_nodes: usize) -> Self {
        GraphBuilder { num_nodes, edges: Vec::new(), hetero: false }
    }

    /// Add an undirected edge (self-loops are dropped: the samplers add
    /// normalized self-connections themselves).
    pub fn add_edge(&mut self, u: u32, v: u32) {
        self.add_rel_edge(u, v, 0);
    }

    /// Add a typed undirected edge.
    pub fn add_rel_edge(&mut self, u: u32, v: u32, rel: u8) {
        debug_assert!((u as usize) < self.num_nodes);
        debug_assert!((v as usize) < self.num_nodes);
        if u == v {
            return;
        }
        if rel > 0 {
            self.hetero = true;
        }
        self.edges.push((u, v, rel));
        self.edges.push((v, u, rel));
    }

    pub fn num_pending(&self) -> usize {
        self.edges.len() / 2
    }

    /// Build the CSR (dedup on (src, dst): first relation wins).
    pub fn build(mut self) -> Graph {
        self.edges.sort_unstable();
        self.edges.dedup_by_key(|e| (e.0, e.1));
        let n = self.num_nodes;
        let mut offsets = vec![0u64; n + 1];
        for &(u, _, _) in &self.edges {
            offsets[u as usize + 1] += 1;
        }
        for i in 0..n {
            offsets[i + 1] += offsets[i];
        }
        let neighbors: Vec<u32> = self.edges.iter().map(|e| e.1).collect();
        let rel: Option<Vec<u8>> = if self.hetero {
            Some(self.edges.iter().map(|e| e.2).collect())
        } else {
            None
        };
        let num_relations = rel
            .as_ref()
            .map(|r| r.iter().copied().max().unwrap_or(0) as usize + 1)
            .unwrap_or(1);
        Graph {
            offsets: offsets.into(),
            neighbors: neighbors.into(),
            rel: rel.map(Into::into),
            features: FeatureStore::default(),
            feat_dim: 0,
            labels: vec![0; n].into(),
            num_classes: 1,
            num_relations,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn triangle() -> Graph {
        let mut b = GraphBuilder::new(4);
        b.add_edge(0, 1);
        b.add_edge(1, 2);
        b.add_edge(2, 0);
        b.build()
    }

    #[test]
    fn builds_symmetric_csr() {
        let g = triangle();
        assert_eq!(g.num_nodes(), 4);
        assert_eq!(g.num_edges(), 3);
        assert_eq!(g.neighbors_of(0), &[1, 2]);
        assert_eq!(g.neighbors_of(1), &[0, 2]);
        assert_eq!(g.neighbors_of(3), &[] as &[u32]);
        assert_eq!(g.degree(2), 2);
    }

    #[test]
    fn dedup_and_self_loops() {
        let mut b = GraphBuilder::new(3);
        b.add_edge(0, 1);
        b.add_edge(1, 0); // duplicate
        b.add_edge(2, 2); // self loop dropped
        let g = b.build();
        assert_eq!(g.num_edges(), 1);
        assert_eq!(g.degree(2), 0);
    }

    #[test]
    fn has_edge_and_edges_iter() {
        let g = triangle();
        assert!(g.has_edge(0, 1));
        assert!(!g.has_edge(0, 3));
        let es: Vec<_> = g.edges().collect();
        assert_eq!(es, vec![(0, 1), (0, 2), (1, 2)]);
    }

    #[test]
    fn hetero_relations_tracked() {
        let mut b = GraphBuilder::new(3);
        b.add_rel_edge(0, 1, 0);
        b.add_rel_edge(1, 2, 3);
        let g = b.build();
        assert_eq!(g.num_relations, 4);
        assert_eq!(g.rels_of(1).unwrap(), &[0, 3]);
    }

    #[test]
    fn prop_csr_well_formed_on_random_graphs() {
        use crate::util::rng::Rng;
        crate::util::prop::check(30, 41, |rng: &mut Rng| {
            let n = rng.range(1, 60);
            let m = rng.range(0, 200);
            let mut b = GraphBuilder::new(n);
            for _ in 0..m {
                b.add_edge(rng.below(n) as u32, rng.below(n) as u32);
            }
            let g = b.build();
            crate::prop_assert!(g.offsets.len() == n + 1);
            crate::prop_assert!(
                *g.offsets.last().unwrap() as usize == g.neighbors.len()
            );
            // symmetry + sorted rows + no self loops
            for u in 0..n {
                let row = g.neighbors_of(u);
                crop_sorted(row)?;
                for &v in row {
                    crate::prop_assert!(v as usize != u, "self loop at {u}");
                    crate::prop_assert!(
                        g.has_edge(v as usize, u),
                        "asymmetric edge {u}->{v}"
                    );
                }
            }
            Ok(())
        });

        fn crop_sorted(row: &[u32]) -> Result<(), String> {
            if row.windows(2).all(|w| w[0] < w[1]) {
                Ok(())
            } else {
                Err(format!("row not strictly sorted: {row:?}"))
            }
        }
    }
}
