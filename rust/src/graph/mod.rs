//! Graph substrate: CSR storage, builders, statistics, subgraph
//! induction, link-prediction splits and binary IO.
//!
//! All training-time graph access in the coordinator goes through
//! [`Graph`] (a compact CSR with node features and synthetic class
//! labels). Node-induced subgraphs ([`subgraph::Subgraph`]) are what
//! each TMA trainer receives — local IDs plus the mapping back to
//! global IDs, matching the paper's restricted-local-access setting.
//! The coordinator materialises all of them at once through the fused
//! parallel path ([`induce::induce_all`]); [`Subgraph::induce`] is the
//! single-set reference implementation it is differentially tested
//! against.
//!
//! # Feature storage backends
//!
//! Node features live behind [`features::FeatureStore`], an enum over
//! three physical backends with bit-identical read semantics:
//!
//! - **`Owned`** (`Vec<f32>`) — private row-major buffer. Hand-built
//!   test graphs land here (`g.features = vec.into()`), and it is the
//!   reference backend the differential suite compares against.
//!   Subgraph views of an `Owned` parent gather (copy) rows, which is
//!   the pre-FeatureStore behaviour.
//! - **`Shared`** (`Arc<[f32]>` slab + `u32` row index) — what the
//!   generators ([`crate::gen`]) and [`io::load`] produce for full
//!   graphs (identity index). [`induce_all`] turns a `Shared` parent
//!   into `k` index-only views over the *same* slab: prep copies zero
//!   feature floats, and every trainer thread borrows the slab through
//!   the `Arc`. This is the coordinator's default at run time.
//! - **`Mapped`** (mmap of an RTMAGRF2 cache file) — produced by
//!   [`io::load_mapped`] when the operator opts in (`RTMA_MMAP=1`, see
//!   [`crate::gen::presets`]). Feature rows are faulted in from the
//!   page cache on first touch, so feature slabs larger than RAM
//!   still train; induction composes views exactly like `Shared`.
//!
//! The coordinator picks the backend implicitly: whatever the dataset
//! loader produced flows through `split_links` (slab-sharing clone)
//! and `induce_all` (slab-sharing views) unchanged. Failure drills
//! ([`induce::induce_all_except`]) give skipped partitions an empty
//! `Owned` placeholder — lost data is never materialised in any
//! backend.
//!
//! # CSR storage backends
//!
//! The CSR-side arrays (offsets / neighbors / rel / labels) get the
//! same treatment through [`slab::Slab`]: `Owned` heap vectors for
//! everything built in memory, or `Mapped` windows of one shared
//! [`slab::MappedFile`] when a cache is opened with
//! [`io::load_mapped`]. A fully-mapped graph touches the heap only
//! for what training actually faults in, so billion-edge presets can
//! be generated once, cached, and trained on machines where even the
//! CSR exceeds RAM.

pub mod csr;
pub mod features;
pub mod induce;
pub mod io;
pub mod slab;
pub mod split;
pub mod stats;
pub mod subgraph;

pub use csr::{Graph, GraphBuilder};
pub use features::FeatureStore;
pub use induce::{induce_all, induce_all_except};
pub use slab::{MappedFile, Slab};
pub use split::{LinkSplit, split_links};
pub use subgraph::Subgraph;
