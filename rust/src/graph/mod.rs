//! Graph substrate: CSR storage, builders, statistics, subgraph
//! induction, link-prediction splits and binary IO.
//!
//! All training-time graph access in the coordinator goes through
//! [`Graph`] (a compact CSR with node features and synthetic class
//! labels). Node-induced subgraphs ([`subgraph::Subgraph`]) are what
//! each TMA trainer receives — local IDs plus the mapping back to
//! global IDs, matching the paper's restricted-local-access setting.
//! The coordinator materialises all of them at once through the fused
//! parallel path ([`induce::induce_all`]); [`Subgraph::induce`] is the
//! single-set reference implementation it is differentially tested
//! against.

pub mod csr;
pub mod induce;
pub mod io;
pub mod split;
pub mod stats;
pub mod subgraph;

pub use csr::{Graph, GraphBuilder};
pub use induce::{induce_all, induce_all_except};
pub use split::{LinkSplit, split_links};
pub use subgraph::Subgraph;
