//! Graph substrate: CSR storage, builders, statistics, subgraph
//! induction, link-prediction splits and binary IO.
//!
//! All training-time graph access in the coordinator goes through
//! [`Graph`] (a compact CSR with node features and synthetic class
//! labels). Node-induced subgraphs ([`subgraph::Subgraph`]) are what
//! each TMA trainer receives — local IDs plus the mapping back to
//! global IDs, matching the paper's restricted-local-access setting.

pub mod csr;
pub mod io;
pub mod split;
pub mod stats;
pub mod subgraph;

pub use csr::{Graph, GraphBuilder};
pub use split::{LinkSplit, split_links};
pub use subgraph::Subgraph;
