//! Typed array slabs: heap-owned or served from a mapped cache file.
//!
//! [`Slab<T>`] is the storage behind every CSR-side array of
//! [`Graph`](super::Graph) — offsets, neighbors, relation types and
//! labels. It mirrors the shape [`FeatureStore`](super::FeatureStore)
//! established for the feature matrix: an `Owned(Vec<T>)` backend for
//! everything built in memory (generators, builders, induction,
//! [`io::load`](super::io::load)), and a `Mapped` backend that reads
//! the corresponding 8-aligned RTMAGRF2 section straight out of one
//! shared [`MappedFile`] ([`io::load_mapped`](super::io::load_mapped)).
//! With both in place, a cached graph whose *CSR* exceeds RAM — not
//! just its feature slab — trains from the page cache.
//!
//! `Slab<T>` derefs to `&[T]`, so all read access (indexing, slicing,
//! iteration, `binary_search`, equality) is exactly slice access; the
//! backend only matters at construction time. Mutation goes through
//! building a `Vec<T>` and converting with `.into()` — slabs are
//! immutable once built, which is what lets the `Mapped` backend exist
//! at all.

use std::sync::Arc;

/// Element types a mapped slab may expose: plain-old-data with no
/// invalid bit patterns and no padding, stored little-endian in the
/// cache file. Sealed by construction — implemented exactly for the
/// section element types of the RTMAGRF2 layout.
pub trait SlabElem:
    Copy + Send + Sync + std::fmt::Debug + PartialEq + 'static
{
}

impl SlabElem for u8 {}
impl SlabElem for u16 {}
impl SlabElem for u32 {}
impl SlabElem for u64 {}
impl SlabElem for f32 {}

/// A whole cache file mapped read-only into the address space. All
/// section views — the CSR `Slab`s and the feature store's
/// [`Slab<f32>`] ([`FeatureStore::Mapped`](super::FeatureStore)) —
/// share one `Arc` of this, so a fully-mapped graph costs a single
/// `mmap` and unmaps when the last view drops.
pub struct MappedFile {
    base: *mut u8,
    len: usize,
}

// SAFETY: the mapping is PROT_READ/MAP_PRIVATE and never mutated after
// construction, so concurrent reads from any thread are sound.
unsafe impl Send for MappedFile {}
// SAFETY: same argument as Send — the region is immutable for the
// mapping's whole lifetime, so shared references race nothing.
unsafe impl Sync for MappedFile {}

impl std::fmt::Debug for MappedFile {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "MappedFile({} bytes)", self.len)
    }
}

impl MappedFile {
    /// An empty mapping (no file behind it). Zero-length sections view
    /// this instead of calling `mmap`, which rejects length 0.
    pub fn empty() -> MappedFile {
        MappedFile { base: std::ptr::null_mut(), len: 0 }
    }

    /// Map `file` whole, read-only. Mapped sections are read verbatim,
    /// so the (little-endian) layout requires a little-endian host —
    /// big-endian hosts must use the heap loader instead.
    #[cfg(unix)]
    pub fn map(file: &std::fs::File) -> anyhow::Result<MappedFile> {
        use std::os::unix::io::AsRawFd;

        if cfg!(target_endian = "big") {
            anyhow::bail!(
                "mapped graph sections require a little-endian host \
                 (file layout is LE)"
            );
        }
        let len = file.metadata()?.len() as usize;
        if len == 0 {
            return Ok(MappedFile::empty());
        }

        const PROT_READ: i32 = 0x1;
        const MAP_PRIVATE: i32 = 0x2;
        // SAFETY: length is the exact file size, fd is a valid open
        // file, and the returned region is only ever read.
        let base = unsafe {
            mmap(
                std::ptr::null_mut(),
                len,
                PROT_READ,
                MAP_PRIVATE,
                file.as_raw_fd(),
                0,
            )
        };
        if base as isize == -1 {
            anyhow::bail!(
                "mmap({len} bytes) failed: {}",
                std::io::Error::last_os_error()
            );
        }
        Ok(MappedFile { base: base.cast(), len })
    }

    /// Non-unix hosts fall back to heap loading at the `io` layer.
    #[cfg(not(unix))]
    pub fn map(_file: &std::fs::File) -> anyhow::Result<MappedFile> {
        anyhow::bail!("mapped graph sections are only supported on unix")
    }

    /// Mapped length in bytes.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Validate that `[byte_off, byte_off + count * size_of::<T>())`
    /// is an in-bounds, `T`-aligned window of the mapping. `count == 0`
    /// is always valid (the view is the empty slice).
    pub(crate) fn check_window<T: SlabElem>(
        &self,
        byte_off: usize,
        count: usize,
    ) -> anyhow::Result<()> {
        if count == 0 {
            return Ok(());
        }
        anyhow::ensure!(
            byte_off % std::mem::align_of::<T>() == 0,
            "section at byte {byte_off} is not {}-byte aligned",
            std::mem::align_of::<T>()
        );
        let bytes = count
            .checked_mul(std::mem::size_of::<T>())
            .and_then(|b| b.checked_add(byte_off));
        anyhow::ensure!(
            bytes.is_some_and(|end| end <= self.len),
            "section [{byte_off}, +{count}*{}) exceeds the {}-byte map",
            std::mem::size_of::<T>(),
            self.len
        );
        Ok(())
    }

    /// The window as a typed slice. Callers must have validated it via
    /// [`Self::check_window`] at construction time.
    pub(crate) fn slice<T: SlabElem>(
        &self,
        byte_off: usize,
        count: usize,
    ) -> &[T] {
        if count == 0 {
            return &[];
        }
        debug_assert!(self.check_window::<T>(byte_off, count).is_ok());
        // SAFETY: construction validated alignment and bounds, T is
        // plain-old-data, and the mapping is never written and lives
        // as long as `self`.
        unsafe {
            std::slice::from_raw_parts(
                self.base.add(byte_off).cast::<T>(),
                count,
            )
        }
    }
}

impl Drop for MappedFile {
    fn drop(&mut self) {
        #[cfg(unix)]
        if self.len > 0 {
            // SAFETY: base/len came from a successful mmap.
            unsafe {
                munmap(self.base.cast(), self.len);
            }
        }
    }
}

#[cfg(unix)]
extern "C" {
    fn mmap(
        addr: *mut std::ffi::c_void,
        length: usize,
        prot: i32,
        flags: i32,
        fd: i32,
        offset: i64,
    ) -> *mut std::ffi::c_void;
    fn munmap(addr: *mut std::ffi::c_void, length: usize) -> i32;
}

/// One immutable `[T]` array behind a heap or mapped backend. See the
/// module docs; reads always go through [`std::ops::Deref`] to `&[T]`.
#[derive(Clone)]
pub enum Slab<T: SlabElem> {
    /// Heap-resident array (the construction-time backend).
    Owned(Vec<T>),
    /// A validated window of a shared [`MappedFile`].
    Mapped { file: Arc<MappedFile>, byte_off: usize, count: usize },
}

impl<T: SlabElem> Slab<T> {
    /// View `count` elements of `file` starting at `byte_off`,
    /// validating alignment and bounds up front so every later read is
    /// a plain slice access.
    pub fn mapped(
        file: Arc<MappedFile>,
        byte_off: usize,
        count: usize,
    ) -> anyhow::Result<Slab<T>> {
        file.check_window::<T>(byte_off, count)?;
        Ok(Slab::Mapped { file, byte_off, count })
    }

    /// Short backend tag for logs and test diagnostics.
    pub fn backend(&self) -> &'static str {
        match self {
            Slab::Owned(_) => "owned",
            Slab::Mapped { .. } => "mapped",
        }
    }

    /// The array as a slice (what [`std::ops::Deref`] returns).
    pub fn as_slice(&self) -> &[T] {
        match self {
            Slab::Owned(d) => d,
            Slab::Mapped { file, byte_off, count } => {
                file.slice(*byte_off, *count)
            }
        }
    }

    /// Bytes of process heap this slab privately holds: the buffer for
    /// `Owned`, zero for `Mapped` (those bytes belong to the page
    /// cache).
    pub fn heap_bytes(&self) -> usize {
        match self {
            Slab::Owned(d) => d.len() * std::mem::size_of::<T>(),
            Slab::Mapped { .. } => 0,
        }
    }
}

impl<T: SlabElem> std::ops::Deref for Slab<T> {
    type Target = [T];

    fn deref(&self) -> &[T] {
        self.as_slice()
    }
}

/// `for x in &slab` iterates the logical array (deref coercion does
/// not reach `for` loops, so this is spelled out).
impl<'a, T: SlabElem> IntoIterator for &'a Slab<T> {
    type Item = &'a T;
    type IntoIter = std::slice::Iter<'a, T>;

    fn into_iter(self) -> Self::IntoIter {
        self.as_slice().iter()
    }
}

impl<T: SlabElem> Default for Slab<T> {
    fn default() -> Slab<T> {
        Slab::Owned(Vec::new())
    }
}

impl<T: SlabElem> From<Vec<T>> for Slab<T> {
    fn from(data: Vec<T>) -> Slab<T> {
        Slab::Owned(data)
    }
}

impl<T: SlabElem> std::fmt::Debug for Slab<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Slab::{}({} elems)", self.backend(), self.len())
    }
}

/// Slabs compare as their logical arrays, whatever the backends.
impl<T: SlabElem> PartialEq for Slab<T> {
    fn eq(&self, other: &Slab<T>) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl<T: SlabElem> Eq for Slab<T> where T: Eq {}

impl<T: SlabElem> PartialEq<Vec<T>> for Slab<T> {
    fn eq(&self, other: &Vec<T>) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl<T: SlabElem> PartialEq<&[T]> for Slab<T> {
    fn eq(&self, other: &&[T]) -> bool {
        self.as_slice() == *other
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn owned_reads_like_a_slice() {
        let s: Slab<u32> = vec![5, 6, 7].into();
        assert_eq!(s.len(), 3);
        assert_eq!(s[1], 6);
        assert_eq!(&s[1..], &[6, 7]);
        assert_eq!(s.backend(), "owned");
        assert_eq!(s.heap_bytes(), 12);
        assert_eq!(s, vec![5, 6, 7]);
        assert!(Slab::<u16>::default().is_empty());
    }

    #[cfg(unix)]
    #[test]
    fn mapped_window_reads_and_validates() {
        let path = std::env::temp_dir()
            .join(format!("rtma_slabfile_{}.bin", std::process::id()));
        let mut bytes = vec![0u8; 8];
        for v in [3u32, 9, 27] {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        std::fs::write(&path, &bytes).unwrap();
        let file = std::fs::File::open(&path).unwrap();
        let map = Arc::new(MappedFile::map(&file).unwrap());
        std::fs::remove_file(&path).ok();

        let s = Slab::<u32>::mapped(Arc::clone(&map), 8, 3).unwrap();
        assert_eq!(s.backend(), "mapped");
        assert_eq!(s.heap_bytes(), 0);
        assert_eq!(s, vec![3, 9, 27]);
        assert_eq!(s.clone(), s); // clones share the Arc

        // misaligned / out-of-bounds windows are rejected up front
        assert!(Slab::<u32>::mapped(Arc::clone(&map), 6, 1).is_err());
        assert!(Slab::<u32>::mapped(Arc::clone(&map), 8, 4).is_err());
        assert!(Slab::<u64>::mapped(Arc::clone(&map), 4, 1).is_err());
        // zero-length windows are always fine
        let empty = Slab::<u64>::mapped(map, 1, 0).unwrap();
        assert!(empty.is_empty());
    }

    #[test]
    fn equality_is_logical_not_physical() {
        let a: Slab<u16> = vec![1, 2].into();
        let b: Slab<u16> = vec![1, 2].into();
        let c: Slab<u16> = vec![1, 3].into();
        assert_eq!(a, b);
        assert_ne!(a, c);
    }
}
