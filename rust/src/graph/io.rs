//! Binary graph serialization (little-endian, versioned) + mmap open.
//!
//! Used to cache generated datasets between bench runs so the
//! generators run once per configuration. Current format (RTMAGRF2):
//!
//! ```text
//! magic "RTMAGRF2" | n: u64 | adj: u64 | feat_dim: u64 | classes: u64
//! relations: u64 | has_rel: u8
//! -- every section below starts 8-byte aligned (zero padding) --
//! offsets [n+1] u64 | neighbors [adj] u32 | rel [adj] u8 (if has_rel)
//! labels [n] u16 | features [n*feat_dim] f32
//! ```
//!
//! The legacy RTMAGRF1 layout (same sections, unaligned) is still
//! readable by [`load`]; [`save`] always writes RTMAGRF2. The
//! alignment exists for [`load_mapped`]: every section of a v2 file —
//! offsets, neighbors, rel, labels ([`Slab::Mapped`]) *and* features
//! ([`FeatureStore::Mapped`]) — is handed out as a typed slice
//! straight out of one shared `mmap` without a heap copy, so cached
//! graphs whose CSR or feature matrix exceeds RAM still train from
//! the page cache.
//!
//! All array sections are bulk little-endian (one `read_exact` /
//! `write_all` per section on LE hosts — the same treatment the comm
//! wire format got), and every header is validated against the actual
//! file length with overflow-checked arithmetic *before* any
//! allocation, so truncated or corrupted caches fail with an error
//! instead of an OOM or an out-of-bounds map.

use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, ensure, Context, Result};

use super::{FeatureStore, Graph, MappedFile, Slab};

const MAGIC_V1: &[u8; 8] = b"RTMAGRF1";
const MAGIC_V2: &[u8; 8] = b"RTMAGRF2";
const HEADER_BYTES: u64 = 8 + 5 * 8 + 1;

/// Bulk LE array IO: on little-endian hosts (every deployment target)
/// one `read_exact`/`write_all` over the element buffer's bytes; a
/// per-element `from_le`/`to_le` loop elsewhere.
///
/// SAFETY of the byte views: the element types are plain-old-data
/// (no invalid bit patterns, no padding), the slices are fully
/// initialized, and `u8` has the weakest alignment.
macro_rules! bulk_le {
    ($read:ident, $write:ident, $t:ty, $size:expr) => {
        fn $read<R: Read>(r: &mut R, out: &mut [$t]) -> std::io::Result<()> {
            if cfg!(target_endian = "little") {
                // SAFETY: `$t` is plain-old-data with no padding,
                // `out` is fully initialized, and `u8` has the
                // weakest alignment — the mutable byte view covers
                // exactly the element buffer.
                let bytes = unsafe {
                    std::slice::from_raw_parts_mut(
                        out.as_mut_ptr().cast::<u8>(),
                        out.len() * $size,
                    )
                };
                r.read_exact(bytes)
            } else {
                let mut b = [0u8; $size];
                for x in out.iter_mut() {
                    r.read_exact(&mut b)?;
                    *x = <$t>::from_le_bytes(b);
                }
                Ok(())
            }
        }

        fn $write<W: Write>(w: &mut W, xs: &[$t]) -> std::io::Result<()> {
            if cfg!(target_endian = "little") {
                // SAFETY: same byte-view argument as the read side,
                // shared (read-only) this time.
                let bytes = unsafe {
                    std::slice::from_raw_parts(
                        xs.as_ptr().cast::<u8>(),
                        xs.len() * $size,
                    )
                };
                w.write_all(bytes)
            } else {
                for x in xs {
                    w.write_all(&x.to_le_bytes())?;
                }
                Ok(())
            }
        }
    };
}

bulk_le!(read_u64s, write_u64s, u64, 8);
bulk_le!(read_u32s, write_u32s, u32, 4);
bulk_le!(read_u16s, write_u16s, u16, 2);
bulk_le!(read_f32s, write_f32s, f32, 4);

#[derive(Clone, Copy, Debug)]
struct Header {
    v2: bool,
    n: u64,
    adj: u64,
    feat_dim: u64,
    num_classes: u64,
    num_relations: u64,
    has_rel: bool,
}

/// Absolute byte offsets of each section plus the exact file size the
/// header implies. Everything is overflow-checked: a corrupt length
/// field yields an error here, before any allocation or mapping.
#[derive(Clone, Copy, Debug)]
struct Layout {
    off_offsets: u64,
    off_neighbors: u64,
    off_rel: u64,
    off_labels: u64,
    off_features: u64,
    total: u64,
}

fn align8(x: u64) -> Option<u64> {
    x.checked_add(7).map(|y| y & !7)
}

impl Layout {
    fn of(h: &Header) -> Result<Layout> {
        let err = || anyhow::anyhow!("header length fields overflow");
        let align = |x: u64| -> Result<u64> {
            if h.v2 {
                align8(x).ok_or_else(err)
            } else {
                Ok(x)
            }
        };
        let sec = |pos: u64, count: u64, elem: u64| -> Result<u64> {
            pos.checked_add(count.checked_mul(elem).ok_or_else(err)?)
                .ok_or_else(err)
        };

        let off_offsets = align(HEADER_BYTES)?;
        let rows = h.n.checked_add(1).ok_or_else(err)?;
        let off_neighbors = align(sec(off_offsets, rows, 8)?)?;
        let off_rel = align(sec(off_neighbors, h.adj, 4)?)?;
        let rel_end = if h.has_rel {
            sec(off_rel, h.adj, 1)?
        } else {
            off_rel
        };
        let off_labels = align(rel_end)?;
        let off_features = align(sec(off_labels, h.n, 2)?)?;
        let floats = h.n.checked_mul(h.feat_dim).ok_or_else(err)?;
        let total = sec(off_features, floats, 4)?;
        Ok(Layout {
            off_offsets,
            off_neighbors,
            off_rel,
            off_labels,
            off_features,
            total,
        })
    }
}

pub fn save(g: &Graph, path: &Path) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    // Write to a sibling temp file and rename into place: concurrent
    // readers always see a complete file, and an existing cache inode
    // that another process may have mmap'd is never truncated
    // (shrinking a live mapping's file turns its next page touch into
    // SIGBUS — rename leaves the old inode intact until unmapped).
    // pid + in-process counter: concurrent savers (test threads, racing
    // bench binaries) each get a private temp file.
    static SEQ: std::sync::atomic::AtomicUsize =
        std::sync::atomic::AtomicUsize::new(0);
    let seq = SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    let tmp = path
        .with_extension(format!("tmp{}.{seq}", std::process::id()));
    if let Err(e) = write_graph(g, &tmp) {
        std::fs::remove_file(&tmp).ok();
        return Err(e);
    }
    std::fs::rename(&tmp, path)
        .with_context(|| format!("rename into {}", path.display()))?;
    Ok(())
}

fn write_graph(g: &Graph, path: &Path) -> Result<()> {
    let n = g.num_nodes();
    ensure!(
        g.feat_dim == 0 || g.features.num_rows(g.feat_dim) == n,
        "feature store has {} rows, graph has {n} nodes",
        g.features.num_rows(g.feat_dim)
    );
    // An Owned buffer must be an exact n*d matrix: floor-division rows
    // would pass the check above yet make the file's feature section
    // contradict its own header (every later load rejects it).
    if let FeatureStore::Owned(d) = &g.features {
        ensure!(
            d.len() == n * g.feat_dim,
            "owned feature buffer has {} f32s, expected n*d = {}",
            d.len(),
            n * g.feat_dim
        );
    }
    ensure!(g.labels.len() == n, "labels/node count mismatch");
    if let Some(rel) = &g.rel {
        ensure!(
            rel.len() == g.neighbors.len(),
            "rel/adjacency length mismatch"
        );
    }
    let h = Header {
        v2: true,
        n: n as u64,
        adj: g.num_adj() as u64,
        feat_dim: g.feat_dim as u64,
        num_classes: g.num_classes as u64,
        num_relations: g.num_relations as u64,
        has_rel: g.rel.is_some(),
    };
    // One source of truth for the byte layout: the writer pads each
    // section up to the very offsets the reader will compute.
    let lay = Layout::of(&h)?;

    let mut w = BufWriter::new(std::fs::File::create(path)?);
    w.write_all(MAGIC_V2)?;
    write_u64s(
        &mut w,
        &[h.n, h.adj, h.feat_dim, h.num_classes, h.num_relations],
    )?;
    w.write_all(&[h.has_rel as u8])?;

    let mut pos = HEADER_BYTES;
    let pad_to = |w: &mut BufWriter<std::fs::File>,
                  pos: &mut u64,
                  target: u64|
     -> Result<()> {
        ensure!(
            target >= *pos,
            "writer ahead of layout: at {pos}, section starts at {target}"
        );
        w.write_all(&vec![0u8; (target - *pos) as usize])?;
        *pos = target;
        Ok(())
    };

    pad_to(&mut w, &mut pos, lay.off_offsets)?;
    write_u64s(&mut w, &g.offsets)?;
    pos += g.offsets.len() as u64 * 8;
    pad_to(&mut w, &mut pos, lay.off_neighbors)?;
    write_u32s(&mut w, &g.neighbors)?;
    pos += g.neighbors.len() as u64 * 4;
    if let Some(rel) = &g.rel {
        pad_to(&mut w, &mut pos, lay.off_rel)?;
        w.write_all(rel)?;
        pos += rel.len() as u64;
    }
    pad_to(&mut w, &mut pos, lay.off_labels)?;
    write_u16s(&mut w, &g.labels)?;
    pos += g.labels.len() as u64 * 2;
    pad_to(&mut w, &mut pos, lay.off_features)?;
    match g.features.contiguous(g.feat_dim) {
        Some(slab) => write_f32s(&mut w, slab)?,
        // Scattered view (e.g. saving a trainer subgraph): gather once.
        None => write_f32s(&mut w, &g.features.to_vec(g.feat_dim))?,
    }
    w.flush()?;
    Ok(())
}

/// Whether `path` carries the mappable (RTMAGRF2) magic. Cache policy
/// uses this to tell "regenerate to upgrade the layout" apart from
/// "mmap is unavailable in this environment" when a map attempt fails.
pub fn is_mappable_layout(path: &Path) -> bool {
    let mut magic = [0u8; 8];
    std::fs::File::open(path)
        .and_then(|mut f| f.read_exact(&mut magic))
        .map(|_| &magic == MAGIC_V2)
        .unwrap_or(false)
}

/// Read the magic + fixed header fields and validate the implied
/// layout against the real file length.
fn read_header(
    r: &mut impl Read,
    file_len: u64,
    path: &Path,
) -> Result<(Header, Layout)> {
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    let v2 = match &magic {
        m if m == MAGIC_V2 => true,
        m if m == MAGIC_V1 => false,
        _ => bail!("{}: bad magic", path.display()),
    };
    let mut fields = [0u64; 5];
    read_u64s(r, &mut fields)?;
    let mut flag = [0u8; 1];
    r.read_exact(&mut flag)?;
    if flag[0] > 1 {
        bail!("{}: bad has_rel flag {}", path.display(), flag[0]);
    }
    let h = Header {
        v2,
        n: fields[0],
        adj: fields[1],
        feat_dim: fields[2],
        num_classes: fields[3],
        num_relations: fields[4],
        has_rel: flag[0] == 1,
    };
    let lay = Layout::of(&h)
        .with_context(|| format!("{}: corrupt header", path.display()))?;
    ensure!(
        lay.total == file_len,
        "{}: truncated or corrupt (file is {file_len} bytes, header \
         implies {})",
        path.display(),
        lay.total
    );
    Ok((h, lay))
}

/// Skip `k` padding bytes of the reader.
fn skip(r: &mut impl Read, k: u64) -> Result<()> {
    let mut buf = [0u8; 8];
    let mut left = k;
    while left > 0 {
        let take = left.min(8) as usize;
        r.read_exact(&mut buf[..take])?;
        left -= take as u64;
    }
    Ok(())
}

/// Everything before the feature section, plus where features start.
fn load_prefix(
    r: &mut impl Read,
    h: &Header,
    lay: &Layout,
) -> Result<Graph> {
    let n = h.n as usize;
    let adj = h.adj as usize;

    skip(r, lay.off_offsets - HEADER_BYTES)?;
    let mut offsets = vec![0u64; n + 1];
    read_u64s(r, &mut offsets)?;

    let mut neighbors = vec![0u32; adj];
    read_u32s(r, &mut neighbors)?;

    let rel = if h.has_rel {
        skip(r, lay.off_rel - (lay.off_neighbors + h.adj * 4))?;
        let mut rel = vec![0u8; adj];
        r.read_exact(&mut rel)?;
        skip(r, lay.off_labels - (lay.off_rel + h.adj))?;
        Some(rel)
    } else {
        skip(r, lay.off_labels - (lay.off_neighbors + h.adj * 4))?;
        None
    };

    let mut labels = vec![0u16; n];
    read_u16s(r, &mut labels)?;
    skip(r, lay.off_features - (lay.off_labels + h.n * 2))?;

    Ok(Graph {
        offsets: offsets.into(),
        neighbors: neighbors.into(),
        rel: rel.map(Into::into),
        features: FeatureStore::default(), // caller fills
        feat_dim: h.feat_dim as usize,
        labels: labels.into(),
        num_classes: h.num_classes as usize,
        num_relations: h.num_relations as usize,
    })
}

/// Load a cached graph fully into the heap. Features come back as a
/// [`FeatureStore::Shared`] identity slab, so the coordinator's
/// subsequent `induce_all` is zero-copy.
pub fn load(path: &Path) -> Result<Graph> {
    let file = std::fs::File::open(path)
        .with_context(|| format!("open {}", path.display()))?;
    let file_len = file.metadata()?.len();
    let mut r = BufReader::new(file);
    let (h, lay) = read_header(&mut r, file_len, path)?;
    let mut g = load_prefix(&mut r, &h, &lay)?;
    let mut features = vec![0f32; (h.n * h.feat_dim) as usize];
    read_f32s(&mut r, &mut features)?;
    g.features = FeatureStore::shared_from_vec(features, g.feat_dim);
    Ok(g)
}

/// Load a cached graph with *every* array section left on disk: the
/// file is mapped once, and offsets / neighbors / rel / labels come
/// back as [`Slab::Mapped`] windows of that mapping while features
/// become a [`FeatureStore::Mapped`] over the same map — nothing but
/// the fixed header is copied to the heap, and pages fault in on
/// first touch. Requires the RTMAGRF2 layout — legacy v1 caches are
/// rejected (re-save to upgrade) because their sections are
/// unaligned.
pub fn load_mapped(path: &Path) -> Result<Graph> {
    let file = std::fs::File::open(path)
        .with_context(|| format!("open {}", path.display()))?;
    let file_len = file.metadata()?.len();
    let mut r = BufReader::new(&file);
    let (h, lay) = read_header(&mut r, file_len, path)?;
    drop(r);
    ensure!(
        h.v2,
        "{}: mmap requires the aligned RTMAGRF2 layout (legacy cache — \
         delete it to regenerate)",
        path.display()
    );
    let map = Arc::new(
        MappedFile::map(&file)
            .with_context(|| format!("mmap {}", path.display()))?,
    );
    let n = h.n as usize;
    let adj = h.adj as usize;
    // Layout::of validated every section against the real file length,
    // so these windows only fail on non-LE hosts.
    fn section<T: super::slab::SlabElem>(
        map: &Arc<MappedFile>,
        path: &Path,
        what: &str,
        off: u64,
        count: usize,
    ) -> Result<Slab<T>> {
        Slab::mapped(Arc::clone(map), off as usize, count)
            .with_context(|| format!("{}: map {what}", path.display()))
    }
    let offsets: Slab<u64> =
        section(&map, path, "offsets", lay.off_offsets, n + 1)?;
    let neighbors: Slab<u32> =
        section(&map, path, "neighbors", lay.off_neighbors, adj)?;
    let rel: Option<Slab<u8>> = if h.has_rel {
        Some(section(&map, path, "rel", lay.off_rel, adj)?)
    } else {
        None
    };
    let labels: Slab<u16> =
        section(&map, path, "labels", lay.off_labels, n)?;
    let floats = (h.n * h.feat_dim) as usize;
    let features = if floats == 0 {
        FeatureStore::default()
    } else {
        // The feature section rides the same shared mapping as the
        // CSR sections, behind the same generic Slab<f32> window.
        let slab: Slab<f32> =
            section(&map, path, "features", lay.off_features, floats)?;
        FeatureStore::Mapped { slab, index: None }
    };
    Ok(Graph {
        offsets,
        neighbors,
        rel,
        features,
        feat_dim: h.feat_dim as usize,
        labels,
        num_classes: h.num_classes as usize,
        num_relations: h.num_relations as usize,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;
    use crate::util::rng::Rng;

    fn sample(hetero: bool) -> Graph {
        let mut b = GraphBuilder::new(6);
        b.add_rel_edge(0, 1, 0);
        b.add_rel_edge(1, 2, if hetero { 2 } else { 0 });
        b.add_rel_edge(4, 5, if hetero { 1 } else { 0 });
        let mut g = b.build();
        g.feat_dim = 3;
        g.features =
            (0..18).map(|i| i as f32 * 0.5).collect::<Vec<f32>>().into();
        g.labels = vec![0, 1, 2, 0, 1, 2].into();
        g.num_classes = 3;
        g
    }

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir()
            .join(format!("rtma_io_{name}_{}.bin", std::process::id()))
    }

    #[test]
    fn roundtrip_homogeneous() {
        let g = sample(false);
        let path = tmp("homo");
        save(&g, &path).unwrap();
        let h = load(&path).unwrap();
        assert_eq!(g.offsets, h.offsets);
        assert_eq!(g.neighbors, h.neighbors);
        assert_eq!(g.rel, h.rel);
        assert!(g.features.rows_equal(&h.features, 3));
        assert_eq!(h.features.backend(), "shared");
        assert_eq!(g.labels, h.labels);
        assert_eq!(g.num_classes, h.num_classes);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn roundtrip_heterogeneous() {
        let g = sample(true);
        let path = tmp("het");
        save(&g, &path).unwrap();
        let h = load(&path).unwrap();
        assert!(h.rel.is_some());
        assert_eq!(g.rel, h.rel);
        assert_eq!(g.num_relations, h.num_relations);
        std::fs::remove_file(path).ok();
    }

    /// save -> load -> save must reproduce the file byte-for-byte, on
    /// both the homogeneous and the `rel` branch — the cache format is
    /// a fixed point of the round trip.
    #[test]
    fn save_load_save_byte_identity() {
        for (name, hetero) in [("ident_homo", false), ("ident_het", true)] {
            let g = sample(hetero);
            let p1 = tmp(name);
            save(&g, &p1).unwrap();
            let bytes1 = std::fs::read(&p1).unwrap();
            let reloaded = load(&p1).unwrap();
            let p2 = tmp(&format!("{name}_2"));
            save(&reloaded, &p2).unwrap();
            let bytes2 = std::fs::read(&p2).unwrap();
            assert_eq!(bytes1, bytes2, "{name}: round trip not identity");
            // And the fully-mapped view serves every section in place:
            // CSR arrays and features all read back identically from
            // `Mapped` backends, and re-saving the mapped graph still
            // reproduces the file byte-for-byte.
            if cfg!(unix) {
                let mapped = load_mapped(&p1).unwrap();
                assert_eq!(mapped.features.backend(), "mapped");
                assert!(mapped.features.rows_equal(&g.features, 3));
                for (what, backend) in [
                    ("offsets", mapped.offsets.backend()),
                    ("neighbors", mapped.neighbors.backend()),
                    ("labels", mapped.labels.backend()),
                ] {
                    assert_eq!(backend, "mapped", "{name}: {what}");
                }
                assert_eq!(mapped.offsets, g.offsets);
                assert_eq!(mapped.neighbors, g.neighbors);
                assert_eq!(mapped.rel, g.rel);
                assert_eq!(mapped.labels, g.labels);
                if let Some(rel) = &mapped.rel {
                    assert_eq!(rel.backend(), "mapped");
                }
                let p3 = tmp(&format!("{name}_3"));
                save(&mapped, &p3).unwrap();
                let bytes3 = std::fs::read(&p3).unwrap();
                assert_eq!(
                    bytes1, bytes3,
                    "{name}: mapped round trip not identity"
                );
                std::fs::remove_file(p3).ok();
            }
            std::fs::remove_file(p1).ok();
            std::fs::remove_file(p2).ok();
        }
    }

    #[test]
    fn legacy_v1_layout_still_loads() {
        // Hand-encode the v1 (unaligned) layout of sample(false).
        let g = sample(false);
        let mut b: Vec<u8> = Vec::new();
        b.extend_from_slice(MAGIC_V1);
        for v in [6u64, g.num_adj() as u64, 3, 3, 1] {
            b.extend_from_slice(&v.to_le_bytes());
        }
        b.push(0);
        for &o in &g.offsets {
            b.extend_from_slice(&o.to_le_bytes());
        }
        for &nb in &g.neighbors {
            b.extend_from_slice(&nb.to_le_bytes());
        }
        for &l in &g.labels {
            b.extend_from_slice(&l.to_le_bytes());
        }
        for f in g.features.to_vec(3) {
            b.extend_from_slice(&f.to_le_bytes());
        }
        let path = tmp("v1");
        std::fs::write(&path, &b).unwrap();
        let h = load(&path).unwrap();
        assert_eq!(h.offsets, g.offsets);
        assert_eq!(h.neighbors, g.neighbors);
        assert!(h.features.rows_equal(&g.features, 3));
        // ...but the unaligned layout cannot be mapped.
        assert!(load_mapped(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_bad_magic() {
        let path = tmp("bad");
        std::fs::write(&path, b"NOTAGRAPH").unwrap();
        assert!(load(&path).is_err());
        assert!(load_mapped(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    /// Truncating a valid file anywhere must produce a clean error
    /// from both open paths — never a panic, OOM or over-read.
    #[test]
    fn prop_truncated_files_rejected() {
        let g = sample(true);
        let path = tmp("trunc_src");
        save(&g, &path).unwrap();
        let full = std::fs::read(&path).unwrap();
        std::fs::remove_file(&path).ok();
        crate::util::prop::check(40, 57, |rng: &mut Rng| {
            let cut = rng.below(full.len()); // strictly shorter
            let p = tmp(&format!("trunc_{cut}"));
            std::fs::write(&p, &full[..cut]).unwrap();
            let heap = load(&p);
            let mapped = load_mapped(&p);
            std::fs::remove_file(&p).ok();
            crate::prop_assert!(heap.is_err(), "load accepted {cut} bytes");
            crate::prop_assert!(
                mapped.is_err(),
                "load_mapped accepted {cut} bytes"
            );
            Ok(())
        });
    }

    /// Corrupting header length fields with huge values (the overflow
    /// and OOM vectors) must error out during layout validation —
    /// before any allocation or mapping happens.
    #[test]
    fn prop_header_length_overflow_rejected() {
        let g = sample(true);
        let path = tmp("ovf_src");
        save(&g, &path).unwrap();
        let full = std::fs::read(&path).unwrap();
        std::fs::remove_file(&path).ok();
        crate::util::prop::check(30, 91, |rng: &mut Rng| {
            let mut bytes = full.clone();
            // One of n/adj/feat_dim at byte 8/16/24, ORed with huge
            // high bits (u64::MAX-ish down to "merely" 2^40).
            let field = rng.below(3);
            let huge: u64 = u64::MAX >> rng.below(24);
            let off = 8 + field * 8;
            let old = u64::from_le_bytes(
                bytes[off..off + 8].try_into().unwrap(),
            );
            bytes[off..off + 8]
                .copy_from_slice(&(old | huge).to_le_bytes());
            let p = tmp(&format!("ovf_{field}_{huge}"));
            std::fs::write(&p, &bytes).unwrap();
            let heap = load(&p);
            let mapped = load_mapped(&p);
            std::fs::remove_file(&p).ok();
            crate::prop_assert!(
                heap.is_err(),
                "load accepted field {field} |= {huge:#x}"
            );
            crate::prop_assert!(
                mapped.is_err(),
                "load_mapped accepted field {field} |= {huge:#x}"
            );
            Ok(())
        });
    }

    /// Arbitrary single-byte header corruption never panics: either a
    /// clean error or a structurally in-bounds graph.
    #[test]
    fn prop_header_corruption_never_panics() {
        let g = sample(true);
        let path = tmp("corr_src");
        save(&g, &path).unwrap();
        let full = std::fs::read(&path).unwrap();
        std::fs::remove_file(&path).ok();
        crate::util::prop::check(60, 143, |rng: &mut Rng| {
            let mut bytes = full.clone();
            let off = rng.below(HEADER_BYTES as usize);
            bytes[off] ^= 1u8 << rng.below(8);
            let p = tmp(&format!("corr_{off}"));
            std::fs::write(&p, &bytes).unwrap();
            let heap = load(&p);
            let mapped = load_mapped(&p);
            std::fs::remove_file(&p).ok();
            if let Ok(h) = heap {
                // Whatever loaded stayed within the file's bytes.
                crate::prop_assert!(
                    h.features.num_rows(h.feat_dim) * h.feat_dim * 4
                        <= full.len()
                );
            }
            if let Ok(m) = mapped {
                // Every mapped section window was validated against
                // the real file length — reading each one end to end
                // must stay in bounds (no fault, no over-read).
                crate::prop_assert!(m.offsets.len() * 8 <= full.len());
                crate::prop_assert!(m.neighbors.len() * 4 <= full.len());
                crate::prop_assert!(m.labels.len() * 2 <= full.len());
                let touch = m.offsets.iter().map(|&x| x as u128).sum::<u128>()
                    + m.neighbors.iter().map(|&x| x as u128).sum::<u128>()
                    + m.labels.iter().map(|&x| x as u128).sum::<u128>()
                    + m.rel
                        .as_ref()
                        .map_or(0, |r| r.iter().map(|&x| x as u128).sum());
                let _ = touch;
            }
            Ok(())
        });
    }
}
