//! Binary graph serialization (little-endian, versioned).
//!
//! Used to cache generated datasets between bench runs so the
//! generators run once per configuration. Format:
//!
//! ```text
//! magic "RTMAGRF1" | n: u64 | adj: u64 | feat_dim: u64 | classes: u64
//! relations: u64 | has_rel: u8
//! offsets [n+1] u64 | neighbors [adj] u32 | rel [adj] u8 (if has_rel)
//! labels [n] u16 | features [n*feat_dim] f32
//! ```

use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::Graph;

const MAGIC: &[u8; 8] = b"RTMAGRF1";

pub fn save(g: &Graph, path: &Path) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut w = BufWriter::new(std::fs::File::create(path)?);
    w.write_all(MAGIC)?;
    for v in [
        g.num_nodes() as u64,
        g.num_adj() as u64,
        g.feat_dim as u64,
        g.num_classes as u64,
        g.num_relations as u64,
    ] {
        w.write_all(&v.to_le_bytes())?;
    }
    w.write_all(&[g.rel.is_some() as u8])?;
    for &o in &g.offsets {
        w.write_all(&o.to_le_bytes())?;
    }
    for &nb in &g.neighbors {
        w.write_all(&nb.to_le_bytes())?;
    }
    if let Some(rel) = &g.rel {
        w.write_all(rel)?;
    }
    for &l in &g.labels {
        w.write_all(&l.to_le_bytes())?;
    }
    for &f in &g.features {
        w.write_all(&f.to_le_bytes())?;
    }
    w.flush()?;
    Ok(())
}

pub fn load(path: &Path) -> Result<Graph> {
    let mut r = BufReader::new(
        std::fs::File::open(path)
            .with_context(|| format!("open {}", path.display()))?,
    );
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{}: bad magic", path.display());
    }
    let mut u64buf = [0u8; 8];
    let mut read_u64 = |r: &mut BufReader<std::fs::File>| -> Result<u64> {
        r.read_exact(&mut u64buf)?;
        Ok(u64::from_le_bytes(u64buf))
    };
    let n = read_u64(&mut r)? as usize;
    let adj = read_u64(&mut r)? as usize;
    let feat_dim = read_u64(&mut r)? as usize;
    let num_classes = read_u64(&mut r)? as usize;
    let num_relations = read_u64(&mut r)? as usize;
    let mut flag = [0u8; 1];
    r.read_exact(&mut flag)?;

    let mut offsets = vec![0u64; n + 1];
    for o in &mut offsets {
        let mut b = [0u8; 8];
        r.read_exact(&mut b)?;
        *o = u64::from_le_bytes(b);
    }
    let mut neighbors = vec![0u32; adj];
    for nb in &mut neighbors {
        let mut b = [0u8; 4];
        r.read_exact(&mut b)?;
        *nb = u32::from_le_bytes(b);
    }
    let rel = if flag[0] == 1 {
        let mut rel = vec![0u8; adj];
        r.read_exact(&mut rel)?;
        Some(rel)
    } else {
        None
    };
    let mut labels = vec![0u16; n];
    for l in &mut labels {
        let mut b = [0u8; 2];
        r.read_exact(&mut b)?;
        *l = u16::from_le_bytes(b);
    }
    let mut features = vec![0f32; n * feat_dim];
    for f in &mut features {
        let mut b = [0u8; 4];
        r.read_exact(&mut b)?;
        *f = f32::from_le_bytes(b);
    }
    Ok(Graph {
        offsets,
        neighbors,
        rel,
        features,
        feat_dim,
        labels,
        num_classes,
        num_relations,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    fn sample(hetero: bool) -> Graph {
        let mut b = GraphBuilder::new(6);
        b.add_rel_edge(0, 1, 0);
        b.add_rel_edge(1, 2, if hetero { 2 } else { 0 });
        b.add_rel_edge(4, 5, if hetero { 1 } else { 0 });
        let mut g = b.build();
        g.feat_dim = 3;
        g.features = (0..18).map(|i| i as f32 * 0.5).collect();
        g.labels = vec![0, 1, 2, 0, 1, 2];
        g.num_classes = 3;
        g
    }

    #[test]
    fn roundtrip_homogeneous() {
        let g = sample(false);
        let path = std::env::temp_dir().join("rtma_io_homo.bin");
        save(&g, &path).unwrap();
        let h = load(&path).unwrap();
        assert_eq!(g.offsets, h.offsets);
        assert_eq!(g.neighbors, h.neighbors);
        assert_eq!(g.rel, h.rel);
        assert_eq!(g.features, h.features);
        assert_eq!(g.labels, h.labels);
        assert_eq!(g.num_classes, h.num_classes);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn roundtrip_heterogeneous() {
        let g = sample(true);
        let path = std::env::temp_dir().join("rtma_io_het.bin");
        save(&g, &path).unwrap();
        let h = load(&path).unwrap();
        assert!(h.rel.is_some());
        assert_eq!(g.rel, h.rel);
        assert_eq!(g.num_relations, h.num_relations);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_bad_magic() {
        let path = std::env::temp_dir().join("rtma_io_bad.bin");
        std::fs::write(&path, b"NOTAGRAPH").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(path).ok();
    }
}
