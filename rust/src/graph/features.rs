//! Feature storage backends: owned, shared-slab, and mmap'd.
//!
//! Every trainer subgraph used to carry a private `Vec<f32>` copy of
//! its `|V_p| x d` feature rows, which dominated prep cost on high-d
//! graphs and capped dataset size at RAM. [`FeatureStore`] replaces the
//! raw vector behind [`Graph::feature`] with three backends:
//!
//! - [`FeatureStore::Owned`] — a plain row-major `Vec<f32>`. The
//!   construction-time backend for hand-built test graphs and the
//!   *reference* the differential suite compares the other two against.
//! - [`FeatureStore::Shared`] — an `Arc<[f32]>` slab plus a `u32`
//!   row-index. Generators and the binary loader produce the full
//!   graph in this form (identity index); subgraph induction then
//!   emits index-only *views* over the parent slab, so extracting `k`
//!   trainer subgraphs copies **zero** feature floats and every
//!   trainer borrows the same allocation through the `Arc`.
//! - [`FeatureStore::Mapped`] — the feature section of an RTMAGRF2
//!   cache file mapped read-only into the address space, held as the
//!   same generic [`Slab<f32>`] window the CSR sections use
//!   ([`crate::graph::io::load_mapped`] hands every section one shared
//!   [`MappedFile`](super::MappedFile)). Rows are faulted in by the
//!   page cache on first touch, so graphs whose feature slab exceeds
//!   RAM still train; views compose the same way as `Shared`.
//!
//! The store is deliberately dumb about geometry: the row width `dim`
//! lives on [`Graph::feat_dim`] (one source of truth) and is passed
//! into every accessor. All three backends yield bit-identical
//! [`row`](FeatureStore::row) slices for the same logical content —
//! locked in by the differential tests in `graph::induce` and
//! `tests/feature_store.rs`.
//!
//! [`Graph::feature`]: super::Graph::feature
//! [`Graph::feat_dim`]: super::Graph::feat_dim

use std::sync::Arc;

use super::Slab;

/// Node-feature storage: one logical `rows x dim` row-major f32 matrix
/// behind one of three physical backends. See the module docs.
#[derive(Clone)]
pub enum FeatureStore {
    /// Private row-major buffer (row `v` at `v*dim..(v+1)*dim`).
    Owned(Vec<f32>),
    /// Reference-counted slab; `index[local] = row` within the slab.
    Shared { slab: Arc<[f32]>, index: Vec<u32> },
    /// Memory-mapped slab — a [`Slab<f32>`] window of the cache file's
    /// shared mapping (`io::load_mapped` always builds it with
    /// [`Slab::mapped`], never the heap backend); `index` of `None`
    /// means identity (the full on-disk graph), `Some` is a subgraph
    /// view into the mapped rows. Cloning a view clones the `Slab`
    /// (an `Arc` bump), never feature floats.
    Mapped { slab: Slab<f32>, index: Option<Vec<u32>> },
}

impl Default for FeatureStore {
    fn default() -> FeatureStore {
        FeatureStore::Owned(Vec::new())
    }
}

/// `Vec<f32>` literals become the `Owned` baseline backend.
impl From<Vec<f32>> for FeatureStore {
    fn from(data: Vec<f32>) -> FeatureStore {
        FeatureStore::Owned(data)
    }
}

impl std::fmt::Debug for FeatureStore {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FeatureStore::Owned(d) => {
                write!(f, "FeatureStore::Owned({} f32)", d.len())
            }
            FeatureStore::Shared { slab, index } => write!(
                f,
                "FeatureStore::Shared({} rows over {}-f32 slab)",
                index.len(),
                slab.len()
            ),
            FeatureStore::Mapped { slab, index } => write!(
                f,
                "FeatureStore::Mapped({} rows over {}-f32 map)",
                index.as_ref().map_or(slab.len(), |i| i.len()),
                slab.len()
            ),
        }
    }
}

impl FeatureStore {
    /// Full-graph `Shared` store: moves `data` into an `Arc` slab with
    /// an identity index of `data.len() / dim` rows. This is what the
    /// generators and `io::load` hand the coordinator so later
    /// induction is zero-copy. A featureless graph (`dim == 0`)
    /// degenerates to the empty `Owned` store — there is no slab worth
    /// sharing and no per-node row to index.
    pub fn shared_from_vec(data: Vec<f32>, dim: usize) -> FeatureStore {
        if dim == 0 {
            return FeatureStore::default();
        }
        debug_assert_eq!(
            data.len() % dim,
            0,
            "feature buffer is not a whole number of {dim}-wide rows"
        );
        let rows = data.len() / dim;
        FeatureStore::Shared {
            slab: Arc::from(data),
            index: (0..rows as u32).collect(),
        }
    }

    /// Short backend tag for logs and test diagnostics.
    pub fn backend(&self) -> &'static str {
        match self {
            FeatureStore::Owned(_) => "owned",
            FeatureStore::Shared { .. } => "shared",
            FeatureStore::Mapped { .. } => "mapped",
        }
    }

    /// Row `v` as a feature slice of width `dim`.
    #[inline]
    pub fn row(&self, v: usize, dim: usize) -> &[f32] {
        if dim == 0 {
            return &[];
        }
        match self {
            FeatureStore::Owned(d) => &d[v * dim..(v + 1) * dim],
            FeatureStore::Shared { slab, index } => {
                let r = index[v] as usize;
                &slab[r * dim..(r + 1) * dim]
            }
            FeatureStore::Mapped { slab, index } => {
                let r = index.as_ref().map_or(v, |i| i[v] as usize);
                &slab[r * dim..(r + 1) * dim]
            }
        }
    }

    /// Number of logical rows (nodes) this store describes.
    pub fn num_rows(&self, dim: usize) -> usize {
        match self {
            FeatureStore::Owned(d) => {
                if dim == 0 {
                    0
                } else {
                    d.len() / dim
                }
            }
            FeatureStore::Shared { index, .. } => index.len(),
            FeatureStore::Mapped { slab, index } => match index {
                Some(i) => i.len(),
                None => {
                    if dim == 0 {
                        0
                    } else {
                        slab.len() / dim
                    }
                }
            },
        }
    }

    /// True when the store describes no feature data at all.
    pub fn is_empty(&self) -> bool {
        match self {
            FeatureStore::Owned(d) => d.is_empty(),
            FeatureStore::Shared { index, .. } => index.is_empty(),
            FeatureStore::Mapped { slab, index } => match index {
                Some(i) => i.is_empty(),
                None => slab.is_empty(),
            },
        }
    }

    /// Subgraph view: row `i` of the result is row `rows[i]` of
    /// `self`. `Shared`/`Mapped` compose indices without touching a
    /// single feature float; `Owned` falls back to a gathering copy
    /// (the pre-refactor per-trainer-slab semantics, kept as the
    /// differential baseline).
    pub fn view(&self, rows: &[u32], dim: usize) -> FeatureStore {
        match self {
            FeatureStore::Owned(d) => {
                let mut out = Vec::with_capacity(rows.len() * dim);
                for &g in rows {
                    let g = g as usize;
                    out.extend_from_slice(&d[g * dim..(g + 1) * dim]);
                }
                FeatureStore::Owned(out)
            }
            FeatureStore::Shared { slab, index } => FeatureStore::Shared {
                slab: Arc::clone(slab),
                index: rows.iter().map(|&g| index[g as usize]).collect(),
            },
            FeatureStore::Mapped { slab, index } => FeatureStore::Mapped {
                slab: slab.clone(),
                index: Some(match index {
                    Some(i) => {
                        rows.iter().map(|&g| i[g as usize]).collect()
                    }
                    None => rows.to_vec(),
                }),
            },
        }
    }

    /// Gather the logical matrix into a fresh row-major vector.
    pub fn to_vec(&self, dim: usize) -> Vec<f32> {
        if let FeatureStore::Owned(d) = self {
            return d.clone();
        }
        let n = self.num_rows(dim);
        let mut out = Vec::with_capacity(n * dim);
        for v in 0..n {
            out.extend_from_slice(self.row(v, dim));
        }
        out
    }

    /// The backing slab as one contiguous row-major slice, when the
    /// store IS its slab in row order (Owned; Shared with an identity
    /// index covering the whole slab; Mapped without a view index).
    /// `None` for scattered views — callers gather instead.
    pub fn contiguous(&self, dim: usize) -> Option<&[f32]> {
        match self {
            FeatureStore::Owned(d) => Some(d),
            FeatureStore::Shared { slab, index } => {
                let identity = dim > 0
                    && index.len().checked_mul(dim) == Some(slab.len())
                    && index
                        .iter()
                        .enumerate()
                        .all(|(i, &r)| r as usize == i);
                if identity || (slab.is_empty() && index.is_empty()) {
                    Some(slab)
                } else {
                    None
                }
            }
            FeatureStore::Mapped { slab, index: None } => {
                Some(slab.as_slice())
            }
            FeatureStore::Mapped { .. } => None,
        }
    }

    /// Bytes of process heap this store *privately* adds on top of the
    /// backing slab: the whole buffer for `Owned`, only the u32 row
    /// index for `Shared`/`Mapped` views. The slab itself is
    /// attributed to no store (it is one allocation however many views
    /// borrow it; mapped bytes belong to the page cache). The
    /// zero-copy regression tests assert on this; the driver's
    /// `local_bytes` deployment metric instead counts logical
    /// `rows x dim` bytes per trainer.
    pub fn heap_bytes(&self) -> usize {
        match self {
            FeatureStore::Owned(d) => d.len() * 4,
            FeatureStore::Shared { index, .. } => index.len() * 4,
            FeatureStore::Mapped { index, .. } => {
                index.as_ref().map_or(0, |i| i.len() * 4)
            }
        }
    }

    /// Base address of the backing slab — `None` for `Owned`. Two
    /// stores returning the same pointer share one allocation; the
    /// zero-copy regression tests assert this across all `k` trainer
    /// subgraphs of one induction.
    pub fn slab_ptr(&self) -> Option<*const f32> {
        match self {
            FeatureStore::Owned(_) => None,
            FeatureStore::Shared { slab, .. } => Some(slab.as_ptr()),
            FeatureStore::Mapped { slab, .. } => {
                Some(slab.as_slice().as_ptr())
            }
        }
    }

    /// True for the zero-copy in-memory backend.
    pub fn is_shared(&self) -> bool {
        matches!(self, FeatureStore::Shared { .. })
    }

    /// Bit-exact row-by-row equality (the differential-suite check:
    /// `f32` compared as raw bits, so even NaN payloads must agree).
    pub fn rows_equal(&self, other: &FeatureStore, dim: usize) -> bool {
        if dim == 0 {
            return true;
        }
        let n = self.num_rows(dim);
        if n != other.num_rows(dim) {
            return false;
        }
        (0..n).all(|v| {
            self.row(v, dim)
                .iter()
                .zip(other.row(v, dim))
                .all(|(a, b)| a.to_bits() == b.to_bits())
        })
    }
}

/// Test support for the differential suites (unit, integration and
/// bench harnesses all rehost the same way — keep ONE recipe): the
/// same graph with its features rehosted on each backend — `owned`
/// (the copying reference), `shared`, and, on unix, `mapped` via an
/// RTMAGRF2 temp-file round trip. Panics on IO errors; hidden from
/// the public docs.
#[doc(hidden)]
pub fn rehost_backends(
    g: &super::Graph,
    tag: &str,
) -> Vec<(&'static str, super::Graph)> {
    let owned = {
        let mut h = g.clone();
        h.features = h.features.to_vec(h.feat_dim).into();
        h
    };
    let shared = {
        let mut h = g.clone();
        h.features = FeatureStore::shared_from_vec(
            g.features.to_vec(g.feat_dim),
            g.feat_dim,
        );
        h
    };
    let mut out = vec![("owned", owned), ("shared", shared)];
    if cfg!(unix) {
        // Unique file per call: differential tests run concurrently in
        // one process, so tag + pid alone could collide.
        static SEQ: std::sync::atomic::AtomicUsize =
            std::sync::atomic::AtomicUsize::new(0);
        let seq = SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let path = std::env::temp_dir().join(format!(
            "rtma_rehost_{tag}_{}_{seq}.bin",
            std::process::id()
        ));
        super::io::save(g, &path).unwrap();
        let mapped = super::io::load_mapped(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(mapped.features.backend(), "mapped");
        out.push(("mapped", mapped));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn owned() -> FeatureStore {
        FeatureStore::Owned((0..12).map(|i| i as f32).collect())
    }

    #[test]
    fn owned_rows_and_geometry() {
        let s = owned();
        assert_eq!(s.num_rows(3), 4);
        assert_eq!(s.row(1, 3), &[3.0, 4.0, 5.0]);
        assert_eq!(s.backend(), "owned");
        assert!(s.slab_ptr().is_none());
        assert_eq!(s.heap_bytes(), 48);
        assert!(!s.is_empty());
        assert!(FeatureStore::default().is_empty());
    }

    #[test]
    fn shared_identity_matches_owned() {
        let o = owned();
        let s = FeatureStore::shared_from_vec(o.to_vec(3), 3);
        assert_eq!(s.num_rows(3), 4);
        assert!(s.is_shared());
        assert!(s.rows_equal(&o, 3));
        assert_eq!(s.contiguous(3).unwrap(), o.contiguous(3).unwrap());
        // views share the allocation, never copy
        let v = s.view(&[2, 0], 3);
        assert_eq!(v.num_rows(3), 2);
        assert_eq!(v.row(0, 3), &[6.0, 7.0, 8.0]);
        assert_eq!(v.row(1, 3), &[0.0, 1.0, 2.0]);
        assert_eq!(v.slab_ptr(), s.slab_ptr());
        assert_eq!(v.heap_bytes(), 8); // two u32 index entries
        assert!(v.contiguous(3).is_none());
        // nested views compose indices
        let vv = v.view(&[1], 3);
        assert_eq!(vv.row(0, 3), &[0.0, 1.0, 2.0]);
        assert_eq!(vv.slab_ptr(), s.slab_ptr());
    }

    #[test]
    fn owned_view_gathers() {
        let o = owned();
        let v = o.view(&[3, 1], 3);
        assert_eq!(v.backend(), "owned");
        assert_eq!(v.to_vec(3), vec![9.0, 10.0, 11.0, 3.0, 4.0, 5.0]);
    }

    #[test]
    fn zero_dim_is_benign() {
        let s = FeatureStore::shared_from_vec(Vec::new(), 0);
        assert_eq!(s.num_rows(0), 0);
        assert!(s.is_empty());
        assert!(s.rows_equal(&FeatureStore::default(), 0));
        let o = FeatureStore::default();
        assert_eq!(o.row(5, 0), &[] as &[f32]);
    }

    #[test]
    fn rows_equal_is_bitwise() {
        let a = FeatureStore::Owned(vec![0.0, -0.0]);
        let b = FeatureStore::Owned(vec![0.0, 0.0]);
        assert!(!a.rows_equal(&b, 1), "-0.0 must differ bitwise");
        assert!(a.rows_equal(&a.clone(), 2));
    }

    #[cfg(unix)]
    #[test]
    fn mapped_store_reads_aligned_f32s() {
        use super::super::MappedFile;

        let path = std::env::temp_dir().join(format!(
            "rtma_slab_{}.bin",
            std::process::id()
        ));
        let floats: Vec<f32> = (0..6).map(|i| i as f32 * 1.5).collect();
        let mut bytes = vec![0u8; 8]; // 8-byte "header"
        for f in &floats {
            bytes.extend_from_slice(&f.to_le_bytes());
        }
        std::fs::write(&path, &bytes).unwrap();
        let file = std::fs::File::open(&path).unwrap();
        let map = Arc::new(MappedFile::map(&file).unwrap());
        let slab = Slab::<f32>::mapped(map, 8, 6).unwrap();
        assert_eq!(slab.as_slice(), &floats[..]);
        let store = FeatureStore::Mapped { slab, index: None };
        assert_eq!(store.num_rows(3), 2);
        assert_eq!(store.row(1, 3), &floats[3..6]);
        assert_eq!(store.contiguous(3).unwrap(), &floats[..]);
        let view = store.view(&[1, 0], 3);
        assert_eq!(view.row(0, 3), &floats[3..6]);
        assert_eq!(view.slab_ptr(), store.slab_ptr());
        assert_eq!(view.heap_bytes(), 8);
        assert!(view.contiguous(3).is_none());
        std::fs::remove_file(&path).ok();
    }
}
