//! Node-induced subgraphs with local<->global ID mapping.
//!
//! This is the paper's restricted-access unit: a TMA trainer `i`
//! receives `Subgraph` induced by its partition `alpha^{-1}(i)` —
//! edges crossing the partition boundary are *discarded*, exactly the
//! data loss the randomized schemes are designed to tolerate.
//!
//! [`Subgraph::induce`] here is the straightforward single-set
//! implementation; the coordinator's hot path materialises all
//! partitions at once via [`super::induce::induce_all`], which is
//! differentially tested to produce identical output and keeps this
//! version as its reference.

use std::collections::HashMap;

use super::{Graph, GraphBuilder};

/// A node-induced subgraph plus the mapping back to global IDs.
#[derive(Clone, Debug)]
pub struct Subgraph {
    /// Local graph over `0..global_ids.len()`.
    pub graph: Graph,
    /// `global_ids[local] = global` (sorted ascending).
    pub global_ids: Vec<u32>,
    /// Undirected edges of the *parent* graph lost at the boundary.
    pub cut_edges: usize,
}

impl Subgraph {
    /// Induce the subgraph of `parent` on `nodes` (deduplicated and
    /// sorted internally). Features and labels are *gathered into
    /// private buffers* (an `Owned` feature store) regardless of the
    /// parent's backend — this is the copying reference semantics the
    /// zero-copy [`super::induce::induce_all`] views are differentially
    /// tested against.
    pub fn induce(parent: &Graph, nodes: &[u32]) -> Subgraph {
        let mut global_ids: Vec<u32> = nodes.to_vec();
        global_ids.sort_unstable();
        global_ids.dedup();
        let mut local_of: HashMap<u32, u32> =
            HashMap::with_capacity(global_ids.len());
        for (l, &g) in global_ids.iter().enumerate() {
            local_of.insert(g, l as u32);
        }

        let mut b = GraphBuilder::new(global_ids.len());
        let mut cut = 0usize;
        for (lu, &gu) in global_ids.iter().enumerate() {
            let rels = parent.rels_of(gu as usize);
            for (k, &gv) in parent.neighbors_of(gu as usize).iter().enumerate()
            {
                match local_of.get(&gv) {
                    Some(&lv) => {
                        // add once per undirected edge
                        if (lu as u32) < lv {
                            let r = rels.map(|rs| rs[k]).unwrap_or(0);
                            b.add_rel_edge(lu as u32, lv, r);
                        }
                    }
                    None => cut += 1,
                }
            }
        }

        let mut graph = b.build();
        graph.feat_dim = parent.feat_dim;
        graph.num_classes = parent.num_classes;
        graph.num_relations = parent.num_relations;
        let mut features =
            Vec::with_capacity(global_ids.len() * parent.feat_dim);
        let mut labels = Vec::with_capacity(global_ids.len());
        for &g in &global_ids {
            features.extend_from_slice(parent.feature(g as usize));
            labels.push(parent.labels[g as usize]);
        }
        graph.labels = labels.into();
        graph.features = features.into();
        // Homogeneous parents produce rel=None subgraphs even if built
        // via add_rel_edge(0): GraphBuilder only records rel when >0.
        Subgraph { graph, global_ids, cut_edges: cut }
    }

    pub fn num_nodes(&self) -> usize {
        self.global_ids.len()
    }

    /// Local ID of a global node, if present.
    pub fn local_of(&self, global: u32) -> Option<u32> {
        self.global_ids
            .binary_search(&global)
            .ok()
            .map(|i| i as u32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    fn parent() -> Graph {
        // square 0-1-2-3-0 plus diagonal 0-2; features = id
        let mut b = GraphBuilder::new(5);
        for (u, v) in [(0, 1), (1, 2), (2, 3), (3, 0), (0, 2)] {
            b.add_edge(u, v);
        }
        let mut g = b.build();
        g.feat_dim = 1;
        g.features = (0..5).map(|i| i as f32).collect::<Vec<f32>>().into();
        g.labels = vec![0, 1, 0, 1, 0].into();
        g.num_classes = 2;
        g
    }

    #[test]
    fn induces_internal_edges_only() {
        let g = parent();
        let s = Subgraph::induce(&g, &[0, 1, 2]);
        assert_eq!(s.num_nodes(), 3);
        assert_eq!(s.graph.num_edges(), 3); // (0,1),(1,2),(0,2)
        // cut: 0-3, 2-3 seen from inside = 2 directed views
        assert_eq!(s.cut_edges, 2);
    }

    #[test]
    fn copies_features_and_labels() {
        let g = parent();
        let s = Subgraph::induce(&g, &[3, 1]);
        assert_eq!(s.global_ids, vec![1, 3]);
        assert_eq!(s.graph.features.to_vec(1), vec![1.0, 3.0]);
        assert_eq!(s.graph.labels, vec![1, 1]);
        assert_eq!(s.local_of(3), Some(1));
        assert_eq!(s.local_of(0), None);
    }

    #[test]
    fn dedups_input_nodes() {
        let g = parent();
        let s = Subgraph::induce(&g, &[2, 2, 0]);
        assert_eq!(s.num_nodes(), 2);
        assert_eq!(s.graph.num_edges(), 1);
    }

    #[test]
    fn prop_partition_subgraphs_cover_internal_edges() {
        use crate::util::rng::Rng;
        crate::util::prop::check(25, 77, |rng: &mut Rng| {
            let n = rng.range(2, 50);
            let mut b = GraphBuilder::new(n);
            for _ in 0..rng.range(0, 150) {
                b.add_edge(rng.below(n) as u32, rng.below(n) as u32);
            }
            let mut g = b.build();
            g.feat_dim = 0;
            g.labels = vec![0; n].into();
            // random 2-way partition
            let assign: Vec<usize> = (0..n).map(|_| rng.below(2)).collect();
            let parts: Vec<Vec<u32>> = (0..2)
                .map(|p| {
                    (0..n)
                        .filter(|&v| assign[v] == p)
                        .map(|v| v as u32)
                        .collect()
                })
                .collect();
            let subs: Vec<_> =
                parts.iter().map(|p| Subgraph::induce(&g, p)).collect();
            // internal + cut must account for every edge view
            let internal: usize =
                subs.iter().map(|s| s.graph.num_edges()).sum();
            let cut_views: usize = subs.iter().map(|s| s.cut_edges).sum();
            crate::prop_assert!(
                internal + cut_views / 2 == g.num_edges(),
                "internal={internal} cut_views={cut_views} total={}",
                g.num_edges()
            );
            Ok(())
        });
    }
}
