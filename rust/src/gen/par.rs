//! Shared machinery for the parallel count-then-fill generators.
//!
//! All three generators ([`super::dcsbm`], [`super::sbm2`],
//! [`super::bipartite`]) follow the same discipline the prep hot path
//! ([`crate::graph::induce_all`]) established:
//!
//! 1. **Chunk** the edge budget deterministically: per community
//!    (dcsbm/sbm2) or per type block (bipartite), each group's share
//!    apportioned by cumulative rounding of its sampling weight and
//!    then split into sub-chunks of at most [`CHUNK_EDGES`] edges, so
//!    chunk boundaries depend only on the config — never on threads.
//! 2. **Sample** chunks in parallel on [`parallel_map`], each chunk
//!    drawing from its own [`Rng::stream`]`(seed, domain, chunk)`, so
//!    the sampled multiset of edges is a pure function of the seed.
//! 3. **Count-then-fill** the CSR ([`assemble_csr`]): parallel
//!    per-node-range counting sort of the directed entries, per-row
//!    sort + dedup (rows are ~avg-degree long — no global O(E log E)
//!    re-sort), then a parallel fill of the pre-sized arrays via
//!    [`parallel_fill`]. Row content is a pure function of the edge
//!    multiset, so the output is byte-identical for a fixed seed at
//!    any worker count — the determinism property tests lock this in.
//!
//! Feature matrices get the same treatment: fixed node blocks, one
//! RNG stream per block, parallel fill of one pre-sized slab
//! ([`gaussian_mixture_features`]).

use crate::util::rng::Rng;
use crate::util::threadpool::{parallel_fill, parallel_map};

use crate::graph::Slab;

/// Upper bound on edges sampled by one chunk. Small enough that even
/// a single hot community (degree-skewed dcsbm) splits into many
/// chunks, large enough that per-chunk overhead stays negligible.
pub(crate) const CHUNK_EDGES: usize = 16_384;

/// Default worker count for the public generator entry points.
pub(crate) fn default_workers() -> usize {
    crate::util::threadpool::default_workers()
}

/// One sampling chunk: `target` edges drawn for `group` (a community
/// or type block), as sub-chunk `index` of the whole plan — the tag
/// that names its RNG stream.
#[derive(Clone, Copy, Debug)]
pub(crate) struct Chunk {
    pub group: usize,
    pub target: usize,
}

/// Split `total` edges over groups proportionally to `weights` —
/// cumulative rounding, so targets are integers that sum exactly to
/// `total` and depend only on the inputs — then cut each group's
/// share into sub-chunks of at most [`CHUNK_EDGES`].
pub(crate) fn plan_chunks(total: usize, weights: &[f64]) -> Vec<Chunk> {
    let mass: f64 = weights.iter().sum();
    let mut chunks = Vec::new();
    if mass <= 0.0 || weights.is_empty() {
        return chunks;
    }
    let mut cum = 0.0;
    let mut allotted_before = 0usize;
    for (group, &w) in weights.iter().enumerate() {
        cum += w;
        let allotted_through =
            ((total as f64 * cum / mass).round() as usize).min(total);
        let mut left = allotted_through - allotted_before;
        allotted_before = allotted_through;
        while left > 0 {
            let take = left.min(CHUNK_EDGES);
            chunks.push(Chunk { group, target: take });
            left -= take;
        }
    }
    chunks
}

/// Undirected edges sampled by one chunk, all typed `rel` (generators
/// sample one relation per type block; 0 for homogeneous graphs).
pub(crate) struct ChunkEdges {
    pub rel: u8,
    pub pairs: Vec<(u32, u32)>,
}

/// Count-then-fill CSR assembly over per-chunk undirected edge lists.
///
/// Every pair `(u, v)` becomes the two directed entries `u->v` and
/// `v->u`; rows come out sorted with duplicate neighbours removed
/// (smallest relation wins, matching `GraphBuilder`'s first-wins rule
/// on its sorted stream), exactly the invariants the rest of the crate
/// assumes of generated CSRs. Callers must not pass self-loops.
///
/// Work is split over contiguous node ranges in two parallel passes:
/// first each *chunk* buckets its directed entries by destination
/// range (total work O(E), parallel across chunks), then each *range*
/// consumes only its own buckets, counting-sorts them locally and
/// sorts + dedups each row, and the pre-sized output arrays are
/// filled in parallel. Row contents are a function of the edge
/// multiset alone, so the result does not depend on `workers` or the
/// range split.
pub(crate) fn assemble_csr(
    n: usize,
    chunks: &[ChunkEdges],
    workers: usize,
) -> (Slab<u64>, Slab<u32>, Option<Slab<u8>>) {
    let hetero = chunks.iter().any(|c| c.rel > 0 && !c.pairs.is_empty());

    struct BlockRows {
        /// Deduplicated row length per node of the range.
        lens: Vec<u32>,
        nbrs: Vec<u32>,
        rels: Vec<u8>,
    }

    let nblocks = if n == 0 { 0 } else { (workers * 2).clamp(1, n) };
    let span = if nblocks == 0 { 0 } else { n.div_ceil(nblocks) };

    // Pass 1 (parallel over chunks): route both directions of every
    // pair to the node range owning its source, so no range ever
    // scans another range's edges.
    let buckets: Vec<Vec<Vec<(u32, u32, u8)>>> = if nblocks == 0 {
        Vec::new()
    } else {
        parallel_map(chunks.len(), workers.max(1), |ci| {
            let ch = &chunks[ci];
            let mut per_block: Vec<Vec<(u32, u32, u8)>> =
                (0..nblocks).map(|_| Vec::new()).collect();
            for &(u, v) in &ch.pairs {
                per_block[u as usize / span].push((u, v, ch.rel));
                per_block[v as usize / span].push((v, u, ch.rel));
            }
            per_block
        })
    };

    // Pass 2 (parallel over ranges): build each range's rows from its
    // own buckets. Bucket order is fixed (chunk order), but any order
    // would do: rows are sorted below, so content depends only on the
    // multiset.
    let blocks: Vec<BlockRows> = parallel_map(nblocks, workers.max(1), |b| {
        let lo = ((b * span).min(n)) as u32;
        let hi = (((b + 1) * span).min(n)) as u32;
        let width = (hi - lo) as usize;

        let mut mine: Vec<(u32, u32, u8)> = Vec::new();
        for per_block in &buckets {
            for &(s, d, r) in &per_block[b] {
                mine.push((s - lo, d, r));
            }
        }

        // Counting sort by local source row.
        let mut cur = vec![0u32; width + 1];
        for &(l, _, _) in &mine {
            cur[l as usize + 1] += 1;
        }
        for l in 0..width {
            cur[l + 1] += cur[l];
        }
        let mut raw_n = vec![0u32; mine.len()];
        let mut raw_r = vec![0u8; if hetero { mine.len() } else { 0 }];
        let mut fill = cur.clone();
        for &(l, nb, r) in &mine {
            let pos = fill[l as usize] as usize;
            fill[l as usize] += 1;
            raw_n[pos] = nb;
            if hetero {
                raw_r[pos] = r;
            }
        }

        // Per-row sort + dedup (first = smallest rel wins).
        let mut lens = vec![0u32; width];
        let mut nbrs = Vec::with_capacity(mine.len());
        let mut rels = Vec::with_capacity(if hetero { mine.len() } else { 0 });
        let mut row: Vec<(u32, u8)> = Vec::new();
        for l in 0..width {
            let (a, b) = (cur[l] as usize, cur[l + 1] as usize);
            if hetero {
                row.clear();
                row.extend(
                    raw_n[a..b].iter().zip(&raw_r[a..b]).map(|(&x, &r)| (x, r)),
                );
                row.sort_unstable();
                row.dedup_by_key(|e| e.0);
                lens[l] = row.len() as u32;
                for &(x, r) in &row {
                    nbrs.push(x);
                    rels.push(r);
                }
            } else {
                let start = nbrs.len();
                nbrs.extend_from_slice(&raw_n[a..b]);
                nbrs[start..].sort_unstable();
                let mut keep = start;
                for i in start..nbrs.len() {
                    if keep == start || nbrs[keep - 1] != nbrs[i] {
                        nbrs[keep] = nbrs[i];
                        keep += 1;
                    }
                }
                nbrs.truncate(keep);
                lens[l] = (keep - start) as u32;
            }
        }
        BlockRows { lens, nbrs, rels }
    });

    // Offsets from the deduplicated row lengths (count half done).
    let mut offsets = vec![0u64; n + 1];
    {
        let mut v = 0usize;
        for b in &blocks {
            for &len in &b.lens {
                offsets[v + 1] = offsets[v] + len as u64;
                v += 1;
            }
        }
        debug_assert_eq!(v, n);
    }
    let total = offsets[n] as usize;

    // Parallel fill of the pre-sized arrays: each range's rows are
    // contiguous in node order, so its slice of the output is one
    // disjoint window.
    let sizes: Vec<usize> = blocks.iter().map(|b| b.nbrs.len()).collect();
    let mut neighbors = vec![0u32; total];
    parallel_fill(&mut neighbors, &sizes, workers.max(1), |i, w| {
        w.copy_from_slice(&blocks[i].nbrs);
    });
    let rel = if hetero {
        let mut rel = vec![0u8; total];
        parallel_fill(&mut rel, &sizes, workers.max(1), |i, w| {
            w.copy_from_slice(&blocks[i].rels);
        });
        Some(rel.into())
    } else {
        None
    };
    (offsets.into(), neighbors.into(), rel)
}

/// Node span of one feature-fill block. Fixed (not worker-derived):
/// each block's noise comes from its own RNG stream, so the split
/// must be a pure function of the graph size.
pub(crate) const FEAT_BLOCK_NODES: usize = 8_192;

/// `n x f` Gaussian-mixture features, filled in parallel over fixed
/// node blocks: row `v` is `mu[labels[v]] + noise_of(v) * N(0, I)`,
/// with block `b` drawing from `Rng::stream(seed, domain, b)`.
pub(crate) fn gaussian_mixture_features(
    n: usize,
    f: usize,
    labels: &[u16],
    mu: &[f32],
    noise_of: impl Fn(usize) -> f64 + Sync,
    seed: u64,
    domain: u64,
    workers: usize,
) -> Vec<f32> {
    let mut out = vec![0f32; n * f];
    if n == 0 || f == 0 {
        return out;
    }
    let nblocks = n.div_ceil(FEAT_BLOCK_NODES);
    let sizes: Vec<usize> = (0..nblocks)
        .map(|b| {
            let lo = b * FEAT_BLOCK_NODES;
            let hi = ((b + 1) * FEAT_BLOCK_NODES).min(n);
            (hi - lo) * f
        })
        .collect();
    parallel_fill(&mut out, &sizes, workers.max(1), |b, w| {
        let mut rng = Rng::stream(seed, domain, b as u64);
        let lo = b * FEAT_BLOCK_NODES;
        for (i, row) in w.chunks_exact_mut(f).enumerate() {
            let v = lo + i;
            let cc = labels[v] as usize;
            let noise = noise_of(v) as f32;
            for (d, x) in row.iter_mut().enumerate() {
                *x = mu[cc * f + d] + noise * rng.gaussian() as f32;
            }
        }
    });
    out
}

/// Weighted sampler over a fixed weight vector via cumulative sums.
/// Shared by the degree-corrected samplers of `dcsbm` (parallel and
/// reference paths alike).
pub(crate) struct CumSampler {
    cum: Vec<f64>,
}

impl CumSampler {
    pub fn new(weights: &[f64]) -> CumSampler {
        let mut cum = Vec::with_capacity(weights.len());
        let mut acc = 0.0;
        for &w in weights {
            acc += w;
            cum.push(acc);
        }
        CumSampler { cum }
    }

    pub fn total(&self) -> f64 {
        *self.cum.last().unwrap_or(&0.0)
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        let x = rng.f64() * self.total();
        match self.cum.binary_search_by(|c| c.partial_cmp(&x).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(self.cum.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_chunks_sums_and_bounds() {
        let chunks = plan_chunks(100_000, &[1.0, 3.0, 0.0, 1.0]);
        let total: usize = chunks.iter().map(|c| c.target).sum();
        assert_eq!(total, 100_000);
        assert!(chunks.iter().all(|c| c.target <= CHUNK_EDGES));
        assert!(chunks.iter().all(|c| c.group < 4));
        // group 1 holds ~3/5 of the mass
        let g1: usize =
            chunks.iter().filter(|c| c.group == 1).map(|c| c.target).sum();
        assert!((g1 as f64 - 60_000.0).abs() < 2.0, "g1={g1}");
        // zero-weight groups sample nothing
        assert!(chunks.iter().all(|c| c.group != 2));
        assert!(plan_chunks(10, &[]).is_empty());
        assert!(plan_chunks(10, &[0.0, 0.0]).is_empty());
    }

    #[test]
    fn assemble_matches_graph_builder() {
        use crate::graph::GraphBuilder;
        use crate::util::rng::Rng;
        crate::util::prop::check(25, 83, |rng: &mut Rng| {
            let n = rng.range(1, 120);
            let hetero = rng.chance(0.5);
            let nchunks = rng.range(1, 6);
            let mut chunks = Vec::new();
            let mut b = GraphBuilder::new(n);
            for c in 0..nchunks {
                // Give each chunk a single rel, mirroring the
                // generators' type blocks; keep (u, v) pairs disjoint
                // across rels by parity so first-wins never fires
                // across different relations (the generators'
                // invariant).
                let rel = if hetero { (c % 2) as u8 } else { 0 };
                let mut pairs = Vec::new();
                for _ in 0..rng.range(0, 120) {
                    let u = rng.below(n) as u32;
                    let v = rng.below(n) as u32;
                    if u == v {
                        continue;
                    }
                    let (lo, hi) = (u.min(v), u.max(v));
                    if hetero && (lo + hi) % 2 != (rel as u32) % 2 {
                        continue;
                    }
                    pairs.push((u, v));
                    b.add_rel_edge(u, v, rel);
                }
                chunks.push(ChunkEdges { rel, pairs });
            }
            let reference = b.build();
            for workers in [1, 2, 4] {
                let (offsets, neighbors, rel) =
                    assemble_csr(n, &chunks, workers);
                crate::prop_assert!(
                    offsets == reference.offsets,
                    "offsets (w={workers})"
                );
                crate::prop_assert!(
                    neighbors == reference.neighbors,
                    "neighbors (w={workers})"
                );
                crate::prop_assert!(rel == reference.rel, "rel (w={workers})");
            }
            Ok(())
        });
    }

    #[test]
    fn gaussian_features_deterministic_across_workers() {
        let labels: Vec<u16> = (0..1000).map(|v| (v % 4) as u16).collect();
        let mu: Vec<f32> = (0..4 * 3).map(|i| i as f32 * 0.25).collect();
        let base = gaussian_mixture_features(
            1000, 3, &labels, &mu, |_| 0.5, 7, 9, 1,
        );
        for workers in [2, 5] {
            let other = gaussian_mixture_features(
                1000, 3, &labels, &mu, |_| 0.5, 7, 9, workers,
            );
            assert!(
                base.iter().zip(&other).all(|(a, b)| a.to_bits() == b.to_bits()),
                "workers={workers}"
            );
        }
    }
}
