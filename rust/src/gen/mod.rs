//! Synthetic graph generators — the data substrate.
//!
//! The paper's datasets (Reddit, ogbl-citation2, MAG240M-P and the
//! proprietary E-comm graph) are unavailable here (see DESIGN.md §2),
//! so this module builds functional equivalents that exercise the same
//! code paths and, crucially, the same *mechanism*: community structure
//! correlated with features, so that min-cut partitioning induces
//! cross-trainer feature disparity while randomized partitioning does
//! not.
//!
//! - [`dcsbm`] — degree-corrected stochastic block model with a
//!   homophily (class-compatibility) parameter and power-law degrees;
//!   presets emulate the three homogeneous benchmarks.
//! - [`sbm2`] — the exact 2-class compatibility model of Lemma 1 with
//!   one-hot features; used by the theory-validation bench.
//! - [`bipartite`] — query-item graph with typed edges for the
//!   heterogeneous (E-comm) experiments.
//! - [`presets`] — named dataset configurations + on-disk caching.
//!
//! # Parallel count-then-fill generation
//!
//! All three generators run the discipline of `par`: the edge
//! budget is chunked deterministically (per community for
//! dcsbm/sbm2, per type block for bipartite), every chunk samples
//! from its own `Rng::stream(seed, domain, chunk)`, and the CSR and
//! feature slab are counted and filled in parallel on the crate
//! threadpool — no `GraphBuilder`, no O(E log E) re-sort. For a fixed
//! seed the output is **byte-identical at any worker count** (the
//! `*_with_workers` entry points expose the knob; the determinism
//! suite in `tests/gen_determinism.rs` locks it in). The original
//! serial implementations survive in [`reference`] as the perf
//! baseline for `benches/perf_hotpath.rs`.

mod bipartite;
mod dcsbm;
pub(crate) mod par;
pub mod presets;
pub mod reference;
mod sbm2;

pub use bipartite::{
    bipartite, bipartite_with_workers, BipartiteConfig, BipartiteGraph,
};
pub use dcsbm::{dcsbm, dcsbm_with_workers, DcsbmConfig};
pub use presets::{cache_path, load_preset, preset_names, Preset};
pub use sbm2::{sbm2, sbm2_with_workers, Sbm2Config};
