//! Synthetic graph generators — the data substrate.
//!
//! The paper's datasets (Reddit, ogbl-citation2, MAG240M-P and the
//! proprietary E-comm graph) are unavailable here (see DESIGN.md §2),
//! so this module builds functional equivalents that exercise the same
//! code paths and, crucially, the same *mechanism*: community structure
//! correlated with features, so that min-cut partitioning induces
//! cross-trainer feature disparity while randomized partitioning does
//! not.
//!
//! - [`dcsbm`] — degree-corrected stochastic block model with a
//!   homophily (class-compatibility) parameter and power-law degrees;
//!   presets emulate the three homogeneous benchmarks.
//! - [`sbm2`] — the exact 2-class compatibility model of Lemma 1 with
//!   one-hot features; used by the theory-validation bench.
//! - [`bipartite`] — query-item graph with typed edges for the
//!   heterogeneous (E-comm) experiments.
//! - [`presets`] — named dataset configurations + on-disk caching.

mod bipartite;
mod dcsbm;
pub mod presets;
mod sbm2;

pub use bipartite::{bipartite, BipartiteConfig};
pub use dcsbm::{dcsbm, DcsbmConfig};
pub use presets::{load_preset, preset_names, Preset};
pub use sbm2::{sbm2, Sbm2Config};
