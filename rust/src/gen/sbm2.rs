//! The exact 2-class compatibility model of Lemma 1.
//!
//! Two equally-sized classes y in {0,1}, edge probability
//! p_ji ∝ H(y_i, y_j) with H = h on the diagonal and 1-h off it, and
//! one-hot features x_v = onehot(y_v). The theory-validation bench
//! measures expected edge-cut (Eq. 2) and the initial-gradient
//! discrepancies (Thm 2) on graphs from this generator and compares
//! them with the closed forms.
//!
//! Sampling follows the parallel count-then-fill discipline of
//! `gen::par`: the edge budget is chunked by the class of `u`
//! (uniform endpoint draw → equal class weights) and each chunk
//! samples from its own `(seed, chunk)` stream, so output is
//! byte-identical for a fixed seed at any worker count.

use crate::graph::{FeatureStore, Graph};
use crate::util::rng::Rng;
use crate::util::threadpool::parallel_map;

use super::par::{assemble_csr, default_workers, plan_chunks, ChunkEdges};

#[derive(Clone, Debug)]
pub struct Sbm2Config {
    /// Nodes per class (total = 2 * class_size).
    pub class_size: usize,
    pub avg_degree: f64,
    /// Homophily h in [0, 1]: P(same-class partner).
    pub homophily: f64,
    pub seed: u64,
}

const DOM_EDGES: u64 = 0x5B20;

pub fn sbm2(cfg: &Sbm2Config) -> Graph {
    sbm2_with_workers(cfg, default_workers())
}

/// [`sbm2`] with an explicit worker count; output is independent of it.
pub fn sbm2_with_workers(cfg: &Sbm2Config, workers: usize) -> Graph {
    assert!(cfg.class_size >= 1 && workers >= 1);
    let cs = cfg.class_size;
    let n = cs * 2;
    // labels: first half 0, second half 1 (node order is irrelevant to
    // every consumer; partitioners are label-blind).
    let labels: Vec<u16> = (0..n).map(|v| (v >= cs) as u16).collect();

    // Chunk by the (uniformly drawn) class of `u`: equal weights.
    let target = (n as f64 * cfg.avg_degree / 2.0) as usize;
    let chunks = plan_chunks(target, &[1.0, 1.0]);

    let lists: Vec<ChunkEdges> = parallel_map(chunks.len(), workers, |i| {
        let (cu, target) = (chunks[i].group, chunks[i].target);
        let mut rng = Rng::stream(cfg.seed, DOM_EDGES, i as u64);
        let mut pairs = Vec::with_capacity(target);
        let mut attempts = 0usize;
        let max_attempts = target * 20;
        while pairs.len() < target && attempts < max_attempts {
            attempts += 1;
            let u = cu * cs + rng.below(cs);
            let same = rng.chance(cfg.homophily);
            let cv = if same { cu } else { 1 - cu };
            let v = cv * cs + rng.below(cs);
            if u != v {
                pairs.push((u as u32, v as u32));
            }
        }
        ChunkEdges { rel: 0, pairs }
    });

    let (offsets, neighbors, rel) = assemble_csr(n, &lists, workers);

    // one-hot features
    let onehot: Vec<f32> = labels
        .iter()
        .flat_map(|&y| if y == 0 { [1.0, 0.0] } else { [0.0, 1.0] })
        .collect();
    Graph {
        offsets,
        neighbors,
        rel,
        features: FeatureStore::shared_from_vec(onehot, 2),
        feat_dim: 2,
        labels: labels.into(),
        num_classes: 2,
        num_relations: 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::stats::homophily_ratio;

    #[test]
    fn classes_balanced_and_onehot() {
        let g = sbm2(&Sbm2Config {
            class_size: 500,
            avg_degree: 10.0,
            homophily: 0.8,
            seed: 1,
        });
        assert_eq!(g.num_nodes(), 1000);
        let c1 = g.labels.iter().filter(|&&y| y == 1).count();
        assert_eq!(c1, 500);
        for v in 0..g.num_nodes() {
            let f = g.feature(v);
            assert_eq!(f[g.labels[v] as usize], 1.0);
            assert_eq!(f[1 - g.labels[v] as usize], 0.0);
        }
    }

    #[test]
    fn empirical_homophily_matches_h() {
        for &h in &[0.5, 0.7, 0.9] {
            let g = sbm2(&Sbm2Config {
                class_size: 2000,
                avg_degree: 16.0,
                homophily: h,
                seed: 3,
            });
            let emp = homophily_ratio(&g);
            assert!((emp - h).abs() < 0.03, "h={h} emp={emp}");
        }
    }

    #[test]
    fn worker_count_does_not_change_output() {
        let cfg = Sbm2Config {
            class_size: 1500,
            avg_degree: 12.0,
            homophily: 0.75,
            seed: 6,
        };
        let one = sbm2_with_workers(&cfg, 1);
        let four = sbm2_with_workers(&cfg, 4);
        assert_eq!(one.offsets, four.offsets);
        assert_eq!(one.neighbors, four.neighbors);
    }
}
