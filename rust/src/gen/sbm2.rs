//! The exact 2-class compatibility model of Lemma 1.
//!
//! Two equally-sized classes y in {0,1}, edge probability
//! p_ji ∝ H(y_i, y_j) with H = h on the diagonal and 1-h off it, and
//! one-hot features x_v = onehot(y_v). The theory-validation bench
//! measures expected edge-cut (Eq. 2) and the initial-gradient
//! discrepancies (Thm 2) on graphs from this generator and compares
//! them with the closed forms.

use crate::graph::{FeatureStore, Graph, GraphBuilder};
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct Sbm2Config {
    /// Nodes per class (total = 2 * class_size).
    pub class_size: usize,
    pub avg_degree: f64,
    /// Homophily h in [0, 1]: P(same-class partner).
    pub homophily: f64,
    pub seed: u64,
}

pub fn sbm2(cfg: &Sbm2Config) -> Graph {
    let n = cfg.class_size * 2;
    let mut rng = Rng::new(cfg.seed);
    // labels: first half 0, second half 1 (node order is irrelevant to
    // every consumer; partitioners are label-blind).
    let labels: Vec<u16> =
        (0..n).map(|v| (v >= cfg.class_size) as u16).collect();

    let target = (n as f64 * cfg.avg_degree / 2.0) as usize;
    let mut b = GraphBuilder::new(n);
    let mut attempts = 0;
    while b.num_pending() < target && attempts < target * 20 {
        attempts += 1;
        let u = rng.below(n);
        let same = rng.chance(cfg.homophily);
        let v = loop {
            let cand = if same == (labels[u] == 0) {
                rng.below(cfg.class_size) // class 0
            } else {
                cfg.class_size + rng.below(cfg.class_size) // class 1
            };
            if cand != u {
                break cand;
            }
        };
        b.add_edge(u as u32, v as u32);
    }
    let mut g = b.build();
    // one-hot features
    g.feat_dim = 2;
    let onehot: Vec<f32> = labels
        .iter()
        .flat_map(|&y| if y == 0 { [1.0, 0.0] } else { [0.0, 1.0] })
        .collect();
    g.features = FeatureStore::shared_from_vec(onehot, 2);
    g.labels = labels;
    g.num_classes = 2;
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::stats::homophily_ratio;

    #[test]
    fn classes_balanced_and_onehot() {
        let g = sbm2(&Sbm2Config {
            class_size: 500,
            avg_degree: 10.0,
            homophily: 0.8,
            seed: 1,
        });
        assert_eq!(g.num_nodes(), 1000);
        let c1 = g.labels.iter().filter(|&&y| y == 1).count();
        assert_eq!(c1, 500);
        for v in 0..g.num_nodes() {
            let f = g.feature(v);
            assert_eq!(f[g.labels[v] as usize], 1.0);
            assert_eq!(f[1 - g.labels[v] as usize], 0.0);
        }
    }

    #[test]
    fn empirical_homophily_matches_h() {
        for &h in &[0.5, 0.7, 0.9] {
            let g = sbm2(&Sbm2Config {
                class_size: 2000,
                avg_degree: 16.0,
                homophily: h,
                seed: 3,
            });
            let emp = homophily_ratio(&g);
            assert!((emp - h).abs() < 0.03, "h={h} emp={emp}");
        }
    }
}
