//! Serial reference generators — the pre-parallel implementations.
//!
//! These are the original single-`Rng`, `GraphBuilder`-based samplers
//! (one edge at a time through a global stream, O(E log E) build-time
//! re-sort). They are kept for two jobs, mirroring how
//! [`Subgraph::induce`](crate::graph::Subgraph::induce) anchors the
//! fused induction path:
//!
//! - the **perf baseline** of `benches/perf_hotpath.rs`'s generation
//!   section (serial reference vs parallel at 1/2/8 workers);
//! - a **statistical cross-check** that the parallel rewrites sample
//!   the same model (edge budget, homophily) even though their RNG
//!   streams — and therefore their exact graphs — differ.
//!
//! Nothing in the runtime path calls these.

use crate::graph::{FeatureStore, Graph, GraphBuilder};
use crate::util::rng::Rng;

use super::par::CumSampler;
use super::{BipartiteConfig, BipartiteGraph, DcsbmConfig, Sbm2Config};

/// Serial [`super::dcsbm`]: one global RNG, rejection sampling into a
/// `GraphBuilder`.
pub fn dcsbm_serial(cfg: &DcsbmConfig) -> Graph {
    assert!(cfg.communities >= 1 && cfg.nodes >= cfg.communities);
    let mut rng = Rng::new(cfg.seed);
    let n = cfg.nodes;
    let c = cfg.communities;

    let labels: Vec<u16> = (0..n).map(|v| (v % c) as u16).collect();
    let mut members: Vec<Vec<u32>> = vec![Vec::new(); c];
    for (v, &l) in labels.iter().enumerate() {
        members[l as usize].push(v as u32);
    }

    let theta: Vec<f64> = (0..n)
        .map(|_| {
            if cfg.degree_exponent <= 0.0 {
                1.0
            } else {
                let u = 1.0 - rng.f64();
                u.powf(-cfg.degree_exponent).min(100.0)
            }
        })
        .collect();

    let global = CumSampler::new(&theta);
    let per_comm: Vec<CumSampler> = members
        .iter()
        .map(|ms| {
            CumSampler::new(
                &ms.iter().map(|&v| theta[v as usize]).collect::<Vec<_>>(),
            )
        })
        .collect();

    let target_edges = (n as f64 * cfg.avg_degree / 2.0) as usize;
    let mut b = GraphBuilder::new(n);
    let mut attempts = 0usize;
    let max_attempts = target_edges * 20;
    while b.num_pending() < target_edges && attempts < max_attempts {
        attempts += 1;
        let u = global.sample(&mut rng) as u32;
        let cu = labels[u as usize] as usize;
        let cv = if rng.chance(cfg.homophily) || c == 1 {
            cu
        } else {
            let mut k = rng.below(c - 1);
            if k >= cu {
                k += 1;
            }
            k
        };
        let v = members[cv][per_comm[cv].sample(&mut rng)];
        if u != v {
            b.add_edge(u, v);
        }
    }
    let mut g = b.build();

    let f = cfg.feat_dim;
    let mut mu = vec![0.0f32; c * f];
    for x in mu.iter_mut() {
        *x = rng.gaussian() as f32;
    }
    let mut features = vec![0.0f32; n * f];
    for v in 0..n {
        let cc = labels[v] as usize;
        for d in 0..f {
            features[v * f + d] =
                mu[cc * f + d] + cfg.feature_noise as f32 * rng.gaussian() as f32;
        }
    }

    g.features = FeatureStore::shared_from_vec(features, f);
    g.feat_dim = f;
    g.labels = labels.into();
    g.num_classes = c;
    g
}

/// Serial [`super::sbm2`].
pub fn sbm2_serial(cfg: &Sbm2Config) -> Graph {
    let n = cfg.class_size * 2;
    let mut rng = Rng::new(cfg.seed);
    let labels: Vec<u16> =
        (0..n).map(|v| (v >= cfg.class_size) as u16).collect();

    let target = (n as f64 * cfg.avg_degree / 2.0) as usize;
    let mut b = GraphBuilder::new(n);
    let mut attempts = 0;
    while b.num_pending() < target && attempts < target * 20 {
        attempts += 1;
        let u = rng.below(n);
        let same = rng.chance(cfg.homophily);
        let v = loop {
            let cand = if same == (labels[u] == 0) {
                rng.below(cfg.class_size) // class 0
            } else {
                cfg.class_size + rng.below(cfg.class_size) // class 1
            };
            if cand != u {
                break cand;
            }
        };
        b.add_edge(u as u32, v as u32);
    }
    let mut g = b.build();
    g.feat_dim = 2;
    let onehot: Vec<f32> = labels
        .iter()
        .flat_map(|&y| if y == 0 { [1.0, 0.0] } else { [0.0, 1.0] })
        .collect();
    g.features = FeatureStore::shared_from_vec(onehot, 2);
    g.labels = labels.into();
    g.num_classes = 2;
    g
}

/// Serial [`super::bipartite`].
pub fn bipartite_serial(cfg: &BipartiteConfig) -> BipartiteGraph {
    let nq = cfg.num_queries;
    let ni = cfg.num_items;
    let n = nq + ni;
    let c = cfg.communities;
    let mut rng = Rng::new(cfg.seed);

    let labels: Vec<u16> = (0..n).map(|v| (v % c) as u16).collect();
    let item_members: Vec<Vec<u32>> = {
        let mut m = vec![Vec::new(); c];
        for v in nq..n {
            m[labels[v] as usize].push(v as u32);
        }
        m
    };

    let mut b = GraphBuilder::new(n);
    let pick_item = |rng: &mut Rng, home: usize| -> u32 {
        let cc = if rng.chance(cfg.homophily) || c == 1 {
            home
        } else {
            let mut k = rng.below(c - 1);
            if k >= home {
                k += 1;
            }
            k
        };
        let ms = &item_members[cc];
        ms[rng.below(ms.len())]
    };

    let qi_total = (nq as f64 * cfg.qi_degree) as usize;
    for _ in 0..qi_total {
        let q = rng.below(nq);
        let it = pick_item(&mut rng, labels[q] as usize);
        b.add_rel_edge(q as u32, it, 0);
    }
    let ii_total = (ni as f64 * cfg.ii_degree / 2.0) as usize;
    for _ in 0..ii_total {
        let u = nq + rng.below(ni);
        let v = pick_item(&mut rng, labels[u] as usize);
        if u as u32 != v {
            b.add_rel_edge(u as u32, v, 1);
        }
    }

    let mut g = b.build();
    let f = cfg.feat_dim;
    let mut mu = vec![0.0f32; c * f];
    for x in mu.iter_mut() {
        *x = rng.gaussian() as f32;
    }
    let mut features = vec![0.0f32; n * f];
    for v in 0..n {
        let cc = labels[v] as usize;
        let noise = if v < nq {
            cfg.feature_noise * 1.5
        } else {
            cfg.feature_noise
        };
        for d in 0..f {
            features[v * f + d] =
                mu[cc * f + d] + noise as f32 * rng.gaussian() as f32;
        }
    }
    g.features = FeatureStore::shared_from_vec(features, f);
    g.feat_dim = f;
    g.labels = labels.into();
    g.num_classes = c;
    g.num_relations = 2;
    BipartiteGraph { graph: g, boundary: nq as u32 }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::stats::{graph_stats, homophily_ratio};

    /// The parallel rewrites sample the same model as the serial
    /// references: edge budgets within dedup slack, homophily within
    /// sampling noise. (Exact graphs differ — the RNG streams do.)
    #[test]
    fn parallel_generators_match_reference_statistics() {
        let dc = DcsbmConfig {
            nodes: 3000,
            communities: 10,
            avg_degree: 12.0,
            homophily: 0.8,
            feat_dim: 4,
            feature_noise: 0.4,
            degree_exponent: 0.8,
            seed: 42,
        };
        let a = graph_stats(&super::super::dcsbm(&dc));
        let b = graph_stats(&dcsbm_serial(&dc));
        assert!(
            (a.avg_degree - b.avg_degree).abs() < 1.5,
            "avg degree {} vs {}",
            a.avg_degree,
            b.avg_degree
        );
        let ha = homophily_ratio(&super::super::dcsbm(&dc));
        let hb = homophily_ratio(&dcsbm_serial(&dc));
        assert!((ha - hb).abs() < 0.05, "homophily {ha} vs {hb}");

        let sb = Sbm2Config {
            class_size: 2000,
            avg_degree: 14.0,
            homophily: 0.7,
            seed: 43,
        };
        let ha = homophily_ratio(&super::super::sbm2(&sb));
        let hb = homophily_ratio(&sbm2_serial(&sb));
        assert!((ha - hb).abs() < 0.05, "sbm2 homophily {ha} vs {hb}");

        let bc = BipartiteConfig {
            num_queries: 800,
            num_items: 1200,
            communities: 8,
            qi_degree: 6.0,
            ii_degree: 4.0,
            homophily: 0.8,
            feat_dim: 4,
            feature_noise: 0.3,
            seed: 44,
        };
        let a = super::super::bipartite(&bc).graph;
        let b = bipartite_serial(&bc).graph;
        let (ea, eb) = (a.num_edges() as f64, b.num_edges() as f64);
        assert!(
            (ea - eb).abs() / eb < 0.05,
            "bipartite edges {ea} vs {eb}"
        );
    }
}
