//! Degree-corrected stochastic block model with homophily control.
//!
//! Edges are sampled by: draw endpoint `u` proportional to its degree
//! propensity theta_u (power-law for realistic skew), then draw the
//! partner's community — the own community with probability `h`
//! (homophily), otherwise a uniformly random other community — and the
//! partner within that community again proportional to theta. This is
//! the class-compatibility matrix H of the paper's §3.2.1 generalised
//! to C communities with degree correction.
//!
//! Features are a per-community Gaussian mixture: x_v = mu_{y_v} +
//! noise * N(0, I), giving the feature/label correlation the paper's
//! theory assumes (one-hot features are the noise→0, orthogonal-mu
//! special case).
//!
//! Generation is parallel count-then-fill (see `gen::par`): the
//! edge budget is chunked per community of `u` — weighted by each
//! community's theta mass, the marginal of the old global sampler —
//! every chunk samples from its own `(seed, chunk)` RNG stream, and
//! the CSR and feature slab are filled in parallel. Output is
//! byte-identical for a fixed seed at any worker count;
//! [`super::reference::dcsbm_serial`] keeps the original serial
//! `GraphBuilder` implementation for the perf baseline.

use crate::graph::{FeatureStore, Graph};
use crate::util::rng::Rng;
use crate::util::threadpool::parallel_map;

use super::par::{
    assemble_csr, default_workers, gaussian_mixture_features, plan_chunks,
    ChunkEdges, CumSampler,
};

#[derive(Clone, Debug)]
pub struct DcsbmConfig {
    pub nodes: usize,
    pub communities: usize,
    /// Target average (undirected) degree.
    pub avg_degree: f64,
    /// Probability an edge stays within its community (h >= 0.5 for
    /// homophilic graphs; h = 1/C degenerates to Erdos-Renyi-like).
    pub homophily: f64,
    pub feat_dim: usize,
    /// Std of the within-community feature noise.
    pub feature_noise: f64,
    /// Pareto exponent for the degree propensity (0.0 = uniform; the
    /// presets use 0.8-1.2 for realistic skew).
    pub degree_exponent: f64,
    pub seed: u64,
}

// RNG stream domains: distinct per purpose (and per generator across
// the crate), so no two streams of one seed ever coincide.
const DOM_THETA: u64 = 0xDC01;
const DOM_EDGES: u64 = 0xDC02;
const DOM_MU: u64 = 0xDC03;
const DOM_FEAT: u64 = 0xDC04;

pub fn dcsbm(cfg: &DcsbmConfig) -> Graph {
    dcsbm_with_workers(cfg, default_workers())
}

/// [`dcsbm`] with an explicit worker count — the knob the determinism
/// tests and the generation bench turn; output is independent of it.
pub fn dcsbm_with_workers(cfg: &DcsbmConfig, workers: usize) -> Graph {
    assert!(cfg.communities >= 1 && cfg.nodes >= cfg.communities);
    assert!(workers >= 1);
    let n = cfg.nodes;
    let c = cfg.communities;

    // Community assignment: cyclic, so every community is non-empty.
    // Contiguity is irrelevant downstream (partitioners never see
    // labels).
    let labels: Vec<u16> = (0..n).map(|v| (v % c) as u16).collect();
    let mut members: Vec<Vec<u32>> = vec![Vec::new(); c];
    for (v, &l) in labels.iter().enumerate() {
        members[l as usize].push(v as u32);
    }

    // Degree propensities: theta ~ Pareto(exponent), capped for
    // sanity. Drawn from a dedicated stream so edge chunks never see
    // its consumption.
    let theta: Vec<f64> = {
        let mut rng = Rng::stream(cfg.seed, DOM_THETA, 0);
        (0..n)
            .map(|_| {
                if cfg.degree_exponent <= 0.0 {
                    1.0
                } else {
                    let u = 1.0 - rng.f64();
                    u.powf(-cfg.degree_exponent).min(100.0)
                }
            })
            .collect()
    };
    let per_comm: Vec<CumSampler> = parallel_map(c, workers.min(c), |cc| {
        CumSampler::new(
            &members[cc]
                .iter()
                .map(|&v| theta[v as usize])
                .collect::<Vec<_>>(),
        )
    });

    // Chunk the edge budget by the community of `u`, each community
    // weighted by its theta mass — exactly the marginal under which
    // the serial reference's global sampler lands in that community.
    let target = (n as f64 * cfg.avg_degree / 2.0) as usize;
    let weights: Vec<f64> = per_comm.iter().map(|s| s.total()).collect();
    let chunks = plan_chunks(target, &weights);

    let lists: Vec<ChunkEdges> = parallel_map(chunks.len(), workers, |i| {
        let (cu, target) = (chunks[i].group, chunks[i].target);
        let mut rng = Rng::stream(cfg.seed, DOM_EDGES, i as u64);
        let mut pairs = Vec::with_capacity(target);
        let mut attempts = 0usize;
        let max_attempts = target * 20;
        while pairs.len() < target && attempts < max_attempts {
            attempts += 1;
            let u = members[cu][per_comm[cu].sample(&mut rng)];
            let cv = if rng.chance(cfg.homophily) || c == 1 {
                cu
            } else {
                // uniformly random *other* community
                let mut k = rng.below(c - 1);
                if k >= cu {
                    k += 1;
                }
                k
            };
            let v = members[cv][per_comm[cv].sample(&mut rng)];
            if u != v {
                pairs.push((u, v));
            }
        }
        ChunkEdges { rel: 0, pairs }
    });

    let (offsets, neighbors, rel) = assemble_csr(n, &lists, workers);

    // Per-community Gaussian feature mixture; the slab is filled in
    // parallel over fixed node blocks, one noise stream per block.
    let f = cfg.feat_dim;
    let mu: Vec<f32> = {
        let mut rng = Rng::stream(cfg.seed, DOM_MU, 0);
        (0..c * f).map(|_| rng.gaussian() as f32).collect()
    };
    let features = gaussian_mixture_features(
        n,
        f,
        &labels,
        &mu,
        |_| cfg.feature_noise,
        cfg.seed,
        DOM_FEAT,
        workers,
    );

    // Shared identity slab: trainer subgraphs induced from this graph
    // are zero-copy index views over one Arc'd allocation.
    Graph {
        offsets,
        neighbors,
        rel,
        features: FeatureStore::shared_from_vec(features, f),
        feat_dim: f,
        labels: labels.into(),
        num_classes: c,
        num_relations: 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::stats::{graph_stats, homophily_ratio};

    fn base(h: f64, seed: u64) -> DcsbmConfig {
        DcsbmConfig {
            nodes: 2000,
            communities: 8,
            avg_degree: 12.0,
            homophily: h,
            feat_dim: 8,
            feature_noise: 0.3,
            degree_exponent: 0.8,
            seed,
        }
    }

    #[test]
    fn hits_target_size() {
        let g = dcsbm(&base(0.8, 1));
        let s = graph_stats(&g);
        assert_eq!(s.num_nodes, 2000);
        // dedup loses a few percent; allow slack
        assert!(
            (s.avg_degree - 12.0).abs() < 2.0,
            "avg_degree={}",
            s.avg_degree
        );
        assert_eq!(s.feat_dim, 8);
        assert_eq!(s.num_classes, 8);
    }

    #[test]
    fn homophily_tracks_parameter() {
        let lo = homophily_ratio(&dcsbm(&base(0.5, 2)));
        let hi = homophily_ratio(&dcsbm(&base(0.95, 2)));
        assert!(hi > lo + 0.2, "lo={lo} hi={hi}");
        assert!(hi > 0.85, "hi={hi}");
    }

    #[test]
    fn deterministic_per_seed() {
        let a = dcsbm(&base(0.8, 5));
        let b = dcsbm(&base(0.8, 5));
        assert_eq!(a.neighbors, b.neighbors);
        assert!(a.features.rows_equal(&b.features, a.feat_dim));
        assert_eq!(a.features.backend(), "shared");
        let c = dcsbm(&base(0.8, 6));
        assert_ne!(a.neighbors, c.neighbors);
    }

    #[test]
    fn worker_count_does_not_change_output() {
        let cfg = base(0.8, 12);
        let one = dcsbm_with_workers(&cfg, 1);
        for workers in [2, 4] {
            let w = dcsbm_with_workers(&cfg, workers);
            assert_eq!(one.offsets, w.offsets, "workers={workers}");
            assert_eq!(one.neighbors, w.neighbors, "workers={workers}");
            assert!(one.features.rows_equal(&w.features, one.feat_dim));
        }
    }

    #[test]
    fn degree_skew_with_exponent() {
        let uniform = dcsbm(&DcsbmConfig { degree_exponent: 0.0, ..base(0.8, 7) });
        let skewed = dcsbm(&DcsbmConfig { degree_exponent: 1.2, ..base(0.8, 7) });
        let max_u = (0..uniform.num_nodes()).map(|v| uniform.degree(v)).max().unwrap();
        let max_s = (0..skewed.num_nodes()).map(|v| skewed.degree(v)).max().unwrap();
        assert!(max_s > max_u * 2, "max_u={max_u} max_s={max_s}");
    }

    #[test]
    fn features_cluster_by_community() {
        use crate::graph::stats::{l2_distance, mean_feature};
        let g = dcsbm(&base(0.8, 9));
        let c0: Vec<u32> = (0..g.num_nodes() as u32)
            .filter(|&v| g.labels[v as usize] == 0)
            .collect();
        let c1: Vec<u32> = (0..g.num_nodes() as u32)
            .filter(|&v| g.labels[v as usize] == 1)
            .collect();
        let inter = l2_distance(&mean_feature(&g, &c0), &mean_feature(&g, &c1));
        // two independent Gaussian means in 8-d: expected distance ~ sqrt(16)=4
        assert!(inter > 1.0, "communities not separated: {inter}");
    }
}
