//! Degree-corrected stochastic block model with homophily control.
//!
//! Edges are sampled by: draw endpoint `u` proportional to its degree
//! propensity theta_u (power-law for realistic skew), then draw the
//! partner's community — the own community with probability `h`
//! (homophily), otherwise a uniformly random other community — and the
//! partner within that community again proportional to theta. This is
//! the class-compatibility matrix H of the paper's §3.2.1 generalised
//! to C communities with degree correction.
//!
//! Features are a per-community Gaussian mixture: x_v = mu_{y_v} +
//! noise * N(0, I), giving the feature/label correlation the paper's
//! theory assumes (one-hot features are the noise→0, orthogonal-mu
//! special case).

use crate::graph::{FeatureStore, Graph, GraphBuilder};
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct DcsbmConfig {
    pub nodes: usize,
    pub communities: usize,
    /// Target average (undirected) degree.
    pub avg_degree: f64,
    /// Probability an edge stays within its community (h >= 0.5 for
    /// homophilic graphs; h = 1/C degenerates to Erdos-Renyi-like).
    pub homophily: f64,
    pub feat_dim: usize,
    /// Std of the within-community feature noise.
    pub feature_noise: f64,
    /// Pareto exponent for the degree propensity (0.0 = uniform; the
    /// presets use 0.8-1.2 for realistic skew).
    pub degree_exponent: f64,
    pub seed: u64,
}

/// Weighted sampler over a fixed weight vector via cumulative sums.
struct CumSampler {
    cum: Vec<f64>,
}

impl CumSampler {
    fn new(weights: &[f64]) -> CumSampler {
        let mut cum = Vec::with_capacity(weights.len());
        let mut acc = 0.0;
        for &w in weights {
            acc += w;
            cum.push(acc);
        }
        CumSampler { cum }
    }

    fn total(&self) -> f64 {
        *self.cum.last().unwrap_or(&0.0)
    }

    fn sample(&self, rng: &mut Rng) -> usize {
        let x = rng.f64() * self.total();
        match self
            .cum
            .binary_search_by(|c| c.partial_cmp(&x).unwrap())
        {
            Ok(i) => i,
            Err(i) => i.min(self.cum.len() - 1),
        }
    }
}

pub fn dcsbm(cfg: &DcsbmConfig) -> Graph {
    assert!(cfg.communities >= 1 && cfg.nodes >= cfg.communities);
    let mut rng = Rng::new(cfg.seed);
    let n = cfg.nodes;
    let c = cfg.communities;

    // Community assignment: contiguous equal-size ranges, then a light
    // shuffle of boundaries via random residual assignment. Contiguity
    // is irrelevant downstream (partitioners never see labels).
    let labels: Vec<u16> = (0..n).map(|v| (v % c) as u16).collect();
    let mut members: Vec<Vec<u32>> = vec![Vec::new(); c];
    for (v, &l) in labels.iter().enumerate() {
        members[l as usize].push(v as u32);
    }

    // Degree propensities: theta ~ Pareto(exponent) capped for sanity.
    let theta: Vec<f64> = (0..n)
        .map(|_| {
            if cfg.degree_exponent <= 0.0 {
                1.0
            } else {
                let u = 1.0 - rng.f64();
                u.powf(-cfg.degree_exponent).min(100.0)
            }
        })
        .collect();

    let global = CumSampler::new(&theta);
    let per_comm: Vec<CumSampler> = members
        .iter()
        .map(|ms| {
            CumSampler::new(
                &ms.iter().map(|&v| theta[v as usize]).collect::<Vec<_>>(),
            )
        })
        .collect();

    let target_edges = (n as f64 * cfg.avg_degree / 2.0) as usize;
    let mut b = GraphBuilder::new(n);
    let mut attempts = 0usize;
    let max_attempts = target_edges * 20;
    while b.num_pending() < target_edges && attempts < max_attempts {
        attempts += 1;
        let u = global.sample(&mut rng) as u32;
        let cu = labels[u as usize] as usize;
        let cv = if rng.chance(cfg.homophily) || c == 1 {
            cu
        } else {
            // uniformly random *other* community
            let mut k = rng.below(c - 1);
            if k >= cu {
                k += 1;
            }
            k
        };
        let v = members[cv][per_comm[cv].sample(&mut rng)];
        if u != v {
            b.add_edge(u, v);
        }
    }
    let mut g = b.build();

    // Per-community Gaussian feature mixture.
    let f = cfg.feat_dim;
    let mut mu = vec![0.0f32; c * f];
    for cc in 0..c {
        for d in 0..f {
            mu[cc * f + d] = rng.gaussian() as f32;
        }
    }
    let mut features = vec![0.0f32; n * f];
    for v in 0..n {
        let cc = labels[v] as usize;
        for d in 0..f {
            features[v * f + d] = mu[cc * f + d]
                + cfg.feature_noise as f32 * rng.gaussian() as f32;
        }
    }

    // Shared identity slab: trainer subgraphs induced from this graph
    // are zero-copy index views over one Arc'd allocation.
    g.features = FeatureStore::shared_from_vec(features, f);
    g.feat_dim = f;
    g.labels = labels;
    g.num_classes = c;
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::stats::{graph_stats, homophily_ratio};

    fn base(h: f64, seed: u64) -> DcsbmConfig {
        DcsbmConfig {
            nodes: 2000,
            communities: 8,
            avg_degree: 12.0,
            homophily: h,
            feat_dim: 8,
            feature_noise: 0.3,
            degree_exponent: 0.8,
            seed,
        }
    }

    #[test]
    fn hits_target_size() {
        let g = dcsbm(&base(0.8, 1));
        let s = graph_stats(&g);
        assert_eq!(s.num_nodes, 2000);
        // dedup loses a few percent; allow slack
        assert!(
            (s.avg_degree - 12.0).abs() < 2.0,
            "avg_degree={}",
            s.avg_degree
        );
        assert_eq!(s.feat_dim, 8);
        assert_eq!(s.num_classes, 8);
    }

    #[test]
    fn homophily_tracks_parameter() {
        let lo = homophily_ratio(&dcsbm(&base(0.5, 2)));
        let hi = homophily_ratio(&dcsbm(&base(0.95, 2)));
        assert!(hi > lo + 0.2, "lo={lo} hi={hi}");
        assert!(hi > 0.85, "hi={hi}");
    }

    #[test]
    fn deterministic_per_seed() {
        let a = dcsbm(&base(0.8, 5));
        let b = dcsbm(&base(0.8, 5));
        assert_eq!(a.neighbors, b.neighbors);
        assert!(a.features.rows_equal(&b.features, a.feat_dim));
        assert_eq!(a.features.backend(), "shared");
        let c = dcsbm(&base(0.8, 6));
        assert_ne!(a.neighbors, c.neighbors);
    }

    #[test]
    fn degree_skew_with_exponent() {
        let uniform = dcsbm(&DcsbmConfig { degree_exponent: 0.0, ..base(0.8, 7) });
        let skewed = dcsbm(&DcsbmConfig { degree_exponent: 1.2, ..base(0.8, 7) });
        let max_u = (0..uniform.num_nodes()).map(|v| uniform.degree(v)).max().unwrap();
        let max_s = (0..skewed.num_nodes()).map(|v| skewed.degree(v)).max().unwrap();
        assert!(max_s > max_u * 2, "max_u={max_u} max_s={max_s}");
    }

    #[test]
    fn features_cluster_by_community() {
        use crate::graph::stats::{l2_distance, mean_feature};
        let g = dcsbm(&base(0.8, 9));
        let c0: Vec<u32> = (0..g.num_nodes() as u32)
            .filter(|&v| g.labels[v as usize] == 0)
            .collect();
        let c1: Vec<u32> = (0..g.num_nodes() as u32)
            .filter(|&v| g.labels[v as usize] == 1)
            .collect();
        let inter = l2_distance(&mean_feature(&g, &c0), &mean_feature(&g, &c1));
        // two independent Gaussian means in 8-d: expected distance ~ sqrt(16)=4
        assert!(inter > 1.0, "communities not separated: {inter}");
    }
}
