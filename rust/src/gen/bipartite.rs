//! Bipartite query-item generator (E-comm substitute).
//!
//! Two node populations — queries `[0, num_queries)` and items
//! `[num_queries, n)` — with typed edges:
//!   rel 0: query-item association (the prediction target relation)
//!   rel 1: item-item correlation
//! Items carry community structure (think product categories);
//! queries attach to items of one home community with probability
//! `homophily`. The sampler expands these two undirected types into
//! the 4 directional relations (forward + inverse) the RGCN artifacts
//! expect, matching the paper's "4 bases = total forward and inverse
//! relations" setup.

use crate::graph::{FeatureStore, Graph, GraphBuilder};
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct BipartiteConfig {
    pub num_queries: usize,
    pub num_items: usize,
    pub communities: usize,
    /// Average query-item edges per query.
    pub qi_degree: f64,
    /// Average item-item edges per item.
    pub ii_degree: f64,
    /// P(edge partner drawn from own community).
    pub homophily: f64,
    pub feat_dim: usize,
    pub feature_noise: f64,
    pub seed: u64,
}

/// Result carries the type boundary the samplers need.
pub struct BipartiteGraph {
    pub graph: Graph,
    /// Nodes `< boundary` are queries, the rest items.
    pub boundary: u32,
}

pub fn bipartite(cfg: &BipartiteConfig) -> BipartiteGraph {
    let nq = cfg.num_queries;
    let ni = cfg.num_items;
    let n = nq + ni;
    let c = cfg.communities;
    let mut rng = Rng::new(cfg.seed);

    // Community per node: queries inherit a "home" community too.
    let labels: Vec<u16> = (0..n).map(|v| (v % c) as u16).collect();
    let item_members: Vec<Vec<u32>> = {
        let mut m = vec![Vec::new(); c];
        for v in nq..n {
            m[labels[v] as usize].push(v as u32);
        }
        m
    };

    let mut b = GraphBuilder::new(n);
    let pick_item = |rng: &mut Rng, home: usize| -> u32 {
        let cc = if rng.chance(cfg.homophily) || c == 1 {
            home
        } else {
            let mut k = rng.below(c - 1);
            if k >= home {
                k += 1;
            }
            k
        };
        let ms = &item_members[cc];
        ms[rng.below(ms.len())]
    };

    // query-item edges
    let qi_total = (nq as f64 * cfg.qi_degree) as usize;
    for _ in 0..qi_total {
        let q = rng.below(nq);
        let i = pick_item(&mut rng, labels[q] as usize);
        b.add_rel_edge(q as u32, i, 0);
    }
    // item-item edges
    let ii_total = (ni as f64 * cfg.ii_degree / 2.0) as usize;
    for _ in 0..ii_total {
        let u = nq + rng.below(ni);
        let v = pick_item(&mut rng, labels[u] as usize);
        if u as u32 != v {
            b.add_rel_edge(u as u32, v, 1);
        }
    }

    let mut g = b.build();
    // Gaussian mixture features per community; queries noisier (they
    // are "BERT embeddings of query text" in the paper's setting).
    let f = cfg.feat_dim;
    let mut mu = vec![0.0f32; c * f];
    for x in mu.iter_mut() {
        *x = rng.gaussian() as f32;
    }
    let mut features = vec![0.0f32; n * f];
    for v in 0..n {
        let cc = labels[v] as usize;
        let noise = if v < nq {
            cfg.feature_noise * 1.5
        } else {
            cfg.feature_noise
        };
        for d in 0..f {
            features[v * f + d] =
                mu[cc * f + d] + noise as f32 * rng.gaussian() as f32;
        }
    }
    g.features = FeatureStore::shared_from_vec(features, f);
    g.feat_dim = f;
    g.labels = labels;
    g.num_classes = c;
    g.num_relations = 2;
    BipartiteGraph { graph: g, boundary: nq as u32 }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> BipartiteConfig {
        BipartiteConfig {
            num_queries: 400,
            num_items: 600,
            communities: 6,
            qi_degree: 6.0,
            ii_degree: 4.0,
            homophily: 0.8,
            feat_dim: 8,
            feature_noise: 0.3,
            seed: 11,
        }
    }

    #[test]
    fn respects_bipartite_structure() {
        let bg = bipartite(&cfg());
        let g = &bg.graph;
        assert_eq!(g.num_nodes(), 1000);
        assert_eq!(bg.boundary, 400);
        for q in 0..400usize {
            let rels = g.rels_of(q).unwrap();
            for (k, &v) in g.neighbors_of(q).iter().enumerate() {
                // queries only connect to items, via rel 0
                assert!(v >= 400, "query-query edge {q}-{v}");
                assert_eq!(rels[k], 0);
            }
        }
    }

    #[test]
    fn item_item_edges_typed() {
        let bg = bipartite(&cfg());
        let g = &bg.graph;
        let mut seen_ii = 0;
        for u in 400..1000usize {
            let rels = g.rels_of(u).unwrap();
            for (k, &v) in g.neighbors_of(u).iter().enumerate() {
                if v >= 400 {
                    assert_eq!(rels[k], 1);
                    seen_ii += 1;
                } else {
                    assert_eq!(rels[k], 0);
                }
            }
        }
        assert!(seen_ii > 0);
    }

    #[test]
    fn deterministic() {
        let a = bipartite(&cfg());
        let b = bipartite(&cfg());
        assert_eq!(a.graph.neighbors, b.graph.neighbors);
        assert_eq!(a.graph.rel, b.graph.rel);
    }
}
