//! Bipartite query-item generator (E-comm substitute).
//!
//! Two node populations — queries `[0, num_queries)` and items
//! `[num_queries, n)` — with typed edges:
//!   rel 0: query-item association (the prediction target relation)
//!   rel 1: item-item correlation
//! Items carry community structure (think product categories);
//! queries attach to items of one home community with probability
//! `homophily`. The sampler expands these two undirected types into
//! the 4 directional relations (forward + inverse) the RGCN artifacts
//! expect, matching the paper's "4 bases = total forward and inverse
//! relations" setup.
//!
//! Generation is parallel count-then-fill (`gen::par`): the two
//! type blocks are chunked separately (each chunk samples one
//! relation from its own `(seed, chunk)` stream) and the typed CSR is
//! assembled without a builder or global re-sort. Output is
//! byte-identical for a fixed seed at any worker count.

use crate::graph::{FeatureStore, Graph};
use crate::util::rng::Rng;
use crate::util::threadpool::parallel_map;

use super::par::{
    assemble_csr, default_workers, gaussian_mixture_features, plan_chunks,
    ChunkEdges,
};

#[derive(Clone, Debug)]
pub struct BipartiteConfig {
    pub num_queries: usize,
    pub num_items: usize,
    pub communities: usize,
    /// Average query-item edges per query.
    pub qi_degree: f64,
    /// Average item-item edges per item.
    pub ii_degree: f64,
    /// P(edge partner drawn from own community).
    pub homophily: f64,
    pub feat_dim: usize,
    pub feature_noise: f64,
    pub seed: u64,
}

/// Result carries the type boundary the samplers need.
pub struct BipartiteGraph {
    pub graph: Graph,
    /// Nodes `< boundary` are queries, the rest items.
    pub boundary: u32,
}

const DOM_EDGES: u64 = 0xB1A0;
const DOM_MU: u64 = 0xB1A1;
const DOM_FEAT: u64 = 0xB1A2;

pub fn bipartite(cfg: &BipartiteConfig) -> BipartiteGraph {
    bipartite_with_workers(cfg, default_workers())
}

/// [`bipartite`] with an explicit worker count; output is independent
/// of it.
pub fn bipartite_with_workers(
    cfg: &BipartiteConfig,
    workers: usize,
) -> BipartiteGraph {
    let nq = cfg.num_queries;
    let ni = cfg.num_items;
    let n = nq + ni;
    let c = cfg.communities;
    assert!(c >= 1 && ni >= c && workers >= 1);

    // Community per node: queries inherit a "home" community too.
    let labels: Vec<u16> = (0..n).map(|v| (v % c) as u16).collect();
    let item_members: Vec<Vec<u32>> = {
        let mut m = vec![Vec::new(); c];
        for v in nq..n {
            m[labels[v] as usize].push(v as u32);
        }
        m
    };

    let pick_item = |rng: &mut Rng, home: usize| -> u32 {
        let cc = if rng.chance(cfg.homophily) || c == 1 {
            home
        } else {
            let mut k = rng.below(c - 1);
            if k >= home {
                k += 1;
            }
            k
        };
        let ms = &item_members[cc];
        ms[rng.below(ms.len())]
    };

    // Two type blocks, chunked separately: group 0 = query-item edges
    // (rel 0), group 1 = item-item (rel 1). The type of a chunk is a
    // pure function of its position in the plan.
    let qi_total = (nq as f64 * cfg.qi_degree) as usize;
    let ii_total = (ni as f64 * cfg.ii_degree / 2.0) as usize;
    let qi_chunks = plan_chunks(qi_total, &[1.0]);
    let n_qi = qi_chunks.len();
    let mut chunks = qi_chunks;
    chunks.extend(plan_chunks(ii_total, &[1.0]));

    let lists: Vec<ChunkEdges> = parallel_map(chunks.len(), workers, |i| {
        let target = chunks[i].target;
        let rel = (i >= n_qi) as u8;
        let mut rng = Rng::stream(cfg.seed, DOM_EDGES, i as u64);
        let mut pairs = Vec::with_capacity(target);
        if rel == 0 {
            for _ in 0..target {
                let q = rng.below(nq);
                let it = pick_item(&mut rng, labels[q] as usize);
                pairs.push((q as u32, it));
            }
        } else {
            for _ in 0..target {
                let u = nq + rng.below(ni);
                let v = pick_item(&mut rng, labels[u] as usize);
                if u as u32 != v {
                    pairs.push((u as u32, v));
                }
            }
        }
        ChunkEdges { rel, pairs }
    });

    let (offsets, neighbors, rel) = assemble_csr(n, &lists, workers);

    // Gaussian mixture features per community; queries noisier (they
    // are "BERT embeddings of query text" in the paper's setting).
    let f = cfg.feat_dim;
    let mu: Vec<f32> = {
        let mut rng = Rng::stream(cfg.seed, DOM_MU, 0);
        (0..c * f).map(|_| rng.gaussian() as f32).collect()
    };
    let features = gaussian_mixture_features(
        n,
        f,
        &labels,
        &mu,
        |v| {
            if v < nq {
                cfg.feature_noise * 1.5
            } else {
                cfg.feature_noise
            }
        },
        cfg.seed,
        DOM_FEAT,
        workers,
    );

    let graph = Graph {
        offsets,
        neighbors,
        rel,
        features: FeatureStore::shared_from_vec(features, f),
        feat_dim: f,
        labels: labels.into(),
        num_classes: c,
        num_relations: 2,
    };
    BipartiteGraph { graph, boundary: nq as u32 }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> BipartiteConfig {
        BipartiteConfig {
            num_queries: 400,
            num_items: 600,
            communities: 6,
            qi_degree: 6.0,
            ii_degree: 4.0,
            homophily: 0.8,
            feat_dim: 8,
            feature_noise: 0.3,
            seed: 11,
        }
    }

    #[test]
    fn respects_bipartite_structure() {
        let bg = bipartite(&cfg());
        let g = &bg.graph;
        assert_eq!(g.num_nodes(), 1000);
        assert_eq!(bg.boundary, 400);
        for q in 0..400usize {
            let rels = g.rels_of(q).unwrap();
            for (k, &v) in g.neighbors_of(q).iter().enumerate() {
                // queries only connect to items, via rel 0
                assert!(v >= 400, "query-query edge {q}-{v}");
                assert_eq!(rels[k], 0);
            }
        }
    }

    #[test]
    fn item_item_edges_typed() {
        let bg = bipartite(&cfg());
        let g = &bg.graph;
        let mut seen_ii = 0;
        for u in 400..1000usize {
            let rels = g.rels_of(u).unwrap();
            for (k, &v) in g.neighbors_of(u).iter().enumerate() {
                if v >= 400 {
                    assert_eq!(rels[k], 1);
                    seen_ii += 1;
                } else {
                    assert_eq!(rels[k], 0);
                }
            }
        }
        assert!(seen_ii > 0);
    }

    #[test]
    fn deterministic() {
        let a = bipartite(&cfg());
        let b = bipartite(&cfg());
        assert_eq!(a.graph.neighbors, b.graph.neighbors);
        assert_eq!(a.graph.rel, b.graph.rel);
    }

    #[test]
    fn worker_count_does_not_change_output() {
        let one = bipartite_with_workers(&cfg(), 1);
        let four = bipartite_with_workers(&cfg(), 4);
        assert_eq!(one.graph.offsets, four.graph.offsets);
        assert_eq!(one.graph.neighbors, four.graph.neighbors);
        assert_eq!(one.graph.rel, four.graph.rel);
        assert!(one
            .graph
            .features
            .rows_equal(&four.graph.features, one.graph.feat_dim));
    }
}
