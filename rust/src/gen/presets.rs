//! Named dataset presets emulating the paper's four benchmarks, with
//! on-disk caching (`data/<name>.bin`) so generation runs once.
//!
//! Sizes are scaled to this testbed (single CPU core) while keeping the
//! *relative* characteristics of Table 1: Reddit is the densest, the
//! citation graphs are sparser and larger, E-comm is bipartite and
//! heterogeneous. `--quick` variants divide node counts for smoke runs.
//!
//! Generation runs the parallel count-then-fill generators on all
//! available cores, and `RTMA_MMAP=1` reopens the cache fully
//! memory-mapped — generate a big preset once, cache it, and train on
//! machines where even the CSR exceeds RAM.

use std::path::PathBuf;

use crate::graph::{split_links, Graph, LinkSplit};
use crate::util::rng::Rng;

use super::{bipartite, dcsbm, BipartiteConfig, DcsbmConfig};

/// A generated dataset ready for distributed training.
pub struct Preset {
    pub name: String,
    /// Full graph (before link-split removal).
    pub graph: Graph,
    /// Train graph + held-out edges + fixed negatives.
    pub split: LinkSplit,
    /// Bipartite boundary (queries < boundary); 0 for homogeneous.
    pub boundary: u32,
}

pub fn preset_names() -> &'static [&'static str] {
    &["reddit-sim", "citation-sim", "mag-sim", "ecomm-sim"]
}

fn scale(x: usize, quick: bool) -> usize {
    if quick {
        (x / 8).max(64)
    } else {
        x
    }
}

/// Generate (or load from cache) a named dataset.
///
/// `eval_edges` held-out edges per split and `negatives` fixed
/// candidates per edge parameterise MRR evaluation (the paper uses
/// 1000 negatives; benches default lower for the CPU budget).
pub fn load_preset(
    name: &str,
    quick: bool,
    eval_edges: usize,
    negatives: usize,
    seed: u64,
) -> anyhow::Result<Preset> {
    let (graph, boundary) = cached_graph(name, quick, seed)?;
    let split = split_links(&graph, eval_edges, negatives, seed ^ 0x51EE_7ED5_EED5_0001);
    Ok(Preset { name: name.to_string(), graph, split, boundary })
}

/// On-disk cache location for a preset graph (`data/<name>.bin`,
/// keyed by quick-scaling and seed). Public so out-of-crate smoke
/// checks (the CI cache round trip) can reopen exactly the file a
/// `load_preset` call produced.
pub fn cache_path(name: &str, quick: bool, seed: u64) -> PathBuf {
    let q = if quick { ".quick" } else { "" };
    PathBuf::from("data").join(format!("{name}{q}.s{seed}.bin"))
}

/// `RTMA_MMAP=1` opts cache opens into [`crate::graph::io::load_mapped`]:
/// the *whole* graph — CSR offsets/neighbors/rel/labels and the
/// feature slab alike — is served straight from the page cache, so a
/// preset bigger than RAM in any dimension still loads. Default stays
/// the heap loader (heap CSR + a Shared feature slab).
fn use_mmap() -> bool {
    std::env::var("RTMA_MMAP").is_ok_and(|v| v == "1")
}

fn cached_graph(
    name: &str,
    quick: bool,
    seed: u64,
) -> anyhow::Result<(Graph, u32)> {
    let boundary = bipartite_boundary(name, quick);
    let path = cache_path(name, quick, seed);
    if path.exists() {
        if use_mmap() {
            match crate::graph::io::load_mapped(&path) {
                Ok(g) => return Ok((g, boundary)),
                Err(e) if crate::graph::io::is_mappable_layout(&path) => {
                    // The layout is already mappable, so regenerating
                    // cannot help — mmap is unavailable in this
                    // environment (non-unix, filesystem without mmap).
                    // Heap-load the same cache, loudly.
                    crate::telemetry::info(
                        "gen",
                        "mmap_fallback",
                        &[],
                        format_args!(
                            "RTMA_MMAP=1: cannot map {} ({e:#}); \
                             falling back to the in-memory shared slab",
                            path.display()
                        ),
                    );
                    if let Ok(g) = crate::graph::io::load(&path) {
                        return Ok((g, boundary));
                    }
                }
                // Legacy (v1) or corrupt cache: NO silent heap
                // fallback — that would load the full slab into RAM
                // forever, the exact thing the opt-in avoids. Fall
                // through to regenerate + re-save, which upgrades the
                // cache to the mappable RTMAGRF2 layout.
                Err(e) => crate::telemetry::info(
                    "gen",
                    "mmap_regen",
                    &[],
                    format_args!(
                        "RTMA_MMAP=1: cannot map {}: {e:#}; \
                         regenerating the cache in the mappable layout",
                        path.display()
                    ),
                ),
            }
        } else if let Ok(g) = crate::graph::io::load(&path) {
            return Ok((g, boundary));
        }
    }
    let g = generate(name, quick, seed)?;
    let saved = crate::graph::io::save(&g, &path).is_ok(); // best-effort
    if saved && use_mmap() {
        // Re-open through the cache so a first run under RTMA_MMAP=1
        // actually maps the file it just wrote.
        match crate::graph::io::load_mapped(&path) {
            Ok(m) => return Ok((m, boundary)),
            Err(e) => crate::telemetry::info(
                "gen",
                "mmap_fallback",
                &[],
                format_args!(
                    "RTMA_MMAP=1: mmap failed after save ({e:#}); \
                     continuing with the in-memory shared slab"
                ),
            ),
        }
    }
    Ok((g, boundary))
}

fn bipartite_boundary(name: &str, quick: bool) -> u32 {
    if name == "ecomm-sim" {
        scale(12_000, quick) as u32
    } else {
        0
    }
}

fn generate(name: &str, quick: bool, seed: u64) -> anyhow::Result<Graph> {
    let mut rng = Rng::new(seed);
    let jitter = rng.next_u64();
    Ok(match name {
        // Reddit: small but dense (paper: 233k nodes, avg degree ~984 —
        // scaled to avg degree 40 here), strong communities.
        "reddit-sim" => dcsbm(&DcsbmConfig {
            nodes: scale(24_000, quick),
            communities: 50,
            avg_degree: 40.0,
            homophily: 0.85,
            feat_dim: 64,
            feature_noise: 0.6,
            degree_exponent: 0.6,
            seed: jitter,
        }),
        // ogbl-citation2: larger, sparse, moderate homophily.
        "citation-sim" => dcsbm(&DcsbmConfig {
            nodes: scale(60_000, quick),
            communities: 100,
            avg_degree: 10.0,
            homophily: 0.75,
            feat_dim: 64,
            feature_noise: 0.8,
            degree_exponent: 1.0,
            seed: jitter,
        }),
        // MAG240M-P: the "massive" benchmark — largest node count and
        // the strongest degree skew.
        "mag-sim" => dcsbm(&DcsbmConfig {
            nodes: scale(120_000, quick),
            communities: 150,
            avg_degree: 12.0,
            homophily: 0.8,
            feat_dim: 64,
            feature_noise: 0.7,
            degree_exponent: 1.1,
            seed: jitter,
        }),
        // E-comm: bipartite, heterogeneous.
        "ecomm-sim" => {
            bipartite(&BipartiteConfig {
                num_queries: scale(12_000, quick),
                num_items: scale(18_000, quick),
                communities: 40,
                qi_degree: 8.0,
                ii_degree: 5.0,
                homophily: 0.8,
                feat_dim: 64,
                feature_noise: 0.5,
                seed: jitter,
            })
            .graph
        }
        other => anyhow::bail!("unknown preset {other:?}"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_presets_generate_quick() {
        for name in preset_names() {
            let p = load_preset(name, true, 20, 8, 3).unwrap();
            assert!(p.graph.num_nodes() > 0, "{name}");
            assert!(p.graph.num_edges() > 0, "{name}");
            assert_eq!(p.graph.feat_dim, 64, "{name}");
            assert_eq!(p.split.val.len(), 20);
            assert_eq!(p.split.val_negatives[0].len(), 8);
            if *name == "ecomm-sim" {
                assert!(p.boundary > 0);
                assert!(p.graph.rel.is_some());
            } else {
                assert_eq!(p.boundary, 0);
            }
        }
    }

    #[test]
    fn cache_roundtrip_consistent() {
        let _ = std::fs::remove_file(cache_path("reddit-sim", true, 4));
        let a = load_preset("reddit-sim", true, 10, 4, 4).unwrap();
        // second load hits the cache
        let b = load_preset("reddit-sim", true, 10, 4, 4).unwrap();
        assert_eq!(a.graph.neighbors, b.graph.neighbors);
        assert_eq!(a.split.val, b.split.val);
    }

    #[test]
    fn unknown_preset_errors() {
        assert!(load_preset("nope", true, 1, 1, 1).is_err());
    }
}
