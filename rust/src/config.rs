//! Typed experiment configuration.
//!
//! A [`RunConfig`] fully determines one distributed-training run:
//! dataset preset, model variant, training approach, trainer count,
//! time budget ΔT_train, aggregation interval ρ (ΔT_int), evaluation
//! shape and the failure/heterogeneity drills. Configs round-trip
//! through JSON (`util::json`) so benches can persist exactly what ran.

use crate::model::AggregateOp;
use crate::partition::Scheme;
use crate::util::json::Json;

/// The training approaches compared throughout the paper (§4.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Approach {
    /// RandomTMA: randomized node partition + TMA.
    RandomTma,
    /// SuperTMA: randomized super-node partition + TMA.
    SuperTma { num_clusters: usize },
    /// PSGD-PA: min-cut (N = M) partition + periodic averaging
    /// (enhanced with time-based aggregation, as in the paper's §4.1).
    PsgdPa,
    /// LLCG: PSGD-PA + server-side global correction steps.
    Llcg { correction_steps: usize },
    /// Global Graph Sampling: full-graph access per trainer +
    /// synchronous per-step gradient averaging (idealised DistDGL).
    Ggs,
}

impl Approach {
    pub fn name(&self) -> &'static str {
        match self {
            Approach::RandomTma => "RandomTMA",
            Approach::SuperTma { .. } => "SuperTMA",
            Approach::PsgdPa => "PSGD-PA",
            Approach::Llcg { .. } => "LLCG",
            Approach::Ggs => "GGS",
        }
    }

    /// The partition scheme this approach uses for trainer data
    /// (GGS gives every trainer the full graph — no partition).
    pub fn scheme(&self) -> Option<Scheme> {
        match self {
            Approach::RandomTma => Some(Scheme::Random),
            Approach::SuperTma { num_clusters } => {
                Some(Scheme::Super { num_clusters: *num_clusters })
            }
            Approach::PsgdPa | Approach::Llcg { .. } => Some(Scheme::MinCut),
            Approach::Ggs => None,
        }
    }

    /// Parse "RandomTMA" / "SuperTMA" / "PSGD-PA" / "LLCG" / "GGS".
    pub fn parse(s: &str, num_clusters: usize) -> Option<Approach> {
        match s.to_ascii_lowercase().as_str() {
            "randomtma" | "random" => Some(Approach::RandomTma),
            "supertma" | "super" => {
                Some(Approach::SuperTma { num_clusters })
            }
            "psgd-pa" | "psgdpa" | "psgd" => Some(Approach::PsgdPa),
            "llcg" => Some(Approach::Llcg { correction_steps: 4 }),
            "ggs" => Some(Approach::Ggs),
            _ => None,
        }
    }

    /// All five approaches with the paper's default settings scaled to
    /// this testbed (paper: N = 15000 on ~10^5..10^8-node graphs; the
    /// driver scales N to the generated graph size).
    pub fn all(num_clusters: usize) -> Vec<Approach> {
        vec![
            Approach::RandomTma,
            Approach::SuperTma { num_clusters },
            Approach::PsgdPa,
            Approach::Llcg { correction_steps: 4 },
            Approach::Ggs,
        ]
    }
}

/// Full specification of one run.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub dataset: String,
    pub quick: bool,
    /// Model variant from the AOT manifest, e.g. "gcn_mlp".
    pub variant: String,
    /// Kernel implementation: "pallas" (default) or "jnp". Only
    /// meaningful on the PJRT backend (picks the artifact flavour).
    pub impl_name: String,
    /// Compute backend override: "" keeps the manifest/env selection
    /// (`runtime::manifest::resolve_backend`), "native" or "pjrt"
    /// force one. This is the `--backend` CLI flag's landing spot and
    /// the top of the precedence chain (manifest < RTMA_BACKEND <
    /// --backend) — see docs/ENGINE.md.
    pub backend: String,
    pub approach: Approach,
    /// Number of trainers M.
    pub trainers: usize,
    /// Total training time ΔT_train (seconds).
    pub train_secs: f64,
    /// Aggregation interval ρ = ΔT_int (seconds).
    pub agg_secs: f64,
    pub aggregate_op: AggregateOp,
    /// Held-out edges per split and fixed negatives per edge.
    pub eval_edges: usize,
    pub negatives: usize,
    /// Validation edges scored at each periodic evaluation (the final
    /// test evaluation uses the full split).
    pub eval_sample: usize,
    /// Trainers that fail to start (F of M; Table 6). The highest
    /// trainer ids fail unless `failed_ids` overrides the choice.
    pub failures: usize,
    /// Explicit failed trainer ids (Table 6 drops each subgraph in
    /// turn under the same assignment).
    pub failed_ids: Vec<usize>,
    /// Deterministic per-trainer slowdown factors (cycled; 1.0 = full
    /// speed) emulating heterogeneous instances (§4.3.2).
    pub slowdown: Vec<f64>,
    /// Round codec: "" keeps the default (identity unless `RTMA_CODEC`
    /// is set — the env var wins over this field; see
    /// `comm::codec::resolve` and docs/COMM.md). "delta", "f16", "i8"
    /// and "topk[:denom]" select compressed round payloads.
    pub codec: String,
    /// Where to persist the best tracked parameters after training
    /// (`serve::save_weights` format; `rtma serve --model` loads it).
    /// Empty = don't save.
    pub save_model: String,
    pub seed: u64,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            dataset: "citation-sim".into(),
            quick: false,
            variant: "gcn_mlp".into(),
            impl_name: "pallas".into(),
            backend: String::new(),
            approach: Approach::RandomTma,
            trainers: 3,
            train_secs: 30.0,
            agg_secs: 2.0,
            aggregate_op: AggregateOp::Mean,
            eval_edges: 128,
            negatives: 64,
            eval_sample: 64,
            failures: 0,
            failed_ids: Vec::new(),
            slowdown: Vec::new(),
            codec: String::new(),
            save_model: String::new(),
            seed: 17,
        }
    }
}

impl RunConfig {
    /// The set of trainer ids that fail to start.
    pub fn failed_set(&self) -> Vec<usize> {
        if !self.failed_ids.is_empty() {
            return self.failed_ids.clone();
        }
        // default: the highest F ids
        (self.trainers.saturating_sub(self.failures)..self.trainers).collect()
    }
}

impl RunConfig {
    pub fn label(&self) -> String {
        format!(
            "{}/{}/{}/M{}",
            self.dataset,
            self.variant,
            self.approach.name(),
            self.trainers
        )
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("dataset", Json::str(self.dataset.clone())),
            ("quick", Json::Bool(self.quick)),
            ("variant", Json::str(self.variant.clone())),
            ("impl", Json::str(self.impl_name.clone())),
            ("backend", Json::str(self.backend.clone())),
            ("approach", Json::str(self.approach.name())),
            (
                "num_clusters",
                match self.approach {
                    Approach::SuperTma { num_clusters } => {
                        Json::num(num_clusters as f64)
                    }
                    _ => Json::Null,
                },
            ),
            ("trainers", Json::num(self.trainers as f64)),
            ("train_secs", Json::num(self.train_secs)),
            ("agg_secs", Json::num(self.agg_secs)),
            ("eval_edges", Json::num(self.eval_edges as f64)),
            ("negatives", Json::num(self.negatives as f64)),
            ("eval_sample", Json::num(self.eval_sample as f64)),
            ("failures", Json::num(self.failures as f64)),
            ("codec", Json::str(self.codec.clone())),
            ("save_model", Json::str(self.save_model.clone())),
            ("seed", Json::num(self.seed as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn approach_parse_roundtrip() {
        for a in Approach::all(100) {
            let p = Approach::parse(a.name(), 100).unwrap();
            assert_eq!(p.name(), a.name());
        }
        assert!(Approach::parse("nope", 1).is_none());
    }

    #[test]
    fn schemes_match_paper_mapping() {
        assert_eq!(Approach::RandomTma.scheme(), Some(Scheme::Random));
        assert_eq!(Approach::PsgdPa.scheme(), Some(Scheme::MinCut));
        assert_eq!(
            Approach::Llcg { correction_steps: 1 }.scheme(),
            Some(Scheme::MinCut)
        );
        assert_eq!(Approach::Ggs.scheme(), None);
        assert_eq!(
            Approach::SuperTma { num_clusters: 7 }.scheme(),
            Some(Scheme::Super { num_clusters: 7 })
        );
    }

    #[test]
    fn config_json_has_key_fields() {
        let c = RunConfig::default();
        let j = c.to_json();
        assert_eq!(j.get("dataset").as_str(), Some("citation-sim"));
        assert_eq!(j.get("trainers").as_usize(), Some(3));
        let text = format!("{j}");
        assert!(Json::parse(&text).is_ok());
    }
}
