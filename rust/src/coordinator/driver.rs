//! The driver: one [`RunConfig`] in, one [`RunResult`] out.
//!
//! Assembles a full distributed run: dataset → fused partition +
//! per-trainer subgraph extraction ([`induce_all_except`], timed as
//! Table 3/7's prep column) → samplers → evaluator + trainer threads →
//! server loop → final test evaluation of the best validation round.

use std::sync::mpsc;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::runtime::load_backend;

use crate::comm::codec;
use crate::config::{Approach, RunConfig};
use crate::gen::{load_preset, Preset};
use crate::graph::induce_all_except;
use crate::metrics::RunResult;
use crate::model::ModelState;
use crate::partition::partition_stats_with_cuts;
use crate::runtime::Manifest;
use crate::sampler::eval::EvalBlockConfig;
use crate::sampler::{AdjMode, EvalPlan, TrainSampler, TrainSamplerConfig};
use crate::telemetry;
use crate::util::rng::Rng;

use super::evaluator::{evaluator_thread, EvalDone, EvalReq};
use super::ggs::{ggs_server, ggs_trainer, GgsTrainerSpec};
use super::kv::{Control, GlobalWeights};
use super::server::{llcg_steps, tma_server, LlcgCorrector};
use super::trainer::{tma_trainer, TrainerSpec};

/// SuperTMA cluster-count default: the paper uses N = 15,000 on graphs
/// of 10^5..10^8 nodes; scale to ~|V|/40 with a floor well above M.
pub fn default_clusters(num_nodes: usize) -> usize {
    (num_nodes / 40).max(64)
}

/// Run one experiment end to end.
pub fn run_experiment(cfg: &RunConfig) -> Result<RunResult> {
    let preset = load_preset(
        &cfg.dataset,
        cfg.quick,
        cfg.eval_edges,
        cfg.negatives,
        cfg.seed,
    )?;
    run_on_preset(cfg, &preset)
}

/// Run on an already-generated dataset (benches reuse one preset
/// across approaches so every approach sees identical data).
pub fn run_on_preset(cfg: &RunConfig, preset: &Preset) -> Result<RunResult> {
    // The builtin manifest mirrors `python/compile/model.py`'s layout,
    // so a bare checkout trains on the native backend with no
    // artifacts; an `artifacts/manifest.json` (run `make artifacts`)
    // only matters for the optional PJRT fast path.
    let mut manifest = Manifest::load_or_builtin();
    if !cfg.backend.is_empty() {
        manifest.backend = cfg.backend.clone();
    }
    let variant = manifest.variant(&cfg.variant)?.clone();
    let dims = manifest.dims;
    let train_graph = &preset.split.train;
    let m = cfg.trainers;
    let mut rng = Rng::new(cfg.seed ^ 0xC0FFEE);
    // Round codec: identity default < `cfg.codec` < `RTMA_CODEC` env.
    // Resolved once here so trainers and server agree by construction
    // (the TCP path negotiates the same choice in its handshake).
    let codec_kind = codec::resolve(&cfg.codec)?;
    if !codec_kind.is_identity() {
        telemetry::info(
            "driver",
            "codec",
            &[("codec", codec_kind.id() as f64)],
            format_args!("round codec: {}", codec_kind.name()),
        );
    }

    // ---- Partition + subgraph extraction (R1) ----------------------------
    // The timed prep step now covers the *whole* data-preparation cost
    // a deployment would pay before training starts (Table 3 / Table
    // 7's prep column): assignment, the fused parallel multi-induction
    // of every surviving trainer's subgraph, and the partition
    // statistics — which reuse the induction's per-part cut counts
    // instead of re-scanning the edge set. Feature slabs are *not*
    // copied per trainer: the generators/loader back the train graph
    // with a Shared (or Mapped) FeatureStore, and `induce_all` hands
    // each trainer an index-only view, so every trainer thread borrows
    // the one slab through its Arc and prep moves zero feature floats.
    // Failed trainers' partitions (Table 6 drills) are never
    // materialised, only cut-counted, so failure runs pay extraction
    // cost for survivors alone as before.
    let failed = cfg.failed_set();
    let t_prep = crate::telemetry::now();
    let (subgraphs, ratio_r) = match cfg.approach.scheme() {
        Some(scheme) => {
            let assignment = scheme.assign(train_graph, m, &mut rng);
            let subs =
                induce_all_except(train_graph, &assignment, m, &failed);
            let cuts: Vec<usize> =
                subs.iter().map(|s| s.cut_edges).collect();
            let stats =
                partition_stats_with_cuts(train_graph, &assignment, m, &cuts);
            (Some(subs), stats.ratio_r)
        }
        None => (None, 1.0),
    };
    let prep_secs = t_prep.elapsed().as_secs_f64();

    // ---- Per-trainer data -------------------------------------------------
    let adj_mode = AdjMode::for_encoder(&variant.encoder);
    let relations = if adj_mode == AdjMode::Relational {
        dims.relations
    } else {
        1
    };
    let sampler_cfg = TrainSamplerConfig {
        block_nodes: dims.block_nodes,
        block_edges: dims.block_edges,
        feat_dim: dims.feat_dim,
        fanouts: vec![10, 5],
        adj_mode,
        relations,
        boundary: preset.boundary,
    };

    let mut samplers: Vec<(usize, TrainSampler)> = Vec::new();
    let mut local_bytes = 0usize;
    match subgraphs {
        Some(subs) => {
            for (id, sub) in subs.into_iter().enumerate() {
                if failed.contains(&id) {
                    continue; // this trainer (and its data) is lost
                }
                local_bytes += graph_bytes(&sub.graph);
                samplers.push((
                    id,
                    TrainSampler::new(
                        sub.graph,
                        sub.global_ids,
                        sampler_cfg.clone(),
                    ),
                ));
            }
        }
        None => {
            // GGS: full training-graph access per trainer.
            for id in 0..m {
                if failed.contains(&id) {
                    continue;
                }
                let globals: Vec<u32> =
                    (0..train_graph.num_nodes() as u32).collect();
                local_bytes += graph_bytes(train_graph);
                samplers.push((
                    id,
                    TrainSampler::new(
                        train_graph.clone(),
                        globals,
                        sampler_cfg.clone(),
                    ),
                ));
            }
        }
    }
    anyhow::ensure!(!samplers.is_empty(), "all trainers failed");
    let active = samplers.len();

    // ---- Evaluation plans --------------------------------------------------
    let eval_cfg = EvalBlockConfig::new(
        dims.block_nodes,
        dims.feat_dim,
        adj_mode,
        relations,
        preset.boundary,
    );
    let nval = cfg.eval_sample.min(preset.split.val.len());
    let val_plan = EvalPlan::build(
        train_graph,
        &preset.split.val[..nval],
        &preset.split.val_negatives[..nval],
        &eval_cfg,
    );
    let test_plan = EvalPlan::build(
        train_graph,
        &preset.split.test,
        &preset.split.test_negatives,
        &eval_cfg,
    );

    // ---- Threads -----------------------------------------------------------
    let control = Arc::new(Control::new());
    // Registry baseline: RunResult.telemetry reports this run's delta,
    // not process-lifetime totals (benches run many configs in one
    // process).
    let telemetry_base = telemetry::snapshot();
    telemetry::info(
        "driver",
        "run_start",
        &[("trainers", active as f64)],
        format_args!("run start: {} ({} trainers)", cfg.label(), active),
    );
    let (msg_tx, msg_rx) = mpsc::channel();
    let (eval_req_tx, eval_req_rx) = mpsc::channel::<EvalReq>();
    let (eval_done_tx, eval_done_rx) = mpsc::channel::<EvalDone>();

    let eval_handle = {
        let manifest = manifest.clone();
        let variant_name = cfg.variant.clone();
        let impl_name = cfg.impl_name.clone();
        std::thread::spawn(move || {
            evaluator_thread(
                manifest,
                variant_name,
                impl_name,
                val_plan,
                test_plan,
                eval_req_rx,
                eval_done_tx,
            )
        })
    };

    let is_ggs = matches!(cfg.approach, Approach::Ggs);
    let mut global_txs = Vec::with_capacity(active);
    let mut handles = Vec::with_capacity(active);
    for (id, sampler) in samplers {
        // Broadcast channel: the server sends one shared Arc per
        // round, so M trainers cost M pointer clones, not M×P floats.
        let (gtx, grx) = mpsc::channel::<GlobalWeights>();
        global_txs.push(gtx);
        let slowdown = if cfg.slowdown.is_empty() {
            1.0
        } else {
            cfg.slowdown[id % cfg.slowdown.len()]
        };
        let manifest = manifest.clone();
        let variant_name = cfg.variant.clone();
        let impl_name = cfg.impl_name.clone();
        let control = control.clone();
        let tx = msg_tx.clone();
        let seed = cfg.seed;
        if is_ggs {
            handles.push(std::thread::spawn(move || {
                ggs_trainer(GgsTrainerSpec {
                    id,
                    manifest,
                    variant: variant_name,
                    impl_name,
                    sampler,
                    control,
                    rx_params: grx,
                    tx,
                    slowdown,
                    seed,
                    codec: codec_kind,
                })
            }));
        } else {
            handles.push(std::thread::spawn(move || {
                tma_trainer(TrainerSpec {
                    id,
                    manifest,
                    variant: variant_name,
                    impl_name,
                    sampler,
                    control,
                    rx_global: grx,
                    tx,
                    slowdown,
                    seed,
                    codec: codec_kind,
                })
            }));
        }
    }
    drop(msg_tx);

    // Server-side init weights (Alg 1 l. 2): one seed for all trainers.
    let init = ModelState::init(&variant, &mut Rng::new(cfg.seed ^ 0x1417))
        .params;

    // LLCG corrector (backend loaded on the server thread).
    let llcg = match llcg_steps(&cfg.approach) {
        Some(steps) => {
            let engine =
                load_backend(&manifest, &cfg.variant, &cfg.impl_name, "driver")?;
            let globals: Vec<u32> =
                (0..train_graph.num_nodes() as u32).collect();
            let sampler = TrainSampler::new(
                train_graph.clone(),
                globals,
                sampler_cfg.clone(),
            );
            let state = ModelState::init(
                &variant,
                &mut Rng::new(cfg.seed ^ 0x11C6),
            );
            Some(LlcgCorrector {
                engine,
                sampler,
                state,
                steps_per_round: steps,
                rng: Rng::new(cfg.seed ^ 0x11C7),
            })
        }
        None => None,
    };

    let outcome = if is_ggs {
        ggs_server(
            cfg,
            &control,
            init,
            &global_txs,
            &msg_rx,
            &eval_req_tx,
            &eval_done_rx,
            &manifest,
        )?
    } else {
        tma_server(
            cfg,
            &control,
            init,
            &global_txs,
            &msg_rx,
            &eval_req_tx,
            &eval_done_rx,
            llcg,
            codec_kind,
        )?
    };
    drop(global_txs); // unblock any trainer waiting on a broadcast

    let mut reports = Vec::new();
    for h in handles {
        match h.join() {
            Ok(r) => reports.push(r),
            Err(_) => anyhow::bail!("trainer thread panicked"),
        }
    }
    reports.sort_by_key(|r| r.id);

    // ---- Drain remaining evals, pick best, run the test eval ---------------
    let mut val_curve = outcome.val_curve;
    let mut best = outcome.best;
    // Every periodic request eventually yields exactly one EvalDone;
    // wait for the in-flight remainder (bounded timeout per eval). The
    // tracker keeps only the best parameters so far plus the in-flight
    // handful — not one clone per eval point — so run length no longer
    // grows server-side memory.
    while val_curve.len() < outcome.evals_sent {
        match eval_done_rx.recv_timeout(std::time::Duration::from_secs(120)) {
            Ok(done) if !done.is_final => {
                val_curve.push(crate::metrics::EvalPoint {
                    t: done.t,
                    round: done.round,
                    val_mrr: done.mrr,
                });
                best.on_result(done.round, done.mrr);
            }
            Ok(_) => {}
            Err(_) => break, // an eval errored server-side; proceed
        }
    }

    // NaN-safe best-round selection: the tracker only ever promotes
    // finite MRRs, so a diverged model scoring NaN everywhere can
    // neither panic the run nor win the argmax.
    let (best_val_mrr, best_params) = best
        .best()
        .map(|(mrr, params)| (mrr, params.clone()))
        .context(
            "no finite validation MRR — every eval returned NaN, or \
             train_secs too short for a single evaluation",
        )?;
    // Deploy hook: persist the champion parameters for `rtma serve`
    // before the final test eval consumes them.
    if !cfg.save_model.is_empty() {
        let path = std::path::Path::new(&cfg.save_model);
        crate::serve::save_weights(path, &best_params)
            .with_context(|| format!("saving model to {}", path.display()))?;
        telemetry::info(
            "driver",
            "model_saved",
            &[("params", best_params.len() as f64)],
            format_args!(
                "saved best params ({} floats, val MRR {best_val_mrr:.4}) \
                 to {}",
                best_params.len(),
                path.display()
            ),
        );
    }
    eval_req_tx.send(EvalReq::Final { params: best_params }).ok();
    drop(eval_req_tx);
    let mut test_mrr = 0.0;
    while let Ok(done) =
        eval_done_rx.recv_timeout(std::time::Duration::from_secs(300))
    {
        if done.is_final {
            test_mrr = done.mrr;
            break;
        } else {
            val_curve.push(crate::metrics::EvalPoint {
                t: done.t,
                round: done.round,
                val_mrr: done.mrr,
            });
            best.on_result(done.round, done.mrr);
        }
    }
    eval_handle.join().ok();

    // Survivor count from the authoritative control plane (not thread
    // bookkeeping): a trainer that died mid-run marked itself dead.
    let trainers_live = control.live_count(active);
    telemetry::info(
        "driver",
        "run_end",
        &[
            ("wall_secs", outcome.wall_secs),
            ("rounds", outcome.rounds as f64),
            ("live", trainers_live as f64),
        ],
        format_args!(
            "run end: {} ({} rounds, {trainers_live}/{active} \
             trainers live)",
            cfg.label(),
            outcome.rounds
        ),
    );
    telemetry::trace_counters("driver");
    telemetry::flush();

    Ok(RunResult {
        label: cfg.label(),
        val_curve,
        best_val_mrr,
        test_mrr,
        trainer_losses: reports.iter().map(|r| r.timeline.clone()).collect(),
        steps: reports.iter().map(|r| r.steps).collect(),
        ratio_r,
        prep_secs,
        local_bytes,
        wall_secs: outcome.wall_secs,
        trainers_spawned: active,
        trainers_live,
        telemetry: telemetry::snapshot().delta_since(&telemetry_base),
    })
}

/// Logical bytes a trainer's local graph occupies in the *modeled*
/// deployment (the Table 3 memory proxy): distributed trainers each
/// materialise their `|V_p| x d` feature slice, so features count in
/// full regardless of backend. The in-process Arc/mmap slab sharing is
/// a simulation artifact and deliberately NOT reflected here — see
/// `FeatureStore::heap_bytes` for what this process actually allocates
/// (the zero-copy regression tests assert on that instead).
fn graph_bytes(g: &crate::graph::Graph) -> usize {
    g.offsets.len() * 8
        + g.neighbors.len() * 4
        + g.rel.as_ref().map(|r| r.len()).unwrap_or(0)
        + g.features.num_rows(g.feat_dim) * g.feat_dim * 4
        + g.labels.len() * 2
}
