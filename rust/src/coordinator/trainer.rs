//! The TMA trainer loop — Algorithm 2.
//!
//! Each trainer thread: loads its own compute backend (native by
//! default; see `runtime::load_backend`), waits for the server's
//! initial broadcast, then loops {sample local mini-batch →
//! fused Adam step}. When the server opens an aggregation round it
//! ships its weights and blocks until the new global weights arrive
//! (local Adam moments are kept — only weights are synchronised).
//!
//! Asynchrony is the point: between rounds trainers run entirely
//! independently, so a slow trainer finishes fewer steps instead of
//! gating the others (contrast with `ggs`). A deterministic
//! `slowdown` factor emulates heterogeneous instances (§4.3.2).

use std::sync::mpsc;
use std::sync::Arc;

use crate::comm::codec::{CodecKind, RoundEncoder};
use crate::metrics::LossPoint;
use crate::model::ModelState;
use crate::runtime::{load_backend, ComputeBackend, Manifest};
use crate::sampler::TrainSampler;
use crate::telemetry::{self, metrics};
use crate::util::rng::Rng;

use super::kv::{
    Control, GlobalWeights, RoundPayload, TrainerAction, TrainerMsg,
    TrainerReport,
};

/// Everything a TMA trainer thread needs (moved into the thread).
pub struct TrainerSpec {
    pub id: usize,
    pub manifest: Manifest,
    pub variant: String,
    pub impl_name: String,
    pub sampler: TrainSampler,
    pub control: Arc<Control>,
    /// Server -> trainer weight broadcasts (first message = W[0]).
    /// Broadcasts arrive as shared [`GlobalWeights`] allocations — the
    /// server clones an `Arc` per trainer, never the parameters.
    pub rx_global: mpsc::Receiver<GlobalWeights>,
    /// Trainer -> server round messages.
    pub tx: mpsc::Sender<TrainerMsg>,
    /// Speed factor >= 1.0 (1.0 = full speed).
    pub slowdown: f64,
    pub seed: u64,
    /// Round codec for shipped weights. Identity ships
    /// [`RoundPayload::Dense`] (the pre-codec wire, bit-for-bit);
    /// anything else encodes against the last broadcast — which the
    /// server holds bit-identically, having taken the same codec
    /// round-trip before broadcasting.
    pub codec: CodecKind,
}

/// Run Algorithm 2 to completion; returns the trainer's report.
pub fn tma_trainer(spec: TrainerSpec) -> TrainerReport {
    let TrainerSpec {
        id,
        manifest,
        variant,
        impl_name,
        mut sampler,
        control,
        rx_global,
        tx,
        slowdown,
        seed,
        codec,
    } = spec;
    // Upstream encoder + last-broadcast base (codec reference point).
    // Seed forked per trainer so stochastic-rounding codecs decorrelate
    // across trainers while staying run-reproducible.
    let mut up_enc = (!codec.is_identity())
        .then(|| RoundEncoder::new(codec, seed ^ (id as u64).wrapping_mul(0x9e37_79b9)));
    let mut base: GlobalWeights = Vec::new().into();

    // Startup failures MUST mark_dead before returning: the server's
    // ready barrier counts ready + dead, so a trainer that can't come
    // up releases the barrier instead of hanging it forever.
    // `load_backend` owns the failure telemetry (one event + the
    // `engine_load_fail` counter) for every component.
    let engine = match load_backend(&manifest, &variant, &impl_name, "trainer") {
        Ok(e) => e,
        Err(_) => {
            control.mark_dead();
            return TrainerReport { id, steps: 0, timeline: Vec::new() };
        }
    };
    let mut rng = Rng::new(seed).fork(id as u64 + 1);
    let mut state = ModelState::init(engine.variant(), &mut rng);
    // Compile this role's entry point BEFORE signalling ready — the
    // server's training window opens at the ready barrier.
    if let Err(e) = engine.prepare(&["train"]) {
        telemetry::info(
            "trainer",
            "compile_failed",
            &[("trainer", id as f64)],
            format_args!("trainer {id}: compile failed: {e}"),
        );
        control.mark_dead();
        return TrainerReport { id, steps: 0, timeline: Vec::new() };
    }
    control.mark_ready();

    // Initial broadcast (Alg 2 line 5). The server sends it only after
    // every trainer is ready (engines compiled) and anchors the shared
    // run epoch right after — every LossPoint stamp below reads that
    // one clock (`Control::since_epoch`), so per-trainer curves and
    // the server's eval curve share an origin.
    match rx_global.recv() {
        Ok(w) => {
            state.set_params(&w);
            base = w;
        }
        Err(_) => return TrainerReport { id, steps: 0, timeline: Vec::new() },
    }

    let mut last_round = 0u64;
    let mut last_loss = f32::NAN;
    let mut steps = 0u64;
    let mut timeline: Vec<LossPoint> = Vec::new();

    loop {
        // Round-check BEFORE stop-check (Control::next_action): when
        // the budget expires the server opens one final collection
        // round and only then raises stop, so a trainer must ship its
        // last weights before honouring the stop flag — otherwise the
        // final aggregation silently loses this trainer's interval and
        // the server blocks on its collection timeout.
        match control.next_action(last_round) {
            TrainerAction::Ship { round } => {
                let payload = match up_enc.as_mut() {
                    None => RoundPayload::Dense(state.params.clone()),
                    Some(enc) => {
                        let mut body = Vec::new();
                        let cid =
                            enc.encode_up(&state.params, &base, &mut body);
                        RoundPayload::Encoded {
                            codec: cid,
                            n: state.params.len(),
                            body,
                        }
                    }
                };
                let msg = TrainerMsg {
                    id,
                    round,
                    payload,
                    loss: last_loss,
                    steps,
                };
                if tx.send(msg).is_err() {
                    break;
                }
                // The server broadcasts once per opened round — the
                // final one included — so this never deadlocks.
                match rx_global.recv() {
                    Ok(w) => {
                        state.set_params(&w);
                        base = w;
                    }
                    Err(_) => break, // server gone
                }
                last_round = round;
                continue;
            }
            TrainerAction::Stop => break,
            TrainerAction::Train => {}
        }

        // One local step.
        let t0 = crate::telemetry::now();
        match sampler.next_block(&mut rng) {
            None => {
                // Empty partition (e.g. after failures): stay alive to
                // participate in aggregation, but learn nothing.
                std::thread::sleep(std::time::Duration::from_millis(5));
            }
            Some(block) => match engine.train_step(&mut state, block) {
                // A non-finite loss means the optimisation diverged
                // (or the batch is corrupt): stop this trainer instead
                // of shipping NaN weights into aggregation, where one
                // bad trainer would poison the global average and the
                // run's reported metrics.
                Ok(loss) if !loss.is_finite() => {
                    telemetry::info(
                        "trainer",
                        "nonfinite_loss",
                        &[("trainer", id as f64), ("step", steps as f64)],
                        format_args!(
                            "trainer {id}: non-finite loss {loss} at step \
                             {steps}; marking dead"
                        ),
                    );
                    control.mark_dead();
                    break;
                }
                Ok(loss) => {
                    last_loss = loss;
                    steps += 1;
                    metrics().train_steps.inc();
                    metrics()
                        .step_us
                        .observe(t0.elapsed().as_micros() as u64);
                    metrics().last_loss_bits.set(loss.to_bits() as u64);
                    timeline.push(LossPoint {
                        t: control.since_epoch(),
                        loss,
                        step: steps,
                    });
                }
                Err(e) => {
                    telemetry::info(
                        "trainer",
                        "step_failed",
                        &[
                            ("trainer", id as f64),
                            ("step", steps as f64),
                        ],
                        format_args!("trainer {id}: step failed: {e}"),
                    );
                    // Tell the server this trainer will never answer
                    // another collection: later rounds size themselves
                    // to the survivors, and a round already collecting
                    // proceeds with them after its timeout instead of
                    // failing the whole run.
                    control.mark_dead();
                    break;
                }
            },
        }
        if slowdown > 1.0 {
            let extra = t0.elapsed().mul_f64(slowdown - 1.0);
            std::thread::sleep(extra);
        }
    }
    TrainerReport { id, steps, timeline }
}
