//! Evaluation: block encoding + candidate scoring → MRR, run on a
//! dedicated thread with its own engine (the paper's separate
//! evaluation process, Fig 1), so training never blocks on it.

use std::collections::HashMap;
use std::sync::mpsc;

use anyhow::Result;

use crate::runtime::{
    load_backend, score_batched, ComputeBackend, ScoreScratch,
};
use crate::sampler::{EvalPlan, Mrr};
use crate::telemetry::{self, metrics};

use super::kv::GlobalWeights;

/// Full MRR evaluation of `params` under `plan`.
///
/// Encodes every plan block, gathers target embeddings, scores the
/// (positive + negatives) candidate schedule in fixed `score_batch`
/// chunks, and folds ranks into the MRR.
pub fn evaluate_mrr(
    engine: &dyn ComputeBackend,
    plan: &EvalPlan,
    params: &[f32],
) -> Result<f64> {
    let h = engine.dims().hidden;
    // 1: target embeddings
    let mut table: HashMap<u32, Vec<f32>> =
        HashMap::with_capacity(plan.slot_of.len());
    for (bi, block) in plan.blocks.iter().enumerate() {
        let emb = engine.encode(params, block)?;
        for s in 0..plan.targets[bi] {
            let g = block.globals[s];
            table.insert(g, emb[s * h..(s + 1) * h].to_vec());
        }
    }

    // 2: score the pair schedule through the shared batched entry
    // point (runtime::score_batched) — the same path the serve
    // batcher folds queries through, so eval and serving stay
    // bit-identical by construction.
    let mut emb_u: Vec<f32> = Vec::with_capacity(plan.num_pairs() * h);
    let mut emb_v: Vec<f32> = Vec::with_capacity(plan.num_pairs() * h);
    let mut rel: Vec<i32> = Vec::with_capacity(plan.num_pairs());
    for (u, cand, r) in plan.pairs() {
        emb_u.extend_from_slice(&table[&u]);
        emb_v.extend_from_slice(&table[&cand]);
        rel.push(r);
    }
    let mut all_scores: Vec<f32> = Vec::with_capacity(plan.num_pairs());
    let mut scratch = ScoreScratch::default();
    score_batched(
        engine,
        params,
        &emb_u,
        &emb_v,
        &rel,
        &mut scratch,
        &mut all_scores,
    )?;

    // 3: fold into MRR — pairs are grouped (pos, neg_1..neg_K) per edge
    let mut mrr = Mrr::default();
    let mut cursor = 0usize;
    for negs in &plan.negatives {
        let k = negs.len();
        let pos = all_scores[cursor];
        let neg = &all_scores[cursor + 1..cursor + 1 + k];
        mrr.add(pos, neg);
        cursor += 1 + k;
    }
    Ok(mrr.value())
}

/// Request to the evaluator thread. Parameters travel as
/// [`GlobalWeights`] — the same shared allocation the round broadcast
/// uses — so enqueueing an evaluation costs an `Arc` clone, not `P`
/// floats.
pub enum EvalReq {
    /// Periodic validation eval of round `round` at time `t`.
    Periodic { round: u64, t: f64, params: GlobalWeights },
    /// Final test eval of the best weights.
    Final { params: GlobalWeights },
}

/// Response from the evaluator thread: the score alone. The evaluated
/// weights are NOT echoed back — the server side keeps the best
/// parameters so far in a [`BestTracker`] instead, fixing the old
/// O(rounds × P) `eval_params` growth (a full parameter clone per
/// eval point, retained for the whole run).
#[derive(Debug, Clone, Copy)]
pub struct EvalDone {
    pub round: u64,
    pub t: f64,
    pub mrr: f64,
    pub is_final: bool,
}

/// Best-validation-round bookkeeping with O(P) memory: the best
/// parameters so far plus the (throttled, ≤3) in-flight requests —
/// never one clone per eval point.
///
/// Evaluations are asynchronous: the server registers the parameters
/// it sends with [`Self::on_request`] and resolves them against the
/// returned score in [`Self::on_result`]. Requests are answered in
/// FIFO order by the single evaluator thread, so resolving the first
/// in-flight entry with a matching round is exact even when two
/// requests share a round number (GGS's final eval can reuse the last
/// round id). NaN-safety: a non-finite MRR (diverged model scoring
/// NaN everywhere) can never become the best round — it only retires
/// its in-flight entry.
#[derive(Debug, Default)]
pub struct BestTracker {
    inflight: Vec<(u64, GlobalWeights)>,
    best: Option<(f64, GlobalWeights)>,
}

impl BestTracker {
    pub fn new() -> BestTracker {
        BestTracker::default()
    }

    /// Register a periodic request's parameters until its score lands.
    pub fn on_request(&mut self, round: u64, params: &GlobalWeights) {
        self.inflight.push((round, params.clone()));
    }

    /// Resolve a periodic result: retire the matching in-flight entry
    /// and promote it to best if its MRR is finite and strictly
    /// better.
    pub fn on_result(&mut self, round: u64, mrr: f64) {
        let Some(i) =
            self.inflight.iter().position(|(r, _)| *r == round)
        else {
            // A result for an unregistered round: a protocol bug, but
            // never worth poisoning the run over.
            telemetry::info(
                "server",
                "eval_unknown_round",
                &[("round", round as f64)],
                format_args!(
                    "eval result for unknown round {round} dropped"
                ),
            );
            return;
        };
        let (_, params) = self.inflight.remove(i);
        let better = match &self.best {
            Some((best_mrr, _)) => mrr > *best_mrr,
            None => true,
        };
        if mrr.is_finite() && better {
            self.best = Some((mrr, params));
        }
    }

    /// Best `(val_mrr, params)` so far, if any finite eval landed.
    pub fn best(&self) -> Option<(f64, &GlobalWeights)> {
        self.best.as_ref().map(|(m, p)| (*m, p))
    }

    /// Requests awaiting a score (bounded by the eval throttle).
    pub fn inflight_len(&self) -> usize {
        self.inflight.len()
    }
}

/// Evaluator thread body: owns its engine, serves requests until the
/// request channel closes.
pub fn evaluator_thread(
    manifest: crate::runtime::Manifest,
    variant: String,
    impl_name: String,
    val_plan: EvalPlan,
    test_plan: EvalPlan,
    rx: mpsc::Receiver<EvalReq>,
    tx: mpsc::Sender<EvalDone>,
) {
    let engine = match load_backend(&manifest, &variant, &impl_name, "evaluator")
    {
        Ok(e) => e,
        Err(_) => return,
    };
    if let Err(e) = engine.prepare(&["encode", "score"]) {
        telemetry::info(
            "evaluator",
            "compile_failed",
            &[],
            format_args!("compile failed: {e}"),
        );
        return;
    }
    while let Ok(req) = rx.recv() {
        match req {
            EvalReq::Periodic { round, t, params } => {
                match evaluate_mrr(&*engine, &val_plan, &params) {
                    Ok(mrr) => {
                        metrics().evals_done.inc();
                        let _ = tx.send(EvalDone {
                            round,
                            t,
                            mrr,
                            is_final: false,
                        });
                    }
                    Err(e) => telemetry::info(
                        "evaluator",
                        "eval_failed",
                        &[("round", round as f64)],
                        format_args!("round {round}: {e}"),
                    ),
                }
            }
            EvalReq::Final { params } => {
                match evaluate_mrr(&*engine, &test_plan, &params) {
                    Ok(mrr) => {
                        metrics().evals_done.inc();
                        let _ = tx.send(EvalDone {
                            round: u64::MAX,
                            t: 0.0,
                            mrr,
                            is_final: true,
                        });
                    }
                    Err(e) => telemetry::info(
                        "evaluator",
                        "final_eval_failed",
                        &[],
                        format_args!("final: {e}"),
                    ),
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn params(tag: f32) -> GlobalWeights {
        Arc::from(vec![tag; 4])
    }

    #[test]
    fn tracker_keeps_only_best_and_inflight() {
        let mut t = BestTracker::new();
        assert!(t.best().is_none());
        let (a, b, c) = (params(1.0), params(2.0), params(3.0));
        t.on_request(1, &a);
        t.on_request(2, &b);
        assert_eq!(t.inflight_len(), 2);
        t.on_result(1, 0.4);
        t.on_result(2, 0.2); // worse: retired, not promoted
        assert_eq!(t.inflight_len(), 0);
        let (mrr, best) = t.best().unwrap();
        assert_eq!(mrr, 0.4);
        assert_eq!(best[0], 1.0);
        t.on_request(3, &c);
        t.on_result(3, 0.9);
        assert_eq!(t.best().unwrap().1[0], 3.0);
    }

    #[test]
    fn tracker_ignores_nonfinite_mrr() {
        let mut t = BestTracker::new();
        let a = params(1.0);
        t.on_request(1, &a);
        t.on_result(1, f64::NAN);
        assert!(t.best().is_none(), "NaN must never win the argmax");
        assert_eq!(t.inflight_len(), 0, "entry must still retire");
        t.on_request(2, &a);
        t.on_result(2, f64::NEG_INFINITY);
        assert!(t.best().is_none());
    }

    #[test]
    fn tracker_resolves_duplicate_rounds_fifo() {
        // GGS can evaluate the same round id twice (last periodic +
        // final-weights eval); the single evaluator thread answers in
        // FIFO order, so first-match removal pairs them correctly.
        let mut t = BestTracker::new();
        let (a, b) = (params(1.0), params(2.0));
        t.on_request(5, &a);
        t.on_request(5, &b);
        t.on_result(5, 0.9); // resolves the FIRST round-5 entry (a)
        t.on_result(5, 0.1);
        assert_eq!(t.best().unwrap().1[0], 1.0);
        assert_eq!(t.inflight_len(), 0);
    }

    #[test]
    fn tracker_shares_the_broadcast_allocation() {
        // The whole point: tracking an eval point must not clone P
        // floats.
        let mut t = BestTracker::new();
        let a = params(7.0);
        t.on_request(1, &a);
        t.on_result(1, 0.5);
        assert!(std::ptr::eq(
            t.best().unwrap().1.as_ptr(),
            a.as_ptr()
        ));
    }

    #[test]
    fn tracker_drops_unknown_round_results() {
        let mut t = BestTracker::new();
        t.on_result(9, 0.5); // must not panic or become best
        assert!(t.best().is_none());
    }
}
