//! Evaluation: block encoding + candidate scoring → MRR, run on a
//! dedicated thread with its own engine (the paper's separate
//! evaluation process, Fig 1), so training never blocks on it.

use std::collections::HashMap;
use std::sync::mpsc;

use anyhow::Result;

use crate::runtime::Engine;
use crate::sampler::{EvalPlan, Mrr};

/// Full MRR evaluation of `params` under `plan`.
///
/// Encodes every plan block, gathers target embeddings, scores the
/// (positive + negatives) candidate schedule in fixed `score_batch`
/// chunks, and folds ranks into the MRR.
pub fn evaluate_mrr(engine: &Engine, plan: &EvalPlan, params: &[f32]) -> Result<f64> {
    let h = engine.dims.hidden;
    // 1: target embeddings
    let mut table: HashMap<u32, Vec<f32>> =
        HashMap::with_capacity(plan.slot_of.len());
    for (bi, block) in plan.blocks.iter().enumerate() {
        let emb = engine.encode(params, block)?;
        for s in 0..plan.targets[bi] {
            let g = block.globals[s];
            table.insert(g, emb[s * h..(s + 1) * h].to_vec());
        }
    }

    // 2: score the pair schedule in S-sized chunks
    let s_len = engine.dims.score_batch;
    let mut emb_u = vec![0f32; s_len * h];
    let mut emb_v = vec![0f32; s_len * h];
    let mut rel = vec![0i32; s_len];
    let mut all_scores: Vec<f32> = Vec::with_capacity(plan.num_pairs());
    let mut fill = 0usize;
    let flush = |emb_u: &[f32],
                 emb_v: &[f32],
                 rel: &[i32],
                 fill: usize,
                 out: &mut Vec<f32>|
     -> Result<()> {
        let scores = engine.score(params, emb_u, emb_v, rel)?;
        out.extend_from_slice(&scores[..fill]);
        Ok(())
    };
    for (u, cand, r) in plan.pairs() {
        let eu = &table[&u];
        let ev = &table[&cand];
        emb_u[fill * h..(fill + 1) * h].copy_from_slice(eu);
        emb_v[fill * h..(fill + 1) * h].copy_from_slice(ev);
        rel[fill] = r;
        fill += 1;
        if fill == s_len {
            flush(&emb_u, &emb_v, &rel, fill, &mut all_scores)?;
            fill = 0;
        }
    }
    if fill > 0 {
        flush(&emb_u, &emb_v, &rel, fill, &mut all_scores)?;
    }

    // 3: fold into MRR — pairs are grouped (pos, neg_1..neg_K) per edge
    let mut mrr = Mrr::default();
    let mut cursor = 0usize;
    for negs in &plan.negatives {
        let k = negs.len();
        let pos = all_scores[cursor];
        let neg = &all_scores[cursor + 1..cursor + 1 + k];
        mrr.add(pos, neg);
        cursor += 1 + k;
    }
    Ok(mrr.value())
}

/// Request to the evaluator thread.
pub enum EvalReq {
    /// Periodic validation eval of round `round` at time `t`.
    Periodic { round: u64, t: f64, params: Vec<f32> },
    /// Final test eval of the best weights.
    Final { params: Vec<f32> },
}

/// Response from the evaluator thread.
#[derive(Debug, Clone)]
pub struct EvalDone {
    pub round: u64,
    pub t: f64,
    pub mrr: f64,
    pub is_final: bool,
    /// The evaluated weights (kept so the server can recover the best
    /// round's parameters for the final test evaluation).
    pub params: Vec<f32>,
}

/// Evaluator thread body: owns its engine, serves requests until the
/// request channel closes.
pub fn evaluator_thread(
    manifest: crate::runtime::Manifest,
    variant: String,
    impl_name: String,
    val_plan: EvalPlan,
    test_plan: EvalPlan,
    rx: mpsc::Receiver<EvalReq>,
    tx: mpsc::Sender<EvalDone>,
) {
    let engine = match Engine::load(&manifest, &variant, &impl_name) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("[evaluator] engine load failed: {e}");
            return;
        }
    };
    if let Err(e) = engine.prepare(&["encode", "score"]) {
        eprintln!("[evaluator] compile failed: {e}");
        return;
    }
    while let Ok(req) = rx.recv() {
        match req {
            EvalReq::Periodic { round, t, params } => {
                match evaluate_mrr(&engine, &val_plan, &params) {
                    Ok(mrr) => {
                        let _ = tx.send(EvalDone {
                            round,
                            t,
                            mrr,
                            is_final: false,
                            params,
                        });
                    }
                    Err(e) => eprintln!("[evaluator] round {round}: {e}"),
                }
            }
            EvalReq::Final { params } => {
                match evaluate_mrr(&engine, &test_plan, &params) {
                    Ok(mrr) => {
                        let _ = tx.send(EvalDone {
                            round: u64::MAX,
                            t: 0.0,
                            mrr,
                            is_final: true,
                            params,
                        });
                    }
                    Err(e) => eprintln!("[evaluator] final: {e}"),
                }
            }
        }
    }
}
