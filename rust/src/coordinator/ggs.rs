//! GGS — the fully synchronous baseline (idealised DistDGL, §4.1).
//!
//! Every trainer has unrestricted access to the full training graph;
//! each global step the server broadcasts the current weights, every
//! trainer computes a gradient on its own mini-batch via the `grad`
//! artifact, the server averages the gradients (allreduce-mean) and
//! applies one shared rust-side Adam update. The slowest trainer gates
//! every step — exactly the throughput penalty Table 3 quantifies.
//!
//! The allreduce is a streaming fold: each arriving gradient is
//! accumulated straight into a reused [`MeanAccum`] buffer — no
//! `Vec<Vec<f32>>` staging of M gradients, and no per-step buffer
//! churn beyond the one broadcast `Arc`.

use std::sync::mpsc;
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use crate::comm::codec::{self, CodecKind, RoundEncoder};
use crate::config::RunConfig;
use crate::metrics::{EvalPoint, LossPoint};
use crate::model::{Adam, MeanAccum};
use crate::runtime::{load_backend, ComputeBackend, Manifest};
use crate::sampler::TrainSampler;
use crate::telemetry::{self, metrics, Span};
use crate::util::rng::Rng;

use super::evaluator::{BestTracker, EvalDone, EvalReq};
use super::kv::{
    Control, GlobalWeights, RoundPayload, TrainerMsg, TrainerReport,
};
use super::server::ServerOutcome;

/// GGS trainer thread: gradient worker over the full graph.
pub struct GgsTrainerSpec {
    pub id: usize,
    pub manifest: Manifest,
    pub variant: String,
    pub impl_name: String,
    pub sampler: TrainSampler,
    pub control: Arc<Control>,
    pub rx_params: mpsc::Receiver<GlobalWeights>,
    pub tx: mpsc::Sender<TrainerMsg>,
    pub slowdown: f64,
    pub seed: u64,
    /// Round codec for shipped gradients. Gradients encode against a
    /// zero base ([`codec`]'s "empty base = zeros" convention): a
    /// top-k codec then ships the k largest gradient entries with
    /// error feedback, delta RLE-compresses gradient sparsity.
    pub codec: CodecKind,
}

pub fn ggs_trainer(spec: GgsTrainerSpec) -> TrainerReport {
    let GgsTrainerSpec {
        id,
        manifest,
        variant,
        impl_name,
        mut sampler,
        control,
        rx_params,
        tx,
        slowdown,
        seed,
        codec: codec_kind,
    } = spec;
    let mut up_enc = (!codec_kind.is_identity()).then(|| {
        RoundEncoder::new(
            codec_kind,
            seed ^ (id as u64).wrapping_mul(0x9e37_79b9),
        )
    });
    // Startup failures mark_dead so the server's ready barrier (which
    // counts ready + dead) releases instead of hanging forever.
    // `load_backend` owns the failure telemetry.
    let engine = match load_backend(&manifest, &variant, &impl_name, "ggs") {
        Ok(e) => e,
        Err(_) => {
            control.mark_dead();
            return TrainerReport { id, steps: 0, timeline: Vec::new() };
        }
    };
    let mut rng = Rng::new(seed).fork(id as u64 + 101);
    if let Err(e) = engine.prepare(&["grad"]) {
        telemetry::info(
            "ggs",
            "compile_failed",
            &[("trainer", id as f64)],
            format_args!("trainer {id}: compile failed: {e}"),
        );
        control.mark_dead();
        return TrainerReport { id, steps: 0, timeline: Vec::new() };
    }
    control.mark_ready();

    let mut steps = 0u64;
    let mut timeline = Vec::new();
    // Lock-step: one params broadcast per global step. Timeline stamps
    // read the shared run epoch the server anchors after the ready
    // barrier (`Control::since_epoch`).
    while let Ok(params) = rx_params.recv() {
        if control.stopped() {
            break;
        }
        let t0 = crate::telemetry::now();
        let block = match sampler.next_block(&mut rng) {
            Some(b) => b,
            None => {
                // Defensive (the full graph always has edges) — but if
                // it ever fires, the exit must still mark dead, or the
                // server waits a full collection deadline for a
                // gradient that will never come and aborts the run.
                telemetry::info(
                    "ggs",
                    "empty_sampler",
                    &[("trainer", id as f64)],
                    format_args!("trainer {id}: no block; exiting"),
                );
                control.mark_dead();
                break;
            }
        };
        match engine.grad_step(&params, block) {
            // A non-finite loss/gradient poisons the allreduce mean;
            // drop out instead of shipping it (cf. tma_trainer).
            Ok((_, loss)) if !loss.is_finite() => {
                telemetry::info(
                    "ggs",
                    "nonfinite_loss",
                    &[("trainer", id as f64), ("step", steps as f64)],
                    format_args!(
                        "trainer {id}: non-finite loss {loss} at step \
                         {steps}; marking dead"
                    ),
                );
                control.mark_dead();
                break;
            }
            Ok((grad, loss)) => {
                steps += 1;
                metrics().train_steps.inc();
                metrics()
                    .step_us
                    .observe(t0.elapsed().as_micros() as u64);
                metrics().last_loss_bits.set(loss.to_bits() as u64);
                timeline.push(LossPoint {
                    t: control.since_epoch(),
                    loss,
                    step: steps,
                });
                if slowdown > 1.0 {
                    std::thread::sleep(t0.elapsed().mul_f64(slowdown - 1.0));
                }
                let payload = match up_enc.as_mut() {
                    None => RoundPayload::Dense(grad),
                    Some(enc) => {
                        let mut body = Vec::new();
                        let cid = enc.encode_up(&grad, &[], &mut body);
                        RoundPayload::Encoded {
                            codec: cid,
                            n: grad.len(),
                            body,
                        }
                    }
                };
                let msg = TrainerMsg {
                    id,
                    round: steps,
                    payload,
                    loss,
                    steps,
                };
                if tx.send(msg).is_err() {
                    break;
                }
            }
            Err(e) => {
                telemetry::info(
                    "ggs",
                    "grad_failed",
                    &[("trainer", id as f64), ("step", steps as f64)],
                    format_args!("trainer {id}: grad failed: {e}"),
                );
                control.mark_dead();
                break;
            }
        }
    }
    TrainerReport { id, steps, timeline }
}

/// GGS server: broadcast → collect grads → allreduce-mean → Adam step.
#[allow(clippy::too_many_arguments)]
pub fn ggs_server(
    cfg: &RunConfig,
    control: &Arc<Control>,
    init_weights: Vec<f32>,
    txs: &[mpsc::Sender<GlobalWeights>],
    rx: &mpsc::Receiver<TrainerMsg>,
    eval_tx: &mpsc::Sender<EvalReq>,
    eval_rx: &mpsc::Receiver<EvalDone>,
    manifest: &Manifest,
) -> Result<ServerOutcome> {
    let registered = txs.len();
    // Ready barrier counts dead trainers too (cf. tma_server).
    let mut active = control.wait_ready(registered);
    anyhow::ensure!(active > 0, "all {registered} ggs trainers failed");
    if active < registered {
        telemetry::info(
            "ggs",
            "startup_deaths",
            &[
                ("dead", (registered - active) as f64),
                ("live", active as f64),
            ],
            format_args!(
                "{} of {registered} trainers died before ready; \
                 stepping with {active}",
                registered - active
            ),
        );
    }
    // Budget starts after the ready barrier (cf. tma_server); this is
    // also the shared timeline epoch the trainers stamp against.
    let start = control.set_epoch();
    let mut w = init_weights;
    let mut adam = Adam::new(manifest.adam, w.len());
    // Streaming allreduce state, reused across every global step.
    let mut acc = MeanAccum::new(w.len());
    let mut grad_mean: Vec<f32> = Vec::with_capacity(w.len());

    let mut val_curve = Vec::new();
    let mut best = BestTracker::new();
    let mut evals_sent = 0usize;
    let mut t_eval = crate::telemetry::now();
    let w0: GlobalWeights = w.as_slice().into();
    if eval_tx
        .send(EvalReq::Periodic { round: 0, t: 0.0, params: w0.clone() })
        .is_ok()
    {
        best.on_request(0, &w0);
        evals_sent += 1;
        metrics().evals_dispatched.inc();
    }

    let mut rounds = 0u64;
    loop {
        while let Ok(done) = eval_rx.try_recv() {
            if !done.is_final {
                val_curve.push(EvalPoint {
                    t: done.t,
                    round: done.round,
                    val_mrr: done.mrr,
                });
                best.on_result(done.round, done.mrr);
            }
        }
        if start.elapsed().as_secs_f64() >= cfg.train_secs {
            control.request_stop();
            break;
        }
        // One synchronous global step: one shared broadcast
        // allocation, M `Arc` clones.
        {
            let _sp = Span::start("ggs", "broadcast")
                .round(rounds + 1)
                .hist(&metrics().phase_broadcast);
            let wb: GlobalWeights = w.as_slice().into();
            for tx in txs {
                tx.send(wb.clone()).ok();
            }
        }
        {
            let _sp = Span::start("ggs", "collect")
                .round(rounds + 1)
                .hist(&metrics().phase_collect);
            acc.reset();
            let deadline = crate::telemetry::now() + Duration::from_secs(60);
            while acc.count() < active {
                match rx.recv_timeout(Duration::from_millis(200)) {
                    Ok(msg) => {
                        metrics().round_msgs.inc();
                        match msg.payload {
                            RoundPayload::Dense(g) => acc.add(&g),
                            RoundPayload::Encoded { codec: cid, n, body } => {
                                // Gradients encode against a zero base.
                                // Undecodable bodies can't happen (our
                                // own encoder); drop the message so the
                                // step completes with the others.
                                if let Err(e) = codec::decode_fold(
                                    cid, n, &body, &[], &mut acc,
                                ) {
                                    metrics().comm_frames_rejected.inc();
                                    telemetry::info(
                                        "ggs",
                                        "codec_drop",
                                        &[("trainer", msg.id as f64)],
                                        format_args!(
                                            "undecodable codec body from \
                                             trainer {}: {e}",
                                            msg.id
                                        ),
                                    );
                                }
                            }
                        }
                    }
                    Err(_) => {
                        // Poll wakeup: a grad failure marks the trainer
                        // dead — shrink this and every later step to
                        // the survivors instead of riding a 60 s stall
                        // into a whole-run abort. A live-but-silent
                        // trainer still trips the deadline.
                        let live = control.live_count(registered);
                        if live < active {
                            active = live;
                            anyhow::ensure!(
                                active > 0,
                                "ggs: every trainer died"
                            );
                            telemetry::info(
                                "ggs",
                                "mid_step_death",
                                &[("live", active as f64)],
                                format_args!(
                                    "a trainer died mid-step; \
                                     continuing with {active}"
                                ),
                            );
                        } else if crate::telemetry::now() >= deadline {
                            anyhow::bail!("ggs: trainer unresponsive");
                        }
                    }
                }
            }
        }
        {
            let _sp = Span::start("ggs", "aggregate")
                .round(rounds + 1)
                .hist(&metrics().phase_aggregate);
            // `None` base = zeros: sparse codec folds contribute their
            // base-relative values directly (identity path is bitwise
            // `mean_into`).
            acc.mean_with_into(None, &mut grad_mean);
            adam.step(&mut w, &grad_mean);
        }
        rounds += 1;

        // Periodic eval on the same ρ cadence as TMA for fairness.
        // Skip if the evaluator is >2 evals behind (bounds post-run
        // draining on the shared core).
        if t_eval.elapsed().as_secs_f64() >= cfg.agg_secs
            && best.inflight_len() <= 2
        {
            let _sp = Span::start("ggs", "eval_dispatch")
                .round(rounds)
                .hist(&metrics().phase_eval_dispatch);
            let params: GlobalWeights = w.as_slice().into();
            if eval_tx
                .send(EvalReq::Periodic {
                    round: rounds,
                    t: start.elapsed().as_secs_f64(),
                    params: params.clone(),
                })
                .is_ok()
            {
                best.on_request(rounds, &params);
                evals_sent += 1;
                metrics().evals_dispatched.inc();
            }
            t_eval = crate::telemetry::now();
        }
    }
    // Final eval of the last weights.
    let params: GlobalWeights = w.as_slice().into();
    if eval_tx
        .send(EvalReq::Periodic {
            round: rounds,
            t: start.elapsed().as_secs_f64(),
            params: params.clone(),
        })
        .is_ok()
    {
        best.on_request(rounds, &params);
        evals_sent += 1;
        metrics().evals_dispatched.inc();
    }
    telemetry::trace_counters("ggs");

    Ok(ServerOutcome {
        val_curve,
        best,
        rounds,
        wall_secs: start.elapsed().as_secs_f64(),
        evals_sent,
    })
}
