//! GGS — the fully synchronous baseline (idealised DistDGL, §4.1).
//!
//! Every trainer has unrestricted access to the full training graph;
//! each global step the server broadcasts the current weights, every
//! trainer computes a gradient on its own mini-batch via the `grad`
//! artifact, the server averages the gradients (allreduce-mean) and
//! applies one shared rust-side Adam update. The slowest trainer gates
//! every step — exactly the throughput penalty Table 3 quantifies.

use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::config::RunConfig;
use crate::metrics::{EvalPoint, LossPoint};
use crate::model::{mean_grads, Adam};
use crate::runtime::{Engine, Manifest};
use crate::sampler::TrainSampler;
use crate::util::rng::Rng;

use super::evaluator::{EvalDone, EvalReq};
use super::kv::{Control, TrainerMsg, TrainerReport};
use super::server::ServerOutcome;

/// GGS trainer thread: gradient worker over the full graph.
pub struct GgsTrainerSpec {
    pub id: usize,
    pub manifest: Manifest,
    pub variant: String,
    pub impl_name: String,
    pub sampler: TrainSampler,
    pub control: Arc<Control>,
    pub rx_params: mpsc::Receiver<Vec<f32>>,
    pub tx: mpsc::Sender<TrainerMsg>,
    pub slowdown: f64,
    pub seed: u64,
    pub start: Instant,
}

pub fn ggs_trainer(spec: GgsTrainerSpec) -> TrainerReport {
    let GgsTrainerSpec {
        id,
        manifest,
        variant,
        impl_name,
        mut sampler,
        control,
        rx_params,
        tx,
        slowdown,
        seed,
        start: _start,
    } = spec;
    let engine = match Engine::load(&manifest, &variant, &impl_name) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("[ggs trainer {id}] engine load failed: {e}");
            return TrainerReport { id, steps: 0, timeline: Vec::new() };
        }
    };
    let mut rng = Rng::new(seed).fork(id as u64 + 101);
    if let Err(e) = engine.prepare(&["grad"]) {
        eprintln!("[ggs trainer {id}] compile failed: {e}");
        return TrainerReport { id, steps: 0, timeline: Vec::new() };
    }
    control.mark_ready();

    let mut steps = 0u64;
    let mut timeline = Vec::new();
    let mut anchor: Option<Instant> = None;
    // Lock-step: one params broadcast per global step.
    while let Ok(params) = rx_params.recv() {
        if control.stopped() {
            break;
        }
        // Re-anchor the timeline at the first broadcast (post-compile).
        let start = *anchor.get_or_insert_with(Instant::now);
        let _ = start;
        let t0 = Instant::now();
        let block = match sampler.next_block(&mut rng) {
            Some(b) => b,
            None => break, // full graph always has edges; defensive
        };
        match engine.grad_step(&params, block) {
            Ok((grad, loss)) => {
                steps += 1;
                timeline.push(LossPoint {
                    t: start.elapsed().as_secs_f64(),
                    loss,
                    step: steps,
                });
                if slowdown > 1.0 {
                    std::thread::sleep(t0.elapsed().mul_f64(slowdown - 1.0));
                }
                let msg = TrainerMsg {
                    id,
                    round: steps,
                    weights: grad,
                    loss,
                    steps,
                };
                if tx.send(msg).is_err() {
                    break;
                }
            }
            Err(e) => {
                eprintln!("[ggs trainer {id}] grad failed: {e}");
                break;
            }
        }
    }
    TrainerReport { id, steps, timeline }
}

/// GGS server: broadcast → collect grads → allreduce-mean → Adam step.
#[allow(clippy::too_many_arguments)]
pub fn ggs_server(
    cfg: &RunConfig,
    control: &Arc<Control>,
    init_weights: Vec<f32>,
    txs: &[mpsc::Sender<Vec<f32>>],
    rx: &mpsc::Receiver<TrainerMsg>,
    eval_tx: &mpsc::Sender<EvalReq>,
    eval_rx: &mpsc::Receiver<EvalDone>,
    manifest: &Manifest,
    start: Instant,
) -> Result<ServerOutcome> {
    let active = txs.len();
    while control.ready_count() < active {
        std::thread::sleep(Duration::from_millis(5));
    }
    // Budget starts after the ready barrier (cf. tma_server).
    let _ = start;
    let start = Instant::now();
    let mut w = init_weights;
    let mut adam = Adam::new(manifest.adam, w.len());
    let mut grad_mean: Vec<f32> = Vec::new();
    let mut grads: Vec<Vec<f32>> = Vec::with_capacity(active);

    let mut val_curve = Vec::new();
    let mut eval_params = Vec::new();
    let mut evals_sent = 0usize;
    let mut t_eval = Instant::now();
    if eval_tx
        .send(EvalReq::Periodic { round: 0, t: 0.0, params: w.clone() })
        .is_ok()
    {
        evals_sent += 1;
    }

    let mut rounds = 0u64;
    loop {
        while let Ok(done) = eval_rx.try_recv() {
            if !done.is_final {
                val_curve.push(EvalPoint {
                    t: done.t,
                    round: done.round,
                    val_mrr: done.mrr,
                });
                eval_params.push(done.params);
            }
        }
        if start.elapsed().as_secs_f64() >= cfg.train_secs {
            control.request_stop();
            break;
        }
        // One synchronous global step.
        for tx in txs {
            tx.send(w.clone()).ok();
        }
        grads.clear();
        for _ in 0..active {
            match rx.recv_timeout(Duration::from_secs(60)) {
                Ok(msg) => grads.push(msg.weights),
                Err(_) => anyhow::bail!("ggs: trainer unresponsive"),
            }
        }
        mean_grads(&grads, &mut grad_mean);
        adam.step(&mut w, &grad_mean);
        rounds += 1;

        // Periodic eval on the same ρ cadence as TMA for fairness.
        // Skip if the evaluator is >2 evals behind (bounds post-run
        // draining on the shared core).
        if t_eval.elapsed().as_secs_f64() >= cfg.agg_secs
            && evals_sent - val_curve.len() <= 2
        {
            if eval_tx
                .send(EvalReq::Periodic {
                    round: rounds,
                    t: start.elapsed().as_secs_f64(),
                    params: w.clone(),
                })
                .is_ok()
            {
                evals_sent += 1;
            }
            t_eval = Instant::now();
        }
    }
    // Final eval of the last weights.
    if eval_tx
        .send(EvalReq::Periodic {
            round: rounds,
            t: start.elapsed().as_secs_f64(),
            params: w.clone(),
        })
        .is_ok()
    {
        evals_sent += 1;
    }

    Ok(ServerOutcome {
        val_curve,
        eval_params,
        rounds,
        wall_secs: start.elapsed().as_secs_f64(),
        evals_sent,
    })
}
