//! Coordination control plane — the in-process equivalent of the
//! paper's distributed key-value store (Alg 1/2's `KV[agg]`,
//! `KV[stop]`, `KV[ready]`).
//!
//! Instead of a boolean `agg` flag (which races between "server
//! collected" and "trainer re-checks"), aggregation is a monotone
//! **round counter**: the server bumps it to open round `r`; each
//! trainer that observes `round > last_seen` ships its weights exactly
//! once and blocks for the round-`r` broadcast. This gives the same
//! semantics as Alg 1/2 without a timing hole.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex, OnceLock};
use std::time::Instant;

use crate::telemetry::metrics;

/// The server→trainer broadcast payload: one shared allocation of the
/// global weights per round. Every trainer (and the evaluator request)
/// clones the `Arc`, not the `P` floats — the round data plane's
/// zero-clone contract.
pub type GlobalWeights = Arc<[f32]>;

/// Shared control block between server, trainers and evaluator.
#[derive(Debug, Default)]
pub struct Control {
    /// Monotone aggregation round (0 = no aggregation yet).
    agg_round: AtomicU64,
    /// `KV[stop]`.
    stop: AtomicBool,
    /// `KV[ready]` count.
    ready: AtomicUsize,
    /// Trainers that died before (or instead of) marking ready —
    /// engine load or compile failures. The ready barrier counts these
    /// so a failed trainer can't hang the whole run.
    dead: AtomicUsize,
    /// The run epoch: set once by the server right after the ready
    /// barrier. Every timeline stamp ([`crate::metrics::LossPoint::t`],
    /// [`crate::metrics::EvalPoint::t`]) measures from this shared
    /// instant, so curves from different trainers are directly
    /// comparable — before, each producer re-anchored its own
    /// `Instant::now()`.
    epoch: OnceLock<Instant>,
    /// Subscribers to each round's global-weight broadcast
    /// ([`Self::watch_weights`]) — the train-and-serve deploy hook.
    /// Cold path (touched once per aggregation round, not per step),
    /// so a `Mutex` is fine.
    weight_watchers: Mutex<Vec<mpsc::Sender<(u64, GlobalWeights)>>>,
}

impl Control {
    pub fn new() -> Self {
        Control::default()
    }

    pub fn open_round(&self) -> u64 {
        metrics().rounds_opened.inc();
        self.agg_round.fetch_add(1, Ordering::SeqCst) + 1
    }

    /// Fix the run epoch at `Instant::now()` (first call wins) and
    /// return it. The server calls this once, after the ready barrier
    /// — ΔT_train and every timeline stamp measure from here.
    pub fn set_epoch(&self) -> Instant {
        *self.epoch.get_or_init(crate::telemetry::now)
    }

    /// Seconds since the run epoch (0.0 before [`Self::set_epoch`]).
    pub fn since_epoch(&self) -> f64 {
        self.epoch
            .get()
            .map(|e| e.elapsed().as_secs_f64())
            .unwrap_or(0.0)
    }

    pub fn current_round(&self) -> u64 {
        self.agg_round.load(Ordering::SeqCst)
    }

    pub fn request_stop(&self) {
        self.stop.store(true, Ordering::SeqCst);
    }

    pub fn stopped(&self) -> bool {
        self.stop.load(Ordering::SeqCst)
    }

    pub fn mark_ready(&self) {
        metrics().trainer_ready_marks.inc();
        self.ready.fetch_add(1, Ordering::SeqCst);
    }

    pub fn ready_count(&self) -> usize {
        self.ready.load(Ordering::SeqCst)
    }

    /// Record a trainer that will never mark ready (engine load or
    /// compile failed, or its loop died mid-run). Counted by
    /// [`Self::wait_ready`] and by the server's per-round collection
    /// targets, so the rest of the run proceeds with the survivors
    /// instead of hanging.
    pub fn mark_dead(&self) {
        metrics().trainer_dead_marks.inc();
        self.dead.fetch_add(1, Ordering::SeqCst);
    }

    pub fn dead_count(&self) -> usize {
        self.dead.load(Ordering::SeqCst)
    }

    /// Of `total` registered trainers, how many are still live (have
    /// not marked dead). Servers size their per-round collection
    /// targets off this so a dead trainer shrinks the round to the
    /// survivors instead of failing it.
    pub fn live_count(&self, total: usize) -> usize {
        total - self.dead_count().min(total)
    }

    /// The ready barrier (Alg 1 l. 3): block until every one of
    /// `total` trainers has either marked ready or died, then return
    /// the number of live trainers. Before [`Self::mark_dead`]
    /// existed, a trainer whose `Engine::load`/`prepare` failed simply
    /// returned, and the server spun forever in
    /// `while ready_count() < total` — the ready-barrier hang.
    pub fn wait_ready(&self, total: usize) -> usize {
        loop {
            let dead = self.dead_count();
            if self.ready_count() + dead >= total {
                return total - dead.min(total);
            }
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
    }

    /// Subscribe to global-weight broadcasts: every subsequent
    /// [`Self::publish_weights`] delivers `(round, weights)` — an
    /// `Arc` clone, never a parameter copy. A running `rtma serve`
    /// instance follows one of these to swap weights live at round
    /// boundaries (docs/SERVING.md).
    pub fn watch_weights(&self) -> mpsc::Receiver<(u64, GlobalWeights)> {
        let (tx, rx) = mpsc::channel();
        self.weight_watchers.lock().unwrap().push(tx);
        rx
    }

    /// Deliver one round's global weights to every watcher, dropping
    /// the ones that hung up. Servers call this at each broadcast
    /// point; with no watchers it is two atomic ops and an empty loop.
    pub fn publish_weights(&self, round: u64, w: &GlobalWeights) {
        let mut watchers = self.weight_watchers.lock().unwrap();
        watchers.retain(|tx| tx.send((round, w.clone())).is_ok());
    }

    /// Decide a trainer's next move given the last round it served.
    ///
    /// The round check comes **before** the stop check, and the stop
    /// path re-reads the round counter, so a trainer can never exit
    /// while an open round still awaits its weights. The server opens
    /// its final collection round *before* raising `stop`
    /// (`tma_server`); with SeqCst ordering, any thread that observes
    /// the stop flag is guaranteed to also observe that final round on
    /// the re-read. Without this, a trainer that happened to poll
    /// `stop` first exited silently and the server's final collection
    /// blocked on its 60 s timeout, aggregating a subset.
    pub fn next_action(&self, last_round: u64) -> TrainerAction {
        let round = self.current_round();
        if round > last_round {
            return TrainerAction::Ship { round };
        }
        if self.stopped() {
            let round = self.current_round(); // final-round re-read
            if round > last_round {
                return TrainerAction::Ship { round };
            }
            return TrainerAction::Stop;
        }
        TrainerAction::Train
    }
}

/// What a trainer should do at the top of its loop (see
/// [`Control::next_action`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TrainerAction {
    /// Round `round` is open and unanswered: ship local weights, then
    /// block for that round's broadcast.
    Ship { round: u64 },
    /// Stop requested and no round pending: exit the loop.
    Stop,
    /// Keep taking local steps.
    Train,
}

/// A round message's weight (or gradient) payload. `Dense` is the
/// pre-codec path — the raw vector, folded bit-identically to the
/// staged reference. Non-identity codecs ship `Encoded`: the compact
/// body plus the *actual* wire encoding id
/// ([`crate::comm::codec::CODEC_DELTA`] etc.) and the decoded element
/// count, exactly what a `WeightsEnc` TCP frame carries — the
/// in-process channels and the wire stay one protocol.
#[derive(Debug, Clone)]
pub enum RoundPayload {
    Dense(Vec<f32>),
    Encoded { codec: u8, n: usize, body: Vec<u8> },
}

impl RoundPayload {
    /// Decoded element count.
    pub fn len(&self) -> usize {
        match self {
            RoundPayload::Dense(w) => w.len(),
            RoundPayload::Encoded { n, .. } => *n,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Bytes this payload would occupy on the wire (the compression
    /// the codec bought, for telemetry/debugging).
    pub fn wire_bytes(&self) -> usize {
        match self {
            RoundPayload::Dense(w) => w.len() * 4,
            RoundPayload::Encoded { body, .. } => body.len(),
        }
    }
}

/// Message a trainer ships to the server at an aggregation round (or
/// every step, for GGS where the payload carries the gradient).
#[derive(Debug, Clone)]
pub struct TrainerMsg {
    pub id: usize,
    pub round: u64,
    pub payload: RoundPayload,
    pub loss: f32,
    pub steps: u64,
}

/// Final report a trainer thread returns on join.
#[derive(Debug, Clone)]
pub struct TrainerReport {
    pub id: usize,
    pub steps: u64,
    pub timeline: Vec<crate::metrics::LossPoint>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn rounds_are_monotone() {
        let c = Control::new();
        assert_eq!(c.current_round(), 0);
        assert_eq!(c.open_round(), 1);
        assert_eq!(c.open_round(), 2);
        assert_eq!(c.current_round(), 2);
    }

    #[test]
    fn stop_and_ready() {
        let c = Control::new();
        assert!(!c.stopped());
        c.request_stop();
        assert!(c.stopped());
        c.mark_ready();
        c.mark_ready();
        assert_eq!(c.ready_count(), 2);
    }

    #[test]
    fn next_action_orders_round_before_stop() {
        let c = Control::new();
        assert_eq!(c.next_action(0), TrainerAction::Train);
        c.open_round();
        assert_eq!(c.next_action(0), TrainerAction::Ship { round: 1 });
        assert_eq!(c.next_action(1), TrainerAction::Train);
        // Budget expiry: final round opens, then stop is raised. A
        // trainer that has not served round 2 must ship, not stop.
        c.open_round();
        c.request_stop();
        assert_eq!(c.next_action(1), TrainerAction::Ship { round: 2 });
        assert_eq!(c.next_action(2), TrainerAction::Stop);
    }

    #[test]
    fn wait_ready_counts_dead_trainers() {
        // 2 ready + 1 dead of 3: the barrier must release with 2 live
        // trainers instead of spinning on ready_count() < 3 forever.
        let c = Arc::new(Control::new());
        let c2 = c.clone();
        let (tx, rx) = std::sync::mpsc::channel();
        std::thread::spawn(move || {
            tx.send(c2.wait_ready(3)).unwrap();
        });
        c.mark_ready();
        c.mark_ready();
        assert!(
            rx.try_recv().is_err(),
            "barrier released before the last trainer resolved"
        );
        c.mark_dead();
        let live = rx
            .recv_timeout(std::time::Duration::from_secs(10))
            .expect("ready barrier hung on a dead trainer");
        assert_eq!(live, 2);
        assert_eq!(c.dead_count(), 1);
        assert_eq!(c.live_count(3), 2);
        assert_eq!(c.live_count(0), 0, "live_count never underflows");
    }

    #[test]
    fn wait_ready_all_dead_returns_zero() {
        let c = Control::new();
        c.mark_dead();
        c.mark_dead();
        assert_eq!(c.wait_ready(2), 0);
    }

    #[test]
    fn epoch_is_shared_first_call_wins_and_monotone() {
        let c = Control::new();
        assert_eq!(c.since_epoch(), 0.0, "unset epoch reads 0");
        let e1 = c.set_epoch();
        let e2 = c.set_epoch(); // second call must not re-anchor
        assert_eq!(e1, e2);
        let a = c.since_epoch();
        std::thread::sleep(std::time::Duration::from_millis(2));
        let b = c.since_epoch();
        assert!(a >= 0.0 && b >= a, "epoch clock went backwards");
    }

    #[test]
    fn weight_watchers_share_the_broadcast_allocation() {
        let c = Control::new();
        c.publish_weights(1, &Arc::from(vec![0.0f32; 2])); // no watchers: no-op
        let rx_a = c.watch_weights();
        let rx_b = c.watch_weights();
        let w: GlobalWeights = Arc::from(vec![1.0f32, 2.0]);
        c.publish_weights(2, &w);
        let (ra, wa) = rx_a.try_recv().unwrap();
        let (rb, wb) = rx_b.try_recv().unwrap();
        assert_eq!((ra, rb), (2, 2));
        // Arc clones of the same slab — never a parameter copy.
        assert!(std::ptr::eq(wa.as_ptr(), w.as_ptr()));
        assert!(std::ptr::eq(wb.as_ptr(), w.as_ptr()));
        // A hung-up watcher is dropped, the live one keeps receiving.
        drop(rx_a);
        c.publish_weights(3, &w);
        assert_eq!(rx_b.try_recv().unwrap().0, 3);
    }

    #[test]
    fn round_visible_across_threads() {
        let c = Arc::new(Control::new());
        let c2 = c.clone();
        let h = std::thread::spawn(move || {
            while c2.current_round() == 0 {
                std::hint::spin_loop();
            }
            c2.current_round()
        });
        std::thread::sleep(std::time::Duration::from_millis(5));
        c.open_round();
        assert_eq!(h.join().unwrap(), 1);
    }
}
