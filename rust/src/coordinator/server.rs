//! The TMA server loop — Algorithm 1.
//!
//! Every ΔT_int: open an aggregation round, collect the `M` local
//! weight vectors, apply the aggregation operator φ (plain averaging
//! by default — the paper's finding), optionally run LLCG's global
//! correction on the server, broadcast the new global weights, and
//! enqueue an asynchronous validation evaluation. Stops at ΔT_train,
//! then the driver selects t* = argmax val-MRR and evaluates test MRR.
//!
//! **Round data plane (PR 5):** the round path is zero-clone and O(P)
//! per round however many trainers report. Collection is a streaming
//! fold — each arriving [`TrainerMsg`] is accumulated in place into
//! one pre-sized [`MeanAccum`] buffer (no `Vec<Vec<f32>>` staging),
//! deduped by trainer id. Broadcast ships one [`GlobalWeights`]
//! (`Arc<[f32]>`) allocation per round; trainers and the evaluator
//! request clone the `Arc`, never the `P` floats. `InverseLoss` needs
//! every loss before any vector can be scaled, so it stays on the
//! staged path (ablation bench only). The streamed aggregate is
//! locked bit-for-bit against the staged reference
//! ([`collect_round_staged`] + [`aggregate`]) by
//! `tests/aggregation.rs`.
//!
//! Shutdown ordering matters: at budget expiry the final round is
//! opened **before** the stop flag is raised, pairing with the
//! round-before-stop check in [`super::kv::Control::next_action`] so
//! every live trainer ships its last-interval weights instead of
//! racing out of the loop (and the final collection never has to ride
//! its timeout). Collections also validate each message's round stamp
//! ([`collect_round`]) so a stale message can't be aggregated into the
//! wrong round, and the ready barrier counts dead trainers
//! ([`super::kv::Control::wait_ready`]) so a failed engine can't hang
//! the run before it starts.

use std::sync::mpsc;
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use crate::comm::codec::{self, CodecKind, RoundEncoder};
use crate::config::{Approach, RunConfig};
use crate::metrics::EvalPoint;
use crate::model::{aggregate, AggregateOp, MeanAccum, ModelState};
use crate::runtime::{Backend, ComputeBackend};
use crate::sampler::TrainSampler;
use crate::telemetry::{self, metrics, Span};
use crate::util::rng::Rng;

use super::evaluator::{BestTracker, EvalDone, EvalReq};
use super::kv::{Control, GlobalWeights, RoundPayload, TrainerMsg};

/// LLCG's server-side global correction state: an engine + sampler
/// over the *full* training graph and a persistent optimizer state.
pub struct LlcgCorrector {
    pub engine: Backend,
    pub sampler: TrainSampler,
    pub state: ModelState,
    pub steps_per_round: usize,
    pub rng: Rng,
}

impl LlcgCorrector {
    /// Run the correction: overwrite server weights into the local
    /// state, take a few global mini-batch steps, return the result.
    pub fn correct(&mut self, weights: &[f32]) -> Result<Vec<f32>> {
        self.state.set_params(weights);
        for _ in 0..self.steps_per_round {
            if let Some(block) = self.sampler.next_block(&mut self.rng) {
                self.engine.train_step(&mut self.state, block)?;
            }
        }
        Ok(self.state.params.clone())
    }
}

/// Outcome of the server loop.
pub struct ServerOutcome {
    pub val_curve: Vec<EvalPoint>,
    /// Best validation weights so far + in-flight eval bookkeeping.
    /// Replaces the old `eval_params` log, which retained a full
    /// parameter clone per eval point for the whole run.
    pub best: BestTracker,
    pub rounds: u64,
    pub wall_secs: f64,
    /// Periodic evaluation requests issued (for driver-side draining).
    pub evals_sent: usize,
}

/// Run Algorithm 1 until ΔT_train elapses. `txs` holds one broadcast
/// channel per registered trainer (M - F under failure drills).
///
/// With a non-identity `codec_kind`, trainer payloads arrive
/// [`RoundPayload::Encoded`] and are folded against the current
/// `w_global` base without materialising the dense vectors
/// ([`codec::decode_fold`]); broadcasts take a codec round-trip
/// (encode against the outgoing base, decode, broadcast the decode)
/// so the server and every trainer hold bit-identical bases *and* the
/// quantization a lossy codec would apply on the wire is applied
/// honestly in-process too.
#[allow(clippy::too_many_arguments)]
pub fn tma_server(
    cfg: &RunConfig,
    control: &Arc<Control>,
    init_weights: Vec<f32>,
    txs: &[mpsc::Sender<GlobalWeights>],
    rx: &mpsc::Receiver<TrainerMsg>,
    eval_tx: &mpsc::Sender<EvalReq>,
    eval_rx: &mpsc::Receiver<EvalDone>,
    mut llcg: Option<LlcgCorrector>,
    codec_kind: CodecKind,
) -> Result<ServerOutcome> {
    // Downstream (broadcast) encoder: one per server, seeded off the
    // run seed so quantizing codecs are reproducible.
    let mut down_enc = (!codec_kind.is_identity())
        .then(|| RoundEncoder::new(codec_kind, cfg.seed ^ 0xb07a_dc0d));
    let mut codec_body: Vec<u8> = Vec::new();
    let registered = txs.len();
    // Ready barrier (Alg 1 l. 3-5): wait until every trainer either
    // compiled its engine and marked ready or died trying — a trainer
    // that fails startup can no longer hang the barrier; the run
    // proceeds with the survivors (failure-drill semantics).
    let live = control.wait_ready(registered);
    anyhow::ensure!(live > 0, "all {registered} trainers failed to start");
    if live < registered {
        telemetry::info(
            "server",
            "startup_deaths",
            &[("dead", (registered - live) as f64), ("live", live as f64)],
            format_args!(
                "{} of {registered} trainers died before ready; \
                 training with {live}",
                registered - live
            ),
        );
    }
    // Broadcast W[0]: one shared allocation, M `Arc` clones. Weight
    // watchers (a co-located `rtma serve`, docs/SERVING.md) get the
    // same rounds the trainers do — deploy points are exactly the
    // round boundaries.
    let mut w_global: GlobalWeights = init_weights.into();
    for tx in txs {
        tx.send(w_global.clone()).ok();
    }
    control.publish_weights(0, &w_global);
    // T_start = now (Alg 1 l. 6): the budget starts after the ready
    // barrier + initial broadcast, excluding engine-compile startup.
    // This is also the shared run epoch every timeline stamp (trainer
    // losses, eval points) measures from — see `Control::set_epoch`.
    let start = control.set_epoch();

    let mut t_agg = crate::telemetry::now();
    #[allow(unused_assignments)]
    let mut rounds = 0u64;
    let mut val_curve = Vec::new();
    let mut best = BestTracker::new();
    let mut evals_sent = 0usize;
    // Evaluate the initial weights too (round 0 baseline).
    if eval_tx
        .send(EvalReq::Periodic {
            round: 0,
            t: start.elapsed().as_secs_f64(),
            params: w_global.clone(),
        })
        .is_ok()
    {
        best.on_request(0, &w_global);
        evals_sent += 1;
        metrics().evals_dispatched.inc();
    }

    loop {
        std::thread::sleep(Duration::from_millis(10));

        // Drain finished evaluations (asynchronous, Alg 1 l. 14).
        while let Ok(done) = eval_rx.try_recv() {
            if !done.is_final {
                val_curve.push(EvalPoint {
                    t: done.t,
                    round: done.round,
                    val_mrr: done.mrr,
                });
                best.on_result(done.round, done.mrr);
            }
        }

        if start.elapsed().as_secs_f64() >= cfg.train_secs {
            // Budget expired: open the FINAL aggregation round before
            // raising stop. Trainers re-check the round counter after
            // observing the stop flag (Control::next_action), so every
            // live trainer ships its last-interval weights instead of
            // exiting — the final collection below completes within
            // one local step rather than timing out per lost trainer.
            rounds = control.open_round();
            control.request_stop();
            break;
        }

        if t_agg.elapsed().as_secs_f64() >= cfg.agg_secs {
            rounds = control.open_round();
            // Collect W_i from every live trainer (Alg 1 l. 10),
            // folding each message into the accumulator as it lands.
            let expect = control.live_count(registered);
            anyhow::ensure!(
                expect > 0,
                "round {rounds}: every trainer died"
            );
            let collected = {
                let _sp = Span::start("server", "collect")
                    .round(rounds)
                    .hist(&metrics().phase_collect);
                collect_round_with(
                    rx,
                    &|| control.live_count(registered),
                    rounds,
                    Duration::from_secs(60),
                    cfg.aggregate_op,
                    Some(&w_global),
                )
            };
            if collected.reporters < expect {
                // A trainer died *during* the collection (step
                // failure marks dead): the target shrank within a
                // poll slice and the round completed with the
                // survivors — same semantics as the final round and
                // the ready barrier. A live-but-silent trainer is
                // still a hard error.
                let live_now = control.live_count(registered);
                anyhow::ensure!(
                    collected.reporters >= live_now
                        && collected.reporters > 0,
                    "round {rounds}: trainer unresponsive \
                     ({} of {expect} reported)",
                    collected.reporters
                );
                telemetry::info(
                    "server",
                    "mid_round_death",
                    &[
                        ("round", rounds as f64),
                        ("reporters", collected.reporters as f64),
                    ],
                    format_args!(
                        "round {rounds}: a trainer died mid-round; \
                         aggregating {} survivors",
                        collected.reporters
                    ),
                );
            }
            // φ (Alg 1 l. 12) already folded; LLCG's server-side
            // global correction runs before the broadcast.
            w_global = {
                let _sp = Span::start("server", "aggregate")
                    .round(rounds)
                    .hist(&metrics().phase_aggregate);
                let mut next =
                    collected.global.expect("non-empty round collection");
                if let Some(corr) = llcg.as_mut() {
                    next = corr.correct(&next)?;
                }
                // Codec round-trip against the outgoing base: the
                // broadcast carries exactly what a lossy codec would
                // deliver over the wire, so server and trainers hold
                // bit-identical bases for the next round's encode.
                if let Some(enc) = down_enc.as_mut() {
                    let id =
                        enc.encode_down(&next, &w_global, &mut codec_body);
                    next = codec::decode_dense(
                        id,
                        next.len(),
                        &codec_body,
                        &w_global,
                    )?;
                }
                next.into()
            };
            {
                let _sp = Span::start("server", "broadcast")
                    .round(rounds)
                    .hist(&metrics().phase_broadcast);
                for tx in txs {
                    tx.send(w_global.clone()).ok();
                }
                control.publish_weights(rounds, &w_global);
            }
            t_agg = crate::telemetry::now();
            // Async validation eval of the new global weights. Skip if
            // the evaluator is >2 evals behind (bounds the post-run
            // drain on the shared core).
            let _sp = Span::start("server", "eval_dispatch")
                .round(rounds)
                .hist(&metrics().phase_eval_dispatch);
            if best.inflight_len() <= 2
                && eval_tx
                    .send(EvalReq::Periodic {
                        round: rounds,
                        t: start.elapsed().as_secs_f64(),
                        params: w_global.clone(),
                    })
                    .is_ok()
            {
                best.on_request(rounds, &w_global);
                evals_sent += 1;
                metrics().evals_dispatched.inc();
            }
            metrics().eval_inflight.set(best.inflight_len() as u64);
        }
    }

    // Final aggregation so the last interval's work is not lost. The
    // final round was opened before `stop` was raised, so every live
    // trainer ships; the timeout is only a safety net for trainers
    // that died outright (engine failure), in which case we aggregate
    // the survivors.
    let expect = control.live_count(registered);
    let collected = {
        let _sp = Span::start("server", "collect")
            .round(rounds)
            .hist(&metrics().phase_collect);
        collect_round_with(
            rx,
            &|| control.live_count(registered),
            rounds,
            Duration::from_secs(60),
            cfg.aggregate_op,
            Some(&w_global),
        )
    };
    if collected.reporters < expect {
        telemetry::info(
            "server",
            "final_round_partial",
            &[
                ("round", rounds as f64),
                ("reporters", collected.reporters as f64),
                ("expect", expect as f64),
            ],
            format_args!(
                "final round {rounds}: {} of {expect} trainers \
                 reported (aggregating survivors)",
                collected.reporters
            ),
        );
    }
    if let Some(mut next) = collected.global {
        w_global = {
            let _sp = Span::start("server", "aggregate")
                .round(rounds)
                .hist(&metrics().phase_aggregate);
            if let Some(enc) = down_enc.as_mut() {
                let id =
                    enc.encode_down(&next, &w_global, &mut codec_body);
                next = codec::decode_dense(
                    id,
                    next.len(),
                    &codec_body,
                    &w_global,
                )?;
            }
            next.into()
        };
        let _sp = Span::start("server", "eval_dispatch")
            .round(rounds)
            .hist(&metrics().phase_eval_dispatch);
        if eval_tx
            .send(EvalReq::Periodic {
                round: rounds,
                t: start.elapsed().as_secs_f64(),
                params: w_global.clone(),
            })
            .is_ok()
        {
            best.on_request(rounds, &w_global);
            evals_sent += 1;
            metrics().evals_dispatched.inc();
        }
    }
    // Unblock trainers waiting on the final round's broadcast.
    {
        let _sp = Span::start("server", "broadcast")
            .round(rounds)
            .hist(&metrics().phase_broadcast);
        for tx in txs {
            tx.send(w_global.clone()).ok();
        }
        control.publish_weights(rounds, &w_global);
    }
    telemetry::trace_counters("server");

    Ok(ServerOutcome {
        val_curve,
        best,
        rounds,
        wall_secs: start.elapsed().as_secs_f64(),
        evals_sent,
    })
}

/// Outcome of one round's streaming collection.
pub struct RoundOutcome {
    /// φ over the deduped round messages (`None` when none arrived in
    /// time).
    pub global: Option<Vec<f32>>,
    /// Distinct trainers that reported in time.
    pub reporters: usize,
}

/// Collect up to `expect` round-`round` weight messages within
/// `deadline` and reduce them with φ **as they arrive**. Fixed-target
/// wrapper over [`collect_round_with`] (tests and the differential
/// suite use this form).
pub fn collect_round(
    rx: &mpsc::Receiver<TrainerMsg>,
    expect: usize,
    round: u64,
    deadline: Duration,
    op: AggregateOp,
) -> RoundOutcome {
    collect_round_with(rx, &|| expect, round, deadline, op, None)
}

/// Streaming round collection with a live-target callback.
///
/// Waits in ≤200 ms slices, re-polling `target()` between slices:
/// the server passes `|| control.live_count(registered)`, so a
/// trainer that dies *during* the collection (step failure →
/// `mark_dead`) shrinks the target within a slice and the round
/// completes with the survivors, instead of stalling out the full
/// deadline on a message that will never come. The deadline remains
/// the safety net for a live-but-silent trainer.
///
/// - A message stamped with a different round is *stale* — rounds are
///   collected fully before the next one opens, so it can only come
///   from a trainer that died mid-protocol or a logic bug — and is
///   dropped with a warning rather than silently attributed to the
///   wrong round's aggregation.
/// - A second message from the same trainer id is a *duplicate* and is
///   dropped too: before dedup it filled a collection slot, which both
///   skewed the aggregate toward the duplicated trainer and silently
///   evicted another trainer's weights from the round.
/// - `Mean` folds each vector straight into one pre-sized accumulator
///   (O(P) bytes per round, bit-identical to the staged reference —
///   see [`MeanAccum`]); `InverseLoss` stages, since no vector can be
///   scaled before every loss is known.
/// - [`RoundPayload::Encoded`] messages decode against `base` (the
///   broadcast the trainers encoded against); sparse codecs fold
///   base-relative ([`MeanAccum::fold_sparse`]) without materialising
///   a dense vector. `Dense` payloads never touch `base`, keeping the
///   pre-codec path bitwise intact.
///
/// Public so the shutdown-protocol regression tests and the
/// differential suite drive the exact collection path the server uses.
pub fn collect_round_with(
    rx: &mpsc::Receiver<TrainerMsg>,
    target: &dyn Fn() -> usize,
    round: u64,
    deadline: Duration,
    op: AggregateOp,
    base: Option<&[f32]>,
) -> RoundOutcome {
    const POLL: Duration = Duration::from_millis(200);
    let t0 = crate::telemetry::now();
    let mut seen: Vec<usize> = Vec::new();
    let mut acc: Option<MeanAccum> = None;
    let mut staged: Vec<Vec<f32>> = Vec::new();
    let mut losses: Vec<f32> = Vec::new();
    loop {
        if seen.len() >= target() {
            break;
        }
        let left = deadline.saturating_sub(t0.elapsed());
        if left.is_zero() {
            break; // overall deadline: return the survivors
        }
        let msg = match rx.recv_timeout(left.min(POLL)) {
            Ok(msg) => msg,
            Err(mpsc::RecvTimeoutError::Timeout) => continue,
            Err(mpsc::RecvTimeoutError::Disconnected) => break,
        };
        if msg.round != round {
            metrics().round_stale_dropped.inc();
            telemetry::info(
                "server",
                "stale_drop",
                &[("round", round as f64), ("trainer", msg.id as f64)],
                format_args!(
                    "dropping stale round-{} message from trainer \
                     {} while collecting round {round}",
                    msg.round, msg.id
                ),
            );
            continue;
        }
        if seen.contains(&msg.id) {
            metrics().round_dup_dropped.inc();
            telemetry::info(
                "server",
                "dup_drop",
                &[("round", round as f64), ("trainer", msg.id as f64)],
                format_args!(
                    "dropping duplicate round-{round} message from \
                     trainer {}",
                    msg.id
                ),
            );
            continue;
        }
        metrics().round_msgs.inc();
        seen.push(msg.id);
        losses.push(if msg.loss.is_nan() {
            f32::MAX // trainer with no batch yet
        } else {
            msg.loss
        });
        match op {
            AggregateOp::Mean => {
                let accum = acc
                    .get_or_insert_with(|| MeanAccum::new(msg.payload.len()));
                match msg.payload {
                    RoundPayload::Dense(w) => accum.add(&w),
                    RoundPayload::Encoded { codec: cid, n, body } => {
                        if let Err(e) = codec::decode_fold(
                            cid,
                            n,
                            &body,
                            base.unwrap_or(&[]),
                            accum,
                        ) {
                            // Can't-happen path: our own encoder
                            // produced the body. A partially-applied
                            // fold can leak into the aggregate here;
                            // drop the reporter so at least the round
                            // target and loss bookkeeping stay honest.
                            metrics().comm_frames_rejected.inc();
                            telemetry::info(
                                "server",
                                "codec_drop",
                                &[
                                    ("round", round as f64),
                                    ("trainer", msg.id as f64),
                                ],
                                format_args!(
                                    "round {round}: undecodable codec \
                                     body from trainer {}: {e}",
                                    msg.id
                                ),
                            );
                            seen.pop();
                            losses.pop();
                        }
                    }
                }
            }
            AggregateOp::InverseLoss => match msg.payload {
                RoundPayload::Dense(w) => staged.push(w),
                RoundPayload::Encoded { codec: cid, n, body } => {
                    match codec::decode_dense(
                        cid,
                        n,
                        &body,
                        base.unwrap_or(&[]),
                    ) {
                        Ok(w) => staged.push(w),
                        Err(e) => {
                            metrics().comm_frames_rejected.inc();
                            telemetry::info(
                                "server",
                                "codec_drop",
                                &[
                                    ("round", round as f64),
                                    ("trainer", msg.id as f64),
                                ],
                                format_args!(
                                    "round {round}: undecodable codec \
                                     body from trainer {}: {e}",
                                    msg.id
                                ),
                            );
                            seen.pop();
                            losses.pop();
                        }
                    }
                }
            },
        }
    }
    let global = match op {
        AggregateOp::Mean => acc.map(|a| a.mean_with(base)),
        AggregateOp::InverseLoss => {
            if staged.is_empty() {
                None
            } else {
                Some(aggregate(op, &staged, &losses))
            }
        }
    };
    RoundOutcome { global, reporters: seen.len() }
}

/// The pre-streaming staging collection: every weight vector is held
/// in memory until the round completes (O(M·P) bytes live at once),
/// then reduced by [`aggregate`]. Protocol-identical to
/// [`collect_round`] (round-validated, id-deduped, NaN-sanitised
/// losses); kept as the differential reference the streaming fold is
/// locked against (`tests/aggregation.rs`) and the baseline of the
/// `perf_hotpath` aggregation bench. The live server never calls this.
pub fn collect_round_staged(
    rx: &mpsc::Receiver<TrainerMsg>,
    expect: usize,
    round: u64,
    deadline: Duration,
    base: Option<&[f32]>,
) -> (Vec<Vec<f32>>, Vec<f32>) {
    let t0 = crate::telemetry::now();
    let mut ids: Vec<usize> = Vec::with_capacity(expect);
    let mut weights = Vec::with_capacity(expect);
    let mut losses = Vec::with_capacity(expect);
    while weights.len() < expect {
        let left = deadline.saturating_sub(t0.elapsed());
        match rx.recv_timeout(left) {
            Ok(msg) if msg.round == round && !ids.contains(&msg.id) => {
                ids.push(msg.id);
                losses.push(if msg.loss.is_nan() {
                    f32::MAX
                } else {
                    msg.loss
                });
                match msg.payload {
                    RoundPayload::Dense(w) => weights.push(w),
                    RoundPayload::Encoded { codec: cid, n, body } => {
                        match codec::decode_dense(
                            cid,
                            n,
                            &body,
                            base.unwrap_or(&[]),
                        ) {
                            Ok(w) => weights.push(w),
                            Err(_) => {
                                ids.pop();
                                losses.pop();
                            }
                        }
                    }
                }
            }
            Ok(msg) => telemetry::info(
                "server",
                "staged_drop",
                &[("round", round as f64), ("trainer", msg.id as f64)],
                format_args!(
                    "staged reference dropping stale/duplicate \
                     round-{} message from trainer {}",
                    msg.round, msg.id
                ),
            ),
            Err(_) => break, // timeout, or every sender hung up
        }
    }
    (weights, losses)
}

/// Helper used by the driver to pick LLCG correction settings.
pub fn llcg_steps(approach: &Approach) -> Option<usize> {
    match approach {
        Approach::Llcg { correction_steps } => Some(*correction_steps),
        _ => None,
    }
}
