//! The TMA server loop — Algorithm 1.
//!
//! Every ΔT_int: open an aggregation round, collect the `M` local
//! weight vectors, apply the aggregation operator φ (plain averaging
//! by default — the paper's finding), optionally run LLCG's global
//! correction on the server, broadcast the new global weights, and
//! enqueue an asynchronous validation evaluation. Stops at ΔT_train,
//! then the driver selects t* = argmax val-MRR and evaluates test MRR.
//!
//! Shutdown ordering matters: at budget expiry the final round is
//! opened **before** the stop flag is raised, pairing with the
//! round-before-stop check in [`super::kv::Control::next_action`] so
//! every live trainer ships its last-interval weights instead of
//! racing out of the loop (and the final collection never has to ride
//! its timeout). Collections also validate each message's round stamp
//! ([`collect_round`]) so a stale message can't be aggregated into the
//! wrong round.

use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::config::{Approach, RunConfig};
use crate::metrics::EvalPoint;
use crate::model::{aggregate, ModelState};
use crate::runtime::Engine;
use crate::sampler::TrainSampler;
use crate::util::rng::Rng;

use super::evaluator::{EvalDone, EvalReq};
use super::kv::{Control, TrainerMsg};

/// LLCG's server-side global correction state: an engine + sampler
/// over the *full* training graph and a persistent optimizer state.
pub struct LlcgCorrector {
    pub engine: Engine,
    pub sampler: TrainSampler,
    pub state: ModelState,
    pub steps_per_round: usize,
    pub rng: Rng,
}

impl LlcgCorrector {
    /// Run the correction: overwrite server weights into the local
    /// state, take a few global mini-batch steps, return the result.
    pub fn correct(&mut self, weights: &[f32]) -> Result<Vec<f32>> {
        self.state.set_params(weights);
        for _ in 0..self.steps_per_round {
            if let Some(block) = self.sampler.next_block(&mut self.rng) {
                self.engine.train_step(&mut self.state, block)?;
            }
        }
        Ok(self.state.params.clone())
    }
}

/// Outcome of the server loop.
pub struct ServerOutcome {
    pub val_curve: Vec<EvalPoint>,
    /// Weights per completed evaluation (aligned with `val_curve`).
    pub eval_params: Vec<Vec<f32>>,
    pub rounds: u64,
    pub wall_secs: f64,
    /// Periodic evaluation requests issued (for driver-side draining).
    pub evals_sent: usize,
}

/// Run Algorithm 1 until ΔT_train elapses. `active` is the number of
/// live trainers (M - F under failures).
#[allow(clippy::too_many_arguments)]
pub fn tma_server(
    cfg: &RunConfig,
    control: &Arc<Control>,
    init_weights: Vec<f32>,
    txs: &[mpsc::Sender<Vec<f32>>],
    rx: &mpsc::Receiver<TrainerMsg>,
    eval_tx: &mpsc::Sender<EvalReq>,
    eval_rx: &mpsc::Receiver<EvalDone>,
    mut llcg: Option<LlcgCorrector>,
    start: Instant,
) -> Result<ServerOutcome> {
    let active = txs.len();
    // Wait for trainers to come up, then broadcast W[0] (Alg 1 l. 3-5).
    while control.ready_count() < active {
        std::thread::sleep(Duration::from_millis(5));
    }
    let mut w_global = init_weights;
    for tx in txs {
        tx.send(w_global.clone()).ok();
    }
    // T_start = now (Alg 1 l. 6): the budget starts after the ready
    // barrier + initial broadcast, excluding engine-compile startup.
    let _ = start;
    let start = Instant::now();

    let mut t_agg = Instant::now();
    #[allow(unused_assignments)]
    let mut rounds = 0u64;
    let mut val_curve = Vec::new();
    let mut eval_params = Vec::new();
    let mut evals_sent = 0usize;
    // Evaluate the initial weights too (round 0 baseline).
    if eval_tx
        .send(EvalReq::Periodic {
            round: 0,
            t: start.elapsed().as_secs_f64(),
            params: w_global.clone(),
        })
        .is_ok()
    {
        evals_sent += 1;
    }

    loop {
        std::thread::sleep(Duration::from_millis(10));

        // Drain finished evaluations (asynchronous, Alg 1 l. 14).
        while let Ok(done) = eval_rx.try_recv() {
            if !done.is_final {
                val_curve.push(EvalPoint {
                    t: done.t,
                    round: done.round,
                    val_mrr: done.mrr,
                });
                eval_params.push(done.params);
            }
        }

        if start.elapsed().as_secs_f64() >= cfg.train_secs {
            // Budget expired: open the FINAL aggregation round before
            // raising stop. Trainers re-check the round counter after
            // observing the stop flag (Control::next_action), so every
            // live trainer ships its last-interval weights instead of
            // exiting — the final collection below completes within
            // one local step rather than timing out per lost trainer.
            rounds = control.open_round();
            control.request_stop();
            break;
        }

        if t_agg.elapsed().as_secs_f64() >= cfg.agg_secs {
            rounds = control.open_round();
            // Collect W_i from every live trainer (Alg 1 l. 10).
            let (weights, losses) =
                collect_round(rx, active, rounds, Duration::from_secs(60));
            if weights.len() < active {
                anyhow::bail!("round {rounds}: trainer unresponsive");
            }
            // φ (Alg 1 l. 12).
            w_global = aggregate(cfg.aggregate_op, &weights, &losses);
            // LLCG: server-side global correction before broadcast.
            if let Some(corr) = llcg.as_mut() {
                w_global = corr.correct(&w_global)?;
            }
            for tx in txs {
                tx.send(w_global.clone()).ok();
            }
            t_agg = Instant::now();
            // Async validation eval of the new global weights. Skip if
            // the evaluator is >2 evals behind (bounds the post-run
            // drain on the shared core).
            if evals_sent - val_curve.len() <= 2 {
            if eval_tx
                .send(EvalReq::Periodic {
                    round: rounds,
                    t: start.elapsed().as_secs_f64(),
                    params: w_global.clone(),
                })
                .is_ok()
            {
                evals_sent += 1;
            }
            }
        }
    }

    // Final aggregation so the last interval's work is not lost. The
    // final round was opened before `stop` was raised, so every live
    // trainer ships; the timeout is only a safety net for trainers
    // that died outright (engine failure), in which case we aggregate
    // the survivors.
    let (weights, losses) =
        collect_round(rx, active, rounds, Duration::from_secs(60));
    if weights.len() < active {
        eprintln!(
            "[server] final round {rounds}: {} of {active} trainers \
             reported (aggregating survivors)",
            weights.len()
        );
    }
    if !weights.is_empty() {
        w_global = aggregate(cfg.aggregate_op, &weights, &losses);
        if eval_tx
            .send(EvalReq::Periodic {
                round: rounds,
                t: start.elapsed().as_secs_f64(),
                params: w_global.clone(),
            })
            .is_ok()
        {
            evals_sent += 1;
        }
    }
    // Unblock trainers waiting on the final round's broadcast.
    for tx in txs {
        tx.send(w_global.clone()).ok();
    }

    Ok(ServerOutcome {
        val_curve,
        eval_params,
        rounds,
        wall_secs: start.elapsed().as_secs_f64(),
        evals_sent,
    })
}

/// Collect up to `active` round-`round` weight messages within
/// `deadline`, returning the weight vectors and sanitised losses.
///
/// A message stamped with a different round is *stale* — rounds are
/// collected fully before the next one opens, so it can only come from
/// a trainer that died mid-protocol or a logic bug — and is dropped
/// with a warning rather than silently attributed to the wrong round's
/// aggregation. Public so the shutdown-protocol regression tests drive
/// the exact collection path the server uses.
pub fn collect_round(
    rx: &mpsc::Receiver<TrainerMsg>,
    active: usize,
    round: u64,
    deadline: Duration,
) -> (Vec<Vec<f32>>, Vec<f32>) {
    let t0 = Instant::now();
    let mut weights = Vec::with_capacity(active);
    let mut losses = Vec::with_capacity(active);
    while weights.len() < active {
        let left = deadline.saturating_sub(t0.elapsed());
        match rx.recv_timeout(left) {
            Ok(msg) if msg.round == round => {
                losses.push(if msg.loss.is_nan() {
                    f32::MAX // trainer with no batch yet
                } else {
                    msg.loss
                });
                weights.push(msg.weights);
            }
            Ok(msg) => eprintln!(
                "[server] dropping stale round-{} message from trainer \
                 {} while collecting round {round}",
                msg.round, msg.id
            ),
            Err(_) => break, // timeout, or every sender hung up
        }
    }
    (weights, losses)
}

/// Helper used by the driver to pick LLCG correction settings.
pub fn llcg_steps(approach: &Approach) -> Option<usize> {
    match approach {
        Approach::Llcg { correction_steps } => Some(*correction_steps),
        _ => None,
    }
}
