//! The TMA coordinator — the paper's system contribution (Alg 1 + 2).
//!
//! Topology (in-process mode): one **server** (the calling thread), `M`
//! **trainer** threads and one **evaluator** thread. Each trainer owns
//! its own PJRT engine and its local partition subgraph — trainers
//! never touch the global graph (the paper's restricted-access
//! setting). Coordination state lives in [`kv::Control`], the stand-in
//! for the paper's distributed key-value store; weights move over
//! channels.
//!
//! - [`server`] — the time-based aggregation loop (Alg 1): every
//!   ΔT_int collect local weights, apply φ, (LLCG only:) run global
//!   correction steps, broadcast, enqueue an async validation eval.
//! - [`trainer`] — the local loop (Alg 2): sample a local mini-batch,
//!   run the fused AOT train step, honour aggregation rounds.
//! - [`ggs`] — the synchronous baseline: per-step gradient allreduce.
//! - [`evaluator`] — encode blocks + score candidates → MRR, off the
//!   training path (the paper's separate evaluation processes).
//! - [`driver`] — assembles a full run from a [`crate::config::RunConfig`]:
//!   partition → samplers → threads → result.

pub mod driver;
pub mod evaluator;
pub mod ggs;
pub mod kv;
pub mod server;
pub mod trainer;

pub use driver::run_experiment;
pub use evaluator::evaluate_mrr;
