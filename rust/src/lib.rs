//! `random_tma` — reproduction of *"Simplifying Distributed Neural Network
//! Training on Massive Graphs: Randomized Partitions Improve Model
//! Aggregation"* (RandomTMA / SuperTMA, 2023).
//!
//! Three-layer architecture:
//! - **L3 (this crate)**: the distributed coordinator — graph substrates,
//!   partitioners, samplers, the Time-based Model Aggregation (TMA) server
//!   and trainers, baselines (PSGD-PA, LLCG, GGS), evaluation and benches.
//! - **L2 (python/compile/model.py)**: JAX link-prediction models
//!   (GCN/SAGE/MLP/RGCN encoders, MLP/DistMult decoders) lowered AOT to
//!   HLO text in `artifacts/`.
//! - **L1 (python/compile/kernels/)**: Pallas kernels for the compute
//!   hot-spots (tiled matmul, fused GCN aggregation, decoder scoring).
//!
//! Python never runs on the training path: the rust binary loads the AOT
//! artifacts through PJRT (`runtime`) and drives everything else natively.

pub mod util;

pub mod telemetry;

pub mod config;
pub mod graph;
pub mod gen;
pub mod partition;
pub mod sampler;
pub mod runtime;
pub mod model;
pub mod coordinator;
pub mod comm;
pub mod metrics;
pub mod benchkit;
pub mod serve;
