//! Deterministic PRNGs: splitmix64 (seeding) + xoshiro256++ (stream).
//!
//! Every stochastic component in the crate (graph generation, random
//! partitions, samplers, failure drills) takes an explicit [`Rng`] so
//! whole experiments are reproducible from a single `u64` seed, which
//! the benches fan out per run with [`Rng::fork`].

/// splitmix64 step — used to expand seeds and as a standalone mixer.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256++ PRNG (Blackman & Vigna), seeded via splitmix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Gaussian from Box-Muller.
    gauss_spare: Option<f64>,
}

impl Rng {
    /// Create from a 64-bit seed (splitmix64-expanded into the state).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Deterministic named stream for parallel generation: mixes
    /// `(seed, domain, chunk)` through three splitmix64 rounds, so a
    /// chunk's stream depends only on those values — never on thread
    /// count or scheduling — and streams don't collide across seeds,
    /// domains or chunk ids (each round fully avalanches its input).
    /// The generators give every work chunk its own stream; the
    /// determinism property tests lock in both properties.
    pub fn stream(seed: u64, domain: u64, chunk: u64) -> Rng {
        let mut sm = seed;
        let a = splitmix64(&mut sm);
        let mut sm = a ^ domain;
        let b = splitmix64(&mut sm);
        let mut sm = b ^ chunk;
        Rng::new(splitmix64(&mut sm))
    }

    /// Derive an independent child stream (stable: depends only on the
    /// parent state and `tag`, not on call order elsewhere).
    pub fn fork(&self, tag: u64) -> Rng {
        let mut sm = self.s[0] ^ self.s[2] ^ tag.wrapping_mul(0xA24B_AED4_963E_E407);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Bernoulli(p).
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller (with spare caching).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        // Avoid log(0).
        let u1 = loop {
            let u = self.f64();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.gauss_spare = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (Floyd's algorithm).
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        let k = k.min(n);
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.below(j + 1);
            let pick = if chosen.contains(&t) { j } else { t };
            chosen.insert(pick);
            out.push(pick);
        }
        out
    }

    /// Index draw from unnormalised non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn stream_depends_on_every_input() {
        let mut base = Rng::stream(1, 2, 3);
        assert_eq!(base.next_u64(), Rng::stream(1, 2, 3).next_u64());
        for (s, d, c) in [(9, 2, 3), (1, 9, 3), (1, 2, 9)] {
            let mut other = Rng::stream(s, d, c);
            let mut again = Rng::stream(1, 2, 3);
            assert_ne!(again.next_u64(), other.next_u64());
        }
    }

    #[test]
    fn fork_independent_of_parent_consumption() {
        let parent = Rng::new(7);
        let mut c1 = parent.fork(3);
        let mut c2 = parent.fork(3);
        assert_eq!(c1.next_u64(), c2.next_u64());
        let mut c3 = parent.fork(4);
        assert_ne!(c1.next_u64(), c3.next_u64());
    }

    #[test]
    fn uniform_mean_close() {
        let mut r = Rng::new(9);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_unbiased_small_n() {
        let mut r = Rng::new(11);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[r.below(3)] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 500.0, "{counts:?}");
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(13);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn sample_distinct_properties() {
        let mut r = Rng::new(19);
        for _ in 0..50 {
            let n = r.range(1, 50);
            let k = r.range(0, n + 1);
            let s = r.sample_distinct(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k, "duplicates in {s:?}");
            assert!(s.iter().all(|&x| x < n));
        }
    }

    #[test]
    fn weighted_respects_weights() {
        let mut r = Rng::new(23);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..20_000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "{counts:?}");
    }
}
