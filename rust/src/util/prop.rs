//! Seeded property-testing harness (replaces `proptest`).
//!
//! `check(cases, seed, |rng| ...)` runs a closure over `cases`
//! independent RNG streams; a failure reports the exact case seed so it
//! can be replayed with `check(1, <seed>, ...)`. Deliberately minimal:
//! no shrinking, but deterministic seeds make failures reproducible,
//! which is what matters for CI.
//!
//! Used throughout the crate for coordinator invariants (partition
//! coverage/balance, sampler validity, aggregation algebra, routing).

use crate::util::rng::Rng;

/// Run `f` across `cases` forked RNG streams; panics with the failing
/// case seed on the first error returned.
pub fn check<F>(cases: usize, seed: u64, mut f: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let root = Rng::new(seed);
    for case in 0..cases {
        let case_seed = seed ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = root.fork(case as u64);
        if let Err(msg) = f(&mut rng) {
            panic!(
                "property failed on case {case}/{cases} \
                 (replay: check(1, {case_seed:#x}, ..)): {msg}"
            );
        }
    }
}

/// Assert helper producing `Result` for use inside [`check`].
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
    ($cond:expr) => {
        if !$cond {
            return Err(format!("assertion failed: {}", stringify!($cond)));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_when_property_holds() {
        check(50, 1, |rng| {
            let n = rng.range(1, 100);
            prop_assert!(n < 100);
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn fails_with_case_info() {
        check(50, 2, |rng| {
            let n = rng.range(0, 10);
            prop_assert!(n < 9, "n was {n}");
            Ok(())
        });
    }

    #[test]
    fn cases_see_distinct_streams() {
        let mut seen = std::collections::HashSet::new();
        check(20, 3, |rng| {
            seen.insert(rng.next_u64());
            Ok(())
        });
        assert_eq!(seen.len(), 20);
    }
}
