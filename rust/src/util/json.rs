//! Minimal JSON: value model, recursive-descent parser, writer.
//!
//! Replaces `serde_json` (unavailable offline). Handles everything the
//! crate exchanges as JSON: the AOT `artifacts/manifest.json` contract,
//! experiment configs and bench result files. Numbers are kept as `f64`
//! (adequate: the manifest's largest integers are array shapes).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use `BTreeMap` for deterministic output.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---- typed accessors -------------------------------------------------
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Object field lookup; `Json::Null` for missing keys.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(m) => m.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Path lookup: `j.at(&["variants", "gcn_mlp", "params"])`.
    pub fn at(&self, path: &[&str]) -> &Json {
        path.iter().fold(self, |j, k| j.get(k))
    }

    // ---- constructors ----------------------------------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }
    pub fn num<T: Into<f64>>(x: T) -> Json {
        Json::Num(x.into())
    }
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    /// Insert into an object (panics on non-objects: programmer error).
    pub fn set(&mut self, key: &str, value: Json) {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), value);
            }
            _ => panic!("Json::set on non-object"),
        }
    }

    // ---- io ----------------------------------------------------------------
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    pub fn read_file(path: &std::path::Path) -> anyhow::Result<Json> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("read {}: {e}", path.display()))?;
        Ok(Json::parse(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?)
    }

    pub fn write_file(&self, path: &std::path::Path) -> anyhow::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, format!("{self:#}"))?;
        Ok(())
    }
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}
impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.i }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected value")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            out.insert(key, self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match c {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.i..self.i + 4)
                                .ok_or_else(|| self.err("short \\u"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)
                                    .map_err(|_| self.err("bad \\u"))?,
                                16,
                            )
                            .map_err(|_| self.err("bad \\u"))?;
                            self.i += 4;
                            // Surrogate pairs: not needed by our files;
                            // map unpaired surrogates to U+FFFD.
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        }
                        _ => return Err(self.err("bad escape char")),
                    }
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = &self.b[self.i..];
                    let text = std::str::from_utf8(rest)
                        .map_err(|_| self.err("invalid utf8"))?;
                    let ch = text.chars().next().unwrap();
                    s.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

// ---- writer ----------------------------------------------------------------

impl fmt::Display for Json {
    /// `{}` for compact, `{:#}` for pretty (2-space indent).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write_json(self, f, if f.alternate() { Some(0) } else { None })
    }
}

fn write_json(
    j: &Json,
    f: &mut fmt::Formatter<'_>,
    indent: Option<usize>,
) -> fmt::Result {
    match j {
        Json::Null => write!(f, "null"),
        Json::Bool(b) => write!(f, "{b}"),
        Json::Num(x) => {
            if x.fract() == 0.0 && x.abs() < 1e15 {
                write!(f, "{}", *x as i64)
            } else {
                write!(f, "{x}")
            }
        }
        Json::Str(s) => write_str(s, f),
        Json::Arr(v) => {
            if v.is_empty() {
                return write!(f, "[]");
            }
            let (open, sep, close, pad) = seps('[', ']', indent);
            write!(f, "{open}")?;
            for (i, item) in v.iter().enumerate() {
                if i > 0 {
                    write!(f, "{sep}")?;
                }
                write!(f, "{pad}")?;
                write_json(item, f, indent.map(|d| d + 1))?;
            }
            write!(f, "{close}")
        }
        Json::Obj(m) => {
            if m.is_empty() {
                return write!(f, "{{}}");
            }
            let (open, sep, close, pad) = seps('{', '}', indent);
            write!(f, "{open}")?;
            for (i, (k, v)) in m.iter().enumerate() {
                if i > 0 {
                    write!(f, "{sep}")?;
                }
                write!(f, "{pad}")?;
                write_str(k, f)?;
                write!(f, ": ")?;
                write_json(v, f, indent.map(|d| d + 1))?;
            }
            write!(f, "{close}")
        }
    }
}

fn seps(o: char, c: char, indent: Option<usize>) -> (String, String, String, String) {
    match indent {
        None => (o.to_string(), ",".into(), c.to_string(), "".into()),
        Some(d) => {
            let inner = "  ".repeat(d + 1);
            let outer = "  ".repeat(d);
            (
                format!("{o}"),
                ",".to_string(),
                format!("\n{outer}{c}"),
                format!("\n{inner}"),
            )
        }
    }
}

fn write_str(s: &str, f: &mut fmt::Formatter<'_>) -> fmt::Result {
    write!(f, "\"")?;
    for ch in s.chars() {
        match ch {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": false}], "c": "x"}"#).unwrap();
        assert_eq!(j.at(&["a"]).as_arr().unwrap().len(), 3);
        assert_eq!(j.at(&["a"]).as_arr().unwrap()[2].get("b"), &Json::Bool(false));
        assert_eq!(j.get("c").as_str(), Some("x"));
        assert_eq!(j.get("missing"), &Json::Null);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let j = Json::obj(vec![
            ("name", Json::str("tma")),
            ("xs", Json::arr([Json::num(1), Json::num(2.5)])),
            ("flag", Json::Bool(true)),
            ("nothing", Json::Null),
        ]);
        for text in [format!("{j}"), format!("{j:#}")] {
            assert_eq!(Json::parse(&text).unwrap(), j);
        }
    }

    #[test]
    fn integers_print_without_fraction() {
        assert_eq!(format!("{}", Json::num(256)), "256");
        assert_eq!(format!("{}", Json::num(0.5)), "0.5");
    }

    #[test]
    fn unicode_and_escapes_roundtrip() {
        let j = Json::str("π \"quoted\"\ttab\nnl");
        let text = format!("{j}");
        assert_eq!(Json::parse(&text).unwrap(), j);
    }

    #[test]
    fn parses_real_manifest_shape() {
        let text = r#"{
          "adam": {"beta1": 0.9, "lr": 0.001},
          "variants": {"gcn_mlp": {"params": {"total": 260,
            "tensors": [{"init": "glorot", "name": "enc0.w",
                         "offset": 0, "shape": [8, 8]}]}}}
        }"#;
        let j = Json::parse(text).unwrap();
        assert_eq!(j.at(&["adam", "lr"]).as_f64(), Some(0.001));
        let t = &j.at(&["variants", "gcn_mlp", "params", "tensors"]).as_arr().unwrap()[0];
        assert_eq!(t.get("shape").as_arr().unwrap()[0].as_usize(), Some(8));
    }

    #[test]
    fn prop_roundtrip_random_values() {
        // Property: parse(print(v)) == v for arbitrary generated values.
        use crate::util::rng::Rng;
        fn gen(r: &mut Rng, depth: usize) -> Json {
            match if depth == 0 { r.below(4) } else { r.below(6) } {
                0 => Json::Null,
                1 => Json::Bool(r.chance(0.5)),
                2 => Json::Num((r.gaussian() * 100.0 * 8.0).round() / 8.0),
                3 => Json::Str(format!("s{}", r.next_u64() % 1000)),
                4 => Json::Arr((0..r.below(4)).map(|_| gen(r, depth - 1)).collect()),
                _ => Json::Obj(
                    (0..r.below(4))
                        .map(|i| (format!("k{i}"), gen(r, depth - 1)))
                        .collect(),
                ),
            }
        }
        let mut r = Rng::new(99);
        for _ in 0..200 {
            let v = gen(&mut r, 3);
            assert_eq!(Json::parse(&format!("{v}")).unwrap(), v);
            assert_eq!(Json::parse(&format!("{v:#}")).unwrap(), v);
        }
    }
}
