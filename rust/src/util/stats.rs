//! Summary statistics, EMA smoothing and ranking helpers.
//!
//! Used by the metrics pipeline (convergence-time extraction, Fig 3
//! loss smoothing) and by the bench harness (robust timing summaries,
//! the paper's "Average Rank" columns in Table 2).

/// Arithmetic mean; 0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Sample standard deviation (n-1 denominator); 0 for n < 2.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64)
        .sqrt()
}

/// p-th percentile (0..=100) by linear interpolation.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
}

/// Exponential moving average with factor `alpha` (paper Fig 3 uses
/// alpha = 0.1 on the raw loss curves).
pub fn ema(xs: &[f64], alpha: f64) -> Vec<f64> {
    let mut out = Vec::with_capacity(xs.len());
    let mut acc = None;
    for &x in xs {
        acc = Some(match acc {
            None => x,
            Some(prev) => alpha * x + (1.0 - alpha) * prev,
        });
        out.push(acc.unwrap());
    }
    out
}

/// 1-based competition ranks of `xs` (rank 1 = best). `higher_better`
/// selects the direction; ties share the smallest applicable rank —
/// matching how the paper computes its "Average Rank" columns.
pub fn ranks(xs: &[f64], higher_better: bool) -> Vec<f64> {
    let n = xs.len();
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| {
        let (x, y) = (xs[a], xs[b]);
        if higher_better {
            y.partial_cmp(&x).unwrap()
        } else {
            x.partial_cmp(&y).unwrap()
        }
    });
    let mut out = vec![0.0; n];
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && xs[idx[j + 1]] == xs[idx[i]] {
            j += 1;
        }
        for k in i..=j {
            out[idx[k]] = (i + 1) as f64;
        }
        i = j + 1;
    }
    out
}

/// Mean ± std as the paper prints it, e.g. `47.78 ±0.21`.
pub fn fmt_mean_std(xs: &[f64], decimals: usize) -> String {
    format!(
        "{:.*} ±{:.*}",
        decimals,
        mean(xs),
        decimals,
        std_dev(xs)
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_basics() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.138089935).abs() < 1e-6);
        assert_eq!(std_dev(&[1.0]), 0.0);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(median(&xs), 2.5);
        assert_eq!(percentile(&xs, 50.0), 2.5);
    }

    #[test]
    fn ema_matches_hand_computation() {
        let out = ema(&[1.0, 2.0, 3.0], 0.5);
        assert_eq!(out, vec![1.0, 1.5, 2.25]);
        assert!(ema(&[], 0.1).is_empty());
    }

    #[test]
    fn ranks_directions_and_ties() {
        // higher better: 9 -> rank 1
        assert_eq!(ranks(&[1.0, 9.0, 5.0], true), vec![3.0, 1.0, 2.0]);
        // lower better: 1 -> rank 1
        assert_eq!(ranks(&[1.0, 9.0, 5.0], false), vec![1.0, 3.0, 2.0]);
        // ties share the smallest rank
        assert_eq!(ranks(&[5.0, 5.0, 1.0], true), vec![1.0, 1.0, 3.0]);
    }

    #[test]
    fn fmt_matches_paper_style() {
        assert_eq!(fmt_mean_std(&[47.57, 47.99], 2), "47.78 ±0.30");
    }

    #[test]
    fn prop_percentile_bounded_and_monotone() {
        use crate::util::rng::Rng;
        let mut r = Rng::new(5);
        for _ in 0..100 {
            let n = r.range(1, 40);
            let xs: Vec<f64> = (0..n).map(|_| r.gaussian()).collect();
            let (lo, hi) = (min(&xs), max(&xs));
            let mut prev = f64::NEG_INFINITY;
            for p in [0.0, 10.0, 25.0, 50.0, 75.0, 90.0, 100.0] {
                let v = percentile(&xs, p);
                assert!(v >= lo - 1e-12 && v <= hi + 1e-12);
                assert!(v >= prev - 1e-12);
                prev = v;
            }
        }
    }
}
