//! Timing/bench harness (replaces `criterion`, unavailable offline).
//!
//! Every `[[bench]]` target is a `harness = false` binary built on this
//! module: `time()` measures a closure with warmup + repeated samples
//! and robust statistics; `Table` renders the paper-style result tables
//! to stdout and `results/*.json` for EXPERIMENTS.md.

use std::time::Instant;

use crate::util::json::Json;
use crate::util::stats;

/// Timing summary over n samples.
#[derive(Debug, Clone)]
pub struct Timing {
    pub label: String,
    pub samples: Vec<f64>, // seconds
}

impl Timing {
    pub fn median_s(&self) -> f64 {
        stats::median(&self.samples)
    }
    pub fn mean_s(&self) -> f64 {
        stats::mean(&self.samples)
    }
    pub fn p95_s(&self) -> f64 {
        stats::percentile(&self.samples, 95.0)
    }

    pub fn report(&self) -> String {
        format!(
            "{:40} median {:>10} p95 {:>10} (n={})",
            self.label,
            fmt_secs(self.median_s()),
            fmt_secs(self.p95_s()),
            self.samples.len()
        )
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("label", Json::str(self.label.clone())),
            ("median_s", Json::num(self.median_s())),
            ("mean_s", Json::num(self.mean_s())),
            ("p95_s", Json::num(self.p95_s())),
            ("n", Json::num(self.samples.len() as f64)),
        ])
    }
}

/// Human-readable seconds.
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{s:.2}s")
    }
}

/// Time `f` with `warmup` discarded runs then `samples` measured runs.
pub fn time<F: FnMut()>(label: &str, warmup: usize, samples: usize, mut f: F) -> Timing {
    for _ in 0..warmup {
        f();
    }
    let mut out = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        f();
        out.push(t0.elapsed().as_secs_f64());
    }
    Timing { label: label.to_string(), samples: out }
}

/// Paper-style text table with aligned columns.
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..ncol {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let line = |cells: &[String], widths: &[usize]| {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}", w = w))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&line(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncol - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row, &widths));
            out.push('\n');
        }
        out
    }

    /// Print to stdout and persist under `results/<name>.json`.
    pub fn emit(&self, name: &str) {
        println!("{}", self.render());
        let json = Json::obj(vec![
            ("title", Json::str(self.title.clone())),
            (
                "headers",
                Json::arr(self.headers.iter().map(|h| Json::str(h.clone()))),
            ),
            (
                "rows",
                Json::arr(self.rows.iter().map(|r| {
                    Json::arr(r.iter().map(|c| Json::str(c.clone())))
                })),
            ),
        ]);
        let path = std::path::Path::new("results").join(format!("{name}.json"));
        if let Err(e) = json.write_file(&path) {
            eprintln!("warn: could not write {}: {e}", path.display());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_returns_requested_samples() {
        let t = time("noop", 1, 5, || {});
        assert_eq!(t.samples.len(), 5);
        assert!(t.median_s() >= 0.0);
    }

    #[test]
    fn fmt_secs_scales() {
        assert!(fmt_secs(2.5e-9).ends_with("ns"));
        assert!(fmt_secs(2.5e-5).ends_with("µs"));
        assert!(fmt_secs(2.5e-3).ends_with("ms"));
        assert!(fmt_secs(2.5).ends_with('s'));
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("T", &["a", "bbbb"]);
        t.row(vec!["1".into(), "2".into()]);
        t.row(vec!["333".into(), "4".into()]);
        let r = t.render();
        assert!(r.contains("== T =="));
        assert!(r.lines().count() >= 4);
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn table_rejects_bad_rows() {
        let mut t = Table::new("T", &["a"]);
        t.row(vec!["1".into(), "2".into()]);
    }
}
