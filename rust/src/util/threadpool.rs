//! Scoped parallel-map helper over std threads.
//!
//! Replaces rayon for the few embarrassingly-parallel preprocessing
//! sections (per-partition subgraph induction, eval block encoding is
//! *not* parallelised — the PJRT executables are per-thread). On this
//! testbed (1 core) parallelism degenerates gracefully to sequential.

/// Worker-thread default: one per available core (1 when the core
/// count is unknown). The generators and the streaming aggregation
/// fold both size their chunking off this.
pub fn default_workers() -> usize {
    std::thread::available_parallelism().map_or(1, |c| c.get())
}

/// Near-even split of `n` items into `parts` consecutive window sizes:
/// the first `n % parts` windows take one extra item. Sizes sum to
/// exactly `n` (so they tile a buffer for [`parallel_fill`]); `parts`
/// may exceed `n`, leaving zero-size trailing windows.
pub fn even_chunks(n: usize, parts: usize) -> Vec<usize> {
    assert!(parts > 0);
    let base = n / parts;
    let extra = n % parts;
    (0..parts).map(|i| base + usize::from(i < extra)).collect()
}

/// Run `f(i)` for i in 0..n on up to `workers` scoped threads and
/// collect results in index order.
pub fn parallel_map<T, F>(n: usize, workers: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    assert!(workers > 0);
    if n == 0 {
        return Vec::new();
    }
    let m = crate::telemetry::metrics();
    m.pool_sections.inc();
    m.pool_tasks.add(n as u64);
    m.pool_workers.add(workers.min(n) as u64);
    if workers == 1 || n == 1 {
        return (0..n).map(&f).collect();
    }
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let next = std::sync::atomic::AtomicUsize::new(0);
    let slots: Vec<std::sync::Mutex<&mut Option<T>>> =
        out.iter_mut().map(std::sync::Mutex::new).collect();
    std::thread::scope(|scope| {
        for _ in 0..workers.min(n) {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let v = f(i);
                **slots[i].lock().unwrap() = Some(v);
            });
        }
    });
    out.into_iter().map(|x| x.expect("worker missed slot")).collect()
}

/// Split `out` into consecutive windows of the given `sizes` and run
/// `f(i, window_i)` on up to `workers` scoped threads. The windows are
/// disjoint `&mut` slices, so workers write the shared buffer with no
/// locks on the data path (each window's mutex is locked exactly once,
/// uncontended, to move the slice into its worker). Used by the
/// parallel generators to fill pre-sized CSR and feature buffers in
/// place — the "fill" half of their count-then-fill passes.
pub fn parallel_fill<T, F>(out: &mut [T], sizes: &[usize], workers: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(workers > 0);
    assert_eq!(
        sizes.iter().sum::<usize>(),
        out.len(),
        "window sizes must tile the output buffer exactly"
    );
    let m = crate::telemetry::metrics();
    m.pool_sections.inc();
    m.pool_tasks.add(sizes.len() as u64);
    m.pool_workers.add(workers.min(sizes.len()) as u64);
    let mut windows: Vec<&mut [T]> = Vec::with_capacity(sizes.len());
    let mut rest = out;
    for &s in sizes {
        let tmp = std::mem::take(&mut rest);
        let (w, r) = tmp.split_at_mut(s);
        windows.push(w);
        rest = r;
    }
    if workers == 1 || windows.len() <= 1 {
        for (i, w) in windows.into_iter().enumerate() {
            f(i, w);
        }
        return;
    }
    let next = std::sync::atomic::AtomicUsize::new(0);
    let slots: Vec<std::sync::Mutex<&mut [T]>> =
        windows.into_iter().map(std::sync::Mutex::new).collect();
    std::thread::scope(|scope| {
        for _ in 0..workers.min(slots.len()) {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= slots.len() {
                    break;
                }
                let mut w = slots[i].lock().unwrap();
                f(i, &mut **w);
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn even_chunks_tile_exactly() {
        assert_eq!(even_chunks(10, 3), vec![4, 3, 3]);
        assert_eq!(even_chunks(9, 3), vec![3, 3, 3]);
        assert_eq!(even_chunks(2, 4), vec![1, 1, 0, 0]);
        assert_eq!(even_chunks(0, 2), vec![0, 0]);
        for (n, parts) in [(17, 4), (1000, 7), (5, 5)] {
            let sizes = even_chunks(n, parts);
            assert_eq!(sizes.len(), parts);
            assert_eq!(sizes.iter().sum::<usize>(), n);
            // windows differ by at most one item
            let (mn, mx) = (
                sizes.iter().min().unwrap(),
                sizes.iter().max().unwrap(),
            );
            assert!(mx - mn <= 1, "uneven split {sizes:?}");
        }
    }

    #[test]
    fn default_workers_is_positive() {
        assert!(default_workers() >= 1);
    }

    #[test]
    fn preserves_order() {
        let out = parallel_map(100, 4, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn sequential_path_matches() {
        assert_eq!(parallel_map(5, 1, |i| i + 1), vec![1, 2, 3, 4, 5]);
        assert!(parallel_map(0, 4, |i: usize| i).is_empty());
    }

    #[test]
    fn workers_capped_by_n() {
        assert_eq!(parallel_map(1, 16, |_| 7), vec![7]);
    }

    #[test]
    fn fill_tiles_windows_in_order() {
        for workers in [1, 2, 4] {
            let mut out = vec![0usize; 10];
            parallel_fill(&mut out, &[3, 0, 2, 5], workers, |i, w| {
                for x in w.iter_mut() {
                    *x = i + 1;
                }
            });
            assert_eq!(out, vec![1, 1, 1, 3, 3, 4, 4, 4, 4, 4], "w={workers}");
        }
    }

    #[test]
    fn fill_empty_buffer_is_noop() {
        let mut out: Vec<u32> = Vec::new();
        parallel_fill(&mut out, &[], 4, |_, _| panic!("no windows"));
        parallel_fill(&mut out, &[0, 0], 4, |_, w| assert!(w.is_empty()));
    }

    #[test]
    #[should_panic(expected = "tile the output buffer")]
    fn fill_rejects_mismatched_sizes() {
        let mut out = vec![0u8; 4];
        parallel_fill(&mut out, &[1, 2], 2, |_, _| {});
    }
}
