//! Scoped parallel-map helper over std threads.
//!
//! Replaces rayon for the few embarrassingly-parallel preprocessing
//! sections (per-partition subgraph induction, eval block encoding is
//! *not* parallelised — the PJRT executables are per-thread). On this
//! testbed (1 core) parallelism degenerates gracefully to sequential.

/// Run `f(i)` for i in 0..n on up to `workers` scoped threads and
/// collect results in index order.
pub fn parallel_map<T, F>(n: usize, workers: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    assert!(workers > 0);
    if n == 0 {
        return Vec::new();
    }
    if workers == 1 || n == 1 {
        return (0..n).map(&f).collect();
    }
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let next = std::sync::atomic::AtomicUsize::new(0);
    let slots: Vec<std::sync::Mutex<&mut Option<T>>> =
        out.iter_mut().map(std::sync::Mutex::new).collect();
    std::thread::scope(|scope| {
        for _ in 0..workers.min(n) {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let v = f(i);
                **slots[i].lock().unwrap() = Some(v);
            });
        }
    });
    out.into_iter().map(|x| x.expect("worker missed slot")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let out = parallel_map(100, 4, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn sequential_path_matches() {
        assert_eq!(parallel_map(5, 1, |i| i + 1), vec![1, 2, 3, 4, 5]);
        assert!(parallel_map(0, 4, |i: usize| i).is_empty());
    }

    #[test]
    fn workers_capped_by_n() {
        assert_eq!(parallel_map(1, 16, |_| 7), vec![7]);
    }
}
