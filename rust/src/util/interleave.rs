//! Exhaustive interleaving explorer — a dependency-free stand-in
//! for `loom` sized to this crate's control plane.
//!
//! [`explore`] enumerates *every* schedule of a small set of threads
//! whose steps are plain functions over a cloneable model state, and
//! runs a property check at each terminal state. The crate's
//! concurrency-sensitive logic (`coordinator::kv::Control`) uses only
//! `SeqCst` atomics, so every real execution is equivalent to some
//! total order of its atomic operations — which is exactly the set of
//! schedules this explorer enumerates when each step models one
//! atomic op. That makes the `tests/loom_control.rs` models sound
//! without instrumenting the real types: the model transcribes the
//! production decision code at atomic-op granularity and the explorer
//! proves the property over the full schedule space.
//!
//! The schedule count for step counts `n1..nk` is the multinomial
//! `(n1+..+nk)! / (n1!·..·nk!)` — [`interleavings`] computes it so
//! tests can assert the exploration really was exhaustive.

/// One model step: mutate the shared state; `usize` is the acting
/// thread's index (so one function can serve N symmetric threads).
pub type Step<S> = fn(&mut S, usize);

/// Run `check` on the terminal state of every interleaving of
/// `threads` (each a program: an ordered list of steps) starting
/// from `init`. Returns the number of schedules explored.
pub fn explore<S: Clone>(
    init: &S,
    threads: &[Vec<Step<S>>],
    check: &mut dyn FnMut(&S),
) -> u64 {
    let mut pcs = vec![0usize; threads.len()];
    let mut count = 0u64;
    dfs(init, &mut pcs, threads, check, &mut count);
    count
}

fn dfs<S: Clone>(
    state: &S,
    pcs: &mut [usize],
    threads: &[Vec<Step<S>>],
    check: &mut dyn FnMut(&S),
    count: &mut u64,
) {
    let mut terminal = true;
    for t in 0..threads.len() {
        if pcs[t] >= threads[t].len() {
            continue;
        }
        terminal = false;
        let mut next = state.clone();
        (threads[t][pcs[t]])(&mut next, t);
        pcs[t] += 1;
        dfs(&next, pcs, threads, check, count);
        pcs[t] -= 1;
    }
    if terminal {
        check(state);
        *count += 1;
    }
}

/// Number of distinct schedules for threads with these step counts:
/// the multinomial coefficient `(Σn)! / Πn!`, computed without
/// factorial overflow.
pub fn interleavings(lens: &[usize]) -> u64 {
    let mut total = 1u64;
    let mut placed = 0u64;
    for &n in lens {
        for k in 1..=n as u64 {
            placed += 1;
            // running product stays integral: after placing each
            // step, total is a product of binomial coefficients
            total = total * placed / k;
        }
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Clone, Default)]
    struct Race {
        counter: u64,
        temp: [u64; 3],
    }

    fn read(s: &mut Race, t: usize) {
        s.temp[t] = s.counter;
    }

    fn write(s: &mut Race, t: usize) {
        s.counter = s.temp[t] + 1;
    }

    #[test]
    fn finds_the_lost_update() {
        // Two unsynchronized read-modify-write threads: the classic
        // lost update MUST appear in some schedule, and the clean
        // outcome in another. An explorer that misses either is not
        // exhaustive.
        let prog: Vec<Step<Race>> = vec![read, write];
        let threads = vec![prog.clone(), prog];
        let mut outcomes = std::collections::BTreeSet::new();
        let n = explore(&Race::default(), &threads, &mut |s: &Race| {
            outcomes.insert(s.counter);
        });
        assert_eq!(n, interleavings(&[2, 2]));
        assert_eq!(n, 6);
        assert!(outcomes.contains(&1), "lost update never surfaced");
        assert!(outcomes.contains(&2), "clean outcome never surfaced");
    }

    #[test]
    fn multinomial_counts() {
        assert_eq!(interleavings(&[]), 1);
        assert_eq!(interleavings(&[5]), 1);
        assert_eq!(interleavings(&[1, 1]), 2);
        assert_eq!(interleavings(&[2, 2]), 6);
        assert_eq!(interleavings(&[3, 2]), 10);
        assert_eq!(interleavings(&[2, 2, 2]), 90);
        assert_eq!(interleavings(&[3, 3, 3]), 1680);
    }

    #[test]
    fn schedule_count_matches_for_three_threads() {
        let prog: Vec<Step<Race>> = vec![read];
        let threads = vec![prog.clone(), prog.clone(), prog];
        let n =
            explore(&Race::default(), &threads, &mut |_s: &Race| {});
        assert_eq!(n, interleavings(&[1, 1, 1]));
        assert_eq!(n, 6);
    }
}
