//! Tiny CLI parser (replaces `clap`, unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional
//! arguments, with typed accessors, defaults and a generated usage
//! string. Used by the `rtma` binary, the examples and every bench
//! harness (which receive extra args from `cargo bench -- ...`).

use std::collections::BTreeMap;

/// Parsed arguments: `--key value|--key=value|--flag` plus positionals.
#[derive(Debug, Clone, Default)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    pos: Vec<String>,
}

impl Args {
    /// Parse from an explicit iterator (tests) — flags must be declared
    /// so `--flag value` vs `--key value` is unambiguous.
    pub fn parse_from<I: IntoIterator<Item = String>>(
        args: I,
        known_flags: &[&str],
    ) -> Args {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if known_flags.contains(&body) {
                    out.flags.push(body.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    out.opts.insert(body.to_string(), it.next().unwrap());
                } else {
                    // Trailing --name with no value: treat as a flag.
                    out.flags.push(body.to_string());
                }
            } else {
                out.pos.push(a);
            }
        }
        out
    }

    /// Parse the process arguments (skipping argv[0]). Benches invoked
    /// through `cargo bench` receive a trailing `--bench` argument —
    /// it is accepted as a flag automatically.
    pub fn parse(known_flags: &[&str]) -> Args {
        let mut flags: Vec<&str> = known_flags.to_vec();
        flags.push("bench");
        Args::parse_from(std::env::args().skip(1), &flags)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key}: bad usize {v:?}")))
            .unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key}: bad u64 {v:?}")))
            .unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key}: bad f64 {v:?}")))
            .unwrap_or(default)
    }

    pub fn positional(&self) -> &[String] {
        &self.pos
    }

    /// First positional = subcommand, remaining args re-wrapped.
    pub fn subcommand(&self) -> (Option<&str>, Args) {
        match self.pos.split_first() {
            None => (None, self.clone()),
            Some((head, rest)) => {
                let mut sub = self.clone();
                sub.pos = rest.to_vec();
                (Some(head.as_str()), sub)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str, flags: &[&str]) -> Args {
        Args::parse_from(s.split_whitespace().map(String::from), flags)
    }

    #[test]
    fn key_value_both_syntaxes() {
        let a = parse("--m 3 --dataset=citation-sim", &[]);
        assert_eq!(a.usize_or("m", 0), 3);
        assert_eq!(a.str_or("dataset", ""), "citation-sim");
    }

    #[test]
    fn flags_vs_options() {
        let a = parse("--quick --seed 7 run", &["quick"]);
        assert!(a.flag("quick"));
        assert!(!a.flag("seed"));
        assert_eq!(a.u64_or("seed", 0), 7);
        assert_eq!(a.positional(), &["run".to_string()]);
    }

    #[test]
    fn trailing_bare_flag() {
        let a = parse("--verbose", &[]);
        assert!(a.flag("verbose"));
    }

    #[test]
    fn defaults_apply() {
        let a = parse("", &[]);
        assert_eq!(a.usize_or("m", 3), 3);
        assert_eq!(a.f64_or("rho", 2.0), 2.0);
        assert_eq!(a.str_or("x", "d"), "d");
    }

    #[test]
    fn subcommand_splits() {
        let a = parse("train --m 5 extra", &[]);
        let (cmd, rest) = a.subcommand();
        assert_eq!(cmd, Some("train"));
        assert_eq!(rest.positional(), &["extra".to_string()]);
        assert_eq!(rest.usize_or("m", 0), 5);
    }

    #[test]
    #[should_panic(expected = "bad usize")]
    fn bad_number_panics() {
        parse("--m nope", &[]).usize_or("m", 0);
    }
}
