//! Standard-library substrates.
//!
//! The build environment is offline and the local crate cache lacks the
//! usual ecosystem crates (serde, rand, clap, criterion, proptest,
//! tokio), so this module provides small, fully-tested replacements:
//!
//! - [`rng`] — splitmix64 / xoshiro256++ PRNGs, Gaussian sampling,
//!   shuffles, weighted choice (replaces `rand`).
//! - [`json`] — JSON value model, parser and writer (replaces
//!   `serde_json`); used for the AOT manifest, configs and results.
//! - [`stats`] — mean/std/median/percentiles, EMA smoothing, ranking.
//! - [`cli`] — flag/subcommand parser for the `rtma` binary and the
//!   bench harnesses (replaces `clap`).
//! - [`prop`] — a seeded property-testing harness (replaces `proptest`).
//! - [`bench`] — timing harness with warmup and robust statistics
//!   (replaces `criterion`; every `[[bench]]` target uses it).
//! - [`threadpool`] — scoped worker pool for parallel sections.
//! - [`interleave`] — exhaustive schedule explorer for model-checking
//!   the control plane (replaces `loom`; see `tests/loom_control.rs`).

pub mod bench;
pub mod cli;
pub mod interleave;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod threadpool;
