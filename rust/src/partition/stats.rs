//! Partition quality measures: edge-cut, retained-edge ratio `r`,
//! balance, and the data-disparity quantities of the paper's theory.

use crate::graph::stats::{class_distribution, l2_distance, mean_feature};
use crate::graph::Graph;

use super::parts_of;

/// Everything the paper reports about a partition (Tables 2, 5, 7).
#[derive(Debug, Clone)]
pub struct PartitionStats {
    pub k: usize,
    pub part_sizes: Vec<usize>,
    /// Undirected edges crossing partition boundaries.
    pub edge_cut: usize,
    /// Fraction of training edges that remain available: Table 2's `r`.
    pub ratio_r: f64,
    /// max part size / ideal part size (1.0 = perfectly balanced).
    pub balance: f64,
    /// Max pairwise L2 distance between per-partition class
    /// distributions — the ||C_i - C_j|| of Thm 2.
    pub class_disparity: f64,
    /// Max pairwise L2 distance between per-partition mean features.
    pub feature_disparity: f64,
}

pub fn partition_stats(g: &Graph, assign: &[u32], k: usize) -> PartitionStats {
    stats_inner(g, assign, k, None)
}

/// [`partition_stats`] without the edge scan: reuses the per-partition
/// *directed cut-view* counts that [`crate::graph::induce_all`] already
/// computed while extracting the trainer subgraphs. `cut_views[p]`
/// counts parent adjacency entries leaving part `p`, so across a full
/// assignment they sum to exactly twice the undirected edge-cut.
pub fn partition_stats_with_cuts(
    g: &Graph,
    assign: &[u32],
    k: usize,
    cut_views: &[usize],
) -> PartitionStats {
    assert_eq!(cut_views.len(), k, "one cut count per partition");
    stats_inner(g, assign, k, Some(cut_views))
}

fn stats_inner(
    g: &Graph,
    assign: &[u32],
    k: usize,
    cut_views: Option<&[usize]>,
) -> PartitionStats {
    assert_eq!(assign.len(), g.num_nodes());
    let parts = parts_of(assign, k);
    let part_sizes: Vec<usize> = parts.iter().map(|p| p.len()).collect();

    let (cut, total) = match cut_views {
        // Every cross edge is seen once from each side: sum/2.
        Some(views) => {
            (views.iter().sum::<usize>() / 2, g.num_edges())
        }
        None => {
            let mut cut = 0usize;
            let mut total = 0usize;
            for (u, v) in g.edges() {
                total += 1;
                if assign[u as usize] != assign[v as usize] {
                    cut += 1;
                }
            }
            (cut, total)
        }
    };
    let ratio_r = if total == 0 {
        0.0
    } else {
        (total - cut) as f64 / total as f64
    };

    let ideal = g.num_nodes() as f64 / k as f64;
    let balance = part_sizes
        .iter()
        .map(|&s| s as f64 / ideal)
        .fold(0.0f64, f64::max);

    let class_dists: Vec<Vec<f64>> =
        parts.iter().map(|p| class_distribution(g, p)).collect();
    let feat_means: Vec<Vec<f64>> =
        parts.iter().map(|p| mean_feature(g, p)).collect();

    let mut class_disparity = 0.0f64;
    let mut feature_disparity = 0.0f64;
    for i in 0..k {
        for j in (i + 1)..k {
            class_disparity =
                class_disparity.max(l2_distance(&class_dists[i], &class_dists[j]));
            feature_disparity = feature_disparity
                .max(l2_distance(&feat_means[i], &feat_means[j]));
        }
    }

    PartitionStats {
        k,
        part_sizes,
        edge_cut: cut,
        ratio_r,
        balance,
        class_disparity,
        feature_disparity,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    fn two_cliques() -> Graph {
        // cliques {0..4} and {5..9} joined by one bridge, labels = clique
        let mut b = GraphBuilder::new(10);
        for u in 0..5u32 {
            for v in (u + 1)..5 {
                b.add_edge(u, v);
                b.add_edge(u + 5, v + 5);
            }
        }
        b.add_edge(0, 5);
        let mut g = b.build();
        g.labels = (0..10).map(|v| (v >= 5) as u16).collect::<Vec<_>>().into();
        g.num_classes = 2;
        g.feat_dim = 1;
        g.features = (0..10)
            .map(|v| if v >= 5 { 1.0 } else { 0.0 })
            .collect::<Vec<f32>>()
            .into();
        g
    }

    #[test]
    fn perfect_cut_stats() {
        let g = two_cliques();
        let assign: Vec<u32> = (0..10).map(|v| (v >= 5) as u32).collect();
        let s = partition_stats(&g, &assign, 2);
        assert_eq!(s.edge_cut, 1);
        assert!((s.ratio_r - 20.0 / 21.0).abs() < 1e-9);
        assert_eq!(s.part_sizes, vec![5, 5]);
        assert!((s.balance - 1.0).abs() < 1e-9);
        // perfectly separated classes: onehot dists distance = sqrt(2)
        assert!((s.class_disparity - 2f64.sqrt()).abs() < 1e-9);
        assert!((s.feature_disparity - 1.0).abs() < 1e-9);
    }

    #[test]
    fn near_uniform_mix_has_low_disparity() {
        let g = two_cliques();
        // part0 = {0,1,4,5,6}: classes {0,0,0,1,1} -> C = [3/5, 2/5]
        // part1 = {2,3,7,8,9}: classes {0,0,1,1,1} -> C = [2/5, 3/5]
        // (with 5 nodes per class, 1/5 residual imbalance is the best a
        // 5/5 split can do) -> disparity = sqrt(2) * 0.2, far below the
        // class-separating assignment's sqrt(2).
        let assign: Vec<u32> = vec![0, 0, 1, 1, 0, 0, 0, 1, 1, 1];
        let s = partition_stats(&g, &assign, 2);
        assert!((s.class_disparity - 2f64.sqrt() * 0.2).abs() < 1e-9);
        assert!(s.ratio_r < 0.6); // mixing cuts many clique edges
    }

    #[test]
    fn exact_mix_has_zero_disparity() {
        // 4-node cliques (even class sizes) admit a perfectly balanced
        // split: each part gets 2 nodes of each class.
        let mut b = GraphBuilder::new(8);
        for u in 0..4u32 {
            for v in (u + 1)..4 {
                b.add_edge(u, v);
                b.add_edge(u + 4, v + 4);
            }
        }
        let mut g = b.build();
        g.labels = (0..8).map(|v| (v >= 4) as u16).collect::<Vec<_>>().into();
        g.num_classes = 2;
        g.feat_dim = 1;
        g.features = (0..8)
            .map(|v| (v >= 4) as i32 as f32)
            .collect::<Vec<f32>>()
            .into();
        let assign: Vec<u32> = vec![0, 0, 1, 1, 0, 0, 1, 1];
        let s = partition_stats(&g, &assign, 2);
        assert!(s.class_disparity < 1e-12);
        assert!(s.feature_disparity < 1e-12);
    }

    #[test]
    fn singleton_partition_r_is_one() {
        let g = two_cliques();
        let s = partition_stats(&g, &vec![0; 10], 1);
        assert_eq!(s.edge_cut, 0);
        assert!((s.ratio_r - 1.0).abs() < 1e-12);
    }

    #[test]
    fn supplied_cuts_match_full_edge_scan() {
        use crate::graph::induce_all;
        use crate::partition::random_partition;
        use crate::util::rng::Rng;
        let g = crate::gen::dcsbm(&crate::gen::DcsbmConfig {
            nodes: 900,
            communities: 9,
            avg_degree: 11.0,
            homophily: 0.8,
            feat_dim: 4,
            feature_noise: 0.4,
            degree_exponent: 0.5,
            seed: 21,
        });
        let mut rng = Rng::new(23);
        for k in [1, 2, 4] {
            let assign = random_partition(g.num_nodes(), k, &mut rng);
            let cuts: Vec<usize> = induce_all(&g, &assign, k)
                .iter()
                .map(|s| s.cut_edges)
                .collect();
            let scanned = partition_stats(&g, &assign, k);
            let reused = partition_stats_with_cuts(&g, &assign, k, &cuts);
            assert_eq!(scanned.edge_cut, reused.edge_cut, "k={k}");
            assert!((scanned.ratio_r - reused.ratio_r).abs() < 1e-12);
            assert_eq!(scanned.part_sizes, reused.part_sizes);
            assert!((scanned.balance - reused.balance).abs() < 1e-12);
        }
    }
}
