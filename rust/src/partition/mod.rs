//! Graph partitioning and assignment — requirement **R1** of the paper.
//!
//! Three schemes, matching §3.2:
//!
//! - [`random_partition`] — **RandomTMA**: every node independently and
//!   uniformly assigned to one of `k` partitions. No clustering cost;
//!   expected cross-partition edge fraction `1 - 1/k`; zero expected
//!   disparity of per-partition data distributions (Cor 3).
//! - [`metis_like`] — our METIS substrate: multilevel min-edge-cut
//!   k-way partitioning (heavy-edge-matching coarsening → greedy
//!   initial partition → boundary FM refinement). One-to-one mapping of
//!   its `k = M` parts to trainers is exactly the **PSGD-PA / LLCG**
//!   baseline scheme the paper critiques (Lem 1: min-cut on homophilic
//!   graphs maximises disparity).
//! - [`supernode_partition`] — **SuperTMA**: cluster into `N >> M`
//!   mini-clusters (coarsening-based, [`cluster_coarsen`]), then assign
//!   whole clusters to trainers uniformly at random. Interpolates
//!   between the two (N=M → PSGD-PA, N=|V| → RandomTMA).
//!
//! [`PartitionStats`] quantifies what the theory talks about: edge-cut,
//! retained-edge ratio `r` (Table 2), balance, and the disparity
//! `||C_i - C_j||` of per-partition class/feature distributions.

pub mod metis;
pub mod random;
pub mod stats;
pub mod supernode;

pub use metis::{cluster_coarsen, metis_like, MetisConfig};
pub use random::random_partition;
pub use stats::{partition_stats, partition_stats_with_cuts, PartitionStats};
pub use supernode::supernode_partition;

use crate::graph::Graph;
use crate::util::rng::Rng;

/// Which partition scheme to run — the experiment axis of Tables 2-8.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scheme {
    /// RandomTMA: N = |V| (node-level randomized).
    Random,
    /// SuperTMA: N mini-clusters randomly assigned.
    Super { num_clusters: usize },
    /// PSGD-PA / LLCG: min-cut with N = M (one cluster per trainer).
    MinCut,
}

impl Scheme {
    pub fn name(&self) -> String {
        match self {
            Scheme::Random => "random".into(),
            Scheme::Super { num_clusters } => format!("super{num_clusters}"),
            Scheme::MinCut => "mincut".into(),
        }
    }

    /// Produce the node -> partition assignment for `k` trainers.
    pub fn assign(&self, g: &Graph, k: usize, rng: &mut Rng) -> Vec<u32> {
        match self {
            Scheme::Random => random_partition(g.num_nodes(), k, rng),
            Scheme::Super { num_clusters } => {
                supernode_partition(g, *num_clusters, k, rng)
            }
            Scheme::MinCut => {
                metis_like(g, k, &MetisConfig::default(), rng)
            }
        }
    }
}

/// Group an assignment vector into per-partition node lists.
pub fn parts_of(assign: &[u32], k: usize) -> Vec<Vec<u32>> {
    let mut parts = vec![Vec::new(); k];
    for (v, &p) in assign.iter().enumerate() {
        parts[p as usize].push(v as u32);
    }
    parts
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::{dcsbm, DcsbmConfig};

    #[test]
    fn scheme_names() {
        assert_eq!(Scheme::Random.name(), "random");
        assert_eq!(Scheme::Super { num_clusters: 500 }.name(), "super500");
        assert_eq!(Scheme::MinCut.name(), "mincut");
    }

    #[test]
    fn all_schemes_produce_valid_assignments() {
        let g = dcsbm(&DcsbmConfig {
            nodes: 600,
            communities: 6,
            avg_degree: 10.0,
            homophily: 0.85,
            feat_dim: 4,
            feature_noise: 0.3,
            degree_exponent: 0.5,
            seed: 1,
        });
        let mut rng = Rng::new(2);
        for scheme in [
            Scheme::Random,
            Scheme::Super { num_clusters: 64 },
            Scheme::MinCut,
        ] {
            let assign = scheme.assign(&g, 3, &mut rng);
            assert_eq!(assign.len(), 600, "{}", scheme.name());
            assert!(assign.iter().all(|&p| p < 3), "{}", scheme.name());
            let parts = parts_of(&assign, 3);
            assert!(
                parts.iter().all(|p| !p.is_empty()),
                "{}: empty part",
                scheme.name()
            );
        }
    }

    #[test]
    fn mincut_cuts_fewer_edges_than_random() {
        // The core premise of the paper's analysis: min-cut retains far
        // more edges (high r) than random partition (r ~= 1/M).
        let g = dcsbm(&DcsbmConfig {
            nodes: 1200,
            communities: 12,
            avg_degree: 14.0,
            homophily: 0.9,
            feat_dim: 4,
            feature_noise: 0.3,
            degree_exponent: 0.0,
            seed: 5,
        });
        let mut rng = Rng::new(7);
        let r_rand = partition_stats(
            &g,
            &Scheme::Random.assign(&g, 3, &mut rng),
            3,
        )
        .ratio_r;
        let r_cut = partition_stats(
            &g,
            &Scheme::MinCut.assign(&g, 3, &mut rng),
            3,
        )
        .ratio_r;
        assert!(
            r_cut > r_rand + 0.2,
            "mincut r={r_cut:.3} random r={r_rand:.3}"
        );
        assert!((r_rand - 1.0 / 3.0).abs() < 0.05, "random r={r_rand}");
    }
}
