//! RandomTMA's partition: i.i.d. uniform node assignment (§3.2.2).
//!
//! "each node is randomly and independently assigned to one of the
//! graph partitions" — no clustering pass, no graph access at all, so
//! the preprocessing cost is O(|V|) (vs minutes of METIS on the paper's
//! graphs, Table 7 "Prep. Time" column).

use crate::util::rng::Rng;

/// Assign each of `n` nodes to one of `k` partitions uniformly.
pub fn random_partition(n: usize, k: usize, rng: &mut Rng) -> Vec<u32> {
    assert!(k >= 1);
    (0..n).map(|_| rng.below(k) as u32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covers_all_and_balanced_in_expectation() {
        let mut rng = Rng::new(1);
        let assign = random_partition(30_000, 3, &mut rng);
        let mut counts = [0usize; 3];
        for &p in &assign {
            counts[p as usize] += 1;
        }
        for c in counts {
            let dev = (c as f64 - 10_000.0).abs() / 10_000.0;
            assert!(dev < 0.05, "{counts:?}");
        }
    }

    #[test]
    fn prop_assignments_in_range() {
        crate::util::prop::check(50, 3, |rng: &mut Rng| {
            let n = rng.range(1, 500);
            let k = rng.range(1, 24);
            let a = random_partition(n, k, rng);
            crate::prop_assert!(a.len() == n);
            crate::prop_assert!(a.iter().all(|&p| (p as usize) < k));
            Ok(())
        });
    }

    #[test]
    fn expected_cross_edge_fraction_is_1_minus_1_over_k() {
        // Cor 3 setup: each edge survives with probability 1/M.
        use crate::gen::{dcsbm, DcsbmConfig};
        let g = dcsbm(&DcsbmConfig {
            nodes: 4000,
            communities: 8,
            avg_degree: 12.0,
            homophily: 0.9,
            feat_dim: 2,
            feature_noise: 0.1,
            degree_exponent: 0.0,
            seed: 4,
        });
        let mut rng = Rng::new(9);
        let assign = random_partition(g.num_nodes(), 4, &mut rng);
        let internal = g
            .edges()
            .filter(|&(u, v)| assign[u as usize] == assign[v as usize])
            .count();
        let frac = internal as f64 / g.num_edges() as f64;
        assert!((frac - 0.25).abs() < 0.03, "frac={frac}");
    }
}
