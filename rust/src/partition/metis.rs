//! Multilevel min-edge-cut k-way partitioner — the METIS substrate.
//!
//! METIS [17] is unavailable offline, so this implements the same
//! multilevel scheme from scratch:
//!
//! 1. **Coarsening** — repeated heavy-edge matching: visit nodes in
//!    random order, match each unmatched node with its unmatched
//!    neighbour of maximum edge weight, contract matched pairs. Edge
//!    and node weights accumulate so coarse cuts equal fine cuts.
//! 2. **Initial partition** — greedy balanced assignment of coarse
//!    nodes (heaviest first, to the lightest part with the best gain).
//! 3. **Uncoarsening + FM refinement** — project the assignment back
//!    level by level; at each level run boundary Fiduccia-Mattheyses
//!    passes: move a node to the neighbouring part with the highest
//!    positive cut gain subject to a balance constraint.
//!
//! What matters for the paper is not bit-compatibility with METIS but
//! the *objective*: minimise edge-cut under balance. On homophilic
//! community graphs that objective aligns parts with communities —
//! precisely the disparity mechanism of Lemma 1 (validated by
//! `benches/theory_validation.rs` and the partition_study example).
//!
//! The same coarsening machinery exposed as [`cluster_coarsen`]
//! produces the `N >> M` mini-clusters ("super-nodes") for SuperTMA.

use std::collections::HashMap;

use crate::graph::Graph;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct MetisConfig {
    /// Stop coarsening when this many coarse nodes remain (>= 8*k is
    /// sensible; clamped internally).
    pub coarsen_target: usize,
    /// Allowed imbalance: max part weight <= (1 + eps) * ideal.
    pub balance_eps: f64,
    /// FM passes per uncoarsening level.
    pub refine_passes: usize,
}

impl Default for MetisConfig {
    fn default() -> Self {
        MetisConfig { coarsen_target: 200, balance_eps: 0.10, refine_passes: 4 }
    }
}

/// Weighted graph used through the multilevel hierarchy.
struct WGraph {
    /// Sorted adjacency (neighbour, weight) per node.
    adj: Vec<Vec<(u32, f64)>>,
    /// Node weights (number of original vertices inside).
    vw: Vec<f64>,
}

impl WGraph {
    fn from_graph(g: &Graph) -> WGraph {
        let n = g.num_nodes();
        let adj = (0..n)
            .map(|v| {
                g.neighbors_of(v)
                    .iter()
                    .map(|&u| (u, 1.0))
                    .collect::<Vec<_>>()
            })
            .collect();
        WGraph { adj, vw: vec![1.0; n] }
    }

    fn len(&self) -> usize {
        self.vw.len()
    }
}

/// One coarsening step: heavy-edge matching + contraction.
/// Returns (coarse graph, map fine node -> coarse node).
fn coarsen_once(g: &WGraph, rng: &mut Rng) -> (WGraph, Vec<u32>) {
    let n = g.len();
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order);

    const UNMATCHED: u32 = u32::MAX;
    let mut mate = vec![UNMATCHED; n];
    for &v in &order {
        if mate[v] != UNMATCHED {
            continue;
        }
        // heaviest unmatched neighbour
        let mut best: Option<(u32, f64)> = None;
        for &(u, w) in &g.adj[v] {
            if mate[u as usize] == UNMATCHED && u as usize != v {
                if best.map(|(_, bw)| w > bw).unwrap_or(true) {
                    best = Some((u, w));
                }
            }
        }
        match best {
            Some((u, _)) => {
                mate[v] = u;
                mate[u as usize] = v as u32;
            }
            None => mate[v] = v as u32, // self-matched singleton
        }
    }

    // Enumerate coarse ids.
    let mut coarse_of = vec![u32::MAX; n];
    let mut next = 0u32;
    for v in 0..n {
        if coarse_of[v] == u32::MAX {
            let m = mate[v] as usize;
            coarse_of[v] = next;
            coarse_of[m] = next;
            next += 1;
        }
    }

    // Contract.
    let cn = next as usize;
    let mut vw = vec![0.0; cn];
    let mut maps: Vec<HashMap<u32, f64>> = vec![HashMap::new(); cn];
    for v in 0..n {
        let cv = coarse_of[v] as usize;
        vw[cv] += g.vw[v];
        for &(u, w) in &g.adj[v] {
            let cu = coarse_of[u as usize];
            if cu as usize != cv {
                *maps[cv].entry(cu).or_insert(0.0) += w;
            }
        }
    }
    let adj = maps
        .into_iter()
        .map(|m| {
            let mut v: Vec<(u32, f64)> = m.into_iter().collect();
            v.sort_unstable_by_key(|e| e.0);
            v
        })
        .collect();
    (WGraph { adj, vw }, coarse_of)
}

/// Greedy balanced initial k-way assignment on the coarsest graph.
fn initial_partition(g: &WGraph, k: usize, rng: &mut Rng) -> Vec<u32> {
    let n = g.len();
    let mut order: Vec<usize> = (0..n).collect();
    // heaviest first (stable tiebreak via shuffle-then-stable-sort)
    rng.shuffle(&mut order);
    order.sort_by(|&a, &b| g.vw[b].partial_cmp(&g.vw[a]).unwrap());

    let mut assign = vec![u32::MAX; n];
    let mut load = vec![0.0f64; k];
    for &v in &order {
        // gain of each part = connectivity to it; prefer connected &
        // light parts.
        let mut conn = vec![0.0f64; k];
        for &(u, w) in &g.adj[v] {
            let p = assign[u as usize];
            if p != u32::MAX {
                conn[p as usize] += w;
            }
        }
        let min_load = load.iter().cloned().fold(f64::INFINITY, f64::min);
        let mut best = 0usize;
        let mut best_score = f64::NEG_INFINITY;
        for p in 0..k {
            // Hard-ish balance: avoid parts already > 1.3x the lightest
            // + average node weight.
            if load[p] > min_load + g.vw[v].max(1.0) * 4.0 && k > 1 {
                continue;
            }
            let score = conn[p] - 0.01 * load[p];
            if score > best_score {
                best_score = score;
                best = p;
            }
        }
        assign[v] = best as u32;
        load[best] += g.vw[v];
    }
    assign
}

/// Boundary FM refinement passes at one level.
fn refine(g: &WGraph, assign: &mut [u32], k: usize, cfg: &MetisConfig) {
    let total: f64 = g.vw.iter().sum();
    let cap = (1.0 + cfg.balance_eps) * total / k as f64;
    let mut load = vec![0.0f64; k];
    for (v, &p) in assign.iter().enumerate() {
        load[p as usize] += g.vw[v];
    }
    for _ in 0..cfg.refine_passes {
        let mut moved = 0usize;
        for v in 0..g.len() {
            let cur = assign[v] as usize;
            let mut conn = vec![0.0f64; k];
            for &(u, w) in &g.adj[v] {
                conn[assign[u as usize] as usize] += w;
            }
            let mut best = cur;
            let mut best_gain = 0.0;
            for p in 0..k {
                if p == cur {
                    continue;
                }
                if load[p] + g.vw[v] > cap {
                    continue;
                }
                // don't empty the source part
                if load[cur] - g.vw[v] <= 0.0 {
                    continue;
                }
                let gain = conn[p] - conn[cur];
                if gain > best_gain {
                    best_gain = gain;
                    best = p;
                }
            }
            if best != cur {
                load[cur] -= g.vw[v];
                load[best] += g.vw[v];
                assign[v] = best as u32;
                moved += 1;
            }
        }
        if moved == 0 {
            break;
        }
    }
}

/// Multilevel k-way min-cut partition of `g` (the PSGD-PA / LLCG and
/// SuperTMA-cluster substrate). Returns a node -> part assignment.
pub fn metis_like(
    g: &Graph,
    k: usize,
    cfg: &MetisConfig,
    rng: &mut Rng,
) -> Vec<u32> {
    assert!(k >= 1);
    if k == 1 {
        return vec![0; g.num_nodes()];
    }
    let target = cfg.coarsen_target.max(8 * k);

    // Build hierarchy.
    let mut levels: Vec<WGraph> = vec![WGraph::from_graph(g)];
    let mut maps: Vec<Vec<u32>> = Vec::new();
    while levels.last().unwrap().len() > target {
        let (coarse, map) = coarsen_once(levels.last().unwrap(), rng);
        // stop if coarsening stalls (e.g. star graphs)
        if coarse.len() as f64 > levels.last().unwrap().len() as f64 * 0.95 {
            break;
        }
        maps.push(map);
        levels.push(coarse);
    }

    // Initial partition on the coarsest level.
    let mut assign = initial_partition(levels.last().unwrap(), k, rng);
    refine(levels.last().unwrap(), &mut assign, k, cfg);

    // Project back + refine at each level.
    for li in (0..maps.len()).rev() {
        let fine_n = levels[li].len();
        let mut fine_assign = vec![0u32; fine_n];
        for v in 0..fine_n {
            fine_assign[v] = assign[maps[li][v] as usize];
        }
        assign = fine_assign;
        refine(&levels[li], &mut assign, k, cfg);
    }
    assign
}

/// Coarsening-based clustering into ~`n_clusters` mini-clusters — the
/// SuperTMA "super-node" generator (paper footnote 3: ClusterGCN-style
/// mini-clusters used for *partitioning* rather than mini-batching).
pub fn cluster_coarsen(g: &Graph, n_clusters: usize, rng: &mut Rng) -> Vec<u32> {
    let n = g.num_nodes();
    if n_clusters >= n {
        return (0..n as u32).collect();
    }
    let mut wg = WGraph::from_graph(g);
    // identity composition of per-level maps
    let mut cluster_of: Vec<u32> = (0..n as u32).collect();
    while wg.len() > n_clusters {
        let (coarse, map) = coarsen_once(&wg, rng);
        if coarse.len() as f64 > wg.len() as f64 * 0.98 {
            break; // stalled
        }
        for c in cluster_of.iter_mut() {
            *c = map[*c as usize];
        }
        wg = coarse;
    }
    cluster_of
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::{dcsbm, sbm2, DcsbmConfig, Sbm2Config};
    use crate::partition::{partition_stats, random_partition};

    fn community_graph(seed: u64) -> Graph {
        dcsbm(&DcsbmConfig {
            nodes: 900,
            communities: 6,
            avg_degree: 14.0,
            homophily: 0.92,
            feat_dim: 4,
            feature_noise: 0.2,
            degree_exponent: 0.0,
            seed,
        })
    }

    #[test]
    fn produces_balanced_parts() {
        let g = community_graph(1);
        let mut rng = Rng::new(2);
        let assign = metis_like(&g, 3, &MetisConfig::default(), &mut rng);
        let stats = partition_stats(&g, &assign, 3);
        assert!(
            stats.balance < 1.35,
            "imbalanced: {:?}",
            stats.part_sizes
        );
        assert!(stats.part_sizes.iter().all(|&s| s > 0));
    }

    #[test]
    fn beats_random_on_edge_cut() {
        let g = community_graph(3);
        let mut rng = Rng::new(4);
        let metis = metis_like(&g, 3, &MetisConfig::default(), &mut rng);
        let rand = random_partition(g.num_nodes(), 3, &mut rng);
        let cut_m = partition_stats(&g, &metis, 3).edge_cut;
        let cut_r = partition_stats(&g, &rand, 3).edge_cut;
        assert!(
            (cut_m as f64) < cut_r as f64 * 0.5,
            "metis cut {cut_m} vs random {cut_r}"
        );
    }

    #[test]
    fn two_class_sbm_separates_classes() {
        // Lemma 1's setting: min-cut on a homophilic 2-class graph
        // should align parts with classes (high label purity).
        let g = sbm2(&Sbm2Config {
            class_size: 400,
            avg_degree: 16.0,
            homophily: 0.9,
            seed: 5,
        });
        let mut rng = Rng::new(6);
        let assign = metis_like(&g, 2, &MetisConfig::default(), &mut rng);
        let stats = partition_stats(&g, &assign, 2);
        // class disparity should be near its maximum (sqrt 2 for onehot)
        assert!(
            stats.class_disparity > 0.8,
            "disparity {}",
            stats.class_disparity
        );
    }

    #[test]
    fn k1_trivial() {
        let g = community_graph(7);
        let mut rng = Rng::new(8);
        let a = metis_like(&g, 1, &MetisConfig::default(), &mut rng);
        assert!(a.iter().all(|&p| p == 0));
    }

    #[test]
    fn cluster_coarsen_reaches_target() {
        let g = community_graph(9);
        let mut rng = Rng::new(10);
        let clusters = cluster_coarsen(&g, 64, &mut rng);
        let distinct: std::collections::HashSet<_> = clusters.iter().collect();
        assert!(distinct.len() <= 96, "too many clusters: {}", distinct.len());
        assert!(distinct.len() >= 16, "too few clusters: {}", distinct.len());
        assert_eq!(clusters.len(), g.num_nodes());
    }

    #[test]
    fn cluster_coarsen_groups_connected_nodes() {
        // On a disconnected pair of cliques, clusters never span both.
        use crate::graph::GraphBuilder;
        let mut b = GraphBuilder::new(20);
        for u in 0..10u32 {
            for v in (u + 1)..10 {
                b.add_edge(u, v);
                b.add_edge(u + 10, v + 10);
            }
        }
        let g = b.build();
        let mut rng = Rng::new(11);
        let clusters = cluster_coarsen(&g, 4, &mut rng);
        for u in 0..10 {
            for v in 10..20 {
                assert_ne!(clusters[u], clusters[v], "cluster spans cliques");
            }
        }
    }

    #[test]
    fn prop_metis_valid_assignment() {
        crate::util::prop::check(8, 12, |rng: &mut Rng| {
            let g = dcsbm(&DcsbmConfig {
                nodes: rng.range(50, 300),
                communities: rng.range(2, 8),
                avg_degree: 8.0,
                homophily: 0.8,
                feat_dim: 2,
                feature_noise: 0.3,
                degree_exponent: 0.0,
                seed: rng.next_u64(),
            });
            let k = rng.range(2, 6);
            let assign = metis_like(&g, k, &MetisConfig::default(), rng);
            crate::prop_assert!(assign.len() == g.num_nodes());
            crate::prop_assert!(assign.iter().all(|&p| (p as usize) < k));
            let sizes = crate::partition::parts_of(&assign, k)
                .iter()
                .map(|p| p.len())
                .collect::<Vec<_>>();
            crate::prop_assert!(
                sizes.iter().all(|&s| s > 0),
                "empty part: {sizes:?}"
            );
            Ok(())
        });
    }
}
