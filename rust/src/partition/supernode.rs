//! SuperTMA's partition: random assignment of super-nodes (§3.2.2).
//!
//! `N >> M` mini-clusters from [`cluster_coarsen`] are treated as
//! super-nodes and assigned to the `M` trainers uniformly at random.
//! This keeps RandomTMA's expected data uniformity (each trainer gets
//! an i.i.d. sample of *clusters*) while retaining far more edges,
//! because intra-cluster edges always survive. Setting N = M recovers
//! the PSGD-PA scheme; N = |V| recovers RandomTMA.

use crate::graph::Graph;
use crate::util::rng::Rng;

use super::metis::cluster_coarsen;

/// Node -> trainer assignment via randomized super-node placement.
pub fn supernode_partition(
    g: &Graph,
    num_clusters: usize,
    k: usize,
    rng: &mut Rng,
) -> Vec<u32> {
    let clusters = cluster_coarsen(g, num_clusters, rng);
    let num_found = clusters.iter().copied().max().map(|m| m as usize + 1).unwrap_or(0);
    // Random cluster -> trainer map.
    let map: Vec<u32> = (0..num_found).map(|_| rng.below(k) as u32).collect();
    clusters.iter().map(|&c| map[c as usize]).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::{dcsbm, DcsbmConfig};
    use crate::partition::{partition_stats, random_partition};

    fn graph(seed: u64) -> Graph {
        dcsbm(&DcsbmConfig {
            nodes: 1500,
            communities: 10,
            avg_degree: 12.0,
            homophily: 0.9,
            feat_dim: 4,
            feature_noise: 0.3,
            degree_exponent: 0.5,
            seed,
        })
    }

    #[test]
    fn retains_more_edges_than_random() {
        // Table 2's central r ordering: r_random < r_super < r_mincut.
        let g = graph(1);
        let mut rng = Rng::new(2);
        let sup = supernode_partition(&g, 128, 3, &mut rng);
        let rand = random_partition(g.num_nodes(), 3, &mut rng);
        let r_sup = partition_stats(&g, &sup, 3).ratio_r;
        let r_rand = partition_stats(&g, &rand, 3).ratio_r;
        assert!(r_sup > r_rand + 0.05, "r_sup={r_sup} r_rand={r_rand}");
    }

    #[test]
    fn lower_disparity_than_mincut() {
        use crate::partition::{metis_like, MetisConfig};
        let g = graph(3);
        let mut rng = Rng::new(4);
        let sup = supernode_partition(&g, 256, 3, &mut rng);
        let cut = metis_like(&g, 3, &MetisConfig::default(), &mut rng);
        let d_sup = partition_stats(&g, &sup, 3).class_disparity;
        let d_cut = partition_stats(&g, &cut, 3).class_disparity;
        assert!(
            d_sup < d_cut * 0.7,
            "super disparity {d_sup} vs mincut {d_cut}"
        );
    }

    #[test]
    fn n_equals_v_degenerates_to_random_like() {
        let g = graph(5);
        let mut rng = Rng::new(6);
        let assign = supernode_partition(&g, g.num_nodes(), 3, &mut rng);
        let r = partition_stats(&g, &assign, 3).ratio_r;
        assert!((r - 1.0 / 3.0).abs() < 0.05, "r={r}");
    }

    #[test]
    fn prop_valid_assignment() {
        crate::util::prop::check(10, 7, |rng: &mut Rng| {
            let g = graph(rng.next_u64());
            let k = rng.range(2, 8);
            let n_clusters = rng.range(k, 512);
            let a = supernode_partition(&g, n_clusters, k, rng);
            crate::prop_assert!(a.len() == g.num_nodes());
            crate::prop_assert!(a.iter().all(|&p| (p as usize) < k));
            Ok(())
        });
    }
}
