//! The PJRT backend (feature `pjrt`): one PJRT client + the four
//! compiled entry points of one model variant. The optional AOT fast
//! path behind the [`super::ComputeBackend`] abstraction — the
//! default backend is [`super::native`].
//!
//! Follows /opt/xla-example/load_hlo: `HloModuleProto::from_text_file`
//! → `XlaComputation::from_proto` → `client.compile` → `execute`.
//! HLO **text** is the interchange format (64-bit-id protos from
//! jax >= 0.5 are rejected by xla_extension 0.5.1; the text parser
//! reassigns ids).
//!
//! Literal packing is name-driven against the manifest arg specs so a
//! schema drift between Python and rust fails with a clear error, and
//! shape mismatches are caught before they reach XLA.

use anyhow::{bail, Context, Result};

use crate::model::ModelState;
use crate::sampler::Block;

use super::manifest::{Dtype, EntrySpec, Manifest, ModelDims, VariantSpec};

/// f32 slice as raw little-endian bytes (x86-64 target).
fn f32_bytes(xs: &[f32]) -> &[u8] {
    // SAFETY: `f32` is 4-byte plain-old-data with no padding, the
    // slice is fully initialized, and `u8` has the weakest
    // alignment; the view borrows `xs` for its full length.
    unsafe {
        std::slice::from_raw_parts(xs.as_ptr() as *const u8, xs.len() * 4)
    }
}

fn i32_bytes(xs: &[i32]) -> &[u8] {
    // SAFETY: as `f32_bytes` — `i32` is 4-byte plain-old-data.
    unsafe {
        std::slice::from_raw_parts(xs.as_ptr() as *const u8, xs.len() * 4)
    }
}

/// Named argument sources for one call.
pub struct ArgSources<'a> {
    pub f32s: Vec<(&'a str, &'a [f32])>,
    pub i32s: Vec<(&'a str, &'a [i32])>,
}

impl<'a> ArgSources<'a> {
    fn lookup_f32(&self, name: &str) -> Option<&'a [f32]> {
        self.f32s.iter().find(|(n, _)| *n == name).map(|(_, s)| *s)
    }
    fn lookup_i32(&self, name: &str) -> Option<&'a [i32]> {
        self.i32s.iter().find(|(n, _)| *n == name).map(|(_, s)| *s)
    }
}

/// One model variant ready to execute. Entry points are compiled
/// **lazily on first use** — a TMA trainer only ever touches `train`,
/// a GGS worker only `grad`, the evaluator only `encode`/`score` — so
/// per-role startup compiles 1-2 HLO modules instead of 4 (a large
/// win on this single-core testbed; see EXPERIMENTS.md §Perf).
pub struct Engine {
    client: xla::PjRtClient,
    pub variant: VariantSpec,
    pub dims: ModelDims,
    pub impl_name: String,
    artifact_dir: std::path::PathBuf,
    exes: std::cell::RefCell<
        std::collections::BTreeMap<&'static str, std::rc::Rc<xla::PjRtLoadedExecutable>>,
    >,
}

impl Engine {
    /// Create the engine (PJRT client only; compiles lazily).
    pub fn load(manifest: &Manifest, variant: &str, impl_name: &str) -> Result<Engine> {
        let v = manifest.variant(variant)?.clone();
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("pjrt cpu client: {e}"))?;
        Ok(Engine {
            client,
            variant: v,
            dims: manifest.dims,
            impl_name: impl_name.to_string(),
            artifact_dir: manifest.dir.clone(),
            exes: Default::default(),
        })
    }

    /// Compiled executable for `entry`, compiling on first use.
    fn exe(&self, entry: &'static str) -> Result<std::rc::Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.exes.borrow().get(entry) {
            return Ok(e.clone());
        }
        let path =
            self.variant
                .artifact_path(&self.artifact_dir, entry, &self.impl_name)?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("utf8 path")?,
        )
        .map_err(|e| anyhow::anyhow!("parse {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {}: {e}", path.display()))?;
        let rc = std::rc::Rc::new(exe);
        self.exes.borrow_mut().insert(entry, rc.clone());
        Ok(rc)
    }

    /// Eagerly compile all four entry points (doctor / benches).
    pub fn compile_all(&self) -> Result<()> {
        self.prepare(&["train", "grad", "encode", "score"])
    }

    /// Eagerly compile a role's entry points. Trainers call this
    /// BEFORE marking ready so the server's ΔT_train clock (which
    /// starts at the ready barrier) never overlaps compilation.
    pub fn prepare(&self, entries: &[&'static str]) -> Result<()> {
        for entry in entries {
            self.exe(entry)?;
        }
        Ok(())
    }

    pub fn hetero(&self) -> bool {
        self.variant.hetero
    }

    pub fn param_total(&self) -> usize {
        self.variant.param_total
    }

    /// Pack literals for `entry` from named sources, in manifest order.
    fn pack(&self, entry: &EntrySpec, src: &ArgSources) -> Result<Vec<xla::Literal>> {
        let mut out = Vec::with_capacity(entry.args.len());
        for a in &entry.args {
            let lit = match a.dtype {
                Dtype::F32 => {
                    let s = src
                        .lookup_f32(&a.name)
                        .with_context(|| format!("missing f32 arg {:?}", a.name))?;
                    if s.len() != a.elements() {
                        bail!(
                            "arg {:?}: have {} elements, artifact wants {:?}",
                            a.name,
                            s.len(),
                            a.shape
                        );
                    }
                    xla::Literal::create_from_shape_and_untyped_data(
                        xla::ElementType::F32,
                        &a.shape,
                        f32_bytes(s),
                    )
                    .map_err(|e| anyhow::anyhow!("literal {}: {e}", a.name))?
                }
                Dtype::I32 => {
                    let s = src
                        .lookup_i32(&a.name)
                        .with_context(|| format!("missing i32 arg {:?}", a.name))?;
                    if s.len() != a.elements() {
                        bail!(
                            "arg {:?}: have {} elements, artifact wants {:?}",
                            a.name,
                            s.len(),
                            a.shape
                        );
                    }
                    xla::Literal::create_from_shape_and_untyped_data(
                        xla::ElementType::S32,
                        &a.shape,
                        i32_bytes(s),
                    )
                    .map_err(|e| anyhow::anyhow!("literal {}: {e}", a.name))?
                }
            };
            out.push(lit);
        }
        Ok(out)
    }

    fn run(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        entry: &EntrySpec,
        src: &ArgSources,
    ) -> Result<Vec<xla::Literal>> {
        let args = self.pack(entry, src)?;
        let result = exe
            .execute::<xla::Literal>(&args)
            .map_err(|e| anyhow::anyhow!("execute: {e}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("to_literal: {e}"))?;
        // Artifacts are lowered with return_tuple=True.
        let parts = lit
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untuple: {e}"))?;
        if parts.len() != entry.outputs.len() {
            bail!(
                "output arity mismatch: got {}, manifest says {}",
                parts.len(),
                entry.outputs.len()
            );
        }
        Ok(parts)
    }

    /// Block argument views shared by train/grad packing. `feats` is
    /// the sampler's gather buffer — rows copied out of the graph's
    /// FeatureStore (owned, shared-slab or mmap'd backends read
    /// bit-identically), so literal packing is backend-agnostic and
    /// the raw-LE byte view below stays valid for every store.
    fn block_sources<'a>(
        &self,
        params: &'a [f32],
        block: &'a Block,
    ) -> ArgSources<'a> {
        ArgSources {
            f32s: vec![
                ("params", params),
                ("feats", &block.feats),
                ("adj", &block.adj),
                ("mask", &block.mask),
            ],
            i32s: vec![
                ("pos_u", &block.pos_u),
                ("pos_v", &block.pos_v),
                ("rel", &block.rel),
                ("neg_v", &block.neg_v),
            ],
        }
    }

    /// One fused Adam step on `state` from `block`. Returns the loss.
    pub fn train_step(&self, state: &mut ModelState, block: &Block) -> Result<f32> {
        let entry = self.variant.entry("train")?.clone();
        let mut src = self.block_sources(&state.params, block);
        src.f32s.push(("adam_m", &state.adam_m));
        src.f32s.push(("adam_v", &state.adam_v));
        src.f32s.push(("adam_t", &state.adam_t));
        let out = self.run(&*self.exe("train")?, &entry, &src)?;
        // outputs: params', m', v', t', loss
        out[0]
            .copy_raw_to::<f32>(&mut state.params)
            .map_err(|e| anyhow::anyhow!("params out: {e}"))?;
        out[1]
            .copy_raw_to::<f32>(&mut state.adam_m)
            .map_err(|e| anyhow::anyhow!("m out: {e}"))?;
        out[2]
            .copy_raw_to::<f32>(&mut state.adam_v)
            .map_err(|e| anyhow::anyhow!("v out: {e}"))?;
        out[3]
            .copy_raw_to::<f32>(&mut state.adam_t)
            .map_err(|e| anyhow::anyhow!("t out: {e}"))?;
        let loss = out[4]
            .get_first_element::<f32>()
            .map_err(|e| anyhow::anyhow!("loss out: {e}"))?;
        Ok(loss)
    }

    /// Loss + gradient w.r.t. the flat params (GGS / LLCG correction).
    pub fn grad_step(&self, params: &[f32], block: &Block) -> Result<(Vec<f32>, f32)> {
        let entry = self.variant.entry("grad")?.clone();
        let src = self.block_sources(params, block);
        let out = self.run(&*self.exe("grad")?, &entry, &src)?;
        let mut g = vec![0f32; self.variant.param_total];
        out[0]
            .copy_raw_to::<f32>(&mut g)
            .map_err(|e| anyhow::anyhow!("grad out: {e}"))?;
        let loss = out[1]
            .get_first_element::<f32>()
            .map_err(|e| anyhow::anyhow!("loss out: {e}"))?;
        Ok((g, loss))
    }

    /// Node embeddings `[Bn, H]` (row-major) for one eval block.
    pub fn encode(&self, params: &[f32], block: &Block) -> Result<Vec<f32>> {
        let entry = self.variant.entry("encode")?.clone();
        let src = ArgSources {
            f32s: vec![
                ("params", params),
                ("feats", &block.feats),
                ("adj", &block.adj),
            ],
            i32s: vec![],
        };
        let out = self.run(&*self.exe("encode")?, &entry, &src)?;
        let mut emb = vec![0f32; self.dims.block_nodes * self.dims.hidden];
        out[0]
            .copy_raw_to::<f32>(&mut emb)
            .map_err(|e| anyhow::anyhow!("emb out: {e}"))?;
        Ok(emb)
    }

    /// Decoder scores for `S` (emb_u, emb_v[, rel]) pairs.
    pub fn score(
        &self,
        params: &[f32],
        emb_u: &[f32],
        emb_v: &[f32],
        rel: &[i32],
    ) -> Result<Vec<f32>> {
        let entry = self.variant.entry("score")?.clone();
        let src = ArgSources {
            f32s: vec![("params", params), ("emb_u", emb_u), ("emb_v", emb_v)],
            i32s: vec![("rel", rel)],
        };
        let out = self.run(&*self.exe("score")?, &entry, &src)?;
        let mut scores = vec![0f32; self.dims.score_batch];
        out[0]
            .copy_raw_to::<f32>(&mut scores)
            .map_err(|e| anyhow::anyhow!("score out: {e}"))?;
        Ok(scores)
    }

    /// Quick smoke summary used by `rtma doctor`.
    pub fn describe(&self) -> String {
        format!(
            "{} ({}) P={} median |param| n/a",
            self.variant.name, self.impl_name, self.variant.param_total
        )
    }
}
